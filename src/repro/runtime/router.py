"""Multi-replica SLO-aware router (DESIGN.md Section 13).

``RouterEngine`` fronts N ``ServeEngine``/``MeshServeEngine`` replicas
behind the single submission API the rest of the stack already speaks
(``add`` + ``step``/``run`` -> rid-keyed outputs).  Per router tick it:

  1. moves arrived requests into the bounded EDF admission queue
     (``runtime.slo.AdmissionQueue`` — infeasible/overflow/expired work
     is shed deterministically, never backlogged without bound);
  2. fires any due replica-level faults (``runtime.fault.ReplicaFault``),
     drains the dead replica — in-flight requests are replayed from
     scratch on survivors (attribution ``RETRIED``) or promoted to their
     live hedge copy — and readmits recovered replicas with a fresh
     engine;
  3. steps the degradation ladder (``runtime.slo.DegradationLadder``)
     off queue pressure and applies its level to every live replica
     (chunk cap -> degraded Mode -> priority shed);
  4. dispatches feasible queue entries to the least-loaded live replica
     (ties to the lowest index) while any replica has a free slot;
  5. hedges stalled requests: no first token within ``hedge_after``
     ticks of dispatch re-dispatches the request to a second replica —
     greedy decode is deterministic and row-independent, so both copies
     produce the *same* token stream and whichever finishes first wins
     token-exactly while the loser is cancelled mid-flight
     (``ServeEngine.cancel``);
  6. ticks every live replica (index order) and harvests completions.

Every decision is a pure function of (trace seed, tick counter): replica
choice is (load, index)-ordered, queue order is the EDF key, fault sites
fire by tick — the chaos tier replays routing exactly and the bench
regression gate compares shed counts and TTFT percentiles with ``==``.

Time is virtual: one router tick is one SLO "millisecond"
(``runtime.slo``).  TTFT/completion latencies are measured in router
ticks; inter-token latency uses the winning engine's own clock
(``RequestOutput.token_steps``), which advances one step per fused
decode row.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .engine import Attribution, Request
from .fault import ReplicaFault
from .slo import (AdmissionQueue, CostModel, DegradationConfig,
                  DegradationLadder, ShedEvent)


@dataclasses.dataclass
class RouterOutput:
    """Router-side per-request record.  ``submit``/``dispatch``/
    ``first_token``/``finished`` are router ticks (-1 = not yet);
    ``token_steps`` is the winning engine's per-token clock (for
    inter-token latency); ``attribution`` says how the request was
    served (``runtime.engine.Attribution``)."""

    rid: int
    submit: int
    tokens: List[int] = dataclasses.field(default_factory=list)
    token_steps: List[int] = dataclasses.field(default_factory=list)
    dispatch: int = -1
    first_token: int = -1
    finished: int = -1
    replica: int = -1
    attribution: Attribution = Attribution.NORMAL
    shed_reason: Optional[str] = None
    retries: int = 0
    hedged: bool = False


@dataclasses.dataclass
class ReplicaHandle:
    index: int
    engine: object            # ServeEngine / MeshServeEngine
    up: bool = True
    rejoin_at: Optional[int] = None

    def state(self) -> str:
        """Tick-phase classification for replica fault sites: would the
        engine admit this tick ("prefill"), is it decoding ("decode"),
        or neither ("idle")."""
        eng = self.engine
        if eng.sched.would_admit(eng.clock):
            return "prefill"
        return "decode" if eng.sched.running else "idle"


@dataclasses.dataclass
class _Dispatch:
    """In-flight bookkeeping: where a request currently runs and the
    frozen deadline it was admitted under (absolute ticks)."""

    rid: int
    req: Request              # the dispatched copy (arrival=0)
    replica: int
    tick: int                 # dispatch tick (hedge timer base)
    deadline: Optional[int]
    hedge: Optional[int] = None


class RouterEngine:
    """SLO-aware multi-replica serving router (module docstring has the
    tick anatomy).  ``make_engine`` is called once per replica — and
    again when a killed replica rejoins, so recovery never trusts a dead
    engine's state.

    ``queue_bound=None`` is the unbounded baseline; ``hedge_after=None``
    disables hedging; ``degradation=None`` disables the ladder.
    ``target_depth`` only feeds the ladder's pressure signal when the
    queue is unbounded.
    """

    def __init__(self, make_engine: Callable[[], object],
                 num_replicas: int, *,
                 queue_bound: Optional[int] = None,
                 cost_model: Optional[CostModel] = None,
                 hedge_after: Optional[int] = None,
                 degradation: Optional[DegradationConfig] = None,
                 replica_faults: Sequence[ReplicaFault] = (),
                 target_depth: int = 8):
        if num_replicas < 1:
            raise ValueError("need at least one replica")
        if hedge_after is not None and hedge_after < 1:
            raise ValueError("hedge_after must be >= 1 tick")
        self._make_engine = make_engine
        self.replicas = [ReplicaHandle(i, make_engine())
                         for i in range(num_replicas)]
        self.queue = AdmissionQueue(queue_bound, cost_model)
        self.hedge_after = hedge_after
        self.ladder = (DegradationLadder(degradation)
                       if degradation is not None else None)
        self.faults = list(replica_faults)
        self.target_depth = max(1, target_depth)
        self.clock = 0
        self.outputs: Dict[int, RouterOutput] = {}
        self._arrivals: List[Tuple[int, int, Request]] = []   # (arrival, rid)
        self._inflight: Dict[int, _Dispatch] = {}
        self.health_log: List[Dict] = []
        self.stats = {"submitted": 0, "dispatches": 0, "completed": 0,
                      "shed": 0, "retried": 0, "hedged": 0}

    # -- submission ---------------------------------------------------------

    def add(self, req: Request) -> None:
        if req.rid in self.outputs:
            raise ValueError(f"duplicate rid {req.rid}")
        heapq.heappush(self._arrivals, (req.arrival, req.rid, req))
        self.outputs[req.rid] = RouterOutput(rid=req.rid,
                                             submit=max(req.arrival, 0))
        self.stats["submitted"] += 1

    def has_work(self) -> bool:
        return bool(self._arrivals or self.queue.depth or self._inflight)

    @property
    def up_replicas(self) -> List[ReplicaHandle]:
        return [h for h in self.replicas if h.up]

    # -- tick ---------------------------------------------------------------

    def step(self) -> None:
        """One router tick (one virtual SLO millisecond)."""
        self._admit_arrivals()
        self._fire_faults()
        self._rejoin_recovered()
        self._apply_ladder()
        self._dispatch_queue()
        self._hedge_stalled()
        for h in self.up_replicas:
            if h.engine.sched.has_work():
                h.engine.step()
        self._harvest()
        self.clock += 1

    def run(self, requests: Sequence[Request] = (),
            max_ticks: Optional[int] = None) -> Dict[int, RouterOutput]:
        """Drain: submit ``requests``, tick until every request finished
        or was shed (or ``max_ticks``), return rid -> RouterOutput."""
        for r in requests:
            self.add(r)
        ticks = 0
        while self.has_work():
            if not self.up_replicas and not any(
                    h.rejoin_at is not None for h in self.replicas):
                raise RuntimeError("no live replicas and no scheduled "
                                   "rejoin; queued work cannot complete")
            self.step()
            ticks += 1
            if max_ticks is not None and ticks >= max_ticks:
                break
        return self.outputs

    # -- tick phases --------------------------------------------------------

    def _admit_arrivals(self) -> None:
        while self._arrivals and self._arrivals[0][0] <= self.clock:
            _, _, req = heapq.heappop(self._arrivals)
            ev = self.queue.push(req, self.clock, self._bucket(req))
            if ev is not None:
                self._record_shed(ev)

    def _bucket(self, req: Request) -> Optional[int]:
        for h in self.replicas:         # replicas share one config; any
            if h.engine is not None:    # live engine's bucketing will do
                return h.engine.bucket_for(req.prompt_len)
        return None

    def _record_shed(self, ev: ShedEvent) -> None:
        out = self.outputs[ev.rid]
        out.attribution = Attribution.SHED
        out.shed_reason = ev.reason.value
        out.finished = -1
        self.stats["shed"] += 1
        # a displaced/expired entry may already have an in-flight record
        # (it cannot — sheds only happen pre-dispatch — but keep the
        # bookkeeping defensive and deterministic)
        self._inflight.pop(ev.rid, None)

    def _fire_faults(self) -> None:
        for fault in self.faults:
            h = self.replicas[fault.replica]
            if not h.up:
                continue
            if fault.poll(h.index, h.state(), self.clock):
                self._kill_replica(h, fault)

    def _kill_replica(self, h: ReplicaHandle, fault: ReplicaFault) -> None:
        h.up = False
        h.rejoin_at = (None if fault.recover_after is None
                       else self.clock + fault.recover_after)
        drained = sorted(rid for rid, rec in self._inflight.items()
                         if h.index in (rec.replica, rec.hedge))
        self.health_log.append({"tick": self.clock, "event": "kill",
                                "replica": h.index, "state": h.state(),
                                "drained": drained,
                                "rejoin_at": h.rejoin_at})
        for rid in drained:
            rec = self._inflight[rid]
            if rec.hedge is not None:
                # one copy survives: promote it (token streams are
                # identical, so nothing is lost)
                if rec.replica == h.index:
                    rec.replica, rec.hedge = rec.hedge, None
                else:
                    rec.hedge = None
                continue
            self._requeue(rec)
        # the dead engine's state is never trusted again; drop it so a
        # rejoin starts from a fresh make_engine() build
        h.engine = None

    def _requeue(self, rec: _Dispatch) -> None:
        """Replay a drained request from scratch: discard partial tokens
        (greedy replay regenerates the identical stream) and push it back
        through admission with its *original* absolute deadline."""
        out = self.outputs[rec.rid]
        out.tokens = []
        out.token_steps = []
        out.first_token = -1
        out.dispatch = -1
        out.replica = -1
        out.retries += 1
        if out.attribution in (Attribution.NORMAL, Attribution.HEDGED):
            out.attribution = Attribution.RETRIED
        self.stats["retried"] += 1
        del self._inflight[rec.rid]
        rel = (None if rec.deadline is None
               else rec.deadline - self.clock)
        req = dataclasses.replace(rec.req, deadline_ms=rel)
        ev = self.queue.push(req, self.clock, self._bucket(req))
        if ev is not None:
            self._record_shed(ev)

    def _rejoin_recovered(self) -> None:
        for h in self.replicas:
            if not h.up and h.rejoin_at is not None \
                    and self.clock >= h.rejoin_at:
                h.engine = self._make_engine()
                h.up = True
                h.rejoin_at = None
                self.health_log.append({"tick": self.clock,
                                        "event": "rejoin",
                                        "replica": h.index})

    def _apply_ladder(self) -> None:
        if self.ladder is None:
            return
        denom = self.queue.bound or self.target_depth
        level = self.ladder.update(self.queue.depth / denom, self.clock)
        cfg = self.ladder.cfg
        for h in self.up_replicas:
            eng = h.engine
            eng.chunk_cap = (max(cfg.min_chunk, eng.decode_chunk // 2)
                             if level >= 1 else None)
            eng.set_degraded(level >= 2)
        self.queue.shed_min_priority = (cfg.shed_min_priority
                                        if level >= 3 else None)

    def _dispatch_queue(self) -> None:
        while True:
            ready = [h for h in self.up_replicas
                     if h.engine.load < h.engine.num_slots]
            if not ready:
                return
            entry, expired = self.queue.pop(self.clock)
            for ev in expired:
                self._record_shed(ev)
            if entry is None:
                return
            h = min(ready, key=lambda h: (h.engine.load, h.index))
            self._dispatch_to(entry.req, h, deadline=entry.deadline)

    def _dispatch_to(self, req: Request, h: ReplicaHandle,
                     deadline: Optional[int],
                     hedge_of: Optional[_Dispatch] = None) -> None:
        copy = dataclasses.replace(req, arrival=0)
        h.engine.add(copy)
        self.stats["dispatches"] += 1
        if hedge_of is not None:
            hedge_of.hedge = h.index
            return
        out = self.outputs[req.rid]
        out.dispatch = self.clock
        out.replica = h.index
        self._inflight[req.rid] = _Dispatch(
            rid=req.rid, req=copy, replica=h.index, tick=self.clock,
            deadline=deadline)

    def _hedge_stalled(self) -> None:
        if self.hedge_after is None:
            return
        for rid in sorted(self._inflight):
            rec = self._inflight[rid]
            out = self.outputs[rid]
            if (rec.hedge is not None or out.first_token >= 0
                    or self.clock - rec.tick < self.hedge_after):
                continue
            spare = [h for h in self.up_replicas
                     if h.index != rec.replica
                     and h.engine.load < h.engine.num_slots]
            if not spare:
                continue
            h = min(spare, key=lambda h: (h.engine.load, h.index))
            self._dispatch_to(rec.req, h, deadline=rec.deadline,
                              hedge_of=rec)
            out.hedged = True
            if out.attribution == Attribution.NORMAL:
                out.attribution = Attribution.HEDGED
            self.stats["hedged"] += 1

    def _harvest(self) -> None:
        for rid in sorted(self._inflight):
            rec = self._inflight[rid]
            copies = [(rec.replica, False)]
            if rec.hedge is not None:
                copies.append((rec.hedge, True))
            winner = None
            for idx, is_hedge in copies:       # primary wins ties
                h = self.replicas[idx]
                if not h.up:
                    continue
                eo = h.engine.outputs.get(rid)
                if eo is None:
                    continue
                out = self.outputs[rid]
                if out.first_token < 0 and eo.tokens:
                    out.first_token = self.clock
                if eo.finished >= 0 and winner is None:
                    winner = (idx, eo)
            if winner is None:
                continue
            idx, eo = winner
            out = self.outputs[rid]
            out.tokens = list(eo.tokens)
            out.token_steps = list(eo.token_steps)
            out.finished = self.clock
            out.replica = idx
            loser = rec.hedge if idx == rec.replica else rec.replica
            if loser is not None and self.replicas[loser].up:
                eng = self.replicas[loser].engine
                eng.cancel(rid)
                eng.outputs.pop(rid, None)
            del self._inflight[rid]
            self.stats["completed"] += 1

    # -- reporting ----------------------------------------------------------

    @property
    def max_queue_depth(self) -> int:
        return self.queue.max_depth

    @property
    def shed_log(self) -> List[ShedEvent]:
        return self.queue.shed_log
