"""Serving runtime: batched prefill + decode with sharded KV caches."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.registry import ModelApi
from .sharding import shard_batch, shard_cache, shard_params


def jit_serve_fns(api: ModelApi, mesh: Mesh, batch: int, cache_len: int,
                  fsdp: bool = False):
    """Returns (prefill_fn, decode_fn, shardings).

    Serving defaults to fsdp=False: parameters live model-sharded and
    replicated over the data axis so decode steps pay no per-step parameter
    all-gathers (the train-path FSDP layout would; see EXPERIMENTS.md
    Section Perf).
    """
    p_shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    p_sh = shard_params(p_shapes, mesh, fsdp=fsdp)
    cache_shapes = jax.eval_shape(lambda: api.init_cache(batch, cache_len))
    c_sh = shard_cache(cache_shapes, mesh, batch)
    rep = NamedSharding(mesh, P())

    def prefill_fn(params, inp):
        return api.prefill(params, inp, cache_len=cache_len)

    def decode_fn(params, cache, token):
        return api.decode_step(params, cache, token)

    logits_sh = NamedSharding(mesh, P(*(("pod", "data") if "pod" in
                                        mesh.axis_names else ("data",)),)
                              ) if batch % _dp(mesh) == 0 else rep
    prefill_jit = jax.jit(prefill_fn,
                          in_shardings=(p_sh, None),
                          out_shardings=(c_sh, None))
    decode_jit = jax.jit(decode_fn,
                         in_shardings=(p_sh, c_sh, None),
                         out_shardings=(None, c_sh),
                         donate_argnums=(1,))
    return prefill_jit, decode_jit, (p_sh, c_sh)


def _dp(mesh: Mesh) -> int:
    n = mesh.shape.get("data", 1)
    n *= mesh.shape.get("pod", 1)
    return n


def greedy_generate(api: ModelApi, params, batch: Dict, steps: int,
                    cache_len: int):
    """Reference generation loop (CPU-scale); real serving drives the jitted
    fns from launch/serve.py with continuous batching."""
    cache, logits = api.prefill(params, batch, cache_len=cache_len)
    toks = [jnp.argmax(logits, -1).astype(jnp.int32)[:, None]]
    for _ in range(steps - 1):
        logits, cache = api.decode_step(params, cache, toks[-1])
        toks.append(jnp.argmax(logits, -1).astype(jnp.int32)[:, None])
    return jnp.concatenate(toks, axis=1)
