"""Serving runtime: batched prefill + decode with sharded KV caches, the
fused multi-step decode chunk (DESIGN.md Section 9), and prompt-bucket
padding shared by the engine and its greedy oracle.

``jit_serve_fns`` is the *lockstep* sharded factory (dp logits, pooled
decode); the mesh-parallel slot-pool engine builds its per-Mode jit sets
from ``runtime.mesh_serve.mesh_serve_fns`` instead, which reuses
``make_chunk_ladder``/``make_decode_chunk_fn`` below with the serving
layout's explicit shardings (DESIGN.md Section 10).

Everything here is stateless in the engine's failure-handling sense: these
factories hold no arena or scheduler state, so elastic recovery (DESIGN.md
Section 11) rebuilds them freely on the post-loss mesh — only the jit
caches are lost, never tokens."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.registry import ModelApi
from .sharding import shard_batch, shard_cache, shard_params


def pad_prompt_batch(batch: Dict[str, jax.Array],
                     bucket: Optional[int]) -> Dict[str, jax.Array]:
    """Right-pad ``batch["tokens"]`` to ``bucket`` and record the true
    prompt lengths under ``"lengths"`` — the input contract of every
    family's bucketed prefill (DESIGN.md Section 9).  ``bucket=None`` is
    the identity (exact-length prefill, no lengths threaded), so callers
    can pass ``ServeEngine.bucket_for(...)`` verbatim."""
    if bucket is None:
        return batch
    toks = batch["tokens"]
    B, S = toks.shape
    if bucket < S:
        raise ValueError(f"bucket {bucket} shorter than prompt {S}")
    out = dict(batch)
    out["tokens"] = jnp.pad(toks, ((0, 0), (0, bucket - S)))
    out["lengths"] = jnp.full((B,), S, jnp.int32)
    return out


def make_chunk_ladder(api: ModelApi, decode_chunk: int,
                      jit_wrap: Callable[[Callable], Callable]) -> Callable:
    """Build ``chunk_for(n)``: a memoized fused-chunk executable per scan
    length on the engine's power-of-two ladder 1..``decode_chunk``
    (``ServeEngine._chunk_len``), so at most log2(decode_chunk)+1 traces
    exist per mode.  ``jit_wrap`` supplies the jit policy (plain donation
    for single-host, shardings on a mesh); the cap is validated here so
    both paths enforce the same ladder contract."""
    cache: Dict[int, Callable] = {}

    def chunk_for(n: int) -> Callable:
        if n < 1 or n > decode_chunk:
            raise ValueError(f"chunk length {n} outside the configured "
                             f"ladder 1..{decode_chunk}")
        fn = cache.get(n)
        if fn is None:
            fn = jit_wrap(make_decode_chunk_fn(api, n))
            cache[n] = fn
        return fn

    return chunk_for


def make_decode_chunk_fn(api: ModelApi, decode_chunk: int) -> Callable:
    """Build the fused multi-step decode tick: one ``lax.scan`` over
    ``decode_chunk`` pooled decode steps with argmax, token feedback and
    per-slot bookkeeping all on device (DESIGN.md Section 9).

    Carry: (cache, tokens (B, 1) int32, remaining (B,) int32 — tokens each
    slot still owes, 0 for free/unadmitted slots).  Per step the live mask
    is ``remaining > 0``; live rows contribute their exact-zero logit
    fraction to a running (num, den) pair — the engine's workload-category
    measurement — and decrement ``remaining``.  Returns the small arrays
    the host actually needs: the (chunk, B) token ring plus the two
    measurement scalars.  Finished and never-admitted rows keep decoding
    garbage (row-wise independence makes that harmless — DESIGN.md
    Section 8); they are excluded from both the ring drain (host side) and
    the measurement (the live mask here).
    """

    def chunk_fn(params, cache, tokens, remaining):
        def body(carry, _):
            cache, tokens, remaining = carry
            logits, cache = api.decode_step(params, cache, tokens)
            toks = jnp.argmax(logits, -1).astype(jnp.int32)       # (B,)
            live = remaining > 0
            zf_rows = jnp.mean((logits == 0).astype(jnp.float32), axis=-1)
            zf_num = jnp.sum(zf_rows * live)
            zf_den = jnp.sum(live.astype(jnp.float32))
            remaining = remaining - live.astype(remaining.dtype)
            return (cache, toks[:, None], remaining), (toks, zf_num, zf_den)

        carry, (ring, nums, dens) = jax.lax.scan(
            body, (cache, tokens, remaining), length=decode_chunk)
        cache, tokens, remaining = carry
        return cache, tokens, remaining, ring, nums.sum(), dens.sum()

    return chunk_fn


def jit_serve_fns(api: ModelApi, mesh: Mesh, batch: int, cache_len: int,
                  fsdp: bool = False, params: Optional[Any] = None,
                  decode_chunk: int = 8):
    """Returns (prefill_fn, decode_fn, chunk_for, (p_sh, c_sh, logits_sh)).

    ``params`` is the tree actually being served — pass it whenever it is
    not shaped like ``api.init``'s output (block-compacted ``GriffinWeights``
    leaves from ``sparsity.sparsify_params`` replace single arrays with
    metadata subtrees, each needing its own spec from
    ``runtime.sharding.param_spec``); defaults to the dense init shapes.

    These are the fns the serving engine drives (``runtime.engine
    .ServeEngine`` takes ``lambda: jit_serve_fns(...)`` as its fns
    factory): ``prefill_fn`` admits one request (its output cache is
    slot-inserted into the pool arena), ``decode_fn`` advances the whole
    pool one step, and ``chunk_for(n)`` returns the fused n-step tick the
    engine actually serves with (up to ``decode_chunk`` pooled steps per
    host round-trip; see :func:`make_decode_chunk_fn`) — cache, token and
    remaining buffers all donated so the arena updates in place.
    ``logits_sh`` is the dp-sharded logits layout both fns produce — it
    assumes the pool batch divides the dp axes, so batch-1 admission
    prefills need a 1-dp mesh (multi-host serving buckets prefills on a
    separate dp=1 mesh; see DESIGN.md Section 8).

    Serving defaults to fsdp=False: parameters live model-sharded and
    replicated over the data axis so decode steps pay no per-step parameter
    all-gathers (the train-path FSDP layout would; see EXPERIMENTS.md
    Section Perf).
    """
    p_shapes = (jax.eval_shape(api.init, jax.random.PRNGKey(0))
                if params is None else params)
    p_sh = shard_params(p_shapes, mesh, fsdp=fsdp)
    cache_shapes = jax.eval_shape(lambda: api.init_cache(batch, cache_len))
    c_sh = shard_cache(cache_shapes, mesh, batch)
    rep = NamedSharding(mesh, P())

    def prefill_fn(params, inp):
        return api.prefill(params, inp, cache_len=cache_len)

    def decode_fn(params, cache, token):
        return api.decode_step(params, cache, token)

    logits_sh = NamedSharding(mesh, P(*(("pod", "data") if "pod" in
                                        mesh.axis_names else ("data",)),)
                              ) if batch % _dp(mesh) == 0 else rep
    prefill_jit = jax.jit(prefill_fn,
                          in_shardings=(p_sh, None),
                          out_shardings=(c_sh, logits_sh))
    decode_jit = jax.jit(decode_fn,
                         in_shardings=(p_sh, c_sh, None),
                         out_shardings=(logits_sh, c_sh),
                         donate_argnums=(1,))
    chunk_for = make_chunk_ladder(
        api, decode_chunk,
        lambda fn: jax.jit(fn,
                           in_shardings=(p_sh, c_sh, rep, rep),
                           out_shardings=(c_sh, rep, rep, rep, rep, rep),
                           donate_argnums=(1, 2, 3)))
    return prefill_jit, decode_jit, chunk_for, (p_sh, c_sh, logits_sh)


def _dp(mesh: Mesh) -> int:
    n = mesh.shape.get("data", 1)
    n *= mesh.shape.get("pod", 1)
    return n


def greedy_generate(api: ModelApi, params, batch: Dict, steps: int,
                    cache_len: int, prompt_bucket: Optional[int] = None):
    """Reference generation loop, one static batch in lockstep — the parity
    oracle for the continuous-batching engine (``runtime.engine``): per-slot
    decode is row-wise independent, so the engine's tokens for a request
    must match a batch-1 greedy run of the same prompt token for token
    (tests/test_engine.py asserts this, dense and sparse).

    ``prompt_bucket`` replays the engine's bucketed-prefill path (pass
    ``engine.bucket_for(prompt_len)``): the prompt is right-padded to the
    bucket with lengths threaded, so the oracle runs the *same padded
    computation* the engine admitted the request with — the definition of
    token parity under bucketing (DESIGN.md Section 9)."""
    batch = pad_prompt_batch(batch, prompt_bucket)
    cache, logits = api.prefill(params, batch, cache_len=cache_len)
    toks = [jnp.argmax(logits, -1).astype(jnp.int32)[:, None]]
    for _ in range(steps - 1):
        logits, cache = api.decode_step(params, cache, toks[-1])
        toks.append(jnp.argmax(logits, -1).astype(jnp.int32)[:, None])
    return jnp.concatenate(toks, axis=1)
