"""Serving runtime: batched prefill + decode with sharded KV caches."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.registry import ModelApi
from .sharding import shard_batch, shard_cache, shard_params


def jit_serve_fns(api: ModelApi, mesh: Mesh, batch: int, cache_len: int,
                  fsdp: bool = False, params: Optional[Any] = None):
    """Returns (prefill_fn, decode_fn, (p_sh, c_sh, logits_sh)).

    ``params`` is the tree actually being served — pass it whenever it is
    not shaped like ``api.init``'s output (block-compacted ``GriffinWeights``
    leaves from ``sparsity.sparsify_params`` replace single arrays with
    metadata subtrees, each needing its own spec from
    ``runtime.sharding.param_spec``); defaults to the dense init shapes.

    These are the fns the serving engine drives (``runtime.engine
    .ServeEngine`` takes ``lambda: jit_serve_fns(...)`` as its fns
    factory): ``prefill_fn`` admits one request (its output cache is
    slot-inserted into the pool arena), ``decode_fn`` advances the whole
    pool with the cache donated so the arena updates in place.
    ``logits_sh`` is the dp-sharded logits layout both fns produce — it
    assumes the pool batch divides the dp axes, so batch-1 admission
    prefills need a 1-dp mesh (multi-host serving buckets prefills on a
    separate dp=1 mesh; see DESIGN.md Section 8).

    Serving defaults to fsdp=False: parameters live model-sharded and
    replicated over the data axis so decode steps pay no per-step parameter
    all-gathers (the train-path FSDP layout would; see EXPERIMENTS.md
    Section Perf).
    """
    p_shapes = (jax.eval_shape(api.init, jax.random.PRNGKey(0))
                if params is None else params)
    p_sh = shard_params(p_shapes, mesh, fsdp=fsdp)
    cache_shapes = jax.eval_shape(lambda: api.init_cache(batch, cache_len))
    c_sh = shard_cache(cache_shapes, mesh, batch)
    rep = NamedSharding(mesh, P())

    def prefill_fn(params, inp):
        return api.prefill(params, inp, cache_len=cache_len)

    def decode_fn(params, cache, token):
        return api.decode_step(params, cache, token)

    logits_sh = NamedSharding(mesh, P(*(("pod", "data") if "pod" in
                                        mesh.axis_names else ("data",)),)
                              ) if batch % _dp(mesh) == 0 else rep
    prefill_jit = jax.jit(prefill_fn,
                          in_shardings=(p_sh, None),
                          out_shardings=(c_sh, logits_sh))
    decode_jit = jax.jit(decode_fn,
                         in_shardings=(p_sh, c_sh, None),
                         out_shardings=(logits_sh, c_sh),
                         donate_argnums=(1,))
    return prefill_jit, decode_jit, (p_sh, c_sh, logits_sh)


def _dp(mesh: Mesh) -> int:
    n = mesh.shape.get("data", 1)
    n *= mesh.shape.get("pod", 1)
    return n


def greedy_generate(api: ModelApi, params, batch: Dict, steps: int,
                    cache_len: int):
    """Reference generation loop, one static batch in lockstep — the parity
    oracle for the continuous-batching engine (``runtime.engine``): per-slot
    decode is row-wise independent, so the engine's tokens for a request
    must match a batch-1 greedy run of the same prompt token for token
    (tests/test_engine.py asserts this, dense and sparse)."""
    cache, logits = api.prefill(params, batch, cache_len=cache_len)
    toks = [jnp.argmax(logits, -1).astype(jnp.int32)[:, None]]
    for _ in range(steps - 1):
        logits, cache = api.decode_step(params, cache, toks[-1])
        toks.append(jnp.argmax(logits, -1).astype(jnp.int32)[:, None])
    return jnp.concatenate(toks, axis=1)
