"""Distributed runtime: sharding rules, train/serve step factories,
elastic remesh, straggler mitigation."""
