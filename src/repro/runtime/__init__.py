"""Distributed runtime: sharding rules, train/serve step factories, the
continuous-batching serving engine (engine.py), elastic remesh, straggler
mitigation, and the SLO layer — admission control / graceful degradation
policy (slo.py) under the multi-replica router with retry and hedging
(router.py, DESIGN.md Section 13)."""
