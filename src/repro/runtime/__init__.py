"""Distributed runtime: sharding rules, train/serve step factories, the
continuous-batching serving engine (engine.py), elastic remesh, straggler
mitigation."""
