"""Train-step factory: loss + grad + AdamW + metrics, with sharding specs.

``make_train_step`` returns a pure (state, batch) -> (state, metrics)
function plus the in/out shardings the launcher passes to jit.  Sparsity is
a first-class feature: an optional ``PruneSchedule`` applies Griffin-style
weight pruning at ramp milestones (host side, between steps), keeping the
weight tensors in the exactly-zero form the sparse kernels consume.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.registry import ModelApi
from ..optim import adamw
from ..sparsity.pruning import PruneSchedule
from .sharding import shard_batch, shard_params


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: adamw.OptState
    step: jax.Array

    def tree_flatten(self):
        return (self.params, self.opt, self.step), None


jax.tree_util.register_pytree_node(
    TrainState, TrainState.tree_flatten,
    lambda aux, children: TrainState(*children))


def init_state(api: ModelApi, key) -> TrainState:
    params = api.init(key)
    return TrainState(params=params, opt=adamw.init(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(api: ModelApi, opt_cfg: adamw.AdamWConfig,
                    n_micro: int = 1
                    ) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    """(state, batch) -> (state, metrics).

    ``n_micro > 1`` splits the batch into microbatches scanned sequentially
    with f32 gradient accumulation: peak activation memory drops ~n_micro x
    at identical math (the standard lever that fits large train cells in
    HBM; see EXPERIMENTS.md Section Perf iteration 3)."""
    def grads_of(params, batch):
        return jax.value_and_grad(api.loss)(params, batch)

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        if n_micro == 1:
            loss, grads = grads_of(state.params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]), batch)

            def mb(carry, mbatch):
                loss_acc, gacc = carry
                loss, g = grads_of(state.params, mbatch)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (loss_acc + loss, gacc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, gsum), _ = jax.lax.scan(
                mb, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
        params, opt, metrics = adamw.apply(opt_cfg, state.params, grads,
                                           state.opt)
        metrics["loss"] = loss
        return TrainState(params, opt, state.step + 1), metrics

    return train_step


def state_shardings(api: ModelApi, mesh: Mesh, fsdp: bool = True
                    ) -> TrainState:
    """Sharding tree matching TrainState (opt moments mirror params: ZeRO)."""
    p_shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    p_shard = shard_params(p_shapes, mesh, fsdp)
    rep = NamedSharding(mesh, P())
    return TrainState(
        params=p_shard,
        opt=adamw.OptState(mu=p_shard, nu=p_shard, count=rep),
        step=rep)


def jit_train_step(api: ModelApi, opt_cfg: adamw.AdamWConfig, mesh: Mesh,
                   batch_specs: Any, fsdp: bool = True, donate: bool = True):
    step_fn = make_train_step(api, opt_cfg)
    st_sh = state_shardings(api, mesh, fsdp)
    metric_sh = NamedSharding(mesh, P())
    return jax.jit(
        step_fn,
        in_shardings=(st_sh, batch_specs),
        out_shardings=(st_sh, {"loss": metric_sh, "grad_norm": metric_sh,
                               "lr": metric_sh}),
        donate_argnums=(0,) if donate else (),
    ), st_sh


def apply_prune(state: TrainState, schedule: PruneSchedule,
                match: Callable[[str], bool]) -> TrainState:
    """Host-side pruning at ramp milestones (keeps zeros exact)."""
    flat, td = jax.tree_util.tree_flatten_with_path(state.params)
    out = []
    for p, leaf in flat:
        key = jax.tree_util.keystr(p)
        if leaf.ndim >= 2 and match(key):
            leaf = schedule.apply(leaf, int(state.step))
        out.append(leaf)
    return TrainState(jax.tree_util.tree_unflatten(td, out), state.opt,
                      state.step)
