"""SLO-aware admission control and graceful degradation (DESIGN.md
Section 13).

Pure host-side policy — no jax anywhere in this module — shared by the
multi-replica router (``runtime.router``) and the serving CLI's
per-request SLO reporting.  Everything is a deterministic function of the
submitted trace: the chaos tier replays routing decisions exactly, and
the bench-regression gate compares shed counts and TTFT percentiles with
``==`` rather than tolerances.

Time is **virtual**: one router tick is one "millisecond" of the SLO
clock (``deadline_ms``/``ttft_deadline_ms`` on ``runtime.engine.Request``
count ticks after arrival).  On the CI box wall clock is noise; virtual
deadlines make every admission/shed decision replayable — the recorded
deviation from real-clock SLOs (DESIGN.md Section 13).

Three pieces:

  - :class:`CostModel` — expected service steps for a request: its
    bucketed prefill (``ServeEngine.bucket_for`` shapes, amortized at
    ``prefill_tokens_per_step``) plus one decode step per generated
    token.
  - :class:`AdmissionQueue` — bounded earliest-deadline-first queue.
    Admission sheds *deterministically* instead of backlogging without
    bound: infeasible work (cost already overruns the deadline) is shed
    at the door, a full queue sheds the worst entry by EDF order (never
    silently grows), and entries whose deadline expired while queued are
    shed at pop time, so nothing infeasible is ever dispatched.
  - :class:`DegradationLadder` — hysteresis ladder over a queue-pressure
    signal.  Each level is strictly cheaper service, never a fall-over:
    1 shrinks the fused decode chunk (admission latency over batch
    efficiency), 2 forces the cheaper sparse execution Mode through the
    PR 8 thresholds (``ServeEngine.set_degraded``), 3 sheds the lowest
    priority class at admission.  Pressure clearing walks the same
    ladder back up.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
import math
from typing import Dict, List, Optional, Sequence, Tuple

# EDF key for "no deadline": sorts after every real deadline, so
# best-effort work only runs when nothing deadlined is waiting.
_NO_DEADLINE = float("inf")


class ShedReason(str, enum.Enum):
    """Why an admission decision dropped a request (deterministic,
    recorded on the request's output attribution)."""

    INFEASIBLE = "infeasible"    # cost model says the deadline cannot be met
    QUEUE_FULL = "queue_full"    # bounded queue preferred other work (EDF)
    EXPIRED = "expired"          # deadline passed while queued
    DEGRADED = "degraded"        # ladder level 3: priority class shed


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Expected service steps for a request — the admission feasibility
    input.  ``prefill_tokens_per_step`` amortizes the bucketed prefill
    (a 64-token bucket is one engine dispatch but costs more than a
    decode step); ``per_token_steps`` is 1.0 for the greedy engines
    (one fused-scan row per token)."""

    prefill_tokens_per_step: int = 64
    per_token_steps: float = 1.0

    def estimate(self, prompt_len: int, max_new_tokens: int,
                 bucket: Optional[int] = None) -> int:
        span = bucket if bucket is not None else prompt_len
        prefill = max(1, -(-span // self.prefill_tokens_per_step))
        return prefill + int(math.ceil(self.per_token_steps
                                       * max_new_tokens))


@dataclasses.dataclass
class ShedEvent:
    rid: int
    step: int
    reason: ShedReason
    priority: int
    deadline: Optional[int]      # absolute (ticks), None = best-effort


@dataclasses.dataclass
class _Entry:
    """Queued admission candidate.  ``deadline`` is absolute ticks (the
    request's relative ``deadline_ms`` resolved against its submit
    tick); ``cost`` is the frozen CostModel estimate."""

    key: Tuple[float, int, int]          # (deadline, priority, seq)
    rid: int
    req: object                          # runtime.engine.Request
    submit: int
    cost: int
    deadline: Optional[int]

    def __lt__(self, other: "_Entry") -> bool:
        return self.key < other.key


class AdmissionQueue:
    """Bounded earliest-deadline-first admission queue.

    ``bound=None`` is the unbounded baseline (never sheds for capacity —
    the failure mode benchmarks/bench_serve.py's overload row exists to
    demonstrate).  With a bound, the queue holds at most ``bound``
    entries and every overflow sheds exactly one entry — the *worst* by
    EDF order (latest deadline, then lowest priority, then latest
    submission), which may be the incoming request itself.  Hence for a
    fixed push sequence the shed count is ``max(0, feasible - bound)``:
    deterministic, and monotone non-increasing in the bound
    (tests/test_properties.py holds both).

    ``shed_min_priority`` is the degradation ladder's level-3 knob: when
    set, any pushed request with ``priority >= shed_min_priority`` is
    shed up front (priority 0 is the most important class).
    """

    def __init__(self, bound: Optional[int] = None,
                 cost_model: Optional[CostModel] = None):
        if bound is not None and bound < 1:
            raise ValueError("queue bound must be >= 1 (None = unbounded)")
        self.bound = bound
        self.cost_model = cost_model or CostModel()
        self.shed_min_priority: Optional[int] = None
        self._heap: List[_Entry] = []
        self._seq = 0
        self.shed_log: List[ShedEvent] = []
        self.max_depth = 0

    @property
    def depth(self) -> int:
        return len(self._heap)

    def push(self, req, now: int,
             bucket: Optional[int] = None) -> Optional[ShedEvent]:
        """Offer ``req`` at tick ``now``.  Returns the ShedEvent if the
        request (or a displaced queue entry) was shed — a displaced
        entry's event carries *its* rid, and ``req`` is queued."""
        cost = self.cost_model.estimate(req.prompt_len, req.max_new_tokens,
                                        bucket)
        deadline = (None if req.deadline_ms is None
                    else now + int(req.deadline_ms))
        entry = _Entry(key=(_NO_DEADLINE if deadline is None else deadline,
                            req.priority, self._seq),
                       rid=req.rid, req=req, submit=now, cost=cost,
                       deadline=deadline)
        self._seq += 1
        if (self.shed_min_priority is not None
                and req.priority >= self.shed_min_priority):
            return self._log_shed(entry, now, ShedReason.DEGRADED)
        if deadline is not None and now + cost > deadline:
            return self._log_shed(entry, now, ShedReason.INFEASIBLE)
        if self.bound is not None and len(self._heap) >= self.bound:
            worst = max(self._heap)
            if entry.key >= worst.key:
                return self._log_shed(entry, now, ShedReason.QUEUE_FULL)
            self._heap.remove(worst)
            heapq.heapify(self._heap)
            heapq.heappush(self._heap, entry)
            self.max_depth = max(self.max_depth, len(self._heap))
            return self._log_shed(worst, now, ShedReason.QUEUE_FULL)
        heapq.heappush(self._heap, entry)
        self.max_depth = max(self.max_depth, len(self._heap))
        return None

    def pop(self, now: int) -> Tuple[Optional[_Entry], List[ShedEvent]]:
        """Earliest-deadline entry still feasible at ``now`` (its shed
        events are the entries whose deadline expired while queued — the
        dispatcher forwards them to the output log).  An admitted entry
        therefore always satisfies ``now + cost <= deadline``: deadline
        slack accounting never goes negative (tests/test_properties.py)."""
        expired: List[ShedEvent] = []
        while self._heap:
            e = heapq.heappop(self._heap)
            if e.deadline is not None and now + e.cost > e.deadline:
                expired.append(self._log_shed(e, now, ShedReason.EXPIRED))
                continue
            return e, expired
        return None, expired

    def slack(self, entry: _Entry, now: int) -> Optional[int]:
        if entry.deadline is None:
            return None
        return entry.deadline - now - entry.cost

    def _log_shed(self, e: _Entry, now: int,
                  reason: ShedReason) -> ShedEvent:
        ev = ShedEvent(rid=e.rid, step=now, reason=reason,
                       priority=e.req.priority, deadline=e.deadline)
        self.shed_log.append(ev)
        return ev


# ---------------------------------------------------------------------------
# graceful degradation (the overload ladder)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DegradationConfig:
    """Hysteresis thresholds for the pressure ladder.  ``pressure`` is
    queue depth over the queue bound (or over ``target_depth`` when
    unbounded); a level change needs ``patience`` consecutive ticks past
    the water mark, so one bursty tick never thrashes the jit caches
    (level 2 swaps the traced Mode)."""

    high_water: float = 0.75
    low_water: float = 0.25
    patience: int = 2
    max_level: int = 3
    min_chunk: int = 2           # level-1 floor for the fused decode chunk
    shed_min_priority: int = 1   # level 3 sheds priority >= this


class DegradationLadder:
    """Step replicas down a cost ladder under sustained pressure and back
    up when it clears.  Levels are cumulative:

      0  normal service
      1  halve the fused decode chunk (floor ``min_chunk``) — shorter
         host round-trips, so admissions drain the queue sooner
      2  force the cheaper execution Mode through the PR 8 thresholds
         (``ServeEngine.set_degraded``: b_threshold -> 0, so pruned
         weights run the Sparse.B kernels even in the dense-preferred
         regime)
      3  shed the lowest-priority class at admission

    ``update`` is a pure function of the pressure history — the ladder
    trajectory is part of the deterministic routing record."""

    def __init__(self, cfg: DegradationConfig = DegradationConfig()):
        self.cfg = cfg
        self.level = 0
        self._above = 0
        self._below = 0
        self.history: List[Tuple[int, int]] = []     # (tick, new level)

    def update(self, pressure: float, tick: int) -> int:
        c = self.cfg
        if pressure >= c.high_water:
            self._above += 1
            self._below = 0
            if self._above >= c.patience and self.level < c.max_level:
                self.level += 1
                self._above = 0
                self.history.append((tick, self.level))
        elif pressure <= c.low_water:
            self._below += 1
            self._above = 0
            if self._below >= c.patience and self.level > 0:
                self.level -= 1
                self._below = 0
                self.history.append((tick, self.level))
        else:
            self._above = self._below = 0
        return self.level


# ---------------------------------------------------------------------------
# latency / attainment reporting
# ---------------------------------------------------------------------------

def percentile(xs: Sequence[float], q: float) -> Optional[float]:
    """Nearest-rank percentile (deterministic, no interpolation): the
    smallest element >= q of the distribution.  None on empty input."""
    if not xs:
        return None
    s = sorted(xs)
    k = max(0, min(len(s) - 1, int(math.ceil(q / 100.0 * len(s))) - 1))
    return s[k]


def request_rows(outputs: Dict[int, object], reqs) -> List[Dict]:
    """Per-request SLO rows from served outputs.  Works for both output
    shapes: the router's ``RouterOutput`` (tick-based ``submit`` /
    ``first_token`` / ``finished``) and the single engine's
    ``RequestOutput`` (per-token engine-clock ``token_steps`` against the
    request's ``arrival``).  TTFT/completion are virtual ticks after
    arrival; ``attained`` is None when the request carries no deadline."""
    rows = []
    for r in sorted(reqs, key=lambda r: r.rid):
        o = outputs.get(r.rid)
        if o is None:
            continue
        attribution = getattr(o, "attribution", "normal")
        if getattr(o, "first_token", None) is not None:      # RouterOutput
            base = o.submit
            ttft = o.first_token - base if o.first_token >= 0 else None
            done = o.finished - base if o.finished >= 0 else None
        else:                                                # RequestOutput
            steps = getattr(o, "token_steps", [])
            ttft = steps[0] - r.arrival if steps else None
            done = (steps[-1] - r.arrival
                    if steps and getattr(o, "finished", -1) >= 0 else None)
        itl = _itl(getattr(o, "token_steps", []))
        attained = None
        if attribution == "shed":
            attained = False
        elif r.deadline_ms is not None or r.ttft_deadline_ms is not None:
            attained = done is not None
            if r.deadline_ms is not None:
                attained = attained and done <= r.deadline_ms
            if r.ttft_deadline_ms is not None:
                attained = attained and ttft is not None \
                    and ttft <= r.ttft_deadline_ms
        rows.append(dict(rid=r.rid, priority=r.priority,
                         ttft=ttft, completion=done,
                         deadline_ms=r.deadline_ms,
                         ttft_deadline_ms=r.ttft_deadline_ms,
                         itl_max=max(itl) if itl else None,
                         tokens=len(getattr(o, "tokens", [])),
                         attribution=str(getattr(attribution, "value",
                                                 attribution)),
                         attained=attained))
    return rows


def _itl(token_steps: Sequence[int]) -> List[int]:
    return [b - a for a, b in zip(token_steps, token_steps[1:])]


def latency_summary(rows: List[Dict]) -> Dict:
    """Aggregate p50/p99 TTFT, inter-token latency and SLO attainment
    over ``request_rows`` output — the fields BENCH_serve.json records
    for the overload row and the regression gate replays exactly."""
    ttfts = [r["ttft"] for r in rows if r["ttft"] is not None]
    itls = [r["itl_max"] for r in rows if r["itl_max"] is not None]
    gated = [r for r in rows if r["attained"] is not None]
    shed = sum(1 for r in rows if r["attribution"] == "shed")
    return {
        "requests": len(rows),
        "completed": sum(1 for r in rows if r["completion"] is not None),
        "shed": shed,
        "ttft_p50": percentile(ttfts, 50),
        "ttft_p99": percentile(ttfts, 99),
        "itl_p50": percentile(itls, 50),
        "itl_p99": percentile(itls, 99),
        "slo_attainment": (round(sum(1 for r in gated if r["attained"])
                                 / len(gated), 4) if gated else None),
    }
