"""Deterministic fault injection for the serving engines (DESIGN.md
Section 11).

Chaos testing a serving stack only proves something when the chaos is
*reproducible*: the same fault at the same engine step must yield the same
recovery and — because the engines are deterministic and the sharded
layouts are reduction-order-preserving (DESIGN.md Section 10) — the same
tokens as an uninterrupted run.  ``FaultInjector`` is the hook both
``runtime.engine.ServeEngine`` and ``runtime.mesh_serve.MeshServeEngine``
poll at three points of every tick:

  - ``"admission"``  — before the scheduler pops this tick's admissions;
  - ``"prefill"``    — after an admission's prefill computed but before its
                       slot insert (the prefill result is lost);
  - ``"decode"``     — after the fused decode chunk was dispatched but
                       before its token ring was consumed (the chunk's work
                       is lost).

A kill fires exactly once, at the first poll of the matching phase whose
engine clock has reached ``at_step``, by raising :class:`DeviceLoss` with
the dead device ids; the engine catches it, rolls back to its tick-start
snapshot, remeshes onto the survivors (``runtime.elastic``), reshards, and
replays the tick.  ``delay_host`` instead inflates one host's recorded
step times so the ``runtime.straggler.StragglerDetector`` — not the
injector — is what triggers the very same recovery path after its eviction
streak fills.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

PHASES = ("admission", "prefill", "decode")


class DeviceLoss(RuntimeError):
    """A device (or set of devices) died mid-tick; carries the lost device
    ids.  Raised by :meth:`FaultInjector.poll`, caught by the engine's
    ``step`` wrapper, which recovers and retries the interrupted tick."""

    def __init__(self, lost: Sequence[int]):
        self.lost = tuple(sorted(set(int(d) for d in lost)))
        super().__init__(f"lost devices {list(self.lost)}")


@dataclasses.dataclass
class FaultInjector:
    """Deterministic chaos hook (DESIGN.md Section 11).

    ``kill_devices`` are jax device *ids* (``device.id``) to kill at the
    first ``phase`` poll at or after engine step ``at_step`` — once only
    (``fired_at`` records when).  ``delay_host`` multiplies the named
    host's step-time readings by ``delay_factor`` from ``at_step`` on, for
    as long as the trace runs — a persistent straggler, not a blip — so
    the detector's eviction streak can fill.
    """

    kill_devices: Tuple[int, ...] = ()
    at_step: int = 0
    phase: str = "decode"
    delay_host: Optional[int] = None
    delay_factor: float = 8.0
    fired_at: Optional[int] = None

    def __post_init__(self):
        if self.phase not in PHASES:
            raise ValueError(f"unknown fault phase {self.phase!r} "
                             f"(known: {PHASES})")
        if self.at_step < 0:
            raise ValueError("at_step must be >= 0")

    @property
    def fired(self) -> bool:
        return self.fired_at is not None

    def poll(self, phase: str, clock: int) -> None:
        """Engine-side injection point; raises :class:`DeviceLoss` when the
        configured kill is due.  Never fires twice (recovery re-executes the
        tick through the same polls)."""
        if (self.kill_devices and not self.fired and phase == self.phase
                and clock >= self.at_step):
            self.fired_at = int(clock)
            raise DeviceLoss(self.kill_devices)

    def host_delay(self, host: int, clock: int) -> float:
        """Multiplier for ``host``'s recorded step time at ``clock``."""
        if self.delay_host is not None and host == self.delay_host \
                and clock >= self.at_step:
            return self.delay_factor
        return 1.0


REPLICA_STATES = ("prefill", "decode", "idle", "any")


@dataclasses.dataclass
class ReplicaFault:
    """Router-level fault site (DESIGN.md Section 13): kill a whole
    replica — the pool analogue of :class:`FaultInjector`'s device kill.

    Fires once, at the first router tick at or after ``at_step`` whose
    replica activity matches ``during`` (``"prefill"`` — the replica
    would admit work this tick; ``"decode"`` — it has running slots;
    ``"idle"`` — neither; ``"any"`` — unconditional).  The router drains
    the dead replica, replays its in-flight requests on survivors, and —
    when ``recover_after`` is set — readmits the replica that many ticks
    after the kill."""

    replica: int
    at_step: int = 0
    during: str = "any"
    recover_after: Optional[int] = None
    fired_at: Optional[int] = None

    def __post_init__(self):
        if self.during not in REPLICA_STATES:
            raise ValueError(f"unknown replica fault state {self.during!r} "
                             f"(known: {REPLICA_STATES})")
        if self.at_step < 0:
            raise ValueError("at_step must be >= 0")

    @property
    def fired(self) -> bool:
        return self.fired_at is not None

    def poll(self, replica: int, state: str, clock: int) -> bool:
        """Router-side injection point: True when this fault kills
        ``replica`` (whose current activity is ``state``) at router tick
        ``clock``.  Fires at most once."""
        if (not self.fired and replica == self.replica
                and clock >= self.at_step
                and (self.during == "any" or state == self.during)):
            self.fired_at = int(clock)
            return True
        return False


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Parsed ``--inject-fault`` flag (launch/serve.py); ``build`` resolves
    the device *index* against the serving mesh's device list into the
    device *ids* a :class:`FaultInjector` wants.  ``build_replica`` turns a
    ``replica:`` spec into the :class:`ReplicaFault` the router polls."""

    kind: str                   # "kill" | "delay" | "replica"
    index: int                  # device index (kill) / host row (delay)
                                # / replica index (replica)
    at_step: int
    phase: str = "decode"
    factor: float = 8.0
    recover: Optional[int] = None

    def build(self, devices: Sequence) -> FaultInjector:
        if self.kind == "kill":
            dev = list(devices)[self.index]
            return FaultInjector(kill_devices=(int(dev.id),),
                                 at_step=self.at_step, phase=self.phase)
        return FaultInjector(delay_host=self.index, at_step=self.at_step,
                             delay_factor=self.factor)

    def build_replica(self) -> ReplicaFault:
        if self.kind != "replica":
            raise ValueError(f"not a replica fault spec: {self.kind!r}")
        return ReplicaFault(replica=self.index, at_step=self.at_step,
                            during=self.phase, recover_after=self.recover)


def parse_fault_spec(spec: str) -> FaultSpec:
    """``kill:<dev>@<step>[:<phase>]``, ``delay:<host>@<step>[:<factor>]``,
    or ``replica:<i>@<step>[:<during>[:<recover>]]``.

    ``<dev>`` indexes the serving mesh's device list (negative counts from
    the end, so ``kill:-1@3`` kills the last device at engine step 3);
    ``<phase>`` is one of ``admission|prefill|decode`` (default decode);
    ``<factor>`` is the straggler slowdown multiplier (default 8).
    ``replica:`` faults are router-level: ``<i>`` is the replica index,
    ``<during>`` one of ``prefill|decode|idle|any`` (default any), and
    ``<recover>`` the tick count after which the replica rejoins the pool
    (default: stays dead).
    """
    kind, _, rest = spec.partition(":")
    if kind not in ("kill", "delay", "replica") or not rest:
        raise ValueError(f"fault spec {spec!r} is not "
                         "'kill:<dev>@<step>[:<phase>]', "
                         "'delay:<host>@<step>[:<factor>]', or "
                         "'replica:<i>@<step>[:<during>[:<recover>]]'")
    head, _, tail = rest.partition("@")
    if not tail:
        raise ValueError(f"fault spec {spec!r} is missing '@<step>'")
    try:
        index = int(head)
    except ValueError:
        raise ValueError(f"fault spec {spec!r}: bad index {head!r}")
    at, _, opt = tail.partition(":")
    try:
        step = int(at)
    except ValueError:
        raise ValueError(f"fault spec {spec!r}: bad step {at!r}")
    if step < 0:
        raise ValueError(f"fault spec {spec!r}: step must be >= 0")
    if kind == "kill":
        phase = opt or "decode"
        if phase not in PHASES:
            raise ValueError(f"fault spec {spec!r}: unknown phase "
                             f"{phase!r} (known: {PHASES})")
        return FaultSpec("kill", index, step, phase=phase)
    if kind == "replica":
        during, _, rec = opt.partition(":")
        during = during or "any"
        if during not in REPLICA_STATES:
            raise ValueError(f"fault spec {spec!r}: unknown replica state "
                             f"{during!r} (known: {REPLICA_STATES})")
        try:
            recover = int(rec) if rec else None
        except ValueError:
            raise ValueError(f"fault spec {spec!r}: bad recover {rec!r}")
        if recover is not None and recover <= 0:
            raise ValueError(f"fault spec {spec!r}: recover must be > 0")
        return FaultSpec("replica", index, step, phase=during,
                         recover=recover)
    try:
        factor = float(opt) if opt else 8.0
    except ValueError:
        raise ValueError(f"fault spec {spec!r}: bad factor {opt!r}")
    if factor <= 1.0:
        raise ValueError(f"fault spec {spec!r}: delay factor must be > 1")
    return FaultSpec("delay", index, step, factor=factor)
