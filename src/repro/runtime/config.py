"""Unified engine configuration (DESIGN.md Section 14).

Nine PRs of growth left the engines with sprawling constructors (a 20-kwarg
``ServeEngine.__init__``, a near-duplicate ``MeshServeEngine`` signature and
33 CLI flags in launch/serve.py).  ``EngineConfig`` is the one home for all
of it: a frozen dataclass of frozen sections —

* ``ArenaConfig``  — KV arena: slots, cache_len, paging (page_size/num_pages)
  and KV dtype (``"fp32"`` | ``"int8"``, runtime/paging.py);
* ``SchedConfig``  — admission policy, fused-chunk ladder, bucketed prefill;
* ``KernelConfig`` — Pallas kernel dispatch knobs + tuned-plan path;
* ``FaultConfig``  — snapshots, fault-injection spec, straggler eviction;
* ``RouterConfig`` — multi-replica routing (replicas, queue bound, hedging).

``ServeEngine(api, params, config=EngineConfig(...))`` is the documented
construction path; the old keyword arguments still work for one release
through a deprecation shim (``resolve_engine_config`` maps them onto the
nested fields and warns).  ``to_json``/``from_json`` round-trip the whole
config, powering ``launch/serve.py --config engine.json`` (explicit CLI
flags override file values).  ``derive_cache_len`` is the single source of
truth for the trace-driven arena bound that used to be duplicated between
``build_engine`` and ``main()``.
"""
from __future__ import annotations

import dataclasses
import json
import warnings
from typing import Any, Dict, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ArenaConfig:
    """KV arena shape.  ``page_size=None`` keeps the fixed
    ``num_slots x cache_len`` arena; setting it (power of two) activates the
    paged pool of runtime/paging.py — ``num_pages`` physical pages shared by
    all slots (default: fixed-arena capacity + the DUMP page), ``kv_dtype``
    selecting fp32 (bit-exact) or int8 (per-row scales, gated tolerance)
    pages.  ``cache_len=None`` means "derive from the trace" via
    :meth:`EngineConfig.derive_cache_len`."""

    num_slots: int = 4
    cache_len: Optional[int] = None
    page_size: Optional[int] = None
    num_pages: Optional[int] = None
    kv_dtype: str = "fp32"


@dataclasses.dataclass(frozen=True)
class SchedConfig:
    policy: str = "continuous"
    max_admissions_per_step: int = 1
    decode_chunk: int = 8
    measure_every: int = 8
    bucket_prompts: bool = True
    fused: bool = True


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    use_kernels: bool = False
    interpret: bool = False
    spmd_kernels: bool = True
    a_sparsity: Optional[float] = None
    block_m: int = 128
    plan: Optional[str] = None          # path of a tuned kernel plan (json)


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    inject: Optional[str] = None        # fault spec string (runtime.fault)
    snapshot_dir: Optional[str] = None
    recovery_model_parallel: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    replicas: int = 0                   # 0 = plain single-engine serving
    queue_bound: Optional[int] = None
    hedge_after: Optional[int] = None
    shed_policy: str = "shed"


_SECTIONS = {"arena": ArenaConfig, "sched": SchedConfig,
             "kernels": KernelConfig, "fault": FaultConfig,
             "router": RouterConfig}

# legacy ServeEngine/MeshServeEngine keyword -> (section, field)
_LEGACY = {
    "num_slots": ("arena", "num_slots"),
    "cache_len": ("arena", "cache_len"),
    "page_size": ("arena", "page_size"),
    "num_pages": ("arena", "num_pages"),
    "kv_dtype": ("arena", "kv_dtype"),
    "policy": ("sched", "policy"),
    "max_admissions_per_step": ("sched", "max_admissions_per_step"),
    "decode_chunk": ("sched", "decode_chunk"),
    "measure_every": ("sched", "measure_every"),
    "bucket_prompts": ("sched", "bucket_prompts"),
    "fused": ("sched", "fused"),
    "use_kernels": ("kernels", "use_kernels"),
    "interpret": ("kernels", "interpret"),
    "spmd_kernels": ("kernels", "spmd_kernels"),
    "a_sparsity": ("kernels", "a_sparsity"),
    "block_m": ("kernels", "block_m"),
    "snapshot_dir": ("fault", "snapshot_dir"),
    "recovery_model_parallel": ("fault", "recovery_model_parallel"),
}


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    arena: ArenaConfig = dataclasses.field(default_factory=ArenaConfig)
    sched: SchedConfig = dataclasses.field(default_factory=SchedConfig)
    kernels: KernelConfig = dataclasses.field(default_factory=KernelConfig)
    fault: FaultConfig = dataclasses.field(default_factory=FaultConfig)
    router: RouterConfig = dataclasses.field(default_factory=RouterConfig)
    mesh: Optional[str] = None          # "DxM" data x model mesh, None = off

    # -- construction helpers ----------------------------------------------

    def replace(self, **sections: Any) -> "EngineConfig":
        return dataclasses.replace(self, **sections)

    def with_fields(self, **kv: Any) -> "EngineConfig":
        """Functional update by flat field name (``num_slots=8``,
        ``kv_dtype="int8"``, ``mesh="2x2"``): each key is routed to its
        section via the same map the legacy-kwarg shim uses."""
        out = self
        for key, val in kv.items():
            if key == "mesh":
                out = dataclasses.replace(out, mesh=val)
                continue
            if key not in _LEGACY:
                raise TypeError(f"unknown engine config field {key!r}")
            section, field = _LEGACY[key]
            sec = dataclasses.replace(getattr(out, section), **{field: val})
            out = dataclasses.replace(out, **{section: sec})
        return out

    @staticmethod
    def heavy_gen_cap(gen_lens: Sequence[int]) -> int:
        """Generation cap for ``length_dist="heavy"`` traces: the Pareto
        draw is capped at 2x the largest nominal gen length, so the arena
        bound stays finite.  Shared by :meth:`derive_cache_len` and the
        trace construction in launch/serve.py — one definition, no drift."""
        return 2 * max(gen_lens)

    @classmethod
    def derive_cache_len(cls, prompt_lens: Sequence[int],
                         gen_lens: Sequence[int],
                         length_dist: str = "choice") -> int:
        """The trace-driven arena bound: longest prompt + the generation
        cap + 1 feedback token.  Single source of truth for what
        ``build_engine`` and ``main()`` in launch/serve.py used to compute
        independently (with a hand-maintained heavy-tail 2x special case
        that had to match)."""
        gen_cap = (cls.heavy_gen_cap(gen_lens) if length_dist == "heavy"
                   else max(gen_lens))
        return max(prompt_lens) + gen_cap + 1

    @classmethod
    def from_args(cls, args: Any, defaults: Optional[Dict[str, Any]] = None
                  ) -> "EngineConfig":
        """EngineConfig from launch/serve.py's argparse namespace.

        ``--config <json>`` (when present on ``args``) sets the baseline;
        every CLI flag whose value differs from its parser default
        (``defaults``, a dest -> default map) is laid on top.  argparse
        cannot distinguish "absent" from "passed the default", so a flag
        explicitly set *to* its default never clobbers a file value — the
        documented override rule.  With ``defaults=None`` every present
        flag counts as explicit."""
        path = getattr(args, "config", None)
        if path:
            with open(path) as f:
                base = cls.from_json(f.read())
        else:
            base = cls()

        def explicit(dest: str) -> bool:
            if not hasattr(args, dest):
                return False
            if defaults is None or dest not in defaults:
                return True
            return getattr(args, dest) != defaults[dest]

        flat = {"slots": "num_slots", "cache_len": "cache_len",
                "page_size": "page_size", "num_pages": "num_pages",
                "kv_dtype": "kv_dtype", "policy": "policy",
                "measure_every": "measure_every",
                "decode_chunk": "decode_chunk", "use_kernels": "use_kernels",
                "snapshot_dir": "snapshot_dir",
                "remesh_model_parallel": "recovery_model_parallel",
                "mesh": "mesh"}
        kv = {field: getattr(args, dest) for dest, field in flat.items()
              if explicit(dest)}
        out = base.with_fields(**kv) if kv else base
        if explicit("spmd_fallback"):
            out = out.replace(kernels=dataclasses.replace(
                out.kernels, spmd_kernels=not args.spmd_fallback))
        if explicit("plan"):
            out = out.replace(kernels=dataclasses.replace(
                out.kernels, plan=args.plan))
        if explicit("inject_fault"):
            out = out.replace(fault=dataclasses.replace(
                out.fault, inject=args.inject_fault))
        router: Dict[str, Any] = {}
        if explicit("replicas"):
            router["replicas"] = args.replicas
        if explicit("queue_bound"):
            router["queue_bound"] = args.queue_bound or None
        if explicit("hedge_ms"):
            router["hedge_after"] = args.hedge_ms or None
        if explicit("shed_policy"):
            router["shed_policy"] = args.shed_policy
        if router:
            out = out.replace(router=dataclasses.replace(out.router,
                                                         **router))
        return out

    # -- json round-trip ----------------------------------------------------

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "EngineConfig":
        raw = json.loads(text)
        if not isinstance(raw, dict):
            raise ValueError("engine config json must be an object")
        kw: Dict[str, Any] = {}
        for name, val in raw.items():
            if name == "mesh":
                kw["mesh"] = val
            elif name in _SECTIONS:
                sec_cls = _SECTIONS[name]
                fields = {f.name for f in dataclasses.fields(sec_cls)}
                unknown = set(val) - fields
                if unknown:
                    raise ValueError(f"unknown {name} config fields: "
                                     f"{sorted(unknown)}")
                kw[name] = sec_cls(**val)
            else:
                raise ValueError(f"unknown engine config section {name!r}")
        return cls(**kw)


def resolve_engine_config(config: Optional[EngineConfig],
                          legacy: Dict[str, Any], owner: str
                          ) -> EngineConfig:
    """The engines' deprecation shim: merge old-style keyword arguments
    into ``config`` (legacy values win — they are the more explicit call),
    warning once per construction.  Unknown keywords raise ``TypeError``
    exactly as the old signatures did."""
    cfg = config or EngineConfig()
    if legacy:
        unknown = set(legacy) - set(_LEGACY)
        if unknown:
            raise TypeError(f"{owner} got unexpected keyword arguments "
                            f"{sorted(unknown)}")
        warnings.warn(
            f"{owner}(**kwargs) is deprecated; pass "
            f"config=EngineConfig(...) (keywords {sorted(legacy)} were "
            "mapped onto it)", DeprecationWarning, stacklevel=3)
        cfg = cfg.with_fields(**legacy)
    return cfg
