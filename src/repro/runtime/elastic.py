"""Elastic scaling: remesh on device-count change and reshard state
(DESIGN.md Section 11).

On device loss (or quota change) the recovering engine/launcher calls
``plan_mesh`` with the surviving devices, rebuilds shardings
(``runtime.sharding``), and ``reshard``s the latest state — either live
arrays or a checkpoint via ``checkpoint.restore``'s shardings argument.
The serving layout never splits a reduction (DESIGN.md Section 10) and the
data pipeline is deterministic in (step, shard), so the run continues
bit-exactly on the new mesh.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def _pow2_floor(n: int) -> int:
    return 1 << (int(n).bit_length() - 1)


def plan_mesh_shape(n_devices: int, model_parallel: int) -> Tuple[int, int]:
    """The (data, model) shape ``plan_mesh`` will build — a pure function
    so degenerate survivor counts are unit-testable without devices
    (tests/test_fault_tolerance.py pins the full table).

    Contract: both axes are powers of two (stable collectives); the model
    axis is the largest power of two that is <= ``model_parallel`` *and*
    fits ``n_devices`` (a lone survivor serves 1x1 no matter the requested
    TP degree); the data axis then takes the largest power-of-two number
    of model-axis blocks; devices beyond ``data * model`` are dropped
    (stragglers beyond the largest usable block).
    """
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    if model_parallel < 1:
        raise ValueError(f"model_parallel must be >= 1, got {model_parallel}")
    model = _pow2_floor(min(model_parallel, n_devices))
    data = _pow2_floor(max(n_devices // model, 1))
    return data, model


def plan_mesh(n_devices: int, model_parallel: int,
              devices: Optional[Sequence] = None) -> Mesh:
    """Largest ("data", "model") mesh that fits ``n_devices`` with TP
    degree at most ``model_parallel`` (shape per ``plan_mesh_shape``).
    ``devices`` defaults to ``jax.devices()``; passing the survivor list
    after a loss is the elastic-recovery path (DESIGN.md Section 11)."""
    data, model = plan_mesh_shape(n_devices, model_parallel)
    use = data * model
    devs = list(jax.devices() if devices is None else devices)
    if len(devs) < use:
        raise ValueError(f"planned mesh {data}x{model} needs {use} devices, "
                         f"have {len(devs)}")
    arr = np.empty((use,), dtype=object)
    arr[:] = devs[:use]
    return Mesh(arr.reshape(data, model), ("data", "model"))


def surviving(mesh_devices: Any, lost_ids: Sequence[int]) -> List:
    """A mesh's device list minus the lost ids, in mesh order — the
    ``devices`` argument the recovering engine hands ``plan_mesh``."""
    lost = set(int(i) for i in lost_ids)
    return [d for d in np.asarray(mesh_devices).flat if d.id not in lost]


def reshard(state: Any, shardings: Any) -> Any:
    """device_put a pytree onto new shardings (cross-mesh resharding)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(x), s), state, shardings)
