"""Elastic scaling: remesh on device-count change and reshard state.

On node failure (or quota change) the launcher calls ``plan_mesh`` with the
surviving device count, rebuilds shardings, and ``reshard``s the latest
state (either live arrays or a checkpoint via checkpoint.restore's
shardings argument).  The data pipeline is deterministic in (step, shard),
so the run continues bit-exactly modulo the reduction order.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding


def plan_mesh(n_devices: int, model_parallel: int,
              devices: Optional[Sequence] = None) -> Mesh:
    """Largest (data, model) mesh that fits n_devices with the given TP
    degree; drops stragglers beyond the largest usable power-of-two block."""
    if n_devices < model_parallel:
        model_parallel = max(1, 2 ** int(np.floor(np.log2(n_devices))))
    data = n_devices // model_parallel
    # keep data a power of two for stable collectives
    data = 2 ** int(np.floor(np.log2(max(data, 1))))
    use = data * model_parallel
    devs = list(devices or jax.devices())[:use]
    arr = np.array(devs).reshape(data, model_parallel)
    return Mesh(arr, ("data", "model"))


def reshard(state: Any, shardings: Any) -> Any:
    """device_put a pytree onto new shardings (cross-mesh resharding)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(x), s), state, shardings)
