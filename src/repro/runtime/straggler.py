"""Straggler mitigation.

With synchronous data parallelism one slow host gates every step.  The
detector keeps per-host EMA step times; hosts slower than
``threshold x median`` are flagged and the planner reassigns their data
shards to healthy hosts (work stays deterministic: shard assignment is an
explicit map consumed by data.DataConfig).  Persistent stragglers are
recommended for eviction → runtime.elastic handles the remesh.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class StragglerConfig:
    ema: float = 0.9
    threshold: float = 1.5      # x median EMA step time
    evict_after: int = 20       # consecutive flagged steps


class StragglerDetector:
    def __init__(self, num_hosts: int, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.ema = np.zeros(num_hosts)
        self.flagged_streak = np.zeros(num_hosts, dtype=int)
        self._seen = np.zeros(num_hosts, dtype=bool)

    def record(self, host: int, step_time: float) -> None:
        if not self._seen[host]:
            self.ema[host] = step_time
            self._seen[host] = True
        else:
            self.ema[host] = (self.cfg.ema * self.ema[host] +
                              (1 - self.cfg.ema) * step_time)

    def stragglers(self) -> List[int]:
        if not self._seen.any():
            return []
        med = float(np.median(self.ema[self._seen]))
        out = []
        for h in np.nonzero(self._seen)[0]:
            if self.ema[h] > self.cfg.threshold * med:
                self.flagged_streak[h] += 1
                out.append(int(h))
            else:
                self.flagged_streak[h] = 0
        return out

    def evictions(self) -> List[int]:
        return [int(h) for h in
                np.nonzero(self.flagged_streak >= self.cfg.evict_after)[0]]


def reassign_shards(num_shards: int, healthy: List[int]) -> Dict[int, List[int]]:
    """Round-robin shard → healthy-host map (deterministic)."""
    assert healthy, "no healthy hosts"
    plan: Dict[int, List[int]] = {h: [] for h in healthy}
    for s in range(num_shards):
        plan[healthy[s % len(healthy)]].append(s)
    return plan
