"""Straggler mitigation.

With synchronous data parallelism one slow host gates every step.  The
detector keeps per-host EMA step times; hosts slower than
``threshold x median`` are flagged and the planner reassigns their data
shards to healthy hosts (work stays deterministic: shard assignment is an
explicit map consumed by data.DataConfig).  Persistent stragglers are
recommended for eviction → runtime.elastic handles the remesh, and the
serving engines route the eviction through the same snapshot → remesh →
reshard recovery as a detected device loss (DESIGN.md Section 11).

Observation and query are separate: ``record`` feeds one host's step
time, ``observe`` closes the step — updating the per-host flagged streaks
exactly once — and ``stragglers`` is the side-effect-free query of the
current verdict, callable any number of times per step.  (The pre-split
version mutated ``flagged_streak`` inside ``stragglers()``, so a second
query in the same step double-counted the streak and evicted hosts in half
the configured time; tests/test_fault_tolerance.py pins the fix.)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np


@dataclasses.dataclass
class StragglerConfig:
    ema: float = 0.9
    threshold: float = 1.5      # x median EMA step time
    evict_after: int = 20       # consecutive flagged steps


class StragglerDetector:
    def __init__(self, num_hosts: int, cfg: StragglerConfig = StragglerConfig()):
        if num_hosts < 1:
            raise ValueError("need at least one host")
        self.cfg = cfg
        self.num_hosts = num_hosts
        self.ema = np.zeros(num_hosts)
        self.flagged_streak = np.zeros(num_hosts, dtype=int)
        self._seen = np.zeros(num_hosts, dtype=bool)

    def record(self, host: int, step_time: float) -> None:
        """Feed one host's measured step time (any number per step; the
        EMA absorbs them)."""
        if not self._seen[host]:
            self.ema[host] = step_time
            self._seen[host] = True
        else:
            self.ema[host] = (self.cfg.ema * self.ema[host] +
                              (1 - self.cfg.ema) * step_time)

    def stragglers(self) -> List[int]:
        """Hosts currently slower than ``threshold x median`` EMA — a pure
        query with no streak side effects, safe to call repeatedly."""
        if not self._seen.any():
            return []
        med = float(np.median(self.ema[self._seen]))
        return [int(h) for h in np.nonzero(self._seen)[0]
                if self.ema[h] > self.cfg.threshold * med]

    def observe(self) -> List[int]:
        """Close one step: advance each flagged host's streak (reset the
        rest) exactly once, and return the flagged hosts.  Call once per
        engine step, after the step's ``record`` feeds."""
        flagged = self.stragglers()
        hit = np.zeros(self.num_hosts, dtype=bool)
        hit[flagged] = True
        self.flagged_streak = np.where(hit, self.flagged_streak + 1, 0)
        return flagged

    def evictions(self) -> List[int]:
        """Hosts whose flagged streak reached ``evict_after`` (a pure
        query, like ``stragglers``)."""
        return [int(h) for h in
                np.nonzero(self.flagged_streak >= self.cfg.evict_after)[0]]


def reassign_shards(num_shards: int, healthy: List[int]) -> Dict[int, List[int]]:
    """Round-robin shard → healthy-host map (deterministic)."""
    assert healthy, "no healthy hosts"
    plan: Dict[int, List[int]] = {h: [] for h in healthy}
    for s in range(num_shards):
        plan[healthy[s % len(healthy)]].append(s)
    return plan
