"""Paged KV arena: fixed-size pages from a shared pool + on-device page table.

The fixed ``num_slots x cache_len`` arena (PR 3) provisions every slot for the
worst-case generation length, so heavy-tailed traces waste most of the KV
memory and concurrency is capped long before compute.  Here the per-slot rows
become fixed-size pages (power of two tokens each) drawn from one shared
device-resident pool, indexed through an on-device ``(num_slots, max_pages)``
int32 page table — the same scalar-prefetch metadata pattern the ``sparse_a``
kernels use for their kidx/cnt index maps, applied to memory instead of MACs
(DESIGN.md Section 14).

Layout invariants:

* A cache leaf is *pageable* iff its sequence extent tracks ``cache_len``
  exactly (probed via ``jax.eval_shape`` at two lengths) with layout
  ``(stack, batch, seq, ...)``.  Rolling sliding-window caches (seq extent
  pinned at ``window < cache_len``) stay in the fixed arena; families with no
  pageable leaf (xlstm's recurrent state) degrade to the fixed arena whole.
* Pool leaf: ``(stack, num_pages, page_size, *rest)``; page table entry
  ``pages[slot, j]`` maps logical page ``j`` of a slot to a physical page.
* Page id 0 is the DUMP page: writes from dead/unreserved rows land there and
  it is never read.  A zeroed page table is therefore safe by construction.
* ``cache_len`` is rounded up to a multiple of ``page_size`` so the gathered
  per-slot view ``(batch, max_pages * page_size, *rest)`` has exactly the
  fixed arena's shape — fp32 paged serving is bit-identical to fixed.
* int8 pools carry a ``"<name>_scale"`` ``(stack, num_pages, page_size)``
  float32 leaf: one scale per written token row (quantize-on-write /
  dequantize-on-read, reusing optim/compression.py round/clip/scale).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

DUMP_PAGE = 0
KV_DTYPES = ("fp32", "int8")


@dataclasses.dataclass(frozen=True)
class PagedSpec:
    """Static description of a paged arena (hashable; closed over by jits)."""
    page_size: int
    num_pages: int            # total physical pages, including DUMP page 0
    max_pages: int            # page-table width = cache_len // page_size
    cache_len: int            # rounded up to a multiple of page_size
    kv_dtype: str             # "fp32" | "int8"
    paged_keys: Tuple[str, ...]

    @property
    def usable_pages(self) -> int:
        return self.num_pages - 1

    def pages_needed(self, total_tokens: int) -> int:
        """Physical pages covering positions ``0..total_tokens-1``."""
        return -(-total_tokens // self.page_size)

    def page_row(self, ids: Sequence[int]) -> np.ndarray:
        """(max_pages,) int32 logical->physical row; unreserved -> DUMP."""
        row = np.zeros((self.max_pages,), np.int32)
        row[: len(ids)] = np.asarray(ids, np.int32)
        return row


def discover_paged_keys(api: Any, cache_len: int) -> Tuple[str, ...]:
    """Top-level cache keys whose seq extent tracks ``cache_len`` exactly.

    Probes ``init_cache`` shapes at two lengths: a leaf is pageable iff the
    only differing axis is axis 2, equal to the probe length at both probes
    (so rolling-window caches, encoder cross-KV, and recurrent state all
    stay fixed), and its batch axis is axis 1.
    """
    if cache_len < 2:
        return ()
    alt = cache_len // 2
    t1 = jax.eval_shape(lambda: api.init_cache(2, cache_len))
    t2 = jax.eval_shape(lambda: api.init_cache(2, alt))
    tb = jax.eval_shape(lambda: api.init_cache(1, cache_len))
    if not isinstance(t1, dict):
        return ()
    keys = []
    for key, leaf in t1.items():
        s1 = getattr(leaf, "shape", ())
        s2 = getattr(t2[key], "shape", ())
        sb = getattr(tb[key], "shape", ())
        if len(s1) != len(s2) or len(s1) < 3:
            continue
        diff = [i for i in range(len(s1)) if s1[i] != s2[i]]
        if diff != [2] or s1[2] != cache_len or s2[2] != alt:
            continue
        bdiff = [i for i in range(len(s1)) if s1[i] != sb[i]]
        if bdiff != [1]:
            continue
        keys.append(key)
    return tuple(sorted(keys))


def build_spec(api: Any, num_slots: int, cache_len: int,
               page_size: Optional[int], num_pages: Optional[int] = None,
               kv_dtype: str = "fp32") -> Tuple[Optional[PagedSpec], int]:
    """Resolve (spec, effective cache_len) for an engine's arena.

    Returns ``(None, cache_len)`` when paging is off or the family exposes no
    pageable leaf (fixed-arena degradation).  Otherwise cache_len is rounded
    up to a multiple of page_size so pooled views match fixed-arena shapes.
    """
    if not page_size:
        return None, cache_len
    if page_size < 1 or page_size & (page_size - 1):
        raise ValueError(f"page_size must be a power of two, got {page_size}")
    if kv_dtype not in KV_DTYPES:
        raise ValueError(f"kv_dtype must be one of {KV_DTYPES}, got {kv_dtype!r}")
    clen = -(-cache_len // page_size) * page_size
    keys = discover_paged_keys(api, clen)
    if not keys:
        return None, cache_len
    maxp = clen // page_size
    if num_pages is None:
        num_pages = num_slots * maxp + 1          # fixed-arena capacity + DUMP
    if num_pages < maxp + 1:
        raise ValueError(
            f"num_pages={num_pages} cannot hold one full slot "
            f"({maxp} pages) plus the DUMP page")
    spec = PagedSpec(page_size=page_size, num_pages=num_pages,
                     max_pages=maxp, cache_len=clen, kv_dtype=kv_dtype,
                     paged_keys=keys)
    return spec, clen


def _make(ref: Any, shape: Tuple[int, ...], dtype: Any) -> Any:
    if isinstance(ref, jax.ShapeDtypeStruct):
        return jax.ShapeDtypeStruct(shape, dtype)
    return jnp.zeros(shape, dtype)


def paged_tree(base: Dict[str, Any], num_slots: int, spec: PagedSpec
               ) -> Dict[str, Any]:
    """Rewrite a (promoted) fixed arena tree into its paged form.

    Paged leaves become pools ``(stack, num_pages, page_size, *rest)``; int8
    pools gain a ``<key>_scale`` leaf; a zeroed (= all-DUMP) ``"pages"``
    table is added.  Works on concrete arrays and on eval_shape trees.
    """
    out: Dict[str, Any] = {}
    ref = None
    for key, leaf in base.items():
        if key in spec.paged_keys:
            shape = leaf.shape
            assert shape[1] == num_slots and shape[2] == spec.cache_len, (
                key, shape)
            rest = tuple(shape[3:])
            pool_shape = (shape[0], spec.num_pages, spec.page_size) + rest
            if spec.kv_dtype == "int8":
                out[key] = _make(leaf, pool_shape, jnp.int8)
                out[key + "_scale"] = _make(
                    leaf, pool_shape[:3], jnp.float32)
            else:
                out[key] = _make(leaf, pool_shape, leaf.dtype)
            ref = leaf
        else:
            out[key] = leaf
            ref = ref if ref is not None else leaf
    out["pages"] = _make(ref, (num_slots, spec.max_pages), jnp.int32)
    return out


class PageAllocator:
    """Host-side physical-page accounting: deterministic lowest-id-first.

    Pages ``1..num_pages-1`` are allocatable (0 is the DUMP page).  Reserve
    happens at admission time (head-of-line blocking when the pool is
    exhausted), free at finish/cancel.  ``state_dict`` round-trips through
    engine snapshots and checkpoint manifests so rollback-and-replay recovery
    (DESIGN.md Section 11) reproduces the exact same page assignments.
    """

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free: List[int] = list(range(1, num_pages))
        heapq.heapify(self._free)
        self._held: set = set()

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def reserve(self, n: int) -> Optional[List[int]]:
        """Lowest-id ``n`` free pages, or None if the pool can't cover it."""
        if n < 0 or n > len(self._free):
            return None
        ids = [heapq.heappop(self._free) for _ in range(n)]
        self._held.update(ids)
        return ids

    def free(self, ids: Sequence[int]) -> None:
        for i in ids:
            if i not in self._held:
                raise ValueError(f"freeing page {i} that is not reserved")
            self._held.discard(i)
            heapq.heappush(self._free, i)

    def state_dict(self) -> Dict[str, Any]:
        return {"num_pages": self.num_pages, "held": sorted(self._held)}

    @classmethod
    def from_state_dict(cls, state: Dict[str, Any]) -> "PageAllocator":
        alloc = cls(int(state["num_pages"]))
        held = [int(i) for i in state["held"]]
        alloc._held = set(held)
        alloc._free = [i for i in range(1, alloc.num_pages)
                       if i not in alloc._held]
        heapq.heapify(alloc._free)
        return alloc
