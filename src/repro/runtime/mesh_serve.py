"""Mesh-parallel serving: the fused-chunk engine partitioned over a
("data", "model") device mesh (DESIGN.md Section 10).

``MeshServeEngine`` is the multi-device face of ``runtime.engine
.ServeEngine``: same scheduler, same host mirror, same fused decode-chunk
ladder — but parameters live model-sharded (output-axis-only TP via
``runtime.sharding.shard_params(serve=True)``, with ``GriffinWeights``
b_comp sharding its N axis and the kidx/cnt/inv_perm scalar-prefetch
metadata replicated), and the slot-pool KV arena shards its batch (slot)
axis over "data" and its head axes over "model"
(``runtime.sharding.shard_cache(decode=True)``).  Every per-Mode jit set
(prefill, pooled decode, the fused chunk scan) is traced with explicit
``in_shardings``/``out_shardings`` plus donation, so the arena updates in
place *sharded* and only the (chunk, B) token ring, the admissions' first
tokens, and the live-rows zero-fraction scalars cross back to the host —
the host-sync budget of DESIGN.md Section 9 is unchanged by sharding.

The layout is chosen so that no floating-point reduction is ever split
across devices (contraction dims and softmax axes stay whole; sharded
axes are output/batch/head axes, all reduction-free), which makes the
sharded engine's logits — and therefore its greedy tokens — bit-identical
to the single-device engine on the same trace, for all four execution
Modes.  Because no GEMM's contraction dim is ever split, each device's
share of every matmul is fully local, and ``models.common.griffin_linear``
runs the *real* Pallas kernels on every mesh size by wrapping them in
``jax.experimental.shard_map`` with zero in-kernel collectives — each
device executes ``griffin_matmul_shard``/``sparse_a_matmul_shard``/
``dense_matmul_shard`` on its N-slice (DESIGN.md Section 10).  The former
jnp fallbacks (``griffin_matmul(spmd=True)`` decompaction, plain sharded
dots) are retired from the hot loop and kept only as the parity oracle,
reachable via ``spmd_kernels=False``.  ``mesh=1x1`` degenerates to the
single-device engine: the sharding specs are trivial and the kernels run
un-shard_map'd.

Runs unmodified on an emulated CPU mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) — which is how
the CI ``sharded`` job executes the parity matrix in
``tests/test_mesh_serve.py`` — and on a real TPU slice via
``launch/serve.py --mesh DxM``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.registry import ModelApi
from .config import resolve_engine_config
from .elastic import plan_mesh, reshard, surviving
from .engine import (EngineSnapshot, ServeEngine, _batch_axes, _make_insert,
                     _make_paged_insert, _promote_arena)
from .paging import PagedSpec, build_spec, paged_tree
from .serve import make_chunk_ladder
from .sharding import shard_cache, shard_params


def cache_heads(api: ModelApi) -> int:
    """Head-axis extent of the model's cache leaves — the size
    ``cache_spec(decode=True)`` matches to place "model" (KV heads for
    attention caches, the head axis of mLSTM/sLSTM states).  Families
    whose cache head count differs from ``num_kv_heads`` simply match
    nothing and keep those leaves replicated (spec-respecting, never
    wrong)."""
    cfg = api.cfg
    return int(getattr(cfg, "num_kv_heads", 0)
               or getattr(cfg, "num_heads", 0) or 0)


def _promoted_arena_shapes(api: ModelApi, num_slots: int,
                           cache_len: int) -> Any:
    """ShapeDtypeStructs of the engine's arena — ``engine._promote_arena``
    over ``init_cache``, exactly what ``_init_device_state`` allocates."""
    return jax.eval_shape(
        lambda: _promote_arena(api.init_cache(num_slots, cache_len),
                               num_slots))


def serve_shardings(api: ModelApi, mesh: Mesh, params: Any, num_slots: int,
                    cache_len: int, *, paged: Optional[PagedSpec] = None
                    ) -> Tuple[Any, Any, NamedSharding]:
    """(param, arena, replicated) NamedSharding trees for the mesh-serving
    layout (DESIGN.md Section 10).  ``params`` is the tree actually being
    served, so block-compacted ``GriffinWeights`` leaves get their own
    b_comp/metadata specs.  ``paged``: the arena's ``PagedSpec`` when the
    engine pages its KV cache (runtime/paging.py) — the arena template is
    then the pool + page-table tree and the paged leaf names route through
    ``cache_spec``'s paged rules (pages replicated, pools dp-sharded on
    their page axis)."""
    p_sh = shard_params(params, mesh, fsdp=False, serve=True)
    arena = _promoted_arena_shapes(api, num_slots, cache_len)
    pset = frozenset()
    if paged is not None:
        arena = paged_tree(arena, num_slots, paged)
        pset = frozenset(paged.paged_keys)
    c_sh = shard_cache(arena, mesh, num_slots, decode=True,
                       heads=cache_heads(api), paged=pset)
    return p_sh, c_sh, NamedSharding(mesh, P())


def mesh_serve_fns(api: ModelApi, mesh: Mesh, params: Any, num_slots: int,
                   cache_len: int, decode_chunk: int = 8, shardings=None,
                   paged: Optional[PagedSpec] = None):
    """Returns (prefill_fn, decode_fn, chunk_for, (p_sh, c_sh, rep)) — the
    sharded twin of ``runtime.serve.jit_serve_fns``, shaped for
    ``ServeEngine``'s fns factory (one invocation per selected Mode, each
    traced under that Mode's ``sparse_execution`` scope at first call).

    Batch-1 admission prefills produce a *replicated* cache and logits
    (their batch axis cannot shard), which the sharded ``_insert`` then
    reshards into the arena; the fused chunk scan carries the arena with
    its shardings end to end and donates cache/token/remaining buffers so
    the pool updates in place.  Out-shardings pin the token ring and the
    measurement scalars replicated — they are the only values the host
    fetches per chunk.

    ``shardings``: a precomputed ``serve_shardings`` triple —
    ``MeshServeEngine`` passes its own so the per-Mode factory invocations
    skip four redundant full-tree spec walks.
    """
    p_sh, c_sh, rep = shardings or serve_shardings(api, mesh, params,
                                                   num_slots, cache_len,
                                                   paged=paged)

    def prefill_fn(params, inp):
        return api.prefill(params, inp, cache_len=cache_len)

    def decode_fn(params, cache, token):
        return api.decode_step(params, cache, token)

    prefill_jit = jax.jit(prefill_fn, in_shardings=(p_sh, rep),
                          out_shardings=(rep, rep))
    decode_jit = jax.jit(decode_fn, in_shardings=(p_sh, c_sh, rep),
                         out_shardings=(rep, c_sh), donate_argnums=(1,))
    chunk_for = make_chunk_ladder(
        api, decode_chunk,
        lambda fn: jax.jit(fn,
                           in_shardings=(p_sh, c_sh, rep, rep),
                           out_shardings=(c_sh, rep, rep, rep, rep, rep),
                           donate_argnums=(1, 2, 3)))
    return prefill_jit, decode_jit, chunk_for, (p_sh, c_sh, rep)


class MeshServeEngine(ServeEngine):
    """``ServeEngine`` partitioned over a ("data", "model") mesh.

    Construction places the (possibly ``GriffinWeights``-compacted) param
    tree onto the serving layout and the slot-pool arena onto the decode
    cache layout; the admission insert is re-jitted with the arena
    shardings (donated, so sharded admissions still update in place); and
    every ``sparse_execution`` scope the engine enters carries
    ``spmd_mesh`` so ``griffin_linear`` shard_maps the real Pallas kernels
    over the model axis (``spmd_kernels=False`` retires them to the
    decompaction oracle).  All host-side bookkeeping — scheduler,
    remaining mirror, ring
    drain, measurement cadence, Mode-keyed jit sets — is inherited
    untouched, which is the point: sharding is a placement concern, not a
    scheduling one (DESIGN.md Section 10).

    ``mesh=1x1`` (``launch.mesh.serve_mesh("1x1")``) is the single-device
    special case: specs are trivial, ``spmd_mesh`` stays None, and the
    engine behaves exactly like ``ServeEngine`` with sharding-annotated
    jits.

    Tuned kernel plans (``plan=...``, forwarded to the base engine) need
    no mesh-specific handling: the family thresholds and per-GEMM
    ``GriffinWeights.a_thr`` overrides are trace-time constants, so the
    shard_map'd kernels trace with them exactly like the unsharded ones —
    the plan tier's mesh cell asserts a plan survives this path
    (DESIGN.md Section 12).  Plan-steered compaction granularity must
    still satisfy ``shardable`` (whole N tiles per model shard);
    ``griffin_linear`` falls back to the decompaction oracle per GEMM
    otherwise, exactly as for default granularity.

    Failure handling (DESIGN.md Section 11): on a detected ``DeviceLoss``
    (or a straggler eviction — hosts are the data-rows of the mesh), the
    inherited recovery rolls back to the tick-start snapshot and this class
    rebuilds the whole device story on the survivors — ``elastic.plan_mesh``
    plans the new mesh (TP degree capped by ``recovery_model_parallel``,
    default the current model-axis size), ``serve_shardings`` re-derives the
    layout, the Mode-keyed jit sets are dropped (they bake the old mesh's
    in/out-shardings), and params/arena/counters reshard via
    ``elastic.reshard`` (or ``checkpoint.restore`` when snapshots go to
    disk).  Because every mesh serves bit-identical tokens (Section 10),
    the finished trace equals an uninterrupted run's token for token.
    """

    def __init__(self, api: ModelApi, params: Any, *, mesh: Mesh,
                 config=None, fns_factory: Optional[Callable] = None,
                 fault_injector=None, straggler=None, plan=None, **legacy):
        missing = {"data", "model"} - set(mesh.axis_names)
        if missing:
            raise ValueError(f"serving mesh needs axes ('data', 'model'), "
                             f"got {mesh.axis_names}")
        # resolve the config here (legacy kwargs fold in and warn once) so
        # the sharding layout can be derived before the base constructor
        # allocates anything; the base re-resolution is then a no-op.
        config = resolve_engine_config(config, legacy, type(self).__name__)
        if config.arena.cache_len is None:
            raise ValueError("MeshServeEngine needs arena.cache_len")
        num_slots = config.arena.num_slots
        paged, cache_len = build_spec(
            api, num_slots, config.arena.cache_len, config.arena.page_size,
            config.arena.num_pages, config.arena.kv_dtype)
        if cache_len != config.arena.cache_len:
            config = config.with_fields(cache_len=cache_len)
        self.mesh = mesh
        self._recovery_mp = config.fault.recovery_model_parallel
        if mesh.size > 1:
            self._spmd_mesh = mesh          # class default is None
        self._shardings = serve_shardings(api, mesh, params, num_slots,
                                          cache_len, paged=paged)
        params = jax.tree.map(jax.device_put, params, self._shardings[0])
        if fns_factory is None:
            # late-bound self.mesh/self._shardings: after a recovery remesh
            # the per-Mode factory invocations trace for the new layout
            fns_factory = lambda: mesh_serve_fns(
                api, self.mesh, self.params, num_slots, cache_len,
                decode_chunk=self.decode_chunk, shardings=self._shardings)
        super().__init__(api, params, config=config, fns_factory=fns_factory,
                         fault_injector=fault_injector, straggler=straggler,
                         plan=plan)

    def _init_device_state(self) -> None:
        """Sharded twin of the base allocation: arena placed on the decode
        cache layout, ``_insert`` jitted with the arena in/out shardings
        (pool donated), token/remaining buffers replicated — they return
        to the host every chunk anyway."""
        cache = self._arena()
        _, c_sh, rep = self._shardings
        self.cache = jax.tree.map(jax.device_put, cache, c_sh)
        self._build_insert()
        self._tokens = jax.device_put(
            jnp.zeros((self.num_slots, 1), jnp.int32), rep)
        self._remaining = jax.device_put(
            jnp.zeros((self.num_slots,), jnp.int32), rep)

    def _build_insert(self) -> None:
        """Admission insert carrying the *current* arena shardings —
        rebuilt by recovery after every remesh.  The paged variant takes
        the extra replicated page-row operand (runtime/paging.py)."""
        _, c_sh, rep = self._shardings
        axes = _batch_axes(self.api, self.cache_len)
        if self._paged is not None:
            wrap = lambda f: jax.jit(
                f, in_shardings=(c_sh, rep, rep, rep, rep, rep, rep, rep),
                out_shardings=(c_sh, rep, rep, rep),
                donate_argnums=(0, 1, 2))
            self._insert = _make_paged_insert(axes, self._paged,
                                              jit_wrap=wrap)
        else:
            wrap = lambda f: jax.jit(
                f, in_shardings=(c_sh, rep, rep, rep, rep, rep, rep),
                out_shardings=(c_sh, rep, rep, rep),
                donate_argnums=(0, 1, 2))
            self._insert = _make_insert(axes, jit_wrap=wrap)

    # -- failure handling (DESIGN.md Section 11) ----------------------------

    def _mesh_desc(self) -> str:
        from ..launch.mesh import mesh_spec
        return mesh_spec(self.mesh)

    def _host_device_ids(self, host: int) -> list:
        """Hosts are the data-rows of the serving mesh's device array; a
        row index beyond the (possibly already shrunk) mesh owns nothing."""
        rows = self.mesh.devices
        if host >= rows.shape[0]:
            return []
        return [int(d.id) for d in rows[host].flat]

    def _survivors_exist(self, lost) -> bool:
        return bool(surviving(self.mesh.devices, lost))

    def _remesh(self, lost) -> None:
        """``elastic.plan_mesh`` over the survivors, then rebuild everything
        that baked the old mesh: sharding specs, the model-sharded params
        (from the host-side copy — the dead devices' shards are gone), the
        Mode-keyed jit sets, and the admission insert."""
        survivors = surviving(self.mesh.devices, lost)
        if not survivors:
            raise RuntimeError(f"no surviving devices after losing {lost}")
        mp = self._recovery_mp or int(self.mesh.shape["model"])
        self.mesh = plan_mesh(len(survivors), mp, devices=survivors)
        self._spmd_mesh = self.mesh if self.mesh.size > 1 else None
        self._shardings = serve_shardings(self.api, self.mesh,
                                          self._params_host, self.num_slots,
                                          self.cache_len, paged=self._paged)
        self.params = reshard(self._params_host, self._shardings[0])
        self._mode_fns.clear()      # jits bake in/out-shardings: retrace
        self._build_insert()

    def _restore_device(self, snap: EngineSnapshot) -> None:
        """Place the snapshot's arena/counters onto the (new) mesh's decode
        layout — through ``checkpoint.restore`` when the snapshot went to
        disk (which also re-reads the compacted params), else
        ``elastic.reshard`` from the in-memory copy."""
        p_sh, c_sh, rep = self._shardings
        shardings = {"cache": c_sh, "tokens": rep, "remaining": rep}
        if snap.ckpt_step is not None:
            shardings["params"] = p_sh
            state = self._snapshot_state(snap, shardings=shardings)
            self.params = state["params"]
        else:
            state = {k: reshard(v, shardings[k])
                     for k, v in self._snapshot_state(snap, None).items()}
        self.cache = state["cache"]
        self._tokens = state["tokens"]
        self._remaining = state["remaining"]
