"""Sharding rules: logical-axis mapping from parameter/cache/batch trees to
``PartitionSpec``s on the production mesh.

Strategy (MaxText-style 2D "FSDP + TP"):
  - weight matrices: penultimate (input) dim -> "data" (FSDP: parameters and
    optimizer states are fully sharded; GSPMD inserts the all-gathers),
    last (output) dim -> "model" (TP) — transposed for output projections so
    matmul contractions stay local;
  - embeddings: vocab -> "model", feature -> "data";
  - activations: batch -> ("pod","data") when divisible, otherwise the
    sequence axis (long-context decode with batch 1);
  - KV caches / recurrent states: batch -> dp axes, head_dim/feature ->
    "model" (kv-heads can be < TP degree, head_dim always divides);
  - the "pod" axis only shards the batch: parameters are replicated across
    pods (FSDP within pod, DP across pods), so cross-pod traffic is gradient
    reduction only;
  - block-compacted weights (GriffinWeights pytrees from
    repro.sparsity.sparsify_params): b_comp shards its output (N) axis by
    the parent GEMM's rule, the compacted K rows stay whole (kidx ids are
    global), scalar-prefetch metadata replicates (DESIGN.md Section 4).

A second, stricter layout serves the mesh-parallel decode engine
(``serve=True`` / ``decode=True``, consumed by runtime.mesh_serve —
DESIGN.md Section 10): every GEMM weight shards its **output (N) axis
only** on "model" (contraction dims never split, so no partial-sum
collectives reorder the reduction and sharded logits stay bit-identical
to the single-device trace), embeddings shard the vocab axis (the tied
unembed transpose then also contracts locally), and the slot-pool cache
arena shards its batch axis over the dp axes plus its *head* axes on
"model" — head axes are batch-like (per-head independence), so sharding
them is also reduction-order-free.  Metadata stays replicated in both
layouts.

Divisibility is not required for correctness (GSPMD pads), but rules avoid
padding where it matters; `_divides` guards the places XLA would waste.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# parameter-name classification
_IN_OUT = ("wq", "wk", "wv", "w_gate", "w_up", "w_ff1", "w_x", "router",
           "head", "w_rg", "w_ig", "wz", "wi", "wf", "wo_gate")
_OUT_IN = ("wo", "w_down", "w_ff2", "w_out")
_REPLICATE = ("ln", "ln1", "ln2", "ln_x", "gn", "final_norm", "enc_norm",
              "lam", "qn", "kn")
# GriffinWeights (block-compacted weights) pytree children.  The compacted
# K axis (b_comp rows) is never sharded: kidx holds *global* K-block ids and
# per-shard counts would diverge, so only the output (N) axis splits; the
# scalar-prefetch metadata is tiny and rides along replicated
# (DESIGN.md Section 4).
_GRIFFIN_META = ("kidx", "cnt", "inv_perm")


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _divides(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[a] for a in name]))
    return mesh.shape[name]


def param_spec(path: str, leaf, mesh: Mesh, fsdp: bool = True,
               ep: bool = False, serve: bool = False) -> P:
    """PartitionSpec for one parameter leaf, by trailing name + rank.

    ``ep=True`` shards MoE expert weights (L, E, D, F) with the *expert*
    axis on "model" (expert parallelism: token all-to-alls instead of
    expert-weight gathers) rather than TP-within-expert on F.

    ``serve=True`` selects the decode-serving layout (DESIGN.md
    Section 10): output-axis-only TP — every GEMM weight (including the
    ``_OUT_IN`` projections that train-time TP shards on their input dim)
    puts its last (output) axis on "model" and nothing on "data", and
    embeddings shard the vocab axis so the tied-unembed transpose keeps
    its contraction local.  No contraction dim is ever split, so the
    sharded compute is a reduction-order-preserving rearrangement of the
    single-device compute.
    """
    name = path.rstrip("']").split("'")[-1] if "'" in path else path
    rank = len(leaf.shape)
    data_ax = "data" if (fsdp and not serve
                         and "data" in mesh.axis_names) else None
    child = name.rsplit(".", 1)[-1] if "." in name else ""
    if child in _GRIFFIN_META:
        return P(*([None] * rank))
    if child == "b_comp":
        # parent GEMM name decides which mesh axis the output (N) dim gets;
        # in the serving layout every parent's output axis goes to "model"
        # (the compacted K rows are never split in either layout)
        parent = path[:path.rfind(".")]
        pname = parent.rstrip("']").split("'")[-1] if "'" in parent else parent
        if serve:
            ax = "model" if pname in _IN_OUT + _OUT_IN else None
        else:
            ax = "model" if pname in _IN_OUT else \
                (data_ax if pname in _OUT_IN else None)
        return _checked(P(*([None] * (rank - 1) + [ax])), leaf, mesh)
    if name in _REPLICATE or rank <= 1:
        return P()
    if ep and rank == 4 and name in ("w_gate", "w_up", "w_down") \
            and "moe" in path:
        # (L, E, D, F) or (L, E, F, D): experts over "model", in-dim FSDP
        return _checked(P(None, "model", data_ax, None), leaf, mesh)
    if name == "embed":
        spec = ["model", None] if serve else ["model", data_ax]
    elif name == "conv":
        spec = [None, "model"]
    elif name in ("rz", "ri", "rf", "ro") or (name in ("wq", "wk", "wv")
                                              and rank >= 3
                                              and leaf.shape[-1] == leaf.shape[-2]):
        # per-head block-diagonal mats (H, hd, hd)
        spec = [None] * (rank - 1) + ["model"]
        return _checked(P(*spec), leaf, mesh)
    elif name in _IN_OUT:
        spec = [None] * (rank - 2) + [data_ax, "model"]
    elif name in _OUT_IN:
        spec = ([None] * (rank - 2) + [None, "model"] if serve
                else [None] * (rank - 2) + ["model", data_ax])
    else:
        spec = [None] * rank
    return _checked(P(*spec), leaf, mesh)


def _checked(spec: P, leaf, mesh: Mesh) -> P:
    """Drop axes whose dim is not divisible by the mesh axis: jit input
    shardings require exact divisibility (internal constraints would pad)."""
    out = []
    for dim, ax in zip(leaf.shape, spec):
        if ax is None:
            out.append(None)
            continue
        size = _axis_size(mesh, ax)
        out.append(ax if (dim >= size and dim % size == 0) else None)
    return P(*out)


def shard_params(params_shape: Any, mesh: Mesh, fsdp: bool = True,
                 ep: bool = False, serve: bool = False) -> Any:
    """NamedSharding tree for a (ShapeDtypeStruct or array) param tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = [NamedSharding(mesh, param_spec(jax.tree_util.keystr(p), leaf,
                                            mesh, fsdp, ep, serve))
             for p, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_spec(leaf, mesh: Mesh) -> P:
    """Activations/inputs: batch over dp axes; fall back to the sequence
    axis when the batch doesn't divide (e.g. long_500k batch=1)."""
    dp = dp_axes(mesh)
    dpn = _axis_size(mesh, dp)
    shape = leaf.shape
    if len(shape) == 0:
        return P()
    if _divides(shape[0], dpn):
        return P(dp, *([None] * (len(shape) - 1)))
    if len(shape) >= 2 and _divides(shape[1], dpn):
        return P(None, dp, *([None] * (len(shape) - 2)))
    return P(*([None] * len(shape)))


def shard_batch(batch: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, batch_spec(leaf, mesh)), batch)


def cache_spec(path: str, leaf, mesh: Mesh, batch: int,
               decode: bool = False, heads: int = 0,
               paged: frozenset = frozenset()) -> P:
    """KV caches and recurrent state.

    Default (train/long-context) layout: batch dim -> dp axes; the
    *sequence* axis (longest remaining divisible dim) -> 'model'.
    Sequence-sharding the cache keeps per-chip capacity (a command-r
    decode_32k cache is ~1 TB) while decode attention reduces tiny (B, H)
    softmax partials instead of all-gathering the cache — the
    head_dim-sharded layout all-gathered the full cache every step
    (EXPERIMENTS.md Section Perf, iteration 4).  Batch-1 long-context cells
    shard the sequence over dp as well.

    ``decode=True`` is the slot-pool arena layout of the mesh-parallel
    serving engine (runtime.mesh_serve, DESIGN.md Section 10): the batch
    (slot) axis shards over the dp axes — including rank-1 per-slot
    position/state counters, the promoted ``(B,)`` vectors of
    runtime.engine — and axes whose extent equals ``heads`` (KV heads of
    attention caches, mLSTM/sLSTM head axes) shard on "model".  Head axes
    are batch-like — no reduction ever crosses them — and the last axis
    (head_dim / feature, a contraction dim in decode attention and the
    recurrent cell updates) is deliberately never split, so sharded decode
    stays a reduction-order-preserving rearrangement of the single-device
    step.  Sequence stays whole: per-slot ``dynamic_update_slice`` writes
    land at runtime-variable positions, and splitting them would turn every
    cache write into cross-device traffic.
    """
    dp = dp_axes(mesh)
    dpn = _axis_size(mesh, dp)
    mdl = mesh.shape.get("model", 1)
    shape = leaf.shape
    spec: list = [None] * len(shape)
    if len(shape) == 0:
        return P()
    # paged-arena leaves (runtime/paging.py) never match the slot-batch
    # scan below — the page table is (num_slots, max_pages) and a pool's
    # first data axis is num_pages — so they are classified by name before
    # it: the table replicates (every shard gathers with the same ids),
    # pools shard their *page* axis over dp (pages are batch-like: no
    # reduction crosses them) plus the KV-head axis on "model" in the
    # decode layout, and scale vectors follow their pool's page axis.
    if paged and "'" in path:
        name = path.rstrip("']").split("'")[-1]
        if name == "pages":
            return P(*spec)
        base = name[:-6] if name.endswith("_scale") else name
        if base in paged:
            if len(shape) >= 2 and _divides(shape[1], dpn):
                spec[1] = dp
            if decode and not name.endswith("_scale") and mdl > 1 \
                    and heads > 0 and _divides(heads, mdl):
                for i in range(len(shape) - 2, 1, -1):
                    if shape[i] == heads:
                        spec[i] = "model"
                        break
            return P(*spec)
    placed_dp = None
    for i, d in enumerate(shape):
        if d == batch and _divides(d, dpn):
            spec[i] = dp
            placed_dp = i
            break
    if decode:
        if mdl > 1 and heads > 0 and _divides(heads, mdl):
            # scan from the tail (skipping the last, contraction-bearing
            # axis): head axes sit rightmost in every family's cache
            # layout, so when a leading layer/sequence axis coincidentally
            # equals ``heads`` (e.g. cache_len == num_kv_heads) the real
            # head axis still wins and sequence stays whole
            for i in range(len(shape) - 2, -1, -1):
                if i != placed_dp and shape[i] == heads:
                    spec[i] = "model"
                    break
        return P(*spec)
    if placed_dp is None:
        # batch too small: shard the longest divisible axis (the KV seq)
        cand = [(d, i) for i, d in enumerate(shape[:-1])
                if _divides(d, dpn) and d >= dpn]
        if cand:
            placed_dp = max(cand)[1]
            spec[placed_dp] = dp
    if mdl > 1:
        cand = [(d, i) for i, d in enumerate(shape)
                if i != placed_dp and spec[i] is None
                and _divides(d, mdl) and d >= 8 * mdl]
        if cand:
            spec[max(cand)[1]] = "model"
    return P(*spec)


def shard_cache(cache: Any, mesh: Mesh, batch: int,
                decode: bool = False, heads: int = 0,
                paged: frozenset = frozenset()) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    specs = [NamedSharding(mesh, cache_spec(jax.tree_util.keystr(p), leaf,
                                            mesh, batch, decode, heads,
                                            paged))
             for p, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# per-shard kernel operand specs (shard_map, DESIGN.md Section 10)
# ---------------------------------------------------------------------------
# Under the serving layout every device's GEMM is fully local, so
# ``griffin_linear`` wraps the real Pallas kernels in ``shard_map``.  The
# (in_specs, out_spec) each kernel call uses are defined next to the
# shard-local entry points in the kernel packages (one definition, used by
# dispatch and tests alike); these re-exports are the layout-rule layer's
# view of them, plus the shardability predicate that decides kernel vs
# decompaction-oracle per weight leaf.

def spmm_shard_specs(axis: str = "model"):
    """shard_map specs for ``griffin_matmul_shard``: activations and the
    global column perm replicated; b_comp split on padded-N; kidx/cnt
    split on their N-tile axis; output split on N.  Matches
    ``param_spec(serve=True)``: b_comp's stored sharding IS the kernel's
    in_spec, so entering the shard_map moves no weight bytes."""
    from ..kernels.griffin_spmm.ops import shard_specs
    return shard_specs(axis)


def gemm_shard_specs(axis: str = "model"):
    """shard_map specs for the dense-weight kernels
    (``sparse_a_matmul_shard`` / ``dense_matmul_shard``): only the weights
    and output split, on N; activations and the per-M-tile runtime
    metadata replicate."""
    from ..kernels.sparse_a.ops import shard_specs
    return shard_specs(axis)


def kernel_shardable(leaf, mesh: Mesh, axis: str = "model") -> bool:
    """Can this GEMM weight leaf (a ``GriffinWeights`` or a plain matrix)
    run the real kernel under shard_map on ``mesh``?  The same predicate
    ``models.common.griffin_linear`` applies at dispatch time: compacted
    weights need their N tiles to split evenly over the model axis; dense
    weights only need their output dim to (each shard re-pads locally)."""
    from ..kernels.dense_gemm import ops as dense_ops
    from ..kernels.griffin_spmm import ops as spmm_ops
    if axis not in mesh.axis_names:
        return False
    mp = mesh.shape[axis]
    if isinstance(leaf, spmm_ops.GriffinWeights):
        return spmm_ops.shardable(leaf, mp)
    return dense_ops.shardable(leaf, mp)
