"""Continuous-batching serving engine: slot-pool KV arena + FCFS scheduler
+ device-resident decode hot path (DESIGN.md Sections 8-9).

A fixed ``num_slots x cache_len`` cache arena is shared by all in-flight
requests.  Each engine tick admits waiting requests into freed slots
(prefilling them one at a time at a power-of-two *bucketed* prompt length,
interleaved with decode of the running slots) and then advances every
running slot by ``decode_chunk`` tokens with a single fused, donated scan
(``runtime.serve.make_decode_chunk_fn``): decode -> argmax -> token
feedback -> per-slot remaining/live update all stay on device, and only a
(chunk, B) token ring plus two measurement scalars return to the host —
one host sync per chunk instead of three dispatches and a sync per token.
Admission writes a freshly prefilled single-request cache into its slot in
place (``dynamic_update_slice`` along the per-leaf batch axis, positions
carried as a per-slot (B,) vector the model decode paths understand);
eviction is just marking the slot free — the stale rows are dead weight
until the next admission overwrites them, and the on-device live mask
keeps them out of the measurement.

The engine is the serving face of the paper's hybrid execution: it keeps a
running *measured* activation sparsity (exact-zero fraction of the live
rows of the fused chunk's decode logits, accumulated on device), re-invokes
``core.hybrid.select_mode`` against the offline weight sparsity, and runs
every prefill/decode under a ``sparse_execution`` scope for the selected
category.  Mode is a trace-time decision (DESIGN.md Section 5), so a
category flip swaps to a fresh set of jitted fns traced under the new
scope — the jit cache is keyed by ``Mode``, at most four entries.  A flip
can lag the measurement by up to ``decode_chunk`` steps (Section 9).

``greedy_generate`` (runtime/serve.py) is the parity oracle: per-slot
decode is row-wise independent (MoE decode runs drop-free for exactly this
reason, see ``models.moe.moe_ffn``), so the engine's generated tokens for a
request match a batch-1 greedy run of the same prompt — padded to the same
bucket — token for token.
"""
from __future__ import annotations

import copy
import dataclasses
import enum
import functools
import heapq
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.checkpoint import restore as ckpt_restore, save as ckpt_save
from ..core.hybrid import SPARSE_THRESHOLD, select_mode
from ..core.spec import Mode
from ..kernels.griffin_spmm.ops import GriffinWeights
from ..models.common import sparse_execution
from ..models.registry import ModelApi
from ..optim.compression import quantize_rows
from ..sparsity.pruning import GEMM_WEIGHTS, sparsity_of
from .config import EngineConfig, resolve_engine_config
from .fault import DeviceLoss, FaultInjector
from .paging import PageAllocator, PagedSpec, build_spec, paged_tree
from .serve import make_chunk_ladder, pad_prompt_batch
from .straggler import StragglerDetector

# Category knob handed to the sparse_execution scope when the *measured*
# activation sparsity selects an A-side mode and no declared value exists:
# the scope only consumes the category bit (above/below SPARSE_THRESHOLD),
# so any representative sparse-side constant keeps the trace stable across
# measurement jitter (DESIGN.md Section 5).
DEFAULT_DECLARED_A = 0.5

# Smallest prefill bucket: prompts shorter than this share one padded shape,
# so the bucket set is {8, 16, ..., cache_len} — O(log cache_len) shapes.
MIN_BUCKET = 8


# ---------------------------------------------------------------------------
# requests
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    """One generation request.  ``arrival`` is the earliest engine step at
    which the scheduler may admit it; ``extras`` carries non-token model
    inputs (whisper frames).

    ``priority``/``deadline_ms``/``ttft_deadline_ms`` are the SLO fields
    the multi-replica router's admission control consumes (DESIGN.md
    Section 13): priority 0 is the most important class, deadlines count
    virtual ticks after ``arrival`` (None = best-effort).  The defaults
    are FCFS-compatible — a plain ``ServeEngine`` ignores all three, so
    pre-router traces behave exactly as before."""

    rid: int
    tokens: np.ndarray
    max_new_tokens: int
    arrival: int = 0
    extras: Optional[Dict[str, np.ndarray]] = None
    priority: int = 0
    deadline_ms: Optional[int] = None
    ttft_deadline_ms: Optional[int] = None

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.tokens).shape[-1])

    def as_batch(self, bucket: Optional[int] = None) -> Dict[str, jax.Array]:
        """The batch-1 model input this request prefills with — also what
        oracle replays (greedy_generate) must feed so they compare against
        the same computation.  ``bucket`` right-pads the prompt to the
        engine's bucketed-prefill shape (``ServeEngine.bucket_for``)."""
        batch = {"tokens": jnp.asarray(
            np.asarray(self.tokens, np.int32).reshape(1, -1))}
        for k, v in (self.extras or {}).items():
            batch[k] = jnp.asarray(v)[None]
        return pad_prompt_batch(batch, bucket)


class Attribution(str, enum.Enum):
    """How a request's output came to be (DESIGN.md Section 13): served
    normally, shed by admission control, replayed on a surviving replica
    after its first replica died, or won by a hedged duplicate.  Plain
    engine runs only ever produce ``NORMAL``; the router stamps the
    rest."""

    NORMAL = "normal"
    SHED = "shed"
    RETRIED = "retried"
    HEDGED = "hedged"


@dataclasses.dataclass
class RequestOutput:
    rid: int
    tokens: List[int] = dataclasses.field(default_factory=list)
    admitted: int = -1
    finished: int = -1
    # engine clock at each token's emission — consecutive diffs are the
    # virtual inter-token latency the serve bench reports (Section 13)
    token_steps: List[int] = dataclasses.field(default_factory=list)
    attribution: Attribution = Attribution.NORMAL
    shed_reason: Optional[str] = None


# ---------------------------------------------------------------------------
# scheduler (pure bookkeeping — no jax; the hypothesis sweeps in
# tests/test_properties.py drive it directly against random traces)
# ---------------------------------------------------------------------------

class Scheduler:
    """FCFS slot scheduler.

    ``policy="continuous"``: waiting requests are admitted into freed slots
    every step, at most ``max_admissions_per_step`` per tick, so prefill
    work interleaves with decode of the running slots.
    ``policy="static"``: admission only when the pool has fully drained —
    the classic static-batching baseline whose stragglers idle the pool
    (benchmarks/bench_serve.py measures the gap).

    Admission is amortized O(1) per request: an arrival-ordered heap feeds
    a ready queue ordered by submission as the clock passes each arrival,
    so a tick never rescans the whole waiting set (the old list scan was
    O(waiting) per tick, O(n * steps) per trace).  The admitted order is
    exactly the scan's — FCFS by submission over the arrived portion — and
    tests/test_properties.py holds the two implementations equal under
    random traces.
    """

    def __init__(self, num_slots: int, policy: str = "continuous",
                 max_admissions_per_step: int = 1):
        if num_slots < 1:
            raise ValueError("need at least one slot")
        if policy not in ("continuous", "static"):
            raise ValueError(f"unknown policy {policy!r}")
        self.num_slots = num_slots
        self.policy = policy
        self.max_admissions = max(1, max_admissions_per_step)
        self._seq = 0                             # submission order
        self._by_arrival: List[Tuple[int, int, Request]] = []
        self._ready: List[Tuple[int, Request]] = []
        self.running: Dict[int, Request] = {}
        self.remaining: Dict[int, int] = {}
        self.finished: List[int] = []
        self._free = list(range(num_slots - 1, -1, -1))   # pop() -> slot 0

    def add(self, req: Request) -> None:
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.rid}: max_new_tokens must be >=1")
        heapq.heappush(self._by_arrival, (req.arrival, self._seq, req))
        self._seq += 1

    @property
    def waiting(self) -> List[Request]:
        """Not-yet-admitted requests in submission order (inspection only —
        built on demand; the hot path never materializes it)."""
        pend = [(s, r) for _, s, r in self._by_arrival] + list(self._ready)
        return [r for _, r in sorted(pend)]

    @property
    def waiting_count(self) -> int:
        return len(self._by_arrival) + len(self._ready)

    def admissions(self, step: int,
                   gate: Optional[Callable[[Request], bool]] = None
                   ) -> List[Tuple[int, Request]]:
        """Pop the (slot, request) pairs to admit at ``step`` — FCFS over
        the arrived portion of the queue, bounded by free slots and the
        per-step admission budget.  ``gate`` (the paged arena's page
        reservation, DESIGN.md Section 14) may veto the head request: it is
        pushed back to the front of the ready queue and admission stops —
        head-of-line blocking, so FCFS order is preserved while the pool
        drains.  The gate is only invoked when a slot and budget are
        available, so a True verdict (and any reservation it made) always
        commits."""
        while self._by_arrival and self._by_arrival[0][0] <= step:
            _, seq, req = heapq.heappop(self._by_arrival)
            heapq.heappush(self._ready, (seq, req))
        if self.policy == "static" and self.running:
            return []
        budget = (self.num_slots if self.policy == "static"
                  else self.max_admissions)
        out: List[Tuple[int, Request]] = []
        while self._free and self._ready and len(out) < budget:
            seq, req = heapq.heappop(self._ready)
            if gate is not None and not gate(req):
                heapq.heappush(self._ready, (seq, req))
                break
            slot = self._free.pop()
            self.running[slot] = req
            self.remaining[slot] = req.max_new_tokens
            out.append((slot, req))
        return out

    def emit(self, slot: int) -> bool:
        """Record one emitted token on ``slot``; frees the slot and returns
        True when that was the request's last token."""
        self.remaining[slot] -= 1
        if self.remaining[slot] > 0:
            return False
        req = self.running.pop(slot)
        del self.remaining[slot]
        self._free.append(slot)
        self.finished.append(req.rid)
        return True

    def would_admit(self, step: int,
                    gate: Optional[Callable[[Request], bool]] = None) -> bool:
        """Non-mutating peek: would ``admissions(step)`` pop at least one
        request?  The router classifies a replica's tick phase with it
        (prefill vs decode vs idle) without disturbing the queues.  Pass a
        *non-mutating* ``gate`` (``ServeEngine._admission_fit`` for paged
        arenas) to also account for page availability."""
        if not self._free:
            return False
        if self.policy == "static" and self.running:
            return False
        head = self._ready[0][1] if self._ready else None
        if head is None and self._by_arrival \
                and self._by_arrival[0][0] <= step:
            head = self._by_arrival[0][2]
        if head is None:
            return False
        return gate(head) if gate is not None else True

    def cancel_slot(self, slot: int) -> Request:
        """Free ``slot`` without crediting a finished request — the
        router's hedge-loser/cancel path.  The request is *not* appended
        to ``finished``."""
        req = self.running.pop(slot)
        del self.remaining[slot]
        self._free.append(slot)
        return req

    def remove_waiting(self, rid: int) -> bool:
        """Drop a not-yet-admitted request from the queues (heaps are
        rebuilt — cancellation is rare and off the hot path).  Returns
        True when something was removed."""
        n0 = self.waiting_count
        self._by_arrival = [(a, s, r) for a, s, r in self._by_arrival
                            if r.rid != rid]
        heapq.heapify(self._by_arrival)
        self._ready = [(s, r) for s, r in self._ready if r.rid != rid]
        heapq.heapify(self._ready)
        return self.waiting_count < n0

    @property
    def active(self) -> List[int]:
        return sorted(self.running)

    def next_arrival(self) -> Optional[int]:
        """Arrival step of the earliest not-yet-arrived request (None when
        every waiting request has already arrived or the queue is empty) —
        the engine caps its fused-chunk length with it so a free slot is
        not left idle past a known arrival."""
        return self._by_arrival[0][0] if self._by_arrival else None

    def deferred_ready(self) -> bool:
        """True when arrived requests are still waiting (admission budget
        exhausted this tick) — the engine then keeps chunks short so the
        backlog drains at the next boundary."""
        return bool(self._ready)

    def has_work(self) -> bool:
        return bool(self._by_arrival or self._ready or self.running)

    # -- snapshot plumbing (DESIGN.md Section 11) ---------------------------

    def state_dict(self) -> Dict:
        """JSON-serializable snapshot of every queue — rides a checkpoint
        manifest's ``extra`` (checkpoint.read_manifest) so a fresh process
        can rebuild the host side of an engine snapshot and resume the
        trace.  Request token arrays become int lists; ``extras`` arrays
        (whisper frames) nested float lists — exact round-trips, floats
        included (float32 -> Python float -> float32 is lossless)."""
        def req(r: Request) -> Dict:
            d = {"rid": r.rid, "tokens": np.asarray(r.tokens).tolist(),
                 "max_new_tokens": r.max_new_tokens, "arrival": r.arrival,
                 "priority": r.priority, "deadline_ms": r.deadline_ms,
                 "ttft_deadline_ms": r.ttft_deadline_ms}
            if r.extras:
                d["extras"] = {k: [str(np.asarray(v).dtype),
                                   np.asarray(v).tolist()]
                               for k, v in r.extras.items()}
            return d
        return {"num_slots": self.num_slots, "policy": self.policy,
                "max_admissions": self.max_admissions, "seq": self._seq,
                "by_arrival": [[a, s, req(r)]
                               for a, s, r in sorted(self._by_arrival)],
                "ready": [[s, req(r)] for s, r in sorted(self._ready)],
                "running": {str(slot): req(r)
                            for slot, r in self.running.items()},
                "remaining": {str(s): int(n)
                              for s, n in self.remaining.items()},
                "finished": list(self.finished),
                "free": list(self._free)}

    @classmethod
    def from_state_dict(cls, d: Dict) -> "Scheduler":
        """Inverse of ``state_dict`` — reconstructs the exact queue state
        (heap entries, submission counter, free-slot stack), so admission
        order after a restore equals the uninterrupted run's."""
        def req(rd: Dict) -> Request:
            extras = {k: np.asarray(v, np.dtype(dt))
                      for k, (dt, v) in rd.get("extras", {}).items()} or None
            return Request(rid=rd["rid"],
                           tokens=np.asarray(rd["tokens"], np.int32),
                           max_new_tokens=rd["max_new_tokens"],
                           arrival=rd["arrival"], extras=extras,
                           priority=rd.get("priority", 0),
                           deadline_ms=rd.get("deadline_ms"),
                           ttft_deadline_ms=rd.get("ttft_deadline_ms"))
        sched = cls(d["num_slots"], d["policy"], d["max_admissions"])
        sched._seq = d["seq"]
        sched._by_arrival = [(a, s, req(r)) for a, s, r in d["by_arrival"]]
        heapq.heapify(sched._by_arrival)
        sched._ready = [(s, req(r)) for s, r in d["ready"]]
        heapq.heapify(sched._ready)
        sched.running = {int(k): req(r) for k, r in d["running"].items()}
        sched.remaining = {int(k): int(n) for k, n in d["remaining"].items()}
        sched.finished = list(d["finished"])
        sched._free = list(d["free"])
        return sched


# ---------------------------------------------------------------------------
# cache-arena plumbing
# ---------------------------------------------------------------------------

def _promote_arena(cache: Any, num_slots: int) -> Any:
    """``init_cache``'s tree with scalar counters promoted to per-slot
    (B,) vectors — the decode paths' vector-pos branch.  The single
    definition of the arena's shape contract: both engines allocate with
    it and the mesh layer's jit in_shardings are derived from it
    (runtime.mesh_serve), so the promotion rule cannot drift."""
    return jax.tree.map(
        lambda leaf: jnp.zeros((num_slots,), leaf.dtype)
        if leaf.ndim == 0 else leaf, cache)


def _batch_axes(api: ModelApi, cache_len: int) -> Any:
    """Per-leaf batch-axis index of the cache tree (-1 for scalar position
    counters), discovered by diffing the shapes ``init_cache`` declares for
    batch sizes 2 and 1 — no per-family knowledge needed."""
    two = jax.eval_shape(lambda: api.init_cache(2, cache_len))
    one = jax.eval_shape(lambda: api.init_cache(1, cache_len))

    def axis(p, s):
        diffs = [i for i, (a, b) in enumerate(zip(p.shape, s.shape))
                 if a != b]
        if len(diffs) > 1:
            raise ValueError(f"ambiguous cache batch axis: {p.shape} vs "
                             f"{s.shape}")
        if not diffs:
            if p.shape != ():
                raise ValueError("cache leaf without a batch axis must be "
                                 f"a scalar counter, got shape {p.shape}")
            return -1
        return diffs[0]

    return jax.tree.map(axis, two, one)


def _make_insert(axes: Any, jit_wrap: Optional[Callable] = None) -> Callable:
    """Jitted in-place (donated) admission: writes a single-request cache
    into one slot of the pool arena, seeds the slot's feedback token from
    the prefill logits (argmax on device) and its owed-token counter — one
    dispatch per admission, no host sync.  Scalar counters (axis -1) land
    in the promoted per-slot (B,) vector.  Returns the (1,) first token so
    the host can emit it lazily with the next chunk's sync.

    ``jit_wrap`` supplies the jit policy: plain donation by default; the
    mesh-parallel engine (``runtime.mesh_serve``, DESIGN.md Section 10)
    passes donation *plus* the arena in/out shardings, so a sharded pool
    stays sharded across admissions and the replicated batch-1 prefill
    cache reshards on the way in."""
    wrap = jit_wrap or functools.partial(jax.jit, donate_argnums=(0, 1, 2))

    @wrap
    def insert(pool, tokens, remaining, sub, logits, slot, rem):
        def one(pl, sl, ax):
            if ax < 0:
                return jax.lax.dynamic_update_slice(
                    pl, sl.astype(pl.dtype).reshape(1), (slot,))
            starts = [0] * pl.ndim
            starts[ax] = slot
            return jax.lax.dynamic_update_slice(pl, sl.astype(pl.dtype),
                                                tuple(starts))
        pool = jax.tree.map(one, pool, sub, axes)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)          # (1,)
        tokens = jax.lax.dynamic_update_slice(tokens, tok[:, None], (slot, 0))
        remaining = jax.lax.dynamic_update_slice(
            remaining, rem.reshape(1), (slot,))
        return pool, tokens, remaining, tok

    return insert


def _make_paged_insert(axes: Any, spec: PagedSpec,
                       jit_wrap: Optional[Callable] = None) -> Callable:
    """Paged-arena admission (DESIGN.md Section 14): the prefilled
    single-request cache's pageable leaves are reshaped into (stack,
    max_pages, page_size, ...) token pages and scattered onto the slot's
    reserved physical pages (``page_row``); unreserved logical pages map to
    the DUMP page, so bucket padding beyond the reservation is discarded by
    construction.  The slot's page-table row is installed in the same
    dispatch, every non-pageable leaf takes the fixed-arena
    dynamic_update_slice path, and int8 pools quantize per token row on the
    way in (optim.compression.quantize_rows), storing the scales alongside.
    No resident page is ever copied — admission is one scatter per pageable
    leaf regardless of pool occupancy."""
    wrap = jit_wrap or functools.partial(jax.jit, donate_argnums=(0, 1, 2))

    @wrap
    def insert(pool, tokens, remaining, sub, logits, slot, rem, page_row):
        def one(pl, sl, ax):
            if ax < 0:
                return jax.lax.dynamic_update_slice(
                    pl, sl.astype(pl.dtype).reshape(1), (slot,))
            starts = [0] * pl.ndim
            starts[ax] = slot
            return jax.lax.dynamic_update_slice(pl, sl.astype(pl.dtype),
                                                tuple(starts))
        out = {}
        for key, pl in pool.items():
            if key == "pages":
                out[key] = pl.at[slot].set(page_row)
            elif key in spec.paged_keys or key.endswith("_scale"):
                pass                       # rewritten with their pool below
            else:
                out[key] = jax.tree.map(one, pl, sub[key], axes[key])
        for key in spec.paged_keys:
            x = sub[key][:, 0]                   # (stack, cache_len, *rest)
            x = x.reshape(x.shape[0], spec.max_pages, spec.page_size,
                          *x.shape[2:])
            if spec.kv_dtype == "int8":
                q, s = quantize_rows(x, 3)
                out[key] = pool[key].at[:, page_row].set(q)
                out[key + "_scale"] = \
                    pool[key + "_scale"].at[:, page_row].set(s)
            else:
                out[key] = pool[key].at[:, page_row].set(
                    x.astype(pool[key].dtype))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)          # (1,)
        tokens = jax.lax.dynamic_update_slice(tokens, tok[:, None], (slot, 0))
        remaining = jax.lax.dynamic_update_slice(
            remaining, rem.reshape(1), (slot,))
        return out, tokens, remaining, tok

    return insert


def _default_serve_fns(api: ModelApi, cache_len: int, decode_chunk: int = 8):
    """Unsharded single-host jits; the mesh-aware factory is
    ``runtime.serve.jit_serve_fns`` (launch/serve.py passes it in).  The
    third element is ``chunk_for(n)`` — a memoized fused-chunk jit per scan
    length on the engine's power-of-two ladder — with the cache/token/
    remaining carry donated so the pool arena updates in place."""
    prefill = jax.jit(lambda p, b: api.prefill(p, b, cache_len=cache_len))
    decode = jax.jit(lambda p, c, t: api.decode_step(p, c, t),
                     donate_argnums=(1,))
    chunk_for = make_chunk_ladder(
        api, decode_chunk, lambda fn: jax.jit(fn, donate_argnums=(1, 2, 3)))
    return prefill, decode, chunk_for


def weight_sparsity(params: Any,
                    names: Sequence[str] = GEMM_WEIGHTS) -> float:
    """Mean sparsity of the weight GEMM leaves ``griffin_linear`` executes
    (trailing-name selection as in ``sparsity.sparsify_params``):
    ``GriffinWeights`` leaves report ``1 - density`` (their zeros were
    physically dropped), plain leaves their exact zero fraction — the
    B-side input to ``select_mode``."""
    vals: List[float] = []

    def walk(t, name=""):
        if isinstance(t, GriffinWeights):
            vals.append(1.0 - t.density)
        elif isinstance(t, dict):
            for k, v in t.items():
                walk(v, k)
        elif isinstance(t, (list, tuple)):
            for v in t:
                walk(v, name)
        elif name in names and hasattr(t, "ndim") and t.ndim >= 2 and \
                t.size and jnp.issubdtype(t.dtype, jnp.floating):
            # t.size == 0: zero-length layer stacks (stack_layers(n=0),
            # e.g. the reduced hybrid's empty tail) have no zero fraction
            vals.append(float(sparsity_of(t)))

    walk(params)
    return float(np.mean(vals)) if vals else 0.0


# ---------------------------------------------------------------------------
# recovery snapshots (DESIGN.md Section 11)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EngineSnapshot:
    """Host-side copy of everything one engine tick can mutate, captured at
    tick start while recovery is armed: the device buffers (arena, token
    feedback, per-slot remaining) as numpy trees, deep copies of the pure-
    Python scheduler/outputs, and the measurement/mode/clock scalars.
    Rolling an engine back to a snapshot and replaying is deterministic, so
    a tick interrupted by a fault finishes with the same tokens as an
    uninterrupted run (DESIGN.md Section 11).  ``ckpt_step`` is set when
    the snapshot also went to disk (``ServeEngine(snapshot_dir=...)``) —
    recovery then reloads the device state through ``checkpoint.restore``
    onto the post-loss shardings instead of from memory."""

    device: Dict[str, Any]
    sched: Scheduler
    outputs: Dict[int, RequestOutput]
    events_len: int
    clock: int
    mode: Mode
    a_measured: float
    since_measure: int
    mode_history: List[Tuple[int, Mode]]
    stats: Dict[str, int]
    prefill_buckets: set
    ckpt_step: Optional[int] = None
    # paged-arena host state (allocator free list, slot->pages map, dirty
    # slots pending reclamation) — the device-side pool/page-table/scale
    # arrays already ride ``device["cache"]``, so replay after a restore
    # reproduces the exact same page assignments (DESIGN.md Section 14)
    paging: Optional[Dict] = None


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

class ServeEngine:
    """Continuous-batching driver over a ``ModelApi``.

    ``fns_factory`` returns (prefill_fn, decode_fn, decode_chunk_fn[, ...])
    — pass ``lambda: jit_serve_fns(api, mesh, num_slots, cache_len,
    decode_chunk=...)`` to serve on a mesh (launch/serve.py does); default
    is single-host jits.  The factory is invoked once per selected
    execution mode: the resulting jits are traced (and always called) under
    that mode's ``sparse_execution`` scope, which is how a workload-category
    flip reaches the kernels.

    Greedy decoding only (argmax), matching the ``greedy_generate`` oracle.
    Prompts prefill at power-of-two bucketed lengths (``bucket_for``), so
    prefill retraces are bounded O(log cache_len) per mode instead of one
    per distinct prompt length; decode runs ``decode_chunk`` fused steps
    per host round-trip (DESIGN.md Section 9).

    Failure handling (DESIGN.md Section 11) arms when a ``fault_injector``
    (deterministic chaos, ``runtime.fault``), a ``straggler`` detector, or
    a ``snapshot_dir`` is passed: every tick captures a host-side snapshot
    first, a ``DeviceLoss`` rolls back/remeshes/replays, and persistent
    stragglers are evicted into the same path at tick boundaries.
    ``recoveries``/``recovery_log`` record what happened.
    """

    def __init__(self, api: ModelApi, params: Any, *,
                 config: Optional[EngineConfig] = None,
                 fns_factory: Optional[Callable] = None,
                 fault_injector: Optional[FaultInjector] = None,
                 straggler: Optional[StragglerDetector] = None,
                 plan: Any = None, **legacy: Any):
        # ``config=EngineConfig(...)`` is the construction path (DESIGN.md
        # Section 14); the old flat keywords (num_slots=, cache_len=, ...)
        # still work for one release via the deprecation shim.  Runtime
        # objects (fns_factory, fault_injector, straggler, the resolved
        # kernel plan) stay direct arguments — they are not serializable
        # configuration.
        config = resolve_engine_config(config, legacy, type(self).__name__)
        self.config = config
        self.api = api
        self.params = params
        if config.arena.cache_len is None:
            raise ValueError("cache_len is required: set "
                             "ArenaConfig.cache_len (or legacy cache_len=)")
        # paged arena resolution: a page_size activates the paged pool when
        # the family exposes pageable leaves (runtime/paging.py discovery;
        # xlstm's recurrent state degrades to the fixed arena), and
        # cache_len rounds up to a page multiple so pooled views keep the
        # fixed arena's shapes (fp32 paging stays bit-exact)
        self._paged, cache_len = build_spec(
            api, config.arena.num_slots, config.arena.cache_len,
            config.arena.page_size, config.arena.num_pages,
            config.arena.kv_dtype)
        num_slots = config.arena.num_slots
        policy = config.sched.policy
        max_admissions_per_step = config.sched.max_admissions_per_step
        use_kernels = config.kernels.use_kernels
        interpret = config.kernels.interpret
        spmd_kernels = config.kernels.spmd_kernels
        a_sparsity = config.kernels.a_sparsity
        block_m = config.kernels.block_m
        measure_every = config.sched.measure_every
        decode_chunk = config.sched.decode_chunk
        bucket_prompts = config.sched.bucket_prompts
        fused = config.sched.fused
        snapshot_dir = config.fault.snapshot_dir
        # tuned kernel plan (repro.tuning, DESIGN.md Section 12): a
        # KernelPlan (resolved by this model's family) or a FamilyPlan.
        # Only the Mode-selection thresholds act here — compaction
        # granularity was already applied when the caller ran
        # sparsify_params(plan=...) over these params.  Thresholds change
        # which kernels trace, never what they compute, so a planned
        # engine stays token-identical to the default one.
        fam = plan
        if plan is not None and hasattr(plan, "families"):
            fam = plan.family(api.cfg.family)
        self.plan = fam
        self._a_threshold = (fam.a_threshold if fam is not None
                             and fam.a_threshold is not None
                             else SPARSE_THRESHOLD)
        self._b_threshold = (fam.b_threshold if fam is not None
                             and fam.b_threshold is not None
                             else SPARSE_THRESHOLD)
        self.num_slots = num_slots
        self.cache_len = cache_len
        self.decode_chunk = max(1, decode_chunk)
        # router/SLO hooks (DESIGN.md Section 13): ``chunk_cap`` caps the
        # fused-chunk ladder (degradation level 1 — shorter ticks, faster
        # admission turnaround); ``degraded`` forces the cheaper Mode by
        # zeroing the B-side threshold (level 2).  Both default inert.
        self.chunk_cap: Optional[int] = None
        self.degraded = False
        self.bucket_prompts = bucket_prompts
        # fused=False keeps the PR 3 per-step hot path (one decode dispatch
        # + host argmax + sync per token, measurement gathering the full
        # logits): the benchmark baseline bench_serve.py measures the fused
        # scan against, and a regression reference for the parity suite
        self.fused = fused
        self.sched = Scheduler(num_slots, policy, max_admissions_per_step)
        self._fns_factory = fns_factory or (
            lambda: _default_serve_fns(api, cache_len, self.decode_chunk))
        self._mode_fns: Dict[Mode, Tuple[Callable, ...]] = {}
        self.use_kernels = use_kernels
        self.interpret = interpret
        # spmd_kernels=False forces the SPMD decompaction/dense-product
        # oracles on a multi-device mesh instead of the shard_map'd Pallas
        # kernels — the fallback-forced parity smoke (DESIGN.md Section 10)
        self.spmd_kernels = spmd_kernels
        self.block_m = block_m
        self.a_declared = a_sparsity
        self.measure_every = max(1, measure_every)
        self.b_sparsity = weight_sparsity(params)
        self.a_measured = 0.0
        self.mode = self._select_mode()
        self.mode_history: List[Tuple[int, Mode]] = [(0, self.mode)]
        self.clock = 0
        self._since_measure = 0
        self.outputs: Dict[int, RequestOutput] = {}
        self.events: List[Tuple[int, int, int]] = []    # (step, rid, token)
        self.stats = {"decode_steps": 0, "prefill_calls": 0, "emitted": 0,
                      "idle_steps": 0, "retraces": 0, "chunk_calls": 0,
                      "host_syncs": 0}
        self.prefill_buckets: set = set()       # distinct admitted shapes
        # prompt buckets longer than the usable cache window cannot be
        # right-padded (the window would evict real K/V); those prompts
        # fall back to exact-length prefill
        window = getattr(api.cfg, "window", None)
        self._bucket_cap = min(cache_len, window or cache_len)
        # failure handling (DESIGN.md Section 11): while any of these are
        # armed, every tick starts by capturing a host-side snapshot —
        # detection (an injected/real DeviceLoss, or the straggler
        # detector's eviction verdict) then rolls back, remeshes onto the
        # survivors, reshards, and replays
        self.faults = fault_injector
        self.straggler = straggler
        self.snapshot_dir = snapshot_dir
        self.recoveries = 0
        self.recovery_log: List[Dict] = []
        self._snapshot: Optional[EngineSnapshot] = None
        self._evicted: set = set()
        self._params_host = (jax.tree.map(np.asarray, params)
                             if self._recovery_armed() else None)
        # paged-arena host bookkeeping (DESIGN.md Section 14): the physical
        # page allocator, the slot -> reserved-pages map, reservations made
        # by the admission gate this tick, and dead slots whose page-table
        # rows await the tick-start DUMP redirect + page reclamation
        self._page_alloc = (PageAllocator(self._paged.num_pages)
                            if self._paged is not None else None)
        self._slot_pages: Dict[int, List[int]] = {}
        self._reserved_pages: Dict[int, List[int]] = {}
        self._dirty_slots: set = set()
        self._clear_pages = (jax.jit(
            lambda pages, mask: jnp.where(mask[:, None], 0, pages),
            donate_argnums=(0,)) if self._paged is not None else None)
        self._init_device_state()

    # device placement hooks: the mesh-parallel engine
    # (runtime.mesh_serve.MeshServeEngine, DESIGN.md Section 10) overrides
    # these to place the arena sharded and wrap _insert with shardings; the
    # host-side bookkeeping above (scheduler, remaining mirror, outputs) is
    # identical either way
    _spmd_mesh = None          # consumed by _scope(); None = single-device

    def _arena(self) -> Any:
        """The engine's device arena tree: ``_promote_arena`` over
        init_cache's tree, rewritten into pool + page-table form when the
        arena is paged (runtime.paging.paged_tree)."""
        base = _promote_arena(
            self.api.init_cache(self.num_slots, self.cache_len),
            self.num_slots)
        if self._paged is not None:
            return paged_tree(base, self.num_slots, self._paged)
        return base

    def _init_device_state(self) -> None:
        """Allocate the arena (``_arena``), the donated slot-insert jit,
        and the token/remaining device buffers."""
        self.cache = self._arena()
        self._build_insert()
        self._tokens = jnp.zeros((self.num_slots, 1), jnp.int32)
        self._remaining = jnp.zeros((self.num_slots,), jnp.int32)

    def _build_insert(self) -> None:
        """(Re)jit the donated slot-insert — recovery rebuilds it when the
        arena shardings changed with the mesh (runtime.mesh_serve)."""
        axes = _batch_axes(self.api, self.cache_len)
        self._insert = (_make_paged_insert(axes, self._paged)
                        if self._paged is not None else _make_insert(axes))

    # -- paged-arena bookkeeping (DESIGN.md Section 14) ---------------------

    def _page_gate(self, req: Request) -> bool:
        """Admission gate: reserve the physical pages covering prompt +
        generation before the scheduler commits the slot.  On pool
        exhaustion the request stays at the head of the ready queue
        (head-of-line blocking keeps FCFS order); pages free up as running
        requests finish."""
        need = self._paged.pages_needed(req.prompt_len + req.max_new_tokens)
        ids = self._page_alloc.reserve(need)
        if ids is None:
            return False
        self._reserved_pages[req.rid] = ids
        return True

    def _admission_gate(self) -> Optional[Callable[[Request], bool]]:
        return self._page_gate if self._paged is not None else None

    def _admission_fit(self, req: Request) -> bool:
        """Non-mutating twin of ``_page_gate`` for ``would_admit`` peeks
        (the router's phase classification)."""
        if self._paged is None:
            return True
        need = self._paged.pages_needed(req.prompt_len + req.max_new_tokens)
        return need <= self._page_alloc.free_pages

    def _flush_dirty(self) -> None:
        """Tick-start reclamation: dead slots' page-table rows are
        redirected to the DUMP page on device (so their garbage decode
        writes stop landing on reclaimable pages) and their physical pages
        return to the allocator, becoming reservable by this tick's
        admissions.  Release is O(max_pages) metadata — no page is
        copied."""
        if self._paged is None or not self._dirty_slots:
            return
        mask = np.zeros((self.num_slots,), bool)
        mask[sorted(self._dirty_slots)] = True
        self.cache = dict(self.cache, pages=self._clear_pages(
            self.cache["pages"], jnp.asarray(mask)))
        for slot in sorted(self._dirty_slots):
            self._page_alloc.free(self._slot_pages.pop(slot, ()))
        self._dirty_slots.clear()

    def _paging_state(self) -> Dict:
        """JSON-serializable snapshot of the paged host state — rides
        ``EngineSnapshot.paging`` and the checkpoint manifest so recovery
        (and fresh-process restarts) reproduce the exact page
        assignments."""
        return {"allocator": self._page_alloc.state_dict(),
                "slot_pages": {str(s): [int(i) for i in ids]
                               for s, ids in self._slot_pages.items()},
                "dirty": sorted(int(s) for s in self._dirty_slots)}

    def _restore_paging(self, state: Dict) -> None:
        self._page_alloc = PageAllocator.from_state_dict(state["allocator"])
        self._slot_pages = {int(s): [int(i) for i in ids]
                            for s, ids in state["slot_pages"].items()}
        self._dirty_slots = set(int(s) for s in state["dirty"])
        self._reserved_pages = {}

    # -- mode plumbing ------------------------------------------------------

    def _a_now(self) -> float:
        return (self.a_declared if self.a_declared is not None
                else self.a_measured)

    def _select_mode(self) -> Mode:
        return select_mode(self._a_now(), self.b_sparsity,
                           threshold=self._a_threshold,
                           b_threshold=(0.0 if self.degraded
                                        else self._b_threshold))

    def set_degraded(self, on: bool) -> None:
        """Degradation-ladder level 2 (DESIGN.md Section 13): force the
        cheaper execution Mode through the PR 8 threshold machinery —
        ``on`` zeroes the B-side threshold so any pruned weight selects
        the Sparse.B kernels even in the dense-preferred regime (dense
        weights stay dense: 0 > 0 is false either way).  Re-selects
        immediately; a flip swaps the Mode-keyed jit set like any
        measured flip."""
        if on == self.degraded:
            return
        self.degraded = on
        mode = self._select_mode()
        if mode != self.mode:
            self.mode = mode
            self.mode_history.append((self.clock, mode))

    def _scope(self):
        a_scope = 0.0
        if self.mode in (Mode.A, Mode.AB):
            a_scope = (self.a_declared
                       if self.a_declared is not None
                       and self.a_declared > self._a_threshold
                       else DEFAULT_DECLARED_A)
        return sparse_execution(use_kernels=self.use_kernels,
                                interpret=self.interpret,
                                a_sparsity=a_scope, block_m=self.block_m,
                                spmd_mesh=self._spmd_mesh,
                                spmd_kernels=self.spmd_kernels,
                                a_threshold=self._a_threshold)

    def _fns(self) -> Tuple[Callable, Callable, Callable]:
        fns = self._mode_fns.get(self.mode)
        if fns is None:
            made = self._fns_factory()
            fns = (made[0], made[1], made[2])
            self._mode_fns[self.mode] = fns
            self.stats["retraces"] += 1
        return fns

    def _measure(self, zero_frac: float) -> None:
        """Workload-category measurement from the fused chunk's on-device
        accumulator (exact-zero logit fraction of live rows only — the scan
        masks out freed/unadmitted slots, so their stale rows cannot skew
        the category); a flipped ``select_mode`` verdict swaps the
        jitted-fn set (mode is a trace-time decision, DESIGN.md Section 5)
        starting with the *next* chunk — flips lag the measurement by at
        most ``decode_chunk`` steps (Section 9)."""
        self._since_measure = 0
        self.a_measured = float(zero_frac)
        mode = self._select_mode()
        if mode != self.mode:
            self.mode = mode
            self.mode_history.append((self.clock, mode))

    # -- request lifecycle --------------------------------------------------

    def add(self, req: Request) -> None:
        if req.prompt_len + req.max_new_tokens > self.cache_len:
            raise ValueError(
                f"request {req.rid}: prompt {req.prompt_len} + gen "
                f"{req.max_new_tokens} exceeds cache_len {self.cache_len}")
        if self.api.cfg.is_encdec and (req.extras or {}).get("frames") is None:
            raise ValueError(f"request {req.rid}: enc-dec model needs "
                             "extras['frames']")
        self.sched.add(req)

    def bucket_for(self, prompt_len: int) -> Optional[int]:
        """Power-of-two prefill bucket for a prompt length (min
        ``MIN_BUCKET``), or None when the bucket would overflow the usable
        cache window (exact-length prefill then; also when bucketing is
        disabled).  Bounds distinct admitted prefill shapes — hence prefill
        retraces per mode — to O(log cache_len)."""
        if not self.bucket_prompts:
            return None
        b = MIN_BUCKET
        while b < prompt_len:
            b *= 2
        return b if b <= self._bucket_cap else None

    def _chunk_len(self, admitted_slots: frozenset = frozenset()) -> int:
        """Fused-chunk length for this tick: the largest power of two
        <= ``decode_chunk`` that (a) no live slot finishes inside — the
        host mirror of ``remaining`` makes mid-chunk completions
        predictable, so finishing slots free exactly at a chunk boundary
        and no decode step is ever wasted on a dead row — and (b) does not
        overrun a known arrival (or an admission-budget backlog) while a
        slot sits free.  The completion bound (a) is exact — wasted decode
        steps cost real device work; the latency bounds (b) are floored at
        ``decode_chunk / 4``: shortening chunks further only shaves a few
        steps of admission latency while multiplying host syncs.  The
        ladder costs at most log2(decode_chunk)+1 traces per mode
        (DESIGN.md Section 9).

        ``admitted_slots``: slots admitted *this tick* — their scheduler
        ``remaining`` still includes the prefill-boundary token (emitted
        from the chunk's sync, not by a decode step), so they owe the
        device one step fewer."""
        cap = self.decode_chunk
        if self.chunk_cap is not None:      # degradation level 1 (Sec. 13)
            cap = max(1, min(cap, self.chunk_cap))
        bound = min(self.sched.remaining[s] - (s in admitted_slots)
                    for s in self.sched.active)
        bound = max(1, bound)      # a lone max_new_tokens=1 admission still
        #                            runs the 1-step chunk its sync rides on
        if self.sched._free and self.sched.policy == "continuous":
            floor = max(1, cap // 4)
            if self.sched.deferred_ready():
                bound = min(bound, floor)
            else:
                na = self.sched.next_arrival()
                if na is not None:
                    bound = min(bound, max(floor, na - self.clock))
        c = 1
        while c * 2 <= cap and c * 2 <= bound:
            c *= 2
        return c

    def _prefill(self, req: Request):
        prefill_fn = self._fns()[0]
        bucket = self.bucket_for(req.prompt_len)
        batch = req.as_batch(bucket)
        self.prefill_buckets.add(batch["tokens"].shape[-1])
        with self._scope():
            cache1, logits = prefill_fn(self.params, batch)
        self.stats["prefill_calls"] += 1
        return cache1, logits

    def _emit(self, slot: int, token: int) -> None:
        req = self.sched.running[slot]
        out = self.outputs[req.rid]
        out.tokens.append(token)
        out.token_steps.append(self.clock)
        self.events.append((self.clock, req.rid, token))
        self.stats["emitted"] += 1
        if self.sched.emit(slot):
            out.finished = self.clock
            if self._paged is not None:
                # pages stay owned (the slot may still see garbage decode
                # writes until the chunk ends) — reclaimed at the next
                # tick's _flush_dirty, before any admission can reuse them
                self._dirty_slots.add(slot)

    def cancel(self, rid: int) -> bool:
        """Withdraw a request — the router's hedge-loser / drain hook.
        A running request's slot is freed and its on-device ``remaining``
        zeroed (the live mask drops it, the chunk ladder stops waiting on
        it — the stale rows are the usual dead weight until the next
        admission); a waiting request just leaves the queues.  Call at
        tick boundaries only.  Returns False when ``rid`` is unknown or
        already finished."""
        for slot, req in sorted(self.sched.running.items()):
            if req.rid == rid:
                self._remaining = self._remaining.at[slot].set(0)
                self.sched.cancel_slot(slot)
                if self._paged is not None:
                    self._dirty_slots.add(slot)
                return True
        return self.sched.remove_waiting(rid)

    @property
    def load(self) -> int:
        """Requests this engine currently owns (running + queued) — the
        router's deterministic least-loaded dispatch signal."""
        return len(self.sched.running) + self.sched.waiting_count

    def step(self) -> List[Tuple[int, int, int]]:
        """One engine tick: admissions (each prefilled at its bucketed
        length and written into its slot with first token + owed-token
        counter seeded on device) followed by one fused ``decode_chunk``-
        step scan advancing every running slot.  The single host sync per
        tick fetches the (chunk, B) token ring, the admissions' first
        tokens, and the measurement scalars together; the ring is then
        drained against the scheduler, the clock advancing one step per
        executed chunk row.  Returns the tick's (step, rid, token) events.

        Slots freed mid-chunk idle until the next tick, and newly arrived
        requests wait for the chunk boundary — admission latency is bounded
        by ``decode_chunk`` steps (DESIGN.md Section 9, though the
        chunk-length ladder caps chunks at known completions/arrivals so
        neither happens on predictable traces).

        While recovery is armed (a ``FaultInjector``, a
        ``StragglerDetector``, or a ``snapshot_dir``), the tick starts by
        capturing a host-side snapshot; a ``DeviceLoss`` detected anywhere
        inside the tick rolls back to it, remeshes onto the survivors, and
        replays the tick — deterministically, so the finished trace is
        token-identical to an uninterrupted run (DESIGN.md Section 11).
        """
        t0 = time.perf_counter()
        if self._recovery_armed():
            self._snapshot = self._capture()
        impl = self._step_fused if self.fused else self._step_stepwise
        try:
            events = impl()
        except DeviceLoss as loss:
            self._recover(list(loss.lost), self._snapshot)
            events = impl()
        self._observe_hosts(time.perf_counter() - t0)
        return events

    def _step_fused(self) -> List[Tuple[int, int, int]]:
        ev_start = len(self.events)
        pending: List[Tuple[int, int, jax.Array]] = []  # slot, rid, dev tok
        self._poll_fault("admission")
        self._flush_dirty()
        for slot, req in self.sched.admissions(self.clock,
                                               gate=self._admission_gate()):
            cache1, logits = self._prefill(req)
            self._poll_fault("prefill")
            rem = jnp.asarray(req.max_new_tokens - 1, jnp.int32)
            args = (self.cache, self._tokens, self._remaining, cache1,
                    logits, jnp.asarray(slot, jnp.int32), rem)
            if self._paged is not None:
                ids = self._reserved_pages.pop(req.rid)
                self._slot_pages[slot] = ids
                args += (jnp.asarray(self._paged.page_row(ids)),)
            self.cache, self._tokens, self._remaining, tok = \
                self._insert(*args)
            self.outputs[req.rid] = RequestOutput(req.rid,
                                                  admitted=self.clock)
            pending.append((slot, req.rid, tok))
        admitted = frozenset(s for s, _, _ in pending)
        if self.sched.active and all(
                self.sched.remaining[s] - (s in admitted) <= 0
                for s in self.sched.active):
            # pure-admission tick: every live slot is a fresh single-token
            # request — nothing owes a decode step, so fetch the prefill
            # tokens without dispatching a dead chunk
            first_toks = jax.device_get([t for _, _, t in pending])
            self.stats["host_syncs"] += 1
            for (slot, rid, _), tok in zip(pending, first_toks):
                self._emit(slot, int(tok[0]))
            self.clock += 1
        elif self.sched.active:
            chunk = self._chunk_len(admitted)
            chunk_fn = self._fns()[2](chunk)
            with self._scope():
                (self.cache, self._tokens, self._remaining, ring,
                 zf_num, zf_den) = chunk_fn(self.params, self.cache,
                                            self._tokens, self._remaining)
            self._poll_fault("decode")
            ring, first_toks, zf_num, zf_den = jax.device_get(
                (ring, [t for _, _, t in pending], zf_num, zf_den))
            self.stats["host_syncs"] += 1
            self.stats["chunk_calls"] += 1
            self.stats["decode_steps"] += chunk
            # prefill-boundary emissions first: the chunk consumed these
            # tokens as its first feedback, so they precede the ring rows
            for (slot, rid, _), tok in zip(pending, first_toks):
                self._emit(slot, int(tok[0]))
            for t in range(chunk):
                live = self.sched.active
                if not live:
                    break
                for slot in live:
                    self._emit(slot, int(ring[t, slot]))
                self.clock += 1
            self._since_measure += chunk
            if zf_den > 0 and self._since_measure >= self.measure_every:
                self._measure(float(zf_num) / float(zf_den))
        else:
            if self.sched.waiting_count:
                self.stats["idle_steps"] += 1
            self.clock += 1
        return self.events[ev_start:]

    def _step_stepwise(self) -> List[Tuple[int, int, int]]:
        """The PR 3 per-step hot path (``fused=False``): one pooled decode
        dispatch, argmax and ``np.asarray`` sync per token, measurement
        gathering the live rows of the full (B, vocab) logits.  Kept as the
        benchmark baseline (bench_serve.py times the fused scan against it)
        and as a behavioural reference — token output is identical to the
        fused path by construction."""
        ev_start = len(self.events)
        self._poll_fault("admission")
        self._flush_dirty()
        for slot, req in self.sched.admissions(self.clock,
                                               gate=self._admission_gate()):
            cache1, logits = self._prefill(req)
            self._poll_fault("prefill")
            rem = jnp.asarray(req.max_new_tokens - 1, jnp.int32)
            args = (self.cache, self._tokens, self._remaining, cache1,
                    logits, jnp.asarray(slot, jnp.int32), rem)
            if self._paged is not None:
                ids = self._reserved_pages.pop(req.rid)
                self._slot_pages[slot] = ids
                args += (jnp.asarray(self._paged.page_row(ids)),)
            self.cache, self._tokens, self._remaining, tok = \
                self._insert(*args)
            self.outputs[req.rid] = RequestOutput(req.rid,
                                                  admitted=self.clock)
            self.stats["host_syncs"] += 1
            self._emit(slot, int(tok[0]))
        active = self.sched.active
        if active:
            decode_fn = self._fns()[1]
            with self._scope():
                logits, self.cache = decode_fn(self.params, self.cache,
                                               self._tokens)
            self._poll_fault("decode")
            toks = jnp.argmax(logits, -1).astype(jnp.int32)    # (B,)
            self._tokens = toks[:, None]
            host = np.asarray(toks)
            self.stats["host_syncs"] += 1
            self.stats["decode_steps"] += 1
            self._since_measure += 1
            if self._since_measure >= self.measure_every:
                self._measure(float(sparsity_of(
                    logits[jnp.asarray(active)])))
                self.stats["host_syncs"] += 1
            for slot in active:
                self._emit(slot, int(host[slot]))
        elif self.sched.waiting_count:
            self.stats["idle_steps"] += 1
        self.clock += 1
        return self.events[ev_start:]

    # -- failure handling (DESIGN.md Section 11) ----------------------------

    def _recovery_armed(self) -> bool:
        return (self.faults is not None or self.straggler is not None
                or self.snapshot_dir is not None)

    def _poll_fault(self, phase: str) -> None:
        if self.faults is not None:
            self.faults.poll(phase, self.clock)

    def _capture(self) -> EngineSnapshot:
        """Consistent host-side snapshot of the tick-mutable state — one
        extra device_get per tick while recovery is armed, the price of
        rollback consistency (DESIGN.md Section 11).  With a
        ``snapshot_dir`` the device state (plus the compacted params and
        the scheduler queues) also goes to disk through
        ``checkpoint.save``, so recovery — or a fresh process — can restore
        through ``checkpoint.restore`` onto any mesh's shardings."""
        device = jax.device_get({"cache": self.cache,
                                 "tokens": self._tokens,
                                 "remaining": self._remaining})
        snap = EngineSnapshot(
            device=device, sched=copy.deepcopy(self.sched),
            outputs=copy.deepcopy(self.outputs),
            events_len=len(self.events), clock=self.clock, mode=self.mode,
            a_measured=self.a_measured, since_measure=self._since_measure,
            mode_history=list(self.mode_history), stats=dict(self.stats),
            prefill_buckets=set(self.prefill_buckets),
            paging=(self._paging_state() if self._paged is not None
                    else None))
        if self.snapshot_dir is not None:
            extra = {"scheduler": self.sched.state_dict(),
                     "clock": self.clock, "mode": self.mode.value}
            if snap.paging is not None:
                extra["paging"] = snap.paging
            ckpt_save(self.snapshot_dir, self.clock,
                      dict(device, params=self._params_host), keep=2,
                      extra=extra)
            snap.ckpt_step = self.clock
        return snap

    def _recover(self, lost: List[int], snap: Optional[EngineSnapshot]) -> None:
        """Device loss detected (an injected/real ``DeviceLoss`` mid-tick,
        or a straggler eviction at a tick boundary): remesh onto the
        survivors, roll every host structure back to ``snap``, and rebuild
        the device state from it on the new mesh.  The caller then replays
        from the snapshot's clock; replay is deterministic and the sharded
        layouts are reduction-order-preserving (DESIGN.md Section 10), so
        the finished trace is token-identical to an uninterrupted run."""
        if snap is None:
            raise RuntimeError("device loss with no snapshot armed")
        self._remesh(lost)
        self.sched = copy.deepcopy(snap.sched)
        self.outputs = copy.deepcopy(snap.outputs)
        del self.events[snap.events_len:]
        self.clock = snap.clock
        self.mode = snap.mode
        self.a_measured = snap.a_measured
        self._since_measure = snap.since_measure
        self.mode_history = list(snap.mode_history)
        self.stats = dict(snap.stats)
        self.prefill_buckets = set(snap.prefill_buckets)
        if self._paged is not None:
            if snap.paging is None:
                raise RuntimeError("paged engine snapshot lacks paging state")
            self._restore_paging(snap.paging)
        self._restore_device(snap)
        self.recoveries += 1
        self.recovery_log.append({"step": snap.clock, "lost": sorted(lost),
                                  "mesh": self._mesh_desc()})

    def _remesh(self, lost: List[int]) -> None:
        """A single-device engine has no mesh to shrink: recovery is a
        restart in place (the snapshot rebuilds the device state, the jits
        stay valid).  The mesh engine overrides this with plan_mesh on the
        survivors plus a sharding-spec / Mode-keyed-jit rebuild."""

    def _mesh_desc(self) -> str:
        return "unsharded"

    def _host_device_ids(self, host: int) -> List[int]:
        """Device ids owned by straggler host ``host`` — the single-device
        engine has one host and nothing to evict onto, so evictions only
        land in the recovery log.  The mesh engine maps hosts to data-rows
        of its device array."""
        return []

    def _snapshot_state(self, snap: EngineSnapshot, shardings: Optional[Any]):
        """The snapshot's device-state tree, from disk (through
        ``checkpoint.restore``, placing onto ``shardings``) when the
        snapshot was checkpointed, else from the in-memory copy (placement
        left to the caller)."""
        if snap.ckpt_step is None:
            return dict(snap.device)
        state = dict(snap.device)
        if self._params_host is not None:
            state["params"] = self._params_host
        template = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype),
            state)
        return ckpt_restore(self.snapshot_dir, template, step=snap.ckpt_step,
                            shardings=shardings)

    def _restore_device(self, snap: EngineSnapshot) -> None:
        state = self._snapshot_state(snap, shardings=None)
        self.cache = jax.tree.map(jnp.asarray, state["cache"])
        self._tokens = jnp.asarray(state["tokens"])
        self._remaining = jnp.asarray(state["remaining"])

    def _observe_hosts(self, dt: float) -> None:
        """Feed per-host step timings to the ``StragglerDetector`` (the
        injector's ``delay_host`` inflates one host's reading — a simulated
        persistent straggler) and route its eviction verdict into the same
        snapshot → remesh → reshard path as a detected device loss.  Runs
        at the tick boundary, where the state is already consistent: the
        recovery snapshot is captured on the spot and nothing is replayed."""
        if self.straggler is None:
            return
        for h in range(self.straggler.num_hosts):
            f = (self.faults.host_delay(h, self.clock)
                 if self.faults is not None else 1.0)
            self.straggler.record(h, dt * f)
        self.straggler.observe()
        evict = [h for h in self.straggler.evictions()
                 if h not in self._evicted]
        if not evict:
            return
        self._evicted.update(evict)
        lost = sorted({d for h in evict for d in self._host_device_ids(h)})
        if not lost or not self._survivors_exist(lost):
            self.recovery_log.append({"step": self.clock, "evicted": evict,
                                      "lost": [], "mesh": self._mesh_desc()})
            return
        self._recover(lost, self._capture())

    def _survivors_exist(self, lost: List[int]) -> bool:
        return True     # mesh engine checks against its device array

    def run(self, requests: Sequence[Request] = (),
            max_steps: Optional[int] = None) -> Dict[int, RequestOutput]:
        """Drain: add ``requests``, tick until every request finished (or
        ``max_steps``), return rid -> RequestOutput."""
        for r in requests:
            self.add(r)
        steps = 0
        while self.sched.has_work():
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return self.outputs


# ---------------------------------------------------------------------------
# traces
# ---------------------------------------------------------------------------

def synthetic_trace(cfg, *, num_requests: int, seed: int = 0,
                    prompt_lens: Sequence[int] = (8, 16, 24),
                    gen_lens: Sequence[int] = (4, 8, 16),
                    arrival_every: int = 0,
                    arrival_process: str = "fixed",
                    rate: float = 0.5, burst_rate: float = 4.0,
                    burst_switch: float = 0.15,
                    length_dist: str = "choice",
                    heavy_alpha: float = 1.6,
                    max_gen: Optional[int] = None,
                    priorities: Sequence[int] = (0,),
                    deadline_slack: Optional[float] = None,
                    ttft_deadline: Optional[int] = None) -> List[Request]:
    """Deterministic mixed prompt/gen-length request trace — the
    benchmarks/bench_serve.py workload.

    Arrival processes (all seeded, so routing decisions replay exactly):
    ``"fixed"`` staggers arrivals (request i at step i * arrival_every —
    the pre-router behaviour, and the default); ``"bursty"`` is a
    two-state Markov-modulated process — each request flips the
    calm/burst state with probability ``burst_switch``, then advances
    the arrival clock by an exponential gap at the state's rate
    (``rate`` / ``burst_rate`` requests per step) — the heavy-tailed
    overload workload of DESIGN.md Section 13.

    ``length_dist="heavy"`` replaces the uniform gen-length choice with
    a Pareto draw (shape ``heavy_alpha``) floored at ``min(gen_lens)``
    and capped at ``max_gen`` (default ``8 * max(gen_lens)``) — most
    requests stay short, stragglers dominate the tail.

    SLO fields: ``priorities`` draws each request's priority class,
    ``deadline_slack`` attaches a completion deadline proportional to
    the request's own expected service (slack x (gen + prefill share)),
    and ``ttft_deadline`` a flat first-token deadline.  The defaults
    attach nothing, keeping the trace FCFS-compatible.
    """
    if arrival_process not in ("fixed", "bursty"):
        raise ValueError(f"unknown arrival process {arrival_process!r}")
    if length_dist not in ("choice", "heavy"):
        raise ValueError(f"unknown length distribution {length_dist!r}")
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    t, burst = 0, False
    for i in range(num_requests):
        plen = int(rng.choice(np.asarray(prompt_lens)))
        if length_dist == "heavy":
            gmin = int(min(gen_lens))
            cap = int(max_gen) if max_gen else 8 * int(max(gen_lens))
            glen = min(cap, max(1, int(gmin * (1.0
                                               + rng.pareto(heavy_alpha)))))
        else:
            glen = int(rng.choice(np.asarray(gen_lens)))
        toks = rng.integers(1, cfg.vocab_size, (plen,), dtype=np.int32)
        extras = None
        if cfg.is_encdec:
            extras = {"frames": rng.standard_normal(
                (cfg.enc_frames, cfg.d_model)).astype(np.float32)}
        if arrival_process == "bursty":
            if rng.random() < burst_switch:
                burst = not burst
            r = burst_rate if burst else rate
            t += int(round(rng.exponential(1.0 / max(r, 1e-6))))
            arrival = t
        else:
            arrival = i * arrival_every
        priority = (int(rng.choice(np.asarray(priorities)))
                    if len(priorities) > 1 else int(priorities[0]))
        deadline = None
        if deadline_slack is not None:
            deadline = int(np.ceil(deadline_slack
                                   * (glen + max(1, plen // 8))))
        reqs.append(Request(rid=i, tokens=toks, max_new_tokens=glen,
                            arrival=arrival, extras=extras,
                            priority=priority, deadline_ms=deadline,
                            ttft_deadline_ms=ttft_deadline))
    return reqs
