"""Continuous-batching serving engine: slot-pool KV arena + FCFS scheduler
+ per-step workload-category measurement (DESIGN.md Section 8).

A fixed ``num_slots x cache_len`` cache arena is shared by all in-flight
requests.  Each engine tick admits waiting requests into freed slots
(prefilling them one at a time, interleaved with decode of the running
slots) and then advances *every* running slot by one token with a single
pooled, donated decode step — the decode GEMV work stays batched no matter
how ragged the request lengths are.  Admission writes a freshly prefilled
single-request cache into its slot in place (``dynamic_update_slice`` along
the per-leaf batch axis, positions carried as a per-slot (B,) vector the
model decode paths understand); eviction is just marking the slot free —
the stale rows are dead weight until the next admission overwrites them.

The engine is the serving face of the paper's hybrid execution: it keeps a
running *measured* activation sparsity (exact-zero fraction of the pooled
decode logits, refreshed every ``measure_every`` steps), re-invokes
``core.hybrid.select_mode`` against the offline weight sparsity, and runs
every prefill/decode under a ``sparse_execution`` scope for the selected
category.  Mode is a trace-time decision (DESIGN.md Section 5), so a
category flip swaps to a fresh set of jitted fns traced under the new
scope — the jit cache is keyed by ``Mode``, at most four entries.

``greedy_generate`` (runtime/serve.py) is the parity oracle: per-slot
decode is row-wise independent (MoE decode runs drop-free for exactly this
reason, see ``models.moe.moe_ffn``), so the engine's generated tokens for a
request match a batch-1 greedy run of the same prompt token for token.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.hybrid import SPARSE_THRESHOLD, select_mode
from ..core.spec import Mode
from ..kernels.griffin_spmm.ops import GriffinWeights
from ..models.common import sparse_execution
from ..models.registry import ModelApi
from ..sparsity.pruning import GEMM_WEIGHTS, sparsity_of

# Category knob handed to the sparse_execution scope when the *measured*
# activation sparsity selects an A-side mode and no declared value exists:
# the scope only consumes the category bit (above/below SPARSE_THRESHOLD),
# so any representative sparse-side constant keeps the trace stable across
# measurement jitter (DESIGN.md Section 5).
DEFAULT_DECLARED_A = 0.5


# ---------------------------------------------------------------------------
# requests
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    """One generation request.  ``arrival`` is the earliest engine step at
    which the scheduler may admit it; ``extras`` carries non-token model
    inputs (whisper frames)."""

    rid: int
    tokens: np.ndarray
    max_new_tokens: int
    arrival: int = 0
    extras: Optional[Dict[str, np.ndarray]] = None

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.tokens).shape[-1])

    def as_batch(self) -> Dict[str, jax.Array]:
        """The batch-1 model input this request prefills with — also what
        oracle replays (greedy_generate) must feed so they compare against
        the same computation."""
        batch = {"tokens": jnp.asarray(
            np.asarray(self.tokens, np.int32).reshape(1, -1))}
        for k, v in (self.extras or {}).items():
            batch[k] = jnp.asarray(v)[None]
        return batch


@dataclasses.dataclass
class RequestOutput:
    rid: int
    tokens: List[int] = dataclasses.field(default_factory=list)
    admitted: int = -1
    finished: int = -1


# ---------------------------------------------------------------------------
# scheduler (pure bookkeeping — no jax; the hypothesis sweeps in
# tests/test_properties.py drive it directly against random traces)
# ---------------------------------------------------------------------------

class Scheduler:
    """FCFS slot scheduler.

    ``policy="continuous"``: waiting requests are admitted into freed slots
    every step, at most ``max_admissions_per_step`` per tick, so prefill
    work interleaves with decode of the running slots.
    ``policy="static"``: admission only when the pool has fully drained —
    the classic static-batching baseline whose stragglers idle the pool
    (benchmarks/bench_serve.py measures the gap).
    """

    def __init__(self, num_slots: int, policy: str = "continuous",
                 max_admissions_per_step: int = 1):
        if num_slots < 1:
            raise ValueError("need at least one slot")
        if policy not in ("continuous", "static"):
            raise ValueError(f"unknown policy {policy!r}")
        self.num_slots = num_slots
        self.policy = policy
        self.max_admissions = max(1, max_admissions_per_step)
        self.waiting: List[Request] = []
        self.running: Dict[int, Request] = {}
        self.remaining: Dict[int, int] = {}
        self.finished: List[int] = []
        self._free = list(range(num_slots - 1, -1, -1))   # pop() -> slot 0

    def add(self, req: Request) -> None:
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.rid}: max_new_tokens must be >=1")
        self.waiting.append(req)

    def admissions(self, step: int) -> List[Tuple[int, Request]]:
        """Pop the (slot, request) pairs to admit at ``step`` — FCFS over
        the arrived portion of the queue, bounded by free slots and the
        per-step admission budget."""
        if self.policy == "static" and self.running:
            return []
        budget = (self.num_slots if self.policy == "static"
                  else self.max_admissions)
        out: List[Tuple[int, Request]] = []
        while self._free and len(out) < budget:
            i = next((j for j, r in enumerate(self.waiting)
                      if r.arrival <= step), None)
            if i is None:
                break
            req = self.waiting.pop(i)
            slot = self._free.pop()
            self.running[slot] = req
            self.remaining[slot] = req.max_new_tokens
            out.append((slot, req))
        return out

    def emit(self, slot: int) -> bool:
        """Record one emitted token on ``slot``; frees the slot and returns
        True when that was the request's last token."""
        self.remaining[slot] -= 1
        if self.remaining[slot] > 0:
            return False
        req = self.running.pop(slot)
        del self.remaining[slot]
        self._free.append(slot)
        self.finished.append(req.rid)
        return True

    @property
    def active(self) -> List[int]:
        return sorted(self.running)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)


# ---------------------------------------------------------------------------
# cache-arena plumbing
# ---------------------------------------------------------------------------

def _batch_axes(api: ModelApi, cache_len: int) -> Any:
    """Per-leaf batch-axis index of the cache tree (-1 for scalar position
    counters), discovered by diffing the shapes ``init_cache`` declares for
    batch sizes 2 and 1 — no per-family knowledge needed."""
    two = jax.eval_shape(lambda: api.init_cache(2, cache_len))
    one = jax.eval_shape(lambda: api.init_cache(1, cache_len))

    def axis(p, s):
        diffs = [i for i, (a, b) in enumerate(zip(p.shape, s.shape))
                 if a != b]
        if len(diffs) > 1:
            raise ValueError(f"ambiguous cache batch axis: {p.shape} vs "
                             f"{s.shape}")
        if not diffs:
            if p.shape != ():
                raise ValueError("cache leaf without a batch axis must be "
                                 f"a scalar counter, got shape {p.shape}")
            return -1
        return diffs[0]

    return jax.tree.map(axis, two, one)


def _make_insert(axes: Any) -> Callable:
    """Jitted in-place (donated) write of a single-request cache into one
    slot of the pool arena.  Scalar counters (axis -1) land in the
    promoted per-slot (B,) vector."""

    @functools.partial(jax.jit, donate_argnums=(0,))
    def insert(pool, sub, slot):
        def one(pl, sl, ax):
            if ax < 0:
                return jax.lax.dynamic_update_slice(
                    pl, sl.astype(pl.dtype).reshape(1), (slot,))
            starts = [0] * pl.ndim
            starts[ax] = slot
            return jax.lax.dynamic_update_slice(pl, sl.astype(pl.dtype),
                                                tuple(starts))
        return jax.tree.map(one, pool, sub, axes)

    return insert


def _default_serve_fns(api: ModelApi, cache_len: int):
    """Unsharded single-host jits; the mesh-aware factory is
    ``runtime.serve.jit_serve_fns`` (launch/serve.py passes it in).  The
    decode cache is donated so pool updates happen in place."""
    prefill = jax.jit(lambda p, b: api.prefill(p, b, cache_len=cache_len))
    decode = jax.jit(lambda p, c, t: api.decode_step(p, c, t),
                     donate_argnums=(1,))
    return prefill, decode


def weight_sparsity(params: Any,
                    names: Sequence[str] = GEMM_WEIGHTS) -> float:
    """Mean sparsity of the weight GEMM leaves ``griffin_linear`` executes
    (trailing-name selection as in ``sparsity.sparsify_params``):
    ``GriffinWeights`` leaves report ``1 - density`` (their zeros were
    physically dropped), plain leaves their exact zero fraction — the
    B-side input to ``select_mode``."""
    vals: List[float] = []

    def walk(t, name=""):
        if isinstance(t, GriffinWeights):
            vals.append(1.0 - t.density)
        elif isinstance(t, dict):
            for k, v in t.items():
                walk(v, k)
        elif isinstance(t, (list, tuple)):
            for v in t:
                walk(v, name)
        elif name in names and hasattr(t, "ndim") and t.ndim >= 2 and \
                jnp.issubdtype(t.dtype, jnp.floating):
            vals.append(float(sparsity_of(t)))

    walk(params)
    return float(np.mean(vals)) if vals else 0.0


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

class ServeEngine:
    """Continuous-batching driver over a ``ModelApi``.

    ``fns_factory`` returns (prefill_fn, decode_fn[, ...]) — pass
    ``lambda: jit_serve_fns(api, mesh, num_slots, cache_len)`` to serve on
    a mesh (launch/serve.py does); default is single-host jits.  The
    factory is invoked once per selected execution mode: the resulting jits
    are traced (and always called) under that mode's ``sparse_execution``
    scope, which is how a workload-category flip reaches the kernels.

    Greedy decoding only (argmax), matching the ``greedy_generate`` oracle.
    Prefill jits retrace per distinct prompt length — callers with ragged
    traces should bucket prompt lengths (future work: bucketed prefill).
    """

    def __init__(self, api: ModelApi, params: Any, *, num_slots: int,
                 cache_len: int, fns_factory: Optional[Callable] = None,
                 policy: str = "continuous", max_admissions_per_step: int = 1,
                 use_kernels: bool = False, interpret: bool = False,
                 a_sparsity: Optional[float] = None, block_m: int = 128,
                 measure_every: int = 8):
        self.api = api
        self.params = params
        self.num_slots = num_slots
        self.cache_len = cache_len
        self.sched = Scheduler(num_slots, policy, max_admissions_per_step)
        self._fns_factory = fns_factory or (
            lambda: _default_serve_fns(api, cache_len))
        self._mode_fns: Dict[Mode, Tuple[Callable, Callable]] = {}
        self.use_kernels = use_kernels
        self.interpret = interpret
        self.block_m = block_m
        self.a_declared = a_sparsity
        self.measure_every = max(1, measure_every)
        self.b_sparsity = weight_sparsity(params)
        self.a_measured = 0.0
        self.mode = select_mode(self._a_now(), self.b_sparsity)
        self.mode_history: List[Tuple[int, Mode]] = [(0, self.mode)]
        self.clock = 0
        self._since_measure = 0
        self.outputs: Dict[int, RequestOutput] = {}
        self.events: List[Tuple[int, int, int]] = []    # (step, rid, token)
        self.stats = {"decode_steps": 0, "prefill_calls": 0, "emitted": 0,
                      "idle_steps": 0, "retraces": 0}
        # the arena: init_cache's tree with scalar counters promoted to
        # per-slot (B,) vectors (the decode paths' vector-pos branch)
        cache = api.init_cache(num_slots, cache_len)
        self.cache = jax.tree.map(
            lambda leaf: jnp.zeros((num_slots,), leaf.dtype)
            if leaf.ndim == 0 else leaf, cache)
        self._insert = _make_insert(_batch_axes(api, cache_len))
        self._tokens = jnp.zeros((num_slots, 1), jnp.int32)

    # -- mode plumbing ------------------------------------------------------

    def _a_now(self) -> float:
        return (self.a_declared if self.a_declared is not None
                else self.a_measured)

    def _scope(self):
        a_scope = 0.0
        if self.mode in (Mode.A, Mode.AB):
            a_scope = (self.a_declared
                       if self.a_declared is not None
                       and self.a_declared > SPARSE_THRESHOLD
                       else DEFAULT_DECLARED_A)
        return sparse_execution(use_kernels=self.use_kernels,
                                interpret=self.interpret,
                                a_sparsity=a_scope, block_m=self.block_m)

    def _fns(self) -> Tuple[Callable, Callable]:
        fns = self._mode_fns.get(self.mode)
        if fns is None:
            made = self._fns_factory()
            fns = (made[0], made[1])
            self._mode_fns[self.mode] = fns
            self.stats["retraces"] += 1
        return fns

    def _measure(self, logits: jax.Array) -> None:
        """Workload-category measurement on the step's concrete logits
        (live slots only — stale rows of freed slots would skew the zero
        fraction); a flipped ``select_mode`` verdict swaps the jitted-fn
        set (mode is a trace-time decision, DESIGN.md Section 5)."""
        self._since_measure = 0
        self.a_measured = float(sparsity_of(logits))
        mode = select_mode(self._a_now(), self.b_sparsity)
        if mode != self.mode:
            self.mode = mode
            self.mode_history.append((self.clock, mode))

    # -- request lifecycle --------------------------------------------------

    def add(self, req: Request) -> None:
        if req.prompt_len + req.max_new_tokens > self.cache_len:
            raise ValueError(
                f"request {req.rid}: prompt {req.prompt_len} + gen "
                f"{req.max_new_tokens} exceeds cache_len {self.cache_len}")
        if self.api.cfg.is_encdec and (req.extras or {}).get("frames") is None:
            raise ValueError(f"request {req.rid}: enc-dec model needs "
                             "extras['frames']")
        self.sched.add(req)

    def _prefill(self, req: Request):
        prefill_fn, _ = self._fns()
        with self._scope():
            cache1, logits = prefill_fn(self.params, req.as_batch())
        self.stats["prefill_calls"] += 1
        return cache1, logits

    def _emit(self, slot: int, token: int) -> None:
        req = self.sched.running[slot]
        out = self.outputs[req.rid]
        out.tokens.append(token)
        self.events.append((self.clock, req.rid, token))
        self.stats["emitted"] += 1
        if self.sched.emit(slot):
            out.finished = self.clock

    def step(self) -> List[Tuple[int, int, int]]:
        """One engine tick: admissions (each prefilled and written into its
        slot, first token emitted from the prefill logits) followed by one
        pooled decode step advancing every running slot.  Returns the
        tick's (step, rid, token) events."""
        ev_start = len(self.events)
        for slot, req in self.sched.admissions(self.clock):
            cache1, logits = self._prefill(req)
            self.cache = self._insert(self.cache, cache1,
                                      jnp.asarray(slot, jnp.int32))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)     # (1,)
            self._tokens = jax.lax.dynamic_update_slice(
                self._tokens, tok[:, None], (slot, 0))
            self.outputs[req.rid] = RequestOutput(req.rid,
                                                  admitted=self.clock)
            self._emit(slot, int(tok[0]))
        active = self.sched.active
        if active:
            _, decode_fn = self._fns()
            with self._scope():
                logits, self.cache = decode_fn(self.params, self.cache,
                                               self._tokens)
            toks = jnp.argmax(logits, -1).astype(jnp.int32)    # (B,)
            self._tokens = toks[:, None]
            host = np.asarray(toks)
            self.stats["decode_steps"] += 1
            self._since_measure += 1
            if self._since_measure >= self.measure_every:
                self._measure(logits[jnp.asarray(active)])
            for slot in active:
                self._emit(slot, int(host[slot]))
        elif self.sched.waiting:
            self.stats["idle_steps"] += 1
        self.clock += 1
        return self.events[ev_start:]

    def run(self, requests: Sequence[Request] = (),
            max_steps: Optional[int] = None) -> Dict[int, RequestOutput]:
        """Drain: add ``requests``, tick until every request finished (or
        ``max_steps``), return rid -> RequestOutput."""
        for r in requests:
            self.add(r)
        steps = 0
        while self.sched.has_work():
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return self.outputs


# ---------------------------------------------------------------------------
# traces
# ---------------------------------------------------------------------------

def synthetic_trace(cfg, *, num_requests: int, seed: int = 0,
                    prompt_lens: Sequence[int] = (8, 16, 24),
                    gen_lens: Sequence[int] = (4, 8, 16),
                    arrival_every: int = 0) -> List[Request]:
    """Deterministic mixed prompt/gen-length request trace — the
    benchmarks/bench_serve.py workload.  ``arrival_every > 0`` staggers
    arrivals (request i arrives at step i * arrival_every)."""
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    for i in range(num_requests):
        plen = int(rng.choice(np.asarray(prompt_lens)))
        glen = int(rng.choice(np.asarray(gen_lens)))
        toks = rng.integers(1, cfg.vocab_size, (plen,), dtype=np.int32)
        extras = None
        if cfg.is_encdec:
            extras = {"frames": rng.standard_normal(
                (cfg.enc_frames, cfg.d_model)).astype(np.float32)}
        reqs.append(Request(rid=i, tokens=toks, max_new_tokens=glen,
                            arrival=i * arrival_every, extras=extras))
    return reqs
