"""Three-term roofline analysis from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (chips x peak FLOP/s)
    memory term     = HLO_bytes / (chips x HBM bandwidth)
    collective term = collective_bytes / (chips x link bandwidth)

``cost_analysis()`` on a jax Compiled is per-device (verified empirically)
and counts while-loop bodies ONCE, so the dry-run lowers *unrolled* depth-1
and depth-2 variants (plus two sequence lengths for architectures with
time-recurrent inner scans) and extrapolates:

    total = f(1 unit) + (units - 1) * [f(2 units) - f(1 unit)]

Collective bytes are not in cost_analysis: we parse the (per-device SPMD)
HLO text and sum the operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9_\[\]{},:#\s\.]+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.IGNORECASE)
_TYPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|"
                      r"f64|c64)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes of every collective op in (per-device) HLO text."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1).lower()
        if "-done(" in line:       # avoid double counting start/done pairs
            continue
        # operand types are the type tokens after the '(';
        # the result type is before the op name.
        args = line[m.end():]
        types = _TYPE_RE.findall(args)
        if not types:
            types = _TYPE_RE.findall(line)[:1]
        total = sum(_shape_bytes(t, d) for t, d in types)
        out[kind] = out.get(kind, 0.0) + float(total)
    return out


@dataclasses.dataclass
class CostSample:
    """Per-device costs of one lowered variant."""

    flops: float
    bytes_accessed: float
    coll: Dict[str, float]

    @property
    def coll_total(self) -> float:
        return float(sum(self.coll.values()))


def sample_costs(compiled) -> CostSample:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    txt = compiled.as_text()
    return CostSample(flops=float(ca.get("flops", 0.0)),
                      bytes_accessed=float(ca.get("bytes accessed", 0.0)),
                      coll=collective_bytes(txt))


def extrapolate(f1: CostSample, f2: CostSample, units: float) -> CostSample:
    """total = f1 + (units - 1) * (f2 - f1), per field."""
    keys = set(f1.coll) | set(f2.coll)
    coll = {k: f1.coll.get(k, 0.0) +
            (units - 1) * (f2.coll.get(k, 0.0) - f1.coll.get(k, 0.0))
            for k in keys}
    return CostSample(
        flops=f1.flops + (units - 1) * (f2.flops - f1.flops),
        bytes_accessed=f1.bytes_accessed +
        (units - 1) * (f2.bytes_accessed - f1.bytes_accessed),
        coll=coll)


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_dev: float
    bytes_dev: float
    coll_bytes_dev: float
    model_flops: float
    useful_ratio: float          # MODEL_FLOPS / (HLO_FLOPs x chips)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step's lower bound spent on *useful* model math:
        model_flops/(chips*peak) / max(term) — the score to push up."""
        ideal = self.model_flops / (PEAK_FLOPS * self._chips)
        return ideal / max(self.bound_s, 1e-30)

    _chips: int = 1


def roofline_terms(costs: CostSample, model_flops: float, chips: int
                   ) -> RooflineTerms:
    t = RooflineTerms(
        compute_s=costs.flops / PEAK_FLOPS,
        memory_s=costs.bytes_accessed / HBM_BW,
        collective_s=costs.coll_total / ICI_BW,
        flops_dev=costs.flops,
        bytes_dev=costs.bytes_accessed,
        coll_bytes_dev=costs.coll_total,
        model_flops=model_flops,
        useful_ratio=model_flops / max(costs.flops * chips, 1e-30),
    )
    t._chips = chips
    return t


def model_flops_for(kind: str, n_active_params: float, batch: int,
                    seq_len: int) -> float:
    """MODEL_FLOPS: 6ND for training, 2ND for prefill, 2N per decoded token
    (paper-of-record conventions; attention flops excluded by design so the
    useful_ratio exposes attention + remat + dispatch overheads)."""
    if kind == "train":
        return 6.0 * n_active_params * batch * seq_len
    if kind == "prefill":
        return 2.0 * n_active_params * batch * seq_len
    return 2.0 * n_active_params * batch          # decode: one token
