"""Render dry-run JSONL results into the EXPERIMENTS.md tables."""
from __future__ import annotations

import json
from collections import defaultdict
from typing import Dict, List, Optional


def load(path: str) -> List[Dict]:
    rows = []
    with open(path) as f:
        for line in f:
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                pass
    # last record per (arch, shape, pass) wins (restarts / hillclimb reruns)
    best: Dict = {}
    for r in rows:
        best[(r["arch"], r["shape"], r["pass"])] = r
    return list(best.values())


def _gb(x: float) -> str:
    return f"{x / 2**30:.2f}"


def dryrun_table(rows: List[Dict]) -> str:
    by = {(r["arch"], r["shape"]): {} for r in rows}
    for r in rows:
        by[(r["arch"], r["shape"])][r["pass"]] = r
    out = ["| arch | shape | 16x16 (256) | 2x16x16 (512) | args GB/dev | "
           "temp GB/dev | collectives |",
           "|---|---|---|---|---|---|---|"]
    for (arch, shape), ps in sorted(by.items()):
        cs, cm = ps.get("check_single", {}), ps.get("check_multi", {})
        if cs.get("status") == "skipped":
            out.append(f"| {arch} | {shape} | skipped | skipped | — | — | "
                       f"(full attention; long_500k n/a) |")
            continue
        s1 = cs.get("status", "—")
        s2 = cm.get("status", "—")
        arg = _gb(cs["arg_bytes_per_dev"]) if "arg_bytes_per_dev" in cs else "—"
        tmp = _gb(cs["temp_bytes_per_dev"]) if "temp_bytes_per_dev" in cs else "—"
        coll = ",".join(cs.get("collectives_present", [])) or "—"
        out.append(f"| {arch} | {shape} | {s1} | {s2} | {arg} | {tmp} | "
                   f"{coll} |")
    return "\n".join(out)


def next_lever(r: Dict) -> str:
    """One sentence: what would move the dominant term down (per cell)."""
    dom = r.get("dominant")
    shape = r["shape"]
    decode = shape in ("decode_32k", "long_500k")
    if dom == "collective":
        if decode:
            return ("align cache/query shardings further (residual gathers) "
                    "or replicate small params")
        return ("reduce-scatter gradients + int8 compression on the dp axis "
                "(optim/compression.py)")
    if dom == "memory":
        if decode:
            return ("int8 weights/cache halve streaming; larger serving "
                    "batch amortizes the weight read")
        if r.get("useful_ratio", 0) < 0.4:
            return ("fused (Pallas) attention keeps score traffic in VMEM; "
                    "cut remat recompute with a dots-saveable policy")
        return ("bf16 flash intermediates + fused attention kernel; weight "
                "streaming is already near-minimal")
    return "increase per-chip work (larger microbatch) or reduce remat"


def roofline_table(rows: List[Dict]) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL_FLOPS | useful ratio | roofline frac | what moves the "
           "dominant term |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["pass"] != "cost":
            continue
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped "
                       f"| — | — | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | error | | | | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
            f"**{r['dominant']}** | {r['model_flops']:.2e} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.4f} | "
            f"{next_lever(r)} |")
    return "\n".join(out)


def worst_cells(rows: List[Dict], k: int = 5) -> List[Dict]:
    ok = [r for r in rows if r["pass"] == "cost" and r["status"] == "ok"]
    return sorted(ok, key=lambda r: r["roofline_fraction"])[:k]


def most_collective_bound(rows: List[Dict], k: int = 5) -> List[Dict]:
    ok = [r for r in rows if r["pass"] == "cost" and r["status"] == "ok"]
    return sorted(ok, key=lambda r: -(r["collective_s"] /
                                      max(r["compute_s"] + r["memory_s"],
                                          1e-12)))[:k]


if __name__ == "__main__":
    import sys
    rows = load(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.jsonl")
    print("## Dry-run\n")
    print(dryrun_table(rows))
    print("\n## Roofline\n")
    print(roofline_table(rows))
