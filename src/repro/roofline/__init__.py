from .analysis import (CostSample, RooflineTerms, collective_bytes,
                       extrapolate, model_flops_for, roofline_terms,
                       sample_costs)
