from .checkpoint import (PreemptionGuard, latest_step, restore, save)
