from .checkpoint import (PreemptionGuard, latest_step, read_manifest,
                         restore, save)
