"""Fault-tolerant checkpointing.

- atomic: write to ``<dir>/tmp.<step>`` then ``os.replace`` to ``step_N``
  (a crash mid-save never corrupts the latest checkpoint);
- self-describing: a manifest records the tree structure, shapes, dtypes and
  the mesh the state was sharded on;
- **resharding restore**: ``restore`` device_puts onto any target sharding —
  a checkpoint written on a 512-chip mesh restarts on 256 chips (elastic
  recovery after node failure, see runtime/elastic.py);
- retention: keeps the newest ``keep`` checkpoints;
- preemption: ``install_sigterm_handler`` flips a flag the train loop polls
  to save-and-exit cleanly;
- serving snapshots: the fault-tolerant engines write their live state
  (slot-pool arena, per-slot counters, compacted weights) through ``save``
  with the scheduler queues in the manifest's ``extra`` (``read_manifest``
  gets them back), and recover through ``restore`` onto the post-loss
  mesh's shardings (DESIGN.md Section 11).
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import threading
from typing import Any, Callable, Dict, Optional

import jax
import ml_dtypes
import numpy as np

# numpy's savez cannot serialize ml_dtypes (bfloat16 etc.); round-trip them
# through a same-width integer view, recording the true dtype in the manifest.
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
           "float8_e5m2": np.uint8}


def _flatten(state: Any) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    out = {}
    for p, v in flat:
        a = np.asarray(v)
        if str(a.dtype) in _EXOTIC:
            a = a.view(_EXOTIC[str(a.dtype)])
        out[jax.tree_util.keystr(p)] = a
    return out


def save(ckpt_dir: str, step: int, state: Any, keep: int = 3,
         extra: Optional[Dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrs = _flatten(state)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrs)
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    true_dtypes = {jax.tree_util.keystr(p): str(np.asarray(v).dtype)
                   for p, v in flat}
    manifest = {
        "step": step,
        "keys": sorted(arrs.keys()),
        "shapes": {k: list(v.shape) for k, v in arrs.items()},
        "dtypes": true_dtypes,
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def read_manifest(ckpt_dir: str, step: Optional[int] = None) -> Dict:
    """The manifest of checkpoint ``step`` (latest by default): tree keys,
    shapes, dtypes and the ``extra`` dict ``save`` recorded.  The serving
    engines keep their scheduler queues there
    (``runtime.engine.Scheduler.state_dict``), so a fresh process can
    rebuild the host side of a snapshot and resume the trace (DESIGN.md
    Section 11)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}", "manifest.json")
    with open(path) as f:
        return json.load(f)


def restore(ckpt_dir: str, template: Any, step: Optional[int] = None,
            shardings: Optional[Any] = None) -> Any:
    """Load into the structure of ``template``; placement follows
    ``shardings`` (any mesh — resharding happens in device_put)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    out = []
    for (p, leaf), sh in zip(flat, shard_flat):
        key = jax.tree_util.keystr(p)
        arr = data[key]
        if str(leaf.dtype) in _EXOTIC and arr.dtype == _EXOTIC[str(leaf.dtype)]:
            arr = arr.view(getattr(ml_dtypes, str(leaf.dtype)))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class PreemptionGuard:
    """SIGTERM-aware flag for checkpoint-on-preemption."""

    def __init__(self) -> None:
        self.requested = threading.Event()

    def install(self) -> None:
        signal.signal(signal.SIGTERM, self._handler)

    def _handler(self, signum, frame) -> None:
        self.requested.set()

    @property
    def should_stop(self) -> bool:
        return self.requested.is_set()
