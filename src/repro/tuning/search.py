"""Candidate enumeration + analytical scoring for the autotuner
(DESIGN.md Section 12).

A :class:`Candidate` is one point in the per-family execution design
space: compaction block granularity (``block_k`` x ``block_n``), balance
``unit``, accelerator MUX ``fanin`` budget, and the Mode-selection
``a_threshold``.  :func:`predict_scores` prices every candidate with the
two analytical halves of the repo:

  - the cycle-model DSE (``core.dse.sweep`` over the Sparse.B enumeration
    at the candidate's fan-in budget, through the content-hashed
    ``ResultsCache`` — re-scoring a budget the cache has seen is free);
  - a roofline prediction (``roofline.analysis``) of the decode-step GEMM
    cost from the *actual* pruned weights compacted at the candidate's
    granularity (``compaction_stats``), plus a per-grid-step dispatch
    overhead term — on the CPU interpret lowering that term dominates,
    which is exactly why the predicted ranking differs per platform.

The predicted score only ranks a shortlist (:func:`shortlist`); the
winner is always picked from *measured* tok/s (``tuning.measure``,
:func:`select_best`) — predictions steer, measurements decide.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from ..configs.platform import kernel_interpret
from ..core.dse import ResultsCache, enumerate_sparse_b, sweep
from ..core.spec import Mode
from ..roofline.analysis import CostSample, roofline_terms
from ..sparsity.pruning import _BLOCKDIAG_PARENTS, GEMM_WEIGHTS
from .plan import FamilyPlan, GemmRule

# Per-grid-step dispatch overhead (seconds) added to the roofline bound.
# The interpret lowering executes each grid step in the Python/XLA
# emulation loop, so its per-step cost is ~zeros of magnitude above a real
# TPU grid step — coarse compaction (fewer, bigger blocks) wins there,
# while fine granularity wins where the roofline terms dominate.
STEP_OVERHEAD_INTERPRET = 2e-4
STEP_OVERHEAD_HW = 1e-7

DEFAULT_THRESHOLDS = (0.05, 0.9)
DEFAULT_FANINS = (8, 4)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the per-family execution design space."""

    block_k: int
    block_n: int
    unit: int
    fanin: int
    a_threshold: float

    @property
    def name(self) -> str:
        thr = str(self.a_threshold).replace(".", "p")
        return (f"bk{self.block_k}_bn{self.block_n}_u{self.unit}"
                f"_f{self.fanin}_t{thr}")

    def family_plan(self, family: str, *, b_threshold: Optional[float] = None,
                    predicted: Optional[Dict[str, Any]] = None,
                    measured: Optional[Dict[str, Any]] = None) -> FamilyPlan:
        """The plan entry executing this candidate: one ``"*"`` rule
        steering every GEMM's compaction + the family thresholds."""
        rule = GemmRule(match="*", block_k=self.block_k,
                        block_n=self.block_n, unit=self.unit,
                        a_threshold=self.a_threshold)
        return FamilyPlan(family=family, rules=(rule,),
                          a_threshold=self.a_threshold,
                          b_threshold=b_threshold,
                          predicted=predicted or {}, measured=measured or {})


def gemm_leaves(params: Any, names: Sequence[str] = GEMM_WEIGHTS,
                min_dim: int = 32) -> Dict[str, np.ndarray]:
    """Representative 2-D weight per GEMM name: the same trailing-name /
    min-dim / block-diagonal selection ``sparsity.sparsify_params``
    applies, with stacked leaves (layers, experts) represented by their
    first slice (layers of a stack share shape and — post-pruning — the
    same target sparsity, so one slice prices them all)."""
    out: Dict[str, np.ndarray] = {}

    def walk(tree, name="", path=()):
        if isinstance(tree, dict):
            for k, v in tree.items():
                walk(v, k, path + (k,))
            return
        if isinstance(tree, (list, tuple)):
            for v in tree:
                walk(v, name, path)
            return
        blockdiag = name in ("wq", "wk", "wv") and \
            any(p in _BLOCKDIAG_PARENTS for p in path)
        if name in names and not blockdiag and hasattr(tree, "ndim") \
                and tree.ndim >= 2 \
                and tree.shape[-2] >= min_dim and tree.shape[-1] >= min_dim:
            w = np.asarray(tree)
            w2 = w.reshape((-1,) + w.shape[-2:])
            if w2.shape[0] and name not in out:
                out[name] = w2[0]

    walk(params)
    return out


def enumerate_candidates(shapes: Mapping[str, Tuple[int, int]],
                         budget: int = 16, *,
                         thresholds: Sequence[float] = DEFAULT_THRESHOLDS,
                         fanins: Sequence[int] = DEFAULT_FANINS
                         ) -> List[Candidate]:
    """Deterministic candidate grid fitted to the family's actual GEMM
    dims, truncated to ``budget`` points.

    Block sizes are powers of two up to the smallest GEMM dim plus the
    "coarse" full-dim point (one K block — the degenerate compaction the
    frozen large-model defaults produce on reduced dims).  Loop nesting
    orders the axes by how much they change the *measured* outcome —
    sizes innermost (fastest covered), then thresholds, then balance
    unit, then fan-in (which only scales the DSE half of the score) — so
    a small budget spans granularity and thresholds before doubling up
    on fan-ins.
    """
    min_k = min(s[0] for s in shapes.values())
    min_n = min(s[1] for s in shapes.values())
    dim = min(min_k, min_n)
    sizes = [s for s in (16, 32, 64, 128) if s <= dim]
    if dim not in sizes:
        sizes.append(dim)
    out: List[Candidate] = []
    seen = set()
    for fanin in fanins:
        for unit_kind in ("prune", "tile"):
            for thr in thresholds:
                for s in sizes:
                    unit = 8 if unit_kind == "prune" else s
                    c = Candidate(block_k=s, block_n=s, unit=min(unit, s),
                                  fanin=fanin, a_threshold=thr)
                    if c.name in seen:
                        continue
                    seen.add(c.name)
                    out.append(c)
                    if len(out) >= budget:
                        return out
    return out


def compaction_stats(w: np.ndarray, block_k: int, block_n: int
                     ) -> Dict[str, float]:
    """Compaction of one pruned matrix at (block_k x block_n) granularity
    — the quantities the kernel's cost depends on, computed without
    building the compacted arrays.  Mirrors ``preprocess_weights`` minus
    the balance shuffle (balancing can only tighten ``max_cnt``, so this
    is a safe upper bound for prediction)."""
    k, n = w.shape
    bk, bn = min(block_k, k), min(block_n, n)
    pk, pn = -(-k // bk) * bk, -(-n // bn) * bn
    wp = np.zeros((pk, pn), dtype=w.dtype)
    wp[:k, :n] = w
    nb_k, nb_n = pk // bk, pn // bn
    blk_nz = (wp.reshape(nb_k, bk, nb_n, bn) != 0).any(axis=(1, 3))
    cnt = blk_nz.sum(axis=0)
    max_cnt = max(int(cnt.max(initial=0)), 1)
    return {"nb_k": nb_k, "n_tiles": nb_n, "max_cnt": max_cnt,
            "pn": pn, "bk": bk, "bn": bn,
            "density": float(blk_nz.mean())}


def _predicted_step(weights: Mapping[str, np.ndarray], cand: Candidate,
                    batch: int, step_overhead: float) -> Dict[str, float]:
    """Roofline-bounded decode-step time (seconds) of the family's GEMMs
    compacted at the candidate granularity, plus the grid dispatch term."""
    flops = bytes_acc = 0.0
    grid = 0
    model_flops = 0.0
    for w in weights.values():
        st = compaction_stats(w, cand.block_k, cand.block_n)
        depth = st["max_cnt"] * st["bk"]
        flops += 2.0 * batch * depth * st["pn"]
        bytes_acc += 4.0 * (depth * st["pn"] + batch * w.shape[0] +
                            batch * st["pn"] +
                            st["n_tiles"] * (st["max_cnt"] + 1))
        grid += st["n_tiles"] * st["max_cnt"]
        model_flops += 2.0 * batch * float(np.count_nonzero(w))
    terms = roofline_terms(CostSample(flops=flops, bytes_accessed=bytes_acc,
                                      coll={}), model_flops, chips=1)
    return {"bound_s": terms.bound_s, "grid_steps": grid,
            "predicted_s": terms.bound_s + grid * step_overhead}


def predict_scores(candidates: Sequence[Candidate],
                   weights: Mapping[str, np.ndarray], *, batch: int = 4,
                   cache: Optional[ResultsCache] = None, seed: int = 0,
                   step_overhead: Optional[float] = None
                   ) -> List[Dict[str, Any]]:
    """Score candidates: cycle-model speedup at the fan-in budget (cached
    DSE sweep) divided by the roofline-predicted step time.  Returns one
    row per candidate, input order preserved."""
    if step_overhead is None:
        step_overhead = (STEP_OVERHEAD_INTERPRET if kernel_interpret()
                         else STEP_OVERHEAD_HW)
    dse_best: Dict[int, float] = {}
    for fanin in sorted({c.fanin for c in candidates}):
        rows = sweep(enumerate_sparse_b(max_fanin=fanin), Mode.B,
                     seed=seed, cache=cache)
        dse_best[fanin] = max(r["speedup"] for r in rows)
    out = []
    for c in candidates:
        pred = _predicted_step(weights, c, batch, step_overhead)
        dse_sp = dse_best[c.fanin]
        out.append({"name": c.name, "candidate": c,
                    "dse_speedup": round(float(dse_sp), 4),
                    "grid_steps": int(pred["grid_steps"]),
                    "bound_s": pred["bound_s"],
                    "predicted_s": pred["predicted_s"],
                    "score": float(dse_sp) / pred["predicted_s"]})
    return out


def shortlist(scored: Sequence[Dict[str, Any]], k: int
              ) -> List[Dict[str, Any]]:
    """Top-k rows by predicted score; ties broken by name so the
    selection is a pure function of the score table."""
    return sorted(scored, key=lambda r: (-r["score"], r["name"]))[:k]


def select_best(measured: Mapping[str, float]) -> str:
    """Winner of the measured-tok/s validation round: highest tok/s, ties
    broken by name — deterministic given a frozen measurement table."""
    assert measured, "empty measurement table"
    return sorted(measured.items(), key=lambda kv: (-kv[1], kv[0]))[0][0]
