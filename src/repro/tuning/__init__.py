"""DSE-in-the-loop autotuning (DESIGN.md Section 12).

``plan`` defines the versioned kernel-plan artifact (safe to import from
anywhere — no runtime/benchmark dependencies); ``search`` enumerates and
scores candidate configs through the cycle-model DSE + roofline
predictions; ``measure`` validates shortlisted candidates against
measured tok/s on warm serving runs.  ``launch/autotune.py`` is the CLI
gluing the three into a pipeline.

Only the plan layer is re-exported here: ``measure`` imports the serving
runtime, and consumers of plans (``sparsity``, ``runtime.engine``) must
be importable without it.
"""
from .plan import (FamilyPlan, GemmRule, KernelPlan, PlanSchemaError,
                   PLAN_SCHEMA_VERSION, load_plan)

__all__ = ["FamilyPlan", "GemmRule", "KernelPlan", "PlanSchemaError",
           "PLAN_SCHEMA_VERSION", "load_plan"]
