"""Measured-tok/s validation of shortlisted candidates (DESIGN.md
Section 12): warm ``bench_serve``-style runs of the real serving engine.

The analytical scores (``tuning.search``) only *rank*; every plan that
ships was validated here — engine built with the candidate's compacted
weights + thresholds, jits traced on a throwaway pass, then best-of-N
timed replays of a deterministic trace.  The same run yields the token
streams, so candidate-vs-default token identity (the plan-parity
contract) is asserted in the loop, not trusted.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax

from ..configs import get_config
from ..models import build_model
from ..runtime.engine import ServeEngine, synthetic_trace

# Representative (reduced) arch per model family — the same mapping the
# engine test matrix uses.
FAMILY_ARCHS: Dict[str, str] = {
    "dense": "llama3.2-1b", "moe": "mixtral-8x7b", "audio":
    "whisper-large-v3", "ssm": "xlstm-1.3b", "hybrid": "recurrentgemma-9b",
    "vlm": "chameleon-34b",
}

# The frozen reduced-config pruning granularity (launch/serve.py, the
# engine test matrix).  Pruning ALWAYS stays at this granularity — plans
# steer compaction only, so the zero pattern (hence every token) is
# identical across candidates.
PRUNE = dict(block_k=16, block_n=16, unit=8)

TUNE_SLOTS = 4
TUNE_PROMPT_LENS = (6, 10)
TUNE_GEN_LENS = (4, 8, 16)


def tuning_workload(family: str, *, requests: int = 6, seed: int = 7
                    ) -> Tuple[Any, Any, Any, int, Callable]:
    """(cfg, api, params, cache_len, trace_fn) for one family's tuning
    workload: the reduced registry config on a deterministic mixed
    prompt/gen trace."""
    cfg = get_config(FAMILY_ARCHS[family]).reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    cache_len = max(TUNE_PROMPT_LENS) + max(TUNE_GEN_LENS) + 1
    trace = lambda: synthetic_trace(cfg, num_requests=requests, seed=seed,
                                    prompt_lens=TUNE_PROMPT_LENS,
                                    gen_lens=TUNE_GEN_LENS,
                                    arrival_every=1)
    return cfg, api, params, cache_len, trace


def measure_plan(api, params, cache_len: int, trace_fn: Callable, *,
                 plan=None, decode_chunk: int = 8, slots: int = TUNE_SLOTS,
                 repeats: int = 3, use_kernels: bool = True,
                 interpret: bool = True) -> Dict[str, Any]:
    """Warm measured run of one engine configuration.

    Builds the engine once (``plan`` steers its Mode thresholds; the
    weight compaction was already applied by the caller through
    ``sparsify_params(plan=...)``), traces every jit on a first
    throwaway pass, then times ``repeats`` fresh replays and keeps the
    best (least-contended) wall clock.  Returns tok/s, the deterministic
    tok/step twin, and the full per-request token streams for parity
    checks."""
    eng = ServeEngine(api, params, num_slots=slots, cache_len=cache_len,
                      use_kernels=use_kernels, interpret=interpret,
                      decode_chunk=decode_chunk, plan=plan)
    outs = eng.run(trace_fn())                      # trace/warm pass
    tokens = tuple(tuple(int(t) for t in outs[r].tokens)
                   for r in sorted(outs))
    best = float("inf")
    for _ in range(max(1, repeats)):
        eng.stats = {k: 0 for k in eng.stats}
        t0 = time.perf_counter()
        outs = eng.run(trace_fn())
        best = min(best, time.perf_counter() - t0)
        assert all(o.finished >= 0 for o in outs.values())
    toks = eng.stats["emitted"]
    steps = max(eng.stats["decode_steps"], 1)
    return {"tok_s": toks / best, "tok_per_step": toks / steps,
            "emitted": int(toks), "decode_steps": int(steps),
            "wall_s": best, "mode": eng.mode.value, "tokens": tokens}
