"""Versioned kernel plans: the artifact the autotuner emits and the
execution stack consumes (DESIGN.md Section 12).

A :class:`KernelPlan` maps model families to :class:`FamilyPlan` entries;
each family entry carries Mode-selection thresholds plus per-GEMM
:class:`GemmRule` compaction rules (block granularity / balance unit,
matched by trailing param name, ``"*"`` as the default rule).  Consumers:

  - ``sparsity.sparsify_params(plan=...)`` applies the rules at weight
    *compaction* time.  Rules never touch the *pruning* granularity: a
    pruned block is exactly zero and compaction at any granularity
    preserves every surviving value, so a plan changes how GEMMs execute,
    never what they compute — tuned engines stay token-identical to
    default engines on greedy decode (the plan-parity test tier asserts
    this).
  - ``runtime.engine.ServeEngine(plan=...)`` applies the family
    thresholds to its global ``select_mode`` decision and serving scope;
    per-GEMM ``a_threshold`` rules are stamped onto the compacted
    ``GriffinWeights`` (``a_thr`` meta field) and picked up by
    ``models.common.griffin_linear`` — including under ``shard_map`` on
    meshes, since the threshold is a trace-time constant like every other
    ``SparseExecution`` knob.

The JSON schema is versioned by ``PLAN_SCHEMA_VERSION`` — the same
constant (``core.dse.CONFIG_SCHEMA_VERSION``) the DSE sweep cache keys
include, so a schema bump simultaneously rejects stale plan files *and*
cold-starts cached sweep rows written under the old schema.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Tuple

from ..core.dse import CONFIG_SCHEMA_VERSION

PLAN_SCHEMA_VERSION = CONFIG_SCHEMA_VERSION


class PlanSchemaError(ValueError):
    """A plan file's ``schema_version`` is not the one this code writes."""


@dataclasses.dataclass(frozen=True)
class GemmRule:
    """Per-GEMM execution rule, matched by trailing param name.

    ``match`` is a name from ``sparsity.pruning.GEMM_WEIGHTS`` or ``"*"``
    (matches every GEMM leaf; list it last — first match wins).  ``None``
    fields keep the caller's default; set fields are clamped to the leaf's
    actual dims at application time.
    """

    match: str
    block_k: Optional[int] = None
    block_n: Optional[int] = None
    unit: Optional[int] = None
    a_threshold: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class FamilyPlan:
    """Tuned execution config for one model family.

    ``a_threshold``/``b_threshold`` override ``core.hybrid
    .SPARSE_THRESHOLD`` in the engine's global Mode decision;
    ``rules`` steer per-GEMM compaction granularity and per-GEMM A
    thresholds.  ``predicted``/``measured`` are the autotuner's score
    records (kept for auditability; never consulted at execution time).
    """

    family: str
    rules: Tuple[GemmRule, ...] = ()
    a_threshold: Optional[float] = None
    b_threshold: Optional[float] = None
    predicted: Dict[str, Any] = dataclasses.field(default_factory=dict)
    measured: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def rule_for(self, name: str) -> Optional[GemmRule]:
        for r in self.rules:
            if r.match == name or r.match == "*":
                return r
        return None


@dataclasses.dataclass
class KernelPlan:
    """A family -> FamilyPlan mapping plus provenance metadata."""

    families: Dict[str, FamilyPlan]
    schema_version: int = PLAN_SCHEMA_VERSION
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def family(self, name: str) -> Optional[FamilyPlan]:
        return self.families.get(name)

    # -- JSON ---------------------------------------------------------------

    def to_json(self) -> Dict[str, Any]:
        return {
            "schema_version": self.schema_version,
            "meta": dict(self.meta),
            "families": {
                f: {
                    "family": fp.family,
                    "a_threshold": fp.a_threshold,
                    "b_threshold": fp.b_threshold,
                    "rules": [dataclasses.asdict(r) for r in fp.rules],
                    "predicted": fp.predicted,
                    "measured": fp.measured,
                } for f, fp in self.families.items()
            },
        }

    @classmethod
    def from_json(cls, data: Dict[str, Any]) -> "KernelPlan":
        got = data.get("schema_version")
        if got != PLAN_SCHEMA_VERSION:
            raise PlanSchemaError(
                f"kernel plan schema_version {got!r} != supported "
                f"{PLAN_SCHEMA_VERSION} — re-run `python -m "
                "repro.launch.autotune` to regenerate the plan")
        fams = {}
        for f, fd in data.get("families", {}).items():
            fams[f] = FamilyPlan(
                family=fd["family"],
                rules=tuple(GemmRule(**r) for r in fd.get("rules", [])),
                a_threshold=fd.get("a_threshold"),
                b_threshold=fd.get("b_threshold"),
                predicted=fd.get("predicted", {}),
                measured=fd.get("measured", {}))
        return cls(families=fams, schema_version=got,
                   meta=data.get("meta", {}))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")


def load_plan(path: str) -> KernelPlan:
    """Load + schema-check a plan file (raises :class:`PlanSchemaError`
    on any version this code does not write)."""
    with open(path) as f:
        return KernelPlan.from_json(json.load(f))
