from . import adamw, compression
