"""AdamW with global-norm clipping and warmup-cosine schedule.

Optimizer states mirror the parameter tree (and its sharding — ZeRO: with
FSDP parameter specs the first/second moments are fully sharded too).
Master moments are float32 regardless of parameter dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def init(params: Any) -> OptState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(mu=jax.tree.map(f32, params),
                    nu=jax.tree.map(f32, params),
                    count=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * \
        0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum((g.astype(jnp.float32) ** 2).sum()
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def apply(cfg: AdamWConfig, params: Any, grads: Any, state: OptState
          ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state.count + 1
    lr = schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        step = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) \
            if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (step + decay)
        return new_p.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(new_mu, new_nu, count), metrics
