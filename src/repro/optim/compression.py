"""Int8 error-feedback gradient compression for the data-parallel reduction.

At 1000+ node scale the DP all-reduce dominates cross-pod traffic; 8-bit
quantization with error feedback (residual carried to the next step) cuts it
4x vs float32 / 2x vs bf16 with no asymptotic convergence penalty
(Karimireddy et al., 2019).  Implemented as an explicit ``shard_map`` psum
over the dp axes so it composes with any in-pod sharding.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def quantize_rows(x: jax.Array, ndim_keep: int) -> Tuple[jax.Array, jax.Array]:
    """Row-wise int8 quantization: one scale per leading-``ndim_keep`` index.

    Same round/clip/scale as :func:`quantize`, vectorized so the paged KV
    arena (runtime/paging.py, DESIGN.md Section 14) gets one scale per
    written token row: ``x`` with shape ``(*lead, *rest)`` where ``lead`` is
    the first ``ndim_keep`` axes returns ``q`` of x.shape (int8) and
    ``scale`` of shape ``lead`` (float32).
    """
    red = tuple(range(ndim_keep, x.ndim))
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32), axis=red), 1e-12) / 127.0
    s = scale[(...,) + (None,) * len(red)]
    q = jnp.clip(jnp.round(x32 / s), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_rows(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`quantize_rows` (float32 output)."""
    s = scale[(...,) + (None,) * (q.ndim - scale.ndim)]
    return q.astype(jnp.float32) * s


def compressed_psum_tree(grads: Any, error: Any, mesh: Mesh,
                         axes: Tuple[str, ...]) -> Tuple[Any, Any]:
    """All-reduce mean of ``grads`` over ``axes`` with int8 error feedback.

    Returns (reduced grads, new error residuals).  Call inside shard_map
    (grads already shard-local) or outside with replicated grads.
    """
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        # global scale (one pmax of a scalar) so the int8 payloads are
        # directly summable; the wire format is int8 with switch-level
        # widening on real fabrics — modeled here as an int32 psum of the
        # quantized values, which is numerically identical.
        scale = jax.lax.pmax(jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12),
                             axes) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int32), axes)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axes)
        red = total.astype(jnp.float32) * scale / n
        new_e = g32 - q.astype(jnp.float32) * scale
        return red.astype(g.dtype), new_e

    flat_g, td = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    red = jax.tree_util.tree_unflatten(td, [o[0] for o in out])
    err = jax.tree_util.tree_unflatten(td, [o[1] for o in out])
    return red, err


def init_error(grads_shape: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_shape)
