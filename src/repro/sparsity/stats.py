"""Sparsity measurement: the runtime inputs to Griffin's mode selection."""
from __future__ import annotations

from typing import Dict, Mapping

import jax
import jax.numpy as jnp

from ..core.spec import Mode
from ..core.hybrid import select_mode
from .pruning import sparsity_of


def tensor_report(tree) -> Dict[str, float]:
    """Per-leaf zero fraction of a parameter tree."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): float(sparsity_of(leaf))
            for path, leaf in flat if hasattr(leaf, "dtype")}


def model_mode(params, activations_sparsity: float = 0.0,
               threshold: float = 0.05) -> Mode:
    """Classify a model into the paper's four categories (Table I)."""
    vals = [v for v in tensor_report(params).values()]
    b_sparsity = sum(vals) / max(len(vals), 1)
    return select_mode(activations_sparsity, b_sparsity, threshold)


def activation_sparsity(fn, *args) -> float:
    """Measure post-nonlinearity zero fraction of a forward fn's output."""
    out = fn(*args)
    return float(sparsity_of(out))
