"""Pruning + sparsity statistics substrate."""
from .pruning import (PruneSchedule, block_prune, magnitude_prune,
                      sparsity_of)
from .stats import activation_sparsity, model_mode, tensor_report

__all__ = ["PruneSchedule", "block_prune", "magnitude_prune", "sparsity_of",
           "activation_sparsity", "model_mode", "tensor_report"]
