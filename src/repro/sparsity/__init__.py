"""Pruning + sparsity statistics substrate."""
from .pruning import (GEMM_WEIGHTS, PruneSchedule, block_prune,
                      magnitude_prune, sparsify_params, sparsity_of)
from .stats import activation_sparsity, model_mode, tensor_report

__all__ = ["GEMM_WEIGHTS", "PruneSchedule", "block_prune", "magnitude_prune",
           "sparsify_params", "sparsity_of", "activation_sparsity",
           "model_mode", "tensor_report"]
