"""Weight pruning for the Griffin execution paths.

Two granularities:
  - ``magnitude_prune``: unstructured (element) pruning — what the paper's
    cycle model evaluates (the element-granular accelerator skips these).
  - ``block_prune``: (block_k x unit) block pruning by L2 norm — the
    hardware-aware granularity the TPU kernel (griffin_spmm) can exploit:
    a pruned block is exactly zero, so preprocessing drops it.

Both are pure functions usable inside jit; ``PruneSchedule`` ramps sparsity
during training (cubic schedule, Zhu & Gupta 2017 [73] — the paper's own
pruning reference).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def magnitude_prune(w: jax.Array, sparsity: float) -> jax.Array:
    """Zero the smallest-|w| fraction ``sparsity`` of entries."""
    if sparsity <= 0.0:
        return w
    k = max(1, int(round(w.size * (1.0 - sparsity))))
    thresh = jnp.sort(jnp.abs(w).reshape(-1))[-k]
    return jnp.where(jnp.abs(w) >= thresh, w, 0).astype(w.dtype)


def block_prune(w: jax.Array, sparsity: float, block_k: int = 128,
                unit: int = 32) -> jax.Array:
    """Zero the lowest-L2 fraction ``sparsity`` of (block_k x unit) blocks.

    Shapes not divisible by the block are handled by zero padding (the pad
    never changes block norms).
    """
    if sparsity <= 0.0:
        return w
    k, n = w.shape
    pk, pn = -(-k // block_k) * block_k, -(-n // unit) * unit
    wp = jnp.zeros((pk, pn), w.dtype).at[:k, :n].set(w)
    nb_k, nb_n = pk // block_k, pn // unit
    blocks = wp.reshape(nb_k, block_k, nb_n, unit)
    norms = jnp.sqrt((blocks.astype(jnp.float32) ** 2).sum(axis=(1, 3)))
    nkeep = max(1, int(round(norms.size * (1.0 - sparsity))))
    thresh = jnp.sort(norms.reshape(-1))[-nkeep]
    keep = (norms >= thresh)[:, None, :, None]
    return (blocks * keep).reshape(pk, pn)[:k, :n].astype(w.dtype)


def sparsity_of(x: jax.Array) -> jax.Array:
    """Fraction of exact zeros (the quantity Table IV reports)."""
    return jnp.mean((x == 0).astype(jnp.float32))


@dataclasses.dataclass(frozen=True)
class PruneSchedule:
    """Cubic sparsity ramp s(t) = s_f * (1 - (1 - t/T)^3) on [t0, t0+T]."""

    final_sparsity: float
    begin_step: int = 0
    ramp_steps: int = 1000
    block_k: int = 0          # 0 => unstructured magnitude pruning
    unit: int = 32

    def sparsity_at(self, step: jax.Array) -> jax.Array:
        t = jnp.clip((step - self.begin_step) / max(self.ramp_steps, 1), 0, 1)
        return self.final_sparsity * (1.0 - (1.0 - t) ** 3)

    def apply(self, w: jax.Array, step: int) -> jax.Array:
        """Host-side application at checkpoint boundaries (the ramp changes
        the threshold, so this is applied outside jit per ramp milestone).
        Stacked layer weights (L, ..., in, out) are pruned per layer."""
        s = float(self.sparsity_at(jnp.asarray(step)))
        fn = (lambda x: block_prune(x, s, min(self.block_k, x.shape[0]),
                                    min(self.unit, x.shape[1]))) \
            if self.block_k else (lambda x: magnitude_prune(x, s))
        if w.ndim == 2:
            return fn(w)
        lead = w.shape[:-2]
        flat = w.reshape((-1,) + w.shape[-2:])
        out = jax.vmap(fn)(flat)
        return out.reshape(lead + w.shape[-2:])
