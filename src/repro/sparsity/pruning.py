"""Weight pruning for the Griffin execution paths.

Two granularities:
  - ``magnitude_prune``: unstructured (element) pruning — what the paper's
    cycle model evaluates (the element-granular accelerator skips these).
  - ``block_prune``: (block_k x unit) block pruning by L2 norm — the
    hardware-aware granularity the TPU kernel (griffin_spmm) can exploit:
    a pruned block is exactly zero, so preprocessing drops it.

Both are pure functions usable inside jit; ``PruneSchedule`` ramps sparsity
during training (cubic schedule, Zhu & Gupta 2017 [73] — the paper's own
pruning reference).

``sparsify_params`` is the model-stack entry point (DESIGN.md Section 4):
it block-prunes the weight GEMM leaves of a parameter pytree and replaces
them with block-compacted ``GriffinWeights`` the framework layer
(``models.common.griffin_linear``) executes through the Sparse.B kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def magnitude_prune(w: jax.Array, sparsity: float) -> jax.Array:
    """Zero the smallest-|w| fraction ``sparsity`` of entries."""
    if sparsity <= 0.0:
        return w
    k = max(1, int(round(w.size * (1.0 - sparsity))))
    thresh = jnp.sort(jnp.abs(w).reshape(-1))[-k]
    return jnp.where(jnp.abs(w) >= thresh, w, 0).astype(w.dtype)


def block_prune(w: jax.Array, sparsity: float, block_k: int = 128,
                unit: int = 32) -> jax.Array:
    """Zero the lowest-L2 fraction ``sparsity`` of (block_k x unit) blocks.

    Shapes not divisible by the block are handled by zero padding (the pad
    never changes block norms).
    """
    if sparsity <= 0.0:
        return w
    k, n = w.shape
    pk, pn = -(-k // block_k) * block_k, -(-n // unit) * unit
    wp = jnp.zeros((pk, pn), w.dtype).at[:k, :n].set(w)
    nb_k, nb_n = pk // block_k, pn // unit
    blocks = wp.reshape(nb_k, block_k, nb_n, unit)
    norms = jnp.sqrt((blocks.astype(jnp.float32) ** 2).sum(axis=(1, 3)))
    nkeep = max(1, int(round(norms.size * (1.0 - sparsity))))
    thresh = jnp.sort(norms.reshape(-1))[-nkeep]
    keep = (norms >= thresh)[:, None, :, None]
    return (blocks * keep).reshape(pk, pn)[:k, :n].astype(w.dtype)


def sparsity_of(x: jax.Array) -> jax.Array:
    """Fraction of exact zeros (the quantity Table IV reports)."""
    return jnp.mean((x == 0).astype(jnp.float32))


@dataclasses.dataclass(frozen=True)
class PruneSchedule:
    """Cubic sparsity ramp s(t) = s_f * (1 - (1 - t/T)^3) on [t0, t0+T]."""

    final_sparsity: float
    begin_step: int = 0
    ramp_steps: int = 1000
    block_k: int = 0          # 0 => unstructured magnitude pruning
    unit: int = 32

    def sparsity_at(self, step: jax.Array) -> jax.Array:
        t = jnp.clip((step - self.begin_step) / max(self.ramp_steps, 1), 0, 1)
        return self.final_sparsity * (1.0 - (1.0 - t) ** 3)

    def apply(self, w: jax.Array, step: int) -> jax.Array:
        """Host-side application at checkpoint boundaries (the ramp changes
        the threshold, so this is applied outside jit per ramp milestone).
        Stacked layer weights (L, ..., in, out) are pruned per layer."""
        s = float(self.sparsity_at(jnp.asarray(step)))
        fn = (lambda x: block_prune(x, s, min(self.block_k, x.shape[0]),
                                    min(self.unit, x.shape[1]))) \
            if self.block_k else (lambda x: magnitude_prune(x, s))
        if w.ndim == 2:
            return fn(w)
        lead = w.shape[:-2]
        flat = w.reshape((-1,) + w.shape[-2:])
        out = jax.vmap(fn)(flat)
        return out.reshape(lead + w.shape[-2:])


# ---------------------------------------------------------------------------
# model-stack sparsification
# ---------------------------------------------------------------------------

# Trailing param names of the weight GEMMs griffin_linear executes.  Per-head
# block-diagonal mats (xlstm rz/ri/...) and the recurrent-state path are NOT
# listed: they are not weight GEMMs (DESIGN.md Section 7, deviations).  The
# sLSTM gate projections wz/wi/wf/wo are (D, D) GEMMs and all four are
# listed; the same-named mLSTM gate vectors (din, H) fall under min_dim.
GEMM_WEIGHTS: Tuple[str, ...] = (
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "w_ff1", "w_ff2",
    "wz", "wi", "wf", "head")

# Subtrees whose wq/wk/wv are per-head *block-diagonal* (H, hd, hd) mats
# consumed by einsum, not weight GEMMs: mLSTM q/k/v (models.xlstm).
_BLOCKDIAG_PARENTS: Tuple[str, ...] = ("m_blocks",)


def sparsify_params(params: Any, sparsity: float, *, block_k: int = 128,
                    block_n: int = 128, unit: Optional[int] = None,
                    names: Sequence[str] = GEMM_WEIGHTS,
                    min_dim: int = 32, balance: bool = True,
                    compact: bool = True, plan: Any = None) -> Any:
    """Block-prune the weight GEMM leaves of a parameter pytree.

    With ``compact=True`` each pruned leaf is replaced by a block-compacted
    ``GriffinWeights`` (stacked leaves — layer stacks, MoE experts — get a
    stacked GriffinWeights whose members share a padded common grid depth);
    with ``compact=False`` the pruned weights stay plain zero-carrying
    arrays, which is the bit-exact dense reference for the compacted run
    (``bench_e2e`` compares the two).

    Selection is by trailing param name (``names``) and minimum GEMM dims
    (``min_dim`` — tiny projections like mLSTM gate vectors are skipped:
    metadata would outweigh the blocks).  Norm scales, embeddings and
    per-head block-diagonal mats are never touched.

    ``plan`` is a tuned family plan (``repro.tuning.FamilyPlan`` or
    anything with its ``rule_for(name)`` shape, DESIGN.md Section 12): a
    matching rule overrides the *compaction* granularity (block sizes /
    balance unit, clamped to the leaf dims) and stamps the rule's
    ``a_threshold`` onto the compacted leaf (``GriffinWeights.a_thr``).
    Pruning deliberately stays at the call's base ``block_k``/``unit``: a
    plan must never move a zero — compaction at any granularity preserves
    every surviving value, so planned and default engines stay
    token-identical (the plan-parity tier asserts this).
    """
    from ..kernels.griffin_spmm.ops import preprocess_weights, stack_weights

    def convert(w: jax.Array, name: str):
        bk = min(block_k, w.shape[-2])
        bn = min(block_n, w.shape[-1])
        un = min(unit or max(8, bn // 4), w.shape[-1])
        cbk, cbn, cun, thr = bk, bn, un, None
        rule = plan.rule_for(name) if plan is not None else None
        if rule is not None:
            cbk = min(rule.block_k or cbk, w.shape[-2])
            cbn = min(rule.block_n or cbn, w.shape[-1])
            cun = min(rule.unit or cun, cbn, w.shape[-1])
            thr = rule.a_threshold

        def one(m):
            return block_prune(m, sparsity, bk, un)

        def pre(m):
            gw = preprocess_weights(np.asarray(m), block_k=cbk, block_n=cbn,
                                    unit=cun, balance=balance)
            return (gw if thr is None
                    else dataclasses.replace(gw, a_thr=thr))

        if w.ndim == 2:
            wp = one(w)
            if not compact:
                return wp
            return pre(wp)
        lead = w.shape[:-2]
        flat = w.reshape((-1,) + w.shape[-2:])
        if flat.shape[0] == 0:
            # zero-length layer stack (stack_layers(n=0), e.g. the reduced
            # hybrid's empty tail): nothing to compact, and scan over the
            # length-0 xs is a no-op either way — keep the empty leaf
            return w
        slices = [one(flat[i]) for i in range(flat.shape[0])]
        if not compact:
            return jnp.stack(slices).reshape(w.shape)
        gw = stack_weights([pre(s) for s in slices])
        if len(lead) > 1:                     # e.g. (G, n_m) xlstm groups
            gw = jax.tree.map(
                lambda a: a.reshape(lead + a.shape[1:]), gw)
        return gw

    def walk(tree, name="", path=()):
        if isinstance(tree, dict):
            return {k: walk(v, k, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v, name, path) for v in tree)
        blockdiag = name in ("wq", "wk", "wv") and \
            any(p in _BLOCKDIAG_PARENTS for p in path)
        if name in names and not blockdiag and hasattr(tree, "ndim") \
                and tree.ndim >= 2 \
                and tree.shape[-2] >= min_dim and tree.shape[-1] >= min_dim:
            return convert(tree, name)
        return tree

    return walk(params)
