"""Model zoo: GQA transformers (dense/VLM/MoE), xLSTM, RG-LRU hybrid,
Whisper enc-dec — all scan-based, pure-functional pytree params."""
from .registry import ModelApi, build_model, input_specs

__all__ = ["ModelApi", "build_model", "input_specs"]
