"""Losses.  Cross entropy is computed in sequence chunks so the full
(B, S, vocab) logits tensor — up to 0.5 TB at command-r-plus train_4k —
is never materialized; only (B, chunk, vocab) lives at a time."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import griffin_linear


def chunked_cross_entropy(hidden: jax.Array, unembed: jax.Array,
                          labels: jax.Array, chunk: int = 512) -> jax.Array:
    """hidden: (B, S, D); unembed: (D, V) array or GriffinWeights;
    labels: (B, S) with -1 = masked."""
    B, S, D = hidden.shape
    c = min(chunk, S)
    nc = -(-S // c)
    pad = nc * c - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hs = hidden.reshape(B, nc, c, D).swapaxes(0, 1)
    ls = labels.reshape(B, nc, c).swapaxes(0, 1)

    def body(acc, xs):
        h, lab = xs
        logits = griffin_linear(h, unembed).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lab, 0)[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * (lab >= 0)
        return (acc[0] + nll.sum(), acc[1] + (lab >= 0).sum()), None

    # rematerialize the chunk logits in the backward pass: without this the
    # scan saves every (B, chunk, V) logits block as a residual, which is
    # exactly the memory the chunking exists to avoid
    body = jax.checkpoint(body)
    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (hs, ls))
    return tot / jnp.maximum(cnt, 1)
