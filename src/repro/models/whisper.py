"""Whisper-large-v3 backbone [arXiv:2212.04356]: 32-layer encoder + 32-layer
decoder, d=1280, 20 heads, GeLU MLPs.

The conv audio frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, 1500, d) — the post-conv mel
representation.  The encoder adds sinusoidal positions and runs
bidirectional attention; the decoder is causal with cross-attention (we use
rope for decoder self-attention since the assigned shapes exceed Whisper's
learned 448-position table — recorded as a deviation in DESIGN.md
Section 7).  Weight GEMMs route through ``models.common.griffin_linear``
(the conv frontend stub and attention score/context products do not — they
are not weight GEMMs, DESIGN.md Section 5).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from .attention import attention, decode_attention
from .common import (act_fn, dense_init, griffin_linear, layer_scan,
                     paged_view, paged_write, rms_norm, rope, stack_layers,
                     take_last, write_kv_slot)

Params = Dict[str, Any]


def _sinusoid(length: int, d: int) -> jax.Array:
    pos = np.arange(length)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * dim / d))
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], axis=1),
                       jnp.float32)


def _init_attn(cfg, key, kv_dim=None):
    dt = jnp.dtype(cfg.dtype)
    D, H, hd = cfg.d_model, cfg.num_heads, cfg.hd
    kv_dim = kv_dim or D
    ks = jax.random.split(key, 4)
    return {"wq": dense_init(ks[0], D, H * hd, dt),
            "wk": dense_init(ks[1], kv_dim, H * hd, dt),
            "wv": dense_init(ks[2], kv_dim, H * hd, dt),
            "wo": dense_init(ks[3], H * hd, D, dt)}


def _init_mlp(cfg, key):
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 2)
    return {"w_up": dense_init(ks[0], cfg.d_model, cfg.d_ff, dt),
            "w_down": dense_init(ks[1], cfg.d_ff, cfg.d_model, dt)}


def _init_enc_layer(cfg, key):
    ks = jax.random.split(key, 2)
    return {"ln1": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.dtype)),
            "attn": _init_attn(cfg, ks[0]),
            "ln2": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.dtype)),
            "mlp": _init_mlp(cfg, ks[1])}


def _init_dec_layer(cfg, key):
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)
    return {"ln1": jnp.zeros((cfg.d_model,), dt),
            "self": _init_attn(cfg, ks[0]),
            "ln_x": jnp.zeros((cfg.d_model,), dt),
            "cross": _init_attn(cfg, ks[1]),
            "ln2": jnp.zeros((cfg.d_model,), dt),
            "mlp": _init_mlp(cfg, ks[2])}


def init_params(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        "embed": dense_init(ks[0], cfg.vocab_size, cfg.d_model, dt, scale=1.0),
        "enc_layers": stack_layers(functools.partial(_init_enc_layer, cfg),
                                   ks[1], cfg.encoder_layers),
        "dec_layers": stack_layers(functools.partial(_init_dec_layer, cfg),
                                   ks[2], cfg.num_layers),
        "enc_norm": jnp.zeros((cfg.d_model,), dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
        "head": dense_init(ks[3], cfg.d_model, cfg.vocab_size, dt),
    }


def _mha(cfg, p, xq, xkv, *, causal, positions=None, kv_chunk):
    B, Sq, D = xq.shape
    H, hd = cfg.num_heads, cfg.hd
    q = griffin_linear(xq, p["wq"]).reshape(B, Sq, H, hd)
    k = griffin_linear(xkv, p["wk"]).reshape(B, xkv.shape[1], H, hd)
    v = griffin_linear(xkv, p["wv"]).reshape(B, xkv.shape[1], H, hd)
    if positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    o = attention(q, k, v, causal=causal, kv_chunk=kv_chunk)
    return griffin_linear(o.reshape(B, Sq, -1),
                          p["wo"]).astype(xq.dtype), (k, v)


def encode(cfg: ModelConfig, params: Params, frames: jax.Array) -> jax.Array:
    """frames: (B, F, d) precomputed post-conv embeddings (frontend stub)."""
    x = frames + _sinusoid(frames.shape[1], cfg.d_model).astype(frames.dtype)

    def body(x, lp):
        h, _ = _mha(cfg, lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                    rms_norm(x, lp["ln1"], cfg.norm_eps), causal=False,
                    kv_chunk=cfg.kv_chunk)
        x = (x + h).astype(x.dtype)
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        f = griffin_linear(act_fn(cfg.act)(
            griffin_linear(h2, lp["mlp"]["w_up"])), lp["mlp"]["w_down"])
        return (x + f).astype(x.dtype), None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = layer_scan(cfg.scan_layers, fn, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward_hidden(cfg: ModelConfig, params: Params, tokens: jax.Array,
                   frames: jax.Array, return_kv: bool = False):
    """Decoder over tokens with cross-attention to the encoded frames."""
    enc = encode(cfg, params, frames)
    x = params["embed"][tokens]
    positions = jnp.arange(tokens.shape[1])

    def body(x, lp):
        h, kv = _mha(cfg, lp["self"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                     rms_norm(x, lp["ln1"], cfg.norm_eps), causal=True,
                     positions=positions, kv_chunk=cfg.kv_chunk)
        x = (x + h).astype(x.dtype)
        hx, xkv = _mha(cfg, lp["cross"], rms_norm(x, lp["ln_x"], cfg.norm_eps),
                       enc, causal=False, kv_chunk=cfg.kv_chunk)
        x = (x + hx).astype(x.dtype)
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        f = griffin_linear(act_fn(cfg.act)(
            griffin_linear(h2, lp["mlp"]["w_up"])), lp["mlp"]["w_down"])
        out = (x + f).astype(x.dtype)
        return out, (kv, xkv) if return_kv else None

    fn = jax.checkpoint(body) if (cfg.remat and not return_kv) else body
    x, kvs = layer_scan(cfg.scan_layers, fn, x, params["dec_layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    return (x, aux, kvs) if return_kv else (x, aux)


def init_cache(cfg: ModelConfig, batch: int, length: int) -> Params:
    dt = jnp.dtype(cfg.dtype)
    L, H, hd, F = cfg.num_layers, cfg.num_heads, cfg.hd, cfg.enc_frames
    return {
        "k": jnp.zeros((L, batch, length, H, hd), dt),
        "v": jnp.zeros((L, batch, length, H, hd), dt),
        "xk": jnp.zeros((L, batch, F, H, hd), dt),
        "xv": jnp.zeros((L, batch, F, H, hd), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array,
            frames: jax.Array, cache_len=None, lengths=None):
    """``lengths``: optional (B,) true prompt lengths of a right-padded
    batch (bucketed prefill, DESIGN.md Section 9).  Decoder self-attention
    is causal, so real positions never see the pads; pad K/V rows sit in
    slots ``length..S-1`` where the decode loop overwrites slot ``pos``
    before its position mask admits it."""
    B, S = tokens.shape
    x, _, kvs = forward_hidden(cfg, params, tokens, frames, return_kv=True)
    (ks, vs), (xks, xvs) = kvs
    clen = cache_len or S
    pad = clen - S
    if pad > 0:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    if lengths is None:
        last, pos = x[:, -1], jnp.asarray(S - 1, jnp.int32)
    else:
        last = take_last(x, lengths)
        pos = (lengths - 1).astype(jnp.int32)          # per-row (B,) vector
    logits = griffin_linear(last, params["head"])
    return {"k": ks, "v": vs, "xk": xks, "xv": xvs, "pos": pos}, logits


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                token: jax.Array):
    """``cache["pos"]`` is a scalar (lockstep batch) or a (B,) vector of
    per-row positions (continuous-batching slot pools, runtime/engine.py)."""
    x = params["embed"][token]
    pos = cache["pos"] + 1
    per_slot = pos.ndim > 0
    B = x.shape[0]
    H, hd = cfg.num_heads, cfg.hd
    # "pages" marks a paged self-attention cache (runtime/paging.py): k/v
    # become (L, num_pages, page_size, H, hd) pools indexed through the slot
    # page table; the cross-attention xk/xv leaves stay fixed (encoder K/V
    # is written once at admission, never grows).
    paged = "pages" in cache
    pages = cache.get("pages")
    page_size = cache["k"].shape[2]
    int8 = "k_scale" in cache

    def body(x, xs):
        if paged and int8:
            lp, kc, vc, kscale, vscale, xk, xv = xs
        else:
            lp, kc, vc, xk, xv = xs
            kscale = vscale = None
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        posv = pos[:, None] if per_slot else pos[None]
        q = rope(griffin_linear(h, lp["self"]["wq"]).reshape(B, 1, H, hd),
                 posv, cfg.rope_theta)
        k = rope(griffin_linear(h, lp["self"]["wk"]).reshape(B, 1, H, hd),
                 posv, cfg.rope_theta)
        v = griffin_linear(h, lp["self"]["wv"]).reshape(B, 1, H, hd)
        if paged:
            kc, kscale = paged_write(kc, kscale, pages, k, pos, page_size)
            vc, vscale = paged_write(vc, vscale, pages, v, pos, page_size)
            o = decode_attention(q, paged_view(kc, kscale, pages, x.dtype),
                                 paged_view(vc, vscale, pages, x.dtype), pos)
        else:
            kc = write_kv_slot(kc, k, pos)
            vc = write_kv_slot(vc, v, pos)
            o = decode_attention(q, kc, vc, pos)
        x = (x + griffin_linear(o.reshape(B, 1, -1),
                                lp["self"]["wo"])).astype(x.dtype)
        # cross attention against the static encoder K/V
        hx = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        qx = griffin_linear(hx, lp["cross"]["wq"]).reshape(B, 1, H, hd)
        ox = decode_attention(qx, xk, xv, jnp.asarray(xk.shape[1] - 1))
        x = (x + griffin_linear(ox.reshape(B, 1, -1),
                                lp["cross"]["wo"])).astype(x.dtype)
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        f = griffin_linear(act_fn(cfg.act)(
            griffin_linear(h2, lp["mlp"]["w_up"])), lp["mlp"]["w_down"])
        if paged and int8:
            return (x + f).astype(x.dtype), (kc, vc, kscale, vscale)
        return (x + f).astype(x.dtype), (kc, vc)

    xs = ((params["dec_layers"], cache["k"], cache["v"], cache["k_scale"],
           cache["v_scale"], cache["xk"], cache["xv"]) if paged and int8
          else (params["dec_layers"], cache["k"], cache["v"],
                cache["xk"], cache["xv"]))
    x, ys = layer_scan(cfg.scan_layers, body, x, xs)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = griffin_linear(x[:, 0], params["head"])
    out = {"xk": cache["xk"], "xv": cache["xv"], "pos": pos}
    if paged and int8:
        out["k"], out["v"], out["k_scale"], out["v_scale"] = ys
    else:
        out["k"], out["v"] = ys
    if paged:
        out["pages"] = pages
    return logits, out
