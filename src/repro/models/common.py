"""Shared model components: norms, rope, initializers, tree utilities, and
``griffin_linear`` — the per-GEMM entry point of the sparse execution
substrate (DESIGN.md Section 4)."""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.platform import kernel_interpret
from ..core.hybrid import SPARSE_THRESHOLD, select_mode
from ..core.spec import Mode
from ..kernels.dense_gemm import ops as _dense_ops
from ..kernels.dense_gemm.ops import dense_matmul
from ..kernels.griffin_spmm import ops as _spmm_ops
from ..kernels.griffin_spmm.ops import GriffinWeights, griffin_matmul
from ..kernels.sparse_a import ops as _sparse_a_ops
from ..kernels.sparse_a.ops import sparse_a_matmul

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# sparse execution substrate
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SparseExecution:
    """Static (trace-time) knobs for ``griffin_linear``.

    ``use_kernels`` routes even dense GEMMs through the Pallas substrate
    (off by default: plain ``x @ w`` keeps training/serving behaviour
    byte-identical until a caller opts in).  ``a_sparsity`` is the
    *declared* activation sparsity of the workload category (paper
    Table I) — it must be a concrete float because the mode decision picks
    between kernels at trace time (DESIGN.md Section 5).

    ``spmd_mesh`` (a ``jax.sharding.Mesh`` with > 1 device) switches every
    GEMM to the mesh-partitionable path (DESIGN.md Section 10): inputs and
    outputs are pinned replicated with sharding constraints so GSPMD never
    splits a contraction dim, and each kernel call is wrapped in
    ``shard_map`` — ``pallas_call`` has no GSPMD partitioning rule, but
    the output-axis-only layout makes every device's GEMM fully local, so
    the *real* kernels run per shard (``griffin_matmul(mesh=...)``,
    ``sparse_a_matmul(mesh=...)``, ``dense_matmul(mesh=...)``) with zero
    in-kernel collectives.  ``spmd_kernels=False`` retires that path and
    forces the decompaction/dense-product oracles
    (``griffin_matmul(spmd=True)``, ``sparse_a_matmul(spmd=True)``) —
    kept as the parity reference, no longer the hot loop.  A 1-device
    mesh (or None) keeps the single-device kernel paths byte-identical to
    before.
    """

    use_kernels: bool = False
    interpret: bool = False
    a_sparsity: float = 0.0
    block_m: int = 128
    spmd_mesh: Optional[Any] = None
    spmd_kernels: bool = True
    # Mode-selection A threshold per GEMM (``select_mode``'s first gate).
    # Tuned kernel plans override it per family (``ServeEngine(plan=...)``)
    # and per GEMM (a compacted leaf's ``GriffinWeights.a_thr`` wins over
    # the scope) — a trace-time constant like everything else here, so it
    # survives ``shard_map`` on meshes unchanged (DESIGN.md Section 12).
    a_threshold: float = SPARSE_THRESHOLD


_EXEC_STACK = [SparseExecution()]


@contextlib.contextmanager
def sparse_execution(use_kernels: bool = True, interpret: bool = False,
                     a_sparsity: float = 0.0, block_m: int = 128,
                     spmd_mesh: Optional[Any] = None,
                     spmd_kernels: bool = True,
                     a_threshold: float = SPARSE_THRESHOLD):
    """Scope under which ``griffin_linear`` dispatches to the Pallas
    kernels (mode per GEMM via ``core.hybrid.select_mode``).

    The scope is consulted at **trace time** and is not part of any jit
    cache key: a function jitted (traced) outside the scope keeps its
    dense trace when later called inside one, and vice versa.  Enter the
    scope before the first call of a jitted function — or jit inside the
    scope — exactly as with any trace-time constant (DESIGN.md Section 5).
    """
    _EXEC_STACK.append(SparseExecution(use_kernels=use_kernels,
                                       interpret=interpret,
                                       a_sparsity=a_sparsity,
                                       block_m=block_m,
                                       spmd_mesh=spmd_mesh,
                                       spmd_kernels=spmd_kernels,
                                       a_threshold=a_threshold))
    try:
        yield _EXEC_STACK[-1]
    finally:
        _EXEC_STACK.pop()


# Trace-time dispatch telemetry: ``griffin_linear`` bumps one bucket per
# GEMM it *traces* (jitted callers never re-enter at run time), so an
# engine test can assert the real-kernel shard_map path — not the oracle —
# was taken, turning a silent fallback regression into a test failure
# (DESIGN.md Section 10).  Buckets:
#   "kernel"      single-device Pallas kernel paths
#   "shard_map"   shard_map'd Pallas kernels under an spmd_mesh scope
#   "spmd_oracle" the decompaction / dense-product SPMD oracles
#   "plain"       plain jnp dots (no kernel requested)
# plus one orthogonal outcome bucket: "dual" counts GriffinWeights GEMMs
# whose Mode decision came out AB (dual predication on) — what a tuned
# plan's a_threshold flips, so the plan tier can assert a threshold
# actually changed select_mode outcomes (DESIGN.md Section 12).
KERNEL_DISPATCH: Dict[str, int] = {}


def reset_kernel_dispatch() -> None:
    KERNEL_DISPATCH.clear()


def kernel_dispatch_counts() -> Dict[str, int]:
    return dict(KERNEL_DISPATCH)


def _dispatched(bucket: str) -> None:
    KERNEL_DISPATCH[bucket] = KERNEL_DISPATCH.get(bucket, 0) + 1


def execution_context() -> SparseExecution:
    return _EXEC_STACK[-1]


def _replicated(x: jax.Array, mesh) -> jax.Array:
    """Pin ``x`` fully replicated on ``mesh`` (an all-gather when it
    arrived sharded).  The mesh-serving GEMM contract (DESIGN.md
    Section 10): replicated activations x output-axis-sharded weights mean
    every contraction runs whole on every device, so GSPMD collectives
    only ever *move* values — nothing reorders a floating-point reduction
    and the sharded trace stays bit-identical to the single-device one."""
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec()))


def griffin_linear(x: jax.Array, w) -> jax.Array:
    """The weight GEMM of the model stack: ``x @ w`` morphed per call.

    ``w`` is either a plain array (dense weights) or a ``GriffinWeights``
    (block-compacted, produced by ``repro.sparsity.sparsify_params``).  The
    execution mode follows ``core.hybrid.select_mode`` over the declared
    activation sparsity and the weight representation:

      dense w, dense a  -> plain ``x @ w`` (or the dense Pallas kernel
                           when the ``sparse_execution`` scope is active)
      dense w, sparse a -> Sparse.A kernel (runtime-compacted A)
      GriffinWeights    -> Sparse.B kernel; dual when a is also declared
                           sparse (on-the-fly A-block predication)

    Under a multi-device ``spmd_mesh`` scope the same dispatch wraps each
    kernel call in ``shard_map`` with replicated inputs and outputs
    (``_replicated``; DESIGN.md Section 10): the output-axis-only layout
    makes every device's GEMM fully local, so the real kernels run per
    shard and the replication constraints keep every reduction whole —
    sharding never changes a logit bit.  Weights whose output axis does
    not split evenly over the model axis — or any GEMM when the scope
    sets ``spmd_kernels=False`` — take the decompaction / dense-product
    oracle instead (interpret mode is forced on platforms that need it,
    ``configs.platform.kernel_interpret``, since mesh jit sets are traced
    after placement).

    Leading batch/sequence axes are flattened into the GEMM M axis.
    """
    ctx = _EXEC_STACK[-1]
    mesh = ctx.spmd_mesh
    spmd = mesh is not None and mesh.size > 1
    mp = (mesh.shape.get("model", 0)
          if spmd and "model" in mesh.axis_names else 0)
    if spmd:
        x = _replicated(x, mesh)
    if isinstance(w, GriffinWeights):
        lead = x.shape[:-1]
        thr = w.a_thr if w.a_thr is not None else ctx.a_threshold
        mode = select_mode(ctx.a_sparsity, 1.0, threshold=thr)
        x2 = x.reshape(-1, x.shape[-1])
        dual = mode == Mode.AB
        if dual:
            _dispatched("dual")
        if spmd and ctx.spmd_kernels and mp and _spmm_ops.shardable(w, mp):
            _dispatched("shard_map")
            out = griffin_matmul(x2, w, block_m=ctx.block_m, dual=dual,
                                 interpret=ctx.interpret or kernel_interpret(),
                                 mesh=mesh)
        elif spmd:
            _dispatched("spmd_oracle")
            out = griffin_matmul(x2, w, block_m=ctx.block_m, dual=dual,
                                 spmd=True)
        else:
            _dispatched("kernel")
            out = griffin_matmul(x2, w, block_m=ctx.block_m, dual=dual,
                                 interpret=ctx.interpret)
        out = out.reshape(*lead, w.n).astype(x.dtype)
        return _replicated(out, mesh) if spmd else out
    if not ctx.use_kernels and not spmd:
        return x @ w
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    sparse_a = select_mode(ctx.a_sparsity, 0.0,
                           threshold=ctx.a_threshold) == Mode.A
    if spmd:
        kern_ops = _sparse_a_ops if sparse_a else _dense_ops
        if (ctx.use_kernels and ctx.spmd_kernels and mp
                and kern_ops.shardable(w, mp)):
            _dispatched("shard_map")
            interp = ctx.interpret or kernel_interpret()
            out = (sparse_a_matmul(x2, w, block_m=ctx.block_m,
                                   interpret=interp, mesh=mesh)
                   if sparse_a else
                   dense_matmul(x2, w, block_m=ctx.block_m,
                                interpret=interp, mesh=mesh))
        elif ctx.use_kernels and sparse_a:
            _dispatched("spmd_oracle")
            out = sparse_a_matmul(x2, w, spmd=True)
        else:
            _dispatched("spmd_oracle" if ctx.use_kernels else "plain")
            out = x2 @ w
    elif sparse_a:
        _dispatched("kernel")
        out = sparse_a_matmul(x2, w, block_m=ctx.block_m,
                              interpret=ctx.interpret)
    else:
        _dispatched("kernel")
        out = dense_matmul(x2, w, block_m=ctx.block_m,
                           interpret=ctx.interpret)
    out = out.reshape(*lead, w.shape[-1]).astype(x.dtype)
    return _replicated(out, mesh) if spmd else out


def write_kv_slot(cache: jax.Array, update: jax.Array, slot: jax.Array
                  ) -> jax.Array:
    """Write a one-token K/V update into a (B, S, ...) cache at ``slot``.

    ``slot`` is a scalar (lockstep batch: one shared sequence index) or a
    (B,) vector of per-row indices (continuous-batching slot pools,
    runtime/engine.py) — the vector path is a per-row
    ``dynamic_update_slice`` under ``vmap`` and is bit-identical to the
    scalar path when all entries are equal.  ``update``: (B, 1, ...).
    """
    if slot.ndim:
        upd = jax.vmap(
            lambda c, u, s: jax.lax.dynamic_update_slice(c, u, (s, 0, 0)))
        return upd(cache, update, slot)
    return jax.lax.dynamic_update_slice(cache, update, (0, slot, 0, 0))


def paged_write(pool: jax.Array, scale: Optional[jax.Array],
                pages: jax.Array, update: jax.Array, pos: jax.Array,
                page_size: int) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Write a one-token K/V update into a paged pool (DESIGN.md Sec. 14).

    ``pool``: (num_pages, page_size, ...) shared physical pages;
    ``pages``: (B, max_pages) int32 page table (logical page j of row b ->
    physical page id); ``update``: (B, 1, ...); ``pos``: scalar or (B,)
    per-row position, exactly as ``write_kv_slot`` takes it.  Positions
    wrap at ``max_pages * page_size`` so dead slots (whose positions keep
    advancing after release) stay in range — their table rows point at the
    DUMP page (id 0), which is never read, so their garbage writes are
    discarded by construction.  When ``scale`` is given the pool is int8:
    the row is quantized on the way in (optim.compression.quantize_rows)
    and its per-token scale stored alongside.
    """
    from ..optim.compression import quantize_rows
    B, maxp = pages.shape
    posv = jnp.broadcast_to(jnp.asarray(pos), (B,)).astype(jnp.int32)
    slot = posv % (maxp * page_size)
    pid = jnp.take_along_axis(pages, (slot // page_size)[:, None],
                              axis=1)[:, 0]
    off = slot % page_size
    row = update[:, 0]
    if scale is not None:
        q, s = quantize_rows(row, 1)
        return pool.at[pid, off].set(q), scale.at[pid, off].set(s)
    return pool.at[pid, off].set(row.astype(pool.dtype)), None


def paged_view(pool: jax.Array, scale: Optional[jax.Array],
               pages: jax.Array, dtype: Any) -> jax.Array:
    """Gather each row's pages into a (B, max_pages * page_size, ...) view.

    The engine rounds ``cache_len`` up to ``max_pages * page_size``, so
    this view has exactly the fixed arena's (B, cache_len, ...) shape —
    ``decode_attention``'s position mask then sees identical shapes and
    fp32 paged decode is bit-identical to the fixed arena (masked entries
    contribute an exact 0.0 either way).  int8 pools dequantize through
    the per-token scales on the way out.
    """
    v = pool[pages]                      # (B, max_pages, page_size, ...)
    if scale is not None:
        s = scale[pages]
        v = v.astype(jnp.float32) * s[(...,) + (None,) * (v.ndim - 3)]
    B, maxp, ps = v.shape[:3]
    return v.reshape(B, maxp * ps, *v.shape[3:]).astype(dtype)


def length_mask(lengths: jax.Array, seq_len: int) -> jax.Array:
    """(B,) true prompt lengths -> (B, S) bool validity mask for a
    right-padded token batch (position i valid iff i < length).  The
    bucketed-prefill path (runtime/engine.py, DESIGN.md Section 9) pads
    prompts up to a power-of-two bucket; this mask is what each family's
    prefill threads into its state updates so pad positions are identity."""
    return jnp.arange(seq_len)[None, :] < lengths[:, None]


def take_last(x: jax.Array, lengths: jax.Array) -> jax.Array:
    """Per-row last *valid* timestep of a right-padded (B, S, D) tensor:
    row b -> x[b, lengths[b] - 1].  The bucketed replacement for
    ``x[:, -1]`` (which would read a pad position)."""
    idx = (lengths - 1).astype(jnp.int32)[:, None, None]
    idx = jnp.broadcast_to(idx, (x.shape[0], 1, x.shape[-1]))
    return jnp.take_along_axis(x, idx, axis=1)[:, 0]


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0
         ) -> jax.Array:
    """Rotary embedding.  x: (..., seq, heads, head_dim), positions: (seq,)
    or broadcastable to (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = (1.0 / theta) ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32,
               scale: Optional[float] = None) -> jax.Array:
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) *
            scale).astype(dtype)


def stack_layers(init_one: Callable[[jax.Array], Params], key: jax.Array,
                 n: int) -> Params:
    """Initialize n layers and stack each leaf along a leading axis, the
    layout ``lax.scan`` consumes.  n == 0 yields empty-stacked leaves (scan
    over length-0 xs is a no-op), so irregular depth patterns degrade
    gracefully in reduced configs."""
    if n == 0:
        proto = jax.eval_shape(init_one, key)
        return jax.tree.map(
            lambda x: jnp.zeros((0,) + x.shape, x.dtype), proto)
    keys = jax.random.split(key, n)
    layers = [init_one(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *layers)


def layer_scan(use_scan: bool, body: Callable, carry, xs):
    """``lax.scan`` over stacked layers, or an unrolled python loop.

    The unrolled form exists for the roofline cost pass: XLA's
    HloCostAnalysis counts a while-loop body once regardless of trip count,
    so per-layer costs are extracted from *unrolled* lowers of 1 vs 2 layers
    (launch/dryrun.py) while production compiles use the scan (compile time
    independent of depth).
    """
    n = jax.tree.leaves(xs)[0].shape[0]
    if use_scan or n == 0:
        # length-0 stacks produce structurally-correct empty ys via scan
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs, 0), *ys)
    else:
        ys = None
    return carry, ys


def remat_fn(cfg, body: Callable) -> Callable:
    """Apply the configured rematerialization policy to a layer body."""
    if not cfg.remat:
        return body
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(body)


def act_fn(name: str) -> Callable[[jax.Array], jax.Array]:
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu,
            "gelu_tanh": functools.partial(jax.nn.gelu, approximate=True),
            }[name]


def param_count(params: Params) -> int:
    return int(sum(np.prod(p.shape) for p in jax.tree.leaves(params)))


def cast_tree(params: Params, dtype) -> Params:
    return jax.tree.map(
        lambda p: p.astype(dtype) if jnp.issubdtype(p.dtype, jnp.floating)
        else p, params)
