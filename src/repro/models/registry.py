"""Model registry: one uniform API over the four architecture families.

``build_model(cfg)`` returns a ``ModelApi`` whose members are pure functions
(params and caches are pytrees) — the runtime/launch layers jit and shard
them.  Analytic parameter/FLOP counts feed the roofline's MODEL_FLOPS.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, SHAPES, ShapeConfig
from . import rglru, transformer, whisper, xlstm
from .losses import chunked_cross_entropy

Params = Dict[str, Any]


@dataclasses.dataclass
class ModelApi:
    cfg: ModelConfig
    init: Callable[[jax.Array], Params]
    loss: Callable[..., jax.Array]            # (params, batch) -> scalar
    prefill: Callable[..., Any]               # (params, batch) -> (cache, logits)
    decode_step: Callable[..., Any]           # (params, cache, token) -> (logits, cache)
    init_cache: Callable[[int, int], Params]  # (batch, length) -> cache
    param_count: Callable[[], int]            # analytic, excludes embeddings
    param_count_total: Callable[[], int]


def _transformer_api(cfg: ModelConfig) -> ModelApi:
    def loss(params, batch):
        hidden, aux = transformer.forward_hidden(cfg, params, batch["tokens"])
        ce = chunked_cross_entropy(hidden, transformer.unembed(cfg, params),
                                   batch["labels"], cfg.loss_chunk)
        return ce + 0.01 * aux

    def prefill_fn(params, batch, cache_len=None):
        return transformer.prefill(cfg, params, batch["tokens"], cache_len,
                                   lengths=batch.get("lengths"))

    return ModelApi(
        cfg=cfg,
        init=functools.partial(transformer.init_params, cfg),
        loss=loss,
        prefill=prefill_fn,
        decode_step=functools.partial(transformer.decode_step, cfg),
        init_cache=functools.partial(transformer.init_cache, cfg),
        param_count=lambda: _tf_param_count(cfg, active=True),
        param_count_total=lambda: _tf_param_count(cfg, active=False),
    )


def _tf_param_count(cfg: ModelConfig, active: bool) -> int:
    D, H, KVH, hd, F = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd,
                        cfg.d_ff)
    attn = D * H * hd + 2 * D * KVH * hd + H * hd * D
    if cfg.moe:
        E = cfg.moe.num_experts
        eff = cfg.moe.top_k if active else E
        ffn = D * E + eff * 3 * D * F
    else:
        ffn = 3 * D * F
    return cfg.num_layers * (attn + ffn)


def _xlstm_api(cfg: ModelConfig) -> ModelApi:
    def loss(params, batch):
        hidden, aux = xlstm.forward_hidden(cfg, params, batch["tokens"])
        return chunked_cross_entropy(hidden, params["head"], batch["labels"],
                                     cfg.loss_chunk)

    def prefill_fn(params, batch, cache_len=None):
        return xlstm.prefill(cfg, params, batch["tokens"], cache_len,
                             lengths=batch.get("lengths"))

    def count(active=True):
        D = cfg.d_model
        din = int(cfg.proj_factor * D)
        H = cfg.num_heads
        hd_s = D // H
        pat = cfg.xlstm_pattern
        n_m = sum(1 for b in pat if b == "m") * (cfg.num_layers // len(pat))
        n_s = cfg.num_layers // len(pat) * (len(pat) - len(pat) + 1) \
            if False else (cfg.num_layers // len(pat)) * \
            sum(1 for b in pat if b == "s")
        m_p = D * 2 * din + 3 * H * (din // H) ** 2 + 2 * din * H + din * D
        s_p = 4 * (D * D + H * hd_s * hd_s) + D * int(4 * D / 3) * 2
        return n_m * m_p + n_s * s_p

    return ModelApi(
        cfg=cfg,
        init=functools.partial(xlstm.init_params, cfg),
        loss=loss,
        prefill=prefill_fn,
        decode_step=functools.partial(xlstm.decode_step, cfg),
        init_cache=functools.partial(xlstm.init_cache, cfg),
        param_count=lambda: count(),
        param_count_total=lambda: count(False),
    )


def _rglru_api(cfg: ModelConfig) -> ModelApi:
    def loss(params, batch):
        hidden, _ = rglru.forward_hidden(cfg, params, batch["tokens"])
        return chunked_cross_entropy(hidden, params["head"], batch["labels"],
                                     cfg.loss_chunk)

    def prefill_fn(params, batch, cache_len=None):
        return rglru.prefill(cfg, params, batch["tokens"], cache_len,
                             lengths=batch.get("lengths"))

    def count(active=True):
        D, F = cfg.d_model, cfg.d_ff
        R = cfg.lru_width or D
        H, KVH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
        groups, tail = rglru._group_counts(cfg)
        rec = 2 * D * R + 2 * R * R + R * D
        attn = D * H * hd + 2 * D * KVH * hd + H * hd * D
        mlp = 3 * D * F
        return groups * (2 * rec + attn + 3 * mlp) + tail * (rec + mlp)

    return ModelApi(
        cfg=cfg,
        init=functools.partial(rglru.init_params, cfg),
        loss=loss,
        prefill=prefill_fn,
        decode_step=functools.partial(rglru.decode_step, cfg),
        init_cache=functools.partial(rglru.init_cache, cfg),
        param_count=lambda: count(),
        param_count_total=lambda: count(False),
    )


def _whisper_api(cfg: ModelConfig) -> ModelApi:
    def loss(params, batch):
        hidden, _ = whisper.forward_hidden(cfg, params, batch["tokens"],
                                           batch["frames"])
        return chunked_cross_entropy(hidden, params["head"], batch["labels"],
                                     cfg.loss_chunk)

    def prefill_fn(params, batch, cache_len=None):
        return whisper.prefill(cfg, params, batch["tokens"], batch["frames"],
                               cache_len, lengths=batch.get("lengths"))

    def count(active=True):
        D, H, hd, F = cfg.d_model, cfg.num_heads, cfg.hd, cfg.d_ff
        attn = 4 * D * H * hd
        mlp = 2 * D * F
        enc = cfg.encoder_layers * (attn + mlp)
        dec = cfg.num_layers * (2 * attn + mlp)
        return enc + dec

    return ModelApi(
        cfg=cfg,
        init=functools.partial(whisper.init_params, cfg),
        loss=loss,
        prefill=prefill_fn,
        decode_step=functools.partial(whisper.decode_step, cfg),
        init_cache=functools.partial(whisper.init_cache, cfg),
        param_count=lambda: count(),
        param_count_total=lambda: count(False),
    )


def build_model(cfg: ModelConfig) -> ModelApi:
    if cfg.family in ("dense", "vlm", "moe"):
        return _transformer_api(cfg)
    if cfg.family == "ssm":
        return _xlstm_api(cfg)
    if cfg.family == "hybrid":
        return _rglru_api(cfg)
    if cfg.family == "audio":
        return _whisper_api(cfg)
    raise ValueError(f"unknown family {cfg.family}")


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell
    (weak-type-correct, shardable, no device allocation)."""
    B, S = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    specs: Dict[str, Any] = {}
    if shape.kind == "train":
        specs = {"tokens": tok, "labels": tok}
    elif shape.kind == "prefill":
        specs = {"tokens": tok}
    else:                                    # decode: one new token
        specs = {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    if cfg.is_encdec and shape.kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_frames, cfg.d_model), jnp.dtype(cfg.dtype))
    return specs
