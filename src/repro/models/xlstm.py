"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, chunkwise-parallel
for train, O(1)-state recurrent for decode) and sLSTM (scalar memory with
exponential gating and block-diagonal recurrence).

The 48 blocks follow the 7:1 mLSTM:sLSTM pattern, organized as
``lax.scan`` over groups of (7 stacked mLSTM + 1 sLSTM) so compile time is
depth-independent.  The Griffin sparse technique applies to the projection
GEMMs only (the recurrent state path is not a weight GEMM — DESIGN.md
Section 5).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import (dense_init, griffin_linear, layer_scan, length_mask,
                     rms_norm, stack_layers, take_last)

Params = Dict[str, Any]
MIN_NORM = 1e-6


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    D = cfg.d_model
    din = int(cfg.proj_factor * D)
    ks = jax.random.split(key, 8)
    H = cfg.num_heads
    hd = din // H

    def blockdiag(k):
        # per-head projections (block-diagonal), as in the official xLSTM
        return (jax.random.normal(k, (H, hd, hd), jnp.float32) /
                jnp.sqrt(hd)).astype(dt)

    return {
        "ln": jnp.zeros((D,), dt),
        "w_up": dense_init(ks[0], D, 2 * din, dt),
        "wq": blockdiag(ks[1]),
        "wk": blockdiag(ks[2]),
        "wv": blockdiag(ks[3]),
        "wi": dense_init(ks[4], din, cfg.num_heads, dt),
        "wf": dense_init(ks[5], din, cfg.num_heads, dt),
        "gn": jnp.zeros((din,), dt),
        "w_down": dense_init(ks[6], din, D, dt),
    }


def _mlstm_chunk(q, k, v, i_pre, f_pre, state):
    """One chunk of stabilized chunkwise mLSTM.

    q,k,v: (B, L, H, hd) (k pre-scaled by 1/sqrt(hd));
    i_pre, f_pre: (B, L, H) gate pre-activations;
    state: (C (B,H,hd,hd), n (B,H,hd), m (B,H)).
    """
    C_prev, n_prev, m_prev = state
    B, L, H, hd = q.shape
    lf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))     # (B,L,H)
    b = jnp.cumsum(lf, axis=1)                             # inclusive
    total = b[:, -1]                                       # (B,H)
    i32 = i_pre.astype(jnp.float32)
    # intra-chunk log decay D[t,s] = b[t] - b[s] + i[s], s <= t
    Dlog = b[:, :, None, :] - b[:, None, :, :] + i32[:, None, :, :]
    tmask = jnp.tril(jnp.ones((L, L), bool))
    Dlog = jnp.where(tmask[None, :, :, None], Dlog, -jnp.inf)
    m_intra = Dlog.max(axis=2)                             # (B,L,H)
    a = m_prev[:, None, :] + b                             # inter decay (B,L,H)
    m_t = jnp.maximum(m_intra, a)
    qk = jnp.einsum("blhd,bshd->blsh", q.astype(jnp.float32),
                    k.astype(jnp.float32))
    P = jnp.exp(Dlog - m_t[:, :, None, :]) * qk
    h_intra = jnp.einsum("blsh,bshd->blhd", P, v.astype(jnp.float32))
    qn_intra = P.sum(axis=2)                               # (B,L,H)
    scale_inter = jnp.exp(a - m_t)                         # (B,L,H)
    h_inter = jnp.einsum("blhd,bhde->blhe", q.astype(jnp.float32), C_prev) * \
        scale_inter[..., None]
    qn_inter = jnp.einsum("blhd,bhd->blh", q.astype(jnp.float32), n_prev) * \
        scale_inter
    denom = jnp.maximum(jnp.abs(qn_intra + qn_inter), jnp.exp(-m_t)) + MIN_NORM
    h = (h_intra + h_inter) / denom[..., None]
    # state update to end of chunk
    w = total[:, None, :] - b + i32                        # (B,L,H)
    m_next = jnp.maximum(m_prev + total, w.max(axis=1))
    sc = jnp.exp(w - m_next[:, None, :])
    decay_old = jnp.exp(m_prev + total - m_next)           # (B,H)
    C_next = decay_old[:, :, None, None] * C_prev + \
        jnp.einsum("blh,blhd,blhe->bhde", sc, k.astype(jnp.float32),
                   v.astype(jnp.float32))
    n_next = decay_old[:, :, None] * n_prev + \
        jnp.einsum("blh,blhd->bhd", sc, k.astype(jnp.float32))
    return h, (C_next, n_next, m_next)


def mlstm_seq(cfg: ModelConfig, p: Params, x: jax.Array, state=None,
              chunk: int = 64, mask=None):
    """Full mLSTM block over a sequence.  x: (B, S, D).

    ``mask``: optional (B, S) validity mask of a right-padded batch
    (bucketed prefill).  Pad positions are made exact state no-ops through
    the gate pre-activations alone: the input gate is driven to -1e30 (its
    exp vanishes from both the intra-chunk decay matrix and the chunk state
    update) and the forget gate to +1e30 (log-sigmoid exactly 0, identity
    decay), so (C, n, m) after the padded sequence equal the state at the
    last real token."""
    B, S, D = x.shape
    H = cfg.num_heads
    din = int(cfg.proj_factor * D)
    hd = din // H
    h_in = rms_norm(x, p["ln"], cfg.norm_eps)
    up = griffin_linear(h_in, p["w_up"])
    xm, z = up[..., :din], up[..., din:]
    xh = xm.reshape(B, S, H, hd)
    q = jnp.einsum("bshd,hde->bshe", xh, p["wq"])
    k = jnp.einsum("bshd,hde->bshe", xh, p["wk"]) / \
        jnp.sqrt(hd).astype(x.dtype)
    v = jnp.einsum("bshd,hde->bshe", xh, p["wv"])
    i_pre = griffin_linear(xm, p["wi"])
    f_pre = griffin_linear(xm, p["wf"])
    if mask is not None:
        m3 = mask[:, :, None]
        i_pre = jnp.where(m3, i_pre, jnp.asarray(-1e30, i_pre.dtype))
        f_pre = jnp.where(m3, f_pre, jnp.asarray(1e30, f_pre.dtype))
    if state is None:
        state = mlstm_zero_state(cfg, B)
    L = min(chunk, S)
    nc = -(-S // L)
    assert nc * L == S, (S, L)

    def body(st, xs):
        qc, kc, vc, ic, fc = xs
        h, st = _mlstm_chunk(qc, kc, vc, ic, fc, st)
        return st, h

    xs = tuple(a.reshape(B, nc, L, *a.shape[2:]).swapaxes(0, 1)
               for a in (q, k, v, i_pre, f_pre))
    state, hs = jax.lax.scan(body, state, xs)
    h = hs.swapaxes(0, 1).reshape(B, S, H, hd).reshape(B, S, din)
    h = rms_norm(h, p["gn"], cfg.norm_eps)
    out = griffin_linear(
        h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), p["w_down"])
    return (x + out).astype(x.dtype), state


def mlstm_zero_state(cfg: ModelConfig, batch: int):
    din = int(cfg.proj_factor * cfg.d_model)
    H = cfg.num_heads
    hd = din // H
    return (jnp.zeros((batch, H, hd, hd), jnp.float32),
            jnp.zeros((batch, H, hd), jnp.float32),
            jnp.full((batch, H), -1e30, jnp.float32))


def mlstm_step(cfg: ModelConfig, p: Params, x: jax.Array, state):
    """O(1) decode step.  x: (B, 1, D)."""
    out, state = mlstm_seq(cfg, p, x, state=state, chunk=1)
    return out, state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    D = cfg.d_model
    H = cfg.num_heads
    hd = D // H
    ks = jax.random.split(key, 10)
    def rmat(k):
        return (jax.random.normal(k, (H, hd, hd), jnp.float32) /
                jnp.sqrt(hd)).astype(dt)
    ff = int(4 * D / 3)
    return {
        "ln": jnp.zeros((D,), dt),
        "wz": dense_init(ks[0], D, D, dt), "rz": rmat(ks[1]),
        "wi": dense_init(ks[2], D, D, dt), "ri": rmat(ks[3]),
        "wf": dense_init(ks[4], D, D, dt), "rf": rmat(ks[5]),
        "wo": dense_init(ks[6], D, D, dt), "ro": rmat(ks[7]),
        "gn": jnp.zeros((D,), dt),
        "ln2": jnp.zeros((D,), dt),
        "w_ff1": dense_init(ks[8], D, ff, dt),
        "w_ff2": dense_init(ks[9], ff, D, dt),
    }


def slstm_zero_state(cfg: ModelConfig, batch: int):
    D, H = cfg.d_model, cfg.num_heads
    hd = D // H
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return (z, z, z, jnp.full((batch, H, hd), -1e30, jnp.float32))


def slstm_seq(cfg: ModelConfig, p: Params, x: jax.Array, state=None,
              mask=None):
    """sLSTM block: strict recurrence over time (lax.scan).

    ``mask``: optional (B, S) validity mask of a right-padded batch
    (bucketed prefill).  The hidden state feeds back into the gates, so pad
    steps must hold the *entire* carried state — each step computes
    normally and then selects old-vs-new per row, leaving (c, n, h, m)
    after the padded sequence exactly the state at the last real token."""
    B, S, D = x.shape
    H = cfg.num_heads
    hd = D // H
    xin = rms_norm(x, p["ln"], cfg.norm_eps)
    # precompute input contributions for all gates: (B,S,H,hd)
    pre = {g: griffin_linear(xin, p["w" + g]).reshape(B, S, H, hd)
           .astype(jnp.float32) for g in ("z", "i", "f", "o")}
    if state is None:
        state = slstm_zero_state(cfg, B)
    R = {g: p["r" + g].astype(jnp.float32) for g in ("z", "i", "f", "o")}

    def step(st, xs):
        c, n, h, m = st
        if mask is None:
            zx, ix, fx, ox = xs                            # (B,H,hd)
        else:
            zx, ix, fx, ox, mt = xs
        rec = {g: jnp.einsum("bhd,hde->bhe", h, R[g])
               for g in ("z", "i", "f", "o")}
        zt = jnp.tanh(zx + rec["z"])
        it = ix + rec["i"]                                 # log-space
        ft = jax.nn.log_sigmoid(fx + rec["f"])
        ot = jax.nn.sigmoid(ox + rec["o"])
        m_new = jnp.maximum(ft + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(ft + m - m_new)
        c_new = f_s * c + i_s * zt
        n_new = f_s * n + i_s
        h_new = ot * c_new / jnp.maximum(n_new, MIN_NORM)
        if mask is not None:
            sel = mt[:, None, None]
            c_new = jnp.where(sel, c_new, c)
            n_new = jnp.where(sel, n_new, n)
            h_new = jnp.where(sel, h_new, h)
            m_new = jnp.where(sel, m_new, m)
        return (c_new, n_new, h_new, m_new), h_new

    xs = tuple(pre[g].swapaxes(0, 1) for g in ("z", "i", "f", "o"))
    if mask is not None:
        xs = xs + (mask.swapaxes(0, 1),)
    state, hs = jax.lax.scan(step, state, xs)
    h = hs.swapaxes(0, 1).reshape(B, S, D)
    h = rms_norm(h.astype(x.dtype), p["gn"], cfg.norm_eps)
    x = x + h
    f = rms_norm(x, p["ln2"], cfg.norm_eps)
    f = jax.nn.gelu(griffin_linear(f, p["w_ff1"]).astype(jnp.float32)
                    ).astype(x.dtype)
    return (x + griffin_linear(f, p["w_ff2"])).astype(x.dtype), state


# ---------------------------------------------------------------------------
# model assembly: scan over groups of (n_m mLSTM + n_s sLSTM)
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    pat = cfg.xlstm_pattern
    n_m = sum(1 for b in pat if b == "m")
    n_s = len(pat) - n_m
    groups = cfg.num_layers // len(pat)
    k_emb, k_m, k_s, k_h = jax.random.split(key, 4)

    def init_group_m(k):
        return stack_layers(functools.partial(init_mlstm, cfg), k, n_m)

    def init_group_s(k):
        return stack_layers(functools.partial(init_slstm, cfg), k, n_s)

    return {
        "embed": dense_init(k_emb, cfg.vocab_size, cfg.d_model, dt, scale=1.0),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
        "m_blocks": stack_layers(init_group_m, k_m, groups),   # (G, n_m, ...)
        "s_blocks": stack_layers(init_group_s, k_s, groups),   # (G, n_s, ...)
        "head": dense_init(k_h, cfg.d_model, cfg.vocab_size, dt),
    }


def forward_hidden(cfg: ModelConfig, params: Params, tokens: jax.Array,
                   chunk: int = 64):
    x = params["embed"][tokens]

    def group(x, gp):
        mp, sp = gp

        def m_body(x, lp):
            x, _ = mlstm_seq(cfg, lp, x, chunk=chunk)
            return x, None

        x, _ = layer_scan(cfg.scan_layers, m_body, x, mp)

        def s_body(x, lp):
            x, _ = slstm_seq(cfg, lp, x)
            return x, None

        x, _ = layer_scan(cfg.scan_layers, s_body, x, sp)
        return x, None

    fn = jax.checkpoint(group) if cfg.remat else group
    x, _ = layer_scan(cfg.scan_layers, fn, x,
                      (params["m_blocks"], params["s_blocks"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, length: int) -> Params:
    """Recurrent state: O(1) in sequence length — this is what makes
    long_500k decode feasible."""
    pat = cfg.xlstm_pattern
    n_m = sum(1 for b in pat if b == "m")
    n_s = len(pat) - n_m
    groups = cfg.num_layers // len(pat)

    def rep(x, *lead):
        return jnp.broadcast_to(x, tuple(lead) + x.shape)

    mC, mn, mm = mlstm_zero_state(cfg, batch)
    sc, sn, sh, sm = slstm_zero_state(cfg, batch)
    return {
        "mC": rep(mC, groups, n_m), "mn": rep(mn, groups, n_m),
        "mm": rep(mm, groups, n_m),
        "sc": rep(sc, groups, n_s), "sn": rep(sn, groups, n_s),
        "sh": rep(sh, groups, n_s), "sm": rep(sm, groups, n_s),
        "pos": jnp.zeros((), jnp.int32),
    }


def _scan_groups_with_state(cfg: ModelConfig, params, cache, x, chunk,
                            mask=None):
    def group(x, xs):
        (mp, sp, mC, mn, mm, sc, sn, sh, sm) = xs

        def m_body(x, ms):
            lp, C, n, m = ms
            x, (C, n, m) = mlstm_seq(cfg, lp, x, state=(C, n, m), chunk=chunk,
                                     mask=mask)
            return x, (C, n, m)

        x, mstate = jax.lax.scan(m_body, x, (mp, mC, mn, mm))

        def s_body(x, ss):
            lp, c, n, h, m = ss
            x, (c, n, h, m) = slstm_seq(cfg, lp, x, state=(c, n, h, m),
                                        mask=mask)
            return x, (c, n, h, m)

        x, sstate = jax.lax.scan(s_body, x, (sp, sc, sn, sh, sm))
        return x, mstate + sstate

    x, states = layer_scan(
        cfg.scan_layers, group, x,
        (params["m_blocks"], params["s_blocks"], cache["mC"],
                   cache["mn"], cache["mm"], cache["sc"], cache["sn"],
                   cache["sh"], cache["sm"]))
    new_cache = dict(zip(("mC", "mn", "mm", "sc", "sn", "sh", "sm"), states))
    return x, new_cache


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array,
            cache_len=None, chunk: int = 64, lengths=None):
    """``lengths``: optional (B,) true prompt lengths of a right-padded
    batch (bucketed prefill).  Pad steps are exact state no-ops (see
    ``mlstm_seq`` / ``slstm_seq``), so the carried recurrent state equals
    the state at each row's last real token."""
    B, S = tokens.shape
    cache = init_cache(cfg, B, 0)
    x = params["embed"][tokens]
    mask = None if lengths is None else length_mask(lengths, S)
    x, new_cache = _scan_groups_with_state(cfg, params, cache, x, chunk,
                                           mask=mask)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if lengths is None:
        last, pos = x[:, -1], jnp.asarray(S - 1, jnp.int32)
    else:
        last = take_last(x, lengths)
        pos = (lengths - 1).astype(jnp.int32)          # per-row (B,) vector
    logits = griffin_linear(last, params["head"])
    new_cache["pos"] = pos
    return new_cache, logits


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                token: jax.Array):
    """One recurrent step.  The mLSTM/sLSTM state math is position-free, so
    per-slot serving (continuous batching, runtime/engine.py) needs no
    vector-position branch here: ``cache["pos"]`` increments elementwise
    whether it is the lockstep scalar or a (B,) per-slot vector."""
    x = params["embed"][token]
    x, new_cache = _scan_groups_with_state(cfg, params, cache, x, chunk=1)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = griffin_linear(x[:, 0], params["head"])
    new_cache["pos"] = cache["pos"] + 1
    return logits, new_cache
