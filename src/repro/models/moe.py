"""Mixture-of-Experts FFN: einsum-dispatch (Shazeer-style) with capacity.

The dispatch/combine tensors are built with one-hot einsums so GSPMD can
shard the expert axis (expert parallelism) or the FFN axis (tensor
parallelism) and derive the all-to-all / all-gather pattern itself.  FLOPs
are proportional to E * C ~= tokens * capacity_factor * top_k, i.e. the
*active* expert compute, not the full E * tokens product.

The router and per-expert FFN GEMMs route through ``expert_linear`` /
``models.common.griffin_linear``: pruned experts arrive as a stacked
``GriffinWeights`` (leading expert axis) and run the Sparse.B kernel per
expert (DESIGN.md Section 4).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import MoEConfig
from ..kernels.griffin_spmm.ops import GriffinWeights
from .common import act_fn, dense_init, execution_context, griffin_linear


def expert_linear(xe: jax.Array, w) -> jax.Array:
    """Per-expert weight GEMM: xe (E, C, K) x w (E, K, N) -> (E, C, N).

    ``w`` may be a stacked ``GriffinWeights`` (leading expert axis, built by
    ``repro.sparsity.sparsify_params``) — each expert then runs the Sparse.B
    kernel — or a plain stacked array (einsum batched GEMM; unrolled through
    ``griffin_linear`` per expert when a ``sparse_execution`` scope is
    active)."""
    if isinstance(w, GriffinWeights):
        E = w.b_comp.shape[0]
        return jnp.stack([griffin_linear(xe[e], w[e]) for e in range(E)])
    if execution_context().use_kernels:
        return jnp.stack([griffin_linear(xe[e], w[e])
                          for e in range(w.shape[0])])
    return jnp.einsum("eck,ekn->ecn", xe, w)


def init_moe(key, d_model: int, d_ff: int, moe: MoEConfig, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    E = moe.num_experts
    return {
        "router": dense_init(ks[0], d_model, E, dtype),
        "w_gate": jnp.stack([dense_init(jax.random.fold_in(ks[1], e),
                                        d_model, d_ff, dtype)
                             for e in range(E)]),
        "w_up": jnp.stack([dense_init(jax.random.fold_in(ks[2], e),
                                      d_model, d_ff, dtype)
                           for e in range(E)]),
        "w_down": jnp.stack([dense_init(jax.random.fold_in(ks[3], e),
                                        d_ff, d_model, dtype)
                             for e in range(E)]),
    }


def moe_ffn(p: Dict, x: jax.Array, moe: MoEConfig, act: str = "silu",
            drop_free: bool = False, valid=None) -> Tuple[jax.Array, jax.Array]:
    """x: (N, D) token major.  Returns (out (N, D), aux load-balance loss).

    ``valid`` is an optional (N,) token-validity mask (bucketed prefill
    right-pads prompts, runtime/engine.py): invalid tokens are routed to the
    dump row — they consume no expert capacity and contribute nothing, so a
    padded prompt's kept-token set cannot be displaced by its own padding.

    ``drop_free=True`` sets the expert capacity to N (each expert appears at
    most once per token's top-k, so no token can ever be dropped).  Decode
    steps use it (``models.transformer.block_decode``): with the trained
    capacity a token's drop decision would depend on which *other* requests
    it happens to be co-batched with — under continuous batching
    (runtime/engine.py) that would make served outputs a function of
    scheduling, and it is what breaks bit-parity between pooled decode and
    the batch-1 ``greedy_generate`` oracle.  Kept-token values are row-wise
    independent of capacity, so this changes nothing for tokens the trained
    capacity would have kept.

    Scatter/gather ("sort-based") dispatch: tokens are placed into a dense
    (E*C, D) expert buffer by computed slot ids and gathered back after the
    expert FFNs.  Nothing (N, E, C)-sized ever exists — the one-hot-einsum
    dispatch of Mesh-TF materializes exactly that tensor, which at
    mixtral x train_4k is ~40 TB/device (EXPERIMENTS.md Section Perf,
    iteration 2).  Under GSPMD the scatter/gather lower to the expected
    all-to-alls when the expert buffer is expert-sharded.
    """
    N, D = x.shape
    E, K = moe.num_experts, moe.top_k
    # a valid mask means bucketed serving prefill: run drop-free there too —
    # trained capacity would be computed from the *padded* token count, so a
    # prompt's kept-token set (hence its served tokens) would depend on
    # which bucket it landed in
    C = (N if drop_free or valid is not None
         else max(1, int(N * moe.capacity_factor * K / E)))
    if isinstance(p["router"], GriffinWeights):
        gates = griffin_linear(x.astype(jnp.float32), p["router"])
    elif execution_context().use_kernels:
        # upcast the (tiny) router GEMM so gate logits keep full f32
        # precision end-to-end — griffin_linear returns x.dtype, and a bf16
        # round-trip could flip near-tied top_k routing decisions vs the
        # einsum below, which accumulates straight to f32
        gates = griffin_linear(x.astype(jnp.float32),
                               p["router"].astype(jnp.float32))
    else:
        gates = jnp.einsum("nd,de->ne", x, p["router"],
                           preferred_element_type=jnp.float32)
    probs_full = jax.nn.softmax(gates, axis=-1)
    top_p, top_e = jax.lax.top_k(probs_full, K)           # (N, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    e_flat = top_e.reshape(N * K)
    if valid is not None:
        # invalid (pad) tokens route to pseudo-expert E: they sort after
        # every real token, vanish from the capacity counts, and their
        # (garbage) rank is overridden by the keep mask below
        e_flat = jnp.where(jnp.repeat(valid, K), e_flat, E)
    # position of each (token, k) slot within its expert, in token order:
    # rank among equal-expert slots = stable-sort inverse
    order = jnp.argsort(e_flat, stable=True)              # group by expert
    counts = jnp.bincount(e_flat, length=E)
    starts = jnp.cumsum(counts) - counts                  # (E,)
    rank_in_expert = jnp.zeros(N * K, jnp.int32).at[order].set(
        jnp.arange(N * K, dtype=jnp.int32)) - starts[e_flat].astype(jnp.int32)
    keep = rank_in_expert < C
    if valid is not None:
        keep = keep & jnp.repeat(valid, K)
    slot = jnp.where(keep, e_flat * C + rank_in_expert, E * C)  # E*C = dropped
    # scatter tokens into the expert buffer (unique slots: plain set)
    buf = jnp.zeros((E * C + 1, D), x.dtype)
    xk = jnp.broadcast_to(x[:, None], (N, K, D)).reshape(N * K, D)
    # kept slots are unique; dropped tokens pile into the dump row, which is
    # never read (so their gradient is exactly zero, as it must be)
    buf = buf.at[slot].add(xk, mode="drop")
    xe = buf[:E * C].reshape(E, C, D)
    h = act_fn(act)(expert_linear(xe, p["w_gate"])) * \
        expert_linear(xe, p["w_up"])
    ye = expert_linear(h.astype(x.dtype), p["w_down"])
    # gather back and combine with routing weights
    y_buf = jnp.concatenate([ye.reshape(E * C, D),
                             jnp.zeros((1, D), ye.dtype)], axis=0)
    yk = y_buf[slot].reshape(N, K, D).astype(jnp.float32)
    w = (top_p * keep.reshape(N, K)).astype(jnp.float32)
    out = (yk * w[..., None]).sum(axis=1)
    # Switch-style load-balance auxiliary
    me = probs_full.mean(axis=0)
    fe = jnp.bincount(e_flat, length=E).astype(jnp.float32) / (N * K) * E
    aux = (me * fe).sum() * E
    return out.astype(x.dtype), aux.astype(jnp.float32)
