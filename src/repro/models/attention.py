"""Memory-efficient GQA attention in pure JAX (flash-style chunking).

Train/prefill attention never materializes the (S x S) score matrix: the KV
axis is processed in chunks under ``lax.scan`` with an online softmax
(running max / normalizer), so the live footprint is O(S * chunk).  Causal
and sliding-window masking are applied per chunk.  Decode attends one query
against the cache with a length mask.

GQA: queries have H heads, keys/values KVH <= H heads; query heads are
grouped onto kv heads via reshape (no repetition of KV in memory).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _chunk_attn(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
                window: Optional[int], q_offset, kv_offset, kv_valid: int,
                scale: float) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One (q-block, kv-chunk) tile of online softmax.

    q: (B, Sq, KVH, G, hd)   k/v: (B, Sk, KVH, hd)
    Returns (scores_exp @ v, running max, running sum) pieces.
    """
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    qpos = q_offset + jnp.arange(q.shape[1])
    kpos = kv_offset + jnp.arange(k.shape[1])
    mask = (kpos < kv_valid)[None, :] & jnp.ones((q.shape[1], 1), dtype=bool)
    if causal:
        mask = mask & (qpos[:, None] >= kpos[None, :])
    if window is not None:
        mask = mask & (qpos[:, None] - kpos[None, :] < window)
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    m = logits.max(axis=-1)                            # (B,KVH,G,Sq)
    p = jnp.exp(logits - m[..., None])
    p = jnp.where(mask[None, None, None], p, 0.0)
    l = p.sum(axis=-1)
    o = jnp.einsum("bkgqs,bskd->bkgqd", p, v.astype(jnp.float32))
    return o, m, l


def _flash_fwd_scan(qg, k, v, *, causal, window, q_offset, kv_chunk, Sk):
    B, Sq, KVH, G, hd = qg.shape
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    nchunks = k.shape[1] // kv_chunk
    kc = k.reshape(B, nchunks, kv_chunk, KVH, hd).swapaxes(0, 1)
    vc = v.reshape(B, nchunks, kv_chunk, KVH, hd).swapaxes(0, 1)

    def body(carry, kv):
        m_prev, l_prev, acc, idx = carry
        kcur, vcur = kv
        kv_off = idx * kv_chunk
        o, m, l = _chunk_attn(qg, kcur, vcur, causal=causal, window=window,
                              q_offset=q_offset, kv_offset=kv_off,
                              kv_valid=Sk, scale=scale)
        m_new = jnp.maximum(m_prev, m)
        a_prev = jnp.exp(m_prev - m_new)
        a_cur = jnp.exp(m - m_new)
        l_new = l_prev * a_prev + l * a_cur
        acc = acc * a_prev[..., None] + o * a_cur[..., None]
        return (m_new, l_new, acc, idx + 1), None

    m0 = jnp.full((B, KVH, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KVH, G, Sq), jnp.float32)
    acc0 = jnp.zeros((B, KVH, G, Sq, hd), jnp.float32)
    (m_f, l_f, acc, _), _ = jax.lax.scan(body, (m0, l0, acc0, 0), (kc, vc))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]       # (B,KVH,G,Sq,hd)
    lse = m_f + jnp.log(jnp.maximum(l_f, 1e-30))         # (B,KVH,G,Sq)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(qg, k, v, causal, window, q_offset, kv_chunk, sk_valid):
    out, _ = _flash_fwd_scan(qg, k, v, causal=causal, window=window,
                             q_offset=q_offset, kv_chunk=kv_chunk,
                             Sk=sk_valid)
    return out


def _flash_fwd(qg, k, v, causal, window, q_offset, kv_chunk, sk_valid):
    out, lse = _flash_fwd_scan(qg, k, v, causal=causal, window=window,
                               q_offset=q_offset, kv_chunk=kv_chunk,
                               Sk=sk_valid)
    return out, (qg, k, v, out, lse)


def _flash_bwd(causal, window, q_offset, kv_chunk, sk_valid, res, dout):
    """Flash-style backward: recompute per-KV-chunk probabilities from the
    saved log-sum-exp; nothing S^2-sized is ever stored.  This is what keeps
    the train/prefill activation footprint O(S * hd) per layer (EXPERIMENTS
    Section Perf, iteration 1)."""
    qg, k, v, out, lse = res
    B, Sq, KVH, G, hd = qg.shape
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    nchunks = k.shape[1] // kv_chunk
    kc = k.reshape(B, nchunks, kv_chunk, KVH, hd).swapaxes(0, 1)
    vc = v.reshape(B, nchunks, kv_chunk, KVH, hd).swapaxes(0, 1)
    do = dout.astype(jnp.float32)                        # (B,KVH,G,Sq,hd)
    Dv = (do * out).sum(axis=-1)                         # (B,KVH,G,Sq)
    q32 = qg.astype(jnp.float32)
    qpos = q_offset + jnp.arange(Sq)

    def body(carry, kv):
        dq, idx = carry
        kcur, vcur = kv
        kv_off = idx * kv_chunk
        logits = jnp.einsum("bqkgd,bskd->bkgqs", q32,
                            kcur.astype(jnp.float32)) * scale
        kpos = kv_off + jnp.arange(kv_chunk)
        mask = (kpos < sk_valid)[None, :] & jnp.ones((Sq, 1), dtype=bool)
        if causal:
            mask = mask & (qpos[:, None] >= kpos[None, :])
        if window is not None:
            mask = mask & (qpos[:, None] - kpos[None, :] < window)
        p = jnp.exp(logits - lse[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        dv_c = jnp.einsum("bkgqs,bkgqd->bskd", p, do)
        dp = jnp.einsum("bkgqd,bskd->bkgqs", do, vcur.astype(jnp.float32))
        ds = p * (dp - Dv[..., None])
        dq = dq + jnp.einsum("bkgqs,bskd->bqkgd", ds,
                             kcur.astype(jnp.float32)) * scale
        dk_c = jnp.einsum("bkgqs,bqkgd->bskd", ds, q32) * scale
        return (dq, idx + 1), (dk_c, dv_c)

    dq0 = jnp.zeros_like(q32)
    (dq, _), (dks, dvs) = jax.lax.scan(body, (dq0, 0), (kc, vc))
    dk = dks.swapaxes(0, 1).reshape(k.shape)
    dv = dvs.swapaxes(0, 1).reshape(v.shape)
    return dq.astype(qg.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: Optional[int] = None,
              q_offset: int = 0, kv_chunk: int = 1024) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Sk, KVH, hd).  Returns (B, Sq, H, hd).

    Flash-style: online softmax over KV chunks with a custom VJP that
    recomputes chunk probabilities in the backward pass (live footprint
    O(S * chunk) forward AND backward; the S^2 score matrix never exists).
    ``q_offset`` is the absolute position of q[:,0] relative to k[:,0].
    """
    B, Sq, H, hd = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    qg = q.reshape(B, Sq, KVH, G, hd)
    kv_chunk = min(kv_chunk, Sk)
    nchunks = -(-Sk // kv_chunk)
    pad = nchunks * kv_chunk - Sk
    if pad:
        # padded keys are masked by position (kpos >= Sk fails the causal
        # test only when q_offset+Sq <= Sk; mask explicitly via window-safe
        # NEG_INF by extending with +inf positions): simplest is to pad and
        # rely on causal mask when Sk >= Sq + q_offset; otherwise mask here.
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    out = _flash(qg, k, v, causal, window, q_offset, kv_chunk, Sk)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def local_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    window: int, q_chunk: int = 256) -> jax.Array:
    """Banded (block-local) causal attention: each query attends to at most
    ``window`` previous keys.  Exactly linear in S (no masked-out S^2 work):
    the sequence is tiled into window-sized blocks and block i attends only
    to blocks {i-1, i}.  Used by recurrentgemma's local-attention layers.
    """
    B, S, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    W = window
    nb = -(-S // W)
    pad = nb * W - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qb = q.reshape(B, nb, W, KVH, G, hd)
    kb = k.reshape(B, nb, W, KVH, hd)
    vb = v.reshape(B, nb, W, KVH, hd)
    prev_k = jnp.pad(kb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    prev_v = jnp.pad(vb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    kcat = jnp.concatenate([prev_k, kb], axis=2)         # (B, nb, 2W, KVH, hd)
    vcat = jnp.concatenate([prev_v, vb], axis=2)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qc = min(q_chunk, W)
    nqc = W // qc

    def body(_, sub):
        qs, qoff = sub                                   # (B, nb, qc, KVH, G, hd)
        logits = jnp.einsum("bnqkgd,bnskd->bnkgqs", qs, kcat,
                            preferred_element_type=jnp.float32) * scale
        qpos = qoff + jnp.arange(qc)                     # within-block + block
        kpos = jnp.arange(2 * W) - W
        m = (qpos[:, None] >= kpos[None, :]) & \
            (qpos[:, None] - kpos[None, :] < W)
        logits = jnp.where(m[None, None, None, None], logits, NEG_INF)
        # block 0 has no previous block: its kpos < 0 keys are zero padding
        blk_valid = (jnp.arange(nb)[:, None] > 0) | (kpos[None, :] >= 0)
        logits = jnp.where(blk_valid[None, :, None, None, None], logits,
                           NEG_INF)
        p = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bnkgqs,bnskd->bnkgqd", p, vcat.astype(jnp.float32))
        return None, o

    subs = jnp.moveaxis(qb.reshape(B, nb, nqc, qc, KVH, G, hd), 2, 0)
    offs = jnp.arange(nqc) * qc
    _, outs = jax.lax.scan(body, None, (subs, offs))
    # outs: (nqc, B, nb, KVH, G, qc, hd) -> (B, nb, nqc, qc, KVH, G, hd)
    out = jnp.moveaxis(outs, 0, 2).transpose(0, 1, 2, 5, 3, 4, 6)
    out = out.reshape(B, nb * W, H, hd)[:, :S]
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, window: Optional[int] = None
                     ) -> jax.Array:
    """Single-step attention against a (B, S, KVH, hd) cache.

    ``pos`` is the current position (number of valid cache entries) — a
    scalar, or a (B,) vector when each batch row sits at its own position
    (continuous-batching slot pools, runtime/engine.py); for a rolling
    sliding-window cache pass window=None and a fully-valid cache.
    q: (B, 1, H, hd).
    """
    B, _, H, hd = q.shape
    _, S, KVH, _ = k_cache.shape
    G = H // KVH
    qg = q.reshape(B, KVH, G, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                        preferred_element_type=jnp.float32)
    logits = logits / jnp.sqrt(hd).astype(jnp.float32)
    idx = jnp.arange(S)
    if jnp.ndim(pos):                       # per-row positions: (B, S) mask
        valid = idx[None, :] <= pos[:, None]
        if window is not None:
            valid = valid & (idx[None, :] > pos[:, None] - window)
    else:
        valid = idx <= pos
        if window is not None:
            valid = valid & (idx > pos - window)
    logits = jnp.where(valid[None, None, None] if valid.ndim == 1
                       else valid[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)
