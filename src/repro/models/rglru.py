"""RecurrentGemma [arXiv:2402.19427]: RG-LRU recurrent blocks + local
attention (MQA, window 2048) in a (rec, rec, attn) pattern, GeGLU MLPs.

The RG-LRU diagonal linear recurrence is evaluated with
``lax.associative_scan`` (log-depth, fully counted by cost analysis); decode
carries O(1) recurrent + conv state plus a rolling window cache for the
attention layers, which is what makes long_500k decode O(window).

Every weight GEMM goes through ``models.common.griffin_linear``, like the
other families (DESIGN.md Section 4): plain ``x @ w`` outside a
``sparse_execution`` scope, kernel/mesh dispatch inside one.  This is
what lets block-pruned hybrid weights execute (``sparsity.sparsify_params``
already selected rglru's attention/MLP names) and what makes the family
mesh-servable: the SPMD scope's replication constraints live in
``griffin_linear``, and without them GSPMD is free to leave ``k``/``q``
sharded across the rope half-split — a miscompile-prone layout on the
emulated CPU mesh (DESIGN.md Section 10).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import decode_attention, local_attention
from .common import (act_fn, dense_init, griffin_linear, layer_scan,
                     length_mask, paged_view, paged_write, rms_norm, rope,
                     stack_layers, take_last, write_kv_slot)

Params = Dict[str, Any]
LRU_C = 8.0


# ---------------------------------------------------------------------------
# RG-LRU + conv
# ---------------------------------------------------------------------------

def init_rec_block(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    D = cfg.d_model
    R = cfg.lru_width or D
    ks = jax.random.split(key, 8)
    return {
        "ln": jnp.zeros((D,), dt),
        "w_x": dense_init(ks[0], D, R, dt),
        "w_gate": dense_init(ks[1], D, R, dt),
        "conv": (jax.random.normal(ks[2], (cfg.conv_width, R), jnp.float32)
                 * 0.1).astype(dt),
        "w_rg": dense_init(ks[3], R, R, dt),       # recurrence gate
        "w_ig": dense_init(ks[4], R, R, dt),       # input gate
        "lam": jnp.linspace(0.9, 5.0, R).astype(jnp.float32),  # softplus param
        "w_out": dense_init(ks[5], R, D, dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, state=None, lengths=None):
    """Depthwise causal conv along time.  x: (B,S,R), w: (cw,R).
    state: (B, cw-1, R) previous inputs for decode.

    ``lengths``: optional (B,) true lengths of a right-padded batch
    (bucketed prefill).  The conv is causal, so real outputs never see the
    pads — but the carried decode state must be the last ``cw-1`` *real*
    inputs, which sit at positions ``length-cw+1..length-1`` rather than at
    the array tail; they are gathered per row."""
    cw = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(cw))
    if cw == 1:
        new_state = None
    elif lengths is None:
        new_state = xp[:, -(cw - 1):]
    else:
        # xp index of input position p is p + cw - 1 (left pad); want
        # positions length-cw+1..length-1 -> xp indices length..length+cw-2
        idx = lengths[:, None] + jnp.arange(cw - 1)[None, :]
        new_state = jnp.take_along_axis(xp, idx[..., None], axis=1)
    return out.astype(x.dtype), new_state


def _rg_lru(x: jax.Array, p: Params, h0=None, mask=None):
    """x: (B,S,R) -> (B,S,R), h_last.  Diagonal gated linear recurrence:
      log a_t = -c * softplus(lam) * sigmoid(x W_rg)
      h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (sigmoid(x W_ig) * x_t)
    evaluated as an associative scan on (a, b) pairs.

    ``mask``: optional (B, S) validity mask of a right-padded batch
    (bucketed prefill): pad steps run with (a, b) = (1, 0) — an exact
    identity — so ``h_last`` is the state at each row's last real token."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(griffin_linear(xf, p["w_rg"].astype(jnp.float32)))
    i = jax.nn.sigmoid(griffin_linear(xf, p["w_ig"].astype(jnp.float32)))
    log_a = -LRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    if mask is not None:
        m3 = mask[:, :, None]
        a = jnp.where(m3, a, 1.0)
        b = jnp.where(m3, b, 0.0)
    if h0 is not None:
        # fold the carried state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(l, r_):
        a1, b1 = l
        a2, b2 = r_
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rec_mix(cfg: ModelConfig, p: Params, x: jax.Array, state=None,
            mask=None, lengths=None):
    """Recurrent mixing block.  state: (h0 (B,R) f32, conv (B,cw-1,R)).
    ``mask``/``lengths`` describe right padding (bucketed prefill)."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    xr = griffin_linear(h, p["w_x"])
    gate = jax.nn.gelu(griffin_linear(h, p["w_gate"])
                       .astype(jnp.float32)).astype(x.dtype)
    h0, conv_state = (None, None) if state is None else state
    xr, new_conv = _causal_conv(xr, p["conv"], conv_state, lengths=lengths)
    hr, h_last = _rg_lru(xr, p, h0, mask=mask)
    out = griffin_linear(hr * gate, p["w_out"])
    return (x + out).astype(x.dtype), (h_last, new_conv)


# ---------------------------------------------------------------------------
# attention + MLP blocks
# ---------------------------------------------------------------------------

def init_attn_block(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    D, H, KVH, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "ln": jnp.zeros((D,), dt),
        "wq": dense_init(ks[0], D, H * hd, dt),
        "wk": dense_init(ks[1], D, KVH * hd, dt),
        "wv": dense_init(ks[2], D, KVH * hd, dt),
        "wo": dense_init(ks[3], H * hd, D, dt),
    }


def init_mlp(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    return {
        "ln": jnp.zeros((cfg.d_model,), dt),
        "w_gate": dense_init(ks[0], cfg.d_model, cfg.d_ff, dt),
        "w_up": dense_init(ks[1], cfg.d_model, cfg.d_ff, dt),
        "w_down": dense_init(ks[2], cfg.d_ff, cfg.d_model, dt),
    }


def mlp(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    f = act_fn(cfg.act)(griffin_linear(h, p["w_gate"])) * \
        griffin_linear(h, p["w_up"])
    return (x + griffin_linear(f, p["w_down"])).astype(x.dtype)


def attn_mix(cfg: ModelConfig, p: Params, x: jax.Array, positions):
    B, S, D = x.shape
    H, KVH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = rope(griffin_linear(h, p["wq"]).reshape(B, S, H, hd), positions,
             cfg.rope_theta)
    k = rope(griffin_linear(h, p["wk"]).reshape(B, S, KVH, hd), positions,
             cfg.rope_theta)
    v = griffin_linear(h, p["wv"]).reshape(B, S, KVH, hd)
    o = local_attention(q, k, v, window=cfg.window,
                        q_chunk=min(cfg.kv_chunk, cfg.window))
    return (x + griffin_linear(o.reshape(B, S, -1), p["wo"])
            ).astype(x.dtype), (k, v)


def attn_decode(cfg: ModelConfig, p: Params, x: jax.Array, kc, vc, pos):
    """One-token local attention against a rolling window cache.  ``pos``
    is a scalar, or a (B,) vector of per-row positions (continuous-batching
    slot pools, runtime/engine.py)."""
    B = x.shape[0]
    H, KVH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    per_slot = pos.ndim > 0
    posv = pos[:, None] if per_slot else pos[None]
    q = rope(griffin_linear(h, p["wq"]).reshape(B, 1, H, hd), posv,
             cfg.rope_theta)
    k = rope(griffin_linear(h, p["wk"]).reshape(B, 1, KVH, hd), posv,
             cfg.rope_theta)
    v = griffin_linear(h, p["wv"]).reshape(B, 1, KVH, hd)
    clen = kc.shape[1]
    slot = pos % clen
    kc = write_kv_slot(kc, k, slot)
    vc = write_kv_slot(vc, v, slot)
    eff = jnp.minimum(pos, clen - 1)
    o = decode_attention(q, kc, vc, eff, window=None)
    return (x + griffin_linear(o.reshape(B, 1, -1), p["wo"])
            ).astype(x.dtype), kc, vc


def attn_decode_paged(cfg: ModelConfig, p: Params, x: jax.Array, kc, vc,
                      kscale, vscale, pages, pos):
    """Paged twin of :func:`attn_decode` (runtime/paging.py).  Paging only
    activates when ``window >= cache_len`` (discovery rule), where the
    rolling slot/eff-pos algebra of the fixed path reduces for live rows to
    write-at-``pos`` / attend-to-``pos`` — bit-identical on the gathered
    view.  ``kscale``/``vscale`` are None for fp32 pools."""
    B = x.shape[0]
    H, KVH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    per_slot = pos.ndim > 0
    posv = pos[:, None] if per_slot else pos[None]
    q = rope(griffin_linear(h, p["wq"]).reshape(B, 1, H, hd), posv,
             cfg.rope_theta)
    k = rope(griffin_linear(h, p["wk"]).reshape(B, 1, KVH, hd), posv,
             cfg.rope_theta)
    v = griffin_linear(h, p["wv"]).reshape(B, 1, KVH, hd)
    page_size = kc.shape[1]
    kc, kscale = paged_write(kc, kscale, pages, k, pos, page_size)
    vc, vscale = paged_write(vc, vscale, pages, v, pos, page_size)
    o = decode_attention(q, paged_view(kc, kscale, pages, x.dtype),
                         paged_view(vc, vscale, pages, x.dtype), pos,
                         window=None)
    return (x + griffin_linear(o.reshape(B, 1, -1), p["wo"])
            ).astype(x.dtype), kc, vc, kscale, vscale


# ---------------------------------------------------------------------------
# model assembly: scan over (rec, rec, attn) groups + rec tail
# ---------------------------------------------------------------------------

def _group_counts(cfg: ModelConfig) -> Tuple[int, int]:
    plen = len(cfg.block_pattern)          # 3
    groups = cfg.num_layers // plen        # 12
    tail = cfg.num_layers - groups * plen  # 2 (rec, rec)
    return groups, tail


def init_params(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    groups, tail = _group_counts(cfg)
    ks = jax.random.split(key, 6)

    def init_group(k):
        kk = jax.random.split(k, 6)
        return {
            "rec1": init_rec_block(cfg, kk[0]), "mlp1": init_mlp(cfg, kk[1]),
            "rec2": init_rec_block(cfg, kk[2]), "mlp2": init_mlp(cfg, kk[3]),
            "attn": init_attn_block(cfg, kk[4]), "mlp3": init_mlp(cfg, kk[5]),
        }

    def init_tail(k):
        kk = jax.random.split(k, 2)
        return {"rec": init_rec_block(cfg, kk[0]), "mlp": init_mlp(cfg, kk[1])}

    return {
        "embed": dense_init(ks[0], cfg.vocab_size, cfg.d_model, dt, scale=1.0),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
        "groups": stack_layers(init_group, ks[1], groups),
        "tail": stack_layers(init_tail, ks[2], tail),
        "head": dense_init(ks[3], cfg.d_model, cfg.vocab_size, dt),
    }


def forward_hidden(cfg: ModelConfig, params: Params, tokens: jax.Array):
    x = params["embed"][tokens]
    positions = jnp.arange(tokens.shape[1])

    def group(x, gp):
        x, _ = rec_mix(cfg, gp["rec1"], x)
        x = mlp(cfg, gp["mlp1"], x)
        x, _ = rec_mix(cfg, gp["rec2"], x)
        x = mlp(cfg, gp["mlp2"], x)
        x, _ = attn_mix(cfg, gp["attn"], x, positions)
        x = mlp(cfg, gp["mlp3"], x)
        return x, None

    def tail(x, tp):
        x, _ = rec_mix(cfg, tp["rec"], x)
        x = mlp(cfg, tp["mlp"], x)
        return x, None

    gfn = jax.checkpoint(group) if cfg.remat else group
    x, _ = layer_scan(cfg.scan_layers, gfn, x, params["groups"])
    x, _ = layer_scan(cfg.scan_layers, tail, x, params["tail"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, length: int) -> Params:
    groups, tail = _group_counts(cfg)
    R = cfg.lru_width or cfg.d_model
    cw = cfg.conv_width
    clen = min(length, cfg.window)
    dt = jnp.dtype(cfg.dtype)
    z_h = jnp.zeros((groups, 2, batch, R), jnp.float32)
    z_conv = jnp.zeros((groups, 2, batch, cw - 1, R), dt)
    return {
        "rec_h": z_h, "rec_conv": z_conv,
        "tail_h": jnp.zeros((tail, batch, R), jnp.float32),
        "tail_conv": jnp.zeros((tail, batch, cw - 1, R), dt),
        "k": jnp.zeros((groups, batch, clen, cfg.num_kv_heads, cfg.hd), dt),
        "v": jnp.zeros((groups, batch, clen, cfg.num_kv_heads, cfg.hd), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array,
            cache_len=None, lengths=None):
    """``lengths``: optional (B,) true prompt lengths of a right-padded
    batch (bucketed prefill).  Local attention is causal (real positions
    never see pads); the recurrent/conv state updates are masked to
    identity at pads; pad K/V rows sit in slots ``length..S-1`` where the
    decode loop overwrites slot ``pos % clen`` before its position mask
    admits it (requires the padded length to fit the window cache — the
    bucket policy in runtime/engine.py clamps to it)."""
    B, S = tokens.shape
    positions = jnp.arange(S)
    clen = min(cache_len or S, cfg.window)
    mask = None if lengths is None else length_mask(lengths, S)
    if lengths is not None:
        assert S <= clen, "bucketed prefill must fit the window cache"

    def group(x, gp):
        x, st1 = rec_mix(cfg, gp["rec1"], x, mask=mask, lengths=lengths)
        x = mlp(cfg, gp["mlp1"], x)
        x, st2 = rec_mix(cfg, gp["rec2"], x, mask=mask, lengths=lengths)
        x = mlp(cfg, gp["mlp2"], x)
        x, (k, v) = attn_mix(cfg, gp["attn"], x, positions)
        x = mlp(cfg, gp["mlp3"], x)
        # keep the last window of K/V, rolled so decode can continue writing
        k, v = k[:, -clen:], v[:, -clen:]
        return x, (jnp.stack([st1[0], st2[0]]),
                   jnp.stack([st1[1], st2[1]]), k, v)

    def tail(x, tp):
        x, st = rec_mix(cfg, tp["rec"], x, mask=mask, lengths=lengths)
        x = mlp(cfg, tp["mlp"], x)
        return x, st

    x = params["embed"][tokens]
    x, (rec_h, rec_conv, ks, vs) = layer_scan(cfg.scan_layers, group, x,
                                              params["groups"])
    x, (tail_h, tail_conv) = layer_scan(cfg.scan_layers, tail, x,
                                        params["tail"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if lengths is None:
        last, pos = x[:, -1], jnp.asarray(S - 1, jnp.int32)
    else:
        last = take_last(x, lengths)
        pos = (lengths - 1).astype(jnp.int32)          # per-row (B,) vector
    logits = griffin_linear(last, params["head"])
    # roll the window cache so that slot (pos % clen) is consistent; short
    # prompts pad the tail so the cache is always exactly clen long — the
    # arena shape init_cache declares (decode writes slots S, S+1, ... and
    # the eff-pos mask hides the padding, exactly as in models/transformer)
    if ks.shape[2] < clen:
        pad = clen - ks.shape[2]
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    shift = (S % clen) if S >= clen else 0
    ks = jnp.roll(ks, shift, axis=2)
    vs = jnp.roll(vs, shift, axis=2)
    cache = {"rec_h": rec_h, "rec_conv": rec_conv, "tail_h": tail_h,
             "tail_conv": tail_conv, "k": ks, "v": vs, "pos": pos}
    return cache, logits


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                token: jax.Array):
    x = params["embed"][token]
    pos = cache["pos"] + 1
    # "pages" marks a paged attention cache (runtime/paging.py): k/v become
    # (groups, num_pages, page_size, KVH, hd) pools indexed through the slot
    # page table; the recurrent/conv state leaves are untouched.
    paged = "pages" in cache
    pages = cache.get("pages")
    int8 = "k_scale" in cache

    def group(x, xs):
        if paged and int8:
            gp, rh, rconv, kc, vc, ksc, vsc = xs
        else:
            gp, rh, rconv, kc, vc = xs
            ksc = vsc = None
        x, st1 = rec_mix(cfg, gp["rec1"], x, state=(rh[0], rconv[0]))
        x = mlp(cfg, gp["mlp1"], x)
        x, st2 = rec_mix(cfg, gp["rec2"], x, state=(rh[1], rconv[1]))
        x = mlp(cfg, gp["mlp2"], x)
        if paged:
            x, kc, vc, ksc, vsc = attn_decode_paged(
                cfg, gp["attn"], x, kc, vc, ksc, vsc, pages, pos)
        else:
            x, kc, vc = attn_decode(cfg, gp["attn"], x, kc, vc, pos)
        x = mlp(cfg, gp["mlp3"], x)
        st = (jnp.stack([st1[0], st2[0]]), jnp.stack([st1[1], st2[1]]))
        return x, (st + (kc, vc, ksc, vsc) if paged and int8
                   else st + (kc, vc))

    def tail(x, xs):
        tp, rh, rconv = xs
        x, st = rec_mix(cfg, tp["rec"], x, state=(rh, rconv))
        x = mlp(cfg, tp["mlp"], x)
        return x, st

    xs = ((params["groups"], cache["rec_h"], cache["rec_conv"],
           cache["k"], cache["v"], cache["k_scale"], cache["v_scale"])
          if paged and int8
          else (params["groups"], cache["rec_h"], cache["rec_conv"],
                cache["k"], cache["v"]))
    x, ys = layer_scan(cfg.scan_layers, group, x, xs)
    x, (tail_h, tail_conv) = layer_scan(
        cfg.scan_layers, tail, x,
        (params["tail"], cache["tail_h"], cache["tail_conv"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = griffin_linear(x[:, 0], params["head"])
    out = {"tail_h": tail_h, "tail_conv": tail_conv, "pos": pos}
    if paged and int8:
        (out["rec_h"], out["rec_conv"], out["k"], out["v"],
         out["k_scale"], out["v_scale"]) = ys
    else:
        out["rec_h"], out["rec_conv"], out["k"], out["v"] = ys
    if paged:
        out["pages"] = pages
    return logits, out
