"""Decoder-only transformer family: dense (stablelm, command-r-plus,
llama3.2, minitron), VLM backbone (chameleon, early-fusion token ids), and
MoE (mixtral with SWA, llama4-scout top-1).

All layer stacks are ``lax.scan`` over stacked parameters so HLO size and
compile time are depth-independent at 100B scale; rematerialization is a
config knob.  Cross entropy is computed in sequence chunks so the
(B, S, vocab) logits tensor is never materialized (see models.losses).

Every weight GEMM goes through ``models.common.griffin_linear``: plain
arrays execute as ``x @ w`` (or the dense Pallas kernel under a
``sparse_execution`` scope), block-compacted ``GriffinWeights`` leaves
(from ``repro.sparsity.sparsify_params``) execute through the Sparse.B /
dual kernels — stacked per-layer compacted weights ride the same
``lax.scan`` (DESIGN.md Section 4).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import attention, decode_attention
from .common import (act_fn, dense_init, griffin_linear, layer_scan,
                     length_mask, paged_view, paged_write, remat_fn,
                     rms_norm, rope, stack_layers, take_last, write_kv_slot)
from .moe import init_moe, moe_ffn

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    D, H, KVH, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    p: Params = {
        "ln1": jnp.zeros((D,), dt), "ln2": jnp.zeros((D,), dt),
        "wq": dense_init(ks[0], D, H * hd, dt),
        "wk": dense_init(ks[1], D, KVH * hd, dt),
        "wv": dense_init(ks[2], D, KVH * hd, dt),
        "wo": dense_init(ks[3], H * hd, D, dt),
    }
    if cfg.qk_norm:
        p["qn"] = jnp.zeros((hd,), dt)
        p["kn"] = jnp.zeros((hd,), dt)
    if cfg.moe:
        p["moe"] = init_moe(ks[4], D, cfg.d_ff, cfg.moe, dt)
    else:
        p["w_gate"] = dense_init(ks[5], D, cfg.d_ff, dt)
        p["w_up"] = dense_init(ks[6], D, cfg.d_ff, dt)
        p["w_down"] = dense_init(ks[7], cfg.d_ff, D, dt)
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    params: Params = {
        "embed": dense_init(k_emb, cfg.vocab_size, cfg.d_model, dt, scale=1.0),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
        "layers": stack_layers(functools.partial(_init_layer, cfg),
                               k_layers, cfg.num_layers),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(k_head, cfg.d_model, cfg.vocab_size, dt)
    return params


def unembed(cfg: ModelConfig, params: Params) -> jax.Array:
    return params["embed"].T if cfg.tie_embeddings else params["head"]


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _ffn(cfg: ModelConfig, p: Params, x: jax.Array, decode: bool = False,
         valid=None) -> Tuple[jax.Array, jax.Array]:
    if cfg.moe:
        B, S, D = x.shape
        out, aux = moe_ffn(p["moe"], x.reshape(B * S, D), cfg.moe, cfg.act,
                           drop_free=decode,
                           valid=None if valid is None
                           else valid.reshape(B * S))
        return out.reshape(B, S, D), aux
    h = act_fn(cfg.act)(griffin_linear(x, p["w_gate"])) * \
        griffin_linear(x, p["w_up"])
    return griffin_linear(h, p["w_down"]).astype(x.dtype), \
        jnp.zeros((), jnp.float32)


def _qkv(cfg: ModelConfig, p: Params, x: jax.Array, positions: jax.Array):
    B, S, D = x.shape
    H, KVH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = griffin_linear(x, p["wq"]).reshape(B, S, H, hd)
    k = griffin_linear(x, p["wk"]).reshape(B, S, KVH, hd)
    v = griffin_linear(x, p["wv"]).reshape(B, S, KVH, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["qn"], cfg.norm_eps)
        k = rms_norm(k, p["kn"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def block_train(cfg: ModelConfig, p: Params, x: jax.Array,
                positions: jax.Array, return_kv: bool = False, valid=None):
    """Full-sequence block (train / prefill).  ``valid`` is the optional
    (B, S) right-pad mask of the bucketed-prefill path: causal attention
    already keeps pads out of real positions (pads sit *after* every real
    token), so only the MoE dispatch needs it (pads must not consume expert
    capacity)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qkv(cfg, p, h, positions)
    o = attention(q, k, v, causal=True, window=cfg.window,
                  kv_chunk=cfg.kv_chunk)
    B, S, _, _ = q.shape
    x = x + griffin_linear(o.reshape(B, S, -1), p["wo"]).astype(x.dtype)
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    f, aux = _ffn(cfg, p, h2, valid=valid)
    x = (x + f).astype(x.dtype)
    return (x, aux, (k, v)) if return_kv else (x, aux)


def block_decode(cfg: ModelConfig, p: Params, x: jax.Array, k_cache, v_cache,
                 pos, cache_len: int):
    """One-token block against a (B, S_cache, KVH, hd) cache; returns the
    updated cache slices.  Sliding-window archs use a rolling cache.

    ``pos`` is a scalar (lockstep batch, greedy_generate) or a (B,) vector
    of per-row positions (continuous-batching slot pools,
    runtime/engine.py): each row ropes, writes and masks at its own
    position; with equal entries the vector path is bit-identical to the
    scalar one (every op below is row-wise)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    per_slot = pos.ndim > 0
    q, k, v = _qkv(cfg, p, h,
                   positions=pos[:, None] if per_slot else pos[None])
    rolling = cfg.window is not None and cache_len <= cfg.window
    slot = jnp.where(rolling, pos % cache_len, jnp.minimum(pos, cache_len - 1))
    k_cache = write_kv_slot(k_cache, k, slot)
    v_cache = write_kv_slot(v_cache, v, slot)
    # valid length: rolling caches become fully valid once wrapped
    eff_pos = jnp.where(rolling, jnp.minimum(pos, cache_len - 1), pos)
    win = None if rolling else cfg.window
    o = decode_attention(q, k_cache, v_cache, eff_pos, window=win)
    B = x.shape[0]
    x = x + griffin_linear(o.reshape(B, 1, -1), p["wo"]).astype(x.dtype)
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    f, _ = _ffn(cfg, p, h2, decode=True)
    return (x + f).astype(x.dtype), k_cache, v_cache


def block_decode_paged(cfg: ModelConfig, p: Params, x: jax.Array, k_pool,
                       v_pool, k_scale, v_scale, pages, pos, page_size: int):
    """One-token block against paged KV pools (runtime/paging.py).

    Paging only activates when the arch has no effective sliding window at
    this cache length (discovery rule in runtime/paging.py), so the fixed
    path's rolling/eff-pos algebra collapses for every live row
    (``pos < max_pages * page_size``) to: write at ``pos``, attend with
    ``window=None`` — bit-identical to :func:`block_decode` on the gathered
    view.  ``k_scale``/``v_scale`` are None for fp32 pools."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    per_slot = pos.ndim > 0
    q, k, v = _qkv(cfg, p, h,
                   positions=pos[:, None] if per_slot else pos[None])
    k_pool, k_scale = paged_write(k_pool, k_scale, pages, k, pos, page_size)
    v_pool, v_scale = paged_write(v_pool, v_scale, pages, v, pos, page_size)
    kc = paged_view(k_pool, k_scale, pages, x.dtype)
    vc = paged_view(v_pool, v_scale, pages, x.dtype)
    o = decode_attention(q, kc, vc, pos, window=None)
    B = x.shape[0]
    x = x + griffin_linear(o.reshape(B, 1, -1), p["wo"]).astype(x.dtype)
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    f, _ = _ffn(cfg, p, h2, decode=True)
    return (x + f).astype(x.dtype), k_pool, v_pool, k_scale, v_scale


# ---------------------------------------------------------------------------
# model-level functions
# ---------------------------------------------------------------------------

def forward_hidden(cfg: ModelConfig, params: Params, tokens: jax.Array,
                   return_kv: bool = False, lengths=None):
    """Embed + scan over layers.  Returns final hidden (and per-layer K/V
    stacked over layers when ``return_kv``).  ``lengths``: optional (B,)
    true prompt lengths of a right-padded batch (bucketed prefill)."""
    x = params["embed"][tokens]
    positions = jnp.arange(tokens.shape[1])
    aux0 = jnp.zeros((), jnp.float32)
    valid = (None if lengths is None
             else length_mask(lengths, tokens.shape[1]))

    def body(carry, lp):
        x, aux = carry
        if return_kv:
            x, a, kv = block_train(cfg, lp, x, positions, return_kv=True,
                                   valid=valid)
            return (x, aux + a), kv
        x, a = block_train(cfg, lp, x, positions, valid=valid)
        return (x, aux + a), None

    fn = remat_fn(cfg, body)
    (x, aux), kvs = layer_scan(cfg.scan_layers, fn, (x, aux0),
                               params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return (x, aux, kvs) if return_kv else (x, aux)


def init_cache(cfg: ModelConfig, batch: int, length: int) -> Params:
    """Zeroed KV cache.  Sliding-window archs cap the cache at the window
    (rolling buffer), which is what makes long_500k decode O(window)."""
    clen = min(length, cfg.window) if cfg.window else length
    dt = jnp.dtype(cfg.dtype)
    shape = (cfg.num_layers, batch, clen, cfg.num_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
            "pos": jnp.zeros((), jnp.int32)}


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array,
            cache_len: Optional[int] = None,
            lengths: Optional[jax.Array] = None) -> Tuple[Params, jax.Array]:
    """Process a prompt, build the cache, return (cache, last-token logits).

    ``lengths``: optional (B,) true prompt lengths of a right-padded batch
    (bucketed prefill, DESIGN.md Section 9).  Pad K/V rows land in cache
    slots ``length..S-1`` — dead weight the decode loop overwrites slot
    ``pos`` *before* its position mask admits it, so they are never read.
    Requires the padded length to fit the cache (the bucket policy in
    runtime/engine.py clamps to it)."""
    B, S = tokens.shape
    x, _, (ks, vs) = forward_hidden(cfg, params, tokens, return_kv=True,
                                    lengths=lengths)
    clen = cache_len or S
    clen = min(clen, cfg.window) if cfg.window else clen
    if clen >= S:
        pad = clen - S
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    else:  # keep the last window
        assert lengths is None, "bucketed prefill must fit the cache window"
        ks, vs = ks[:, :, S - clen:], vs[:, :, S - clen:]
    if lengths is None:
        last, pos = x[:, -1], jnp.asarray(S - 1, jnp.int32)
    else:
        last = take_last(x, lengths)
        pos = (lengths - 1).astype(jnp.int32)          # per-row (B,) vector
    logits = griffin_linear(last, unembed(cfg, params))
    cache = {"k": ks, "v": vs, "pos": pos}
    return cache, logits


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                token: jax.Array) -> Tuple[jax.Array, Params]:
    """One decode step for the whole batch.  token: (B, 1) int32.

    A ``"pages"`` key marks a paged cache (runtime/paging.py): ``k``/``v``
    are then (L, num_pages, page_size, KVH, hd) pools indexed through the
    per-slot page table, with optional ``k_scale``/``v_scale`` leaves for
    int8 pools."""
    x = params["embed"][token]
    pos = cache["pos"] + 1
    if "pages" in cache:
        return _decode_step_paged(cfg, params, cache, x, pos)
    clen = cache["k"].shape[2]

    def body(x, xs):
        lp, kc, vc = xs
        x, kc, vc = block_decode(cfg, lp, x, kc, vc, pos, clen)
        return x, (kc, vc)

    x, (ks, vs) = layer_scan(cfg.scan_layers, body, x,
                             (params["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = griffin_linear(x[:, 0], unembed(cfg, params))
    return logits, {"k": ks, "v": vs, "pos": pos}


def _decode_step_paged(cfg: ModelConfig, params: Params, cache: Params,
                       x: jax.Array, pos: jax.Array) -> Tuple[jax.Array, Params]:
    pages = cache["pages"]
    page_size = cache["k"].shape[2]
    int8 = "k_scale" in cache

    def body(x, xs):
        if int8:
            lp, kp, vp, ks_, vs_ = xs
        else:
            lp, kp, vp = xs
            ks_ = vs_ = None
        x, kp, vp, ks_, vs_ = block_decode_paged(
            cfg, lp, x, kp, vp, ks_, vs_, pages, pos, page_size)
        return x, ((kp, vp, ks_, vs_) if int8 else (kp, vp))

    xs = ((params["layers"], cache["k"], cache["v"],
           cache["k_scale"], cache["v_scale"]) if int8
          else (params["layers"], cache["k"], cache["v"]))
    x, ys = layer_scan(cfg.scan_layers, body, x, xs)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = griffin_linear(x[:, 0], unembed(cfg, params))
    out = {"pos": pos, "pages": pages}
    if int8:
        out["k"], out["v"], out["k_scale"], out["v_scale"] = ys
    else:
        out["k"], out["v"] = ys
    return logits, out
