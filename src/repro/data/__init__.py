from .pipeline import CorpusDataset, DataConfig, Prefetcher, make_iterator, synth_batch
