"""Deterministic sharded synthetic data pipeline.

Every batch is a pure function of (seed, step, shard) — restarts and elastic
re-sharding replay identical data with no coordination (the property the
checkpoint/restart tests rely on).  A background-thread prefetcher overlaps
host batch synthesis with device steps.  Real-text mode packs a byte corpus
into fixed-length sequences with the same determinism.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np

from ..configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    num_shards: int = 1
    shard_id: int = 0
    corpus: Optional[str] = None      # path to a text file (byte-level)


def _rng_for(seed: int, step: int, shard: int) -> np.random.Generator:
    return np.random.Generator(np.random.Philox(key=seed,
                                                counter=[0, 0, step, shard]))


def synth_batch(cfg: ModelConfig, shape: ShapeConfig, dc: DataConfig,
                step: int) -> Dict[str, np.ndarray]:
    """Zipf-ish token stream (heavy-tailed like natural text)."""
    rng = _rng_for(dc.seed, step, dc.shard_id)
    b = shape.global_batch // dc.num_shards
    s = shape.seq_len
    # heavy-tailed ids; reserve 0 as padding
    u = rng.random((b, s + 1))
    toks = (np.power(u, 3.0) * (cfg.vocab_size - 2)).astype(np.int32) + 1
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}
    if cfg.is_encdec:
        batch["frames"] = rng.standard_normal(
            (b, cfg.enc_frames, cfg.d_model)).astype(np.float32)
    return batch


class CorpusDataset:
    """Byte-level packing of a real text corpus, deterministically sharded."""

    def __init__(self, path: str, cfg: ModelConfig):
        with open(path, "rb") as f:
            data = np.frombuffer(f.read(), dtype=np.uint8)
        self.data = (data.astype(np.int32) % (cfg.vocab_size - 2)) + 1
        self.cfg = cfg

    def batch(self, shape: ShapeConfig, dc: DataConfig, step: int
              ) -> Dict[str, np.ndarray]:
        rng = _rng_for(dc.seed, step, dc.shard_id)
        b = shape.global_batch // dc.num_shards
        s = shape.seq_len
        starts = rng.integers(0, max(len(self.data) - s - 1, 1), size=b)
        toks = np.stack([self.data[st:st + s + 1] for st in starts])
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}
        if self.cfg.is_encdec:
            batch["frames"] = rng.standard_normal(
                (b, self.cfg.enc_frames, self.cfg.d_model)).astype(np.float32)
        return batch


class Prefetcher:
    """Background-thread prefetch of host batches."""

    def __init__(self, make_batch, start_step: int = 0, depth: int = 2):
        self._make = make_batch
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put(self._make(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __next__(self):
        return self._q.get()

    def close(self) -> None:
        self._stop.set()


def make_iterator(cfg: ModelConfig, shape: ShapeConfig, dc: DataConfig,
                  start_step: int = 0) -> Prefetcher:
    ds = CorpusDataset(dc.corpus, cfg) if dc.corpus else None

    def make(step: int):
        if ds is not None:
            return ds.batch(shape, dc, step)
        return synth_batch(cfg, shape, dc, step)

    return Prefetcher(make, start_step)
