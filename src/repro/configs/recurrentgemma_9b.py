"""recurrentgemma-9b [arXiv:2402.19427]: 38 blocks d=4096, pattern
(rec, rec, attn) — RG-LRU recurrent blocks + local attention (window 2048,
MQA kv=1), d_ff=12288 (GeGLU), lru_width=4096."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    d_ff=12288, vocab_size=256000, head_dim=256, act="gelu",
    window=2048, block_pattern=("rec", "rec", "attn"), lru_width=4096,
))
