"""Assigned-architecture configs (exact published dims) + shapes registry."""
from .base import (ModelConfig, MoEConfig, ShapeConfig, SHAPES,
                   all_configs, applicable_shapes, get_config, register)

__all__ = ["ModelConfig", "MoEConfig", "ShapeConfig", "SHAPES",
           "all_configs", "applicable_shapes", "get_config", "register"]
