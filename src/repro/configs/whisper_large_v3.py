"""whisper-large-v3 [arXiv:2212.04356]: enc-dec, 32+32L d=1280 20H
d_ff=5120 vocab=51866.  The conv audio frontend is a STUB: input_specs()
provides precomputed frame embeddings (1500 frames post-conv)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-large-v3", family="audio",
    num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
    d_ff=5120, vocab_size=51866, act="gelu",
    encoder_layers=32, enc_frames=1500,
))
