"""chameleon-34b [arXiv:2405.09818]: 48L d=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 (early fusion: VQ image tokens share the vocab; the VQ
tokenizer frontend is a stub — inputs are token ids).  QK-norm."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="chameleon-34b", family="vlm",
    num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22016, vocab_size=65536, qk_norm=True,
))
