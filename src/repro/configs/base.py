"""Model / shape configuration system.

``ModelConfig`` covers every assigned architecture family; each
``configs/<id>.py`` instantiates the exact published dims and a ``reduced``
variant for CPU smoke tests.  ``SHAPES`` is the assigned input-shape set;
``applicable_shapes(cfg)`` encodes the assignment rules (long_500k only for
sub-quadratic archs, decode only for archs with a decoder).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | vlm | ssm | audio | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads
    moe: Optional[MoEConfig] = None
    window: Optional[int] = None        # sliding-window attention
    qk_norm: bool = False               # chameleon
    tie_embeddings: bool = False
    act: str = "silu"
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    # hybrid (recurrentgemma): pattern of block kinds, tiled over depth
    block_pattern: Tuple[str, ...] = ()          # e.g. ("rec","rec","attn")
    lru_width: int = 0                           # 0 -> d_model
    conv_width: int = 4
    # ssm (xlstm): blocks per scan group, e.g. 7 mLSTM + 1 sLSTM
    xlstm_pattern: Tuple[str, ...] = ()          # e.g. ("m",)*7 + ("s",)
    proj_factor: float = 2.0                     # mLSTM up-projection
    # enc-dec (whisper)
    encoder_layers: int = 0
    enc_frames: int = 1500
    # runtime knobs
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "none"  # none=save nothing | dots=save matmul outputs
    scan_layers: bool = True    # False: unrolled (roofline cost pass only)
    loss_chunk: int = 512       # sequence-chunked cross entropy
    kv_chunk: int = 512         # attention chunking (flash fwd/bwd transient size)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """Can decode at 500k context: recurrent state and/or bounded-window
        attention only."""
        if self.family in ("ssm",):
            return True
        if self.family == "hybrid":
            return True                  # RG-LRU + local attention
        return self.window is not None   # SWA (mixtral)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        r = dataclasses.replace(
            self, name=self.name + "-smoke",
            num_layers=max(2, len(self.block_pattern) or 2),
            d_model=64,
            num_heads=4, num_kv_heads=min(4, max(1, self.num_kv_heads)),
            head_dim=16,
            d_ff=128 if self.d_ff else 0, vocab_size=128,
            window=min(self.window, 32) if self.window else None,
            moe=MoEConfig(4, self.moe.top_k) if self.moe else None,
            encoder_layers=2 if self.encoder_layers else 0,
            enc_frames=8 if self.is_encdec else self.enc_frames,
            lru_width=64 if self.family == "hybrid" else 0,
            dtype="float32", remat=False, loss_chunk=32, kv_chunk=16,
        )
        if self.xlstm_pattern:
            r = dataclasses.replace(r, xlstm_pattern=("m", "s"),
                                    num_layers=2)
        if self.block_pattern:
            r = dataclasses.replace(r, num_layers=3)
        return r


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> List[str]:
    """Assignment rules: long_500k needs sub-quadratic attention; decode
    shapes need a decoder (all our archs have one)."""
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        shapes.append("long_500k")
    return shapes


_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def all_configs() -> Dict[str, ModelConfig]:
    if not _REGISTRY:
        _load_all()
    return dict(_REGISTRY)


def _load_all() -> None:
    from . import (stablelm_1_6b, command_r_plus_104b, llama3_2_1b,       # noqa
                   minitron_8b, mixtral_8x7b, llama4_scout_17b_a16e,
                   chameleon_34b, xlstm_1_3b, whisper_large_v3,
                   recurrentgemma_9b)
