"""llama4-scout-17b-a16e [hf:meta-llama/Llama-4-Scout-17B-16E]: 48L d=5120
40H (GQA kv=8) d_ff=8192 (per expert), MoE 16 experts top-1, early fusion
(modality frontend stubbed: text/VQ tokens)."""
from .base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    moe=MoEConfig(num_experts=16, top_k=1), rope_theta=500000.0,
))
