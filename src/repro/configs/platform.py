"""Platform selection + XLA flags for the serving stack.

One place answers "what hardware are we on and how should the Pallas
kernels lower there?" — the style of the bayespec ``set_platform`` helper
and the olmax XLA-flag launch scripts (SNIPPETS.md): tiny functions that
mutate ``jax.config`` / ``XLA_FLAGS`` *before* the backend initializes,
plus pure queries the dispatch layer consults at trace time.

Lowering map (``kernel_lowering``):

  tpu -> "mosaic"     the native Pallas TPU path the kernels target
  gpu -> "triton"     staged: Pallas lowers TPU-style kernels to Triton via
                      ``pallas_call``'s GPU backend; the scalar-prefetch
                      grid specs in kernels/ are the TPU dialect, so the
                      GPU port lands behind this switch (gpu_xla_flags()
                      already carries the Triton-GEMM flags it will want)
  cpu -> "interpret"  ``pallas_call(interpret=True)`` — the CI / emulated
                      mesh path; ``kernel_interpret()`` is how
                      ``models.common.griffin_linear`` decides to force
                      interpret mode for the shard_map'd kernel calls
                      (DESIGN.md Section 10)

Environment overrides: ``GRIFFIN_PLATFORM`` picks the platform without a
code change; ``set_host_device_count`` is the in-process twin of the CI
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` export.
"""
from __future__ import annotations

import os
import warnings
from typing import Optional

# staged GPU performance flags (jax.readthedocs.io gpu_performance_tips,
# via the bayespec snippet): applied by set_platform("gpu") so the future
# Triton lowering starts from a tuned baseline
GPU_XLA_FLAGS = (
    "--xla_gpu_triton_gemm_any=True",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)

_LOWERING = {"tpu": "mosaic", "gpu": "triton", "cpu": "interpret"}


def _append_xla_flags(flags) -> None:
    cur = os.environ.get("XLA_FLAGS", "")
    new = [f for f in flags if f.split("=")[0] not in cur]
    if new:
        os.environ["XLA_FLAGS"] = " ".join([cur, *new]).strip()


def resolve_platform(platform: Optional[str] = None) -> str:
    """'cpu' | 'gpu' | 'tpu': the explicit argument, else the
    ``GRIFFIN_PLATFORM`` env var, else whatever backend jax initialized."""
    platform = platform or os.environ.get("GRIFFIN_PLATFORM")
    if platform:
        platform = platform.lower()
        if platform not in _LOWERING:
            raise ValueError(f"unknown platform {platform!r} "
                             f"(known: {sorted(_LOWERING)})")
        return platform
    import jax
    return jax.default_backend()


def set_platform(platform: Optional[str] = None) -> str:
    """Pin jax to a platform and stage its XLA flags; returns the choice.

    Call before the first jax computation (backend selection is
    process-global, exactly as in the bayespec helper).  ``None`` resolves
    from ``GRIFFIN_PLATFORM`` / the default backend, so launch scripts can
    call this unconditionally.
    """
    import jax
    platform = resolve_platform(platform)
    jax.config.update("jax_platform_name", platform)
    if platform == "gpu":
        _append_xla_flags(GPU_XLA_FLAGS)
    return platform


def set_host_device_count(n: int) -> None:
    """Emulate ``n`` host devices (the olmax
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` export, done
    in-process).  Only effective before the backend initializes — warn,
    don't silently no-op, when it is already up."""
    import jax
    if jax._src.xla_bridge._backends:            # already initialized
        if len(jax.devices()) != n:
            warnings.warn(
                f"backend already initialized with {len(jax.devices())} "
                f"devices; --xla_force_host_platform_device_count={n} "
                "takes effect next process", stacklevel=2)
    _append_xla_flags((f"--xla_force_host_platform_device_count={n}",))


def kernel_lowering(platform: Optional[str] = None) -> str:
    """'mosaic' | 'triton' | 'interpret' — how pallas_call should lower on
    ``platform`` (default: the active backend)."""
    return _LOWERING[resolve_platform(platform)]


def kernel_interpret(platform: Optional[str] = None) -> bool:
    """True when Pallas kernels must run in interpret mode here (CPU).

    This is the trace-time default ``griffin_linear`` applies to the
    shard_map'd kernel calls under an ``spmd_mesh`` scope: the mesh
    engine's jit sets are traced after placement, where the backend is
    known, so sharded serving never needs the interpret flag threaded
    through by hand (single-device callers keep passing it explicitly).
    """
    return kernel_lowering(platform) == "interpret"
