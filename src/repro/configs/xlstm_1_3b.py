"""xlstm-1.3b [arXiv:2405.04517]: 48 blocks d=2048, 4 heads, sLSTM+mLSTM
(7:1 mLSTM:sLSTM), no separate FFN (d_ff=0; blocks carry their own
up/down projections, proj_factor=2)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    xlstm_pattern=("m", "m", "m", "m", "m", "m", "m", "s"),
    proj_factor=2.0,
))
