"""Pure-jnp oracle for the dense GEMM kernel."""
import jax.numpy as jnp


def dense_matmul_ref(a, b, out_dtype=None):
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(
        out_dtype or a.dtype)
