"""Jit'd public wrapper for the dense GEMM kernel (padding + defaults)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import dense_matmul_kernel

# MXU-aligned defaults for TPU v5e; interpret mode (CPU validation) uses the
# same shapes so the BlockSpec logic is exercised identically.
DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_K = 128


def _pad_to(x: jax.Array, mult0: int, mult1: int) -> jax.Array:
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def _dense_matmul_jit(a: jax.Array, b: jax.Array, *, block_m, block_n,
                      block_k, interpret) -> jax.Array:
    m, n = a.shape[0], b.shape[1]
    bm, bn, bk = (min(block_m, _rup(m)), min(block_n, _rup(n)),
                  min(block_k, _rup(a.shape[1])))
    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)
    out = dense_matmul_kernel(ap, bp, block_m=bm, block_n=bn, block_k=bk,
                              interpret=interpret)
    return out[:m, :n]


def dense_matmul_shard(a, b, *, block_m: int, block_n: int, block_k: int,
                       interpret: bool = False) -> jax.Array:
    """Shard-local kernel entry: the blocked dense kernel on one device's
    N-slice of ``b`` against the replicated (whole-K) activations — each
    shard pads its slice to its own grid and unpads after, mirroring
    ``sparse_a_matmul_shard``."""
    m, n_local = a.shape[0], b.shape[1]
    bm, bn, bk = (min(block_m, _rup(m)), min(block_n, _rup(n_local)),
                  min(block_k, _rup(a.shape[1])))
    out = dense_matmul_kernel(_pad_to(a, bm, bk), _pad_to(b, bk, bn),
                              block_m=bm, block_n=bn, block_k=bk,
                              interpret=interpret)
    return out[:m, :n_local]


def shardable(b, n_shards: int) -> bool:
    """True when the weights' output axis splits evenly over the shards."""
    return b.ndim == 2 and n_shards >= 1 and b.shape[1] % n_shards == 0


def dense_matmul(a: jax.Array, b: jax.Array, *,
                 block_m: int = DEFAULT_BLOCK_M,
                 block_n: int = DEFAULT_BLOCK_N,
                 block_k: int = DEFAULT_BLOCK_K,
                 interpret: bool = False,
                 mesh=None, mesh_axis: str = "model") -> jax.Array:
    """C = A @ B via the Pallas blocked kernel (arbitrary shapes, padded).

    ``mesh`` runs the kernel under SPMD via ``shard_map`` — every device
    executes ``dense_matmul_shard`` on its N-slice of ``b`` with zero
    in-kernel collectives (DESIGN.md Section 10); requires
    ``shardable(b, mesh.shape[mesh_axis])``.
    """
    if mesh is not None:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        assert shardable(b, mesh.shape[mesh_axis]), \
            (b.shape, dict(mesh.shape), mesh_axis)
        local = functools.partial(dense_matmul_shard, block_m=block_m,
                           block_n=block_n, block_k=block_k,
                           interpret=interpret)
        return shard_map(local, mesh=mesh,
                         in_specs=(P(), P(None, mesh_axis)),
                         out_specs=P(None, mesh_axis),
                         check_rep=False)(a, b)
    return _dense_matmul_jit(a, b, block_m=block_m, block_n=block_n,
                             block_k=block_k, interpret=interpret)


def _rup(x: int, base: int = 8) -> int:
    """Round up to a lane-aligned size so tiny test shapes still tile."""
    return max(base, -(-x // base) * base)
