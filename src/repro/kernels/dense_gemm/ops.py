"""Jit'd public wrapper for the dense GEMM kernel (padding + defaults)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import dense_matmul_kernel

# MXU-aligned defaults for TPU v5e; interpret mode (CPU validation) uses the
# same shapes so the BlockSpec logic is exercised identically.
DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_K = 128


def _pad_to(x: jax.Array, mult0: int, mult1: int) -> jax.Array:
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def dense_matmul(a: jax.Array, b: jax.Array, *,
                 block_m: int = DEFAULT_BLOCK_M,
                 block_n: int = DEFAULT_BLOCK_N,
                 block_k: int = DEFAULT_BLOCK_K,
                 interpret: bool = False) -> jax.Array:
    """C = A @ B via the Pallas blocked kernel (arbitrary shapes, padded)."""
    m, n = a.shape[0], b.shape[1]
    bm, bn, bk = (min(block_m, _rup(m)), min(block_n, _rup(n)),
                  min(block_k, _rup(a.shape[1])))
    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)
    out = dense_matmul_kernel(ap, bp, block_m=bm, block_n=bn, block_k=bk,
                              interpret=interpret)
    return out[:m, :n]


def _rup(x: int, base: int = 8) -> int:
    """Round up to a lane-aligned size so tiny test shapes still tile."""
    return max(base, -(-x // base) * base)
