"""Dense blocked GEMM Pallas kernel — the optimized dense baseline core.

This is the TPU counterpart of the paper's Section II-A dense architecture:
a tiled output-stationary matmul with explicit VMEM residency via BlockSpec.
Block shapes default to MXU-aligned 128 multiples (the (K0, N0, M0) unrolling
of Figure 1 maps onto the 128x128 systolic MXU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    """Grid (mt, nt, kt): accumulate A[i,k] @ B[k,j] into a VMEM f32 scratch,
    flushing to the output block on the last k step."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def dense_matmul_kernel(a: jax.Array, b: jax.Array, *, block_m: int,
                        block_n: int, block_k: int, out_dtype=None,
                        interpret: bool = False) -> jax.Array:
    """C = A @ B with (block_m, block_k) x (block_k, block_n) VMEM tiles.

    Shapes must be multiples of the block sizes (ops.py pads).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    nk = k // block_k
    out_dtype = out_dtype or a.dtype
    grid = (m // block_m, n // block_n, nk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(a, b)
