"""jax.vmap implementation of the greedy sliding-window cycle model.

Mirrors :func:`repro.core.scheduler.schedule` exactly (same priority order,
same window/travel accounting) for one (d1, d2, d3) configuration, vmapped
over the leading tile axis.  The per-cycle placement pass is unrolled at
trace time — ``(1 + d1)`` window chunks x ``(1 + d2)(1 + d3)`` borrow
offsets — so the config must be static and modest; the numpy engine remains
the general path (per-row configs, recording, SparTen-deep windows).
"""
from __future__ import annotations

import functools
from typing import List, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

# keep the priority order identical to the numpy engine
from ...core.scheduler import _offsets, shuffle_lanes

# window-chunks x offsets unroll budget: beyond this the trace (and the
# compiled program) grows uselessly large — SparTen-style 128-deep windows
# belong on the numpy path.
MAX_UNROLL = 512


@functools.partial(jax.jit, static_argnames=("d1", "d2", "d3"))
def _schedule_cycles(mask: jax.Array, d1: int, d2: int, d3: int) -> jax.Array:
    rows, T, K0, G = mask.shape
    win = d1 + 1
    offs: List[Tuple[int, int]] = _offsets(d2, d3)
    t_grid = jnp.arange(T)

    def one(m: jax.Array) -> jax.Array:
        def cond(state):
            R, f, cycles = state
            return R.any()

        def body(state):
            R, f, cycles = state
            occ = jnp.zeros((K0, G), dtype=bool)
            for dt in range(win):                      # oldest chunk first
                tt = f + dt
                valid = tt < T
                ttc = jnp.minimum(tt, T - 1)
                chunk = R[ttc] & valid
                for (dl, dg) in offs:
                    src = chunk[dl:] if dl else chunk
                    src = jnp.roll(src, -dg, axis=1) if dg else src
                    occ_v = occ[:K0 - dl] if dl else occ
                    put = src & ~occ_v
                    if dl:
                        occ = occ.at[:K0 - dl].set(occ[:K0 - dl] | put)
                    else:
                        occ = occ | put
                    taken = jnp.roll(put, dg, axis=1) if dg else put
                    if dl:
                        chunk = chunk.at[dl:].set(chunk[dl:] & ~taken)
                    else:
                        chunk = chunk & ~taken
                R = R.at[ttc].set(jnp.where(valid, chunk, R[ttc]))
            cycles = cycles + 1
            chunk_any = R.any(axis=(1, 2))
            cand = jnp.where(chunk_any & (t_grid >= f), t_grid, T)
            f = jnp.minimum(cand.min(), f + win)       # window front advance
            return R, f, cycles

        R, f, cycles = lax.while_loop(
            cond, body, (m, jnp.int32(0), jnp.int32(0)))
        tail = jnp.maximum(T - f, 0)
        return cycles + -(-tail // win)                # trailing travel

    return jax.vmap(one)(mask)


def schedule_cycles(mask: np.ndarray, d1: int, d2: int, d3: int,
                    shuffle: bool = False) -> np.ndarray:
    """Executed-cycle counts of the greedy schedule, on the jax backend.

    mask: (tiles, T, K0, G) boolean.  Returns (tiles,) int64, bit-exact with
    ``schedule(mask, d1, d2, d3, shuffle).cycles``.
    """
    if mask.ndim != 4:
        raise ValueError(f"mask must be (tiles, T, K0, G), got {mask.shape}")
    win = d1 + 1
    if win * (1 + d2) * (1 + d3) > MAX_UNROLL:
        raise ValueError(
            f"config ({d1},{d2},{d3}) unrolls past {MAX_UNROLL} placement "
            "steps per cycle; use the numpy engine for deep windows")
    if mask.shape[1] == 0 or mask.shape[0] == 0:
        return np.zeros(mask.shape[0], dtype=np.int64)
    if shuffle:
        mask = shuffle_lanes(mask, chunk_axis=1, lane_axis=2)
    out = _schedule_cycles(jnp.asarray(np.ascontiguousarray(mask)),
                           int(d1), int(d2), int(d3))
    return np.asarray(out).astype(np.int64)
