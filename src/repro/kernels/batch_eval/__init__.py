"""Accelerator path for the batched cycle-model evaluation.

The numpy engine in :mod:`repro.core.scheduler` is the reference; this
subpackage is its ``jax.vmap`` twin on the same kernel substrate as the
Pallas GEMM kernels (dense_gemm / griffin_spmm): the greedy sliding-window
schedule is expressed as a per-tile ``lax.while_loop`` with the window and
borrow offsets unrolled at trace time, then vmapped over the tile-stream
batch axis and jitted.  On CPU it is a correctness twin; on a TPU/GPU host
it moves the DSE inner loop off the Python interpreter entirely.

Select it with ``schedule_batched(..., backend="jax")`` (homogeneous config
only) or call :func:`schedule_cycles` directly.
"""
from .ops import schedule_cycles

__all__ = ["schedule_cycles"]
