"""Numpy oracle for the jax batched-evaluation path (test reference)."""
from __future__ import annotations

import numpy as np

from ...core.scheduler import schedule


def schedule_cycles_ref(mask: np.ndarray, d1: int, d2: int, d3: int,
                        shuffle: bool = False) -> np.ndarray:
    """Reference cycle counts from the numpy engine."""
    return schedule(mask, d1, d2, d3, shuffle=shuffle).cycles
