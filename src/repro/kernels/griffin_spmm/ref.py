"""Pure-jnp oracles for the Griffin block-sparse GEMM."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def block_prune_ref(w: np.ndarray, block_k: int, block_n: int) -> np.ndarray:
    """The weight matrix the compacted representation denotes: w with
    all-zero blocks (exactly) preserved — i.e. w itself after block pruning.
    Provided for clarity; preprocessing never changes surviving values."""
    return w


def griffin_spmm_ref(a, w_pruned, out_dtype=None):
    """Oracle: the compacted product must equal the dense product with the
    (block-)pruned weights; dual mode additionally never changes the result
    because skipped A blocks are exactly zero."""
    return jnp.dot(a, w_pruned, preferred_element_type=jnp.float32).astype(
        out_dtype or a.dtype)
