"""Public ops for Griffin sparse execution on TPU.

``preprocess_weights`` is the paper's offline B preprocessing (Fig. 2/3
step 1) at TPU block granularity; ``balance_columns`` is the load-balancing
shuffle; ``griffin_matmul`` executes; ``auto_matmul`` is the hybrid-morphing
entry point that picks dense / Sparse.A / Sparse.B / dual per call
(core.hybrid.select_mode — the same policy the framework layer applies per
GEMM through models.common.griffin_linear).

``GriffinWeights`` is a registered pytree: compacted weights flow through
jit, ``lax.scan`` over stacked layers, and the sharding rules in
runtime.sharding (DESIGN.md Section 4).  ``stack_weights`` builds the
stacked (leading layer/expert axis) form the model stacks consume;
indexing a stacked instance (``gw[i]``) slices every array leaf.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ...core.hybrid import select_mode
from ...core.spec import Mode
from ..dense_gemm.ops import dense_matmul
from ..sparse_a.ops import sparse_a_matmul
from .kernel import griffin_spmm_kernel

DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_K = 128
DEFAULT_BLOCK_N = 128


@dataclasses.dataclass
class GriffinWeights:
    """Block-compacted weight representation + metadata (device arrays).

    Array fields may carry extra leading axes (stacked layers / experts);
    the trailing axes are always the single-matrix layout documented here.
    """

    b_comp: jax.Array        # (..., max_cnt*block_k, N_padded)
    kidx: jax.Array          # (..., n_tiles, max_cnt) int32
    cnt: jax.Array           # (..., n_tiles) int32
    inv_perm: Optional[jax.Array]    # (..., N_padded) undo of the balance
    #                                  shuffle's column permutation (None =
    #                                  identity / balancing disabled)
    k: int                   # original K (padded)
    n: int                   # original N (unpadded)
    block_k: int
    block_n: int
    # Per-GEMM Mode-selection threshold override from a tuned kernel plan
    # (repro.tuning, DESIGN.md Section 12): when set, griffin_linear passes
    # it as ``select_mode``'s A threshold for this GEMM instead of the
    # scope-wide one.  A meta field (trace-time constant): the threshold
    # picks *which* kernel configuration runs, never what it computes.
    a_thr: Optional[float] = None

    @property
    def density(self) -> float:
        """Fraction of surviving (bk x bn) blocks.  Memoized per instance:
        the computation device-syncs ``cnt``, and callers walk it per GEMM
        leaf (``runtime.engine.weight_sparsity`` at every engine
        construction).  The memo lives in ``__dict__`` — not a dataclass
        field, so pytree flatten/unflatten (which rebuilds instances from
        the registered fields only) neither carries a stale value onto
        tree-mapped copies nor breaks; fresh instances recompute lazily."""
        memo = self.__dict__.get("_density_memo")
        if memo is None:
            total_blocks = (self.k // self.block_k) * \
                int(np.prod(self.cnt.shape))
            memo = float(np.asarray(self.cnt).sum()) / max(total_blocks, 1)
            self.__dict__["_density_memo"] = memo
        return memo

    @property
    def compaction(self) -> float:
        """Grid-depth compaction vs dense: max_cnt / nb_k (lower is better)."""
        return self.kidx.shape[-1] / (self.k // self.block_k)

    def __getitem__(self, i) -> "GriffinWeights":
        """Slice a stacked instance along its leading axis."""
        return jax.tree.map(lambda a: a[i], self)


jax.tree_util.register_dataclass(
    GriffinWeights,
    data_fields=["b_comp", "kidx", "cnt", "inv_perm"],
    meta_fields=["k", "n", "block_k", "block_n", "a_thr"])


def balance_columns(w_padded: np.ndarray, block_k: int, block_n: int,
                    unit: int) -> np.ndarray:
    """Unit-column permutation: the paper's load-balancing shuffle at tile
    granularity.

    A kernel N tile spans ``block_n / unit`` pruning units; a K block of the
    tile survives if *any* of its units is nonzero there, so the grid depth
    is the max over tiles of the union pattern size.  Grouping units with
    *similar* K patterns (lexicographic sort of their block-mask bitmaps)
    keeps unions tight and equalizes counts.  Returns a column permutation.
    """
    pk, pn = w_padded.shape
    nb_k = pk // block_k
    nu = pn // unit
    # unit pattern bitmap: (nu, nb_k)
    pat = (w_padded.reshape(nb_k, block_k, nu, unit) != 0).any(axis=(1, 3)).T
    order = np.lexsort(pat.T[::-1])          # cluster similar patterns
    perm = (order[:, None] * unit + np.arange(unit)[None, :]).reshape(-1)
    return perm


def preprocess_weights(w: np.ndarray, *, block_k: int = DEFAULT_BLOCK_K,
                       block_n: int = DEFAULT_BLOCK_N,
                       balance: bool = True,
                       unit: Optional[int] = None) -> GriffinWeights:
    """Offline B preprocessing: drop all-zero (bk x bn) blocks, build the
    per-N-tile metadata, optionally balance unit-columns across tiles.

    ``unit`` is the pruning granularity along N (defaults to block_n / 4,
    min 8): weights are expected pruned in (block_k x unit) blocks, e.g. by
    repro.sparsity.block_prune.
    """
    w = np.asarray(w)
    k, n = w.shape
    pk = -(-k // block_k) * block_k
    pn = -(-n // block_n) * block_n
    wp = np.zeros((pk, pn), dtype=w.dtype)
    wp[:k, :n] = w
    nb_k, nb_n = pk // block_k, pn // block_n
    unit = unit or max(8, block_n // 4)

    inv_perm = None
    if balance and pn > block_n and pn % unit == 0:
        full_perm = balance_columns(wp, block_k, block_n, unit)
        wp = wp[:, full_perm]
        inv_perm = jnp.asarray(np.argsort(full_perm).astype(np.int32))

    blk_nz = (wp.reshape(nb_k, block_k, nb_n, block_n) != 0).any(axis=(1, 3))
    cnt = blk_nz.sum(axis=0).astype(np.int32)                 # (nb_n,)
    max_cnt = max(int(cnt.max()), 1)
    kidx = np.zeros((nb_n, max_cnt), dtype=np.int32)
    b_comp = np.zeros((max_cnt * block_k, pn), dtype=w.dtype)
    for j in range(nb_n):
        ks = np.flatnonzero(blk_nz[:, j])
        kidx[j, :len(ks)] = ks
        if len(ks) < max_cnt:                                 # clamp padding
            kidx[j, len(ks):] = ks[-1] if len(ks) else 0
        for kc, kb in enumerate(ks):
            b_comp[kc * block_k:(kc + 1) * block_k,
                   j * block_n:(j + 1) * block_n] = \
                wp[kb * block_k:(kb + 1) * block_k,
                   j * block_n:(j + 1) * block_n]
    return GriffinWeights(
        b_comp=jnp.asarray(b_comp), kidx=jnp.asarray(kidx),
        cnt=jnp.asarray(cnt), inv_perm=inv_perm, k=pk, n=n,
        block_k=block_k, block_n=block_n)


def stack_weights(gws: Sequence[GriffinWeights]) -> GriffinWeights:
    """Stack per-layer/per-expert compacted weights along a new leading
    axis, padding every member to the common (max over members) grid depth
    so the stacked leaves are rectangular — the layout ``lax.scan`` and the
    unrolled layer loop both consume."""
    assert gws, "empty stack"
    g0 = gws[0]
    for g in gws[1:]:
        assert (g.k, g.n, g.block_k, g.block_n, g.a_thr) == \
            (g0.k, g0.n, g0.block_k, g0.block_n, g0.a_thr), \
            "heterogeneous stack"
        assert (g.inv_perm is None) == (g0.inv_perm is None), \
            "mixed balanced/unbalanced stack"
    max_cnt = max(g.kidx.shape[-1] for g in gws)
    bk = g0.block_k

    def padded(g: GriffinWeights):
        pad_c = max_cnt - g.kidx.shape[-1]
        kidx, b_comp = g.kidx, g.b_comp
        if pad_c:
            # dead entries (kc >= cnt) — clamp-repeat the last id, zero data
            kidx = jnp.concatenate(
                [kidx, jnp.repeat(kidx[:, -1:], pad_c, axis=1)], axis=1)
            b_comp = jnp.concatenate(
                [b_comp, jnp.zeros((pad_c * bk, b_comp.shape[1]),
                                   b_comp.dtype)], axis=0)
        return kidx, b_comp

    ks, bs = zip(*[padded(g) for g in gws])
    return GriffinWeights(
        b_comp=jnp.stack(bs), kidx=jnp.stack(ks),
        cnt=jnp.stack([g.cnt for g in gws]),
        inv_perm=(None if g0.inv_perm is None
                  else jnp.stack([g.inv_perm for g in gws])),
        k=g0.k, n=g0.n, block_k=g0.block_k, block_n=g0.block_n,
        a_thr=g0.a_thr)


@functools.partial(jax.jit, static_argnames=("block_m", "dual", "interpret",
                                             "block_k", "block_n", "n"))
def _run(a, b_comp, kidx, cnt, inv_perm, *, block_m, block_k, block_n, n,
         dual, interpret):
    out = griffin_spmm_kernel(a, b_comp, kidx, cnt, block_m=block_m,
                              block_k=block_k, block_n=block_n, dual=dual,
                              interpret=interpret)
    if inv_perm is not None:
        out = out[:, inv_perm]
    return out[:, :n]


# ---------------------------------------------------------------------------
# shard-local execution (SPMD via shard_map, DESIGN.md Section 10)
# ---------------------------------------------------------------------------

def griffin_matmul_shard(a, b_comp, kidx, cnt, *, block_m: int, block_k: int,
                         block_n: int, dual: bool = False,
                         interpret: bool = False) -> jax.Array:
    """Shard-local kernel entry: the raw griffin_spmm kernel on one
    device's slice of the compacted operands.

    ``a`` is the whole (padded) activation — replicated, because ``kidx``
    holds *global* K-block ids and the serving layout never splits the
    contraction dim.  ``b_comp``/``kidx``/``cnt`` are pre-sliced along the
    N-tile axis (``shard_specs``): a contiguous group of N tiles with their
    own metadata rows is a complete, self-contained kernel problem, so the
    per-shard call is literally the unsharded kernel on a narrower grid —
    zero in-kernel collectives.  The balance shuffle's ``inv_perm`` gather
    and the ``[:, :n]`` unpad are *global* column operations and stay with
    the caller (``griffin_matmul``).
    """
    return griffin_spmm_kernel(a, b_comp, kidx, cnt, block_m=block_m,
                               block_k=block_k, block_n=block_n, dual=dual,
                               interpret=interpret)


def shard_specs(axis: str = "model"):
    """(in_specs, out_spec) partitioning ``griffin_matmul_shard``'s
    operands over mesh axis ``axis``: activations replicated, ``b_comp``
    split on its padded-N (last) axis, ``kidx``/``cnt`` split on their
    N-tile (first) axis, output split on N.  Exposed (and re-exported by
    ``runtime.sharding``) so tests and the layout rules agree on one
    definition of the per-shard operand layout."""
    from jax.sharding import PartitionSpec as P
    return (P(), P(None, axis), P(axis, None), P(axis)), P(None, axis)


def shardable(gw: GriffinWeights, n_shards: int) -> bool:
    """True when the compacted operands split evenly into ``n_shards``
    whole-N-tile groups — the condition for the shard_map path.  A stacked
    instance is never shardable at the op level (the engine slices per
    layer inside its scan)."""
    if gw.b_comp.ndim != 2 or n_shards < 1:
        return False
    n_tiles = gw.kidx.shape[0]
    return n_tiles % n_shards == 0


def _shard_map_run(ap, gw: GriffinWeights, mesh, axis, *, block_m, dual,
                   interpret):
    from jax.experimental.shard_map import shard_map
    in_specs, out_spec = shard_specs(axis)
    local = functools.partial(
        griffin_matmul_shard, block_m=block_m, block_k=gw.block_k,
        block_n=gw.block_n, dual=dual, interpret=interpret)
    # check_rep=False: pallas_call has no replication rule either — the
    # out_spec states the (easily checked) fact that shards are disjoint
    out = shard_map(local, mesh=mesh, in_specs=in_specs,
                    out_specs=out_spec, check_rep=False)(
                        ap, gw.b_comp, gw.kidx, gw.cnt)
    if gw.inv_perm is not None:
        out = out[:, gw.inv_perm]
    return out[:, :gw.n]


def decompact_weights(gw: GriffinWeights) -> jax.Array:
    """jnp reconstruction of the (padded K, n) block-pruned dense matrix a
    single (non-stacked) ``GriffinWeights`` denotes — the spec-respecting
    SPMD fallback's weight operand (DESIGN.md Section 10).

    Pure jnp (one-hot scatter of the compacted blocks back to their global
    K rows, then the balance shuffle's inverse column permutation), so it
    traces under jit and GSPMD can partition it where ``pallas_call`` —
    which has no SPMD partitioning rule — cannot run at all.  Clamp-padded
    dead ``kidx`` entries duplicate a live block id but their ``b_comp``
    rows are zero, so the scatter-add contributes nothing for them.
    Surviving values are reconstructed exactly (preprocessing never changes
    them), hence ``a @ decompact_weights(gw)`` is bit-equal to the dense
    product with the block-pruned weights.
    """
    assert gw.b_comp.ndim == 2, "decompact a per-layer slice, not a stack"
    bk = gw.block_k
    nb_k = gw.k // bk
    nt, mc = gw.kidx.shape
    pn = gw.b_comp.shape[-1]
    bn = pn // nt
    bc = gw.b_comp.reshape(mc, bk, nt, bn)                    # (c, r, t, s)
    onehot = jax.nn.one_hot(gw.kidx, nb_k, dtype=gw.b_comp.dtype)
    w = jnp.einsum("crts,tcK->Krts", bc, onehot)              # (K, r, t, s)
    w = w.reshape(nb_k * bk, pn)
    if gw.inv_perm is not None:
        w = w[:, gw.inv_perm]
    return w[:, :gw.n]


def griffin_matmul(a: jax.Array, gw: GriffinWeights, *,
                   block_m: int = DEFAULT_BLOCK_M, dual: bool = False,
                   interpret: bool = False, spmd: bool = False,
                   mesh=None, mesh_axis: str = "model") -> jax.Array:
    """C = A @ W_pruned from the compacted representation.

    ``mesh`` (a ``jax.sharding.Mesh``) runs the **real kernel under SPMD**
    via ``shard_map`` (DESIGN.md Section 10): every device executes
    ``griffin_matmul_shard`` on its whole-N-tile slice of
    b_comp/kidx/cnt against the replicated activations — bit-identical to
    the unsharded kernel (same per-tile fp32 accumulation order), with
    zero in-kernel collectives.  Requires ``shardable(gw,
    mesh.shape[mesh_axis])``; callers (``models.common.griffin_linear``)
    check and fall back to ``spmd=True`` otherwise.

    ``spmd=True`` is the decompaction **oracle** (previously the only
    multi-device path): reconstruct the denoted block-pruned dense matrix
    and take a plain jnp dot, which GSPMD shards along the weights'
    output (N) axis without ever splitting the contraction.  Bit-equal to
    the dense product with the pruned weights, allclose (different
    reduction order) to the kernel.  Dual-mode predication is a no-op on
    values (skipped A blocks are exactly zero), so it covers Mode.AB too.
    """
    m, k = a.shape
    if spmd:
        w = decompact_weights(gw)
        return jnp.dot(a, w[:k], preferred_element_type=jnp.float32)
    bm = min(block_m, max(8, -(-m // 8) * 8))
    pm = -(-m // bm) * bm
    ap = jnp.pad(a, ((0, pm - m), (0, gw.k - k)))
    if mesh is not None:
        assert shardable(gw, mesh.shape[mesh_axis]), \
            (gw.kidx.shape, dict(mesh.shape), mesh_axis)
        out = _shard_map_run(ap, gw, mesh, mesh_axis, block_m=bm, dual=dual,
                             interpret=interpret)
        return out[:m]
    out = _run(ap, gw.b_comp, gw.kidx, gw.cnt, gw.inv_perm, block_m=bm,
               block_k=gw.block_k, block_n=gw.block_n, n=gw.n, dual=dual,
               interpret=interpret)
    return out[:m]


def auto_matmul(a: jax.Array, w, gw: Optional[GriffinWeights] = None, *,
                a_sparsity: float = 0.0, b_sparsity: float = 0.0,
                interpret: bool = False) -> jax.Array:
    """Hybrid-morphing entry point (paper Section IV-B at the op level):
    measure/declare tensor sparsity, pick the execution mode, run the same
    core in dense / Sparse.A / Sparse.B / dual configuration.

    Dispatch (every ``core.spec.Mode`` reaches a real kernel):
      DENSE -> dense_gemm;  A -> sparse_a (runtime-compacted A, dense B);
      B -> griffin_spmm;    AB -> griffin_spmm dual (compacted B + on-the-fly
      A-block predication).  Declared-sparse B without preprocessed weights
      falls back dense/Sparse.A — there is nothing compacted to walk.
    """
    mode = select_mode(a_sparsity, b_sparsity)
    if mode in (Mode.B, Mode.AB) and gw is not None:
        return griffin_matmul(a, gw, dual=(mode == Mode.AB),
                              interpret=interpret)
    if mode in (Mode.A, Mode.AB):
        return sparse_a_matmul(a, w, interpret=interpret)
    return dense_matmul(a, w, interpret=interpret)
