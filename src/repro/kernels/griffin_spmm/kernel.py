"""Griffin block-sparse GEMM Pallas kernel (the paper's technique on TPU).

TPU adaptation of the paper's mechanisms (DESIGN.md Section 3):

  - **B preprocessing** (Sparse.B): the weight matrix is compacted offline —
    all-zero (block_k x block_n) blocks are dropped, and per output tile j a
    metadata list ``kidx[j]`` of surviving K-block ids plus a count ``cnt[j]``
    is carried as *scalar-prefetch* operands.  The kernel walks the compacted
    list; the data-dependent ``BlockSpec index_map`` plays the role of the
    paper's AMUX (metadata selects which A tile each multiply consumes).
  - **On-the-fly A skipping** (Sparse.A / dual): with ``dual=True`` the
    kernel tests the fetched A tile for all-zero and predicates the MXU op
    (``pl.when``), the block-granular analogue of the paper's zero-mask +
    arbitration steps (Fig. 3 steps 2-4).
  - **Load balancing** (shuffle): ops.py can permute output columns so each
    N tile receives a balanced number of surviving blocks, shrinking the
    padded grid depth max_j cnt[j] — the paper's rotation shuffler at tile
    granularity.

Grid: (m_tiles, n_tiles, max_cnt); the k axis is the *compacted* position.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _spmm_kernel(kidx_ref, cnt_ref, a_ref, b_ref, o_ref, acc_ref,
                 *, nkc: int, dual: bool):
    kc = pl.program_id(2)
    j = pl.program_id(1)

    @pl.when(kc == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = kc < cnt_ref[j]
    if dual:
        # Dual sparsity: also skip when the (dynamic) A tile is all-zero —
        # the paper's on-the-fly zero detection at block granularity.
        a_blk = a_ref[...]
        live = jnp.logical_and(live, jnp.any(a_blk != 0))

        @pl.when(live)
        def _acc_dual():
            acc_ref[...] += jnp.dot(a_blk, b_ref[...],
                                    preferred_element_type=jnp.float32)
    else:
        @pl.when(live)
        def _acc():
            acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                                    preferred_element_type=jnp.float32)

    @pl.when(kc == nkc - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def griffin_spmm_kernel(a: jax.Array, b_comp: jax.Array, kidx: jax.Array,
                        cnt: jax.Array, *, block_m: int, block_k: int,
                        block_n: int, dual: bool = False, out_dtype=None,
                        interpret: bool = False) -> jax.Array:
    """C = A @ B from the block-compacted weight representation.

    a:      (M, K)            — activations, M % block_m == K % block_k == 0.
    b_comp: (max_cnt*block_k, N) — compacted weight blocks per N tile:
            rows [kc*block_k:(kc+1)*block_k] of column tile j hold the
            kidx[j, kc]-th K-block of the original (pruned) weights.
    kidx:   (n_tiles, max_cnt) int32 — source K-block ids (clamped padding).
    cnt:    (n_tiles,) int32  — surviving blocks per N tile.
    """
    m, k = a.shape
    kc_rows, n = b_comp.shape
    assert m % block_m == 0 and k % block_k == 0 and n % block_n == 0
    n_tiles = n // block_n
    max_cnt = kc_rows // block_k
    assert kidx.shape == (n_tiles, max_cnt), (kidx.shape, (n_tiles, max_cnt))
    grid = (m // block_m, n_tiles, max_cnt)
    flat_kidx = kidx.reshape(-1).astype(jnp.int32)
    out_dtype = out_dtype or a.dtype
    return pl.pallas_call(
        functools.partial(_spmm_kernel, nkc=max_cnt, dual=dual),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                # A tile selected by metadata: the AMUX.
                pl.BlockSpec(
                    (block_m, block_k),
                    lambda i, j, kc, kidx_s, cnt_s: (i, kidx_s[j * max_cnt + kc])),
                # compacted B tile: walk the compressed stream.
                pl.BlockSpec(
                    (block_k, block_n),
                    lambda i, j, kc, kidx_s, cnt_s: (kc, j)),
            ],
            out_specs=pl.BlockSpec(
                (block_m, block_n),
                lambda i, j, kc, kidx_s, cnt_s: (i, j)),
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(flat_kidx, cnt.astype(jnp.int32), a, b_comp)
