"""Pallas TPU kernels for the performance-critical GEMM paths.

- dense_gemm:   the optimized dense baseline (blocked MXU matmul).
- griffin_spmm: the paper's sparse technique, TPU-adapted — offline
  block-compaction of weights with scalar-prefetch metadata (Sparse.B),
  optional on-the-fly A-block skipping (dual), and column balancing
  (shuffle).  See DESIGN.md Section 3 for the granularity adaptation.
- batch_eval:   jax.vmap twin of the batched cycle-model scheduler, the
  accelerator path behind ``schedule_batched(..., backend="jax")``.

Kernels are validated against their ref.py oracles in interpret mode on CPU
and target TPU v5e block shapes (128-aligned) for real runs.
"""
from .batch_eval.ops import schedule_cycles
from .dense_gemm.ops import dense_matmul
from .griffin_spmm.ops import (GriffinWeights, auto_matmul, balance_columns,
                               griffin_matmul, preprocess_weights)

__all__ = ["dense_matmul", "GriffinWeights", "auto_matmul",
           "balance_columns", "griffin_matmul", "preprocess_weights",
           "schedule_cycles"]
