"""Pallas TPU kernels for the performance-critical GEMM paths.

- dense_gemm:   the optimized dense baseline (blocked MXU matmul).
- griffin_spmm: the paper's sparse technique, TPU-adapted — offline
  block-compaction of weights with scalar-prefetch metadata (Sparse.B),
  optional on-the-fly A-block skipping (dual), and column balancing
  (shuffle).  See DESIGN.md Section 3 for the granularity adaptation.
- sparse_a:     the Sparse.A analogue — runtime compaction of the A-block
  iteration space with scalar-prefetch metadata against dense weights
  (DESIGN.md Section 3; jit static-shape fallback in Section 5).
- batch_eval:   jax.vmap twin of the batched cycle-model scheduler, the
  accelerator path behind ``schedule_batched(..., backend="jax")``.

``auto_matmul`` dispatches every ``core.spec.Mode`` to one of these kernels;
the framework layer reaches it per GEMM via ``models.common.griffin_linear``.
Kernels are validated against their ref.py oracles in interpret mode on CPU
and target TPU v5e block shapes (128-aligned) for real runs.
"""
from .batch_eval.ops import schedule_cycles
from .dense_gemm.ops import dense_matmul
from .griffin_spmm.ops import (GriffinWeights, auto_matmul, balance_columns,
                               griffin_matmul, preprocess_weights,
                               stack_weights)
from .sparse_a.ops import ActivationMeta, compact_activations, sparse_a_matmul

__all__ = ["dense_matmul", "GriffinWeights", "auto_matmul",
           "balance_columns", "griffin_matmul", "preprocess_weights",
           "stack_weights", "ActivationMeta", "compact_activations",
           "sparse_a_matmul", "schedule_cycles"]
