from .ops import (ActivationMeta, compact_activations,  # noqa: F401
                  sparse_a_matmul)
