"""Sparse.A Pallas kernel: compacted activation-sparse GEMM on TPU.

The Sparse.A analogue of griffin_spmm (DESIGN.md Section 3): where Sparse.B
compacts the *weight* matrix offline, here the *iteration space* over A's
K blocks is compacted at runtime.  Per M tile i a metadata list ``kidx[i]``
of K-block ids whose (block_m x block_k) A tile is nonzero, plus a count
``cnt[i]``, is carried as scalar-prefetch operands:

  - the A BlockSpec ``index_map`` dereferences ``kidx`` — the AMUX again,
    now selecting which *activation* tile each multiply consumes;
  - the B BlockSpec dereferences the same metadata, so the dense weight
    matrix is walked in the compacted order (no physical gather of A: the
    data never moves, only the schedule compacts — a zero-copy analogue of
    the paper's A-side zero-mask + arbitration, Fig. 3 steps 2-4);
  - grid position kc >= cnt[i] is predicated off (``pl.when``), so padding
    introduced by ragged per-row counts costs DMA but no MXU work.

Grid: (m_tiles, n_tiles, max_cnt); the k axis is the *compacted* position.
``max_cnt`` is static: when metadata is built from concrete activations
(op level / serving with host-visible tensors) it is the true max count and
the grid physically shrinks; under jit it falls back to the full K depth
with trailing predicated no-ops (DESIGN.md Section 5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _sparse_a_kernel(kidx_ref, cnt_ref, a_ref, b_ref, o_ref, acc_ref,
                     *, nkc: int):
    i = pl.program_id(0)
    kc = pl.program_id(2)

    @pl.when(kc == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(kc < cnt_ref[i])
    def _acc():
        acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(kc == nkc - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def sparse_a_gemm_kernel(a: jax.Array, b: jax.Array, kidx: jax.Array,
                         cnt: jax.Array, *, block_m: int, block_k: int,
                         block_n: int, out_dtype=None,
                         interpret: bool = False) -> jax.Array:
    """C = A @ B walking only the K blocks listed live per M tile.

    a:    (M, K)              — activations, M % block_m == K % block_k == 0.
    b:    (K, N)              — dense weights, N % block_n == 0.
    kidx: (m_tiles, max_cnt) int32 — live K-block ids per M tile (entries
          past cnt[i] are dead: any valid id, only DMA'd, never multiplied).
    cnt:  (m_tiles,) int32    — live blocks per M tile.
    """
    m, k = a.shape
    kb, n = b.shape
    assert k == kb, (k, kb)
    assert m % block_m == 0 and k % block_k == 0 and n % block_n == 0
    m_tiles = m // block_m
    max_cnt = kidx.shape[1]
    assert kidx.shape == (m_tiles, max_cnt), (kidx.shape, (m_tiles, max_cnt))
    grid = (m_tiles, n // block_n, max_cnt)
    flat_kidx = kidx.reshape(-1).astype(jnp.int32)
    out_dtype = out_dtype or a.dtype
    return pl.pallas_call(
        functools.partial(_sparse_a_kernel, nkc=max_cnt),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                # A tile selected by metadata: the AMUX on the A side.
                pl.BlockSpec(
                    (block_m, block_k),
                    lambda i, j, kc, kidx_s, cnt_s: (i, kidx_s[i * max_cnt + kc])),
                # dense B walked in compacted order via the same metadata.
                pl.BlockSpec(
                    (block_k, block_n),
                    lambda i, j, kc, kidx_s, cnt_s: (kidx_s[i * max_cnt + kc], j)),
            ],
            out_specs=pl.BlockSpec(
                (block_m, block_n),
                lambda i, j, kc, kidx_s, cnt_s: (i, j)),
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(flat_kidx, cnt.astype(jnp.int32), a, b)
