"""Public ops for Sparse.A (activation-sparse) execution on TPU.

``compact_activations`` builds the runtime metadata — the A-side analogue of
griffin_spmm's offline ``preprocess_weights``, except nothing is known until
the activations exist, so compaction happens per call:

  - on **concrete** arrays (op level, serving with host-visible tensors) the
    metadata is built in numpy and ``max_cnt`` is the true maximum live
    count, so the kernel grid physically shrinks (real compaction);
  - on **traced** arrays (inside jit) grid shapes must be static before the
    values exist, so the metadata is built with jnp at the full K depth and
    skipping degrades to trailing predicated no-ops — MXU work is still
    saved, grid depth is not (DESIGN.md Section 5).

``sparse_a_matmul`` pads, compacts, and runs the kernel.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import sparse_a_gemm_kernel

DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_K = 128
DEFAULT_BLOCK_N = 128


@dataclasses.dataclass
class ActivationMeta:
    """Per-M-tile live-K-block metadata for one activation matrix."""

    kidx: jax.Array          # (m_tiles, max_cnt) int32
    cnt: jax.Array           # (m_tiles,) int32
    m: int                   # padded M
    k: int                   # padded K
    block_m: int
    block_k: int

    @property
    def density(self) -> float:
        """Fraction of live (block_m x block_k) A blocks (concrete only)."""
        mt, kt = self.m // self.block_m, self.k // self.block_k
        return float(np.asarray(self.cnt).sum()) / max(mt * kt, 1)

    @property
    def compaction(self) -> float:
        """Grid-depth compaction vs dense: max_cnt / k_tiles (lower is
        better; 1.0 when built under jit — static-shape fallback)."""
        return self.kidx.shape[1] / (self.k // self.block_k)


def _rup(x: int, base: int = 8) -> int:
    return max(base, -(-x // base) * base)


def _pad2(x: jax.Array, p0: int, p1: int) -> jax.Array:
    if p0 > x.shape[0] or p1 > x.shape[1]:
        x = jnp.pad(x, ((0, p0 - x.shape[0]), (0, p1 - x.shape[1])))
    return x


def compact_activations(a: jax.Array, *, block_m: int = DEFAULT_BLOCK_M,
                        block_k: int = DEFAULT_BLOCK_K) -> ActivationMeta:
    """Runtime compaction: list the K blocks each M tile must visit.

    Concrete ``a`` -> numpy metadata with the true (minimal) ``max_cnt``;
    traced ``a`` -> jnp metadata at full K depth (static shapes under jit).
    """
    m, k = a.shape
    bm = min(block_m, _rup(m))
    bk = min(block_k, _rup(k))
    pm, pk = -(-m // bm) * bm, -(-k // bk) * bk
    mt, kt = pm // bm, pk // bk
    if isinstance(a, jax.core.Tracer):
        ap = _pad2(a, pm, pk)
        nz = (ap.reshape(mt, bm, kt, bk) != 0).any(axis=(1, 3))   # (mt, kt)
        cnt = nz.sum(axis=1).astype(jnp.int32)
        # stable sort: live blocks first, original k order preserved; dead
        # trailing entries hold valid ids (DMA'd but predicated off).
        kidx = jnp.argsort(~nz, axis=1, stable=True).astype(jnp.int32)
        return ActivationMeta(kidx=kidx, cnt=cnt, m=pm, k=pk,
                              block_m=bm, block_k=bk)
    a_np = np.zeros((pm, pk), dtype=np.asarray(a).dtype)
    a_np[:m, :k] = np.asarray(a)
    nz = (a_np.reshape(mt, bm, kt, bk) != 0).any(axis=(1, 3))
    cnt = nz.sum(axis=1).astype(np.int32)
    max_cnt = max(int(cnt.max()), 1)
    kidx = np.zeros((mt, max_cnt), dtype=np.int32)
    for i in range(mt):
        ks = np.flatnonzero(nz[i])
        kidx[i, :len(ks)] = ks
        if len(ks) < max_cnt:                                     # clamp pad
            kidx[i, len(ks):] = ks[-1] if len(ks) else 0
    return ActivationMeta(kidx=jnp.asarray(kidx), cnt=jnp.asarray(cnt),
                          m=pm, k=pk, block_m=bm, block_k=bk)


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "block_n",
                                             "interpret"))
def _run(a, b, kidx, cnt, *, block_m, block_k, block_n, interpret):
    return sparse_a_gemm_kernel(a, b, kidx, cnt, block_m=block_m,
                                block_k=block_k, block_n=block_n,
                                interpret=interpret)


def sparse_a_matmul(a: jax.Array, w: jax.Array, *,
                    block_m: int = DEFAULT_BLOCK_M,
                    block_k: int = DEFAULT_BLOCK_K,
                    block_n: int = DEFAULT_BLOCK_N,
                    meta: Optional[ActivationMeta] = None,
                    interpret: bool = False,
                    spmd: bool = False) -> jax.Array:
    """C = A @ W visiting only the live A blocks (Sparse.A execution).

    ``spmd=True`` is the mesh-partitionable fallback (DESIGN.md
    Section 10): skipped A blocks are exactly zero, so the compacted
    product *is* the plain dense product (``ref.sparse_a_ref``), which
    GSPMD can shard along W's output axis — ``pallas_call`` has no SPMD
    partitioning rule, and the runtime-compaction metadata would diverge
    per shard anyway.  MXU skipping is forfeited on the emulated mesh;
    the mode dispatch and jit-set keying upstream stay identical.
    """
    m, k = a.shape
    kw, n = w.shape
    assert k == kw, (k, kw)
    if spmd:
        from .ref import sparse_a_ref
        return sparse_a_ref(a, w)
    if meta is None:
        meta = compact_activations(a, block_m=block_m, block_k=block_k)
    bm, bk = meta.block_m, meta.block_k
    bn = min(block_n, _rup(n))
    pn = -(-n // bn) * bn
    ap = _pad2(a, meta.m, meta.k)
    wp = _pad2(w, meta.k, pn)
    out = _run(ap, wp, meta.kidx, meta.cnt, block_m=bm, block_k=bk,
               block_n=bn, interpret=interpret)
    return out[:m, :n]
