"""Public ops for Sparse.A (activation-sparse) execution on TPU.

``compact_activations`` builds the runtime metadata — the A-side analogue of
griffin_spmm's offline ``preprocess_weights``, except nothing is known until
the activations exist, so compaction happens per call:

  - on **concrete** arrays (op level, serving with host-visible tensors) the
    metadata is built in numpy and ``max_cnt`` is the true maximum live
    count, so the kernel grid physically shrinks (real compaction);
  - on **traced** arrays (inside jit) grid shapes must be static before the
    values exist, so the metadata is built with jnp at the full K depth and
    skipping degrades to trailing predicated no-ops — MXU work is still
    saved, grid depth is not (DESIGN.md Section 5).

``sparse_a_matmul`` pads, compacts, and runs the kernel.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import sparse_a_gemm_kernel

DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_K = 128
DEFAULT_BLOCK_N = 128


@dataclasses.dataclass
class ActivationMeta:
    """Per-M-tile live-K-block metadata for one activation matrix."""

    kidx: jax.Array          # (m_tiles, max_cnt) int32
    cnt: jax.Array           # (m_tiles,) int32
    m: int                   # padded M
    k: int                   # padded K
    block_m: int
    block_k: int

    @property
    def density(self) -> float:
        """Fraction of live (block_m x block_k) A blocks (concrete only)."""
        mt, kt = self.m // self.block_m, self.k // self.block_k
        return float(np.asarray(self.cnt).sum()) / max(mt * kt, 1)

    @property
    def compaction(self) -> float:
        """Grid-depth compaction vs dense: max_cnt / k_tiles (lower is
        better; 1.0 when built under jit — static-shape fallback)."""
        return self.kidx.shape[1] / (self.k // self.block_k)


def _rup(x: int, base: int = 8) -> int:
    return max(base, -(-x // base) * base)


def _pad2(x: jax.Array, p0: int, p1: int) -> jax.Array:
    if p0 > x.shape[0] or p1 > x.shape[1]:
        x = jnp.pad(x, ((0, p0 - x.shape[0]), (0, p1 - x.shape[1])))
    return x


def compact_activations(a: jax.Array, *, block_m: int = DEFAULT_BLOCK_M,
                        block_k: int = DEFAULT_BLOCK_K) -> ActivationMeta:
    """Runtime compaction: list the K blocks each M tile must visit.

    Concrete ``a`` -> numpy metadata with the true (minimal) ``max_cnt``;
    traced ``a`` -> jnp metadata at full K depth (static shapes under jit).
    """
    m, k = a.shape
    bm = min(block_m, _rup(m))
    bk = min(block_k, _rup(k))
    pm, pk = -(-m // bm) * bm, -(-k // bk) * bk
    mt, kt = pm // bm, pk // bk
    if isinstance(a, jax.core.Tracer):
        ap = _pad2(a, pm, pk)
        nz = (ap.reshape(mt, bm, kt, bk) != 0).any(axis=(1, 3))   # (mt, kt)
        cnt = nz.sum(axis=1).astype(jnp.int32)
        # stable sort: live blocks first, original k order preserved; dead
        # trailing entries hold valid ids (DMA'd but predicated off).
        kidx = jnp.argsort(~nz, axis=1, stable=True).astype(jnp.int32)
        return ActivationMeta(kidx=kidx, cnt=cnt, m=pm, k=pk,
                              block_m=bm, block_k=bk)
    a_np = np.zeros((pm, pk), dtype=np.asarray(a).dtype)
    a_np[:m, :k] = np.asarray(a)
    nz = (a_np.reshape(mt, bm, kt, bk) != 0).any(axis=(1, 3))
    cnt = nz.sum(axis=1).astype(np.int32)
    max_cnt = max(int(cnt.max()), 1)
    kidx = np.zeros((mt, max_cnt), dtype=np.int32)
    for i in range(mt):
        ks = np.flatnonzero(nz[i])
        kidx[i, :len(ks)] = ks
        if len(ks) < max_cnt:                                     # clamp pad
            kidx[i, len(ks):] = ks[-1] if len(ks) else 0
    return ActivationMeta(kidx=jnp.asarray(kidx), cnt=jnp.asarray(cnt),
                          m=pm, k=pk, block_m=bm, block_k=bk)


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "block_n",
                                             "interpret"))
def _run(a, b, kidx, cnt, *, block_m, block_k, block_n, interpret):
    return sparse_a_gemm_kernel(a, b, kidx, cnt, block_m=block_m,
                                block_k=block_k, block_n=block_n,
                                interpret=interpret)


# ---------------------------------------------------------------------------
# shard-local execution (SPMD via shard_map, DESIGN.md Section 10)
# ---------------------------------------------------------------------------

def sparse_a_matmul_shard(a, w, kidx, cnt, *, block_m: int, block_k: int,
                          block_n: int, interpret: bool = False) -> jax.Array:
    """Shard-local kernel entry: the raw sparse_a kernel on one device's
    N-slice of the dense weights.

    ``a`` and the runtime-compaction metadata are replicated — the
    metadata is per-*M-tile* (live K blocks of the activations), which an
    output-axis split never touches, so every shard skips exactly the
    same A blocks.  ``w`` arrives pre-sliced on N (``shard_specs``); each
    shard pads its slice up to its own block_n grid and unpads after, so
    uneven tile alignment at the global scale never forces a fallback.
    """
    n_local = w.shape[1]
    bn = min(block_n, _rup(n_local))
    pn = -(-n_local // bn) * bn
    out = sparse_a_gemm_kernel(a, _pad2(w, a.shape[1], pn), kidx, cnt,
                               block_m=block_m, block_k=block_k, block_n=bn,
                               interpret=interpret)
    return out[:, :n_local]


def shard_specs(axis: str = "model"):
    """(in_specs, out_spec) for ``sparse_a_matmul_shard`` over mesh axis
    ``axis``: only the weights (and the output) split, on N; activations
    and per-M-tile metadata replicate."""
    from jax.sharding import PartitionSpec as P
    return (P(), P(None, axis), P(), P()), P(None, axis)


def shardable(w, n_shards: int) -> bool:
    """True when the dense weights' output axis splits evenly (each shard
    re-pads locally, so N-tile alignment is not required)."""
    return w.ndim == 2 and n_shards >= 1 and w.shape[1] % n_shards == 0


def sparse_a_matmul(a: jax.Array, w: jax.Array, *,
                    block_m: int = DEFAULT_BLOCK_M,
                    block_k: int = DEFAULT_BLOCK_K,
                    block_n: int = DEFAULT_BLOCK_N,
                    meta: Optional[ActivationMeta] = None,
                    interpret: bool = False,
                    spmd: bool = False,
                    mesh=None, mesh_axis: str = "model") -> jax.Array:
    """C = A @ W visiting only the live A blocks (Sparse.A execution).

    ``mesh`` runs the **real kernel under SPMD** via ``shard_map``
    (DESIGN.md Section 10): metadata is compacted once (replicated — it is
    per-M-tile and the output-axis split never touches it), then every
    device runs ``sparse_a_matmul_shard`` on its N-slice of ``w`` with
    zero in-kernel collectives.  Requires ``shardable(w,
    mesh.shape[mesh_axis])``.

    ``spmd=True`` is the dense-product oracle (previously the only
    multi-device path): skipped A blocks are exactly zero, so the
    compacted product *is* the plain dense product (``ref.sparse_a_ref``),
    which GSPMD shards along W's output axis.  MXU skipping is forfeited;
    the mode dispatch and jit-set keying upstream stay identical.
    """
    m, k = a.shape
    kw, n = w.shape
    assert k == kw, (k, kw)
    if spmd:
        from .ref import sparse_a_ref
        return sparse_a_ref(a, w)
    if meta is None:
        meta = compact_activations(a, block_m=block_m, block_k=block_k)
    bm, bk = meta.block_m, meta.block_k
    ap = _pad2(a, meta.m, meta.k)
    if mesh is not None:
        from jax.experimental.shard_map import shard_map
        assert shardable(w, mesh.shape[mesh_axis]), \
            (w.shape, dict(mesh.shape), mesh_axis)
        in_specs, out_spec = shard_specs(mesh_axis)
        local = functools.partial(sparse_a_matmul_shard, block_m=bm,
                                  block_k=bk, block_n=block_n,
                                  interpret=interpret)
        out = shard_map(local, mesh=mesh, in_specs=in_specs,
                        out_specs=out_spec, check_rep=False)(
                            ap, _pad2(w, meta.k, n), meta.kidx, meta.cnt)
        return out[:m]
    bn = min(block_n, _rup(n))
    pn = -(-n // bn) * bn
    wp = _pad2(w, meta.k, pn)
    out = _run(ap, wp, meta.kidx, meta.cnt, block_m=bm, block_k=bk,
               block_n=bn, interpret=interpret)
    return out[:m, :n]
