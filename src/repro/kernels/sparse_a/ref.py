"""Pure-jnp oracle for the Sparse.A compacted GEMM."""
from __future__ import annotations

import jax.numpy as jnp


def sparse_a_ref(a, b, out_dtype=None):
    """Oracle: skipped A blocks are exactly zero, so the compacted product
    must equal the plain dense product (bit-matching in f32, tolerance in
    low precision)."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(
        out_dtype or a.dtype)
