"""Griffin core: the paper's contribution as a composable library.

- spec:       parametric architecture definitions (borrowing distances)
- scheduler:  the cycle model (greedy on-the-fly + static packing bound)
- evaluate:   GEMM / network / category cycle evaluation
- functional: executes schedules numerically (exactness oracle)
- overhead:   Table II structures + calibrated 7nm power/area model
- efficiency: effective TOPS/W & TOPS/mm^2 (Definition V.1)
- dse:        design-space exploration (Figures 5-7)
- hybrid:     Griffin morphing (Section IV-B)
- workloads:  Table IV benchmark networks as GEMM streams
"""
from .spec import (CoreConfig, HybridSpec, Mode, SparseSpec, DENSE_BASELINE,
                   GRIFFIN, PRESETS, SPARSE_A_STAR, SPARSE_AB_STAR,
                   SPARSE_B_STAR, sparse_a, sparse_ab, sparse_b)
from .evaluate import (GemmCycles, GemmShape, MaskModel, Workload,
                       gemm_cycles, gemm_cycles_batched, network_speedup,
                       network_speedup_batched, category_speedup,
                       category_speedup_batched)
from .hybrid import (category_design_speedup, category_design_speedup_batched,
                     design_speedup, running_spec, select_mode)
from .efficiency import Efficiency, efficiency, sparsity_tax
from .overhead import power_area, structure

__all__ = [
    "CoreConfig", "HybridSpec", "Mode", "SparseSpec", "DENSE_BASELINE",
    "GRIFFIN", "PRESETS", "SPARSE_A_STAR", "SPARSE_AB_STAR", "SPARSE_B_STAR",
    "sparse_a", "sparse_ab", "sparse_b", "GemmCycles", "GemmShape",
    "MaskModel", "Workload", "gemm_cycles", "gemm_cycles_batched",
    "network_speedup", "network_speedup_batched", "category_speedup",
    "category_speedup_batched", "category_design_speedup",
    "category_design_speedup_batched", "design_speedup", "running_spec",
    "select_mode", "Efficiency", "efficiency", "sparsity_tax", "power_area",
    "structure",
]
