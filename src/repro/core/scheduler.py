"""The borrowing scheduler: the paper's cycle model.

The dense core executes a GEMM output tile by streaming T = ceil(K/K0)
*K-chunks*; each cycle one chunk's worth of MACs executes.  A sparse
architecture keeps a window of ``1 + d1`` consecutive chunks resident in the
operand buffer (ABUF/BBUF); each cycle every multiplier slot may execute one
effectual operation *borrowed* from anywhere in that window subject to the
routing limits:

  - time     (d1): the window spans chunks [f, f + d1]; the front f may
                   advance by at most ``1 + d1`` chunks per cycle (this is
                   also why the paper's ideal speedup is ``1 + d1`` and why
                   SRAM bandwidth must scale with speedup, Section V);
  - lane     (d2): an element in lane ``l`` may execute on lane ``l - dl``
                   for ``dl in [0, d2]`` (one-sided window; the MUX fan-in
                   formulas in Table II count ``1 + d2`` candidates);
  - cross-PE (d3): an element belonging to PE-group coordinate ``g`` may
                   execute on PE ``g - dg`` for ``dg in [0, d3]``, which
                   requires an extra adder tree to route the partial sum back.

Placement is greedy with the priority mechanism of Bit-Tactical [13]: oldest
chunk first (so the window can slide), then smallest lane distance, then
cross-PE.  The window cannot slide past an incomplete chunk (its buffer entry
is still live), which reproduces the stalls the paper attributes to
ABUF/BBUF fullness.

This single primitive scores every architecture family in the paper:
``Sparse.B`` runs it over the weight mask (G = N0 columns), ``Sparse.A`` over
the activation mask (G = M0 rows), and ``Sparse.AB`` runs it twice (offline B
compaction, then on-the-fly scheduling of the A side over the compacted
stream) — see :mod:`repro.core.evaluate`.

Everything is vectorized over a leading ``tiles`` axis with numpy, and — for
design-space exploration — additionally over a *stacked configuration axis*:
:func:`schedule_batched` and :func:`static_pack_cycles_batched` accept
per-row / per-config ``(d1, d2, d3, shuffle)`` parameter vectors so that
hundreds of ``SparseSpec`` points share one vectorized sweep instead of one
Python loop each.  The scalar :func:`schedule` / :func:`static_pack_cycles`
entry points are thin wrappers over the batched core and stay bit-exact.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

ParamLike = Union[int, Sequence[int], np.ndarray]


@dataclasses.dataclass
class Schedule:
    """Result of scheduling one batch of tiles.

    cycles:   (tiles,) int  — executed cycles per tile.
    placement (optional, when ``record=True``): for every source element
      (tiles, T, K0, G):
        cyc:  executed cycle index (-1 where mask is False / unplaced)
        lane: target lane it executes on
        grp:  target PE-group coordinate it executes on
    """

    cycles: np.ndarray
    cyc: Optional[np.ndarray] = None
    lane: Optional[np.ndarray] = None
    grp: Optional[np.ndarray] = None


def shuffle_lanes(mask: np.ndarray, chunk_axis: int = 1, lane_axis: int = 2,
                  rot: int = 4) -> np.ndarray:
    """Paper Section III 'Load Balancing': local rotation shuffling.

    Element at (chunk t, lane l) relocates to lane
    ``rot*(l//rot) + (l + t) % rot`` — a t-dependent rotation inside groups of
    ``rot`` consecutive lanes, implementable with (K0/rot) rot x rot
    crossbars.  Both A and B are shuffled identically along K, so correctness
    is preserved; the point is to spread a persistently-hot lane (a dense
    input channel) over its rotation group.
    """
    k0 = mask.shape[lane_axis]
    rot = min(rot, k0)
    t_idx = np.arange(mask.shape[chunk_axis])
    l_idx = np.arange(k0)
    new_lane = (l_idx[None, :] // rot) * rot + (l_idx[None, :] + t_idx[:, None]) % rot
    out = np.empty_like(mask)
    mask_m = np.moveaxis(mask, (chunk_axis, lane_axis), (0, 1))
    out_m = np.moveaxis(out, (chunk_axis, lane_axis), (0, 1))
    t_b = np.broadcast_to(t_idx[:, None], new_lane.shape)
    out_m[t_b, new_lane] = mask_m
    return out


def _offsets(d2: int, d3: int) -> List[Tuple[int, int]]:
    offs = [(dl, dg) for dg in range(d3 + 1) for dl in range(d2 + 1)]
    offs.sort(key=lambda o: (o[1], o[0]))  # own slot, then lane, then cross-PE
    return offs


def _param_vec(x: ParamLike, n: int, dtype=np.int64) -> np.ndarray:
    """Broadcast a scalar-or-vector config parameter to a (n,) array."""
    arr = np.asarray(x, dtype=dtype)
    if arr.ndim == 0:
        return np.full(n, arr, dtype=dtype)
    if arr.shape != (n,):
        raise ValueError(f"parameter vector must have shape ({n},), "
                         f"got {arr.shape}")
    return arr


def schedule_batched(mask: np.ndarray, d1: ParamLike, d2: ParamLike,
                     d3: ParamLike, shuffle: ParamLike = False,
                     record: bool = False,
                     t_len: Optional[ParamLike] = None,
                     backend: str = "numpy") -> Schedule:
    """Greedy sliding-window scheduling, vectorized over rows *and* configs.

    mask: (rows, T, K0, G) boolean — True where an effectual operation
    exists.  ``d1/d2/d3/shuffle`` may be scalars or per-row vectors, so one
    call can schedule the stacked tile streams of many ``SparseSpec``
    configurations at once; rows never interact, so the result is bit-exact
    with per-config scalar calls.  ``t_len`` optionally gives each row its
    own logical chunk count (rows are zero-padded up to the shared T); the
    trailing-stream accounting then uses the row's own length, which is what
    the dual-sparse stage-2 composition needs when stage-1 compaction depths
    differ per config.

    ``backend="jax"`` routes a homogeneous (scalar-config, cycles-only)
    call through the ``jax.vmap`` twin in
    :mod:`repro.kernels.batch_eval`; the numpy engine stays the general
    path.  Returns per-row executed-cycle counts (and placements if
    ``record``).
    """
    if mask.ndim != 4:
        raise ValueError(f"mask must be (tiles, T, K0, G), got {mask.shape}")
    if backend == "jax":
        if record or t_len is not None:
            raise ValueError("backend='jax' supports cycles-only scheduling "
                             "of full-length streams")
        params = [np.unique(np.asarray(p)) for p in (d1, d2, d3, shuffle)]
        if any(len(p) != 1 for p in params):
            raise ValueError("backend='jax' needs one shared config; "
                             "per-row parameter vectors are numpy-only")
        from repro.kernels.batch_eval.ops import schedule_cycles
        return Schedule(cycles=schedule_cycles(
            mask, int(params[0][0]), int(params[1][0]), int(params[2][0]),
            shuffle=bool(params[3][0])))
    if backend != "numpy":
        raise ValueError(f"unknown backend {backend!r}")
    ntiles, T, K0, G = mask.shape
    d1v = _param_vec(d1, ntiles)
    d2v = _param_vec(d2, ntiles)
    d3v = _param_vec(d3, ntiles)
    shv = _param_vec(shuffle, ntiles, dtype=bool)
    tl = _param_vec(T if t_len is None else t_len, ntiles)
    # A row pays every other row's (1 + d1) x offsets placement steps in the
    # shared per-cycle pass, but splitting the batch also undoes the
    # iteration merging that makes batching fast (one max-trip loop instead
    # of summed per-config loops).  Compromise: bucket the window tuples by
    # per-cycle unroll cost so rows only share a loop with rows within 8x of
    # their own cost — deep windows (Cnvlutin-style lookahead-15) split off,
    # ordinary DSE neighbourhoods stay merged.  Rows never interact, so any
    # partition is bit-exact with the per-row result.
    unroll = (d1v + 1) * (1 + d2v) * (1 + d3v)
    order = np.argsort(unroll, kind="stable")
    buckets: List[np.ndarray] = []
    start = 0
    for i in range(1, ntiles + 1):
        if i == ntiles or unroll[order[i]] > 8 * unroll[order[start]]:
            buckets.append(np.sort(order[start:i]))
            start = i
    if len(buckets) > 1:
        cycles = np.zeros(ntiles, dtype=np.int64)
        rec = [np.full(mask.shape, -1, dtype=dt)
               for dt in (np.int32, np.int16, np.int16)] if record else None
        for sel in buckets:
            sub = _schedule_rows(mask[sel], d1v[sel], d2v[sel], d3v[sel],
                                 shv[sel], record, tl[sel], t_len is not None)
            cycles[sel] = sub.cycles
            if record:
                rec[0][sel], rec[1][sel], rec[2][sel] = \
                    sub.cyc, sub.lane, sub.grp
        if record:
            return Schedule(cycles=cycles, cyc=rec[0], lane=rec[1],
                            grp=rec[2])
        return Schedule(cycles=cycles)
    return _schedule_rows(mask, d1v, d2v, d3v, shv, record, tl,
                          t_len is not None)


def _schedule_rows(mask: np.ndarray, d1v: np.ndarray, d2v: np.ndarray,
                   d3v: np.ndarray, shv: np.ndarray, record: bool,
                   tl: np.ndarray, has_t_len: bool) -> Schedule:
    """Mixed-window scheduling core over one cost bucket of rows."""
    ntiles, T, K0, G = mask.shape
    t_len = tl if has_t_len else None
    if T == 0 or ntiles == 0:
        return Schedule(cycles=np.zeros(ntiles, dtype=np.int64))
    if shv.any():
        shuffled = shuffle_lanes(mask, chunk_axis=1, lane_axis=2)
        mask = np.where(shv[:, None, None, None], shuffled, mask)

    R = mask.copy()                                    # remaining elements
    if t_len is not None:
        R &= (np.arange(T)[None, :] < tl[:, None])[:, :, None, None]
    chunk_any = R.any(axis=(2, 3))                     # (tiles, T)
    rem = chunk_any.any(axis=1)                        # tiles still working
    f = np.zeros(ntiles, dtype=np.int64)               # window front
    cycles = np.zeros(ntiles, dtype=np.int64)
    win = d1v + 1                                      # (tiles,)
    max_win = int(win.max())
    t_grid = np.arange(T)
    orig = np.arange(ntiles)                           # row -> output slot
    out_cycles = np.zeros(ntiles, dtype=np.int64)

    def offsets_for(d2a: np.ndarray, d3a: np.ndarray
                    ) -> List[Tuple[int, int, Optional[np.ndarray]]]:
        # per-offset row gating is loop-invariant between compactions
        out = []
        for (dl, dg) in _offsets(int(d2a.max()), int(d3a.max())):
            allow = (dl <= d2a) & (dg <= d3a)
            if allow.any():
                out.append((dl, dg,
                            None if allow.all() else allow[:, None, None]))
        return out

    offs = offsets_for(d2v, d3v)

    if record:
        rec_cyc = np.full(mask.shape, -1, dtype=np.int32)
        rec_lane = np.full(mask.shape, -1, dtype=np.int16)
        rec_grp = np.full(mask.shape, -1, dtype=np.int16)

    def finalize(sel: np.ndarray) -> None:
        # trailing (and fully-zero) chunk runs still stream the window
        tail = np.maximum(tl[sel] - f[sel], 0)
        out_cycles[orig[sel]] = cycles[sel] + -(-tail // win[sel])

    t_grid32 = t_grid.astype(np.int32)

    # fast-forward leading all-zero chunks (they cost ceil(run/win) cycles)
    def _advance(front: np.ndarray, active: np.ndarray) -> np.ndarray:
        """Next front: earliest incomplete chunk, at most ``win`` ahead."""
        cand = np.where(chunk_any & (t_grid[None, :] >= front[:, None]),
                        t_grid32[None, :], tl[:, None].astype(np.int32))
        nxt = cand.min(axis=1).astype(np.int64)
        return np.where(active, np.minimum(nxt, front + win), front)

    # initial leading-zeros jump is folded into the main loop accounting: the
    # first cycle's window starts at chunk 0 like the hardware's.
    while rem.any():
        # Rows finish at very different cycles (that spread is the whole
        # point of the cycle model); once the finished majority would
        # dominate the per-iteration cost, retire them and keep looping
        # over the survivors only.  Pure reindexing — bit-exact.
        nact = R.shape[0]
        if nact > 64 and int(rem.sum()) * 2 < nact:
            finalize(np.flatnonzero(~rem))
            keep = np.flatnonzero(rem)
            orig, R, chunk_any = orig[keep], R[keep], chunk_any[keep]
            f, cycles, rem = f[keep], cycles[keep], rem[keep]
            win, tl, d2v, d3v = win[keep], tl[keep], d2v[keep], d3v[keep]
            max_win = int(win.max())
            offs = offsets_for(d2v, d3v)
            nact = R.shape[0]
        tile_ix = np.arange(nact)
        occ = np.zeros((nact, K0, G), dtype=bool)
        occ[~rem] = True                               # freeze finished tiles
        for dt in range(max_win):                      # oldest chunk first
            tt = f + dt
            valid = rem & (tt < tl) & (dt < win)
            if not valid.any():
                break
            ttc = np.minimum(tt, T - 1)
            chunk = R[tile_ix, ttc] & valid[:, None, None]   # (rows, K0, G)
            if not chunk.any():
                continue
            for (dl, dg, allow) in offs:
                # source element (l, g) -> slot (l - dl, (g - dg) mod G):
                # lanes are a one-sided window (Table II fan-in 1 + d2), PE
                # borrowing is a ring within the window group (column n
                # borrows from n+dg mod G, one adder-tree hop).  Rows whose
                # config does not reach this offset are gated out (``allow``).
                src = chunk[:, dl:, :] if dl else chunk
                src = np.roll(src, -dg, axis=2) if dg and G > 1 else src
                occ_v = occ[:, :K0 - dl, :] if dl else occ
                put = src & ~occ_v
                if allow is not None:
                    put &= allow
                if not put.any():
                    continue
                if dl:
                    occ[:, :K0 - dl, :] |= put
                else:
                    occ |= put
                taken = np.roll(put, dg, axis=2) if dg and G > 1 else put
                if dl:
                    chunk[:, dl:, :] &= ~taken
                else:
                    chunk &= ~taken
                if record:
                    ti, lt, gt = np.nonzero(put)     # target coords
                    ls, gs = lt + dl, (gt + dg) % G  # source coords
                    rec_cyc[orig[ti], ttc[ti], ls, gs] = \
                        cycles[ti].astype(np.int32)
                    rec_lane[orig[ti], ttc[ti], ls, gs] = lt.astype(np.int16)
                    rec_grp[orig[ti], ttc[ti], ls, gs] = gt.astype(np.int16)
            R[tile_ix[valid], ttc[valid]] = chunk[valid]
            chunk_any[tile_ix[valid], ttc[valid]] = chunk[valid].any(axis=(1, 2))
        cycles[rem] += 1
        f = _advance(f, rem)
        rem = rem & chunk_any.any(axis=1)

    finalize(np.arange(R.shape[0]))
    if record:
        return Schedule(cycles=out_cycles, cyc=rec_cyc, lane=rec_lane,
                        grp=rec_grp)
    return Schedule(cycles=out_cycles)


def schedule(mask: np.ndarray, d1: int, d2: int, d3: int,
             shuffle: bool = False, record: bool = False) -> Schedule:
    """Greedy sliding-window scheduling of a nonzero mask (one config).

    mask: (tiles, T, K0, G) boolean — True where an effectual operation exists.
    Thin wrapper over :func:`schedule_batched` with a single shared config.
    Returns per-tile executed-cycle counts (and placements if ``record``).
    """
    return schedule_batched(mask, d1, d2, d3, shuffle=shuffle, record=record)


def dense_cycles(T: int) -> int:
    """Cycles the dense baseline needs for the same stream."""
    return T


def static_pack_cycles_batched(mask: np.ndarray, d1: ParamLike, d2: ParamLike,
                               d3: ParamLike, shuffle: ParamLike = False,
                               max_chunk_elems: int = 1 << 24) -> np.ndarray:
    """Offline packing bound, vectorized over a stacked config axis.

    mask: (tiles, T, K0, G) — the *shared* tile streams (G is the window
    group).  ``d1/d2/d3/shuffle`` are scalars or (configs,)-vectors; because
    the offline bound only reads the mask through per-interval pool counts,
    the (tiles x intervals) tables are computed once per distinct
    lane-fungibility width and shared by every config with that width —
    that sharing is where the DSE batching wins.  Returns (configs, tiles)
    cycle counts, bit-exact with per-config :func:`static_pack_cycles`.

    See :func:`static_pack_cycles` for the model itself.
    """
    ntiles, T, K0, G = mask.shape
    nconf = max(np.asarray(d1).shape[0] if np.asarray(d1).ndim else 1,
                np.asarray(d2).shape[0] if np.asarray(d2).ndim else 1,
                np.asarray(d3).shape[0] if np.asarray(d3).ndim else 1,
                np.asarray(shuffle).shape[0] if np.asarray(shuffle).ndim else 1)
    d1v = _param_vec(d1, nconf)
    d2v = _param_vec(d2, nconf)
    d3v = _param_vec(d3, nconf)
    shv = _param_vec(shuffle, nconf, dtype=bool)
    out = np.zeros((nconf, ntiles), dtype=np.int64)
    if T == 0 or ntiles == 0:
        return out
    win = d1v + 1                                       # (configs,)
    # fungibility width along lanes, per config
    w_all = np.minimum(K0, np.where(shv, 4, 1) * (1 + d2v))
    travel_total = -(-T // win)                         # (configs,)
    stride = 1 if T <= 32 else 3
    us = np.unique(np.concatenate([np.arange(0, T, stride), [0]]))
    vs = np.unique(np.concatenate([np.arange(stride, T + 1, stride), [T]]))
    spanv = vs[None, :] - us[:, None]                   # (nu, nv) chunk spans
    # The travel term depends on an interval only through its span, and the
    # ceil-divide commutes with max, so the per-config reduction collapses
    # the (nu, nv, ngrp) interval grid to the distinct positive spans:
    #   best = max over spans s:  ceil(maxcnt(tile, s) / cap) + trav(s).
    spans = np.unique(spanv[spanv > 0])                 # (nspan,)
    span_sel = [np.nonzero((spanv == s).ravel())[0] for s in spans]
    for wv in np.unique(w_all):
        conf_ix = np.flatnonzero(w_all == wv)
        ngrp = -(-K0 // int(wv))
        pad_k = ngrp * int(wv)
        m = np.zeros((ntiles, T, pad_k, G), dtype=np.int32)
        m[:, :, :K0, :] = mask
        # pool counts per (tile, chunk, lane-group); d3 pools the whole G axis
        counts = m.reshape(ntiles, T, ngrp, int(wv), G).sum(axis=(3, 4))
        cap = int(wv) * G
        # prefix sums over chunks for all interval counts
        P = np.concatenate([np.zeros((ntiles, 1, ngrp), np.int32),
                            np.cumsum(counts, axis=1, dtype=np.int32)], axis=1)
        # count_g([u,v]) = P[v+1] - P[u].  The full (T x T) interval grid is
        # O(T^2); a strided grid (always including u=0 and v=T) finds the
        # binding interval to within the stride while keeping the lane-total
        # and travel bounds exact.  The interval table is config-independent
        # (shared by every config with this fungibility width); max over the
        # lane groups streams one group at a time to bound peak memory.
        cntmax = np.full((ntiles, len(us) * len(vs)), np.iinfo(np.int32).min,
                         dtype=np.int32)
        buf = np.empty((ntiles, len(us), len(vs)), dtype=np.int32)
        for g in range(ngrp):
            Pg = P[:, :, g]
            np.subtract(Pg[:, None, vs], Pg[:, us, None], out=buf)
            np.maximum(cntmax, buf.reshape(ntiles, -1), out=cntmax)
        # reduce intervals to their span before the config loop
        cnt_span = np.empty((ntiles, len(spans)), dtype=np.int32)
        for si, sel in enumerate(span_sel):
            cnt_span[:, si] = cntmax[:, sel].max(axis=1)
        need_span = -(-cnt_span.astype(np.int64) // cap)  # (tiles, nspan)
        # per config: travel for the chunks outside the binding interval
        rest = T - (spans[None, :] + d1v[conf_ix, None])
        trav = np.where(rest > 0, -(-rest // win[conf_ix, None]), 0)
        step = max(1, max_chunk_elems // max(1, ntiles * len(spans)))
        for lo in range(0, len(conf_ix), step):
            sel = conf_ix[lo:lo + step]
            tot = need_span[None] + trav[lo:lo + step, None, :]
            out[sel] = tot.max(axis=2)
    return np.maximum(out, travel_total[:, None])


def static_pack_cycles(mask: np.ndarray, d1: int, d2: int, d3: int,
                       shuffle: bool = False) -> np.ndarray:
    """Offline (preprocessing-time) packing model for the static B stream.

    Bit-Tactical-style preprocessing schedules each lane's *compressed*
    stream offline; a lane therefore never idles while it still has work,
    except when the activation window pins it: every element executed at
    cycle c must have its original chunk within ``1 + d1`` chunks of the
    cycle's window base, and bases advance monotonically.  The achievable
    makespan is the classic window-capacity bound:

      cycles = max(  ceil(T / (1+d1)),                           # travel
                     max over chunk intervals I, lane groups g:
                        ceil(count_g(I) / cap_g) + travel(T - span(I)) )

    where lanes are *fungible* within a group when the shuffler (rotation
    groups of 4) and/or lane borrowing (d2) can move work between them, and
    d3 additionally pools the (1+d3) columns of the window group.

    mask: (tiles, T, K0, G) — G is the (1+d3)-column window group.
    Returns per-tile cycle counts.  This is a tight *achievable* bound for
    offline packing (it is what the paper's preprocessing step computes),
    whereas :func:`schedule` models the on-the-fly datapath.  Thin wrapper
    over :func:`static_pack_cycles_batched` with one config.
    """
    return static_pack_cycles_batched(mask, int(d1), int(d2), int(d3),
                                      bool(shuffle))[0]


def sparten_tile_cycles(eff_counts: np.ndarray, pe_m: int = 32, pe_n: int = 32
                        ) -> np.ndarray:
    """SparTen-style per-PE intersection model.

    SparTen [18] assigns one output-stationary MAC per (m, n) output and skips
    to the next effectual (both-nonzero) pair with (very deep) prefix-sum
    buffers — no lane or cross-PE routing.  A wave of pe_m x pe_n outputs
    finishes when its slowest PE drains, so

      cycles(wave) = max_{(m,n) in wave} popcount(Amask[m] & Bmask[:, n]).

    eff_counts: (M, N) effectual-pair counts.  Returns per-wave cycles.
    """
    M, N = eff_counts.shape
    mt, nt = -(-M // pe_m), -(-N // pe_n)
    pad = np.zeros((mt * pe_m, nt * pe_n), dtype=eff_counts.dtype)
    pad[:M, :N] = eff_counts
    waves = pad.reshape(mt, pe_m, nt, pe_n).transpose(0, 2, 1, 3)
    return np.maximum(waves.max(axis=(2, 3)), 1)
