"""The borrowing scheduler: the paper's cycle model.

The dense core executes a GEMM output tile by streaming T = ceil(K/K0)
*K-chunks*; each cycle one chunk's worth of MACs executes.  A sparse
architecture keeps a window of ``1 + d1`` consecutive chunks resident in the
operand buffer (ABUF/BBUF); each cycle every multiplier slot may execute one
effectual operation *borrowed* from anywhere in that window subject to the
routing limits:

  - time     (d1): the window spans chunks [f, f + d1]; the front f may
                   advance by at most ``1 + d1`` chunks per cycle (this is
                   also why the paper's ideal speedup is ``1 + d1`` and why
                   SRAM bandwidth must scale with speedup, Section V);
  - lane     (d2): an element in lane ``l`` may execute on lane ``l - dl``
                   for ``dl in [0, d2]`` (one-sided window; the MUX fan-in
                   formulas in Table II count ``1 + d2`` candidates);
  - cross-PE (d3): an element belonging to PE-group coordinate ``g`` may
                   execute on PE ``g - dg`` for ``dg in [0, d3]``, which
                   requires an extra adder tree to route the partial sum back.

Placement is greedy with the priority mechanism of Bit-Tactical [13]: oldest
chunk first (so the window can slide), then smallest lane distance, then
cross-PE.  The window cannot slide past an incomplete chunk (its buffer entry
is still live), which reproduces the stalls the paper attributes to
ABUF/BBUF fullness.

This single primitive scores every architecture family in the paper:
``Sparse.B`` runs it over the weight mask (G = N0 columns), ``Sparse.A`` over
the activation mask (G = M0 rows), and ``Sparse.AB`` runs it twice (offline B
compaction, then on-the-fly scheduling of the A side over the compacted
stream) — see :mod:`repro.core.evaluate`.

Everything is vectorized over a leading ``tiles`` axis with numpy; the only
Python-level loop is over executed cycles.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Schedule:
    """Result of scheduling one batch of tiles.

    cycles:   (tiles,) int  — executed cycles per tile.
    placement (optional, when ``record=True``): for every source element
      (tiles, T, K0, G):
        cyc:  executed cycle index (-1 where mask is False / unplaced)
        lane: target lane it executes on
        grp:  target PE-group coordinate it executes on
    """

    cycles: np.ndarray
    cyc: Optional[np.ndarray] = None
    lane: Optional[np.ndarray] = None
    grp: Optional[np.ndarray] = None


def shuffle_lanes(mask: np.ndarray, chunk_axis: int = 1, lane_axis: int = 2,
                  rot: int = 4) -> np.ndarray:
    """Paper Section III 'Load Balancing': local rotation shuffling.

    Element at (chunk t, lane l) relocates to lane
    ``rot*(l//rot) + (l + t) % rot`` — a t-dependent rotation inside groups of
    ``rot`` consecutive lanes, implementable with (K0/rot) rot x rot
    crossbars.  Both A and B are shuffled identically along K, so correctness
    is preserved; the point is to spread a persistently-hot lane (a dense
    input channel) over its rotation group.
    """
    k0 = mask.shape[lane_axis]
    rot = min(rot, k0)
    t_idx = np.arange(mask.shape[chunk_axis])
    l_idx = np.arange(k0)
    new_lane = (l_idx[None, :] // rot) * rot + (l_idx[None, :] + t_idx[:, None]) % rot
    out = np.empty_like(mask)
    mask_m = np.moveaxis(mask, (chunk_axis, lane_axis), (0, 1))
    out_m = np.moveaxis(out, (chunk_axis, lane_axis), (0, 1))
    t_b = np.broadcast_to(t_idx[:, None], new_lane.shape)
    out_m[t_b, new_lane] = mask_m
    return out


def _offsets(d2: int, d3: int) -> List[Tuple[int, int]]:
    offs = [(dl, dg) for dg in range(d3 + 1) for dl in range(d2 + 1)]
    offs.sort(key=lambda o: (o[1], o[0]))  # own slot, then lane, then cross-PE
    return offs


def schedule(mask: np.ndarray, d1: int, d2: int, d3: int,
             shuffle: bool = False, record: bool = False) -> Schedule:
    """Greedy sliding-window scheduling of a nonzero mask.

    mask: (tiles, T, K0, G) boolean — True where an effectual operation exists.
    Returns per-tile executed-cycle counts (and placements if ``record``).
    """
    if mask.ndim != 4:
        raise ValueError(f"mask must be (tiles, T, K0, G), got {mask.shape}")
    if shuffle:
        mask = shuffle_lanes(mask, chunk_axis=1, lane_axis=2)
    ntiles, T, K0, G = mask.shape
    if T == 0:
        return Schedule(cycles=np.zeros(ntiles, dtype=np.int64))

    R = mask.copy()                                    # remaining elements
    chunk_any = R.any(axis=(2, 3))                     # (tiles, T)
    rem = chunk_any.any(axis=1)                        # tiles still working
    f = np.zeros(ntiles, dtype=np.int64)               # window front
    cycles = np.zeros(ntiles, dtype=np.int64)
    offs = _offsets(d2, d3)
    win = d1 + 1
    t_grid = np.arange(T)
    tile_ix = np.arange(ntiles)

    if record:
        rec_cyc = np.full(mask.shape, -1, dtype=np.int32)
        rec_lane = np.full(mask.shape, -1, dtype=np.int16)
        rec_grp = np.full(mask.shape, -1, dtype=np.int16)

    # fast-forward leading all-zero chunks (they cost ceil(run/win) cycles)
    def _advance(front: np.ndarray, active: np.ndarray) -> np.ndarray:
        """Next front: earliest incomplete chunk, at most ``win`` ahead."""
        cand = np.where(chunk_any & (t_grid[None, :] >= front[:, None]),
                        t_grid[None, :], T)
        nxt = cand.min(axis=1)
        return np.where(active, np.minimum(nxt, front + win), front)

    # initial leading-zeros jump is folded into the main loop accounting: the
    # first cycle's window starts at chunk 0 like the hardware's.
    while rem.any():
        occ = np.zeros((ntiles, K0, G), dtype=bool)
        occ[~rem] = True                               # freeze finished tiles
        for dt in range(win):                          # oldest chunk first
            tt = f + dt
            valid = rem & (tt < T)
            if not valid.any():
                break
            ttc = np.minimum(tt, T - 1)
            chunk = R[tile_ix, ttc] & valid[:, None, None]   # (tiles, K0, G)
            if not chunk.any():
                continue
            for (dl, dg) in offs:
                # source element (l, g) -> slot (l - dl, (g - dg) mod G):
                # lanes are a one-sided window (Table II fan-in 1 + d2), PE
                # borrowing is a ring within the window group (column n
                # borrows from n+dg mod G, one adder-tree hop).
                src = chunk[:, dl:, :] if dl else chunk
                src = np.roll(src, -dg, axis=2) if dg else src
                occ_v = occ[:, :K0 - dl, :] if dl else occ
                put = src & ~occ_v
                if not put.any():
                    continue
                if dl:
                    occ[:, :K0 - dl, :] |= put
                else:
                    occ |= put
                taken = np.roll(put, dg, axis=2) if dg else put
                if dl:
                    chunk[:, dl:, :] &= ~taken
                else:
                    chunk &= ~taken
                if record:
                    ti, lt, gt = np.nonzero(put)     # target coords
                    ls, gs = lt + dl, (gt + dg) % G  # source coords
                    rec_cyc[ti, ttc[ti], ls, gs] = cycles[ti].astype(np.int32)
                    rec_lane[ti, ttc[ti], ls, gs] = lt.astype(np.int16)
                    rec_grp[ti, ttc[ti], ls, gs] = gt.astype(np.int16)
            R[tile_ix[valid], ttc[valid]] = chunk[valid]
            chunk_any[tile_ix[valid], ttc[valid]] = chunk[valid].any(axis=(1, 2))
        cycles[rem] += 1
        f = _advance(f, rem)
        rem = rem & chunk_any.any(axis=1)

    # trailing (and fully-zero) chunk runs still stream through the window
    tail = np.maximum(T - f, 0)
    cycles += -(-tail // win)
    if record:
        return Schedule(cycles=cycles, cyc=rec_cyc, lane=rec_lane, grp=rec_grp)
    return Schedule(cycles=cycles)


def dense_cycles(T: int) -> int:
    """Cycles the dense baseline needs for the same stream."""
    return T


def static_pack_cycles(mask: np.ndarray, d1: int, d2: int, d3: int,
                       shuffle: bool = False) -> np.ndarray:
    """Offline (preprocessing-time) packing model for the static B stream.

    Bit-Tactical-style preprocessing schedules each lane's *compressed*
    stream offline; a lane therefore never idles while it still has work,
    except when the activation window pins it: every element executed at
    cycle c must have its original chunk within ``1 + d1`` chunks of the
    cycle's window base, and bases advance monotonically.  The achievable
    makespan is the classic window-capacity bound:

      cycles = max(  ceil(T / (1+d1)),                           # travel
                     max over chunk intervals I, lane groups g:
                        ceil(count_g(I) / cap_g) + travel(T - span(I)) )

    where lanes are *fungible* within a group when the shuffler (rotation
    groups of 4) and/or lane borrowing (d2) can move work between them, and
    d3 additionally pools the (1+d3) columns of the window group.

    mask: (tiles, T, K0, G) — G is the (1+d3)-column window group.
    Returns per-tile cycle counts.  This is a tight *achievable* bound for
    offline packing (it is what the paper's preprocessing step computes),
    whereas :func:`schedule` models the on-the-fly datapath.
    """
    ntiles, T, K0, G = mask.shape
    if T == 0:
        return np.zeros(ntiles, dtype=np.int64)
    win = d1 + 1
    # fungibility width along lanes
    w = min(K0, (4 if shuffle else 1) * (1 + d2))
    ngrp = -(-K0 // w)
    pad_k = ngrp * w
    m = np.zeros((ntiles, T, pad_k, G), dtype=np.int32)
    m[:, :, :K0, :] = mask
    # pool counts: per (tile, chunk, lane-group); d3 pools the whole G axis
    counts = m.reshape(ntiles, T, ngrp, w, G).sum(axis=(3, 4))  # (tiles,T,ngrp)
    cap = w * G
    # prefix sums over chunks for all interval counts
    P = np.concatenate([np.zeros((ntiles, 1, ngrp), np.int64),
                        np.cumsum(counts, axis=1)], axis=1)      # (tiles,T+1,ngrp)
    # count_g([u,v]) = P[v+1] - P[u].  The full (T x T) interval grid is
    # O(T^2); a strided grid (always including u=0 and v=T) finds the
    # binding interval to within the stride while keeping the lane-total and
    # travel bounds exact.
    best = np.zeros(ntiles, dtype=np.int64)
    travel_total = -(-T // win)
    stride = 1 if T <= 32 else 3
    us = np.unique(np.concatenate([np.arange(0, T, stride), [0]]))
    vs = np.unique(np.concatenate([np.arange(stride, T + 1, stride), [T]]))
    cnt = P[:, None, vs, :] - P[:, us, None, :]     # (tiles, nu, nv, ngrp)
    spanv = vs[None, :, None] - us[:, None, None]   # chunks in interval
    ok = spanv > 0
    need = -(-cnt // cap)
    rest = T - (spanv + d1)
    trav = np.where(rest > 0, -(-rest // win), 0)
    tot = np.where(ok[None], need + trav[None], 0)
    best = np.maximum(best, tot.max(axis=(1, 2, 3)))
    return np.maximum(best, travel_total).astype(np.int64)


def sparten_tile_cycles(eff_counts: np.ndarray, pe_m: int = 32, pe_n: int = 32
                        ) -> np.ndarray:
    """SparTen-style per-PE intersection model.

    SparTen [18] assigns one output-stationary MAC per (m, n) output and skips
    to the next effectual (both-nonzero) pair with (very deep) prefix-sum
    buffers — no lane or cross-PE routing.  A wave of pe_m x pe_n outputs
    finishes when its slowest PE drains, so

      cycles(wave) = max_{(m,n) in wave} popcount(Amask[m] & Bmask[:, n]).

    eff_counts: (M, N) effectual-pair counts.  Returns per-wave cycles.
    """
    M, N = eff_counts.shape
    mt, nt = -(-M // pe_m), -(-N // pe_n)
    pad = np.zeros((mt * pe_m, nt * pe_n), dtype=eff_counts.dtype)
    pad[:M, :N] = eff_counts
    waves = pad.reshape(mt, pe_m, nt, pe_n).transpose(0, 2, 1, 3)
    return np.maximum(waves.max(axis=(2, 3)), 1)
