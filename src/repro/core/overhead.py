"""Hardware overhead and 7nm power/area model (paper Table II, IV-A, VII).

Structural quantities (buffer depths, MUX fan-ins, adder trees, control
units) follow the paper's closed-form formulas exactly.  The translation to
milliwatts / kilo-um^2 uses per-unit costs fitted once against the paper's
own synthesis results (Table VII, Synopsys DC, 7nm, 800 MHz, 0.71 V); the
fit residuals are reported by ``benchmarks/table7_breakdown.py``.  SparTen's
microarchitecture (MAC-per-output, 128-deep prefix-sum buffers, no shared
accumulators) is outside this structural family, so its costs are taken from
Table VII directly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Union

from .spec import (CoreConfig, HybridSpec, Mode, SparseSpec, GRIFFIN,
                   SPARTEN_AB, SPARTEN_A, SPARTEN_B)


@dataclasses.dataclass(frozen=True)
class Structure:
    """Structural overheads of a design point (units: words / inputs / units)."""

    abuf_depth: int = 1
    bbuf_depth: int = 0
    abuf_words: int = 0           # total buffer words beyond the dense core
    bbuf_words: int = 0
    amux_fanin: int = 1
    bmux_fanin: int = 1
    amux_inputs: int = 0          # total extra mux inputs, all muxes
    bmux_inputs: int = 0
    extra_adders_per_pe: int = 0
    ctrl_units: int = 0           # per-PE controllers (dual) / per-row arbiters
    shuffler: bool = False
    dual: bool = False
    a_window: int = 1             # 1 + da1 (SRAM banking for B-side fetch)
    b_window: int = 1             # 1 + db1 (SRAM banking for A-side fetch)


def structure(spec: SparseSpec, core: CoreConfig) -> Structure:
    """Table II (single sparse) and Section IV-A (dual) structural formulas."""
    k0, n0, m0 = core.k0, core.n0, core.m0
    a1, a2, a3 = spec.a_window
    b1, b2, b3 = spec.b_window
    use_a, use_b = spec.supports_a, spec.supports_b
    if use_a and use_b:
        L = (1 + a1) * (1 + b1)
        abuf_depth, bbuf_depth = L, 1 + b1
        amux_fanin = 1 + (L - 1) * (1 + a2 + b2) * (1 + a3)
        bmux_fanin = 1 + a1 * (1 + a2)
        extra_adders = max(a3, b3, a3 * b3)
        ctrl = n0 * m0                       # per-PE zero-mask/arbiter logic
    elif use_b:
        abuf_depth, bbuf_depth = 1 + b1, 0
        amux_fanin = (1 + b1) * (1 + b2)
        bmux_fanin = 1
        extra_adders = b3
        ctrl = 0                             # metadata-driven, no arbiter
    elif use_a:
        abuf_depth, bbuf_depth = 1 + a1, 1 + a1
        amux_fanin = (1 + a1) * (1 + a2) * (1 + a3)
        bmux_fanin = (1 + a1) * (1 + a2)
        extra_adders = a3
        ctrl = m0                            # one arbiter per PE row
    else:
        return Structure(shuffler=spec.shuffle)
    abuf_words = max(abuf_depth - 1, 0) * k0 * m0
    bbuf_words = bbuf_depth * k0 * n0 if bbuf_depth else 0
    # AMUX shared per (lane, column) across the M0 rows; BMUX shared per
    # (lane, row) across columns (Section III).
    amux_inputs = (amux_fanin - 1) * k0 * n0
    bmux_inputs = (bmux_fanin - 1) * k0 * m0
    return Structure(
        abuf_depth=abuf_depth, bbuf_depth=bbuf_depth,
        abuf_words=abuf_words, bbuf_words=bbuf_words,
        amux_fanin=amux_fanin, bmux_fanin=bmux_fanin,
        amux_inputs=amux_inputs, bmux_inputs=bmux_inputs,
        extra_adders_per_pe=extra_adders, ctrl_units=ctrl,
        shuffler=spec.shuffle, dual=use_a and use_b,
        a_window=1 + a1, b_window=1 + b1)


# ---------------------------------------------------------------------------
# power / area translation (fitted to Table VII; see module docstring)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CostModel:
    # dense core (Table VII baseline row)
    base_power_datapath: float = 118.1   # REG/WR + ACC + MUL + ADT (mW)
    base_power_sram: float = 33.3
    base_area_datapath: float = 41.5     # k-um^2
    base_area_sram: float = 176.0
    # fitted unit costs
    buf_uw_per_word: float = 23.4        # buffer power  (uW / word)
    buf_um2_per_word: float = 6.0        # buffer area   (um^2 / word)
    dual_buf_power: float = 1.2          # extra ports in the dual pipeline
    dual_buf_area: float = 2.6
    mux_uw_per_input: float = 3.4
    mux_um2_per_input: float = 6.3
    ctrl_mw_per_unit: float = 0.071      # per-PE controller (dual)
    ctrl_um2_per_unit: float = 0.032
    arb_mw_per_unit: float = 0.30        # per-row arbiter (Sparse.A)
    arb_um2_per_unit: float = 0.17
    adt_mw_per_tree: float = 0.085       # extra adder tree, per PE
    adt_um2_per_tree: float = 0.022
    shf_mw: float = 1.0                  # shuffler (<=1% of dense, Section VI-E)
    shf_um2: float = 1.3
    reg_mw_per_word: float = 18.0e-3     # pipeline regs scale with buffering
    # SRAM banking for windowed fetch (fitted: gamma_a from Sparse.A*,
    # gamma_b from Sparse.B*; cross-checked on Sparse.AB* within 3%)
    gamma_a: float = 0.67
    gamma_b: float = 0.25


DEFAULT_COST_MODEL = CostModel()

# SparTen costs measured by the paper (Table VII): (power mW, area k-um^2).
SPARTEN_COSTS = {"SparTen.AB": (991.0, 1139.0),
                 "SparTen.A": (700.0, 800.0),   # one-sided: ~70% of dual
                 "SparTen.B": (700.0, 800.0)}


@dataclasses.dataclass(frozen=True)
class PowerArea:
    power_mw: float
    area_kum2: float
    breakdown_power: Dict[str, float]
    breakdown_area: Dict[str, float]


def power_area(design: Union[SparseSpec, HybridSpec],
               core: CoreConfig = CoreConfig(),
               cm: CostModel = DEFAULT_COST_MODEL) -> PowerArea:
    """Total power/area of the *physical* design point.

    For a hybrid, the physical hardware is the dual-sparse base plus the
    morphing extras (wider metadata path, one global arbiter per row, larger
    BMUX fan-in — paper Table III): Griffin costs ~2 mW / ~4 k-um^2 over
    Sparse.AB* in Table VII.
    """
    hybrid_extra_p, hybrid_extra_a = 0.0, 0.0
    if isinstance(design, HybridSpec):
        base = design.base
        sa = structure(design.conf_a, core)
        sab = structure(base, core)
        # conf.A needs BMUX fan-in 5 vs 3 (Table III): extra mux inputs, plus
        # one global arbiter per row.
        extra_inputs = max(0, (sa.bmux_fanin - sab.bmux_fanin)) * core.k0 * core.m0
        hybrid_extra_p = extra_inputs * cm.mux_uw_per_input * 1e-3 + \
            core.m0 * cm.arb_mw_per_unit
        hybrid_extra_a = extra_inputs * cm.mux_um2_per_input * 1e-3 + \
            core.m0 * cm.arb_um2_per_unit
        spec = base
    else:
        spec = design
    if spec.name in SPARTEN_COSTS:
        p, a = SPARTEN_COSTS[spec.name]
        return PowerArea(p, a, {"total(paper)": p}, {"total(paper)": a})

    s = structure(spec, core)
    bp = cm.dual_buf_power if s.dual else 1.0
    ba = cm.dual_buf_area if s.dual else 1.0
    words = s.abuf_words + s.bbuf_words
    p_buf_a = s.abuf_words * cm.buf_uw_per_word * bp * 1e-3
    p_buf_b = s.bbuf_words * cm.buf_uw_per_word * bp * 1e-3
    p_mux = (s.amux_inputs + s.bmux_inputs) * cm.mux_uw_per_input * 1e-3
    p_ctrl = (s.ctrl_units * (cm.ctrl_mw_per_unit if s.dual
                              else cm.arb_mw_per_unit))
    p_adt = s.extra_adders_per_pe * core.n0 * core.m0 * cm.adt_mw_per_tree
    p_shf = cm.shf_mw if s.shuffler else 0.0
    p_reg = words * cm.reg_mw_per_word
    p_sram = cm.base_power_sram * (1 + cm.gamma_a * (s.a_window - 1) +
                                   cm.gamma_b * (s.b_window - 1))
    p_total = (cm.base_power_datapath + p_reg + p_buf_a + p_buf_b + p_mux +
               p_ctrl + p_adt + p_shf + p_sram)

    a_buf_a = s.abuf_words * cm.buf_um2_per_word * ba * 1e-3
    a_buf_b = s.bbuf_words * cm.buf_um2_per_word * (1.0 if not s.dual else 1.4) * 1e-3
    a_mux = (s.amux_inputs + s.bmux_inputs) * cm.mux_um2_per_input * 1e-3
    a_ctrl = s.ctrl_units * (cm.ctrl_um2_per_unit if s.dual
                             else cm.arb_um2_per_unit)
    a_adt = s.extra_adders_per_pe * core.n0 * core.m0 * cm.adt_um2_per_tree
    a_shf = cm.shf_um2 if s.shuffler else 0.0
    a_sram = cm.base_area_sram * (1 + 0.11 * (s.a_window - 1) +
                                  0.028 * (s.b_window - 1))
    a_total = (cm.base_area_datapath + a_buf_a + a_buf_b + a_mux + a_ctrl +
               a_adt + a_shf + a_sram)

    return PowerArea(
        power_mw=p_total + hybrid_extra_p,
        area_kum2=a_total + hybrid_extra_a,
        breakdown_power={
            "datapath": cm.base_power_datapath, "reg": p_reg,
            "abuf": p_buf_a, "bbuf": p_buf_b, "mux": p_mux, "ctrl": p_ctrl,
            "adt": p_adt, "shf": p_shf, "sram": p_sram,
            "hybrid": hybrid_extra_p},
        breakdown_area={
            "datapath": cm.base_area_datapath, "abuf": a_buf_a,
            "bbuf": a_buf_b, "mux": a_mux, "ctrl": a_ctrl, "adt": a_adt,
            "shf": a_shf, "sram": a_sram, "hybrid": hybrid_extra_a})


# Table VII ground truth for the fit check (power mW, area k-um^2).
TABLE_VII_TOTALS = {
    "Baseline": (151.0, 217.0),
    "Sparse.B*": (206.0, 258.0),
    "TCL.B": (209.0, 233.0),
    "Sparse.A*": (223.0, 253.0),
    "Sparse.AB*": (282.0, 282.0),
    "Griffin": (284.0, 286.0),
    "TDash.AB": (284.0, 276.0),
    "SparTen.AB": (991.0, 1139.0),
}
