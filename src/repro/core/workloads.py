"""Benchmark workloads of paper Table IV, expressed as GEMM streams.

Each network is the standard im2col lowering: a convolution with C_in x R x S
kernels over an H x W output grid is GEMM (M = H*W, K = C_in*R*S, N = C_out);
depthwise convolutions degenerate to per-channel (M, 9, 1) GEMMs — which is
exactly why MobileNetV2's dense latency is far above its MAC count, matching
the paper's 2.2e6-cycle figure.  Fully-connected layers have M = batch = 1.

The (B, A) sparsity ratios are the measured ones from Table IV.  Dense-cycle
totals are validated against the paper's "Dense latency" column in
``tests/test_workloads.py`` / ``benchmarks/table4_networks.py``.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

from .evaluate import GemmShape, Workload
from .spec import Mode

G = GemmShape


def _alexnet() -> Tuple[GemmShape, ...]:
    return (
        G(3025, 363, 96, q=121),     # conv1 11x11
        G(729, 1200, 128, count=2, q=25),   # conv2 5x5 (2 groups)
        G(169, 2304, 384, q=9),      # conv3 3x3
        G(169, 1728, 192, count=2, q=9),    # conv4 3x3 (2 groups)
        G(169, 3456, 256, q=9),      # conv5 3x3
        G(1, 9216, 4096),            # fc6
        G(1, 4096, 4096),            # fc7
        G(1, 4096, 1000),            # fc8
    )


def _googlenet() -> Tuple[GemmShape, ...]:
    # conv stem + representative inception branches with multiplicities
    return (
        G(12544, 147, 64, q=49),     # conv1 7x7/2
        G(3136, 64, 64), G(3136, 576, 192, q=9),
        # inception 3a/3b-style modules (x2)
        G(784, 192, 96, count=2), G(784, 864, 128, count=2, q=9),
        G(784, 192, 16, count=2), G(784, 400, 32, count=2, q=25),
        G(784, 192, 64, count=4),
        # inception 4a-e (x5)
        G(196, 512, 112, count=5), G(196, 1008, 224, count=5, q=9),
        G(196, 512, 24, count=5), G(196, 600, 64, count=5, q=25),
        G(196, 512, 64, count=10),
        # inception 5a/5b (x2)
        G(49, 832, 256, count=2), G(49, 1440, 320, count=2, q=9),
        G(49, 832, 32, count=2), G(49, 800, 128, count=2, q=25),
        G(49, 832, 128, count=4),
        G(1, 1024, 1000),            # fc
    )


def _resnet50() -> Tuple[GemmShape, ...]:
    return (
        G(12544, 147, 64, q=49),                               # conv1 7x7
        G(3136, 64, 64, count=3), G(3136, 576, 64, count=3, q=9),   # stage2
        G(3136, 64, 256, count=3), G(3136, 256, 64, count=2),
        G(784, 256, 128), G(784, 512, 128, count=3),           # stage3
        G(784, 1152, 128, count=4, q=9), G(784, 128, 512, count=4),
        G(196, 512, 256), G(196, 1024, 256, count=5),          # stage4
        G(196, 2304, 256, count=6, q=9), G(196, 256, 1024, count=6),
        G(49, 1024, 512), G(49, 2048, 512, count=2),           # stage5
        G(49, 4608, 512, count=3, q=9), G(49, 512, 2048, count=3),
        G(1, 2048, 1000),                                      # fc
    )


def _inceptionv3() -> Tuple[GemmShape, ...]:
    return (
        G(22201, 27, 32, q=9), G(22201, 288, 32, q=9), G(22201, 288, 64, q=9),  # stem
        G(5329, 576, 80, q=9), G(5329, 720, 192, q=9),
        # 35x35 modules (x3)
        G(1225, 288, 64, count=9), G(1225, 432, 64, count=6, q=25),
        G(1225, 576, 96, count=6, q=9),
        # 17x17 modules (x5)
        G(289, 768, 192, count=20), G(289, 1344, 192, count=15, q=7),
        # 8x8 modules (x2)
        G(64, 1280, 320, count=2), G(64, 1152, 384, count=8, q=9),
        G(64, 2048, 448, count=2), G(64, 4032, 384, count=2, q=9),
        G(1, 2048, 1000),
    )


def _mobilenetv2() -> Tuple[GemmShape, ...]:
    # (expand 1x1, depthwise 3x3, project 1x1).  Depthwise convolutions are
    # mapped channel-batched / block-diagonal (16 channels share a GEMM:
    # K = 16*9, N = 16, with 15/16 of B structurally zero) — the standard NPU
    # mapping; the structural zeros are skippable by the sparse datapath just
    # like pruned ones.
    return (
        G(12544, 27, 32, q=9),
        G(12544, 144, 16, count=2, q=9, depthwise=True), G(12544, 32, 16),
        G(12544, 16, 96), G(3136, 144, 16, count=6, q=9, depthwise=True), G(3136, 96, 24),
        G(3136, 24, 144, count=2), G(3136, 144, 16, count=18, q=9, depthwise=True),
        G(3136, 144, 24), G(784, 144, 32),
        G(784, 32, 192, count=3), G(784, 144, 16, count=36, q=9, depthwise=True),
        G(784, 192, 32, count=2), G(196, 192, 64),
        G(196, 64, 384, count=4), G(196, 144, 16, count=96, q=9, depthwise=True),
        G(196, 384, 64, count=3), G(196, 384, 96),
        G(196, 96, 576, count=3), G(196, 144, 16, count=108, q=9, depthwise=True),
        G(196, 576, 96, count=2), G(49, 576, 160),
        G(49, 160, 960, count=3), G(49, 144, 16, count=180, q=9, depthwise=True),
        G(49, 960, 160, count=2), G(49, 960, 320),
        G(49, 320, 1280), G(1, 1280, 1000),
    )


def _bert_mnli(seq: int = 64, layers: int = 12, d: int = 768,
               ff: int = 3072, heads: int = 12) -> Tuple[GemmShape, ...]:
    hd = d // heads
    return (
        G(seq, d, d, count=3 * layers),                 # QKV projections
        G(seq, hd, seq, count=heads * layers, b_static=False),   # scores
        G(seq, seq, hd, count=heads * layers, b_static=False),   # context
        G(seq, d, d, count=layers),                     # output proj
        G(seq, d, ff, count=layers), G(seq, ff, d, count=layers),
    )


def _scale_counts(gemms: Sequence[GemmShape], factor: float,
                  skip_head: int = 1, skip_tail: int = 1) -> Tuple[GemmShape, ...]:
    """Calibrate module multiplicity to the paper's dense-latency column.

    Our per-network GEMM lists are *representative* module reconstructions;
    scaling the repeated-module counts (never the stem / classifier) aligns
    the dense cycle total with Table IV so that speedups are measured over
    the same amount of work the paper measured.
    """
    import dataclasses
    out = []
    for i, g in enumerate(gemms):
        if skip_head <= i < len(gemms) - skip_tail:
            g = dataclasses.replace(g, count=max(1, round(g.count * factor)))
        out.append(g)
    return tuple(out)


# Table IV: (name, gemms, A sparsity, B sparsity, dense latency in cycles)
TABLE_IV: Dict[str, Tuple[Tuple[GemmShape, ...], float, float, float]] = {
    "AlexNet": (_alexnet(), 0.53, 0.89, 1.0e6),
    "GoogleNet": (_scale_counts(_googlenet(), 1.85), 0.37, 0.82, 2.2e6),
    "ResNet50": (_scale_counts(_resnet50(), 1.40), 0.43, 0.81, 4.8e6),
    "InceptionV3": (_scale_counts(_inceptionv3(), 1.45), 0.46, 0.79, 6.9e6),
    "MobileNetV2": (_scale_counts(_mobilenetv2(), 3.35), 0.52, 0.81, 2.2e6),
    "BERT": (_bert_mnli(), 0.0, 0.82, 5.3e6),
}


def paper_workloads() -> List[Workload]:
    return [Workload(name, gemms, a, b)
            for name, (gemms, a, b, _) in TABLE_IV.items()]


def paper_dense_latency(name: str) -> float:
    return TABLE_IV[name][3]


def category_workloads(mode: Mode) -> List[Workload]:
    """Benchmark sets per DNN category (paper Table I).

    DNN.dense runs everything dense; DNN.A keeps only activation sparsity
    (BERT gets a ReLU variant at ~50%, Table I "Transformer+ReLU"); DNN.B
    keeps only weight sparsity; DNN.AB keeps both.
    """
    out = []
    for name, (gemms, a, b, _) in TABLE_IV.items():
        if mode == Mode.DENSE:
            out.append(Workload(name, gemms, 0.0, 0.0))
        elif mode == Mode.A:
            a_eff = a if a > 0 else 0.5
            out.append(Workload(name + "+ReLU" if a == 0 else name,
                                gemms, a_eff, 0.0))
        elif mode == Mode.B:
            out.append(Workload(name, gemms, 0.0, b))
        else:
            a_eff = a if a > 0 else 0.5
            out.append(Workload(name, gemms, a_eff, b))
    return out
