"""Cycle evaluation of GEMMs and networks under sparse architectures.

Implements the performance side of the paper:

  - ``Sparse.B``  : offline compaction of the weight stream (preprocessing),
                    one schedule per N0-column group, reused by every M-tile.
  - ``Sparse.A``  : on-the-fly compaction of the activation stream, one
                    schedule per M0-row group, reused by every N-tile.
  - ``Sparse.AB`` : the 7-step dual pipeline (Fig. 3): stage 1 compacts B
                    offline with (db1,db2,db3); stage 2 schedules, per PE
                    column, the effectual (A nonzero AND B-slot filled) mask
                    over the *compacted* cycle base with (da1,da2,da3).  The
                    ABUF depth (1+da1)(1+db1) of Section IV-A is exactly the
                    original-chunk span this composition can reach.
  - ``joint``     : TensorDash-style dual sparsity WITHOUT preprocessing: a
                    single on-the-fly schedule of the pairwise-effectual mask
                    (used for TDash.AB; paper Section VI-C notes these designs
                    "do not exploit the benefits of weight preprocessing").
  - ``sparten``   : per-PE intersection model with very deep buffers.

Cycle counts include the paper's output-synchronization stalls (max over the
PE columns of a tile) and are exact for the greedy priority mechanism; SRAM
bandwidth is assumed scaled with speedup as in Section V.

Every evaluation level has a *batched* twin (``gemm_cycles_batched``,
``network_speedup_batched``, ``category_speedup_batched``) that scores a
whole stack of ``SparseSpec`` configurations in one vectorized pass: masks
are generated once per (workload, layer, seed) and the scheduler runs over
the stacked config axis (see :mod:`repro.core.scheduler`).  The batched
twins are bit-exact with per-spec scalar loops — ``tests/test_batched_parity``
asserts this — and are what :func:`repro.core.dse.sweep` drives.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .scheduler import (Schedule, schedule, schedule_batched, shuffle_lanes,
                        sparten_tile_cycles, static_pack_cycles,
                        static_pack_cycles_batched)
from .spec import CoreConfig, Mode, SparseSpec

# ---------------------------------------------------------------------------
# mask utilities
# ---------------------------------------------------------------------------


def random_mask(shape: Tuple[int, ...], density: float, rng: np.random.Generator
                ) -> np.ndarray:
    return rng.random(shape) < density


def _scales(n: int, cv: float, rng: np.random.Generator, block: int = 1,
            period: int = 0) -> np.ndarray:
    """Mean-1 lognormal scale factors; ``block`` repeats values in runs,
    ``period`` tiles a short pattern (for lane-periodic imbalance)."""
    if cv <= 0 or n == 0:
        return np.ones(n)
    s = float(np.sqrt(np.log1p(cv * cv)))
    if period:
        pat = rng.lognormal(mean=-0.5 * s * s, sigma=s, size=period)
        return np.tile(pat, -(-n // period))[:n]
    nb = -(-n // block)
    v = rng.lognormal(mean=-0.5 * s * s, sigma=s, size=nb)
    return np.repeat(v, block)[:n]


@dataclasses.dataclass(frozen=True)
class MaskModel:
    """Synthetic sparsity-pattern model for pruned weights / ReLU activations.

    Real pruned tensors are not i.i.d.: nonzeros cluster by input channel
    (blocks of q*q spatial taps share a channel's importance), by k-position
    within the dot-product unit (the "load imbalance between different k
    indices" the paper's shuffler targets — e.g. spatial-center taps survive
    magnitude pruning far more often than corners, and activation features
    fire with very different frequencies), and by output channel.  The cv_*
    knobs control those three coefficient of variations; they are calibrated
    once in EXPERIMENTS.md Section "Paper-validation" against the paper's own
    reported speedups and then frozen for every experiment.
    """

    chan_cv: float = 1.2    # per input-channel (k-block) importance: strong
                            # clustering of surviving weights / firing features;
                            # with lane-segment streaming this is exactly the
                            # "load imbalance between k indices" shuffle fixes
    lane_cv: float = 0.0    # extra periodic k-index imbalance (unused by default)
    col_cv: float = 0.30    # per output-channel imbalance (weights)
    row_cv_a: float = 0.10  # per-token/pixel activation imbalance (ReLU kills
                            # roughly uniformly across positions)

    def weight_mask(self, k: int, n: int, density: float,
                    rng: np.random.Generator, q: int = 1) -> np.ndarray:
        r = _scales(k, self.chan_cv, rng, block=max(q, 1))
        if self.lane_cv > 0:
            r = r * _scales(k, self.lane_cv, rng, period=16)
        c = _scales(n, self.col_cv, rng)
        return self._bern((k, n), density, r, c, rng)

    def act_mask(self, m: int, k: int, density: float,
                 rng: np.random.Generator, q: int = 1) -> np.ndarray:
        feat = _scales(k, self.chan_cv, rng, block=max(q, 1))
        if self.lane_cv > 0:
            feat = feat * _scales(k, self.lane_cv, rng, period=16)
        row = _scales(m, self.row_cv_a, rng)
        return self._bern((m, k), density, row, feat, rng)

    @staticmethod
    def _bern(shape, density, r, c, rng) -> np.ndarray:
        if density >= 0.999:
            return np.ones(shape, dtype=bool)
        p = np.clip(density * r[:, None] * c[None, :], 0.0, 1.0)
        mean = p.mean()
        if mean > 1e-9:
            p = np.clip(p * (density / mean), 0.0, 1.0)
        return rng.random(shape) < p


DEFAULT_MASK_MODEL = MaskModel()


def _pack_stream(mask: np.ndarray, k0: int, g0: int) -> np.ndarray:
    """Pack a (K, G_total) nonzero mask into (tiles, T, K0, G0) tile streams.

    Lane l of the dot-product unit streams its own *contiguous K segment*
    (k = l*T + t), exactly like Bit-Tactical's independent weight lanes;
    under output-stationary accumulation any K order is valid.  This packing
    is what gives the paper's load-balancing observations their bite: a run
    of surviving weights inside one channel becomes a same-lane burst, which
    shuffling (t-dependent lane rotation) spreads over the rotation group.
    G_total is tiled into groups of G0 (PE columns for B / rows for A).
    Padding is False (zeros), which is exact: padded positions are
    ineffectual.
    """
    K, Gt = mask.shape
    T = -(-K // k0)
    nt = -(-Gt // g0)
    pad = np.zeros((k0 * T, nt * g0), dtype=bool)
    pad[:K, :Gt] = mask
    # (K0, T, nt, G0) -> (nt, T, K0, G0)
    return pad.reshape(k0, T, nt, g0).transpose(2, 1, 0, 3)


@dataclasses.dataclass
class GemmCycles:
    dense: float
    sparse: float

    @property
    def speedup(self) -> float:
        return self.dense / max(self.sparse, 1e-9)


# ---------------------------------------------------------------------------
# single-sparse families
# ---------------------------------------------------------------------------


def _grouped_cycles(mask_2d: np.ndarray, k0: int, tile_g: int, sub_g: int,
                    d1: int, d2: int, d3: int, shuffle: bool,
                    static: bool = False) -> np.ndarray:
    """Schedule a (K, G_total) stream in window-groups of ``sub_g`` PEs.

    The operand buffer window (front) is private to each group of
    ``1 + d3`` PEs (a column's BBUF / a row's ABUF is its own; cross-PE
    borrowing couples only the d3-adjacent PEs into one window group).  The
    PEs of a tile re-synchronize at the tile boundary (paper: output
    synchronization stalls), so per-tile cycles are the max over its groups.
    Returns per-tile cycle counts.
    """
    tiles = _pack_stream(mask_2d, k0, sub_g)            # (ngroups, T, K0, sub)
    if static:
        # offline preprocessing packs optimally within the window (the
        # paper's Sparse.B preprocessing step); see static_pack_cycles.
        cycles = static_pack_cycles(tiles, d1, d2, d3, shuffle=shuffle)
    else:
        cycles = schedule(tiles, d1, d2, d3, shuffle=shuffle).cycles
    per_tile = -(-tile_g // sub_g)                      # groups per tile
    ngroups = tiles.shape[0]
    pad = -(-ngroups // per_tile) * per_tile
    padded = np.zeros(pad, dtype=np.int64)
    padded[:ngroups] = cycles
    return padded.reshape(-1, per_tile).max(axis=1)


def sparse_b_gemm_cycles(spec: SparseSpec, b_mask: np.ndarray, m: int,
                         core: CoreConfig) -> GemmCycles:
    """Weight-only sparsity.  b_mask: (K, N)."""
    K, N = b_mask.shape
    sub = min(1 + spec.db3, core.n0)
    per_tile = _grouped_cycles(b_mask, core.k0, core.n0, sub,
                               spec.db1, spec.db2, spec.db3, spec.shuffle,
                               static=True)
    m_tiles = -(-m // core.m0)
    T = -(-K // core.k0)
    dense = T * per_tile.shape[0] * m_tiles
    return GemmCycles(dense=dense, sparse=float(per_tile.sum()) * m_tiles)


def sparse_a_gemm_cycles(spec: SparseSpec, a_mask: np.ndarray, n: int,
                         core: CoreConfig) -> GemmCycles:
    """Activation-only sparsity.  a_mask: (M, K)."""
    M, K = a_mask.shape
    sub = min(1 + spec.da3, core.m0)
    per_tile = _grouped_cycles(a_mask.T, core.k0, core.m0, sub,
                               spec.da1, spec.da2, spec.da3, spec.shuffle)
    n_tiles = -(-n // core.n0)
    T = -(-K // core.k0)
    dense = T * per_tile.shape[0] * n_tiles
    return GemmCycles(dense=dense, sparse=float(per_tile.sum()) * n_tiles)


# ---------------------------------------------------------------------------
# dual sparsity (two-stage, Fig. 3) and joint (TensorDash-style)
# ---------------------------------------------------------------------------


def _slot_maps(sched: Schedule, tiles_b: np.ndarray) -> Tuple[np.ndarray, ...]:
    """Invert stage-1 placement records into per-slot source coordinates.

    Returns (filled, src_t, src_l): arrays of shape (tiles, C, K0, G) where
    slot (c, lane, col) of the compacted stream holds B element
    (src_t, src_l, col_src) — col_src is not needed downstream because the A
    operand of a pair depends only on (t, k-lane, m).
    """
    nt, T, K0, G = tiles_b.shape
    C = int(sched.cycles.max())
    filled = np.zeros((nt, C, K0, G), dtype=bool)
    src_t = np.zeros((nt, C, K0, G), dtype=np.int32)
    src_l = np.zeros((nt, C, K0, G), dtype=np.int16)
    ti, ts, ls, gs = np.nonzero(sched.cyc >= 0)
    c = sched.cyc[ti, ts, ls, gs].astype(np.int64)
    lt = sched.lane[ti, ts, ls, gs].astype(np.int64)
    gt = sched.grp[ti, ts, ls, gs].astype(np.int64)
    filled[ti, c, lt, gt] = True
    src_t[ti, c, lt, gt] = ts
    src_l[ti, c, lt, gt] = ls
    return filled, src_t, src_l


def dual_gemm_cycles(spec: SparseSpec, a_mask: np.ndarray, b_mask: np.ndarray,
                     core: CoreConfig, rng: np.random.Generator,
                     sample_mt: int = 4, sample_nt: int = 4,
                     preprocess_b: bool = True) -> GemmCycles:
    """Dual sparsity.  a_mask: (M, K), b_mask: (K, N).

    Stage 1 compacts B offline per window group of (1+db3) columns; stage 2
    schedules, per PE column and per (1+da3)-row window group, the effectual
    (A nonzero AND B-slot filled) mask over the *compacted* cycle base.  The
    tile's columns re-synchronize at the tile boundary (max).

    ``preprocess_b=False`` gives the joint (TensorDash-style) model: stage 1
    is the identity and the da-windows must skip both kinds of zeros on the
    fly over the pairwise-effectual mask.
    """
    M, K = a_mask.shape
    _, N = b_mask.shape
    k0, n0, m0 = core.k0, core.n0, core.m0
    sub_b = min(1 + spec.db3, n0)
    sub_a = min(1 + spec.da3, m0)
    per_tile_b = -(-n0 // sub_b)                       # column groups per tile
    row_subs = -(-m0 // sub_a)                         # row groups per m-tile
    # Shuffle both matrices identically up front (stage schedules then run
    # with shuffle=False so lane coordinates stay consistent across stages).
    a_tiles_all = _pack_stream(a_mask.T, k0, m0)       # (MT, T, K0, M0)
    b_subs_all = _pack_stream(b_mask, k0, sub_b)       # (NT*ptb, T, K0, sub_b)
    if spec.shuffle:
        a_tiles_all = shuffle_lanes(a_tiles_all)
        b_subs_all = shuffle_lanes(b_subs_all)
    MT, T = a_tiles_all.shape[0], a_tiles_all.shape[1]
    NT = -(-N // n0)
    # pad the column-group axis out to whole tiles, then sample whole tiles
    nsub_tot = NT * per_tile_b
    if b_subs_all.shape[0] < nsub_tot:
        padb = np.zeros((nsub_tot, T, k0, sub_b), dtype=bool)
        padb[:b_subs_all.shape[0]] = b_subs_all
        b_subs_all = padb
    b_by_tile = b_subs_all.reshape(NT, per_tile_b, T, k0, sub_b)
    mt_idx = rng.choice(MT, size=min(sample_mt, MT), replace=False)
    nt_idx = rng.choice(NT, size=min(sample_nt, NT), replace=False)
    a_tiles = a_tiles_all[mt_idx]                      # (mt, T, K0, M0)
    b_subs = b_by_tile[nt_idx].reshape(-1, T, k0, sub_b)   # (nt*ptb, T, K0, sub)
    mt, nsub = a_tiles.shape[0], b_subs.shape[0]

    if preprocess_b:
        s1 = schedule(b_subs, spec.db1, spec.db2, spec.db3,
                      shuffle=False, record=True)
        filled, src_t, src_l = _slot_maps(s1, b_subs)   # (nsub, C, K0, sub_b)
    else:
        filled = b_subs
        src_t = np.broadcast_to(
            np.arange(T, dtype=np.int32)[None, :, None, None], filled.shape)
        src_l = np.broadcast_to(
            np.arange(k0, dtype=np.int16)[None, None, :, None], filled.shape)
    C = filled.shape[1]

    # Stage 2 effectual mask: eff[c, l, col, m] = filled & A[src_t, src_l, m],
    # gathered for every m of the M0 group via fancy indexing.
    st = np.broadcast_to(src_t[None], (mt,) + src_t.shape).astype(np.int64)
    sl = np.broadcast_to(src_l[None], (mt,) + src_l.shape).astype(np.int64)
    mt_ax = np.arange(mt)[:, None, None, None, None]
    a_vals = a_tiles[mt_ax, st, sl]                    # (mt, nsub, C, K0, sub_b, M0)
    eff = filled[None, ..., None] & a_vals
    # scheduling unit: one PE column x one row group -> (C, K0, sub_a)
    eff = eff.transpose(0, 1, 4, 2, 3, 5).reshape(
        mt * nsub * sub_b, C, k0, row_subs, sub_a)
    eff = eff.transpose(0, 3, 1, 2, 4).reshape(
        mt * nsub * sub_b * row_subs, C, k0, sub_a)
    s2 = schedule(eff, spec.da1, spec.da2, spec.da3, shuffle=False)
    nt = len(nt_idx)
    per_unit = s2.cycles.reshape(mt, nt, per_tile_b * sub_b * row_subs)
    per_tile = per_unit.max(axis=2)                    # output-sync stall
    mean_tile = float(per_tile.mean())
    dense = T * MT * NT
    return GemmCycles(dense=dense, sparse=mean_tile * MT * NT)


def sparten_gemm_cycles(mode: Mode, a_mask: np.ndarray, b_mask: np.ndarray
                        ) -> GemmCycles:
    """SparTen / SparTen.A / SparTen.B (per-PE intersection, Section V).

    SparTen performs *offline greedy balancing* of the (static) weight
    columns in software [18]; we model it by snake-assigning density-sorted
    columns to the PE waves, which equalizes per-wave maxima.
    """
    M, K = a_mask.shape
    _, N = b_mask.shape
    a = a_mask.astype(np.int32)
    b = b_mask.astype(np.int32)
    if mode in (Mode.B, Mode.AB) and N > 32:
        order = np.argsort(b.sum(axis=0))
        nwaves = -(-N // 32)
        snake = np.concatenate([order[i::2 * nwaves] for i in range(nwaves)] +
                               [order[2 * nwaves - 1 - i::2 * nwaves]
                                for i in range(nwaves)])
        # interleave so each wave receives a balanced density mix
        b = b[:, np.sort(snake.reshape(nwaves, -1), axis=0).T.reshape(-1)]             if False else b[:, snake]
    if mode == Mode.AB:
        counts = a @ b                                  # effectual pairs per output
    elif mode == Mode.B:
        counts = np.broadcast_to(b.sum(axis=0)[None, :], (M, N)).copy()
    elif mode == Mode.A:
        counts = np.broadcast_to(a.sum(axis=1)[:, None], (M, N)).copy()
    else:
        counts = np.full((M, N), K, dtype=np.int32)
    waves = sparten_tile_cycles(counts)
    # dense baseline with the same 1024 MACs: each 32x32 wave takes K cycles
    return GemmCycles(dense=float(waves.size * K), sparse=float(waves.sum()))


# ---------------------------------------------------------------------------
# dispatch: score one GEMM under (spec, mode)
# ---------------------------------------------------------------------------


def gemm_cycles(spec: SparseSpec, mode: Mode, a_mask: np.ndarray,
                b_mask: np.ndarray, core: CoreConfig,
                rng: Optional[np.random.Generator] = None,
                sample_mt: int = 4, sample_nt: int = 4) -> GemmCycles:
    """Cycles for C = A @ B on architecture ``spec`` running category ``mode``.

    The mode is the *model* category; the architecture only exploits the
    sparsity its windows support (Definition III.1/III.2/IV.1).
    """
    rng = rng or np.random.default_rng(0)
    M, K = a_mask.shape
    _, N = b_mask.shape
    if spec.name and spec.name.startswith("SparTen"):
        supported = {"SparTen.AB": Mode.AB, "SparTen.A": Mode.A,
                     "SparTen.B": Mode.B}[spec.name]
        eff_mode = _intersect_mode(mode, supported)
        return sparten_gemm_cycles(eff_mode, a_mask, b_mask)

    use_a = spec.supports_a and mode in (Mode.A, Mode.AB)
    use_b = spec.supports_b and mode in (Mode.B, Mode.AB)
    if use_a and use_b:
        preprocess = not (spec.name == "TDash.AB")
        return dual_gemm_cycles(spec, a_mask, b_mask, core, rng,
                                sample_mt, sample_nt, preprocess_b=preprocess)
    if use_b:
        return sparse_b_gemm_cycles(spec, b_mask, M, core)
    if use_a:
        return sparse_a_gemm_cycles(spec, a_mask, N, core)
    T = -(-K // core.k0)
    dense = T * -(-N // core.n0) * -(-M // core.m0)
    return GemmCycles(dense=dense, sparse=float(dense))


def _intersect_mode(model: Mode, supported: Mode) -> Mode:
    if supported == Mode.AB:
        return model
    if model in (supported, Mode.AB):
        return supported
    return Mode.DENSE


# ---------------------------------------------------------------------------
# network-level evaluation
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GemmShape:
    """One GEMM of a workload: C[M,N] += A[M,K] @ B[K,N].

    ``b_static`` is False for activation x activation GEMMs (attention scores
    / context), where weight preprocessing is impossible (DESIGN.md Section 5).
    """

    m: int
    k: int
    n: int
    count: int = 1        # how many times this GEMM occurs
    b_static: bool = True
    q: int = 1            # spatial-tap period of the im2col K axis (RxS; 1 for FC/1x1)
    depthwise: bool = False  # block-diagonal B: column c only draws from rows [q*c, q*(c+1))


@dataclasses.dataclass(frozen=True)
class Workload:
    """A network benchmark: GEMM list + tensor sparsity levels (Table IV)."""

    name: str
    gemms: Tuple[GemmShape, ...]
    a_sparsity: float     # activation sparsity (0 = dense)
    b_sparsity: float     # weight sparsity    (0 = dense)

    @property
    def mode(self) -> Mode:
        return Mode.of(self.a_sparsity > 0.05, self.b_sparsity > 0.05)

    def dense_cycles(self, core: CoreConfig) -> float:
        tot = 0.0
        for g in self.gemms:
            tot += g.count * (-(-g.k // core.k0)) * (-(-g.n // core.n0)) * \
                (-(-g.m // core.m0))
        return tot


# Keep evaluation tractable on one CPU: cap the K-chunks per scheduled stream
# and the sampled tiles; this is statistical sampling over an i.i.d. mask, so
# the estimate is unbiased.
MAX_CHUNKS = 96


def _layer_jitter(base: float, rng: np.random.Generator, lo=0.75, hi=1.15
                  ) -> float:
    return float(np.clip(base * rng.uniform(lo, hi), 0.0, 0.98))


def allocate_layer_densities(gemms: Sequence["GemmShape"], net_sparsity: float,
                             beta: float = 0.25, floor: float = 0.02,
                             cap: float = 1.0) -> np.ndarray:
    """Per-layer weight densities consistent with a *network-level* ratio.

    Published pruning ratios (Table IV) are parameter-weighted: larger layers
    are pruned much harder (Deep Compression prunes AlexNet's FC6 to ~4%
    density while conv1 keeps most weights).  We allocate density_i
    proportional to size_i^-beta and renormalize so the parameter-weighted
    mean density equals ``1 - net_sparsity``.
    """
    sizes = np.array([max(g.k * g.n, 1) * g.count for g in gemms],
                     dtype=np.float64)
    target = 1.0 - net_sparsity
    if target >= 0.999:
        return np.ones(len(sizes))
    rel = (sizes / sizes.mean()) ** (-beta)
    lam = target * sizes.sum() / (sizes * rel).sum()
    d = np.clip(lam * rel, floor, cap)
    # one correction pass for the clipped mass
    err = (sizes * d).sum() / sizes.sum() - target
    free = (d > floor) & (d < cap)
    if free.any() and abs(err) > 1e-6:
        d[free] = np.clip(d[free] - err * sizes.sum() / sizes[free].sum(),
                          floor, cap)
    return d


def network_speedup(spec: SparseSpec, wl: Workload, core: CoreConfig,
                    seed: int = 0, mode: Optional[Mode] = None,
                    sample_mt: int = 4, sample_nt: int = 4,
                    mask_model: MaskModel = DEFAULT_MASK_MODEL) -> float:
    """End-to-end speedup of ``wl`` on ``spec`` vs the dense baseline.

    Per-layer weight density follows the size-aware allocation above (plus
    jitter); activation sparsity is jittered around the network ratio; masks
    follow the structured ``MaskModel``.
    """
    rng = np.random.default_rng(seed)
    mode = mode or wl.mode
    b_dens = allocate_layer_densities(wl.gemms, wl.b_sparsity)
    dense_total, sparse_total = 0.0, 0.0
    for li, g in enumerate(wl.gemms):
        lrng = np.random.default_rng(seed * 7919 + li)
        a_d = 1.0 - _layer_jitter(wl.a_sparsity, lrng)
        b_d = float(np.clip(b_dens[li] * lrng.uniform(0.9, 1.1), 0.02, 1.0)) \
            if g.b_static else 1.0 - _layer_jitter(wl.a_sparsity, lrng)
        k_eff = min(g.k, MAX_CHUNKS * core.k0)
        m_eff = min(g.m, 64 * core.m0)
        n_eff = min(g.n, 64 * core.n0)
        g_mode = mode if g.b_static else (
            Mode.A if mode in (Mode.A, Mode.AB) and wl.a_sparsity > 0.05
            else Mode.DENSE)
        a_mask = mask_model.act_mask(m_eff, k_eff, a_d, lrng, q=g.q)
        b_mask = mask_model.weight_mask(k_eff, n_eff, b_d, lrng, q=g.q)
        if g.depthwise:
            allowed = (np.arange(k_eff)[:, None] // g.q) == np.arange(n_eff)[None, :]
            b_mask &= allowed
        res = gemm_cycles(spec, g_mode, a_mask, b_mask, core, lrng,
                          sample_mt, sample_nt)
        # scale sampled cycles back to the full layer, weighted by count
        full = g.count * (-(-g.k // core.k0)) * (-(-g.n // core.n0)) * \
            (-(-g.m // core.m0))
        dense_total += full
        sparse_total += full * (res.sparse / res.dense)
    return dense_total / max(sparse_total, 1e-9)


def category_speedup(spec: SparseSpec, workloads: Sequence[Workload],
                     core: CoreConfig, seed: int = 0,
                     mode: Optional[Mode] = None) -> float:
    """Geometric-mean speedup over a benchmark category (Section V)."""
    sp = [network_speedup(spec, w, core, seed=seed + i, mode=mode)
          for i, w in enumerate(workloads)]
    return float(np.exp(np.mean(np.log(sp))))


# ---------------------------------------------------------------------------
# batched evaluation: one vectorized pass over a stack of SparseSpec configs
# ---------------------------------------------------------------------------


def _side_params(specs: Sequence[SparseSpec], side: str
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """(d1, d2, d3, shuffle) vectors for the A or B window of each spec."""
    if side == "a":
        d = [(s.da1, s.da2, s.da3) for s in specs]
    else:
        d = [(s.db1, s.db2, s.db3) for s in specs]
    arr = np.asarray(d, dtype=np.int64).reshape(len(specs), 3)
    sh = np.asarray([s.shuffle for s in specs], dtype=bool)
    return arr[:, 0], arr[:, 1], arr[:, 2], sh


def sparse_b_gemm_cycles_batched(specs: Sequence[SparseSpec],
                                 b_mask: np.ndarray, m: int, core: CoreConfig
                                 ) -> List[GemmCycles]:
    """Weight-only sparsity for a stack of specs.  b_mask: (K, N).

    Specs are grouped by their cross-PE window width (the packing
    granularity); within a group the tile stream is packed once and the
    offline bound runs over the stacked config axis.
    """
    K, N = b_mask.shape
    m_tiles = -(-m // core.m0)
    T = -(-K // core.k0)
    results: List[Optional[GemmCycles]] = [None] * len(specs)
    groups: Dict[int, List[int]] = {}
    for i, sp in enumerate(specs):
        groups.setdefault(min(1 + sp.db3, core.n0), []).append(i)
    for sub, idxs in groups.items():
        tiles = _pack_stream(b_mask, core.k0, sub)     # (ngroups, T, K0, sub)
        sub_specs = [specs[i] for i in idxs]
        d1, d2, d3, sh = _side_params(sub_specs, "b")
        per = static_pack_cycles_batched(tiles, d1, d2, d3, sh)
        per_tile_g = -(-core.n0 // sub)                # groups per tile
        ngroups = tiles.shape[0]
        pad = -(-ngroups // per_tile_g) * per_tile_g
        padded = np.zeros((len(idxs), pad), dtype=np.int64)
        padded[:, :ngroups] = per
        per_tile = padded.reshape(len(idxs), -1, per_tile_g).max(axis=2)
        dense = T * per_tile.shape[1] * m_tiles
        for j, i in enumerate(idxs):
            results[i] = GemmCycles(dense=dense,
                                    sparse=float(per_tile[j].sum()) * m_tiles)
    return results  # type: ignore[return-value]


def sparse_a_gemm_cycles_batched(specs: Sequence[SparseSpec],
                                 a_mask: np.ndarray, n: int, core: CoreConfig
                                 ) -> List[GemmCycles]:
    """Activation-only sparsity for a stack of specs.  a_mask: (M, K)."""
    M, K = a_mask.shape
    n_tiles = -(-n // core.n0)
    T = -(-K // core.k0)
    results: List[Optional[GemmCycles]] = [None] * len(specs)
    groups: Dict[int, List[int]] = {}
    for i, sp in enumerate(specs):
        groups.setdefault(min(1 + sp.da3, core.m0), []).append(i)
    for sub, idxs in groups.items():
        tiles = _pack_stream(a_mask.T, core.k0, sub)   # (ngroups, T, K0, sub)
        ngroups = tiles.shape[0]
        sub_specs = [specs[i] for i in idxs]
        d1, d2, d3, sh = _side_params(sub_specs, "a")
        big = np.broadcast_to(tiles[None], (len(idxs),) + tiles.shape)
        big = big.reshape((-1,) + tiles.shape[1:])
        cycles = schedule_batched(
            big, np.repeat(d1, ngroups), np.repeat(d2, ngroups),
            np.repeat(d3, ngroups), shuffle=np.repeat(sh, ngroups)
        ).cycles.reshape(len(idxs), ngroups)
        per_tile_g = -(-core.m0 // sub)
        pad = -(-ngroups // per_tile_g) * per_tile_g
        padded = np.zeros((len(idxs), pad), dtype=np.int64)
        padded[:, :ngroups] = cycles
        per_tile = padded.reshape(len(idxs), -1, per_tile_g).max(axis=2)
        dense = T * per_tile.shape[1] * n_tiles
        for j, i in enumerate(idxs):
            results[i] = GemmCycles(dense=dense,
                                    sparse=float(per_tile[j].sum()) * n_tiles)
    return results  # type: ignore[return-value]


def dual_gemm_cycles_batched(specs: Sequence[SparseSpec],
                             preprocess: Sequence[bool], a_mask: np.ndarray,
                             b_mask: np.ndarray, core: CoreConfig,
                             mt_idx: np.ndarray, nt_idx: np.ndarray
                             ) -> List[GemmCycles]:
    """Dual sparsity for a stack of specs sharing one (mt_idx, nt_idx) sample.

    Stage-1 B compaction is batched across all specs of a (sub_a, sub_b,
    preprocess) group; stage-2 effectual masks are stacked (padded to the
    deepest compacted stream, each row carrying its own ``t_len``) so the
    expensive on-the-fly schedule runs once per group.
    """
    M, K = a_mask.shape
    _, N = b_mask.shape
    k0, n0, m0 = core.k0, core.n0, core.m0
    base_a = _pack_stream(a_mask.T, k0, m0)            # (MT, T, K0, M0)
    MT, T = base_a.shape[0], base_a.shape[1]
    NT = -(-N // n0)
    a_var = {False: base_a}                            # keyed by shuffle
    b_var: Dict[Tuple[int, bool], np.ndarray] = {}     # keyed by (sub_b, sh)

    def a_tiles_for(sh: bool) -> np.ndarray:
        if sh not in a_var:
            a_var[sh] = shuffle_lanes(base_a)
        return a_var[sh][mt_idx]

    def b_by_tile_for(sub_b: int, sh: bool) -> np.ndarray:
        if (sub_b, sh) not in b_var:
            bs = _pack_stream(b_mask, k0, sub_b)
            if sh:
                bs = shuffle_lanes(bs)
            per_tile_b = -(-n0 // sub_b)
            nsub_tot = NT * per_tile_b
            if bs.shape[0] < nsub_tot:
                padb = np.zeros((nsub_tot, T, k0, sub_b), dtype=bool)
                padb[:bs.shape[0]] = bs
                bs = padb
            b_var[(sub_b, sh)] = bs.reshape(NT, per_tile_b, T, k0, sub_b)
        return b_var[(sub_b, sh)]

    results: List[Optional[GemmCycles]] = [None] * len(specs)
    groups: Dict[Tuple[int, int, bool], List[int]] = {}
    for i, sp in enumerate(specs):
        key = (min(1 + sp.da3, m0), min(1 + sp.db3, n0), bool(preprocess[i]))
        groups.setdefault(key, []).append(i)

    for (sub_a, sub_b, prep), all_idxs in groups.items():
        per_tile_b = -(-n0 // sub_b)
        row_subs = -(-m0 // sub_a)
        mt, nt = len(mt_idx), len(nt_idx)
        nsub = nt * per_tile_b
        # Cap the stacked stage-2 rows per scheduling call: past a few
        # thousand rows the per-cycle working set falls out of cache and
        # the batch turns memory-bound, which costs more than the Python
        # overhead it saves.
        group_units = mt * nsub * sub_b * row_subs
        step = max(1, 6144 // max(group_units, 1))
        chunks = [all_idxs[lo:lo + step]
                  for lo in range(0, len(all_idxs), step)]
        for idxs in chunks:
            _dual_group(specs, idxs, prep, sub_a, sub_b, per_tile_b,
                        row_subs, mt, nt, nsub, T, MT, NT, k0, nt_idx,
                        a_tiles_for, b_by_tile_for, results)
    return results  # type: ignore[return-value]


def _dual_group(specs, idxs, prep, sub_a, sub_b, per_tile_b, row_subs, mt,
                nt, nsub, T, MT, NT, k0, nt_idx, a_tiles_for, b_by_tile_for,
                results) -> None:
    """Score one (sub_a, sub_b, preprocess) chunk of dual-sparse specs."""
    b_subs_all = [
        b_by_tile_for(sub_b, specs[i].shuffle)[nt_idx].reshape(
            -1, T, k0, sub_b) for i in idxs]
    if prep:
        stack = np.concatenate(b_subs_all, axis=0)
        d1, d2, d3, _ = _side_params([specs[i] for i in idxs], "b")
        s1 = schedule_batched(stack, np.repeat(d1, nsub),
                              np.repeat(d2, nsub), np.repeat(d3, nsub),
                              shuffle=False, record=True)
    # stage-2 effectual masks, one per spec, padded to the chunk's C_max
    effs, clens = [], []
    for j, i in enumerate(idxs):
        b_subs = b_subs_all[j]
        if prep:
            sl = slice(j * nsub, (j + 1) * nsub)
            sub_sched = Schedule(cycles=s1.cycles[sl], cyc=s1.cyc[sl],
                                 lane=s1.lane[sl], grp=s1.grp[sl])
            filled, src_t, src_l = _slot_maps(sub_sched, b_subs)
        else:
            filled = b_subs
            src_t = np.broadcast_to(
                np.arange(T, dtype=np.int32)[None, :, None, None],
                filled.shape)
            src_l = np.broadcast_to(
                np.arange(k0, dtype=np.int16)[None, None, :, None],
                filled.shape)
        C = filled.shape[1]
        a_tiles = a_tiles_for(specs[i].shuffle)
        st = np.broadcast_to(src_t[None], (mt,) + src_t.shape
                             ).astype(np.int64)
        slx = np.broadcast_to(src_l[None], (mt,) + src_l.shape
                              ).astype(np.int64)
        mt_ax = np.arange(mt)[:, None, None, None, None]
        a_vals = a_tiles[mt_ax, st, slx]  # (mt, nsub, C, K0, sub_b, M0)
        eff = filled[None, ..., None] & a_vals
        eff = eff.transpose(0, 1, 4, 2, 3, 5).reshape(
            mt * nsub * sub_b, C, k0, row_subs, sub_a)
        eff = eff.transpose(0, 3, 1, 2, 4).reshape(
            mt * nsub * sub_b * row_subs, C, k0, sub_a)
        effs.append(eff)
        clens.append(C)
    c_max = max(clens)
    units = mt * nsub * sub_b * row_subs
    stack2 = np.zeros((len(idxs) * units, c_max, k0, sub_a), dtype=bool)
    for j, eff in enumerate(effs):
        stack2[j * units:(j + 1) * units, :clens[j]] = eff
    da1, da2, da3, _ = _side_params([specs[i] for i in idxs], "a")
    s2 = schedule_batched(stack2, np.repeat(da1, units),
                          np.repeat(da2, units), np.repeat(da3, units),
                          shuffle=False,
                          t_len=np.repeat(np.asarray(clens), units))
    dense = T * MT * NT
    for j, i in enumerate(idxs):
        per_unit = s2.cycles[j * units:(j + 1) * units].reshape(
            mt, nt, per_tile_b * sub_b * row_subs)
        per_tile = per_unit.max(axis=2)                # output-sync stall
        results[i] = GemmCycles(dense=dense,
                                sparse=float(per_tile.mean()) * MT * NT)


def gemm_cycles_batched(specs: Sequence[SparseSpec], mode: Mode,
                        a_mask: np.ndarray, b_mask: np.ndarray,
                        core: CoreConfig,
                        rng: Optional[np.random.Generator] = None,
                        sample_mt: int = 4, sample_nt: int = 4
                        ) -> List[GemmCycles]:
    """Cycles of C = A @ B for every spec of a stack, in one vectorized pass.

    Bit-exact with ``[gemm_cycles(s, mode, ...) for s in specs]`` where each
    scalar call receives an identically-seeded ``rng`` — which is exactly how
    :func:`network_speedup` consumes it, so batched and scalar DSE sweeps
    produce identical numbers.
    """
    rng = rng or np.random.default_rng(0)
    M, K = a_mask.shape
    _, N = b_mask.shape
    results: List[Optional[GemmCycles]] = [None] * len(specs)
    sparten_ix, dual_ix, b_ix, a_ix, dense_ix = [], [], [], [], []
    for i, spec in enumerate(specs):
        if spec.name and spec.name.startswith("SparTen"):
            sparten_ix.append(i)
            continue
        use_a = spec.supports_a and mode in (Mode.A, Mode.AB)
        use_b = spec.supports_b and mode in (Mode.B, Mode.AB)
        if use_a and use_b:
            dual_ix.append(i)
        elif use_b:
            b_ix.append(i)
        elif use_a:
            a_ix.append(i)
        else:
            dense_ix.append(i)
    by_mode: Dict[Mode, GemmCycles] = {}
    for i in sparten_ix:
        supported = {"SparTen.AB": Mode.AB, "SparTen.A": Mode.A,
                     "SparTen.B": Mode.B}[specs[i].name]
        eff_mode = _intersect_mode(mode, supported)
        if eff_mode not in by_mode:
            by_mode[eff_mode] = sparten_gemm_cycles(eff_mode, a_mask, b_mask)
        results[i] = by_mode[eff_mode]
    if dual_ix:
        # the one rng-consuming path: every scalar call draws the same
        # sample from an identically-seeded generator, so draw once here
        MT, NT = -(-M // core.m0), -(-N // core.n0)
        mt_idx = rng.choice(MT, size=min(sample_mt, MT), replace=False)
        nt_idx = rng.choice(NT, size=min(sample_nt, NT), replace=False)
        dres = dual_gemm_cycles_batched(
            [specs[i] for i in dual_ix],
            [specs[i].name != "TDash.AB" for i in dual_ix],
            a_mask, b_mask, core, mt_idx, nt_idx)
        for i, r in zip(dual_ix, dres):
            results[i] = r
    if b_ix:
        for i, r in zip(b_ix, sparse_b_gemm_cycles_batched(
                [specs[i] for i in b_ix], b_mask, M, core)):
            results[i] = r
    if a_ix:
        for i, r in zip(a_ix, sparse_a_gemm_cycles_batched(
                [specs[i] for i in a_ix], a_mask, N, core)):
            results[i] = r
    if dense_ix:
        T = -(-K // core.k0)
        dense = T * -(-N // core.n0) * -(-M // core.m0)
        for i in dense_ix:
            results[i] = GemmCycles(dense=dense, sparse=float(dense))
    return results  # type: ignore[return-value]


def network_speedup_batched(specs: Sequence[SparseSpec], wl: Workload,
                            core: CoreConfig, seed: int = 0,
                            mode: Optional[Mode] = None,
                            sample_mt: int = 4, sample_nt: int = 4,
                            mask_model: MaskModel = DEFAULT_MASK_MODEL
                            ) -> np.ndarray:
    """End-to-end speedups of ``wl`` for a stack of specs (one mask draw).

    The per-layer masks depend only on (workload, seed), not on the spec —
    the scalar path regenerates them per design; here they are drawn once
    and shared, which with the stacked-config scheduler is where the DSE
    batching speedup comes from.  Returns a (len(specs),) array, bit-exact
    with per-spec :func:`network_speedup` calls.
    """
    mode = mode or wl.mode
    b_dens = allocate_layer_densities(wl.gemms, wl.b_sparsity)
    dense_total = 0.0
    sparse_totals = np.zeros(len(specs), dtype=np.float64)
    for li, g in enumerate(wl.gemms):
        lrng = np.random.default_rng(seed * 7919 + li)
        a_d = 1.0 - _layer_jitter(wl.a_sparsity, lrng)
        b_d = float(np.clip(b_dens[li] * lrng.uniform(0.9, 1.1), 0.02, 1.0)) \
            if g.b_static else 1.0 - _layer_jitter(wl.a_sparsity, lrng)
        k_eff = min(g.k, MAX_CHUNKS * core.k0)
        m_eff = min(g.m, 64 * core.m0)
        n_eff = min(g.n, 64 * core.n0)
        g_mode = mode if g.b_static else (
            Mode.A if mode in (Mode.A, Mode.AB) and wl.a_sparsity > 0.05
            else Mode.DENSE)
        a_mask = mask_model.act_mask(m_eff, k_eff, a_d, lrng, q=g.q)
        b_mask = mask_model.weight_mask(k_eff, n_eff, b_d, lrng, q=g.q)
        if g.depthwise:
            allowed = (np.arange(k_eff)[:, None] // g.q) == np.arange(n_eff)[None, :]
            b_mask &= allowed
        res = gemm_cycles_batched(specs, g_mode, a_mask, b_mask, core, lrng,
                                  sample_mt, sample_nt)
        full = g.count * (-(-g.k // core.k0)) * (-(-g.n // core.n0)) * \
            (-(-g.m // core.m0))
        dense_total += full
        sparse_totals += full * np.array([r.sparse / r.dense for r in res])
    return dense_total / np.maximum(sparse_totals, 1e-9)


def category_speedup_batched(specs: Sequence[SparseSpec],
                             workloads: Sequence[Workload], core: CoreConfig,
                             seed: int = 0, mode: Optional[Mode] = None,
                             mask_model: MaskModel = DEFAULT_MASK_MODEL
                             ) -> np.ndarray:
    """Geometric-mean category speedups for a stack of specs."""
    logs = np.zeros((len(workloads), len(specs)))
    for i, w in enumerate(workloads):
        logs[i] = np.log(network_speedup_batched(
            specs, w, core, seed=seed + i, mode=mode, mask_model=mask_model))
    return np.exp(logs.mean(axis=0))


def dense_cycles_batched(workloads: Sequence[Workload], core: CoreConfig
                         ) -> np.ndarray:
    """Dense-baseline cycle totals for many workloads in one numpy pass
    (vectorized twin of :meth:`Workload.dense_cycles`)."""
    wi, kk, nn, mm, cc = [], [], [], [], []
    for i, w in enumerate(workloads):
        for g in w.gemms:
            wi.append(i)
            kk.append(g.k)
            nn.append(g.n)
            mm.append(g.m)
            cc.append(g.count)
    if not wi:
        return np.zeros(len(workloads))
    kk, nn, mm, cc = (np.asarray(x, dtype=np.int64) for x in (kk, nn, mm, cc))
    per = cc * (-(-kk // core.k0)) * (-(-nn // core.n0)) * (-(-mm // core.m0))
    out = np.zeros(len(workloads), dtype=np.float64)
    np.add.at(out, np.asarray(wi), per.astype(np.float64))
    return out
