"""Parametric specification of sparse GEMM accelerator architectures.

This module encodes the paper's Definition III.1/III.2/IV.1: an architecture
is described by how far a multiplier may *borrow* a nonzero operand to replace
a zero one, along three dimensions of each input matrix:

  d?1 : time      — future K-chunks (lookahead)
  d?2 : lane      — neighbouring lane inside the K0-wide dot-product unit
  d?3 : cross-PE  — neighbouring PE (output column for B / output row for A),
                    which requires an extra adder tree to route the partial sum
                    back to the owning accumulator.

``da*`` applies to matrix A (activations, skipped on the fly), ``db*`` to
matrix B (weights, preprocessed offline).  ``shuffle`` enables the paper's
local 4x4 rotation load balancing (Section III, "Load Balancing").
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Optional, Tuple


class Mode(str, enum.Enum):
    """DNN model / execution category (paper Table I)."""

    DENSE = "dense"  # (dense, dense)
    A = "A"          # sparse activations only  -> Sparse.A
    B = "B"          # sparse weights only      -> Sparse.B
    AB = "AB"        # dual sparse              -> Sparse.AB

    @staticmethod
    def of(a_sparse: bool, b_sparse: bool) -> "Mode":
        if a_sparse and b_sparse:
            return Mode.AB
        if a_sparse:
            return Mode.A
        if b_sparse:
            return Mode.B
        return Mode.DENSE


@dataclasses.dataclass(frozen=True)
class CoreConfig:
    """The dense baseline core (paper Table IV, bottom)."""

    k0: int = 16          # dot-product unit width (lanes)
    n0: int = 16          # PE columns (output channels)
    m0: int = 4           # PE rows (output rows)
    freq_ghz: float = 0.8
    # memory system (used by the power model's bandwidth-scaling term)
    asram_kb: int = 512
    bsram_kb: int = 32
    asram_gbps: float = 51.2
    bsram_gbps: float = 204.8
    dram_gbps: float = 50.0

    @property
    def macs(self) -> int:
        return self.k0 * self.n0 * self.m0

    @property
    def dense_tops(self) -> float:
        """Dense INT8 TOPS: 2 ops (mul+add) per MAC per cycle."""
        return 2 * self.macs * self.freq_ghz / 1e3


@dataclasses.dataclass(frozen=True)
class SparseSpec:
    """Borrowing distances for one architecture configuration.

    ``Sparse.A(da1,da2,da3)``  == SparseSpec(da1,da2,da3, 0,0,0)
    ``Sparse.B(db1,db2,db3)``  == SparseSpec(0,0,0, db1,db2,db3)
    ``Sparse.AB(x,y,z,x',y',z')`` carries all six.
    """

    da1: int = 0
    da2: int = 0
    da3: int = 0
    db1: int = 0
    db2: int = 0
    db3: int = 0
    shuffle: bool = False
    name: Optional[str] = None

    # ---- derived properties -------------------------------------------------
    @property
    def a_window(self) -> Tuple[int, int, int]:
        return (self.da1, self.da2, self.da3)

    @property
    def b_window(self) -> Tuple[int, int, int]:
        return (self.db1, self.db2, self.db3)

    @property
    def supports_a(self) -> bool:
        return any(self.a_window)

    @property
    def supports_b(self) -> bool:
        return any(self.b_window)

    def label(self) -> str:
        if self.name:
            return self.name
        s = "on" if self.shuffle else "off"
        if self.supports_a and self.supports_b:
            return f"AB({self.da1},{self.da2},{self.da3},{self.db1},{self.db2},{self.db3},{s})"
        if self.supports_b:
            return f"B({self.db1},{self.db2},{self.db3},{s})"
        if self.supports_a:
            return f"A({self.da1},{self.da2},{self.da3},{s})"
        return f"dense({s})"

    def degrade_to(self, mode: Mode) -> "SparseSpec":
        """Non-hybrid behaviour: a dual-sparse design running a single-sparse
        model simply ignores the other side's borrowing (paper Section IV-B:
        'this design point downgrades to Sparse.A(2,0,0) and Sparse.B(2,0,1)')."""
        if mode == Mode.A:
            return dataclasses.replace(self, db1=0, db2=0, db3=0, name=None)
        if mode == Mode.B:
            return dataclasses.replace(self, da1=0, da2=0, da3=0, name=None)
        if mode == Mode.DENSE:
            return dataclasses.replace(
                self, da1=0, da2=0, da3=0, db1=0, db2=0, db3=0, name=None)
        return self


@dataclasses.dataclass(frozen=True)
class HybridSpec:
    """A hybrid architecture: one physical design (``base`` determines the
    hardware overhead) that *morphs* into per-category configurations
    (paper Section IV-B, Table VI)."""

    base: SparseSpec                      # physical design point (Sparse.AB*)
    conf_a: SparseSpec                    # morph for DNN.A
    conf_b: SparseSpec                    # morph for DNN.B
    name: str = "hybrid"

    def spec_for(self, mode: Mode) -> SparseSpec:
        if mode == Mode.A:
            return self.conf_a
        if mode == Mode.B:
            return self.conf_b
        if mode == Mode.DENSE:
            return self.base.degrade_to(Mode.DENSE)
        return self.base


# --------------------------------------------------------------------------
# Named design points (paper Table V / Table VI and Section V baselines).
# --------------------------------------------------------------------------

def sparse_a(da1: int, da2: int, da3: int, shuffle: bool = False, name=None) -> SparseSpec:
    return SparseSpec(da1, da2, da3, 0, 0, 0, shuffle, name)


def sparse_b(db1: int, db2: int, db3: int, shuffle: bool = False, name=None) -> SparseSpec:
    return SparseSpec(0, 0, 0, db1, db2, db3, shuffle, name)


def sparse_ab(da1, da2, da3, db1, db2, db3, shuffle: bool = False, name=None) -> SparseSpec:
    return SparseSpec(da1, da2, da3, db1, db2, db3, shuffle, name)


DENSE_BASELINE = SparseSpec(name="Baseline")

# Paper Table VI optimal points.
SPARSE_B_STAR = sparse_b(4, 0, 1, shuffle=True, name="Sparse.B*")
SPARSE_A_STAR = sparse_a(2, 1, 0, shuffle=True, name="Sparse.A*")
SPARSE_AB_STAR = sparse_ab(2, 0, 0, 2, 0, 1, shuffle=True, name="Sparse.AB*")

GRIFFIN = HybridSpec(
    base=SPARSE_AB_STAR,
    conf_a=sparse_a(2, 1, 1, shuffle=True, name="Griffin.confA"),
    conf_b=sparse_b(8, 0, 1, shuffle=True, name="Griffin.confB"),
    name="Griffin",
)

# State-of-the-art comparison points (paper Table V; Section V).
#  - Bit-Tactical (TCL.B): weight-only, lookahead+lookaside, no shuffle, db3=0.
#  - TensorDash (TDash.AB): dual, lookahead/lookaside both sides, no
#    preprocessing of B (joint on-the-fly scheduling; see scheduler.py).
#  - SparTen: dual, per-PE time-only intersection with very deep buffers.
TCL_B = sparse_b(2, 5, 0, shuffle=False, name="TCL.B")
TDASH_AB = sparse_ab(2, 1, 0, 2, 1, 0, shuffle=False, name="TDash.AB")
SPARTEN_DEPTH = 127  # 128-deep buffers (paper Section VI-E)
SPARTEN_AB = sparse_ab(SPARTEN_DEPTH, 0, 0, SPARTEN_DEPTH, 0, 0,
                       shuffle=False, name="SparTen.AB")
SPARTEN_A = sparse_a(SPARTEN_DEPTH, 0, 0, shuffle=False, name="SparTen.A")
SPARTEN_B = sparse_b(SPARTEN_DEPTH, 0, 0, shuffle=False, name="SparTen.B")
# Related work encoded as parameter points (Section VII).
CAMBRICON_X = sparse_b(16, 16, 0, shuffle=False, name="Cambricon-X")
CNVLUTIN = sparse_a(15, 0, 0, shuffle=False, name="Cnvlutin")

PRESETS: Dict[str, SparseSpec] = {
    s.name: s for s in [
        DENSE_BASELINE, SPARSE_B_STAR, SPARSE_A_STAR, SPARSE_AB_STAR,
        TCL_B, TDASH_AB, SPARTEN_AB, SPARTEN_A, SPARTEN_B,
        CAMBRICON_X, CNVLUTIN,
    ]
}
