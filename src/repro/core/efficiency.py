"""Effective power/area efficiency metrics (paper Definition V.1)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Union

from .overhead import CostModel, DEFAULT_COST_MODEL, power_area
from .spec import CoreConfig, HybridSpec, Mode, SparseSpec


@dataclasses.dataclass(frozen=True)
class Efficiency:
    speedup: float
    power_mw: float
    area_kum2: float

    @property
    def tops_w(self) -> float:
        """Effective TOPS/W = sparsity speedup x dense TOPS / power."""
        return self.speedup * CoreConfig().dense_tops / (self.power_mw * 1e-3)

    @property
    def tops_mm2(self) -> float:
        return self.speedup * CoreConfig().dense_tops / (self.area_kum2 * 1e-3)


def efficiency(design: Union[SparseSpec, HybridSpec], speedup: float,
               core: CoreConfig = CoreConfig(),
               cm: CostModel = DEFAULT_COST_MODEL) -> Efficiency:
    pa = power_area(design, core, cm)
    return Efficiency(speedup=speedup, power_mw=pa.power_mw,
                      area_kum2=pa.area_kum2)


def sparsity_tax(design: Union[SparseSpec, HybridSpec],
                 core: CoreConfig = CoreConfig(),
                 cm: CostModel = DEFAULT_COST_MODEL) -> Dict[str, float]:
    """Efficiency lost on DNN.dense relative to the dense baseline
    (paper Section VI-F: Griffin's 'sparsity tax' is 29%/24% power/area)."""
    from .spec import DENSE_BASELINE
    base = power_area(DENSE_BASELINE, core, cm)
    this = power_area(design, core, cm)
    return {
        "power_tax": 1.0 - base.power_mw / this.power_mw,
        "area_tax": 1.0 - base.area_kum2 / this.area_kum2,
    }
