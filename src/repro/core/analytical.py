"""Closed-form analytical speedup model (paper Section I: "we create an
analytical model, verified by a simulator").

For a stream of T chunks with i.i.d.-ish per-slot density p, a window of
``1 + d1`` chunks and per-slot service rate 1/cycle, the achievable
steady-state advance rate v (chunks/cycle) is bounded by:

  - window cap:      v <= 1 + d1
  - service cap:     v <= 1 / p_hot          (hottest fungible slot group)
  - burst cap:       v <= (r + d1) / (r * p_run^[r-1] ...) — approximated
                     by the two-element burst bound (2 + d1) / 2 weighted
                     by the burst probability.

``p_hot`` folds in the load-balancing state: lanes are fungible within a
group of w = (4 if shuffle else 1) * (1 + d2) slots (and (1+d3) cross-PE
neighbours), so the binding density is the mean of the top group rather
than the top slot.  The model is calibration-free: its only inputs are the
mask statistics the simulator also sees.  ``verify`` in
tests/test_analytical.py checks it tracks the simulator within a stated
band across densities and windows — exactly the paper's model-vs-simulator
role (fast DSE pre-screening; the simulator remains the scorer of record).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from .spec import CoreConfig, SparseSpec


def _group_hot_density(mask: np.ndarray, w: int, g: int) -> float:
    """Mean density of the hottest fungible slot group.

    mask: (T, K0, G_cols).  Slots are fungible within lane groups of w and
    across g neighbouring columns; the busiest group gates service.
    """
    T, K0, GC = mask.shape
    w = max(1, min(w, K0))
    g = max(1, min(g, GC))
    dens = mask.mean(axis=0)                       # (K0, GC)
    kg = K0 // w
    cg = GC // g
    pooled = dens[:kg * w, :cg * g].reshape(kg, w, cg, g).mean(axis=(1, 3))
    return float(pooled.max()) if pooled.size else float(dens.max())


def predicted_speedup_b(spec: SparseSpec, b_mask: np.ndarray,
                        core: CoreConfig = CoreConfig()) -> float:
    """Closed-form Sparse.B speedup for one (K, N) weight mask."""
    K, N = b_mask.shape
    k0, n0 = core.k0, core.n0
    T = -(-K // k0)
    # column-major lane segments (evaluate.py packing)
    pk = T * k0
    pad = np.zeros((pk, N), dtype=bool)
    pad[:K] = b_mask
    stream = pad.reshape(k0, T, N).transpose(1, 0, 2)      # (T, K0, N)
    win = 1 + spec.db1
    w = (4 if spec.shuffle else 1) * (1 + spec.db2)
    p_hot = _group_hot_density(stream, w, 1 + spec.db3)
    v_service = 1.0 / max(p_hot, 1.0 / win, 1e-9)
    # burst cap: a same-slot pair within the window forces >= 2 cycles for
    # 2 + d1 chunks of travel; weight by how often the hot group bursts
    p2 = min(1.0, p_hot * p_hot * win)
    v_burst = (2.0 + spec.db1) / 2.0
    v = min(win, v_service * (1 - p2) + min(v_service, v_burst) * p2)
    # output sync: the max over the tile's N0 columns — approximate with
    # the hottest column's density relative to the mean
    col_d = stream.reshape(T * k0, N).mean(axis=0)
    mean_d = max(float(col_d.mean()), 1e-9)
    tiles = col_d[:(N // n0) * n0].reshape(-1, n0) if N >= n0 else \
        col_d.reshape(1, -1)
    sync = float((tiles.max(axis=1) / mean_d).mean()) if tiles.size else 1.0
    # cross-PE borrowing relaxes the sync penalty
    sync = 1.0 + (sync - 1.0) / (1.0 + spec.db3)
    return float(max(1.0, min(win, v / max(sync, 1.0))))


@dataclasses.dataclass
class AnalyticalCheck:
    predicted: float
    simulated: float

    @property
    def ratio(self) -> float:
        return self.predicted / max(self.simulated, 1e-9)


def verify(spec: SparseSpec, b_mask: np.ndarray, m: int = 64,
           core: CoreConfig = CoreConfig()) -> AnalyticalCheck:
    from .evaluate import sparse_b_gemm_cycles
    sim = sparse_b_gemm_cycles(spec, b_mask, m, core).speedup
    return AnalyticalCheck(predicted=predicted_speedup_b(spec, b_mask, core),
                           simulated=sim)
