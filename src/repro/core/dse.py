"""Design-space exploration (paper Section VI, Figures 5-7).

Enumerates each architecture family under the paper's MUX fan-in budgets
(<=8 for single-sparse, <=16 for dual), scores every point on its benchmark
category (speedup, power, area, effective TOPS/W and TOPS/mm^2) and extracts
the Pareto frontier.  Results are plain dict rows, written as CSV by the
benchmark drivers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from .efficiency import efficiency, sparsity_tax
from .evaluate import MaskModel, DEFAULT_MASK_MODEL
from .hybrid import category_design_speedup
from .overhead import power_area, structure
from .spec import (CoreConfig, HybridSpec, Mode, SparseSpec, sparse_a,
                   sparse_b, sparse_ab)
from .workloads import category_workloads


def enumerate_sparse_b(max_fanin: int = 8, max_db1: int = 8) -> List[SparseSpec]:
    """Sparse.B family with AMUX fan-in (1+db1)(1+db2) <= max_fanin."""
    out = []
    for db1 in range(1, max_db1 + 1):
        for db2 in range(0, max_fanin):
            if (1 + db1) * (1 + db2) > max_fanin:
                continue
            for db3 in (0, 1, 2):
                for sh in (False, True):
                    out.append(sparse_b(db1, db2, db3, shuffle=sh))
    return out


def enumerate_sparse_a(max_fanin: int = 8, max_da1: int = 4) -> List[SparseSpec]:
    """Sparse.A family with AMUX fan-in (1+da1)(1+da2)(1+da3) <= max_fanin."""
    out = []
    for da1 in range(1, max_da1 + 1):
        for da2 in (0, 1, 2):
            for da3 in (0, 1, 2):
                if (1 + da1) * (1 + da2) * (1 + da3) > max_fanin:
                    continue
                for sh in (False, True):
                    out.append(sparse_a(da1, da2, da3, shuffle=sh))
    return out


def enumerate_sparse_ab(max_fanin: int = 16) -> List[SparseSpec]:
    """Sparse.AB family with AMUX fan-in <= max_fanin.

    Section VI-C prunes da3 > 0 (it inflates AMUX fan-in, unlike db3) and
    da1 > 2 (larger da1 needs deeper BBUF); we enumerate the same region.
    """
    out = []
    for da1 in (1, 2):
        for db1 in (1, 2, 3, 4):
            L = (1 + da1) * (1 + db1)
            for da2 in (0, 1):
                for db2 in (0, 1):
                    fanin = 1 + (L - 1) * (1 + da2 + db2)
                    if fanin > max_fanin:
                        continue
                    for db3 in (0, 1, 2):
                        for sh in (False, True):
                            out.append(sparse_ab(da1, da2, 0, db1, db2, db3,
                                                 shuffle=sh))
    return out


def score(design: Union[SparseSpec, HybridSpec], mode: Mode,
          core: CoreConfig = CoreConfig(), seed: int = 0,
          mask_model: MaskModel = DEFAULT_MASK_MODEL,
          dense_too: bool = True) -> Dict[str, float]:
    """One DSE row: speedup on the category + costs + efficiency."""
    wls = category_workloads(mode)
    sp = category_design_speedup(design, wls, core, seed=seed,
                                 mask_model=mask_model)
    eff = efficiency(design, sp, core)
    name = design.name if isinstance(design, HybridSpec) else design.label()
    row = {
        "design": name, "mode": mode.value, "speedup": sp,
        "power_mw": eff.power_mw, "area_kum2": eff.area_kum2,
        "tops_w": eff.tops_w, "tops_mm2": eff.tops_mm2,
    }
    if dense_too:
        dense_eff = efficiency(design, 1.0, core)
        row["dense_tops_w"] = dense_eff.tops_w
        row["dense_tops_mm2"] = dense_eff.tops_mm2
    return row


def pareto(rows: Sequence[Dict[str, float]], x: str, y: str
           ) -> List[Dict[str, float]]:
    """Rows not dominated in the (maximize x, maximize y) sense."""
    out = []
    for r in rows:
        if not any((o[x] >= r[x] and o[y] >= r[y] and
                    (o[x] > r[x] or o[y] > r[y])) for o in rows):
            out.append(r)
    return sorted(out, key=lambda r: -r[x])
