"""Design-space exploration (paper Section VI, Figures 5-7).

Enumerates each architecture family under the paper's MUX fan-in budgets
(<=8 for single-sparse, <=16 for dual), scores every point on its benchmark
category (speedup, power, area, effective TOPS/W and TOPS/mm^2) and extracts
the Pareto frontier.  Results are plain dict rows, written as CSV by the
benchmark drivers.

:func:`sweep` is the batched sweep driver: it scores a whole design list
through the stacked-config evaluation engine (one mask draw and one
vectorized scheduler pass per workload layer instead of one Python loop per
design) and memoizes finished rows in a content-hashed on-disk
:class:`ResultsCache`, so re-running a figure script only pays for design
points it has never seen.  :func:`score` is the single-design wrapper.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from .efficiency import efficiency, sparsity_tax
from .evaluate import MaskModel, DEFAULT_MASK_MODEL
from .hybrid import category_design_speedup, category_design_speedup_batched
from .overhead import power_area, structure
from .spec import (CoreConfig, HybridSpec, Mode, SparseSpec, sparse_a,
                   sparse_b, sparse_ab)
from .workloads import category_workloads

# Bump to force-invalidate cached sweep rows by hand.  Day to day this is
# unnecessary: fingerprints also include a digest of the model-defining
# module sources (see _model_digest), so editing the cycle model, cost
# model or workload tables cold-starts the cache automatically.
CACHE_VERSION = 1

# Version of the candidate-config / kernel-plan schema (repro.tuning,
# DESIGN.md Section 12).  It is part of every sweep fingerprint: a schema
# bump (candidate fields gaining new semantics) must cold-start the cache,
# otherwise stale ``benchmarks/out/cache/`` rows written under the old
# schema would be served verbatim to plan-era queries.  ``repro.tuning``
# re-exports this as the plan's ``schema_version`` so the two can never
# drift apart.
CONFIG_SCHEMA_VERSION = 2

_MODEL_DIGEST: Optional[str] = None


def _model_digest() -> str:
    """Digest of the source of every module a sweep row's value depends on.

    Hashing source is deliberately coarse: a comment-only edit also
    invalidates, which costs one cold run — far cheaper than a stale
    cache silently reproducing pre-edit results.
    """
    global _MODEL_DIGEST
    if _MODEL_DIGEST is None:
        import inspect
        from . import (efficiency as _eff, evaluate as _ev, hybrid as _hy,
                       overhead as _ov, scheduler as _sc, spec as _sp,
                       workloads as _wl)
        src = "".join(inspect.getsource(m)
                      for m in (_sc, _ev, _hy, _ov, _eff, _sp, _wl))
        _MODEL_DIGEST = hashlib.sha256(src.encode()).hexdigest()[:16]
    return _MODEL_DIGEST


def enumerate_sparse_b(max_fanin: int = 8, max_db1: int = 8) -> List[SparseSpec]:
    """Sparse.B family with AMUX fan-in (1+db1)(1+db2) <= max_fanin."""
    out = []
    for db1 in range(1, max_db1 + 1):
        for db2 in range(0, max_fanin):
            if (1 + db1) * (1 + db2) > max_fanin:
                continue
            for db3 in (0, 1, 2):
                for sh in (False, True):
                    out.append(sparse_b(db1, db2, db3, shuffle=sh))
    return out


def enumerate_sparse_a(max_fanin: int = 8, max_da1: int = 4) -> List[SparseSpec]:
    """Sparse.A family with AMUX fan-in (1+da1)(1+da2)(1+da3) <= max_fanin."""
    out = []
    for da1 in range(1, max_da1 + 1):
        for da2 in (0, 1, 2):
            for da3 in (0, 1, 2):
                if (1 + da1) * (1 + da2) * (1 + da3) > max_fanin:
                    continue
                for sh in (False, True):
                    out.append(sparse_a(da1, da2, da3, shuffle=sh))
    return out


def enumerate_sparse_ab(max_fanin: int = 16) -> List[SparseSpec]:
    """Sparse.AB family with AMUX fan-in <= max_fanin.

    Section VI-C prunes da3 > 0 (it inflates AMUX fan-in, unlike db3) and
    da1 > 2 (larger da1 needs deeper BBUF); we enumerate the same region.
    """
    out = []
    for da1 in (1, 2):
        for db1 in (1, 2, 3, 4):
            L = (1 + da1) * (1 + db1)
            for da2 in (0, 1):
                for db2 in (0, 1):
                    fanin = 1 + (L - 1) * (1 + da2 + db2)
                    if fanin > max_fanin:
                        continue
                    for db3 in (0, 1, 2):
                        for sh in (False, True):
                            out.append(sparse_ab(da1, da2, 0, db1, db2, db3,
                                                 shuffle=sh))
    return out


def _spec_dict(spec: SparseSpec) -> Dict:
    return dataclasses.asdict(spec)


def design_fingerprint(design: Union[SparseSpec, HybridSpec], mode: Mode,
                       core: CoreConfig, seed: int,
                       mask_model: MaskModel, extra: Tuple = ()) -> str:
    """Content hash of everything that determines one sweep row.

    Two invocations with the same design point, category, core geometry,
    seed and mask-model calibration are guaranteed to produce the same row
    (the evaluation engine is deterministic), so the hash is a safe cache
    key across processes and sessions.
    """
    if isinstance(design, HybridSpec):
        dd = {"hybrid": design.name, "base": _spec_dict(design.base),
              "conf_a": _spec_dict(design.conf_a),
              "conf_b": _spec_dict(design.conf_b)}
    else:
        dd = _spec_dict(design)
    payload = {
        "v": CACHE_VERSION, "schema": CONFIG_SCHEMA_VERSION,
        "model": _model_digest(), "design": dd,
        "mode": mode.value, "core": dataclasses.asdict(core), "seed": seed,
        "mask_model": dataclasses.asdict(mask_model), "extra": list(extra),
    }
    blob = json.dumps(payload, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:24]


class ResultsCache:
    """Content-hashed on-disk cache of sweep rows (one JSON file per key).

    Keys come from :func:`design_fingerprint`; values are the plain dict
    rows :func:`sweep` produces.  Corrupt or unreadable entries are treated
    as misses, so a killed run can never poison a later one.
    """

    def __init__(self, path: str):
        self.path = path
        self.hits = 0
        self.misses = 0

    def _file(self, key: str) -> str:
        return os.path.join(self.path, key + ".json")

    def get(self, key: str) -> Optional[Dict]:
        try:
            with open(self._file(key)) as f:
                row = json.load(f)
            self.hits += 1
            return row
        except (OSError, ValueError):
            self.misses += 1
            return None

    def put(self, key: str, row: Dict) -> None:
        os.makedirs(self.path, exist_ok=True)
        tmp = self._file(key) + ".tmp"
        with open(tmp, "w") as f:
            json.dump(row, f)
        os.replace(tmp, self._file(key))


def _row(design: Union[SparseSpec, HybridSpec], mode: Mode, sp: float,
         core: CoreConfig, dense_too: bool) -> Dict[str, float]:
    eff = efficiency(design, sp, core)
    name = design.name if isinstance(design, HybridSpec) else design.label()
    row = {
        "design": name, "mode": mode.value, "speedup": sp,
        "power_mw": eff.power_mw, "area_kum2": eff.area_kum2,
        "tops_w": eff.tops_w, "tops_mm2": eff.tops_mm2,
    }
    if dense_too:
        dense_eff = efficiency(design, 1.0, core)
        row["dense_tops_w"] = dense_eff.tops_w
        row["dense_tops_mm2"] = dense_eff.tops_mm2
    return row


def sweep(designs: Sequence[Union[SparseSpec, HybridSpec]], mode: Mode,
          core: CoreConfig = CoreConfig(), seed: int = 0,
          mask_model: MaskModel = DEFAULT_MASK_MODEL, dense_too: bool = True,
          cache: Optional[ResultsCache] = None) -> List[Dict[str, float]]:
    """Score a design list on one category through the batched engine.

    Cache hits are returned as-is; all misses are evaluated together in a
    single stacked-config pass (see
    :func:`repro.core.hybrid.category_design_speedup_batched`) and written
    back to the cache.  Row order follows ``designs``.
    """
    rows: List[Optional[Dict]] = [None] * len(designs)
    miss_ix: List[int] = []
    keys: List[Optional[str]] = [None] * len(designs)
    for i, d in enumerate(designs):
        if cache is not None:
            keys[i] = design_fingerprint(d, mode, core, seed, mask_model,
                                         extra=("row", dense_too))
            row = cache.get(keys[i])
            if row is not None:
                rows[i] = row
                continue
        miss_ix.append(i)
    if miss_ix:
        wls = category_workloads(mode)
        sps = category_design_speedup_batched(
            [designs[i] for i in miss_ix], wls, core, seed=seed,
            mask_model=mask_model)
        for i, sp in zip(miss_ix, sps):
            rows[i] = _row(designs[i], mode, float(sp), core, dense_too)
            if cache is not None:
                cache.put(keys[i], rows[i])
    return rows  # type: ignore[return-value]


def score(design: Union[SparseSpec, HybridSpec], mode: Mode,
          core: CoreConfig = CoreConfig(), seed: int = 0,
          mask_model: MaskModel = DEFAULT_MASK_MODEL,
          dense_too: bool = True) -> Dict[str, float]:
    """One DSE row: speedup on the category + costs + efficiency.

    Single-design wrapper over :func:`sweep` (no cache); kept for API
    compatibility and as the scalar parity reference.
    """
    sp = category_design_speedup(design, category_workloads(mode), core,
                                 seed=seed, mask_model=mask_model)
    return _row(design, mode, sp, core, dense_too)


def pareto(rows: Sequence[Dict[str, float]], x: str, y: str
           ) -> List[Dict[str, float]]:
    """Rows not dominated in the (maximize x, maximize y) sense."""
    out = []
    for r in rows:
        if not any((o[x] >= r[x] and o[y] >= r[y] and
                    (o[x] > r[x] or o[y] > r[y])) for o in rows):
            out.append(r)
    return sorted(out, key=lambda r: -r[x])
