"""Griffin hybrid morphing (paper Section IV-B, Table III, Table VI).

A hybrid design is one physical core (the dual-sparse base determines the
silicon) that *morphs* per workload category: the 9-entry ABUF, BBUF, extra
adder tree and MUX network bought for dual sparsity are re-purposed as a
deeper single-sided window when only one tensor is sparse.  A plain dual
design instead *downgrades* (ignores the idle resources).

``select_mode`` is the runtime policy: given declared/measured tensor
sparsity it picks the execution mode.  The same policy drives both layers
of the reproduction: the cycle model (this module's ``design_speedup``)
and the TPU execution substrate — ``kernels.griffin_spmm.auto_matmul``
calls it per op, and the framework layer calls it per GEMM through
``models.common.griffin_linear`` (DESIGN.md Section 4).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Union

import numpy as np

from .evaluate import (MaskModel, DEFAULT_MASK_MODEL, network_speedup,
                       network_speedup_batched, Workload)
from .spec import CoreConfig, HybridSpec, Mode, SparseSpec

# Sparsity below this threshold is not worth skipping (metadata/arbitration
# overheads would dominate); the paper treats ~<5% as dense.
SPARSE_THRESHOLD = 0.05


def select_mode(a_sparsity: float, b_sparsity: float,
                threshold: float = SPARSE_THRESHOLD,
                b_threshold: Optional[float] = None) -> Mode:
    """Pick the execution mode from declared/measured tensor sparsities.

    ``threshold`` gates the A side (and the B side too unless
    ``b_threshold`` overrides it separately).  Tuned kernel plans
    (repro.tuning, DESIGN.md Section 12) raise/lower these per family: the
    thresholds change *which* kernel runs, never what it computes — skipped
    blocks are exactly zero either way — so any threshold keeps greedy
    decode token-identical.
    """
    b_thr = threshold if b_threshold is None else b_threshold
    return Mode.of(a_sparsity > threshold, b_sparsity > b_thr)


def running_spec(design: Union[SparseSpec, HybridSpec], mode: Mode
                 ) -> SparseSpec:
    """The configuration the core actually runs for a model category."""
    if isinstance(design, HybridSpec):
        return design.spec_for(mode)
    return design.degrade_to(mode)


def design_speedup(design: Union[SparseSpec, HybridSpec], wl: Workload,
                   core: CoreConfig, seed: int = 0,
                   mode: Optional[Mode] = None,
                   mask_model: MaskModel = DEFAULT_MASK_MODEL) -> float:
    """Speedup of a (possibly hybrid) design on one workload."""
    mode = mode or wl.mode
    spec = running_spec(design, mode)
    return network_speedup(spec, wl, core, seed=seed, mode=mode,
                           mask_model=mask_model)


def category_design_speedup(design: Union[SparseSpec, HybridSpec],
                            workloads: Sequence[Workload], core: CoreConfig,
                            seed: int = 0, mode: Optional[Mode] = None,
                            mask_model: MaskModel = DEFAULT_MASK_MODEL
                            ) -> float:
    sp = [design_speedup(design, w, core, seed=seed + i, mode=mode,
                         mask_model=mask_model)
          for i, w in enumerate(workloads)]
    return float(np.exp(np.mean(np.log(sp))))


def category_design_speedup_batched(designs: Sequence[Union[SparseSpec,
                                                            HybridSpec]],
                                    workloads: Sequence[Workload],
                                    core: CoreConfig, seed: int = 0,
                                    mode: Optional[Mode] = None,
                                    mask_model: MaskModel = DEFAULT_MASK_MODEL
                                    ) -> np.ndarray:
    """Category speedups for a whole stack of (possibly hybrid) designs.

    Designs morph/degrade to their running spec per workload category, the
    resulting specs are deduplicated (two designs running the same config
    score identically), and the unique stack goes through the batched
    evaluation engine once per workload.  Bit-exact with per-design
    :func:`category_design_speedup` calls; this is the entry point
    :func:`repro.core.dse.sweep` uses.
    """
    logs = np.zeros((len(workloads), len(designs)))
    for i, wl in enumerate(workloads):
        wl_mode = mode or wl.mode
        specs = [running_spec(d, wl_mode) for d in designs]
        uniq: list = []
        index: dict = {}
        inverse = np.empty(len(specs), dtype=np.int64)
        for j, sp in enumerate(specs):
            if sp not in index:
                index[sp] = len(uniq)
                uniq.append(sp)
            inverse[j] = index[sp]
        sp_u = network_speedup_batched(uniq, wl, core, seed=seed + i,
                                       mode=wl_mode, mask_model=mask_model)
        logs[i] = np.log(sp_u)[inverse]
    return np.exp(logs.mean(axis=0))
