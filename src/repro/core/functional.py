"""Functional fidelity: execute the borrowing schedule numerically.

The scheduler decides *when and where* every effectual multiply runs; this
module checks that the decision is hardware-legal and that executing it
reproduces the exact GEMM:

  - every nonzero operand is executed exactly once;
  - no multiplier slot is double-booked in a cycle;
  - every borrow respects the (d1, d2, d3) windows (one-sided lanes,
    ring cross-PE, bounded time span per cycle);
  - accumulating the scheduled multiplies equals A @ B bit-for-bit in f64.

These are the invariants the hypothesis property suite sweeps.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from .evaluate import _pack_stream
from .scheduler import Schedule, schedule, shuffle_lanes
from .spec import CoreConfig, SparseSpec


def verify_schedule(mask: np.ndarray, sched: Schedule, d1: int, d2: int,
                    d3: int) -> None:
    """Assert every hardware invariant of a recorded schedule."""
    assert sched.cyc is not None, "schedule must be recorded"
    ntiles, T, K0, G = mask.shape
    placed = sched.cyc >= 0
    # 1. completeness: each effectual element placed exactly once
    np.testing.assert_array_equal(placed, mask)
    if not mask.any():
        return
    ti, ts, ls, gs = np.nonzero(mask)
    cyc = sched.cyc[ti, ts, ls, gs].astype(np.int64)
    lt = sched.lane[ti, ts, ls, gs].astype(np.int64)
    gt = sched.grp[ti, ts, ls, gs].astype(np.int64)
    # 2. routing windows
    dl = ls - lt
    assert (dl >= 0).all() and (dl <= d2).all(), "lane window violated"
    dg = (gs - gt) % G
    assert (dg <= d3).all() or G == 1, "cross-PE window violated"
    # 3. no slot double-booking
    slot_ids = ((ti * (cyc.max() + 1) + cyc) * K0 + lt) * G + gt
    assert len(np.unique(slot_ids)) == len(slot_ids), "slot double-booked"
    # 4. per-cycle time span within the (1+d1)-chunk window
    order = np.lexsort((ts, cyc, ti))
    key = ti[order] * (cyc.max() + 1) + cyc[order]
    tso = ts[order]
    first = np.r_[True, key[1:] != key[:-1]]
    starts = np.flatnonzero(first)
    ends = np.r_[starts[1:], len(key)]
    for s, e in zip(starts, ends):
        assert tso[s:e].max() - tso[s:e].min() <= d1, "time window violated"
    # 5. cycle count covers all placements
    assert (cyc < sched.cycles[ti]).all()


def execute_b_sparse(a: np.ndarray, b: np.ndarray, spec: SparseSpec,
                     core: CoreConfig = CoreConfig()
                     ) -> Tuple[np.ndarray, int]:
    """Run the Sparse.B pipeline end-to-end: preprocess B (schedule with
    metadata), then execute cycle-by-cycle multiplies and accumulate.

    Returns (C, executed_ops).  C must equal a @ b exactly (f64).
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    k0, n0 = core.k0, core.n0
    sub = min(1 + spec.db3, n0)
    bt = _pack_stream(b != 0, k0, sub)                 # (ngrp, T, K0, sub)
    bv = _pack_values(b, k0, sub)
    if spec.shuffle:
        bt = shuffle_lanes(bt)
        bv = shuffle_lanes(bv)
    sched = schedule(bt, spec.db1, spec.db2, spec.db3, shuffle=False,
                     record=True)
    verify_schedule(bt, sched, spec.db1, spec.db2, spec.db3)
    # Execute: each placed element (tile g-group, t, l, g) multiplies
    # A[:, k(t,l)] with its B value and accumulates into column n(tile, g).
    # Source k is recovered through the same (shuffled) packing of the k
    # index grid, so the A operand selection is exactly what the AMUX does.
    kidx = _pack_values(
        np.broadcast_to(np.arange(k0 * (-(-K // k0)), dtype=np.int64)[:, None],
                        (k0 * (-(-K // k0)), b.shape[1])).copy(),
        k0, sub)
    if spec.shuffle:
        kidx = shuffle_lanes(kidx)
    c = np.zeros((M, -(-N // sub) * sub), dtype=np.float64)
    ti, ts, ls, gs = np.nonzero(bt)
    col = ti * sub + gs                                # original column id
    ks = kidx[ti, ts, ls, gs]
    vals = bv[ti, ts, ls, gs].astype(np.float64)
    a_pad = np.zeros((M, int(kidx.max()) + 1), dtype=np.float64)
    a_pad[:, :K] = a
    # accumulate per element: C[:, col] += A[:, k] * v   (duplicates summed)
    contrib = a_pad[:, ks] * vals[None, :]             # (M, nels)
    np.add.at(c.T, col, contrib.T)
    return c[:, :N], len(ks)


def _pack_values(x: np.ndarray, k0: int, g0: int) -> np.ndarray:
    """Same packing as _pack_stream but for value (or index) arrays."""
    K, Gt = x.shape
    T = -(-K // k0)
    nt = -(-Gt // g0)
    pad = np.zeros((k0 * T, nt * g0), dtype=x.dtype)
    pad[:K, :Gt] = x
    return pad.reshape(k0, T, nt, g0).transpose(2, 1, 0, 3)
