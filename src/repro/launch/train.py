"""End-to-end training driver.

On real hardware this runs the production mesh; on CPU it drives a reduced
config end-to-end (examples/train_lm.py uses it to train a ~small model for
a few hundred steps).  Features exercised: sharded train step, deterministic
sharded data, checkpoint/restart (atomic + retention), preemption handling,
straggler detection hooks, optional Griffin pruning schedule.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import PreemptionGuard, latest_step, restore, save
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, make_iterator
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.runtime.elastic import plan_mesh
from repro.runtime.straggler import StragglerDetector
from repro.runtime.train import (TrainState, apply_prune, init_state,
                                 jit_train_step, make_train_step,
                                 state_shardings)
from repro.runtime.sharding import shard_batch
from repro.sparsity.pruning import PruneSchedule


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--prune-sparsity", type=float, default=0.0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = build_model(cfg)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    mesh = plan_mesh(len(jax.devices()), args.model_parallel)
    opt = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                      total_steps=args.steps)

    guard = PreemptionGuard()
    guard.install()

    batch_shapes = {
        "tokens": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32)}
    if cfg.is_encdec:
        batch_shapes["frames"] = jax.ShapeDtypeStruct(
            (args.batch, cfg.enc_frames, cfg.d_model), jnp.float32)
    b_sh = shard_batch(batch_shapes, mesh)
    step_fn, st_sh = jit_train_step(api, opt, mesh, b_sh)

    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        template = jax.eval_shape(
            lambda: init_state(api, jax.random.PRNGKey(0)))
        state = restore(args.ckpt_dir, template, shardings=st_sh)
        start = int(np.asarray(state.step))
        print(f"restored step {start} from {args.ckpt_dir}")
    else:
        state = init_state(api, jax.random.PRNGKey(0))

    prune = (PruneSchedule(args.prune_sparsity, begin_step=args.steps // 4,
                           ramp_steps=args.steps // 2, block_k=128, unit=32)
             if args.prune_sparsity > 0 else None)

    it = make_iterator(cfg, shape, DataConfig(seed=0), start_step=start)
    detector = StragglerDetector(num_hosts=1)
    t_last = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, metrics = step_fn(state, batch)
        dt = time.time() - t_last
        t_last = time.time()
        detector.record(0, dt)
        if prune is not None and step % 25 == 0:
            state = apply_prune(state, prune,
                                match=lambda k: any(s in k for s in
                                                    ("w_gate", "w_up",
                                                     "w_down", "wq", "wk",
                                                     "wv", "wo")))
        if step % args.log_every == 0:
            print(f"step {step}: loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} ({dt*1e3:.0f} ms)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save(args.ckpt_dir, step + 1, state)
        if guard.should_stop:
            if args.ckpt_dir:
                save(args.ckpt_dir, step + 1, state)
            print("preemption requested: checkpointed and exiting")
            break
    it.close()
    print(f"final loss: {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
