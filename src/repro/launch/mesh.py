"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state.  The single-pod production mesh is 16x16 =
256 chips ("data", "model"); the multi-pod mesh is 2x16x16 = 512 chips with
a leading "pod" axis that shards only the batch (data parallelism across
pods; parameters replicate across pods so cross-pod traffic is gradient
reduction only).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:          # older jax: Auto is the only behaviour
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def chips(mesh) -> int:
    import numpy as np
    return int(np.prod(list(mesh.shape.values())))
