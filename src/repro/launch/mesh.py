"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state.  The single-pod production mesh is 16x16 =
256 chips ("data", "model"); the multi-pod mesh is 2x16x16 = 512 chips with
a leading "pod" axis that shards only the batch (data parallelism across
pods; parameters replicate across pods so cross-pod traffic is gradient
reduction only).
"""
from __future__ import annotations

import jax


def serve_mesh(spec: str = "1x1", devices=None):
    """Serving mesh from a ``"DxM"`` spec (data x model), e.g. ``"2x4"`` —
    the ``--mesh`` flag of launch/serve.py and the shape the mesh-parallel
    engine (runtime.mesh_serve, DESIGN.md Section 10) partitions over.
    ``"1x1"`` is the single-device special case.  Raises when the spec is
    malformed or asks for more devices than exist (on CPU, export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to emulate a
    multi-device host — the CI sharded job does)."""
    import numpy as np
    from jax.sharding import Mesh

    parts = spec.lower().split("x")
    if len(parts) != 2 or not all(p.isdigit() for p in parts):
        raise ValueError(f"mesh spec {spec!r} is not 'DxM' (e.g. '2x4')")
    d, m = int(parts[0]), int(parts[1])
    if d < 1 or m < 1:
        raise ValueError(f"mesh spec {spec!r}: axes must be >= 1")
    devs = list(devices if devices is not None else jax.devices())
    if d * m > len(devs):
        raise ValueError(
            f"mesh {spec} needs {d * m} devices, have {len(devs)} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=N "
            "to emulate)")
    return Mesh(np.array(devs[:d * m]).reshape(d, m), ("data", "model"))


def mesh_spec(mesh) -> str:
    """The ``"DxM"`` spec of a serving mesh — inverse of ``serve_mesh`` and
    the string the fault-tolerant engines log after an elastic remesh
    (DESIGN.md Section 11)."""
    return (f"{mesh.shape.get('data', 1)}x{mesh.shape.get('model', 1)}")


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:          # older jax: Auto is the only behaviour
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def chips(mesh) -> int:
    import numpy as np
    return int(np.prod(list(mesh.shape.values())))
