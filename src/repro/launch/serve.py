"""Serving driver: batched prefill + decode with Griffin sparse weights.

Demonstrates the paper's hybrid execution at the serving layer: weights are
block-pruned offline (Sparse.B preprocessing), the runtime measures tensor
sparsity, selects the execution category per model (core.hybrid) and decodes
batched requests.  On CPU this drives a reduced config
(examples/sparse_serve.py); on TPU the same code serves the full configs.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import Mode, select_mode
from repro.data import DataConfig, synth_batch
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.runtime.elastic import plan_mesh
from repro.runtime.serve import greedy_generate, jit_serve_fns
from repro.sparsity import block_prune, sparsity_of, tensor_report


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--sparsity", type=float, default=0.8)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = build_model(cfg)
    mesh = plan_mesh(len(jax.devices()), args.model_parallel)
    params = api.init(jax.random.PRNGKey(0))

    if args.sparsity > 0:
        # Sparse.B path: offline block pruning of the FFN weights
        def prune_leaf(path, leaf):
            key = jax.tree_util.keystr(path)
            if leaf.ndim >= 2 and any(s in key for s in
                                      ("w_gate", "w_up", "w_down")):
                flat = leaf.reshape(-1, leaf.shape[-1])
                return block_prune(flat, args.sparsity, block_k=32,
                                   unit=16).reshape(leaf.shape)
            return leaf
        params = jax.tree_util.tree_map_with_path(prune_leaf, params)
    b_sparsity = float(np.mean([v for v in tensor_report(params).values()]))
    mode = select_mode(0.0, b_sparsity)
    print(f"weight sparsity {b_sparsity:.2f} -> execution mode {mode.value} "
          f"(Griffin morphs to "
          f"{'Sparse.B(8,0,1)' if mode == Mode.B else mode.value})")

    cache_len = args.prompt_len + args.gen_len + 1
    shape = ShapeConfig("serve", args.prompt_len, args.batch, "prefill")
    batch = {k: jnp.asarray(v) for k, v in
             synth_batch(cfg, shape, DataConfig(seed=1), step=0).items()
             if k != "labels"}
    t0 = time.time()
    out = greedy_generate(api, params, batch, args.gen_len, cache_len)
    dt = time.time() - t0
    toks = args.batch * args.gen_len
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s on {jax.default_backend()})")
    print("sample token ids:", np.asarray(out[0][:12]))


if __name__ == "__main__":
    main()
