"""Serving driver: continuous-batching engine over the jitted serve fns.

Demonstrates the paper's hybrid execution at the serving layer
(DESIGN.md Section 8): weights are block-pruned offline (Sparse.B
preprocessing, optionally compacted into ``GriffinWeights`` with
``--use-kernels``), the engine measures the workload category at runtime,
re-invokes ``core.hybrid.select_mode`` and decodes a mixed prompt/gen-length
request trace with per-slot admission/eviction over a fixed KV arena.  The
jitted prefill/decode fns and shardings come from
``runtime.serve.jit_serve_fns`` on the planned mesh.

On CPU this drives a reduced config (examples/sparse_serve.py, the
scripts/ci.sh serve stage); on TPU the same code serves the full
configs.  ``--parity`` replays every request through the batch-1
``greedy_generate`` oracle and asserts token-identical output.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.platform import kernel_interpret
from repro.models import build_model
from repro.launch.mesh import mesh_spec, serve_mesh
from repro.runtime import slo
from repro.runtime.config import EngineConfig
from repro.runtime.elastic import plan_mesh
from repro.runtime.engine import ServeEngine, synthetic_trace
from repro.runtime.fault import parse_fault_spec
from repro.runtime.mesh_serve import MeshServeEngine
from repro.runtime.router import RouterEngine
from repro.runtime.serve import greedy_generate, jit_serve_fns
from repro.runtime.slo import DegradationConfig
from repro.runtime.straggler import StragglerConfig, StragglerDetector
from repro.sparsity import sparsify_params
from repro.tuning import load_plan


def _lens(spec: str):
    return tuple(int(x) for x in spec.split(",") if x)


def _parse_slo(spec: str):
    """``--slo`` spec: comma-separated ``ttft=<ticks>`` (first-token
    deadline) and ``slack=<factor>`` (completion deadline = slack x the
    request's own expected service).  Either half may be omitted."""
    ttft, slack = None, None
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        k, _, v = part.partition("=")
        if k == "ttft":
            ttft = int(v)
        elif k == "slack":
            slack = float(v)
        else:
            raise ValueError(f"--slo {spec!r}: unknown key {k!r} "
                             "(known: ttft, slack)")
    return ttft, slack


def _fault_hooks(args, devices, num_hosts):
    """(injector, detector) from ``--inject-fault`` (DESIGN.md Section 11);
    a delay spec also arms a straggler detector so the eviction path — not
    the injector — drives the recovery."""
    if not args.inject_fault:
        return None, None
    spec = parse_fault_spec(args.inject_fault)
    injector = spec.build(devices)
    detector = None
    if spec.kind == "delay":
        detector = StragglerDetector(
            num_hosts, StragglerConfig(evict_after=args.evict_after))
    return injector, detector


def build_engine(api, params, args, mesh, plan=None, econf=None) -> ServeEngine:
    """Engine from an ``EngineConfig`` (runtime.config) — the one
    construction path for both the unsharded and mesh-parallel engines.
    ``econf=None`` derives it from the CLI namespace (every flag explicit);
    a still-unset ``arena.cache_len`` falls back to the trace-driven bound
    (``EngineConfig.derive_cache_len``, the single source of truth the old
    duplicated derivations collapsed onto)."""
    if econf is None:
        econf = EngineConfig.from_args(args)
    if econf.arena.cache_len is None:
        econf = econf.with_fields(cache_len=EngineConfig.derive_cache_len(
            _lens(args.prompt_lens), _lens(args.gen_lens),
            getattr(args, "length_dist", "choice")))
    econf = econf.replace(kernels=dataclasses.replace(
        econf.kernels,
        # kernels imply interpret lowering on CPU (configs.platform)
        interpret=econf.kernels.use_kernels and kernel_interpret()))
    if econf.mesh:
        # mesh-parallel path (DESIGN.md Section 10): params model-sharded,
        # arena slot/head-sharded, per-Mode jits carry explicit shardings.
        smesh = serve_mesh(econf.mesh)
        injector, detector = _fault_hooks(
            args, list(smesh.devices.flat), smesh.devices.shape[0])
        return MeshServeEngine(api, params, mesh=smesh, config=econf,
                               fault_injector=injector, straggler=detector,
                               plan=plan)
    injector, detector = _fault_hooks(args, jax.devices(), 1)
    fns = None
    if econf.arena.page_size is None:
        # the sharding-annotated serve fns assume the fixed-arena cache
        # tree; paged engines trace through the default opaque-cache fns
        ns, cl = econf.arena.num_slots, econf.arena.cache_len
        dc = econf.sched.decode_chunk
        fns = lambda: jit_serve_fns(api, mesh, ns, cl, params=params,
                                    decode_chunk=dc)
    return ServeEngine(api, params, config=econf, fns_factory=fns,
                       fault_injector=injector, straggler=detector, plan=plan)


def _print_slo(rows, summary) -> None:
    """Per-request SLO attainment table + the aggregate latency summary
    (virtual ticks — runtime.slo's recorded deviation from wall clock)."""
    print("per-request SLO attainment (virtual ticks):")
    for r in rows:
        mark = {True: "ok", False: "MISS", None: "-"}[r["attained"]]
        print(f"  rid {r['rid']:>3} prio {r['priority']} "
              f"ttft {r['ttft'] if r['ttft'] is not None else '-':>4} "
              f"done {r['completion'] if r['completion'] is not None else '-':>4} "
              f"tokens {r['tokens']:>3} {r['attribution']:<8} {mark}")
    print(f"SLO summary: {summary['completed']}/{summary['requests']} "
          f"completed, {summary['shed']} shed, "
          f"ttft p50/p99 {summary['ttft_p50']}/{summary['ttft_p99']}, "
          f"itl p50/p99 {summary['itl_p50']}/{summary['itl_p99']}, "
          f"attainment {summary['slo_attainment']}")


def _run_router(api, params, args, mesh, cfg, fam_plan, reqs,
                econf=None) -> None:
    """Multi-replica path (DESIGN.md Section 13): N engines behind the
    SLO-aware router.  A 'replica:' --inject-fault spec is consumed at
    the router level; kill/delay specs keep arming replica 0's internal
    recovery path as usual."""
    replica_faults = []
    if args.inject_fault:
        spec = parse_fault_spec(args.inject_fault)
        if spec.kind == "replica":
            replica_faults = [spec.build_replica()]
            args.inject_fault = None

    engines = []     # build eagerly so replica 0 reports its config once

    def make_engine():
        eng = build_engine(api, params, args, mesh, plan=fam_plan,
                           econf=econf)
        engines.append(eng)
        return eng

    bound = args.queue_bound or None
    degradation = None
    if args.shed_policy == "none":
        bound = None
    elif bound is None:
        bound = 2 * args.slots * args.replicas
    if args.shed_policy == "degrade":
        degradation = DegradationConfig()
    router = RouterEngine(make_engine, args.replicas,
                          queue_bound=bound,
                          hedge_after=args.hedge_ms or None,
                          degradation=degradation,
                          replica_faults=replica_faults)
    e0 = router.replicas[0].engine
    print(f"router: {args.replicas} replicas x {args.slots} slots, "
          f"queue bound {bound or 'unbounded'}, "
          f"shed policy {args.shed_policy}, "
          f"hedge after {args.hedge_ms or 'off'}, "
          f"weight sparsity {e0.b_sparsity:.2f} -> mode {e0.mode.value}")

    t0 = time.time()
    outs = router.run(reqs)
    dt = time.time() - t0
    toks = sum(len(o.tokens) for o in outs.values())
    print(f"routed {len(reqs)} requests / {toks} tokens in {dt:.2f}s "
          f"over {router.clock} virtual ticks; "
          f"stats {router.stats}, max queue depth "
          f"{router.max_queue_depth}"
          + (f", ladder history {router.ladder.history}"
             if router.ladder else ""))
    if replica_faults:
        print(f"replica fault log: {router.health_log}")
        assert router.stats["completed"] + router.stats["shed"] >= len(reqs), \
            "router fault run left requests unaccounted"

    rows = slo.request_rows(outs, reqs)
    _print_slo(rows, slo.latency_summary(rows))

    if args.overload_smoke:
        assert bound is not None, "--overload-smoke needs a bounded queue"
        assert router.max_queue_depth <= bound, (
            f"queue depth {router.max_queue_depth} exceeded bound {bound}")
        assert router.stats["shed"] > 0, (
            "overload trace shed nothing — not actually overloaded?")
        print(f"overload smoke OK: depth {router.max_queue_depth} <= "
              f"{bound}, shed {router.stats['shed']}")

    if args.parity:
        eng = router.up_replicas[0].engine
        if any(len(e.mode_history) > 1 for e in engines if e is not None):
            print("parity SKIPPED: execution mode changed mid-run")
            return
        checked = 0
        for r in reqs:
            o = outs[r.rid]
            if o.finished < 0:
                continue
            with eng._scope():
                ref = greedy_generate(
                    api, params, r.as_batch(), steps=r.max_new_tokens,
                    cache_len=eng.cache_len,
                    prompt_bucket=eng.bucket_for(r.prompt_len))
            assert np.array_equal(np.asarray(o.tokens),
                                  np.asarray(ref[0])), (
                f"request {r.rid} diverged from greedy oracle")
            checked += 1
        print(f"parity OK: {checked} completed requests token-identical "
              "to greedy_generate")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--config", default=None, metavar="PATH",
                    help="EngineConfig JSON (runtime.config.EngineConfig"
                         ".to_json): the file sets the baseline; CLI flags "
                         "set to non-default values override it")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=None,
                    help="activate the paged KV arena (DESIGN.md Section "
                         "14): power-of-two tokens per page; default keeps "
                         "the fixed num_slots x cache_len arena")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="physical page-pool size (default: fixed-arena "
                         "capacity + the DUMP page)")
    ap.add_argument("--kv-dtype", choices=("fp32", "int8"), default="fp32",
                    help="paged KV page dtype: int8 stores quantized pages "
                         "with per-token-row scales (gated logit tolerance; "
                         "fp32 pages stay token-exact)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-lens", default="8,16,32")
    ap.add_argument("--gen-lens", default="4,8,16")
    ap.add_argument("--arrival-every", type=int, default=0)
    ap.add_argument("--arrival-process", choices=("fixed", "bursty"),
                    default="fixed",
                    help="'bursty' draws Markov-modulated arrival gaps "
                         "(seeded, replayable) instead of the fixed "
                         "--arrival-every stagger")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="bursty calm-state arrival rate (requests/tick)")
    ap.add_argument("--burst-rate", type=float, default=4.0,
                    help="bursty burst-state arrival rate (requests/tick)")
    ap.add_argument("--length-dist", choices=("choice", "heavy"),
                    default="choice",
                    help="'heavy' draws Pareto generation lengths (tail "
                         "stragglers) instead of a uniform choice over "
                         "--gen-lens")
    ap.add_argument("--priorities", default="0",
                    help="comma-separated priority classes drawn per "
                         "request (0 = most important)")
    ap.add_argument("--sparsity", type=float, default=0.8)
    ap.add_argument("--use-kernels", action="store_true",
                    help="compact pruned weights into GriffinWeights and "
                         "execute the Sparse.B kernels (interpret on CPU); "
                         "default keeps the pruned-dense twin on plain jnp")
    ap.add_argument("--policy", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--measure-every", type=int, default=8)
    ap.add_argument("--decode-chunk", type=int, default=8,
                    help="fused decode steps per host round-trip (1 = the "
                         "per-step PR 3 hot path)")
    ap.add_argument("--max-syncs-per-token", type=float, default=0.0,
                    help="assert host_syncs/token <= this after the run "
                         "(0 disables; the scripts/ci.sh serve stage "
                         "uses 0.25)")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="serve mesh-parallel on a data x model device mesh "
                         "(e.g. 2x4; needs D*M devices — on CPU export "
                         "XLA_FLAGS=--xla_force_host_platform_device_count"
                         "=8).  '1x1' is the single-device special case; "
                         "default keeps the unsharded engine")
    ap.add_argument("--spmd-fallback", action="store_true",
                    help="serve >1 meshes through the decompaction oracle "
                         "instead of the shard_map'd Pallas kernels (the "
                         "parity baseline; scripts/ci.sh smokes it to keep "
                         "the oracle alive)")
    ap.add_argument("--plan", default=None, metavar="PATH",
                    help="tuned kernel plan JSON (repro.launch.autotune, "
                         "DESIGN.md Section 12): this model family's entry "
                         "steers weight-compaction granularity and Mode-"
                         "selection thresholds; token output is unchanged "
                         "by construction")
    ap.add_argument("--parity", action="store_true",
                    help="assert engine tokens == greedy_generate per "
                         "request")
    ap.add_argument("--inject-fault", default=None, metavar="SPEC",
                    help="deterministic chaos (DESIGN.md Section 11): "
                         "'kill:<dev>@<step>[:<phase>]' raises a DeviceLoss "
                         "for mesh device index <dev> at engine step <step> "
                         "(phase admission|prefill|decode, default decode); "
                         "'delay:<host>@<step>[:<factor>]' inflates one "
                         "data-row's step times until the straggler "
                         "detector evicts it.  Either way the engine "
                         "snapshots, remeshes onto the survivors and "
                         "finishes the trace token-exactly")
    ap.add_argument("--snapshot-dir", default=None,
                    help="write tick-start snapshots through "
                         "checkpoint.save here and recover via "
                         "checkpoint.restore (default keeps snapshots "
                         "in host memory)")
    ap.add_argument("--remesh-model-parallel", type=int, default=None,
                    help="TP degree cap for the post-loss mesh "
                         "(default: keep the current model-axis size)")
    ap.add_argument("--evict-after", type=int, default=3,
                    help="straggler eviction streak for delay faults")
    ap.add_argument("--replicas", type=int, default=0, metavar="N",
                    help="serve through the SLO-aware multi-replica "
                         "router (DESIGN.md Section 13): N engines behind "
                         "one bounded-EDF admission queue; 0 keeps the "
                         "single-engine path.  'replica:<i>@<tick>"
                         "[:<during>[:<recover>]]' --inject-fault specs "
                         "kill whole replicas at the router level")
    ap.add_argument("--queue-bound", type=int, default=0,
                    help="router admission-queue bound (0 = unbounded "
                         "baseline: never sheds for capacity)")
    ap.add_argument("--slo", default=None, metavar="SPEC",
                    help="attach virtual-tick SLOs to the trace: "
                         "'ttft=<ticks>,slack=<factor>' (either half "
                         "optional); deadlines drive router EDF admission "
                         "and the attainment summary")
    ap.add_argument("--hedge-ms", type=int, default=0,
                    help="router tail-latency hedge: a dispatched request "
                         "with no first token after this many virtual "
                         "ticks is re-dispatched to a second replica and "
                         "the loser cancelled (0 = off)")
    ap.add_argument("--shed-policy", choices=("none", "shed", "degrade"),
                    default="shed",
                    help="router overload response: 'none' = unbounded "
                         "queue (the baseline failure mode), 'shed' = "
                         "bounded queue only, 'degrade' = bounded queue + "
                         "the pressure ladder (chunk cap -> cheaper Mode "
                         "-> priority shed)")
    ap.add_argument("--overload-smoke", action="store_true",
                    help="assert the router stayed bounded: "
                         "max_queue_depth <= --queue-bound and shed "
                         "count > 0 (the CI overload stage)")
    args = ap.parse_args(argv)
    econf = EngineConfig.from_args(
        args, defaults={d: ap.get_default(d) for d in vars(args)})
    if econf.arena.cache_len is None:
        econf = econf.with_fields(cache_len=EngineConfig.derive_cache_len(
            _lens(args.prompt_lens), _lens(args.gen_lens), args.length_dist))
    # a --config file may have set fields the helpers below still read off
    # the namespace; the resolved config is authoritative either way
    args.slots = econf.arena.num_slots
    args.decode_chunk = econf.sched.decode_chunk
    args.use_kernels = econf.kernels.use_kernels
    args.mesh = econf.mesh
    args.replicas = econf.router.replicas
    args.queue_bound = econf.router.queue_bound or 0
    args.hedge_ms = econf.router.hedge_after or 0
    args.shed_policy = econf.router.shed_policy
    if args.inject_fault is None:
        args.inject_fault = econf.fault.inject
    if args.plan is None:
        args.plan = econf.kernels.plan

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = build_model(cfg)
    mesh = plan_mesh(len(jax.devices()), args.model_parallel)
    params = api.init(jax.random.PRNGKey(0))

    fam_plan = None
    if args.plan:
        fam_plan = load_plan(args.plan).family(cfg.family)
        if fam_plan is None:
            print(f"plan {args.plan} has no entry for family "
                  f"{cfg.family!r}; serving with defaults")

    if args.sparsity > 0:
        # Sparse.B preprocessing: offline block pruning of the GEMM weights
        prune = (dict(block_k=16, block_n=16, unit=8) if args.reduced
                 else dict())
        params = sparsify_params(params, args.sparsity,
                                 compact=args.use_kernels, plan=fam_plan,
                                 **prune)

    ttft_slo, slack_slo = _parse_slo(args.slo) if args.slo else (None, None)
    max_gen = None
    if args.length_dist == "heavy":
        # heavy tails must still fit the fixed cache arena
        max_gen = EngineConfig.heavy_gen_cap(_lens(args.gen_lens))
    reqs = synthetic_trace(cfg, num_requests=args.requests, seed=1,
                           prompt_lens=_lens(args.prompt_lens),
                           gen_lens=_lens(args.gen_lens),
                           arrival_every=args.arrival_every,
                           arrival_process=args.arrival_process,
                           rate=args.rate, burst_rate=args.burst_rate,
                           length_dist=args.length_dist, max_gen=max_gen,
                           priorities=_lens(args.priorities),
                           deadline_slack=slack_slo, ttft_deadline=ttft_slo)

    if args.replicas > 0:
        _run_router(api, params, args, mesh, cfg, fam_plan, reqs,
                    econf=econf)
        return

    engine = build_engine(api, params, args, mesh, plan=fam_plan,
                          econf=econf)
    arena = "fixed"
    if engine._paged is not None:
        arena = (f"paged ps={engine._paged.page_size} "
                 f"x {engine._paged.num_pages} pages "
                 f"({engine._paged.kv_dtype})")
    print(f"engine: {args.slots} slots x cache_len {engine.cache_len} "
          f"({arena}), policy={econf.sched.policy}, "
          f"mesh={args.mesh or 'unsharded'}, weight sparsity "
          f"{engine.b_sparsity:.2f} -> mode {engine.mode.value}")

    t0 = time.time()
    outs = engine.run(reqs)
    dt = time.time() - t0
    toks = engine.stats["emitted"]
    syncs_per_tok = engine.stats["host_syncs"] / max(toks, 1)
    print(f"served {len(reqs)} requests / {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s on {jax.default_backend()}); "
          f"{engine.stats['decode_steps']} decode steps in "
          f"{engine.stats['chunk_calls']} fused chunks "
          f"(decode_chunk={args.decode_chunk}), "
          f"{engine.stats['prefill_calls']} prefills over buckets "
          f"{sorted(engine.prefill_buckets)}, "
          f"{syncs_per_tok:.3f} host syncs/token, "
          f"mode history {[(s, m.value) for s, m in engine.mode_history]}")
    first = outs[reqs[0].rid]
    print("request 0 token ids:", np.asarray(first.tokens[:12]))

    if args.slo:
        rows = slo.request_rows(outs, reqs)
        _print_slo(rows, slo.latency_summary(rows))

    if args.inject_fault:
        assert len(outs) == len(reqs), (
            f"fault run finished {len(outs)}/{len(reqs)} requests")
        assert all(len(o.tokens) > 0 for o in outs.values()), (
            "fault run produced an empty completion")
        final = (mesh_spec(engine.mesh) if isinstance(engine, MeshServeEngine)
                 else "unsharded")
        print(f"fault injected ({args.inject_fault}): "
              f"{engine.recoveries} recoveries, log {engine.recovery_log}, "
              f"final mesh {final}; all {len(reqs)} requests completed")

    if args.max_syncs_per_token > 0:
        assert syncs_per_tok <= args.max_syncs_per_token, (
            f"host syncs/token {syncs_per_tok:.3f} exceeds "
            f"{args.max_syncs_per_token} — the fused decode path is "
            "synchronizing per step again")
        print(f"host-sync budget OK: {syncs_per_tok:.3f} <= "
              f"{args.max_syncs_per_token}")

    if args.parity:
        if engine._paged is not None and engine._paged.kv_dtype != "fp32":
            print("parity SKIPPED: int8 KV pages are gated by logit "
                  "tolerance (benchmarks), not token equality")
            return
        if len(engine.mode_history) > 1:
            # tokens emitted before a mid-run category flip came from the
            # previous mode's kernels; a single final-mode oracle replay
            # would compare across categories
            print("parity SKIPPED: execution mode changed mid-run "
                  f"({[(s, m.value) for s, m in engine.mode_history]})")
            return
        for r in reqs:
            with engine._scope():
                ref = greedy_generate(
                    api, params, r.as_batch(), steps=r.max_new_tokens,
                    cache_len=engine.cache_len,
                    prompt_bucket=engine.bucket_for(r.prompt_len))
            assert np.array_equal(np.asarray(outs[r.rid].tokens),
                                  np.asarray(ref[0])), (
                f"request {r.rid} diverged from greedy oracle")
        print(f"parity OK: all {len(reqs)} requests token-identical to "
              "greedy_generate (bucketed prompts, decode_chunk="
              f"{args.decode_chunk})")


if __name__ == "__main__":
    main()
