import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the device
# count at first initialization, and the dry-run needs 512 placeholder host
# devices to build the production meshes.  (Smoke tests and benchmarks must
# NOT see this: the flag lives only here.)

"""Multi-pod dry-run driver.

For every (architecture x input shape x mesh) cell this driver proves the
distribution config is coherent without hardware:

  - ``check`` pass: full-depth (scan-based) lowering + compile on the
    single-pod 16x16 mesh AND the 2x16x16 multi-pod mesh;
    ``compiled.memory_analysis()`` proves the per-device footprint fits.
  - ``cost`` pass: unrolled depth-1/2 (and, for time-recurrent families,
    two sequence lengths) lowerings on the single-pod mesh;
    ``cost_analysis()`` + HLO collective parsing extrapolate the exact
    per-step FLOPs / bytes / collective bytes for the roofline
    (see repro.roofline.analysis for why extrapolation is needed).

Results append to a JSONL file; the driver is restartable (--only-missing).

Usage:
  python -m repro.launch.dryrun --all --out results/dryrun.jsonl
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k \
      --passes check_single,check_multi,cost
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, all_configs, applicable_shapes
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import chips, make_production_mesh
from repro.models import build_model, input_specs
from repro.optim.adamw import AdamWConfig
from repro.roofline.analysis import (CostSample, extrapolate, model_flops_for,
                                     roofline_terms, sample_costs)
from repro.runtime.sharding import shard_batch, shard_cache, shard_params
from repro.runtime.train import init_state, make_train_step, state_shardings


# ---------------------------------------------------------------------------
# lowering builders
# ---------------------------------------------------------------------------

def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               fsdp_train: bool = True, fsdp_serve: bool = False,
               n_micro: int = 1):
    """Lower the cell's step function with production shardings."""
    api = build_model(cfg)
    rep = NamedSharding(mesh, P())
    if shape.kind == "train":
        step = make_train_step(api, AdamWConfig(), n_micro=n_micro)
        st_sh = state_shardings(api, mesh, fsdp_train)
        st_shapes = jax.eval_shape(
            lambda: init_state(api, jax.random.PRNGKey(0)))
        b_shapes = input_specs(cfg, shape)
        b_sh = shard_batch(b_shapes, mesh)
        m_sh = {"loss": rep, "grad_norm": rep, "lr": rep}
        jfn = jax.jit(step, in_shardings=(st_sh, b_sh),
                      out_shardings=(st_sh, m_sh))
        return jfn.lower(st_shapes, b_shapes)
    p_shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    p_sh = shard_params(p_shapes, mesh, fsdp=fsdp_serve)
    if shape.kind == "prefill":
        b_shapes = input_specs(cfg, shape)
        b_sh = shard_batch(b_shapes, mesh)
        cache_shapes = jax.eval_shape(
            lambda: api.init_cache(shape.global_batch, shape.seq_len))
        c_sh = shard_cache(cache_shapes, mesh, shape.global_batch)

        def fn(params, batch):
            return api.prefill(params, batch, cache_len=shape.seq_len)

        jfn = jax.jit(fn, in_shardings=(p_sh, b_sh),
                      out_shardings=(c_sh, None))
        return jfn.lower(p_shapes, b_shapes)
    # decode: one new token against a seq_len cache
    cache_shapes = jax.eval_shape(
        lambda: api.init_cache(shape.global_batch, shape.seq_len))
    c_sh = shard_cache(cache_shapes, mesh, shape.global_batch)
    tok_shapes = input_specs(cfg, shape)["token"]
    tok_sh = shard_batch(tok_shapes, mesh)
    jfn = jax.jit(api.decode_step,
                  in_shardings=(p_sh, c_sh, tok_sh),
                  out_shardings=(None, c_sh))
    return jfn.lower(p_shapes, cache_shapes, tok_shapes)


# ---------------------------------------------------------------------------
# cost-pass variants (see roofline.analysis docstring)
# ---------------------------------------------------------------------------

def _cost_cfg(cfg: ModelConfig, shape: ShapeConfig, depth_units: int
              ) -> ModelConfig:
    """Reduced-depth, scan-free variant at full width/batch."""
    kv = shape.seq_len if shape.kind != "decode" else cfg.kv_chunk
    kw: Dict[str, Any] = dict(scan_layers=False, kv_chunk=kv,
                              loss_chunk=shape.seq_len)
    if cfg.family == "audio":
        kw.update(num_layers=depth_units, encoder_layers=depth_units)
    elif cfg.family == "hybrid":
        kw.update(num_layers=len(cfg.block_pattern) * depth_units)
    elif cfg.family == "ssm":
        kw.update(num_layers=len(cfg.xlstm_pattern) * depth_units)
    else:
        kw.update(num_layers=depth_units)
    return dataclasses.replace(cfg, **kw)


def _depth_units(cfg: ModelConfig) -> float:
    if cfg.family == "audio":
        return cfg.num_layers                       # (enc+dec) pairs
    if cfg.family == "hybrid":
        return cfg.num_layers / len(cfg.block_pattern)   # 38/3 incl. tail
    if cfg.family == "ssm":
        return cfg.num_layers / len(cfg.xlstm_pattern)
    return cfg.num_layers


def _needs_seq_delta(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Only xLSTM keeps trip>1 inner scans (mLSTM chunk scan + sLSTM step
    scan) under the cost config; its cost is exactly linear in S."""
    return cfg.family == "ssm" and shape.kind != "decode"


def cost_pass(cfg: ModelConfig, shape: ShapeConfig, mesh) -> CostSample:
    units = _depth_units(cfg)
    if not _needs_seq_delta(cfg, shape):
        f1 = sample_costs(lower_cell(_cost_cfg(cfg, shape, 1), shape,
                                     mesh).compile())
        f2 = sample_costs(lower_cell(_cost_cfg(cfg, shape, 2), shape,
                                     mesh).compile())
        return extrapolate(f1, f2, units)
    # 2D (depth x sequence) extrapolation for time-recurrent families
    s1 = 128
    su = shape.seq_len / s1
    sh1 = dataclasses.replace(shape, seq_len=s1)
    sh2 = dataclasses.replace(shape, seq_len=2 * s1)
    f11 = sample_costs(lower_cell(_cost_cfg(cfg, sh1, 1), sh1, mesh).compile())
    f21 = sample_costs(lower_cell(_cost_cfg(cfg, sh1, 2), sh1, mesh).compile())
    f12 = sample_costs(lower_cell(_cost_cfg(cfg, sh2, 1), sh2, mesh).compile())
    f22 = sample_costs(lower_cell(_cost_cfg(cfg, sh2, 2), sh2, mesh).compile())
    base_L = extrapolate(f11, f21, units)      # full depth at s1
    alt_L = extrapolate(f12, f22, units)       # full depth at 2*s1
    return extrapolate(base_L, alt_L, su)      # extend to full S


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def all_cells() -> List[Tuple[str, str, Optional[str]]]:
    """(arch, shape, skip_reason) for all 40 assigned cells."""
    out = []
    for arch, cfg in sorted(all_configs().items()):
        app = set(applicable_shapes(cfg))
        for sname in SHAPES:
            reason = None
            if sname not in app:
                reason = "full-attention arch: long_500k requires " \
                    "sub-quadratic attention (DESIGN.md Section 5)"
            out.append((arch, sname, reason))
    return out


def run_pass(arch: str, sname: str, pass_name: str) -> Dict[str, Any]:
    cfg = all_configs()[arch]
    shape = SHAPES[sname]
    api = build_model(cfg)
    rec: Dict[str, Any] = {"arch": arch, "shape": sname, "pass": pass_name,
                           "status": "ok"}
    t0 = time.time()
    if pass_name in ("check_single", "check_multi"):
        mesh = make_production_mesh(multi_pod=(pass_name == "check_multi"))
        # memory-fit microbatching for the big train cells (the cost pass
        # keeps n_micro=1: totals are microbatch-invariant, while-loop
        # bodies are counted once)
        n_micro = 1
        if shape.kind == "train":
            per_dev = shape.global_batch // 16
            n_micro = {True: min(per_dev, 16), False: min(per_dev, 8)}[
                cfg.d_model >= 8192]
        rec["n_micro"] = n_micro
        lowered = lower_cell(cfg, shape, mesh, n_micro=n_micro)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        print(f"[{arch} x {sname} x {pass_name}] memory_analysis: {ma}")
        rec.update(
            chips=chips(mesh),
            arg_bytes_per_dev=int(ma.argument_size_in_bytes),
            temp_bytes_per_dev=int(ma.temp_size_in_bytes),
            out_bytes_per_dev=int(ma.output_size_in_bytes),
            code_bytes=int(ma.generated_code_size_in_bytes),
        )
        ca = compiled.cost_analysis()
        print(f"[{arch} x {sname} x {pass_name}] cost_analysis flops="
              f"{ca.get('flops', 0):.3e} (scan bodies counted once; "
              f"see cost pass for true totals)")
        from repro.roofline.analysis import collective_bytes
        rec["collectives_present"] = sorted(
            collective_bytes(compiled.as_text()).keys())
    elif pass_name == "cost":
        mesh = make_production_mesh(multi_pod=False)
        costs = cost_pass(cfg, shape, mesh)
        n = chips(mesh)
        mf = model_flops_for(shape.kind, api.param_count(),
                             shape.global_batch, shape.seq_len)
        terms = roofline_terms(costs, mf, n)
        rec.update(
            chips=n,
            flops_per_dev=costs.flops, bytes_per_dev=costs.bytes_accessed,
            coll_bytes_per_dev=costs.coll_total,
            coll_breakdown={k: float(v) for k, v in costs.coll.items()},
            compute_s=terms.compute_s, memory_s=terms.memory_s,
            collective_s=terms.collective_s, dominant=terms.dominant,
            model_flops=mf, useful_ratio=terms.useful_ratio,
            roofline_fraction=terms.roofline_fraction,
        )
    rec["elapsed_s"] = round(time.time() - t0, 2)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--passes", default="check_single,check_multi,cost")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--only-missing", action="store_true")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if args.only_missing and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") in ("ok", "skipped"):
                        done.add((r["arch"], r["shape"], r["pass"]))
                except json.JSONDecodeError:
                    pass

    cells = all_cells() if args.all else [(args.arch, args.shape, None)]
    passes = args.passes.split(",")
    with open(args.out, "a") as out:
        for arch, sname, skip in cells:
            for pname in passes:
                if (arch, sname, pname) in done:
                    continue
                if skip is not None:
                    rec = {"arch": arch, "shape": sname, "pass": pname,
                           "status": "skipped", "reason": skip}
                else:
                    try:
                        rec = run_pass(arch, sname, pname)
                    except Exception as e:          # record, keep going
                        rec = {"arch": arch, "shape": sname, "pass": pname,
                               "status": "error", "error": repr(e),
                               "trace": traceback.format_exc()[-2000:]}
                out.write(json.dumps(rec) + "\n")
                out.flush()
                print(f"{arch} x {sname} x {pname}: {rec['status']} "
                      f"({rec.get('elapsed_s', 0)}s)")


if __name__ == "__main__":
    main()
