"""DSE-in-the-loop autotuning entry point (DESIGN.md Section 12).

Closes the loop between the analytical half of the reproduction and the
serving runtime, per family:

  1. enumerate candidate execution configs (compaction block granularity,
     balance unit, MUX fan-in budget, Mode-selection threshold) fitted to
     the family's actual GEMM shapes (``tuning.search``);
  2. score them through the cycle-model DSE sweep (content-hashed
     ``ResultsCache`` — warm re-runs are free) and the roofline
     prediction of the compacted decode step;
  3. validate the predicted shortlist against measured tok/s on warm
     serving runs (``tuning.measure``), asserting candidate-vs-default
     token identity along the way;
  4. emit the winners as a versioned kernel plan consumed by
     ``sparsify_params(plan=...)`` and ``ServeEngine(plan=...)``.

  PYTHONPATH=src python -m repro.launch.autotune \\
      --families dense,ssm --out benchmarks/out/kernel_plan.json

The emitted file is reloaded through ``tuning.load_plan`` before the
process exits, so a plan that would fail its own schema check can never
be written silently.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from ..core.dse import ResultsCache
from ..sparsity import sparsify_params
from ..tuning import KernelPlan, load_plan
from ..tuning.measure import (FAMILY_ARCHS, PRUNE, TUNE_SLOTS, measure_plan,
                              tuning_workload)
from ..tuning.search import (enumerate_candidates, gemm_leaves,
                             predict_scores, select_best, shortlist)


def autotune_family(family: str, *, sparsity: float, budget: int,
                    shortlist_k: int, requests: int, repeats: int,
                    cache_dir: str, seed: int, verbose: bool = True):
    """Run the full predict -> shortlist -> measure pipeline for one
    family; returns (FamilyPlan, summary dict)."""
    cfg, api, params, cache_len, trace = tuning_workload(
        family, requests=requests)
    # pruned-but-uncompacted twin: the zero pattern every candidate shares
    # (plans steer compaction only) and the input to the roofline stats
    pruned = sparsify_params(params, sparsity, compact=False, **PRUNE)
    leaves = gemm_leaves(pruned)
    assert leaves, f"{family}: no GEMM leaves to tune"
    cands = enumerate_candidates(
        {k: w.shape for k, w in leaves.items()}, budget)
    cache = ResultsCache(cache_dir) if cache_dir else None
    scored = predict_scores(cands, leaves, batch=TUNE_SLOTS, cache=cache,
                            seed=seed)
    short = shortlist(scored, shortlist_k)
    if verbose:
        print(f"[{family}] {len(cands)} candidates -> shortlist "
              + ", ".join(f"{r['name']} (score {r['score']:.3g})"
                          for r in short))

    default_params = sparsify_params(params, sparsity, compact=True, **PRUNE)
    base = measure_plan(api, default_params, cache_len, trace,
                        repeats=repeats)
    if verbose:
        print(f"[{family}] default ({PRUNE['block_k']}x{PRUNE['block_n']}"
              f"/u{PRUNE['unit']}): {base['tok_s']:.1f} tok/s, "
              f"mode {base['mode']}")

    measured_tok_s = {}
    by_name = {}
    for row in short:
        c = row["candidate"]
        fp = c.family_plan(cfg.family)
        p = sparsify_params(params, sparsity, compact=True, plan=fp, **PRUNE)
        m = measure_plan(api, p, cache_len, trace, plan=fp, repeats=repeats)
        assert m["tokens"] == base["tokens"], (
            f"{family}/{c.name}: tuned tokens diverged from default — a "
            "plan may change how GEMMs execute, never what they compute")
        measured_tok_s[c.name] = m["tok_s"]
        by_name[c.name] = (c, row, m)
        if verbose:
            print(f"[{family}]   {c.name}: {m['tok_s']:.1f} tok/s "
                  f"(predicted_s {row['predicted_s']:.3g}, "
                  f"mode {m['mode']}) — tokens identical to default")

    winner = select_best(measured_tok_s)
    c, row, m = by_name[winner]
    predicted = {r["name"]: {"score": round(r["score"], 6),
                             "dse_speedup": r["dse_speedup"],
                             "grid_steps": r["grid_steps"],
                             "predicted_s": r["predicted_s"]}
                 for r in short}
    measured = {"default": {"tok_s": round(base["tok_s"], 1),
                            "tok_per_step": round(base["tok_per_step"], 3)},
                **{n: {"tok_s": round(mm[2]["tok_s"], 1),
                       "tok_per_step": round(mm[2]["tok_per_step"], 3)}
                   for n, mm in by_name.items()},
                "winner": winner,
                "winner_vs_default":
                    round(m["tok_s"] / max(base["tok_s"], 1e-9), 3)}
    fp = c.family_plan(cfg.family, predicted=predicted, measured=measured)
    summary = {"family": cfg.family, "arch": FAMILY_ARCHS[family],
               "winner": winner,
               "tok_s_default": base["tok_s"], "tok_s_tuned": m["tok_s"],
               "cache": (f"{cache.hits} hits / {cache.misses} misses"
                         if cache else "off")}
    if verbose:
        print(f"[{family}] winner {winner}: {m['tok_s']:.1f} vs default "
              f"{base['tok_s']:.1f} tok/s "
              f"({measured['winner_vs_default']}x), dse cache "
              f"{summary['cache']}")
    return fp, summary


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--families", default="dense,ssm",
                    help="comma-separated model families "
                         f"(known: {','.join(sorted(FAMILY_ARCHS))})")
    ap.add_argument("--sparsity", type=float, default=0.8)
    ap.add_argument("--budget", type=int, default=16,
                    help="candidate points enumerated per family")
    ap.add_argument("--shortlist", type=int, default=3,
                    help="predicted shortlist size validated by "
                         "measurement")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed replays per measurement (best-of)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache-dir", default="benchmarks/out/cache",
                    help="DSE sweep ResultsCache directory ('' disables)")
    ap.add_argument("--out", default="benchmarks/out/kernel_plan.json")
    args = ap.parse_args(argv)

    families = [f.strip() for f in args.families.split(",") if f.strip()]
    unknown = [f for f in families if f not in FAMILY_ARCHS]
    if unknown:
        ap.error(f"unknown families {unknown} "
                 f"(known: {sorted(FAMILY_ARCHS)})")

    fams = {}
    summaries = []
    for family in families:
        fp, summary = autotune_family(
            family, sparsity=args.sparsity, budget=args.budget,
            shortlist_k=args.shortlist, requests=args.requests,
            repeats=args.repeats, cache_dir=args.cache_dir, seed=args.seed)
        fams[fp.family] = fp
        summaries.append(summary)

    plan = KernelPlan(families=fams, meta={
        "tool": "repro.launch.autotune", "sparsity": args.sparsity,
        "budget": args.budget, "shortlist": args.shortlist,
        "requests": args.requests, "seed": args.seed,
        "prune": dict(PRUNE),
        "archs": {f: FAMILY_ARCHS[f] for f in families}})
    plan.save(args.out)
    # write-then-reload: a plan this process cannot load back (schema
    # drift, serialization bug) must fail here, not at serve time
    reloaded = load_plan(args.out)
    assert set(reloaded.families) == set(fams)
    print(f"kernel plan -> {args.out} "
          f"(schema v{reloaded.schema_version}, "
          f"families {sorted(reloaded.families)})")
    for s in summaries:
        print(f"  {s['family']}: {s['winner']} "
              f"{s['tok_s_tuned']:.1f} tok/s vs default "
              f"{s['tok_s_default']:.1f}")


if __name__ == "__main__":
    main()
