"""End-to-end training example: a small LM for a few hundred steps on CPU,
with checkpoint/restart and a Griffin pruning schedule.

  python examples/train_lm.py            # ~2 min on CPU
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main

main(["--arch", "llama3.2-1b", "--reduced", "--steps", "200",
      "--batch", "8", "--seq", "128", "--lr", "3e-3",
      "--ckpt-dir", "/tmp/repro_ckpt", "--ckpt-every", "100",
      "--prune-sparsity", "0.5"])
