"""Design-space exploration example: sweep a slice of the Sparse.B family
(Fig. 5) through the batched engine, print the Pareto frontier, and show
Griffin's morphing advantage.

  python examples/dse_explore.py

The whole design list is scored in ONE stacked-config pass (masks drawn
once, scheduler vectorized over the config axis) and rows are memoized in
benchmarks/out/cache/ — run it twice and the second run is instant.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import CoreConfig, GRIFFIN, Mode
from repro.core.dse import ResultsCache, pareto, sweep
from repro.core.spec import SPARSE_AB_STAR, sparse_b

core = CoreConfig()
cache = ResultsCache(os.path.join(os.path.dirname(__file__), "..",
                                  "benchmarks", "out", "cache"))
designs = [sparse_b(db1, 0, db3, shuffle=sh)
           for db1 in (2, 4, 8) for db3 in (0, 1) for sh in (False, True)]

rows = sweep(designs, Mode.B, core, seed=1, cache=cache)
for r in rows:
    print(f"{r['design']:16s} speedup={r['speedup']:.2f} "
          f"TOPS/W={r['tops_w']:.1f} (dense {r['dense_tops_w']:.1f})")
print(f"[cache: {cache.hits} hits, {cache.misses} misses]")

front = pareto(rows, "dense_tops_w", "tops_w")
print("\nPareto frontier (dense vs DNN.B power efficiency):")
for r in front:
    print(f"  {r['design']}")

g, d = sweep([GRIFFIN, SPARSE_AB_STAR], Mode.B, core, seed=1, cache=cache)
print(f"\nGriffin morph vs dual downgrade on DNN.B: "
      f"{g['speedup']:.2f}x vs {d['speedup']:.2f}x speedup "
      f"({100 * (g['speedup'] / d['speedup'] - 1):.0f}% gain)")
