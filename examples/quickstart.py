"""Quickstart: the paper's core in five minutes.

1. Score a pruned GEMM on Griffin and the paper's named architectures.
2. Execute a Sparse.B schedule numerically (exactness check).
3. Run the TPU block-sparse kernel (interpret mode) on pruned weights.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax.numpy as jnp

from repro.core import (CoreConfig, GRIFFIN, Mode, SPARSE_AB_STAR,
                        SPARSE_B_STAR, gemm_cycles, power_area, running_spec,
                        select_mode)
from repro.core.evaluate import MaskModel
from repro.core.functional import execute_b_sparse
from repro.kernels import griffin_matmul, preprocess_weights
from repro.sparsity import block_prune

core = CoreConfig()
mm = MaskModel()
rng = np.random.default_rng(0)

# -- 1. cycle model (one batched pass scores the whole design stack) --------
from repro.core.evaluate import gemm_cycles_batched

M, K, N = 64, 1024, 512
a_mask = mm.act_mask(M, K, 1.0, rng)            # dense activations
b_mask = mm.weight_mask(K, N, 0.2, rng)         # 80% pruned weights
mode = select_mode(0.0, 0.8)
print(f"model category: DNN.{mode.value}")
designs = (SPARSE_B_STAR, SPARSE_AB_STAR, GRIFFIN)
specs = [running_spec(d, mode) for d in designs]
for design, spec, r in zip(designs, specs,
                           gemm_cycles_batched(specs, mode, a_mask, b_mask,
                                               core)):
    pa = power_area(design)
    name = getattr(design, "name", None) or spec.label()
    print(f"  {name:12s} runs {spec.label():18s}: speedup {r.speedup:.2f}x, "
          f"core power {pa.power_mw:.0f} mW")

# -- 2. functional fidelity --------------------------------------------------
a = rng.standard_normal((8, 64))
b = rng.standard_normal((64, 32)) * (rng.random((64, 32)) < 0.2)
c, ops = execute_b_sparse(a, b, running_spec(GRIFFIN, Mode.B), core)
assert np.allclose(c, a @ b), "schedule execution must be exact"
print(f"functional check: {ops} effectual MACs reproduce A@B exactly")

# -- 3. TPU kernel (interpret mode on CPU) -----------------------------------
w = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
w = block_prune(w, 0.75, block_k=32, unit=16)
gw = preprocess_weights(np.asarray(w), block_k=32, block_n=32, unit=16)
x = jnp.asarray(rng.standard_normal((16, 256)), jnp.float32)
out = griffin_matmul(x, gw, interpret=True)
print(f"griffin_spmm: grid compaction {gw.compaction:.2f} "
      f"(fraction of dense K-blocks executed), max err "
      f"{float(jnp.abs(out - x @ w).max()):.1e}")
