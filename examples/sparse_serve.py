"""Serving example: block-prune a model offline (the paper's Sparse.B
preprocessing), let the hybrid runtime pick the execution mode, and decode
batched requests.

  python examples/sparse_serve.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

main(["--arch", "llama3.2-1b", "--reduced", "--batch", "4",
      "--prompt-len", "32", "--gen-len", "16", "--sparsity", "0.8"])
