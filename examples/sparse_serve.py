"""Serving example: block-prune a model offline (the paper's Sparse.B
preprocessing), then serve a mixed prompt/gen-length request trace through
the continuous-batching engine (slot-pool KV arena, FCFS admission, runtime
workload-category measurement) and verify every request token-identical
against the batch-1 greedy oracle.

  python examples/sparse_serve.py

Extra launch/serve.py flags pass through, e.g. mesh-parallel serving on an
emulated 8-device CPU mesh (the CI sharded stage runs exactly this):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      python examples/sparse_serve.py --mesh 2x4

or multi-replica SLO-aware routing with overload shedding and graceful
degradation (DESIGN.md Section 13):

  python examples/sparse_serve.py --replicas 2 --queue-bound 6 \\
      --arrival-process bursty --shed-policy degrade
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

main(["--arch", "llama3.2-1b", "--reduced", "--slots", "3",
      "--requests", "6", "--prompt-lens", "8,12,16", "--gen-lens", "6,10,14",
      "--arrival-every", "1", "--sparsity", "0.8", "--parity",
      "--decode-chunk", "8", "--max-syncs-per-token", "0.25",
      # virtual-tick SLOs (runtime.slo): prints the per-request
      # attainment table; deadlines only gate admission in --replicas mode
      "--slo", "ttft=64,slack=8"]
     + sys.argv[1:])
