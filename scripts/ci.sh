#!/usr/bin/env bash
# CI entry point: install, tier-1 tests, benchmark + substrate smoke checks,
# mesh-serving parity, and worktree hygiene.
#
#   scripts/ci.sh                  # full flow (editable install if pip works)
#   scripts/ci.sh tier1 docs       # selected stages only
#   SKIP_INSTALL=1 scripts/ci.sh   # offline: fall back to PYTHONPATH=src
#
# Stages (in default order) — .github/workflows/ci.yml runs the same
# stages as separate jobs, so this script IS the local mirror of CI:
#   tier1             fast default-on pytest suite (kernels split out)
#   kernel            kernel parity (interpret mode, CPU)
#   tier2             serving-engine e2e sweep (all families)
#   paged             paged KV arena: allocator/discovery units, fixed-vs-
#                     paged parity matrix, int8 tolerance gate, CLI smokes
#   serve             fused-chunk serve smoke + parity + sync budget
#   bench-regression  fresh run vs committed BENCH_serve.json invariants
#   serve-bench       static / per-step / fused-chunk benchmark smoke
#   fig5              batched-sweep benchmark smoke (results cache)
#   e2e               registry models through the substrate (smoke)
#   autotune          tiny-budget kernel-plan pipeline smoke (2 families)
#   docs              DESIGN.md citation check
#   router            SLO router: unit tier + replica-kill chaos cells +
#                     seeded 2x-overload smoke (single device)
#   mesh              8-device emulated mesh: sharded parity tier + smoke
#   chaos             8-device emulated mesh: fault-injection matrix + smoke
#   clean             worktree clean after the run (smoke CSV churn reset)
set -euo pipefail
cd "$(dirname "$0")/.."

if [ "${SKIP_INSTALL:-0}" != "1" ] && pip install -e '.[test]' 2>/dev/null; then
    echo "== installed griffin-repro (editable) with [test] extras"
    PYPATH=""
else
    echo "== pip install unavailable; using PYTHONPATH=src fallback"
    PYPATH="src"
fi
run() { PYTHONPATH="${PYPATH}${PYTHONPATH:+:$PYTHONPATH}" "$@"; }

KERNEL_TESTS="tests/test_kernels.py tests/test_sparse_a.py \
tests/test_griffin_linear.py"

stage_tier1() {
    echo "== tier-1 tests (kernel parity split into its own stage)"
    run python -m pytest -x -q \
        $(for t in $KERNEL_TESTS; do printf -- "--ignore=%s " "$t"; done)
}

stage_kernel() {
    echo "== kernel parity (interpret mode, CPU): dense / Sparse.B / Sparse.A"
    run python -m pytest -x -q $KERNEL_TESTS
}

stage_tier2() {
    echo "== tier-2: serving-engine e2e (all families, dense + sparse)"
    run python -m pytest -x -q -m tier2
}

stage_paged() {
    echo "== paged: paged KV arena (DESIGN.md Section 14) — allocator and"
    echo "==   discovery units, fixed-vs-paged token parity (tier-1 cells"
    echo "==   plus the five-family x chunk tier-2 matrix), the int8"
    echo "==   logit-tolerance gate, and serve-CLI smokes through the"
    echo "==   EngineConfig path (fp32 --parity is oracle-exact, int8 e2e)"
    run python -m pytest -x -q tests/test_paged_arena.py
    run python -m pytest -x -q -m tier2 tests/test_paged_arena.py
    run python -m repro.launch.serve --reduced --requests 6 \
        --page-size 16 --parity
    run python -m repro.launch.serve --reduced --requests 6 \
        --page-size 16 --kv-dtype int8
}

stage_serve() {
    echo "== serve smoke: fused-chunk engine, bucketed prefill, parity, and"
    echo "==   host_syncs/token <= 1/4 (asserted inside via --max-syncs-per-token)"
    run python examples/sparse_serve.py
}

stage_bench_regression() {
    echo "== bench regression: fresh serve run vs committed BENCH_serve.json"
    echo "==   (tokens/step + prefills exact, syncs/token <= recorded + 0.02)"
    run python scripts/check_bench_regression.py
}

stage_serve_bench() {
    echo "== serve bench: static / per-step (PR 3) / fused-chunk decode"
    # smoke-mode run: rewrites bench_serve.csv with 16-request rows (the
    # clean stage restores it); the committed BENCH_serve.json perf record
    # is only written by `bench_serve --full --json` and never touched here
    run python -m benchmarks.bench_serve
}

stage_fig5() {
    echo "== benchmark smoke: fig5 (fast mode, batched sweep + results cache)"
    run python -m benchmarks.run --only fig5
}

stage_e2e() {
    echo "== e2e smoke: registry models through the mode-dispatched substrate"
    run python -m benchmarks.bench_e2e --smoke
}

stage_autotune() {
    echo "== autotune smoke: tiny-budget DSE-in-the-loop tuning over 2"
    echo "==   families (DESIGN.md Section 12) — a plan file must be"
    echo "==   emitted, reload through the schema check, and candidate"
    echo "==   token parity is asserted inside the pipeline; then the"
    echo "==   committed plan serves a reduced model with oracle parity"
    # the smoke plan lives under the gitignored benchmarks/out/ so the
    # clean stage stays green; the committed kernel_plan.json is only
    # written by `bench_autotune --json` and never touched here
    run python -m repro.launch.autotune --families dense,ssm \
        --budget 4 --shortlist 1 --requests 3 --repeats 1 \
        --out benchmarks/out/plan_smoke.json
    run python -c "
from repro.tuning import load_plan
p = load_plan('benchmarks/out/plan_smoke.json')
assert {'dense', 'ssm'} <= set(p.families), sorted(p.families)
print('plan_smoke.json loads: families', sorted(p.families),
      'schema v%d' % p.schema_version)
"
    rm -f benchmarks/out/plan_smoke.json
    run python -m repro.launch.serve --reduced --requests 4 --use-kernels \
        --plan benchmarks/out/kernel_plan.json --parity
}

stage_docs() {
    echo "== docs: every DESIGN.md section cited from a docstring exists"
    python scripts/check_design_refs.py
}

stage_router() {
    echo "== router: SLO admission/hedging/degradation unit tier, the"
    echo "==   replica-kill chaos cells (single device — replicas are"
    echo "==   in-process engines, no emulated mesh needed), and a seeded"
    echo "==   2x-overload smoke that must shed under a bounded queue and"
    echo "==   keep depth <= bound (DESIGN.md Section 13)"
    run python -m pytest -x -q tests/test_router.py
    run python -m pytest -x -q -m chaos tests/test_fault_tolerance.py \
        -k router
    run python examples/sparse_serve.py --replicas 2 --queue-bound 4 \
        --arrival-process bursty --rate 1 --burst-rate 8 \
        --length-dist heavy --priorities 0,1 --requests 24 \
        --slo ttft=16,slack=2 --shed-policy degrade --overload-smoke
}

stage_mesh() {
    echo "== mesh: shard-parity tier (real Pallas kernels under shard_map)"
    echo "==   + serve smokes on an emulated 8-device CPU mesh (DESIGN.md"
    echo "==   Section 10) — kernels forced on, then the decompaction-oracle"
    echo "==   fallback forced to keep the parity baseline alive"
    # subshell-scoped env: a later stage in the same invocation (e.g.
    # `ci.sh mesh bench-regression`) must not inherit the emulation
    (
        export XLA_FLAGS="--xla_force_host_platform_device_count=8 ${XLA_FLAGS:-}"
        run python -m pytest -x -q -m mesh \
            tests/test_shard_map_kernels.py tests/test_mesh_serve.py \
            tests/test_autotune.py
        run python examples/sparse_serve.py --mesh 2x4 --use-kernels
        run python examples/sparse_serve.py --mesh 2x2 --use-kernels \
            --spmd-fallback
    )
}

stage_chaos() {
    echo "== chaos: deterministic fault injection on an emulated 8-device"
    echo "==   CPU mesh (DESIGN.md Section 11) — kill/delay mid-trace, the"
    echo "==   engine must remesh onto the survivors and finish token-exact"
    (
        export XLA_FLAGS="--xla_force_host_platform_device_count=8 ${XLA_FLAGS:-}"
        run python -m pytest -x -q -m chaos tests/test_fault_tolerance.py
        run python examples/sparse_serve.py --mesh 2x2 \
            --inject-fault kill:-1@3:decode
    )
}

stage_clean() {
    echo "== clean worktree: the smoke stages above just rewrote the two"
    echo "==   committed benchmark CSVs — restore exactly those (their"
    echo "==   pre-run content is already gone either way), then require"
    echo "==   an otherwise clean tree (stray build junk must be"
    echo "==   gitignored; intentional changes must be committed first)"
    git checkout -- benchmarks/out/bench_serve.csv \
        benchmarks/out/bench_e2e.csv 2>/dev/null || true
    if [ -n "$(git status --porcelain)" ]; then
        echo "FAIL: worktree dirty after CI run:"
        git status --short
        exit 1
    fi
    echo "worktree clean"
}

ALL_STAGES="tier1 kernel tier2 paged serve bench-regression serve-bench \
fig5 e2e autotune docs router mesh chaos clean"
STAGES="${*:-$ALL_STAGES}"
for s in $STAGES; do
    case "$s" in
        tier1) stage_tier1 ;;
        kernel) stage_kernel ;;
        tier2) stage_tier2 ;;
        paged) stage_paged ;;
        serve) stage_serve ;;
        bench-regression) stage_bench_regression ;;
        serve-bench) stage_serve_bench ;;
        fig5) stage_fig5 ;;
        e2e) stage_e2e ;;
        autotune) stage_autotune ;;
        docs) stage_docs ;;
        router) stage_router ;;
        mesh) stage_mesh ;;
        chaos) stage_chaos ;;
        clean) stage_clean ;;
        *) echo "unknown stage: $s (known: $ALL_STAGES)"; exit 2 ;;
    esac
done

echo "== CI OK ($STAGES)"
