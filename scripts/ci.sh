#!/usr/bin/env bash
# CI entry point: install, tier-1 tests, fig5 fast-mode smoke check.
#
#   scripts/ci.sh            # full flow (editable install if pip works)
#   SKIP_INSTALL=1 scripts/ci.sh   # offline: fall back to PYTHONPATH=src
set -euo pipefail
cd "$(dirname "$0")/.."

if [ "${SKIP_INSTALL:-0}" != "1" ] && pip install -e '.[test]' 2>/dev/null; then
    echo "== installed griffin-repro (editable) with [test] extras"
    PYPATH=""
else
    echo "== pip install unavailable; using PYTHONPATH=src fallback"
    PYPATH="src"
fi

echo "== tier-1 tests"
PYTHONPATH="${PYPATH}${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q

echo "== benchmark smoke: fig5 (fast mode, batched sweep + results cache)"
PYTHONPATH="${PYPATH}${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run --only fig5

echo "== CI OK"
