#!/usr/bin/env bash
# CI entry point: install, tier-1 tests, benchmark + substrate smoke checks.
#
#   scripts/ci.sh            # full flow (editable install if pip works)
#   SKIP_INSTALL=1 scripts/ci.sh   # offline: fall back to PYTHONPATH=src
set -euo pipefail
cd "$(dirname "$0")/.."

if [ "${SKIP_INSTALL:-0}" != "1" ] && pip install -e '.[test]' 2>/dev/null; then
    echo "== installed griffin-repro (editable) with [test] extras"
    PYPATH=""
else
    echo "== pip install unavailable; using PYTHONPATH=src fallback"
    PYPATH="src"
fi

KERNEL_TESTS="tests/test_kernels.py tests/test_sparse_a.py \
tests/test_griffin_linear.py"

echo "== tier-1 tests (kernel parity split into its own stage below)"
PYTHONPATH="${PYPATH}${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q \
    $(for t in $KERNEL_TESTS; do printf -- "--ignore=%s " "$t"; done)

echo "== kernel parity (interpret mode, CPU): dense / Sparse.B / Sparse.A"
PYTHONPATH="${PYPATH}${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q $KERNEL_TESTS

echo "== tier-2: serving-engine e2e (all families, dense + sparse)"
PYTHONPATH="${PYPATH}${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q -m tier2

echo "== serve smoke: fused-chunk engine, bucketed prefill, parity, and"
echo "==   host_syncs/token <= 1/4 (asserted inside via --max-syncs-per-token)"
PYTHONPATH="${PYPATH}${PYTHONPATH:+:$PYTHONPATH}" \
    python examples/sparse_serve.py

echo "== serve bench: static / per-step (PR 3) / fused-chunk decode"
# smoke-mode run: rewrites bench_serve.csv with 16-request rows (like the
# other benchmark smokes, restore before committing); the committed
# BENCH_serve.json perf record is only written by `bench_serve --full
# --json` and never touched here
PYTHONPATH="${PYPATH}${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_serve

echo "== benchmark smoke: fig5 (fast mode, batched sweep + results cache)"
PYTHONPATH="${PYPATH}${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run --only fig5

echo "== e2e smoke: registry models through the mode-dispatched substrate"
PYTHONPATH="${PYPATH}${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.bench_e2e --smoke

echo "== docs: every DESIGN.md section cited from a docstring exists"
python scripts/check_design_refs.py

echo "== CI OK"
