#!/usr/bin/env python
"""Check that every ``DESIGN.md Section N`` citation in the codebase
resolves to a real ``## Section N`` heading in DESIGN.md (and that
DESIGN.md exists at all — six modules cited it before it was written)."""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "benchmarks", "tests", "examples", "scripts")
CITE = re.compile(r"DESIGN\.md\s*\n?\s*Section\s+(\d+)")
HEADING = re.compile(r"^##\s+Section\s+(\d+)\b", re.M)


def main() -> int:
    design = ROOT / "DESIGN.md"
    if not design.exists():
        print("FAIL: DESIGN.md does not exist but the code cites it")
        return 1
    sections = set(HEADING.findall(design.read_text()))
    failures = []
    n_cites = 0
    for d in SCAN_DIRS:
        for path in (ROOT / d).rglob("*.py"):
            text = path.read_text()
            for m in CITE.finditer(text):
                n_cites += 1
                if m.group(1) not in sections:
                    line = text[:m.start()].count("\n") + 1
                    failures.append(
                        f"{path.relative_to(ROOT)}:{line}: cites DESIGN.md "
                        f"Section {m.group(1)} which has no heading")
    for f in failures:
        print("FAIL:", f)
    print(f"check_design_refs: {n_cites} citations, "
          f"{len(sections)} sections, {len(failures)} unresolved")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
