#!/usr/bin/env python
"""Serve-benchmark regression gate: replay the trace recorded in the
committed ``benchmarks/out/BENCH_serve.json`` and fail on *invariant*
drift.

The committed JSON is the perf record ``bench_serve --full --json`` wrote;
wall-clock columns in it are machine-dependent and are **not** gated — a
slow CI box must not fail the build.  What is gated is the deterministic
skeleton of the serving engine, per recorded config:

  - emitted tokens, decode steps (hence tokens/step) and prefill calls:
    exact — these change only when scheduling, the chunk-length ladder or
    prompt bucketing change behaviour;
  - host syncs/token: <= recorded + 0.02 — the fused decode path quietly
    re-synchronizing per step is exactly the regression PR 4 exists to
    prevent (DESIGN.md Section 9), while a small slack absorbs intentional
    accounting tweaks without masking a per-step sync (+1.0);
  - sharded/unsharded tok-per-step ratio: must equal the recorded ratio
    (1.0 — sharding is placement, not scheduling) whenever a sharded row
    and its unsharded twin both replay.  Wall-clock tok/s stays ungated:
    on an emulated mesh it measures GSPMD emulation, not hardware
    (bench_serve only asserts the tok/s direction when the host has a
    core per device).

  - router overload rows (``router`` section, DESIGN.md Section 13):
    shed count, max queue depth, p50/p99 TTFT, inter-token latency and
    SLO attainment — exact, because they are counted in virtual router
    ticks over the recorded seeded trace, never in wall clock; plus the
    bounded-vs-unbounded ordering asserted inside the replay itself.

  - paged-arena row (``paged`` section, DESIGN.md Section 14): peak
    concurrent slots at the equal-KV-budget comparison (fixed 4x256 vs
    the 64x16 paged pool), the >= 2x concurrency ratio, paged-fp32
    token identity with the fixed arena, and the int8 token-match
    fraction — exact over the recorded seed; the int8 teacher-forced
    logit gap must stay within the committed tolerance (a float, so it
    is bounded rather than compared with ==).

Configs whose ``mesh`` needs more devices than this process has are
skipped with a note (the CI sharded job runs with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

When ``benchmarks/out/BENCH_autotune.json`` is committed (the
``bench_autotune --json`` record, DESIGN.md Section 12), the committed
kernel plan is additionally replayed per family against the frozen
defaults: tuned/default tok-per-step ratio must be >= 1.0 and match the
record (a plan never changes the decode schedule), and tuned tokens must
be identical to default tokens (the plan-parity contract).  Tuned tok/s
is the *recorded* headline but is not wall-clock-gated here.

Run from the repo root (scripts/ci.sh bench-regression stage):

  PYTHONPATH=src python scripts/check_bench_regression.py
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT))

SYNC_SLACK = 0.02


def check_autotune(failures: list) -> int:
    """Replay the committed autotune record: tuned-vs-default tok/step
    ratio (deterministic) and token identity, per family.  Returns the
    number of families checked (0 when no record is committed)."""
    from repro.sparsity import sparsify_params
    from repro.tuning import load_plan
    from repro.tuning.measure import PRUNE, measure_plan, tuning_workload

    jpath = ROOT / "benchmarks" / "out" / "BENCH_autotune.json"
    if not jpath.exists():
        print("skip autotune gate: BENCH_autotune.json not committed")
        return 0
    rec = json.loads(jpath.read_text())
    plan = load_plan(str(ROOT / rec["plan"]))
    t = rec["tune"]
    checked = 0
    for family, row in rec["families"].items():
        fp = plan.family(family)
        if fp is None:
            failures.append(f"autotune/{family}: committed plan "
                            f"{rec['plan']} has no entry for this family")
            continue
        _, api, params, cache_len, trace = tuning_workload(
            family, requests=t["requests"])
        base = measure_plan(
            api, sparsify_params(params, t["sparsity"], compact=True,
                                 **PRUNE),
            cache_len, trace, repeats=1)
        tuned = measure_plan(
            api, sparsify_params(params, t["sparsity"], compact=True,
                                 plan=fp, **PRUNE),
            cache_len, trace, plan=fp, repeats=1)
        checked += 1
        if tuned["tokens"] != base["tokens"]:
            failures.append(f"autotune/{family}: tuned tokens diverged "
                            "from default — the committed plan changes "
                            "what GEMMs compute")
        ratio = tuned["tok_per_step"] / base["tok_per_step"]
        if ratio < 1.0 - 1e-9:
            failures.append(
                f"autotune/{family}: tuned/default tok-per-step ratio "
                f"{ratio:.3f} < 1.0 — the plan degraded the decode "
                "schedule")
        if abs(ratio - row["tok_per_step_ratio"]) > 1e-6:
            failures.append(
                f"autotune/{family}: tok-per-step ratio drifted "
                f"{row['tok_per_step_ratio']} -> {ratio:.3f}")
        print(f"autotune/{family}: winner={row['winner']} tok/step ratio="
              f"{ratio:.3f} (recorded {row['tok_per_step_ratio']}), "
              f"tokens identical={tuned['tokens'] == base['tokens']}")
    return checked


def check_router(rec, api, params, cache_len, cfg, n_req, factory_cache,
                 failures) -> int:
    """Replay the committed router overload rows (DESIGN.md Section 13).
    Every gated field is in virtual router ticks — deterministic given
    the recorded trace seed — so shed counts, queue depth, p50/p99 TTFT,
    inter-token latency and SLO attainment must match with ``==``
    (wall_s/ticks stay ungated).  Returns rows checked (0 = no router
    section committed)."""
    from benchmarks.bench_serve import run_router_overload

    committed = rec.get("router")
    if not committed:
        print("skip router gate: no router section in BENCH_serve.json")
        return 0
    replay = run_router_overload(api, params, cache_len, cfg, n_req,
                                 factory_cache)
    gated = ("requests", "completed", "shed", "max_queue_depth",
             "ttft_p50", "ttft_p99", "itl_p50", "itl_p99",
             "slo_attainment", "ladder_history")
    checked = 0
    for name, got in replay.items():
        want = committed.get(name)
        if want is None:
            failures.append(f"router/{name}: row missing from the "
                            "committed record — regenerate "
                            "BENCH_serve.json")
            continue
        checked += 1
        for field in gated:
            if got[field] != want[field]:
                failures.append(f"router/{name}: {field} drifted "
                                f"{want[field]} -> {got[field]}")
        print(f"router/{name}: shed={got['shed']} "
              f"depth={got['max_queue_depth']} ttft p50/p99="
              f"{got['ttft_p50']}/{got['ttft_p99']} attainment="
              f"{got['slo_attainment']} (all vs committed, exact)")
    return checked


def check_paged(rec, api, params, cfg, failures) -> int:
    """Replay the committed paged-arena row (DESIGN.md Section 14).
    ``run_paged`` self-gates the acceptance criteria (>= 2x peak
    concurrency at equal KV budget, fp32 token-exact, int8 logit gap
    within tolerance); here the replay is additionally compared field by
    field against the record — peak concurrency, emitted tokens and the
    token-identity flags are deterministic over the recorded seed, so
    they must match with ``==`` (wall_s and the float logit gap stay
    ungated beyond the recorded tolerance).  Returns rows checked (0 =
    no paged section committed)."""
    from benchmarks.bench_serve import run_paged

    committed = rec.get("paged")
    if not committed:
        print("skip paged gate: no paged section in BENCH_serve.json")
        return 0
    replay = run_paged(api, params, cfg, committed["trace"]["requests"])
    for field in ("page_size", "num_pages", "cache_len", "fixed_slots",
                  "paged_slots", "concurrency_ratio", "fp32_token_exact",
                  "int8_token_match"):
        if replay[field] != committed[field]:
            failures.append(f"paged: {field} drifted "
                            f"{committed[field]} -> {replay[field]}")
    checked = 0
    for name, got in replay["configs"].items():
        want = committed["configs"].get(name)
        if want is None:
            failures.append(f"paged/{name}: row missing from the "
                            "committed record — regenerate "
                            "BENCH_serve.json")
            continue
        checked += 1
        for field in ("slots", "peak_concurrent", "kv_rows", "emitted"):
            if got[field] != want[field]:
                failures.append(f"paged/{name}: {field} drifted "
                                f"{want[field]} -> {got[field]}")
        print(f"paged/{name}: peak={got['peak_concurrent']} "
              f"emitted={got['emitted']} (vs committed, exact)")
    if replay["int8_rel_logit_gap"] > committed["int8_tol"]:
        failures.append(
            f"paged: int8 logit gap {replay['int8_rel_logit_gap']} "
            f"exceeds the committed tolerance {committed['int8_tol']}")
    return checked


def main() -> int:
    import jax
    from benchmarks.bench_serve import build_workload, make_engine

    jpath = ROOT / "benchmarks" / "out" / "BENCH_serve.json"
    if not jpath.exists():
        print(f"FAIL: {jpath} missing — run "
              "`python -m benchmarks.bench_serve --full --json` and commit")
        return 1
    rec = json.loads(jpath.read_text())
    n_req = rec["trace"]["requests"]
    cfg, api, params, cache_len, trace = build_workload(n_req)
    # sanity: the committed record must describe the workload this repo
    # builds, otherwise "exact" comparisons are meaningless
    from benchmarks.bench_serve import GEN_LENS, PROMPT_LENS, SLOTS
    if (rec["trace"]["prompt_lens"] != list(PROMPT_LENS)
            or rec["trace"]["gen_lens"] != list(GEN_LENS)
            or rec["trace"]["slots"] != SLOTS or rec["trace"]["seed"] != 7):
        print("FAIL: committed trace parameters differ from "
              "benchmarks/bench_serve.py — regenerate BENCH_serve.json")
        return 1

    n_dev = len(jax.devices())
    failures, checked = [], 0
    factory_cache: dict = {}
    replayed_tps: dict = {}
    fam_plan = None
    if rec.get("plan"):
        from repro.tuning import load_plan
        fam_plan = load_plan(str(ROOT / rec["plan"])).family(cfg.family)
    for name, c in rec["configs"].items():
        mesh = c.get("mesh", "1x1")
        if mesh != "1x1":
            d, m = (int(x) for x in mesh.split("x"))
            if d * m > n_dev:
                print(f"skip {name}: mesh {mesh} needs {d * m} devices, "
                      f"have {n_dev}")
                continue
        fused = c["decode_chunk"] > 1
        eng = make_engine(api, params, factory_cache, c["policy"],
                          cache_len, c["decode_chunk"], fused,
                          None if mesh == "1x1" else mesh, plan=fam_plan)
        outs = eng.run(trace())
        assert len(outs) == n_req and all(o.finished >= 0
                                          for o in outs.values())
        toks = eng.stats["emitted"]
        syncs_tok = eng.stats["host_syncs"] / toks
        replayed_tps[name] = toks / max(eng.stats["decode_steps"], 1)
        checked += 1

        def exact(field, got):
            if got != c[field]:
                failures.append(f"{name}: {field} drifted "
                                f"{c[field]} -> {got}")

        exact("emitted", toks)
        exact("decode_steps", eng.stats["decode_steps"])
        exact("prefill_calls", eng.stats["prefill_calls"])
        if syncs_tok > c["host_syncs_per_token"] + SYNC_SLACK:
            failures.append(
                f"{name}: host syncs/token {syncs_tok:.4f} exceeds recorded "
                f"{c['host_syncs_per_token']} + {SYNC_SLACK} — the fused "
                "decode path is synchronizing more often than the record")
        print(f"{name}: emitted={toks} decode_steps="
              f"{eng.stats['decode_steps']} syncs/token={syncs_tok:.4f} "
              f"(recorded {c['host_syncs_per_token']})")

    # sharded rows are named "<config>@<mesh>"; their deterministic perf
    # invariant vs the unsharded twin is the tok-per-step ratio
    for name, tps in sorted(replayed_tps.items()):
        if "@" not in name:
            continue
        base = name.split("@", 1)[0]
        if base not in replayed_tps:
            continue
        got = tps / replayed_tps[base]
        want = (rec["configs"][name]["tok_per_step"] /
                rec["configs"][base]["tok_per_step"])
        if abs(got - want) > 1e-9:
            failures.append(
                f"{name}: sharded/unsharded tok-per-step ratio drifted "
                f"{want:.3f} -> {got:.3f} — sharding is changing the "
                "decode schedule")
        else:
            print(f"{name}: tok-per-step ratio vs {base} = {got:.3f} "
                  f"(recorded {want:.3f})")

    router_checked = check_router(rec, api, params, cache_len, cfg,
                                  n_req, factory_cache, failures)

    paged_checked = check_paged(rec, api, params, cfg, failures)

    tuned_checked = check_autotune(failures)

    for f in failures:
        print("FAIL:", f)
    print(f"check_bench_regression: {checked} configs + {router_checked} "
          f"router rows + {paged_checked} paged rows replayed against "
          f"{jpath.name} + {tuned_checked} autotuned families, "
          f"{len(failures)} drifts")
    if checked == 0:
        print("FAIL: no configs replayed")
        return 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
