"""Autotuned-vs-default serving benchmark (DESIGN.md Section 12).

Runs the full DSE-in-the-loop pipeline (``repro.launch.autotune``) per
model family — candidate enumeration fitted to the family's GEMM shapes,
cycle-model + roofline scoring through the shared results cache, then
measured tok/s validation of the predicted shortlist — and records the
winner against the frozen reduced-config defaults
(``repro.tuning.measure.PRUNE``, 16x16/u8).

Two things are *asserted*, not just recorded:

  - tok/s: the tuned plan must beat the default on every benched family
    (the PR acceptance criterion — on the CPU interpret lowering the win
    comes from coarse compaction amortizing the per-grid-step dispatch
    overhead, the platform-dependent term ``tuning.search`` models);
  - tok/step ratio == 1.0 and token identity: a plan changes how GEMMs
    execute, never what they compute or how the engine schedules
    (``autotune_family`` asserts per-candidate token parity in-loop).

Writes benchmarks/out/bench_autotune.csv and saves the winning plan to
``--plan-out``; ``--json`` additionally emits
benchmarks/out/BENCH_autotune.json — the committed perf record
scripts/check_bench_regression.py replays (tok/step ratio gate; wall
clock stays ungated on CI boxes).

  PYTHONPATH=src python -m benchmarks.bench_autotune --json
"""
from __future__ import annotations

import argparse
import json
import pathlib

import jax

from repro.launch.autotune import autotune_family
from repro.tuning import PLAN_SCHEMA_VERSION, KernelPlan, load_plan
from repro.tuning.measure import FAMILY_ARCHS, PRUNE

from .common import CACHE_DIR, emit, write_csv

FAMILIES = ("dense", "ssm")


def run(families=FAMILIES, *, sparsity: float = 0.8, budget: int = 16,
        shortlist: int = 3, requests: int = 6, repeats: int = 3,
        seed: int = 0, cache_dir: str = CACHE_DIR,
        plan_out: str = "benchmarks/out/kernel_plan.json",
        json_out: bool = False) -> None:
    fams, rows, fam_json = {}, [], {}
    for family in families:
        fp, s = autotune_family(
            family, sparsity=sparsity, budget=budget, shortlist_k=shortlist,
            requests=requests, repeats=repeats, cache_dir=cache_dir,
            seed=seed)
        fams[fp.family] = fp
        md, mw = fp.measured["default"], fp.measured[s["winner"]]
        ratio = round(s["tok_s_tuned"] / s["tok_s_default"], 3)
        tps_ratio = round(mw["tok_per_step"] / md["tok_per_step"], 3)
        row = {"family": fp.family, "arch": s["arch"],
               "winner": s["winner"],
               "tok_s_default": md["tok_s"], "tok_s_tuned": mw["tok_s"],
               "tok_s_ratio": ratio,
               "tok_per_step_default": md["tok_per_step"],
               "tok_per_step_tuned": mw["tok_per_step"],
               "tok_per_step_ratio": tps_ratio}
        # the record only ships when the tuned plan actually wins, and
        # wins without touching the decode schedule
        assert ratio >= 1.0, (
            f"{fp.family}: tuned plan lost to the frozen defaults "
            f"({ratio}x) — refusing to record a regressing plan")
        assert tps_ratio == 1.0, (
            f"{fp.family}: tuned tok/step drifted ({tps_ratio}) — a plan "
            "must never change the decode schedule")
        rows.append(row)
        fam_json[fp.family] = row
        emit(f"autotune/{fp.family}/{s['winner']}", 1e6 / mw["tok_s"],
             f"tok_s={mw['tok_s']};default={md['tok_s']};ratio={ratio}")

    plan = KernelPlan(families=fams, meta={
        "tool": "benchmarks.bench_autotune", "sparsity": sparsity,
        "budget": budget, "shortlist": shortlist, "requests": requests,
        "seed": seed, "prune": dict(PRUNE),
        "archs": {f: FAMILY_ARCHS[f] for f in families}})
    plan.save(plan_out)
    assert set(load_plan(plan_out).families) == set(fams)
    path = write_csv("bench_autotune", rows)
    print(f"# bench_autotune -> {path}; plan -> {plan_out} "
          f"(schema v{PLAN_SCHEMA_VERSION})")
    if json_out:
        out = {
            "backend": jax.default_backend(),
            "schema_version": PLAN_SCHEMA_VERSION,
            "plan": plan_out,
            "tune": {"sparsity": sparsity, "budget": budget,
                     "shortlist": shortlist, "requests": requests,
                     "repeats": repeats, "seed": seed,
                     "prune": dict(PRUNE)},
            "families": fam_json,
        }
        jpath = pathlib.Path(__file__).parent / "out" / "BENCH_autotune.json"
        jpath.write_text(json.dumps(out, indent=2) + "\n")
        print(f"# bench_autotune json -> {jpath}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--families", default=",".join(FAMILIES))
    ap.add_argument("--sparsity", type=float, default=0.8)
    ap.add_argument("--budget", type=int, default=16)
    ap.add_argument("--shortlist", type=int, default=3)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan-out", default="benchmarks/out/kernel_plan.json")
    ap.add_argument("--json", action="store_true",
                    help="emit benchmarks/out/BENCH_autotune.json")
    args = ap.parse_args()
    run(tuple(f for f in args.families.split(",") if f),
        sparsity=args.sparsity, budget=args.budget,
        shortlist=args.shortlist, requests=args.requests,
        repeats=args.repeats, seed=args.seed, plan_out=args.plan_out,
        json_out=args.json)
