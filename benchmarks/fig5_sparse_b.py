"""Figure 5: Sparse.B design-space exploration (weight-only sparsity).

(a) normalized speedup vs the dense baseline for B(db1,db2,db3,on/off)
    under the AMUX fan-in <= 8 budget;
(b,c) effective TOPS/W and TOPS/mm^2 on DNN.B (y) vs DNN.dense (x).

Checks the paper's headline observations (Section VI-A) and reports the
deltas; full rows land in benchmarks/out/fig5.csv.  The whole design list
goes through the batched sweep driver (one stacked-config pass + results
cache) instead of a per-design Python loop.
"""
from __future__ import annotations

import numpy as np

from repro.core import CoreConfig, Mode
from repro.core.dse import enumerate_sparse_b, pareto, sweep
from repro.core.spec import CAMBRICON_X, TCL_B, sparse_b, SPARSE_B_STAR

from .common import Timer, emit, results_cache, write_csv

# the subset the paper calls out explicitly, with its reported speedups
PAPER_CLAIMS = {
    (4, 0, 0, False): 1.7, (4, 0, 1, False): 2.5, (4, 0, 2, False): 2.9,
    (6, 0, 0, False): 1.9, (6, 0, 0, True): 2.7,
    (2, 1, 1, True): 2.6, (2, 2, 0, True): 2.4, (2, 0, 2, True): 2.4,
    (4, 0, 1, True): 2.63,
}


def run(fast: bool = True) -> None:
    core = CoreConfig()
    designs = [sparse_b(*k[:3], shuffle=k[3]) for k in PAPER_CLAIMS]
    # related work as parameter points (paper Section VII): Bit-Tactical
    # (lookahead 2, lookaside 5, no shuffle) and Cambricon-X (16x16 window
    # crossbar — the design whose input bandwidth the paper calls
    # infeasible to scale; its fan-in would be 119, far past the budget)
    designs += [TCL_B, CAMBRICON_X]
    if not fast:
        seen = {d.label() for d in designs}
        designs += [d for d in enumerate_sparse_b()
                    if d.label() not in seen]
    with Timer() as t:
        rows = sweep(designs, Mode.B, core, seed=1, cache=results_cache())
    us = t.us / max(len(designs), 1)
    for d, row in zip(designs, rows):
        key = (d.db1, d.db2, d.db3, d.shuffle)
        row["paper_speedup"] = PAPER_CLAIMS.get(key, "")
        emit(f"fig5/{d.label()}", us,
             f"speedup={row['speedup']:.2f};paper={row['paper_speedup']};"
             f"tops_w={row['tops_w']:.1f}")
    path = write_csv("fig5", rows)
    front = pareto(rows, "dense_tops_w", "tops_w")
    print(f"# fig5: {len(rows)} designs -> {path}; Pareto(power): "
          + ", ".join(r["design"] for r in front[:6]))
    # paper observation (2): db3 boosts B(4,0,0)
    by = {(r["design"]): r["speedup"] for r in rows}
    b400, b401 = by.get("B(4,0,0,off)"), by.get("B(4,0,1,off)")
    if b400 and b401:
        print(f"# obs2: db3=1 boost {100*(b401/b400-1):.0f}% "
              f"(paper: 48%)")


if __name__ == "__main__":
    run(fast=False)
