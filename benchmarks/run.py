"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines; per-figure CSVs land in
benchmarks/out/.  ``--full`` runs the complete design-space enumerations
(minutes); the default is the paper-claims subset (fast CI mode).
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full DSE enumerations (slow)")
    ap.add_argument("--only", default="",
                    help="comma list: fig5,fig6,fig7,fig8,table4,table7,"
                         "archs,kernels,batched,e2e,serve")
    args = ap.parse_args()

    from . import (bench_archs, bench_batched, bench_e2e, bench_kernels,
                   bench_serve, fig5_sparse_b, fig6_sparse_a, fig7_sparse_ab,
                   fig8_overall, table4_networks, table7_breakdown)
    suites = {
        "table4": table4_networks.run,
        "table7": table7_breakdown.run,
        "fig5": fig5_sparse_b.run,
        "fig6": fig6_sparse_a.run,
        "fig7": fig7_sparse_ab.run,
        "fig8": fig8_overall.run,
        "archs": bench_archs.run,
        "kernels": bench_kernels.run,
        "batched": bench_batched.run,
        "e2e": bench_e2e.run,
        "serve": bench_serve.run,
    }
    only = [s for s in args.only.split(",") if s]
    unknown = [s for s in only if s not in suites]
    if unknown:
        ap.error(f"unknown suite(s) {unknown}; choose from {sorted(suites)}")
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suites.items():
        if only and name not in only:
            continue
        try:
            fn(fast=not args.full)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
