"""Figure 8 + Section VI-D/F: overall comparison across the four DNN
categories — the paper's headline result.

Griffin (hybrid) vs Sparse.AB* (downgrade), Sparse.A*/B*, TCL.B, TDash.AB,
SparTen.AB and the dense baseline, scored on DNN.dense / DNN.B / DNN.A /
DNN.AB.  Reports Griffin-vs-SparTen power-efficiency ratios (paper: 1.2 /
3.0 / 3.1 / 1.4x) and the sparsity tax (paper: 29%/24% vs 42%/80%).
"""
from __future__ import annotations

from typing import Dict

from repro.core import CoreConfig, GRIFFIN, Mode
from repro.core.dse import sweep
from repro.core.efficiency import sparsity_tax
from repro.core.spec import (DENSE_BASELINE, SPARSE_A_STAR, SPARSE_AB_STAR,
                             SPARSE_B_STAR, SPARTEN_AB, TCL_B, TDASH_AB)

from .common import Timer, emit, results_cache, write_csv

DESIGNS = [DENSE_BASELINE, SPARSE_B_STAR, TCL_B, SPARSE_A_STAR,
           SPARSE_AB_STAR, GRIFFIN, TDASH_AB, SPARTEN_AB]
MODES = [Mode.DENSE, Mode.B, Mode.A, Mode.AB]
PAPER_GRIFFIN_VS_SPARTEN = {Mode.DENSE: 1.2, Mode.B: 3.0, Mode.A: 3.1,
                            Mode.AB: 1.4}


def run(fast: bool = True) -> None:
    core = CoreConfig()
    rows = []
    table: Dict = {}
    cache = results_cache()
    # one batched sweep over the whole design list per execution category
    for mode in MODES:
        with Timer() as t:
            mode_rows = sweep(DESIGNS, mode, core, seed=4, cache=cache)
        us = t.us / len(DESIGNS)
        for d, row in zip(DESIGNS, mode_rows):
            name = d.name if hasattr(d, "name") and isinstance(d.name, str) \
                else d.label()
            rows.append(row)
            table[(name, mode)] = row
            emit(f"fig8/{name}/{mode.value}", us,
                 f"speedup={row['speedup']:.2f};tops_w={row['tops_w']:.2f};"
                 f"tops_mm2={row['tops_mm2']:.2f}")
    path = write_csv("fig8", rows)
    print(f"# fig8 -> {path}")
    print("# Griffin vs SparTen.AB power efficiency (paper 1.2/3.0/3.1/1.4):")
    for mode in MODES:
        g = table[("Griffin", mode)]["tops_w"]
        s = table[("SparTen.AB", mode)]["tops_w"]
        print(f"#   {mode.value:6s}: {g / s:.2f}x "
              f"(paper {PAPER_GRIFFIN_VS_SPARTEN[mode]}x)")
    tax_g = sparsity_tax(GRIFFIN)
    tax_s = sparsity_tax(SPARTEN_AB)
    print(f"# sparsity tax Griffin {100*tax_g['power_tax']:.0f}%/"
          f"{100*tax_g['area_tax']:.0f}% (paper 29%/24%); SparTen "
          f"{100*tax_s['power_tax']:.0f}%/{100*tax_s['area_tax']:.0f}% "
          f"(paper 42%/80%)")
    # hybrid-vs-downgrade (Table III): the morphing gain
    for mode, conf in ((Mode.B, "conf.B"), (Mode.A, "conf.A")):
        g = table[("Griffin", mode)]["speedup"]
        ab = table[("Sparse.AB*", mode)]["speedup"]
        print(f"# morph gain {conf}: {100*(g/ab-1):.0f}% speedup over "
              f"downgraded Sparse.AB* (paper: 25% power eff for conf.B, "
              f"23% for conf.A)")


if __name__ == "__main__":
    run(fast=False)
