"""Table IV: benchmark networks — dense-latency validation.

Our im2col GEMM-stream reconstructions must produce the paper's dense cycle
counts (the baseline all speedups normalize to).  All networks are totalled
in one vectorized pass (``dense_cycles_batched``); the per-workload scalar
method is asserted against it, so the batched twin can never drift.
"""
from __future__ import annotations

from repro.core import CoreConfig
from repro.core.evaluate import dense_cycles_batched
from repro.core.workloads import paper_dense_latency, paper_workloads

from .common import Timer, emit, write_csv


def run(fast: bool = True) -> None:
    core = CoreConfig()
    wls = paper_workloads()
    with Timer() as t:
        dense_all = dense_cycles_batched(wls, core)
    us = t.us / len(wls)
    rows = []
    for w, dense in zip(wls, dense_all):
        assert dense == w.dense_cycles(core), "batched dense-cycle drift"
        ref = paper_dense_latency(w.name)
        rows.append({"network": w.name, "dense_cycles": dense,
                     "paper_cycles": ref, "ratio": dense / ref,
                     "b_sparsity": w.b_sparsity, "a_sparsity": w.a_sparsity})
        emit(f"table4/{w.name}", us,
             f"dense={dense:.3e};paper={ref:.1e};ratio={dense/ref:.2f}")
    print(f"# table4 -> {write_csv('table4', rows)}")


if __name__ == "__main__":
    run()
