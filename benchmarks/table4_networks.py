"""Table IV: benchmark networks — dense-latency validation.

Our im2col GEMM-stream reconstructions must produce the paper's dense cycle
counts (the baseline all speedups normalize to)."""
from __future__ import annotations

from repro.core import CoreConfig
from repro.core.workloads import paper_dense_latency, paper_workloads

from .common import Timer, emit, write_csv


def run(fast: bool = True) -> None:
    core = CoreConfig()
    rows = []
    for w in paper_workloads():
        with Timer() as t:
            dense = w.dense_cycles(core)
        ref = paper_dense_latency(w.name)
        rows.append({"network": w.name, "dense_cycles": dense,
                     "paper_cycles": ref, "ratio": dense / ref,
                     "b_sparsity": w.b_sparsity, "a_sparsity": w.a_sparsity})
        emit(f"table4/{w.name}", t.us,
             f"dense={dense:.3e};paper={ref:.1e};ratio={dense/ref:.2f}")
    print(f"# table4 -> {write_csv('table4', rows)}")


if __name__ == "__main__":
    run()
