"""Table VII: power/area breakdown — the fitted 7nm cost model vs the
paper's synthesis results, with per-design residuals."""
from __future__ import annotations

from repro.core import GRIFFIN, PRESETS, power_area
from repro.core.overhead import TABLE_VII_TOTALS

from .common import Timer, emit, write_csv


def run(fast: bool = True) -> None:
    rows = []
    for name, (p_ref, a_ref) in TABLE_VII_TOTALS.items():
        design = GRIFFIN if name == "Griffin" else PRESETS[name]
        with Timer() as t:
            pa = power_area(design)
        rows.append({
            "design": name, "power_mw": round(pa.power_mw, 1),
            "paper_power_mw": p_ref,
            "power_err_pct": round(100 * (pa.power_mw / p_ref - 1), 1),
            "area_kum2": round(pa.area_kum2, 1), "paper_area_kum2": a_ref,
            "area_err_pct": round(100 * (pa.area_kum2 / a_ref - 1), 1),
            **{f"p_{k}": round(v, 2) for k, v in pa.breakdown_power.items()},
        })
        emit(f"table7/{name}", t.us,
             f"power={pa.power_mw:.0f}mW({rows[-1]['power_err_pct']:+.0f}%);"
             f"area={pa.area_kum2:.0f}kum2({rows[-1]['area_err_pct']:+.0f}%)")
    print(f"# table7 -> {write_csv('table7', rows)}")


if __name__ == "__main__":
    run()
