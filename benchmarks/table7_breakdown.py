"""Table VII: power/area breakdown — the fitted 7nm cost model vs the
paper's synthesis results, with per-design residuals.

Rows go through the same content-hashed results cache as the DSE sweeps
(keyed on the design + cost-model version), so repeated benchmark runs read
the fitted breakdowns back instead of re-deriving them.
"""
from __future__ import annotations

from repro.core import GRIFFIN, PRESETS, power_area
from repro.core.dse import design_fingerprint
from repro.core.evaluate import DEFAULT_MASK_MODEL
from repro.core.overhead import TABLE_VII_TOTALS
from repro.core.spec import CoreConfig, Mode

from .common import Timer, emit, results_cache, write_csv


def run(fast: bool = True) -> None:
    cache = results_cache()
    core = CoreConfig()
    rows = []
    for name, (p_ref, a_ref) in TABLE_VII_TOTALS.items():
        design = GRIFFIN if name == "Griffin" else PRESETS[name]
        # key on the paper reference totals too, so editing TABLE_VII_TOTALS
        # invalidates the row (the cost-model source is in the fingerprint)
        key = design_fingerprint(design, Mode.DENSE, core, 0,
                                 DEFAULT_MASK_MODEL,
                                 extra=("table7", p_ref, a_ref))
        with Timer() as t:
            row = cache.get(key)
            if row is None:
                pa = power_area(design)
                row = {
                    "design": name, "power_mw": round(pa.power_mw, 1),
                    "paper_power_mw": p_ref,
                    "power_err_pct": round(100 * (pa.power_mw / p_ref - 1), 1),
                    "area_kum2": round(pa.area_kum2, 1),
                    "paper_area_kum2": a_ref,
                    "area_err_pct": round(100 * (pa.area_kum2 / a_ref - 1), 1),
                    **{f"p_{k}": round(v, 2)
                       for k, v in pa.breakdown_power.items()},
                }
                cache.put(key, row)
        rows.append(row)
        emit(f"table7/{name}", t.us,
             f"power={row['power_mw']:.0f}mW({row['power_err_pct']:+.0f}%);"
             f"area={row['area_kum2']:.0f}kum2({row['area_err_pct']:+.0f}%)")
    print(f"# table7 -> {write_csv('table7', rows)}")


if __name__ == "__main__":
    run()
