"""Batched-DSE engine benchmark: stacked-config sweep vs per-design loop.

Measures the tentpole claim directly: the same Sparse.B design list scored
(a) the seed way — one ``score()`` call per design, i.e. one mask draw and
one scheduler pass each — and (b) through ``sweep()``'s stacked-config
batched engine, then (c) again with a warm results cache.  Asserts row
equality (the batched path is bit-exact) and writes the speedups to
``benchmarks/out/batched_speedup.csv``.

Fast mode uses a 6-design slice; ``--full`` uses the whole fan-in-<=8
Sparse.B enumeration (the fig5 design space).
"""
from __future__ import annotations

import shutil
import tempfile
import time

from repro.core import CoreConfig, Mode
from repro.core.dse import ResultsCache, enumerate_sparse_b, score, sweep
from repro.core.spec import sparse_b

from .common import emit, write_csv


def run(fast: bool = True) -> None:
    core = CoreConfig()
    if fast:
        designs = [sparse_b(4, 0, 1, True), sparse_b(2, 1, 1, True),
                   sparse_b(6, 0, 0, False), sparse_b(4, 0, 0, False),
                   sparse_b(2, 0, 2, True), sparse_b(8, 0, 1, True)]
    else:
        designs = enumerate_sparse_b()

    t0 = time.perf_counter()
    scalar_rows = [score(d, Mode.B, core, seed=1) for d in designs]
    t1 = time.perf_counter()
    batched_rows = sweep(designs, Mode.B, core, seed=1)
    t2 = time.perf_counter()
    cache_dir = tempfile.mkdtemp(prefix="griffin-dse-cache-")
    try:
        cache = ResultsCache(cache_dir)
        sweep(designs, Mode.B, core, seed=1, cache=cache)      # warm it
        t3 = time.perf_counter()
        cached_rows = sweep(designs, Mode.B, core, seed=1, cache=cache)
        t4 = time.perf_counter()
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)

    assert scalar_rows == batched_rows == cached_rows, \
        "batched sweep must be bit-exact with the per-design loop"
    scalar_s, batched_s, cached_s = t1 - t0, t2 - t1, t4 - t3
    rows = [{
        "suite": "sparse_b" + ("" if fast else "_full"),
        "n_designs": len(designs),
        "scalar_loop_s": round(scalar_s, 2),
        "batched_sweep_s": round(batched_s, 2),
        "cached_sweep_s": round(cached_s, 3),
        "batched_speedup": round(scalar_s / batched_s, 2),
        "cached_speedup": round(scalar_s / max(cached_s, 1e-9), 1),
    }]
    emit("bench_batched/sweep", batched_s * 1e6 / len(designs),
         f"n={len(designs)};scalar={scalar_s:.1f}s;batched={batched_s:.1f}s;"
         f"speedup={scalar_s / batched_s:.1f}x;"
         f"cached={scalar_s / max(cached_s, 1e-9):.0f}x")
    print(f"# bench_batched -> {write_csv('batched_speedup', rows)}")


if __name__ == "__main__":
    run(fast=False)
