"""End-to-end Griffin execution: registry models dense vs mode-dispatched.

Runs whole-network prefill forwards (reduced configs, Pallas interpret mode
on CPU) through ``models.common.griffin_linear`` under the four workload
categories of paper Table I:

  dense -> every GEMM on the dense Pallas kernel;
  A     -> declared activation sparsity, Sparse.A kernel (runtime-compacted
           A-block iteration space) against dense weights;
  B     -> weights block-pruned + compacted (``sparsity.sparsify_params``),
           Sparse.B kernel;
  AB    -> compacted weights + declared activation sparsity, dual kernel
           (compacted B walk + on-the-fly A-block predication).

Every category is parity-checked against the plain-``jnp`` reference with
the *same* effective weights (for B/AB: the pruned-but-dense twin from
``sparsify_params(..., compact=False)``), so the mode-dispatched stack is
validated through whole networks, not isolated GEMMs.  Interpret-mode wall
time is NOT TPU performance — the derived column that matters is the mean
*grid compaction* of the compacted weights (the MXU-work fraction a real
TPU skips; same convention as bench_kernels.py / EXPERIMENTS.md).
"""
from __future__ import annotations

import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.kernels.griffin_spmm.ops import GriffinWeights
from repro.models.common import sparse_execution
from repro.models.registry import build_model
from repro.sparsity import sparsify_params

from .common import Timer, emit, write_csv

PRUNE = dict(block_k=16, block_n=16, unit=8)   # reduced dims (d_model 64)
B_SPARSITY = 0.6
A_SPARSITY = 0.5        # declared (paper Table I category knob)
TOL = 1e-4              # reduced configs run float32

FAST_MODELS = ("llama3.2-1b", "xlstm-1.3b")
FULL_MODELS = FAST_MODELS + ("whisper-large-v3", "mixtral-8x7b")


def _batch(cfg, rng):
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    batch = {"tokens": tokens}
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((2, cfg.enc_frames, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    return batch


def _weight_stats(params):
    """Mean density / grid compaction over the GriffinWeights leaves."""
    dens, comp = [], []

    def visit(t):
        if isinstance(t, GriffinWeights):
            dens.append(t.density)
            comp.append(t.compaction)
        elif isinstance(t, dict):
            for v in t.values():
                visit(v)

    visit(params)
    return (float(np.mean(dens)) if dens else 1.0,
            float(np.mean(comp)) if comp else 1.0)


def _timed_prefill(api, params, batch, **scope):
    if scope:
        with sparse_execution(**scope):
            _, logits = api.prefill(params, batch)
            logits.block_until_ready()
            with Timer() as t:
                _, logits = api.prefill(params, batch)
                logits.block_until_ready()
    else:
        _, logits = api.prefill(params, batch)
        logits.block_until_ready()
        with Timer() as t:
            _, logits = api.prefill(params, batch)
            logits.block_until_ready()
    return np.asarray(logits, np.float32), t.us


def run_model(name: str, rows: list) -> None:
    cfg = get_config(name).reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, np.random.default_rng(1))

    dense_ref, us_ref = _timed_prefill(api, params, batch)
    pruned_dense = sparsify_params(params, B_SPARSITY, compact=False, **PRUNE)
    pruned_ref, _ = _timed_prefill(api, pruned_dense, batch)
    compacted = sparsify_params(params, B_SPARSITY, **PRUNE)
    w_density, w_compaction = _weight_stats(compacted)

    cats = {
        "dense": (params, dense_ref, dict(interpret=True)),
        "A": (params, dense_ref, dict(interpret=True,
                                      a_sparsity=A_SPARSITY)),
        "B": (compacted, pruned_ref, dict(interpret=True)),
        "AB": (compacted, pruned_ref, dict(interpret=True,
                                           a_sparsity=A_SPARSITY)),
    }
    for cat, (p, ref, scope) in cats.items():
        out, us = _timed_prefill(api, p, batch, **scope)
        err = float(np.abs(out - ref).max())
        assert err < TOL, (name, cat, err)
        sparse_cat = cat in ("B", "AB")
        derived = (f"compaction={w_compaction if sparse_cat else 1.0:.2f};"
                   f"density={w_density if sparse_cat else 1.0:.2f};"
                   f"max_err={err:.1e}")
        emit(f"e2e/{name}/{cat}", us, derived)
        rows.append({"model": name, "category": cat, "us": us,
                     "us_jnp_ref": us_ref,
                     "weight_density": w_density if sparse_cat else 1.0,
                     "grid_compaction": w_compaction if sparse_cat else 1.0,
                     "max_err": err})


def run(fast: bool = True) -> None:
    rows: list = []
    for name in (FAST_MODELS if fast else FULL_MODELS):
        run_model(name, rows)
    print(f"# bench_e2e -> {write_csv('bench_e2e', rows)}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset (2 models, interpret mode) — the CI "
                         "stage scripts/ci.sh runs")
    args = ap.parse_args()
    run(fast=args.smoke)
