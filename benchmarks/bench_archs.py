"""Beyond-paper: Griffin scored on the 10 assigned LM architectures.

Each architecture's per-layer GEMMs (QKV/O, FFN or expert FFN, recurrent
projections, enc/dec blocks) are extracted from its config and evaluated
under the paper's cycle model for the four execution categories, assuming
80% magnitude-pruned weights (DNN.B), ReLU-variant activations at 50%
(DNN.A), or both.  Attention score/context GEMMs are runtime x runtime so
weight preprocessing is inapplicable there (DESIGN.md Section 5); the
recurrent state paths of xLSTM / RG-LRU are skipped (not weight GEMMs).
"""
from __future__ import annotations

from typing import List, Tuple

from repro.configs import all_configs
from repro.configs.base import ModelConfig
from repro.core import CoreConfig, GRIFFIN, Mode
from repro.core.evaluate import GemmShape, Workload
from repro.core.hybrid import (category_design_speedup_batched, running_spec)
from repro.core.spec import SPARSE_AB_STAR

from .common import Timer, emit, write_csv

G = GemmShape
SEQ = 512          # tokens per evaluation slice (cycle model scale)


def arch_gemms(cfg: ModelConfig, seq: int = SEQ) -> Tuple[GemmShape, ...]:
    D, H, KVH, hd, F = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                        cfg.hd, cfg.d_ff)
    L = cfg.num_layers
    gs: List[GemmShape] = [
        G(seq, D, H * hd, count=L), G(seq, D, KVH * hd, count=2 * L),
        G(seq, H * hd, D, count=L),
        # attention scores/context: runtime x runtime (A-side only)
        G(seq, hd, min(seq, cfg.window or seq), count=H * L, b_static=False),
        G(seq, min(seq, cfg.window or seq), hd, count=H * L, b_static=False),
    ]
    if cfg.moe:
        # active expert GEMMs only (top_k of E)
        act = cfg.moe.top_k
        gs += [G(seq * act, D, F, count=2 * L), G(seq * act, F, D, count=L)]
    elif F:
        gs += [G(seq, D, F, count=2 * L), G(seq, F, D, count=L)]
    if cfg.family == "ssm":
        din = int(cfg.proj_factor * D)
        gs = [G(seq, D, 2 * din, count=L), G(seq, din, D, count=L),
              G(seq, din // H, din // H, count=3 * H * L)]
    if cfg.family == "hybrid":
        R = cfg.lru_width or D
        gs += [G(seq, D, R, count=2 * L // 3 * 2), G(seq, R, D, count=L)]
    if cfg.is_encdec:
        gs += [G(cfg.enc_frames, D, H * hd, count=4 * cfg.encoder_layers),
               G(cfg.enc_frames, D, F, count=2 * cfg.encoder_layers)]
    return tuple(gs)


def run(fast: bool = True) -> None:
    core = CoreConfig()
    rows = []
    archs = sorted(all_configs())
    if fast:
        archs = archs[:4]
    for name in archs:
        cfg = all_configs()[name]
        gemms = arch_gemms(cfg)
        for mode, (a_s, b_s) in [(Mode.B, (0.0, 0.8)), (Mode.A, (0.5, 0.0)),
                                 (Mode.AB, (0.5, 0.8))]:
            wl = Workload(name, gemms, a_s, b_s)
            with Timer() as t:
                # one stacked-config pass scores both designs (shared masks)
                sp_g, sp_ab = category_design_speedup_batched(
                    [GRIFFIN, SPARSE_AB_STAR], [wl], core, seed=5, mode=mode)
            rows.append({"arch": name, "mode": mode.value,
                         "griffin_speedup": round(sp_g, 3),
                         "dual_downgrade_speedup": round(sp_ab, 3),
                         "morph_gain_pct": round(100 * (sp_g / sp_ab - 1), 1)})
            emit(f"bench_archs/{name}/{mode.value}", t.us,
                 f"griffin={sp_g:.2f};dual={sp_ab:.2f}")
    print(f"# bench_archs -> {write_csv('bench_archs', rows)}")


if __name__ == "__main__":
    run(fast=False)
