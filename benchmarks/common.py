"""Shared benchmark utilities: CSV emission, timing + the DSE results cache."""
from __future__ import annotations

import csv
import os
import time
from typing import Dict, List, Sequence

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")
CACHE_DIR = os.path.join(OUT_DIR, "cache")


def results_cache():
    """The shared on-disk sweep-row cache (benchmarks/out/cache/).

    Keys are content hashes of (design, mode, core, seed, mask model), so
    re-running any figure script only re-evaluates design points whose
    inputs changed; delete the directory to force a cold run.
    """
    from repro.core.dse import ResultsCache
    return ResultsCache(CACHE_DIR)


def write_csv(name: str, rows: Sequence[Dict]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name + ".csv")
    if rows:
        keys = list(rows[0].keys())
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=keys)
            w.writeheader()
            for r in rows:
                w.writerow({k: r.get(k, "") for k in keys})
    return path


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The run.py contract: ``name,us_per_call,derived`` CSV lines."""
    print(f"{name},{us_per_call:.1f},{derived}")


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6
