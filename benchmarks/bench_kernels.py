"""Kernel micro-benchmarks: Pallas (interpret mode on CPU) vs jnp oracle.

Interpret-mode wall time is NOT TPU performance — the derived column that
matters is the *grid compaction* (fraction of MXU block-work the Griffin
kernel skips), which is exactly the speedup term a real TPU realizes, plus
the balance-shuffle effect on padded grid depth.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.evaluate import MaskModel
from repro.kernels import (compact_activations, dense_matmul, griffin_matmul,
                           preprocess_weights, sparse_a_matmul)
from repro.kernels.dense_gemm.ref import dense_matmul_ref

from .common import Timer, emit, write_csv


def run(fast: bool = True) -> None:
    rng = np.random.default_rng(0)
    mm = MaskModel()
    rows = []
    m, k, n = (64, 512, 512) if fast else (128, 1024, 1024)
    bk = bn = 64
    unit = 16
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w_dense = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)

    out = dense_matmul(a, w_dense, block_m=64, block_n=64, block_k=64,
                       interpret=True)
    out.block_until_ready()
    with Timer() as t:
        dense_matmul(a, w_dense, block_m=64, block_n=64, block_k=64,
                     interpret=True).block_until_ready()
    ref = dense_matmul_ref(a, w_dense)
    err = float(jnp.max(jnp.abs(out - ref)))
    emit("kernels/dense_gemm", t.us, f"max_err={err:.1e}")
    rows.append({"kernel": "dense_gemm", "us": t.us, "err": err})

    for sparsity in (0.5, 0.8):
        # channel-clustered pruning pattern (the realistic case)
        mask = mm.weight_mask(k // bk, n // unit, 1 - sparsity, rng)
        w = np.asarray(w_dense).copy()
        wb = w.reshape(k // bk, bk, n // unit, unit)
        wb *= mask[:, None, :, None]
        w = wb.reshape(k, n)
        for balance in (False, True):
            gw = preprocess_weights(w, block_k=bk, block_n=bn, unit=unit,
                                    balance=balance)
            for dual in (False, True):
                av = np.asarray(a).copy()
                if dual:
                    av[:, : k // 4] = 0       # bursty activation zeros
                out = griffin_matmul(jnp.asarray(av), gw, block_m=64,
                                     dual=dual, interpret=True)
                out.block_until_ready()
                with Timer() as t:
                    griffin_matmul(jnp.asarray(av), gw, block_m=64,
                                   dual=dual, interpret=True
                                   ).block_until_ready()
                err = float(jnp.max(jnp.abs(out - av @ w)))
                name = (f"kernels/griffin_spmm/s{int(sparsity*100)}"
                        f"{'_bal' if balance else ''}{'_dual' if dual else ''}")
                emit(name, t.us,
                     f"compaction={gw.compaction:.2f};"
                     f"density={gw.density:.2f};max_err={err:.1e}")
                rows.append({"kernel": name, "us": t.us,
                             "compaction": gw.compaction,
                             "density": gw.density, "err": err})

    # Sparse.A: runtime compaction of the A-block iteration space against
    # dense weights (concrete activations -> the grid physically shrinks).
    for sparsity in (0.5, 0.8):
        bm = 8                      # fine M tiles: per-tile ragged counts
        a_mask = mm.act_mask(m // bm, k // bk, 1 - sparsity, rng)
        av = np.asarray(a).copy()
        ab = av.reshape(m // bm, bm, k // bk, bk)
        ab *= a_mask[:, None, :, None]
        av = ab.reshape(m, k)
        aj = jnp.asarray(av)
        meta = compact_activations(aj, block_m=bm, block_k=bk)
        out = sparse_a_matmul(aj, w_dense, meta=meta, block_n=bn,
                              interpret=True)
        out.block_until_ready()
        with Timer() as t:
            sparse_a_matmul(aj, w_dense, meta=meta, block_n=bn,
                            interpret=True).block_until_ready()
        err = float(jnp.max(jnp.abs(out - av @ np.asarray(w_dense))))
        name = f"kernels/sparse_a/s{int(sparsity*100)}"
        emit(name, t.us, f"compaction={meta.compaction:.2f};"
             f"density={meta.density:.2f};max_err={err:.1e}")
        rows.append({"kernel": name, "us": t.us,
                     "compaction": meta.compaction,
                     "density": meta.density, "err": err})
    print(f"# bench_kernels -> {write_csv('bench_kernels', rows)}")


if __name__ == "__main__":
    run(fast=False)
