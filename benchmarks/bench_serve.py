"""Serving-throughput benchmark: static vs continuous batching.

Drives the slot-pool engine (``repro.runtime.engine``) over a deterministic
mixed prompt/gen-length request trace (reduced config, CPU-scale) under the
two scheduler policies.  Both policies share one memoized set of jitted
prefill/decode fns and are timed on a warm second engine, so the measured
gap is pure scheduling: static batching admits a fresh group only when the
pool has fully drained (the longest generation in each group idles every
other slot), continuous batching backfills freed slots from the queue every
step.  The headline column is tok/s; ``tok_per_step`` (emitted tokens per
pooled decode step = mean slot utilization) is the wall-clock-free twin the
tier-2 test asserts on.

Writes benchmarks/out/bench_serve.csv.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.runtime.engine import ServeEngine, synthetic_trace

from .common import emit, write_csv

ARCH = "llama3.2-1b"
SLOTS = 4
PROMPT_LENS = (8, 16, 24)
# heavy-tailed generation lengths (sampled uniformly from the tuple, so
# repeats are weights): most requests are short, ~1 in 8 is a straggler —
# the regime where a static group idles every slot on its longest member
GEN_LENS = (3, 3, 4, 4, 6, 6, 8, 28)


def _make_engine(api, params, factory_cache, policy, cache_len):
    def factory():
        if "fns" not in factory_cache:
            from repro.runtime.engine import _default_serve_fns
            factory_cache["fns"] = _default_serve_fns(api, cache_len)
        return factory_cache["fns"]

    return ServeEngine(api, params, num_slots=SLOTS, cache_len=cache_len,
                       policy=policy, fns_factory=factory)


def run(fast: bool = True) -> None:
    n_req = 16 if fast else 48
    # mid-sized config: big enough that a pooled decode step is compute-
    # (not dispatch-) bound on CPU, so the step-count gap between the two
    # policies is what the wall clock sees
    cfg = dataclasses.replace(get_config(ARCH).reduced(),
                              d_model=256, head_dim=64, d_ff=1024,
                              num_layers=4, vocab_size=512)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    cache_len = max(PROMPT_LENS) + max(GEN_LENS) + 1
    trace = lambda: synthetic_trace(cfg, num_requests=n_req, seed=7,
                                    prompt_lens=PROMPT_LENS,
                                    gen_lens=GEN_LENS)
    factory_cache: dict = {}
    rows = []
    results = {}
    for policy in ("static", "continuous"):
        # cold engine traces the jits (shared via factory_cache), warm
        # engine is timed — both policies run identical executables
        _make_engine(api, params, factory_cache, policy, cache_len
                     ).run(trace())
        eng = _make_engine(api, params, factory_cache, policy, cache_len)
        t0 = time.perf_counter()
        outs = eng.run(trace())
        dt = time.perf_counter() - t0
        assert len(outs) == n_req and all(o.finished >= 0
                                          for o in outs.values())
        toks = eng.stats["emitted"]
        tok_s = toks / dt
        tok_step = toks / max(eng.stats["decode_steps"], 1)
        results[policy] = (tok_s, tok_step, eng, dt)
        emit(f"serve/{ARCH}/{policy}", dt * 1e6 / toks,
             f"tok_s={tok_s:.1f};tok_per_step={tok_step:.2f};"
             f"decode_steps={eng.stats['decode_steps']}")
        rows.append({"policy": policy, "requests": n_req, "slots": SLOTS,
                     "emitted": toks,
                     "decode_steps": eng.stats["decode_steps"],
                     "prefill_calls": eng.stats["prefill_calls"],
                     "wall_s": round(dt, 4), "tok_s": round(tok_s, 1),
                     "tok_per_step": round(tok_step, 3)})
    speedup = results["continuous"][0] / results["static"][0]
    rows.append({"policy": "continuous/static", "requests": n_req,
                 "slots": SLOTS, "emitted": "",
                 "decode_steps": "", "prefill_calls": "",
                 "wall_s": "", "tok_s": round(speedup, 3),
                 "tok_per_step": round(results["continuous"][1] /
                                       results["static"][1], 3)})
    print(f"# bench_serve -> {write_csv('bench_serve', rows)} "
          f"(continuous/static tok/s = {speedup:.2f}x)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="longer trace (48 requests)")
    args = ap.parse_args()
    run(fast=not args.full)
