"""Serving-throughput benchmark: static vs per-step vs fused-chunk decode.

Drives the slot-pool engine (``repro.runtime.engine``) over a deterministic
mixed prompt/gen-length request trace (reduced config, CPU-scale) under
three configurations:

  static        step-wise decode, admission only on a drained pool
  continuous    step-wise decode (decode_chunk=1) — the PR 3 hot path:
                one decode dispatch + argmax + host sync per token
  continuous-chunked
                the fused device-resident hot path (decode_chunk=8):
                decode -> argmax -> feedback -> bookkeeping scanned on
                device, one host sync per 8 steps (DESIGN.md Section 9)

Engines sharing a decode_chunk share one memoized set of jitted fns and
are timed on a warm second run, so the static/continuous gap is pure
scheduling and the continuous/chunked gap is pure host-synchronization.
The headline column is tok/s; ``host_syncs_per_token`` is the wall-clock-
free twin the CI serve stage bounds.

``--mesh DxM`` appends a mesh-parallel row (``runtime.mesh_serve
.MeshServeEngine`` on a data x model device mesh, DESIGN.md Section 10)
with the same trace.  The deterministic invariant — gated here and by
scripts/check_bench_regression.py — is the sharded/unsharded tok-per-step
ratio (exactly 1.0: sharding is a placement concern, not a scheduling
one).  The tok/s ratio is *recorded* in the JSON ``speedups`` but only
*asserted* (sharded >= unsharded at equal total batch) when the host has
at least one core per mesh device: on an emulated mesh multiplexing one
core, wall clock measures GSPMD emulation overhead, not hardware — the
documented deviation in DESIGN.md Section 10.  Every row carries a
``mesh`` field ("1x1" = unsharded).

Writes benchmarks/out/bench_serve.csv; ``--json`` additionally emits
benchmarks/out/BENCH_serve.json so the perf trajectory is machine-readable
across PRs — scripts/check_bench_regression.py replays the recorded trace
against the committed file and fails CI on invariant drift.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import time

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.runtime import slo
from repro.runtime.engine import ServeEngine, synthetic_trace
from repro.runtime.router import RouterEngine
from repro.runtime.slo import DegradationConfig

from .common import emit, write_csv

ARCH = "llama3.2-1b"
SLOTS = 4
CHUNK = 8
PROMPT_LENS = (8, 16, 24)
# heavy-tailed generation lengths (sampled uniformly from the tuple, so
# repeats are weights): most requests are short, ~1 in 8 is a straggler —
# the regime where a static group idles every slot on its longest member,
# and long enough that the fused path sustains full 8-step chunks (the
# chunk-length ladder shortens chunks near each request's end)
GEN_LENS = (12, 12, 16, 16, 24, 24, 32, 112)
# (policy, decode_chunk, fused, mesh): fused=False is the preserved PR 3
# per-step hot path — the baseline the acceptance criterion compares
# against; mesh=None rows run the unsharded engine
CONFIGS = (("static", 1, False, None), ("continuous", 1, False, None),
           ("continuous", CHUNK, True, None))

# router overload row (DESIGN.md Section 13): a seeded 2x-overload bursty
# heavy-tailed trace through 2 replicas, once behind the bounded EDF
# queue + degradation ladder and once behind the unbounded no-SLO
# baseline it replaces.  Every gated metric is in virtual router ticks —
# deterministic, so scripts/check_bench_regression.py replays with ==.
ROUTER_REPLICAS = 2
ROUTER_BOUND = 6
ROUTER_SLO = dict(deadline_slack=4.0, ttft_deadline=6)

# paged-arena row (DESIGN.md Section 14): fixed vs paged at the SAME
# device-memory budget.  The fixed arena must provision every slot for the
# worst case (prompt 24 + heavy-tail gen cap 224 -> cache_len 256), so 4
# slots cost 1024 KV token rows.  The paged pool spends those same 1024
# rows (64 pages x 16 tokens, DUMP page included — strictly no more
# memory) but reserves per request only the pages its actual prompt+gen
# needs, so the heavy-tailed trace (most requests short, ~1 in 8 a
# straggler) admits 10 slots concurrently.  Gated: peak-concurrency
# ratio >= 2x, fp32 token-exact vs fixed, int8 teacher-forced logit gap
# <= PAGED_INT8_TOL (measured ~0.003 on this workload; the tolerance is
# the one DESIGN.md Section 14 documents).
PAGED = dict(page_size=16, num_pages=64, cache_len=256,
             fixed_slots=SLOTS, paged_slots=10)
PAGED_MAX_GEN = 224                 # EngineConfig.heavy_gen_cap(GEN_LENS)
PAGED_INT8_TOL = 0.02


def overload_trace(cfg, n_req: int, with_slo: bool):
    """Bursty Markov-modulated arrivals at ~2x the pool's service rate
    with Pareto generation lengths; ``with_slo`` attaches the
    deadline/priority fields the router's admission control consumes
    (False = the FCFS-unbounded baseline's view of the same workload)."""
    extra = dict(priorities=(0, 1), **ROUTER_SLO) if with_slo else {}
    return synthetic_trace(cfg, num_requests=n_req, seed=11,
                           prompt_lens=PROMPT_LENS,
                           gen_lens=(4, 8, 12, 16),
                           arrival_process="bursty", rate=1.0,
                           burst_rate=8.0, burst_switch=0.2,
                           length_dist="heavy", heavy_alpha=1.6,
                           max_gen=24, **extra)


def build_workload(n_req: int):
    """(cfg, api, params, cache_len, trace_fn) for the benchmark workload —
    shared with scripts/check_bench_regression.py so the regression check
    replays exactly the recorded trace."""
    # sized for the dispatch-bound decode regime the fused chunk targets: a
    # pooled decode step does real GEMV work but completes in O(host
    # round-trip) time — on CPU that is a small model; on TPU a batch-4
    # decode GEMV of a 1B+ model sits in the same regime (~100us step vs
    # ~ms host loop), which is why PR 3's per-token sync idles the core
    cfg = dataclasses.replace(get_config(ARCH).reduced(),
                              d_model=96, head_dim=24, d_ff=384,
                              num_layers=2, vocab_size=256)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    cache_len = max(PROMPT_LENS) + max(GEN_LENS) + 1
    trace = lambda: synthetic_trace(cfg, num_requests=n_req, seed=7,
                                    prompt_lens=PROMPT_LENS,
                                    gen_lens=GEN_LENS)
    return cfg, api, params, cache_len, trace


def _name(policy: str, fused: bool, mesh=None) -> str:
    base = f"{policy}-chunked" if fused else policy
    return f"{base}@{mesh}" if mesh else base


def make_engine(api, params, factory_cache, policy, cache_len, chunk,
                fused, mesh=None, plan=None):
    if mesh:
        from repro.launch.mesh import serve_mesh
        from repro.runtime.mesh_serve import MeshServeEngine
        return MeshServeEngine(api, params, mesh=serve_mesh(mesh),
                               num_slots=SLOTS, cache_len=cache_len,
                               policy=policy, decode_chunk=chunk,
                               fused=fused, plan=plan)

    def factory():
        if chunk not in factory_cache:
            from repro.runtime.engine import _default_serve_fns
            factory_cache[chunk] = _default_serve_fns(api, cache_len, chunk)
        return factory_cache[chunk]

    return ServeEngine(api, params, num_slots=SLOTS, cache_len=cache_len,
                       policy=policy, fns_factory=factory,
                       decode_chunk=chunk, fused=fused, plan=plan)


def run_router_overload(api, params, cache_len, cfg, n_req,
                        factory_cache) -> dict:
    """The overload pair: the same seeded 2x-overload trace through (a)
    the bounded-EDF + degradation router and (b) the unbounded FCFS
    baseline.  Returns the two virtual-tick summaries; asserts the
    bounded run stayed bounded and the baseline demonstrates the queue
    growth it prevents."""
    results = {}
    for name, bounded in (("router-bounded", True),
                          ("router-unbounded", False)):
        router = RouterEngine(
            lambda: make_engine(api, params, factory_cache, "continuous",
                                cache_len, CHUNK, True),
            ROUTER_REPLICAS,
            queue_bound=ROUTER_BOUND if bounded else None,
            degradation=DegradationConfig() if bounded else None)
        reqs = overload_trace(cfg, n_req, with_slo=bounded)
        t0 = time.perf_counter()
        outs = router.run(reqs)
        dt = time.perf_counter() - t0
        summary = slo.latency_summary(slo.request_rows(outs, reqs))
        results[name] = dict(
            replicas=ROUTER_REPLICAS, slots=SLOTS,
            queue_bound=ROUTER_BOUND if bounded else None,
            max_queue_depth=router.max_queue_depth,
            ticks=router.clock,
            ladder_history=[list(t) for t in router.ladder.history]
            if router.ladder else [],
            wall_s=round(dt, 4), **summary)
        emit(f"serve/{ARCH}/{name}", dt * 1e6 / max(1, n_req),
             f"ttft_p99={summary['ttft_p99']};shed={summary['shed']};"
             f"depth={router.max_queue_depth}")
    b, u = results["router-bounded"], results["router-unbounded"]
    assert b["shed"] > 0, "2x-overload trace shed nothing — not overloaded"
    assert b["max_queue_depth"] <= ROUTER_BOUND, \
        f"bounded router overflowed its queue: {b['max_queue_depth']}"
    assert u["max_queue_depth"] > ROUTER_BOUND, \
        "baseline queue never outgrew the bound — the overload row " \
        "demonstrates nothing"
    assert b["ttft_p99"] <= u["ttft_p99"], \
        f"shedding+degradation worsened p99 TTFT ({b['ttft_p99']} vs " \
        f"{u['ttft_p99']} ticks)"
    print(f"# router overload ({ROUTER_REPLICAS} replicas): bounded "
          f"ttft p50/p99 {b['ttft_p50']}/{b['ttft_p99']} ticks, "
          f"shed {b['shed']}, depth {b['max_queue_depth']} <= "
          f"{ROUTER_BOUND}; unbounded baseline ttft p99 {u['ttft_p99']} "
          f"ticks at depth {u['max_queue_depth']}")
    return results


def paged_trace(cfg, n_req: int):
    """The heavy-tailed all-at-once workload of the paged row: every
    request arrives at t=0 (pure concurrency pressure), Pareto generation
    lengths capped at the fixed arena's provisioning bound."""
    return synthetic_trace(cfg, num_requests=n_req, seed=7,
                           prompt_lens=PROMPT_LENS, gen_lens=GEN_LENS,
                           arrival_every=0, length_dist="heavy",
                           heavy_alpha=1.6, max_gen=PAGED_MAX_GEN)


def _drain_peak(eng, reqs):
    """Drain the trace one tick at a time, tracking the peak number of
    concurrently active slots (the quantity the paged arena buys)."""
    for r in reqs:
        eng.add(r)
    peak = 0
    while eng.sched.has_work():
        eng.step()
        peak = max(peak, len(eng.sched.active))
    return peak, {r: list(map(int, o.tokens)) for r, o in eng.outputs.items()}


def int8_logit_gap(api, params, cache_len: int, page_size: int,
                   steps: int = 48, plen: int = 24) -> float:
    """Teacher-forced int8-vs-fp32 paged decode gap: run one straggler-
    length request through both pools feeding the int8 run the fp32 run's
    tokens, and return max |logit diff| / max |fp32 logit| — the metric
    PAGED_INT8_TOL bounds (DESIGN.md Section 14)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.runtime.engine import (_batch_axes, _make_paged_insert,
                                      _promote_arena)
    from repro.runtime.paging import PageAllocator, build_spec, paged_tree

    prompt = jnp.asarray(np.random.default_rng(7).integers(
        1, api.cfg.vocab_size, (1, plen)), jnp.int32)

    def decode(kv_dtype, forced=None):
        spec, clen = build_spec(api, 1, cache_len, page_size,
                                kv_dtype=kv_dtype)
        arena = paged_tree(_promote_arena(api.init_cache(1, clen), 1),
                           1, spec)
        sub, logits0 = api.prefill(params, {"tokens": prompt},
                                   cache_len=clen)
        ids = PageAllocator(spec.num_pages).reserve(
            spec.pages_needed(plen + steps))
        insert = _make_paged_insert(_batch_axes(api, clen), spec)
        cache, _, _, tok = insert(
            arena, jnp.zeros((1, 1), jnp.int32), jnp.zeros((1,), jnp.int32),
            sub, logits0, jnp.asarray(0), jnp.asarray(steps),
            jnp.asarray(spec.page_row(ids)))
        outs, nxt = [logits0[0]], tok[:, None]
        for t in range(steps):
            if forced is not None:
                nxt = forced[t][None, None]
            logits, cache = api.decode_step(params, cache, nxt)
            outs.append(logits[0])
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return jnp.stack(outs)

    l32 = decode("fp32")
    l8 = decode("int8", forced=jnp.argmax(l32, -1).astype(jnp.int32))
    return float(jnp.max(jnp.abs(l8 - l32)) / jnp.max(jnp.abs(l32)))


def run_paged(api, params, cfg, n_req: int) -> dict:
    """The paged-arena row: fixed 4x256 vs a 10-slot paged pool of the
    same 1024 KV rows over the heavy-tailed all-at-once trace.  Gated
    here and by scripts/check_bench_regression.py: peak-concurrency
    ratio >= 2x at equal memory, paged fp32 token-exact vs fixed, int8
    within PAGED_INT8_TOL."""
    from repro.runtime.config import ArenaConfig, EngineConfig

    ps, npages, clen = PAGED["page_size"], PAGED["num_pages"], \
        PAGED["cache_len"]
    assert npages * ps <= PAGED["fixed_slots"] * clen, \
        "paged pool outspends the fixed arena — not an equal-budget row"
    results, tokens = {}, {}
    for name, slots, page_size, kv_dtype in (
            ("fixed", PAGED["fixed_slots"], None, "fp32"),
            ("paged-fp32", PAGED["paged_slots"], ps, "fp32"),
            ("paged-int8", PAGED["paged_slots"], ps, "int8")):
        econf = EngineConfig(arena=ArenaConfig(
            num_slots=slots, cache_len=clen, page_size=page_size,
            num_pages=npages if page_size else None, kv_dtype=kv_dtype)
        ).with_fields(decode_chunk=CHUNK,
                      max_admissions_per_step=PAGED["paged_slots"])
        eng = ServeEngine(api, params, config=econf)
        eng.run(paged_trace(cfg, n_req))            # warm every jit
        eng.stats = {k: 0 for k in eng.stats}
        t0 = time.perf_counter()
        peak, toks = _drain_peak(eng, paged_trace(cfg, n_req))
        dt = time.perf_counter() - t0
        if page_size:
            assert eng._paged is not None
        tokens[name] = toks
        results[name] = dict(slots=slots, peak_concurrent=peak,
                             kv_rows=(npages * ps if page_size
                                      else slots * clen),
                             emitted=eng.stats["emitted"],
                             wall_s=round(dt, 4))
        emit(f"serve/{ARCH}/paged/{name}", dt * 1e6 / max(1, n_req),
             f"peak={peak};emitted={eng.stats['emitted']}")
    ratio = (results["paged-fp32"]["peak_concurrent"] /
             results["fixed"]["peak_concurrent"])
    fp32_exact = tokens["paged-fp32"] == tokens["fixed"]
    int8_match = sum(tokens["paged-int8"][r] == tokens["fixed"][r]
                     for r in tokens["fixed"]) / len(tokens["fixed"])
    gap = int8_logit_gap(api, params, clen, ps)
    assert ratio >= 2.0, \
        f"paged arena peaked at {ratio:.2f}x fixed concurrency (< 2x)"
    assert fp32_exact, "paged fp32 tokens diverged from the fixed arena"
    assert gap <= PAGED_INT8_TOL, \
        f"int8 logit gap {gap:.4f} exceeds tolerance {PAGED_INT8_TOL}"
    print(f"# paged arena (equal {npages * ps}-row KV budget): peak "
          f"concurrency {results['paged-fp32']['peak_concurrent']} vs "
          f"{results['fixed']['peak_concurrent']} fixed ({ratio:.2f}x), "
          f"fp32 token-exact={fp32_exact}, int8 token match "
          f"{int8_match:.2f}, int8 rel logit gap {gap:.4f} <= "
          f"{PAGED_INT8_TOL}")
    return {**PAGED, "max_gen": PAGED_MAX_GEN,
            "trace": {"requests": n_req, "seed": 7,
                      "length_dist": "heavy", "arrival_every": 0},
            "configs": results,
            "concurrency_ratio": round(ratio, 3),
            "fp32_token_exact": fp32_exact,
            "int8_token_match": round(int8_match, 4),
            "int8_rel_logit_gap": round(gap, 6),
            "int8_tol": PAGED_INT8_TOL}


def run(fast: bool = True, json_out: bool = False,
        mesh: str = None, plan_path: str = None) -> None:
    n_req = 16 if fast else 48
    cfg, api, params, cache_len, trace = build_workload(n_req)
    # a tuned kernel plan (repro.launch.autotune, DESIGN.md Section 12)
    # only moves this dense workload's Mode thresholds — rows record which
    # plan (if any) was active so the perf trajectory stays attributable
    fam_plan = None
    if plan_path:
        from repro.tuning import load_plan
        fam_plan = load_plan(plan_path).family(cfg.family)
    configs = list(CONFIGS)
    if mesh and mesh != "1x1":
        configs.append(("continuous", CHUNK, True, mesh))
    factory_cache: dict = {}
    rows = []
    results = {}
    # first pass traces every jit (prefill buckets, chunk ladder, insert —
    # shared via factory_cache per chunk), then the *same* engines re-run
    # fresh copies of the trace with stats zeroed, so the timed passes
    # execute fully warm code.  Wall clock is best-of-3 with the repeat
    # rounds interleaved across configs: min is the least-contended
    # estimate on a shared box, and interleaving keeps a contention burst
    # from landing on one config's entire sample (the per-trace step/sync
    # counts are deterministic either way).
    engines, warm_retraces, best = {}, {}, {}
    for policy, chunk, fused, cmesh in configs:
        name = _name(policy, fused, cmesh)
        eng = make_engine(api, params, factory_cache, policy, cache_len,
                          chunk, fused, cmesh, plan=fam_plan)
        eng.run(trace())
        engines[name] = eng
        warm_retraces[name] = eng.stats["retraces"]
        best[name] = float("inf")
    for _ in range(3):
        for policy, chunk, fused, cmesh in configs:
            name = _name(policy, fused, cmesh)
            eng = engines[name]
            eng.stats = {k: 0 for k in eng.stats}
            t0 = time.perf_counter()
            outs = eng.run(trace())
            best[name] = min(best[name], time.perf_counter() - t0)
            assert len(outs) == n_req and all(o.finished >= 0
                                              for o in outs.values())
    for policy, chunk, fused, cmesh in configs:
        name = _name(policy, fused, cmesh)
        eng, dt = engines[name], best[name]
        toks = eng.stats["emitted"]
        tok_s = toks / dt
        tok_step = toks / max(eng.stats["decode_steps"], 1)
        syncs_tok = eng.stats["host_syncs"] / toks
        results[name] = dict(
            policy=policy, decode_chunk=chunk, requests=n_req, slots=SLOTS,
            mesh=cmesh or "1x1", plan=plan_path,
            emitted=toks, decode_steps=eng.stats["decode_steps"],
            chunk_calls=eng.stats["chunk_calls"],
            prefill_calls=eng.stats["prefill_calls"],
            prefill_buckets=sorted(eng.prefill_buckets),
            retraces=warm_retraces[name],
            host_syncs_per_token=round(syncs_tok, 4),
            wall_s=round(dt, 4), tok_s=round(tok_s, 1),
            tok_per_step=round(tok_step, 3))
        emit(f"serve/{ARCH}/{name}", dt * 1e6 / toks,
             f"tok_s={tok_s:.1f};tok_per_step={tok_step:.2f};"
             f"syncs_per_tok={syncs_tok:.3f};"
             f"decode_steps={eng.stats['decode_steps']}")
        rows.append({"config": name, "mesh": cmesh or "1x1",
                     "plan": plan_path or "",
                     "requests": n_req, "slots": SLOTS,
                     "emitted": toks, "decode_chunk": chunk,
                     "decode_steps": eng.stats["decode_steps"],
                     "prefill_calls": eng.stats["prefill_calls"],
                     "host_syncs_per_token": round(syncs_tok, 4),
                     "wall_s": round(dt, 4), "tok_s": round(tok_s, 1),
                     "tok_per_step": round(tok_step, 3)})
    sched_speedup = (results["continuous"]["tok_s"] /
                     results["static"]["tok_s"])
    fused_speedup = (results["continuous-chunked"]["tok_s"] /
                     results["continuous"]["tok_s"])
    blank = {"mesh": "", "plan": "", "requests": n_req, "slots": SLOTS,
             "emitted": "",
             "decode_chunk": "", "decode_steps": "", "prefill_calls": "",
             "host_syncs_per_token": "", "wall_s": "", "tok_per_step": ""}
    rows.append({"config": "continuous/static",
                 "tok_s": round(sched_speedup, 3), **blank})
    rows.append({"config": "chunked/continuous",
                 "tok_s": round(fused_speedup, 3), **blank})
    path = write_csv("bench_serve", rows)
    print(f"# bench_serve -> {path} (continuous/static tok/s = "
          f"{sched_speedup:.2f}x, chunked/continuous tok/s = "
          f"{fused_speedup:.2f}x)")
    mesh_speedups = {}
    if mesh and mesh != "1x1":
        sh = results[_name("continuous", True, mesh)]
        un = results["continuous-chunked"]
        assert sh["tok_per_step"] == un["tok_per_step"], \
            "mesh sharding changed tokens/step — scheduling is no longer " \
            "placement-invariant"
        tok_s_ratio = sh["tok_s"] / un["tok_s"]
        mesh_speedups = {
            "sharded_vs_unsharded_tok_s": round(tok_s_ratio, 3),
            "sharded_tok_per_step_ratio":
                round(sh["tok_per_step"] / un["tok_per_step"], 3)}
        n_mesh_dev = 1
        for x in mesh.split("x"):
            n_mesh_dev *= int(x)
        if (os.cpu_count() or 1) >= n_mesh_dev:
            # equal total batch, one real core per device: the model-axis
            # split must not lose throughput (acceptance criterion)
            assert tok_s_ratio >= 1.0, \
                f"sharded tok/s regressed vs unsharded ({tok_s_ratio:.3f}x)"
        else:
            print(f"# tok/s ratio {tok_s_ratio:.3f}x recorded, not gated: "
                  f"{os.cpu_count() or 1} host cores emulate {n_mesh_dev} "
                  "devices (wall clock measures GSPMD emulation here)")
        print(f"# sharded row {mesh}: tok/step {sh['tok_per_step']} == "
              f"unsharded (ratio 1.0), tok/s ratio {tok_s_ratio:.3f}x, "
              f"syncs/token {sh['host_syncs_per_token']} "
              f"(vs {un['host_syncs_per_token']})")
    router_results = run_router_overload(api, params, cache_len, cfg,
                                         n_req, factory_cache)
    paged_results = run_paged(api, params, cfg, n_req)
    if json_out:
        out = {
            "arch": ARCH, "backend": jax.default_backend(),
            "mesh": mesh or "1x1", "plan": plan_path,
            "trace": {"requests": n_req, "slots": SLOTS,
                      "prompt_lens": list(PROMPT_LENS),
                      "gen_lens": list(GEN_LENS), "seed": 7},
            "configs": results,
            "speedups": {"continuous_vs_static": round(sched_speedup, 3),
                         "chunked_vs_continuous": round(fused_speedup, 3),
                         **mesh_speedups},
            "router": {"trace": {"requests": n_req, "seed": 11,
                                 "arrival_process": "bursty",
                                 "length_dist": "heavy",
                                 **{k: v for k, v in ROUTER_SLO.items()}},
                       **router_results},
            "paged": paged_results,
        }
        jpath = pathlib.Path(__file__).parent / "out" / "BENCH_serve.json"
        jpath.write_text(json.dumps(out, indent=2) + "\n")
        print(f"# bench_serve json -> {jpath}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="longer trace (48 requests)")
    ap.add_argument("--json", action="store_true",
                    help="emit benchmarks/out/BENCH_serve.json")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="append a mesh-parallel engine row (needs D*M "
                         "devices; on CPU export XLA_FLAGS=--xla_force_"
                         "host_platform_device_count=8)")
    ap.add_argument("--plan", default=None, metavar="PATH",
                    help="tuned kernel plan (repro.launch.autotune); rows "
                         "record it and every engine serves under its "
                         "thresholds")
    args = ap.parse_args()
    run(fast=not args.full, json_out=args.json, mesh=args.mesh,
        plan_path=args.plan)
