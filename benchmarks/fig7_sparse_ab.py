"""Figure 7: dual-sparse design-space exploration (fan-in budget <= 16).

Checks Section VI-C: shuffle replaces db2/da2, da1 <= 2, db3-over-da3
preference; Sparse.AB* = AB(2,0,0,2,0,1,on).  Scored through the batched
sweep driver + results cache.
"""
from __future__ import annotations

from repro.core import CoreConfig, Mode
from repro.core.dse import enumerate_sparse_ab, sweep
from repro.core.spec import (SPARSE_AB_STAR, SPARTEN_AB, TDASH_AB, sparse_ab)

from .common import Timer, emit, results_cache, write_csv

PAPER_CLAIMS = {
    (2, 0, 0, 2, 0, 1, True): 3.9, (2, 0, 0, 4, 0, 2, True): 4.9,
    (1, 0, 0, 3, 0, 1, True): 4.0, (1, 0, 0, 3, 0, 1, False): None,
    (1, 1, 0, 3, 0, 1, False): 3.4, (1, 0, 0, 3, 1, 1, False): 3.8,
}


def run(fast: bool = True) -> None:
    core = CoreConfig()
    designs = [sparse_ab(*k[:6], shuffle=k[6]) for k in PAPER_CLAIMS]
    designs += [TDASH_AB, SPARTEN_AB]
    if not fast:
        seen = {d.label() for d in designs}
        designs += [d for d in enumerate_sparse_ab() if d.label() not in seen]
    with Timer() as t:
        rows = sweep(designs, Mode.AB, core, seed=3, cache=results_cache())
    us = t.us / max(len(designs), 1)
    for d, row in zip(designs, rows):
        key = (d.da1, d.da2, d.da3, d.db1, d.db2, d.db3, d.shuffle)
        row["paper_speedup"] = PAPER_CLAIMS.get(key) or ""
        emit(f"fig7/{d.label()}", us,
             f"speedup={row['speedup']:.2f};paper={row['paper_speedup']};"
             f"tops_w={row['tops_w']:.1f}")
    print(f"# fig7 -> {write_csv('fig7', rows)}")


if __name__ == "__main__":
    run(fast=False)
