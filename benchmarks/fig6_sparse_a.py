"""Figure 6: Sparse.A design-space exploration (activation-only sparsity).

Same axes as fig5 on the DNN.A category (fan-in budget <= 8); checks the
paper's Section VI-B observations (da3 cost, shuffle boost, da1>=4 limit).
Scored through the batched sweep driver + results cache.
"""
from __future__ import annotations

from repro.core import CoreConfig, Mode
from repro.core.dse import enumerate_sparse_a, sweep
from repro.core.spec import CNVLUTIN, sparse_a, SPARTEN_A

from .common import Timer, emit, results_cache, write_csv

PAPER_CLAIMS = {
    (2, 1, 0, True): 1.83, (3, 1, 0, True): 1.89, (2, 1, 1, True): 1.94,
    (2, 1, 2, True): 1.97, (4, 0, 1, False): 1.28, (4, 0, 1, True): 1.79,
}


def run(fast: bool = True) -> None:
    core = CoreConfig()
    designs = [sparse_a(*k[:3], shuffle=k[3]) for k in PAPER_CLAIMS]
    designs += [SPARTEN_A, CNVLUTIN]   # Cnvlutin: time-only A skipping (Section VII)
    if not fast:
        seen = {d.label() for d in designs}
        designs += [d for d in enumerate_sparse_a() if d.label() not in seen]
    with Timer() as t:
        rows = sweep(designs, Mode.A, core, seed=2, cache=results_cache())
    us = t.us / max(len(designs), 1)
    for d, row in zip(designs, rows):
        key = (d.da1, d.da2, d.da3, d.shuffle)
        row["paper_speedup"] = PAPER_CLAIMS.get(key, "")
        emit(f"fig6/{d.label()}", us,
             f"speedup={row['speedup']:.2f};paper={row['paper_speedup']};"
             f"tops_w={row['tops_w']:.1f}")
    path = write_csv("fig6", rows)
    by = {r["design"]: r["speedup"] for r in rows}
    off, on = by.get("A(4,0,1,off)"), by.get("A(4,0,1,on)")
    if off and on:
        print(f"# obs3: shuffle boost {100*(on/off-1):.0f}% (paper: 40%)")
    print(f"# fig6 -> {path}")


if __name__ == "__main__":
    run(fast=False)
