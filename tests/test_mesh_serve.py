"""Mesh-parallel serving tests (DESIGN.md Section 10).

Two tiers:

  - tier-1 (unmarked, runs on one device): the sharding *rules* — the
    serving param layout never splits a contraction dim, the decode cache
    layout places slots on "data" and head axes on "model" — plus the
    ``decompact_weights`` fallback oracle, the ``serve_mesh`` spec parser,
    and the ``mesh=1x1`` special case collapsing onto the plain engine.

  - tier2 + mesh (the CI ``sharded`` job:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8 pytest -m mesh``):
    the parity matrix — mesh {1x2, 2x2, 2x4} x {dense, sparse-B} x
    decode_chunk {1, 3} must emit tokens identical to the *unsharded*
    ``ServeEngine`` on the same mixed trace, plus all four execution Modes
    on 2x4, family coverage (xlstm / whisper / moe / hybrid), and the
    host-sync budget surviving sharding.  Skipped (not failed) when the
    process has too few devices, so the default tier-2 job stays green on
    one device.

Kernel cells serve through the *real* shard_map'd Pallas kernels (the
``GriffinWeights`` are compacted and ``use_kernels=True`` goes to the
mesh engine too): ``_mesh_parity`` resets the ``KERNEL_DISPATCH``
trace-time counter before the sharded run and asserts the shard_map
bucket fired and the decompaction-oracle bucket did not — so a silent
fallback regression fails the matrix even though the oracle is also
token-exact.  ``test_mesh_fallback_forced_parity`` pins the oracle's
continued correctness via ``spmd_kernels=False``.  Per-op shard parity
(bitwise, vs both the unsharded kernels and the oracle) lives in
tests/test_shard_map_kernels.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core.spec import Mode
from repro.kernels.griffin_spmm.ops import (decompact_weights,
                                            preprocess_weights)
from repro.launch.mesh import serve_mesh
from repro.models import build_model
from repro.models.common import (kernel_dispatch_counts,
                                 reset_kernel_dispatch)
from repro.runtime.engine import ServeEngine, synthetic_trace
from repro.runtime.mesh_serve import MeshServeEngine, cache_heads
from repro.runtime.sharding import cache_spec, param_spec
from repro.sparsity import sparsify_params
from repro.sparsity.pruning import block_prune

PRUNE = dict(block_k=16, block_n=16, unit=8)   # reduced dims (d_model 64)


def _needs_devices(n: int):
    return pytest.mark.skipif(
        len(jax.devices()) < n,
        reason=f"needs {n} devices (export XLA_FLAGS="
               "--xla_force_host_platform_device_count=8)")


@dataclasses.dataclass(frozen=True)
class _SpecMesh:
    """Shape-only stand-in: the spec rules consult only .shape/.axis_names,
    so tier-1 can exercise multi-device layouts without multiple devices."""
    shape: dict
    axis_names: tuple


MESH22 = _SpecMesh({"data": 2, "model": 2}, ("data", "model"))


# ---------------------------------------------------------------------------
# tier-1: layout rules
# ---------------------------------------------------------------------------

def test_serve_param_spec_shards_output_axes_only():
    """The serving layout must never split a contraction dim: _IN_OUT and
    _OUT_IN weights alike get their *last* (output) axis on "model", and
    nothing lands on "data" (no FSDP at decode)."""
    wq = jax.ShapeDtypeStruct((4, 64, 128), jnp.float32)
    assert param_spec("['layers']['wq']", wq, MESH22, serve=True) == \
        P(None, None, "model")
    wo = jax.ShapeDtypeStruct((4, 128, 64), jnp.float32)
    assert param_spec("['layers']['wo']", wo, MESH22, serve=True) == \
        P(None, None, "model")          # train layout shards the input dim
    assert param_spec("['layers']['wo']", wo, MESH22, serve=False) == \
        P(None, "model", "data")
    # embeddings shard the vocab axis: the tied unembed transpose then
    # contracts locally too
    emb = jax.ShapeDtypeStruct((1000, 64), jnp.float32)
    assert param_spec("['embed']", emb, MESH22, serve=True) == \
        P("model", None)
    ln = jax.ShapeDtypeStruct((64,), jnp.float32)
    assert param_spec("['ln1']", ln, MESH22, serve=True) == P()


def test_serve_param_spec_compacted_metadata_replicates():
    """b_comp shards its N axis on "model" for *both* GEMM directions in
    the serving layout; kidx/cnt/inv_perm metadata always replicates (the
    ids are global — per-shard counts would diverge)."""
    b = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    assert param_spec("['layers']['wq'].b_comp", b, MESH22, serve=True) == \
        P(None, "model")
    assert param_spec("['layers']['wo'].b_comp", b, MESH22, serve=True) == \
        P(None, "model")
    # train layout: _OUT_IN parents put N on the fsdp axis instead
    assert param_spec("['layers']['wo'].b_comp", b, MESH22, serve=False) == \
        P(None, "data")
    kidx = jax.ShapeDtypeStruct((8, 4), jnp.int32)
    for meta in ("kidx", "cnt", "inv_perm"):
        assert param_spec(f"['layers']['wq'].{meta}", kidx, MESH22,
                          serve=True) == P(None, None)


def test_cache_spec_decode_layout():
    """Arena layout: slot (batch) axis -> dp, head axes -> "model", and the
    last axis (head_dim / feature — a contraction dim in decode attention)
    never splits."""
    kv = jax.ShapeDtypeStruct((2, 4, 31, 4, 16), jnp.float32)  # L,B,S,KVH,hd
    spec = cache_spec("['k']", kv, MESH22, batch=4, decode=True, heads=4)
    assert spec[1] in ("data", ("data",))
    assert spec[3] == "model"
    assert spec[4] is None
    # promoted per-slot (B,) counters ride the dp axes too
    pos = cache_spec("['pos']", jax.ShapeDtypeStruct((4,), jnp.int32),
                     MESH22, batch=4, decode=True, heads=4)
    assert pos[0] in ("data", ("data",))
    # heads that do not divide the model axis: leaf stays replicated on
    # that axis rather than sharded wrong (spec-respecting fallback)
    spec3 = cache_spec("['k']", kv, MESH22, batch=4, decode=True, heads=3)
    assert spec3[3] is None
    # a sequence/layer axis coincidentally equal to `heads` must lose to
    # the real (rightmost non-last) head axis — sequence stays whole
    kv_eq = jax.ShapeDtypeStruct((2, 4, 8, 8, 16), jnp.float32)
    spec_eq = cache_spec("['k']", kv_eq, MESH22, batch=4, decode=True,
                         heads=8)
    assert spec_eq[3] == "model" and spec_eq[2] is None
    # the default (train/long-context) layout is untouched by the new args
    legacy = cache_spec("['k']", kv, MESH22, batch=4)
    assert legacy == cache_spec("['k']", kv, MESH22, batch=4, decode=False)


def test_decompact_weights_is_exact():
    """The SPMD fallback's decompaction must reproduce the block-pruned
    matrix bit-exactly — surviving values are never changed by
    preprocessing — including under the balance shuffle's permutation."""
    rng = np.random.default_rng(0)
    w = rng.standard_normal((64, 96)).astype(np.float32)
    wp = np.asarray(block_prune(jnp.asarray(w), 0.6, 16, 8))
    for balance in (False, True):
        gw = preprocess_weights(wp, block_k=16, block_n=16, unit=8,
                                balance=balance)
        np.testing.assert_array_equal(np.asarray(decompact_weights(gw)), wp)


def test_serve_mesh_spec_parsing():
    m = serve_mesh("1x1")
    assert m.axis_names == ("data", "model") and m.size == 1
    for bad in ("", "2", "2x", "x2", "ax2", "0x1", "2x2x2"):
        with pytest.raises(ValueError):
            serve_mesh(bad)
    with pytest.raises(ValueError):
        serve_mesh(f"{len(jax.devices()) + 1}x1")   # more than exist


def test_cache_heads_matches_config():
    api = build_model(get_config("llama3.2-1b").reduced())
    assert cache_heads(api) == api.cfg.num_kv_heads


# ---------------------------------------------------------------------------
# tier-1: mesh=1x1 special case == plain engine
# ---------------------------------------------------------------------------

def _trace(cfg, n=4):
    return synthetic_trace(cfg, num_requests=n, seed=11,
                           prompt_lens=(6, 10), gen_lens=(2, 4),
                           arrival_every=1)


def test_mesh_engine_1x1_matches_plain_engine():
    cfg = get_config("llama3.2-1b").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    ref = ServeEngine(api, params, num_slots=4, cache_len=16,
                      decode_chunk=3).run(_trace(cfg))
    eng = MeshServeEngine(api, params, mesh=serve_mesh("1x1"), num_slots=4,
                          cache_len=16, decode_chunk=3)
    assert eng._spmd_mesh is None       # kernels stay on the 1-device paths
    out = eng.run(_trace(cfg))
    assert {r: o.tokens for r, o in out.items()} == \
        {r: o.tokens for r, o in ref.items()}


def test_mesh_engine_rejects_wrong_axis_names():
    cfg = get_config("llama3.2-1b").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    from jax.sharding import Mesh
    bad = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("x", "y"))
    with pytest.raises(ValueError):
        MeshServeEngine(api, params, mesh=bad, num_slots=2, cache_len=16)


# ---------------------------------------------------------------------------
# tier2 + mesh: the sharded parity matrix (CI `sharded` job)
# ---------------------------------------------------------------------------

_REF_CACHE: dict = {}


def _reference(api, params, key, n_req, chunk, **kw):
    """Unsharded ServeEngine tokens for a workload, memoized per matrix
    cell family so the 12-cell sweep does not rebuild it 12 times."""
    if key not in _REF_CACHE:
        eng = ServeEngine(api, params, num_slots=4, cache_len=16,
                          decode_chunk=chunk, **kw)
        outs = eng.run(_trace(api.cfg, n_req))
        _REF_CACHE[key] = ({r: o.tokens for r, o in outs.items()},
                           eng.mode, eng.mode_history)
    return _REF_CACHE[key]


def _mesh_parity(arch, mesh_spec, sparse, chunk, n_req=4, a_sparsity=None,
                 expect_mode=None, kernels=None, spmd_kernels=True):
    """Sharded-vs-unsharded token parity for one matrix cell.

    ``kernels`` (default: follow ``sparse``) runs *both* engines on the
    Pallas kernels — compacted ``GriffinWeights`` when ``sparse`` — and
    asserts via the trace-time dispatch counter that the sharded engine
    actually took the shard_map path (or, with ``spmd_kernels=False``,
    the decompaction oracle)."""
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    kernels = sparse if kernels is None else kernels
    refkw, kw = {}, {}
    if sparse:
        params = sparsify_params(params, 0.6, compact=kernels, **PRUNE)
    if kernels:
        refkw.update(use_kernels=True, interpret=True)
        kw.update(use_kernels=True, interpret=True,
                  spmd_kernels=spmd_kernels)
    if a_sparsity is not None:
        refkw["a_sparsity"] = kw["a_sparsity"] = a_sparsity
    ref_tokens, ref_mode, ref_hist = _reference(
        api, params, (arch, sparse, chunk, a_sparsity, kernels), n_req,
        chunk, **refkw)
    assert len(ref_hist) == 1, "mid-run mode flip would break the replay"
    eng = MeshServeEngine(api, params, mesh=serve_mesh(mesh_spec),
                          num_slots=4, cache_len=16, decode_chunk=chunk,
                          **kw)
    reset_kernel_dispatch()
    outs = eng.run(_trace(cfg, n_req))
    assert eng.mode == ref_mode
    if expect_mode is not None:
        assert eng.mode == expect_mode
    got = {r: o.tokens for r, o in outs.items()}
    assert got == ref_tokens, (arch, mesh_spec, sparse, chunk)
    if eng.mesh.size > 1 and kernels:
        # the real-kernel regression gate (acceptance criterion): the
        # sharded run must have traced through shard_map'd Pallas kernels,
        # never the decompaction oracle — or exactly the reverse when the
        # fallback is forced
        counts = kernel_dispatch_counts()
        hot, cold = (("shard_map", "spmd_oracle") if spmd_kernels
                     else ("spmd_oracle", "shard_map"))
        assert counts.get(hot, 0) > 0 and counts.get(cold, 0) == 0, \
            (mesh_spec, spmd_kernels, counts)
    if eng.mesh.size > 1:
        # the run must actually have been sharded: at least one param leaf
        # and one arena leaf carry a non-trivial spec
        def axes(tree):
            return {ax for leaf in jax.tree.leaves(tree)
                    for entry in leaf.sharding.spec if entry is not None
                    for ax in ((entry,) if isinstance(entry, str)
                               else tuple(entry))}
        assert "model" in axes(eng.params), "no param leaf is model-sharded"
        assert axes(eng.cache), "no arena leaf is sharded"
    return eng


@pytest.mark.tier2
@pytest.mark.mesh
@_needs_devices(8)
@pytest.mark.parametrize("mesh_spec", ["1x2", "2x2", "2x4"])
@pytest.mark.parametrize("sparse", [False, True], ids=["dense", "sparseB"])
@pytest.mark.parametrize("chunk", [1, 3])
def test_mesh_parity_matrix(mesh_spec, sparse, chunk):
    """Tokens identical to the single-device engine across mesh shapes,
    weight representations and chunk lengths (acceptance criterion)."""
    _mesh_parity("llama3.2-1b", mesh_spec, sparse, chunk)


@pytest.mark.tier2
@pytest.mark.mesh
@_needs_devices(8)
@pytest.mark.parametrize("mode", list(Mode), ids=[m.value for m in Mode])
def test_mesh_parity_all_four_modes_2x4(mode):
    """Each execution Mode's jit set serves token-identically under
    sharding — through the shard_map'd real kernels (``kernels=True``
    makes ``_mesh_parity`` assert the dispatch counter per Mode):
    declared activation sparsity drives DENSE->A and B->AB exactly as in
    core.hybrid.select_mode."""
    sparse = mode in (Mode.B, Mode.AB)
    a = 0.9 if mode in (Mode.A, Mode.AB) else None
    eng = _mesh_parity("llama3.2-1b", "2x4", sparse, chunk=3, a_sparsity=a,
                       expect_mode=mode, kernels=True)
    assert [m for _, m in eng.mode_history] == [mode]


@pytest.mark.tier2
@pytest.mark.mesh
@_needs_devices(8)
def test_mesh_fallback_forced_parity():
    """``spmd_kernels=False`` retires the shard_map path back to the
    decompaction oracle, which must stay token-exact too — the CI smoke
    that keeps the parity baseline alive (launch/serve.py
    --spmd-fallback)."""
    _mesh_parity("llama3.2-1b", "2x4", sparse=True, chunk=3,
                 expect_mode=Mode.B, spmd_kernels=False)


@pytest.mark.tier2
@pytest.mark.mesh
@_needs_devices(8)
@pytest.mark.parametrize("arch", ["xlstm-1.3b", "whisper-large-v3",
                                  "mixtral-8x7b", "recurrentgemma-9b"])
def test_mesh_parity_families_2x2(arch):
    """Every registry family — including the rglru hybrid, whose GEMMs
    joined the griffin_linear substrate with this PR — serves
    token-identically on a 2x2 mesh."""
    _mesh_parity(arch, "2x2", sparse=False, chunk=3, n_req=3)


@pytest.mark.tier2
@pytest.mark.mesh
@_needs_devices(8)
def test_mesh_sync_budget_survives_sharding():
    """Sharding must not add host syncs: the fused-chunk budget of
    DESIGN.md Section 9 holds on the mesh for a chunk-sustaining trace."""
    cfg = get_config("llama3.2-1b").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    reqs = synthetic_trace(cfg, num_requests=6, seed=1,
                           prompt_lens=(8, 12), gen_lens=(12, 16, 24),
                           arrival_every=1)
    ref = ServeEngine(api, params, num_slots=4, cache_len=48,
                      decode_chunk=8)
    refout = ref.run([dataclasses.replace(r) for r in reqs])
    eng = MeshServeEngine(api, params, mesh=serve_mesh("2x4"), num_slots=4,
                          cache_len=48, decode_chunk=8)
    out = eng.run(reqs)
    assert {r: o.tokens for r, o in out.items()} == \
        {r: o.tokens for r, o in refout.items()}
    assert eng.stats["host_syncs"] == ref.stats["host_syncs"]
    assert eng.stats["host_syncs"] / eng.stats["emitted"] <= 0.25
