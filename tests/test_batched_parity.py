"""Bit-exact parity of the batched evaluation engine with the scalar path.

The batched engine (stacked-config scheduler, batched GEMM/network/category
evaluation, sweep driver) must reproduce the per-design scalar results
*exactly* — same integers out of the scheduler, same floats out of the
speedup chain — across every architecture family: Sparse.A / Sparse.B /
Sparse.AB (two-stage), joint (TensorDash-style, no preprocessing), SparTen
and the dense baseline, plus hybrid morphing.
"""
import numpy as np
import pytest

from repro.core import CoreConfig, GRIFFIN, Mode
from repro.core.dse import score, sweep
from repro.core.evaluate import (GemmShape, MaskModel, Workload, gemm_cycles,
                                 gemm_cycles_batched, network_speedup,
                                 network_speedup_batched)
from repro.core.hybrid import (category_design_speedup,
                               category_design_speedup_batched)
from repro.core.scheduler import (schedule, schedule_batched,
                                  static_pack_cycles,
                                  static_pack_cycles_batched)
from repro.core.spec import (DENSE_BASELINE, SPARSE_A_STAR, SPARSE_AB_STAR,
                             SPARSE_B_STAR, SPARTEN_AB, TDASH_AB, sparse_a,
                             sparse_ab, sparse_b)

CORE = CoreConfig()

WINDOW_CONFIGS = [(0, 0, 0, False), (2, 1, 0, False), (4, 0, 2, True),
                  (1, 2, 1, True), (8, 3, 2, False), (3, 0, 0, True),
                  (15, 0, 0, False)]


def _stacked(cfgs, tiles_per_cfg, mask):
    big = np.concatenate([mask] * len(cfgs), axis=0)
    rep = lambda i: np.repeat([c[i] for c in cfgs], tiles_per_cfg)
    return big, rep(0), rep(1), rep(2), rep(3)


def test_schedule_batched_matches_scalar_per_config():
    mask = np.random.default_rng(7).random((5, 23, 8, 3)) < 0.35
    big, d1, d2, d3, sh = _stacked(WINDOW_CONFIGS, 5, mask)
    out = schedule_batched(big, d1, d2, d3, shuffle=sh, record=True)
    for i, (a, b, c, s) in enumerate(WINDOW_CONFIGS):
        ref = schedule(mask, a, b, c, shuffle=s, record=True)
        sl = slice(5 * i, 5 * (i + 1))
        np.testing.assert_array_equal(ref.cycles, out.cycles[sl])
        np.testing.assert_array_equal(ref.cyc, out.cyc[sl])
        np.testing.assert_array_equal(ref.lane, out.lane[sl])
        np.testing.assert_array_equal(ref.grp, out.grp[sl])


def test_schedule_batched_compaction_parity():
    """Rows finishing at wildly different cycles exercise the retire path."""
    dens = np.linspace(0.02, 0.9, 200)[:, None, None, None]
    mask = np.random.default_rng(5).random((200, 40, 16, 2)) < dens
    for cfg in [(1, 0, 0, False), (4, 1, 1, True)]:
        ref = schedule(mask, *cfg[:3], shuffle=cfg[3], record=True)
        out = schedule_batched(mask, *cfg[:3], shuffle=cfg[3], record=True)
        np.testing.assert_array_equal(ref.cycles, out.cycles)
        np.testing.assert_array_equal(ref.cyc, out.cyc)


def test_schedule_batched_t_len_matches_truncated_streams():
    rng = np.random.default_rng(1)
    lens = rng.integers(1, 24, size=150)
    rows = np.random.default_rng(2).random((150, 23, 8, 2)) < 0.3
    rows &= (np.arange(23)[None, :] < lens[:, None])[:, :, None, None]
    out = schedule_batched(rows, 2, 1, 0, t_len=lens)
    for i in range(150):
        ref = schedule(rows[i:i + 1, :lens[i]], 2, 1, 0)
        assert out.cycles[i] == ref.cycles[0]


def test_static_pack_batched_matches_scalar_per_config():
    mask = np.random.default_rng(9).random((11, 48, 16, 2)) < 0.2
    cfgs = WINDOW_CONFIGS
    out = static_pack_cycles_batched(
        mask, [c[0] for c in cfgs], [c[1] for c in cfgs],
        [c[2] for c in cfgs], [c[3] for c in cfgs])
    for i, (a, b, c, s) in enumerate(cfgs):
        np.testing.assert_array_equal(
            out[i], static_pack_cycles(mask, a, b, c, shuffle=s))


SPECS = [SPARSE_B_STAR, sparse_b(2, 1, 0), SPARSE_A_STAR, sparse_a(1, 0, 1),
         SPARSE_AB_STAR, sparse_ab(1, 1, 0, 3, 0, 2), TDASH_AB, SPARTEN_AB,
         DENSE_BASELINE]


@pytest.mark.parametrize("mode", [Mode.A, Mode.B, Mode.AB, Mode.DENSE])
def test_gemm_cycles_batched_parity_all_modes(mode):
    mm = MaskModel()
    rng = np.random.default_rng(3)
    a_mask = mm.act_mask(32, 128, 0.5, rng)
    b_mask = mm.weight_mask(128, 48, 0.25, rng)
    batched = gemm_cycles_batched(SPECS, mode, a_mask, b_mask, CORE,
                                  np.random.default_rng(7))
    for spec, got in zip(SPECS, batched):
        ref = gemm_cycles(spec, mode, a_mask, b_mask, CORE,
                          np.random.default_rng(7))
        assert (ref.dense, ref.sparse) == (got.dense, got.sparse), spec.label()


TINY_WL = Workload("tiny", (GemmShape(24, 96, 40), GemmShape(8, 64, 32),
                            GemmShape(16, 48, 16, b_static=False)),
                   a_sparsity=0.5, b_sparsity=0.8)


def test_network_speedup_batched_parity():
    specs = [SPARSE_B_STAR, SPARSE_A_STAR, SPARSE_AB_STAR, TDASH_AB,
             DENSE_BASELINE]
    got = network_speedup_batched(specs, TINY_WL, CORE, seed=11)
    for spec, g in zip(specs, got):
        assert network_speedup(spec, TINY_WL, CORE, seed=11) == g, spec.label()


def test_category_design_speedup_batched_handles_hybrids():
    designs = [GRIFFIN, SPARSE_AB_STAR, SPARTEN_AB]
    for mode in (Mode.B, Mode.A, Mode.AB):
        got = category_design_speedup_batched(designs, [TINY_WL], CORE,
                                              seed=4, mode=mode)
        for d, g in zip(designs, got):
            assert category_design_speedup(d, [TINY_WL], CORE, seed=4,
                                           mode=mode) == g


def test_sweep_rows_match_score():
    designs = [SPARSE_B_STAR, GRIFFIN]
    rows = sweep(designs, Mode.B, CORE, seed=1)
    assert rows == [score(d, Mode.B, CORE, seed=1) for d in designs]


def test_jax_backend_matches_numpy():
    pytest.importorskip("jax")
    mask = np.random.default_rng(11).random((6, 19, 8, 3)) < 0.3
    for (d1, d2, d3, sh) in [(0, 0, 0, False), (2, 1, 0, False),
                             (4, 0, 2, True)]:
        ref = schedule(mask, d1, d2, d3, shuffle=sh).cycles
        got = schedule_batched(mask, d1, d2, d3, shuffle=sh,
                               backend="jax").cycles
        np.testing.assert_array_equal(ref, got)


def test_jax_backend_rejects_heterogeneous_configs():
    pytest.importorskip("jax")
    mask = np.zeros((2, 4, 8, 1), dtype=bool)
    with pytest.raises(ValueError):
        schedule_batched(mask, [1, 2], 0, 0, backend="jax")
    with pytest.raises(ValueError):
        schedule_batched(mask, 1, 0, 0, record=True, backend="jax")
