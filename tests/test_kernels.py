"""Pallas kernels vs pure-jnp oracles: shape/dtype/sparsity sweeps.

All kernels run in interpret mode (CPU) with the same BlockSpec logic that
targets TPU.  The hypothesis shape/sparsity sweep lives in
``tests/test_properties.py`` (guarded with ``pytest.importorskip`` —
hypothesis is an optional [test] dependency).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import (balance_columns, dense_matmul, griffin_matmul,
                           preprocess_weights, stack_weights)
from repro.kernels.dense_gemm.ref import dense_matmul_ref
from repro.kernels.griffin_spmm.ref import griffin_spmm_ref
from repro.sparsity import block_prune, magnitude_prune, sparsity_of


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(8, 16, 8), (48, 96, 80), (33, 70, 17),
                                   (128, 256, 128)])
def test_dense_matmul_matches_ref(dtype, shape):
    m, k, n = shape
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.randn(m, k), dtype=dtype)
    b = jnp.asarray(rng.randn(k, n), dtype=dtype)
    out = dense_matmul(a, b, interpret=True)
    ref = dense_matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("balance", [False, True])
@pytest.mark.parametrize("dual", [False, True])
def test_griffin_spmm_matches_ref(dtype, balance, dual):
    rng = np.random.RandomState(1)
    m, k, n = 32, 128, 96
    w = jnp.asarray(rng.randn(k, n), dtype=jnp.float32)
    w = block_prune(w, 0.6, block_k=16, unit=8).astype(dtype)
    gw = preprocess_weights(np.asarray(w.astype(jnp.float32)), block_k=16,
                            block_n=32, unit=8, balance=balance)
    gw.b_comp = gw.b_comp.astype(dtype)
    a = jnp.asarray(rng.randn(m, k), dtype=dtype)
    out = griffin_matmul(a, gw, dual=dual, interpret=True)
    ref = griffin_spmm_ref(a, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_dual_skips_zero_a_blocks_exactly():
    """Dual mode must be bit-identical: skipped A blocks are exact zeros."""
    rng = np.random.RandomState(2)
    a = rng.randn(16, 64).astype(np.float32)
    a[:, 16:48] = 0                       # two all-zero K blocks
    w = block_prune(jnp.asarray(rng.randn(64, 32).astype(np.float32)),
                    0.5, block_k=16, unit=8)
    gw = preprocess_weights(np.asarray(w), block_k=16, block_n=16, unit=8,
                            balance=False)
    out_b = griffin_matmul(jnp.asarray(a), gw, dual=False, interpret=True)
    out_ab = griffin_matmul(jnp.asarray(a), gw, dual=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_b), np.asarray(out_ab))


def test_balancing_reduces_grid_depth_on_clustered_patterns():
    """Channel-clustered pruning (the realistic case, cf. MaskModel) gives
    the shuffle analogue something to balance."""
    rng = np.random.RandomState(3)
    k, n, bk, bn, unit = 256, 256, 16, 64, 16
    # half the unit-columns share pattern P1, half share P2
    p1 = rng.rand(k // bk) < 0.3
    p2 = rng.rand(k // bk) < 0.3
    w = np.zeros((k, n), np.float32)
    for u in range(n // unit):
        pat = p1 if u % 2 == 0 else p2
        for kb in range(k // bk):
            if pat[kb]:
                w[kb * bk:(kb + 1) * bk, u * unit:(u + 1) * unit] = \
                    rng.randn(bk, unit)
    gw_off = preprocess_weights(w, block_k=bk, block_n=bn, unit=unit,
                                balance=False)
    gw_on = preprocess_weights(w, block_k=bk, block_n=bn, unit=unit,
                               balance=True)
    assert gw_on.kidx.shape[1] <= gw_off.kidx.shape[1]
    a = rng.randn(8, k).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(griffin_matmul(jnp.asarray(a), gw_on, interpret=True)),
        a @ w, rtol=2e-4, atol=2e-4)


def test_pruning_hits_target_sparsity():
    rng = np.random.RandomState(4)
    w = jnp.asarray(rng.randn(128, 96).astype(np.float32))
    assert abs(float(sparsity_of(magnitude_prune(w, 0.8))) - 0.8) < 0.02
    wb = block_prune(w, 0.75, block_k=32, unit=16)
    assert 0.6 < float(sparsity_of(wb)) < 0.9


# ---------------------------------------------------------------------------
# GriffinWeights container: stacking, slicing under jit, density memo
# ---------------------------------------------------------------------------

def _toy_gw(seed, k=64, n=64, density=0.4, bk=16, bn=32):
    rng = np.random.RandomState(seed)
    w = rng.randn(k, n).astype(np.float32)
    mask = rng.rand(k // bk, n // 8) < density
    w *= np.repeat(np.repeat(mask, bk, 0), 8, 1)
    return w, preprocess_weights(w, block_k=bk, block_n=bn, unit=8,
                                 balance=False)


def test_stack_weights_clamp_padding_and_parity():
    """Members with shallower grids pad kidx by clamp-repeating the last
    block id with zero data, so the padded tail multiplies by zeros —
    each stacked slice stays numerically identical to its source."""
    w0, g0 = _toy_gw(0, density=0.2)
    w1, g1 = _toy_gw(1, density=0.9)      # deeper grid: forces padding of g0
    assert g0.kidx.shape[-1] < g1.kidx.shape[-1]
    stacked = stack_weights([g0, g1])
    max_cnt = g1.kidx.shape[-1]
    assert stacked.kidx.shape == (2, g0.kidx.shape[0], max_cnt)
    assert stacked.b_comp.shape[1] == max_cnt * g0.block_k
    # clamp padding: dead kidx entries repeat the member's last id ...
    pad = np.asarray(stacked.kidx[0, :, g0.kidx.shape[-1]:])
    last = np.asarray(g0.kidx[:, -1])
    assert (pad == last[:, None]).all()
    # ... and the padded b_comp rows are exact zeros
    assert not np.asarray(
        stacked.b_comp[0, g0.b_comp.shape[0]:, :]).any()
    # cnt is NOT padded: the kernel walks only the live prefix
    np.testing.assert_array_equal(np.asarray(stacked.cnt[0]),
                                  np.asarray(g0.cnt))
    a = np.random.RandomState(7).randn(8, 64).astype(np.float32)
    for i, w in enumerate((w0, w1)):
        out = griffin_matmul(jnp.asarray(a), stacked[i], interpret=True)
        np.testing.assert_allclose(np.asarray(out), a @ w,
                                   rtol=2e-4, atol=2e-4)


def test_stacked_getitem_under_jit():
    """``gw[i]`` inside a jitted fn (traced index included) must slice
    every array leaf — the layout the model stacks' ``lax.scan`` and the
    MoE per-expert loop rely on."""
    w0, g0 = _toy_gw(2)
    w1, g1 = _toy_gw(3)
    stacked = stack_weights([g0, g1])
    a = jnp.asarray(np.random.RandomState(8).randn(8, 64).astype(np.float32))

    @jax.jit
    def run(a, gw, i):
        sl = gw[i]
        return sl.b_comp.sum(), sl.kidx.shape, sl.cnt

    for i, g in enumerate((g0, g1)):
        s, kshape, cnt = run(a, stacked, i)
        assert kshape[0] == g.kidx.shape[0]
        assert kshape[1] == stacked.kidx.shape[-1]
        np.testing.assert_array_equal(np.asarray(cnt), np.asarray(g.cnt))
        np.testing.assert_allclose(float(s), float(jnp.sum(g.b_comp)),
                                   rtol=1e-6)
    # concrete slicing composes with execution
    out = griffin_matmul(a, stacked[1], interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a) @ w1,
                               rtol=2e-4, atol=2e-4)


def test_density_memoized_without_pytree_leakage():
    _, gw = _toy_gw(4)
    d = gw.density
    assert "_density_memo" in gw.__dict__ and gw.__dict__[
        "_density_memo"] == d
    assert gw.density == d                       # second read hits the memo
    # flatten/unflatten rebuilds from registered fields only: the copy must
    # not inherit the memo, and must recompute the same value lazily
    leaves, treedef = jax.tree.flatten(gw)
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert "_density_memo" not in rebuilt.__dict__
    assert rebuilt.density == d
    # a tree-mapped copy with different cnt data recomputes, not inherits
    halved = jax.tree.unflatten(treedef, leaves)
    halved.cnt = halved.cnt // 2
    assert halved.density < d
