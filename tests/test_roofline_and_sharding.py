"""Roofline analysis plumbing + sharding rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.roofline.analysis import (CostSample, collective_bytes,
                                     extrapolate, model_flops_for,
                                     roofline_terms)
from repro.runtime.sharding import (batch_spec, cache_spec, dp_axes,
                                    param_spec, shard_params)


HLO = """
ENTRY %main {
  %ag = bf16[4,128]{1,0} all-gather(bf16[2,128]{1,0} %x), dimensions={0}
  %ar = f32[16]{0} all-reduce(f32[16]{0} %y), to_apply=%sum
  %rs = f32[8]{0} reduce-scatter(f32[16]{0} %z), dimensions={0}
  %cp = bf16[2,2]{1,0} collective-permute(bf16[2,2]{1,0} %w)
  %aa = s8[64]{0} all-to-all(s8[64]{0} %v), dimensions={0}
  %dot = f32[4,4]{1,0} dot(f32[4,8]{1,0} %a, f32[8,4]{1,0} %b)
}
"""


def test_collective_bytes_parses_operands():
    cb = collective_bytes(HLO)
    assert cb["all-gather"] == 2 * 128 * 2
    assert cb["all-reduce"] == 16 * 4
    assert cb["reduce-scatter"] == 16 * 4
    assert cb["collective-permute"] == 2 * 2 * 2
    assert cb["all-to-all"] == 64
    assert "dot" not in cb


def test_extrapolation_is_linear():
    f1 = CostSample(flops=10.0, bytes_accessed=100.0, coll={"all-reduce": 5.0})
    f2 = CostSample(flops=14.0, bytes_accessed=120.0, coll={"all-reduce": 7.0})
    tot = extrapolate(f1, f2, 11)
    assert tot.flops == 10 + 10 * 4
    assert tot.bytes_accessed == 100 + 10 * 20
    assert tot.coll["all-reduce"] == 5 + 10 * 2


def test_roofline_terms_and_dominant():
    c = CostSample(flops=197e12, bytes_accessed=819e9 * 2, coll={"x": 50e9 * 3})
    t = roofline_terms(c, model_flops=197e12 * 256 * 0.5, chips=256)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(2.0)
    assert t.collective_s == pytest.approx(3.0)
    assert t.dominant == "collective"
    assert t.useful_ratio == pytest.approx(0.5)
    assert t.roofline_fraction == pytest.approx(0.5 / 3.0)


def test_model_flops_conventions():
    assert model_flops_for("train", 1e9, 4, 128) == 6e9 * 512
    assert model_flops_for("prefill", 1e9, 4, 128) == 2e9 * 512
    assert model_flops_for("decode", 1e9, 4, 128) == 2e9 * 4


@pytest.fixture(scope="module")
def mesh():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def test_param_spec_rules(mesh):
    wq = jax.ShapeDtypeStruct((4, 64, 128), jnp.bfloat16)
    assert param_spec("['layers']['wq']", wq, mesh) == P(None, "data", "model")
    wo = jax.ShapeDtypeStruct((4, 128, 64), jnp.bfloat16)
    assert param_spec("['layers']['wo']", wo, mesh) == P(None, "model", "data")
    emb = jax.ShapeDtypeStruct((1000, 64), jnp.bfloat16)
    assert param_spec("['embed']", emb, mesh) == P("model", "data")
    ln = jax.ShapeDtypeStruct((64,), jnp.bfloat16)
    assert param_spec("['ln1']", ln, mesh) == P()


def test_param_spec_drops_nondivisible():
    dev = np.array(jax.devices() * 1)[:1].reshape(1, 1)
    m = Mesh(dev, ("data", "model"))
    # with axis size 1 everything divides; simulate non-divisible via a
    # fake mesh shape by checking the _checked logic through param_spec on
    # size-1 axes (always divisible) — structural check only
    w = jax.ShapeDtypeStruct((3, 2730), jnp.float32)
    spec = param_spec("['w_ff1']", w, m)
    assert spec == P("data", "model") or spec == P(None, "model")


def test_batch_spec_falls_back_to_seq(mesh):
    # dp_axes returns an axis tuple (multi-axis dp), so a dim's entry may be
    # the bare name or a 1-tuple of it — both mean the same sharding
    tok = jax.ShapeDtypeStruct((8, 64), jnp.int32)
    assert batch_spec(tok, mesh)[0] in ("data", ("data",))
    tiny = jax.ShapeDtypeStruct((1, 64), jnp.int32)
    spec = batch_spec(tiny, mesh)
    assert spec[0] in (None, "data", ("data",))  # seq fallback when dp > 1


def test_cache_spec_shards_batch_and_seq(mesh):
    kv = jax.ShapeDtypeStruct((16, 8, 4096, 8, 64), jnp.bfloat16)
    spec = cache_spec("['k']", kv, mesh, batch=8)
    assert spec[1] in ("data", ("data",))        # batch dim
    # model axis size 1 -> no model sharding placed
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    assert cache_spec("['pos']", pos, mesh, batch=8) == P()


def test_dryrun_cells_enumeration():
    import importlib
    dr = importlib.import_module("repro.launch.dryrun")
    cells = dr.all_cells()
    assert len(cells) == 40
    skips = [c for c in cells if c[2] is not None]
    assert len(skips) == 7               # full-attention archs x long_500k
    assert all(c[1] == "long_500k" for c in skips)
