"""Sparse.A kernel vs ref.py oracle and vs dense, on random block masks.

Covers both metadata regimes (DESIGN.md Section 5): concrete activations
(numpy metadata, physically compacted grid) and traced activations inside
jit (jnp metadata, full-depth predicated fallback).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import compact_activations, dense_matmul, sparse_a_matmul
from repro.kernels.sparse_a.ref import sparse_a_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-5)


def _sparse_a(rng, m, k, bm, bk, sparsity, dtype):
    """Activations with randomly zeroed (bm x bk) blocks."""
    a = rng.randn(m, k).astype(np.float32)
    pm, pk = -(-m // bm) * bm, -(-k // bk) * bk
    mask = rng.rand(pm // bm, pk // bk) >= sparsity
    for i in range(pm // bm):
        for j in range(pk // bk):
            if not mask[i, j]:
                a[i * bm:(i + 1) * bm, j * bk:(j + 1) * bk] = 0
    return jnp.asarray(a, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sparsity", [0.0, 0.4, 0.8])
@pytest.mark.parametrize("shape", [(16, 64, 32), (33, 70, 17)])
def test_sparse_a_matches_ref_and_dense(dtype, sparsity, shape):
    m, k, n = shape
    rng = np.random.RandomState(0)
    a = _sparse_a(rng, m, k, 16, 16, sparsity, dtype)
    w = jnp.asarray(rng.randn(k, n), dtype)
    out = sparse_a_matmul(a, w, block_m=16, block_k=16, block_n=16,
                          interpret=True)
    ref = sparse_a_ref(a, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))
    dense = dense_matmul(a, w, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(dense, np.float32), **_tol(dtype))


def test_concrete_metadata_compacts_the_grid():
    rng = np.random.RandomState(1)
    a = _sparse_a(rng, 32, 128, 16, 16, 0.7, jnp.float32)
    meta = compact_activations(a, block_m=16, block_k=16)
    assert meta.compaction < 1.0          # grid physically shrank
    assert 0.0 < meta.density < 1.0
    w = jnp.asarray(rng.randn(128, 48), jnp.float32)
    out = sparse_a_matmul(a, w, meta=meta, block_n=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a) @ np.asarray(w),
                               rtol=1e-5, atol=1e-5)


def test_traced_metadata_full_depth_parity():
    """Inside jit, metadata falls back to static full K depth but the
    result is identical (skipped blocks are exact zeros)."""
    rng = np.random.RandomState(2)
    a = _sparse_a(rng, 32, 64, 16, 16, 0.5, jnp.float32)
    w = jnp.asarray(rng.randn(64, 32), jnp.float32)

    f = jax.jit(lambda a, w: sparse_a_matmul(
        a, w, block_m=16, block_k=16, block_n=16, interpret=True))
    out_jit = f(a, w)
    out_eager = sparse_a_matmul(a, w, block_m=16, block_k=16, block_n=16,
                                interpret=True)
    np.testing.assert_array_equal(np.asarray(out_jit), np.asarray(out_eager))
    meta = compact_activations(jnp.asarray(a), block_m=16, block_k=16)
    # traced metadata cannot shrink: verify via the jit-built meta shape
    traced_meta = jax.eval_shape(
        lambda x: compact_activations(x, block_m=16, block_k=16).kidx, a)
    assert traced_meta.shape[1] == 64 // 16          # full depth
    assert meta.kidx.shape[1] <= traced_meta.shape[1]


def test_all_zero_activations():
    a = jnp.zeros((16, 32), jnp.float32)
    w = jnp.asarray(np.random.RandomState(3).randn(32, 16), jnp.float32)
    out = sparse_a_matmul(a, w, block_m=16, block_k=16, block_n=16,
                          interpret=True)
    np.testing.assert_array_equal(np.asarray(out), 0.0)
    meta = compact_activations(a, block_m=16, block_k=16)
    assert int(np.asarray(meta.cnt).sum()) == 0
    assert meta.kidx.shape[1] == 1                   # minimal padded depth
