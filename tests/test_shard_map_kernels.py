"""Shard-parity tier: the real Pallas kernels under SPMD via shard_map
(DESIGN.md Section 10).

The serving layout never splits a GEMM contraction dim, so each device's
share of every matmul is fully local and the kernels run under
``jax.experimental.shard_map`` with zero in-kernel collectives.  Two
tiers, mirroring tests/test_mesh_serve.py:

  - tier-1 (unmarked, runs on one device): the *decomposition laws* the
    shard_map paths rely on — running a shard-local kernel entry
    (``griffin_matmul_shard`` / ``sparse_a_matmul_shard`` /
    ``dense_matmul_shard``) on each manually-cut N-slice and
    concatenating must be bit-equal to the unsharded kernel — plus the
    shard-spec/shardability predicates and the 1x1-mesh degenerate case.

  - mesh-marked (skip below 8 devices, run by the CI ``sharded`` job and
    any ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` tier-1
    invocation): the shard_map'd ops on real {1x2, 2x2, 2x4} meshes must
    be bit-equal to the unsharded kernels and allclose to the
    decompaction oracle, and ``griffin_linear`` under a ``spmd_mesh``
    scope must take the shard_map path (KERNEL_DISPATCH counter) for all
    four execution Modes — with ``spmd_kernels=False`` retiring it to
    the oracle.

Bitwise (not allclose) kernel parity holds because a shard runs the same
per-tile fp32 accumulation as the unsharded kernel over the same K
blocks in the same order; only the oracle (a plain jnp dot over the
decompacted matrix) reduces in a different order.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.spec import Mode
from repro.kernels.dense_gemm import ops as dense_ops
from repro.kernels.griffin_spmm import ops as spmm_ops
from repro.kernels.sparse_a import ops as sparse_a_ops
from repro.models.common import (griffin_linear, kernel_dispatch_counts,
                                 reset_kernel_dispatch, sparse_execution)
from repro.runtime.sharding import (gemm_shard_specs, kernel_shardable,
                                    spmm_shard_specs)
from repro.sparsity.pruning import block_prune

BLK = dict(block_k=16, block_n=16, unit=8)      # reduced-config granularity


def _needs_devices(n: int):
    return pytest.mark.skipif(
        len(jax.devices()) < n,
        reason=f"needs {n} devices (export XLA_FLAGS="
               "--xla_force_host_platform_device_count=8)")


def _mesh(spec: str):
    from repro.launch.mesh import serve_mesh
    return serve_mesh(spec)


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed)
                       .standard_normal(shape).astype(np.float32))


def _sparse_rows(shape, seed=1):
    """Activations with whole zero K-blocks (the Sparse.A workload)."""
    a = np.asarray(_rand(shape, seed)).copy()
    a[:, shape[1] // 4: 3 * shape[1] // 4] = 0.0
    return jnp.asarray(a)


def _gw(k=64, n=128, seed=2, balance=True):
    w = block_prune(_rand((k, n), seed), 0.6, BLK["block_k"], BLK["unit"])
    return spmm_ops.preprocess_weights(np.asarray(w), balance=balance, **BLK)


# ---------------------------------------------------------------------------
# tier-1: specs and shardability predicates
# ---------------------------------------------------------------------------

def test_shard_spec_reexports_are_the_kernel_specs():
    """runtime.sharding's view of the per-shard operand layout must be the
    kernel packages' own definition — one source of truth for dispatch,
    layout rules and tests."""
    assert spmm_shard_specs() == spmm_ops.shard_specs()
    assert gemm_shard_specs() == sparse_a_ops.shard_specs()
    from jax.sharding import PartitionSpec as P
    in_specs, out_spec = spmm_ops.shard_specs("model")
    # activations replicated; b_comp split on padded-N; kidx/cnt on the
    # N-tile axis; output on N
    assert in_specs == (P(), P(None, "model"), P("model", None), P("model"))
    assert out_spec == P(None, "model")
    in_specs, out_spec = sparse_a_ops.shard_specs("model")
    # per-M-tile runtime metadata replicates — an output split never
    # touches which A blocks are live
    assert in_specs == (P(), P(None, "model"), P(), P())
    assert out_spec == P(None, "model")


def test_shardable_predicates():
    gw = _gw(n=128)                              # 8 N tiles of 16
    assert spmm_ops.shardable(gw, 1)
    assert spmm_ops.shardable(gw, 2)
    assert spmm_ops.shardable(gw, 4)
    assert not spmm_ops.shardable(gw, 3)         # tiles must split evenly
    stacked = spmm_ops.stack_weights([gw, _gw(n=128, seed=3)])
    assert not spmm_ops.shardable(stacked, 2)    # engine slices per layer
    w = _rand((64, 96))
    for ops in (dense_ops, sparse_a_ops):
        assert ops.shardable(w, 2) and ops.shardable(w, 4)
        assert not ops.shardable(w, 5)           # 96 % 5 != 0
        assert not ops.shardable(jnp.stack([w, w]), 2)


def test_kernel_shardable_leaf_predicate():
    """The layout-rule wrapper applies the right per-representation
    predicate and refuses meshes without the model axis."""
    import dataclasses

    @dataclasses.dataclass(frozen=True)
    class SpecMesh:
        shape: dict
        axis_names: tuple

    m22 = SpecMesh({"data": 2, "model": 2}, ("data", "model"))
    assert kernel_shardable(_gw(n=128), m22)
    assert kernel_shardable(_rand((64, 64)), m22)
    assert not kernel_shardable(_rand((64, 65)), m22)
    bad = SpecMesh({"x": 2}, ("x",))
    assert not kernel_shardable(_rand((64, 64)), bad)


# ---------------------------------------------------------------------------
# tier-1: decomposition laws (single device — manual N-slices)
# ---------------------------------------------------------------------------

def test_dense_shard_decomposition_law():
    """Concatenated per-shard dense kernels == the unsharded kernel,
    bitwise — including when a shard's local N forces a smaller block_n
    than the global grid used."""
    a, w = _rand((8, 64)), _rand((64, 64), seed=4)
    ref = dense_ops.dense_matmul(a, w, interpret=True)
    for shards in (2, 4):
        n_loc = w.shape[1] // shards
        parts = [dense_ops.dense_matmul_shard(
                     a, w[:, s * n_loc:(s + 1) * n_loc],
                     block_m=128, block_n=128, block_k=128, interpret=True)
                 for s in range(shards)]
        np.testing.assert_array_equal(np.asarray(jnp.concatenate(parts, 1)),
                                      np.asarray(ref))


def test_sparse_a_shard_decomposition_law():
    """Per-shard sparse_a kernels under one shared (replicated) metadata
    == the unsharded kernel, bitwise: the M-tile compaction is invariant
    to the output split."""
    a, w = _sparse_rows((8, 64)), _rand((64, 64), seed=5)
    meta = sparse_a_ops.compact_activations(a, block_m=128, block_k=128)
    ref = sparse_a_ops.sparse_a_matmul(a, w, interpret=True)
    for shards in (2, 4):
        n_loc = w.shape[1] // shards
        parts = [sparse_a_ops.sparse_a_matmul_shard(
                     a, w[:, s * n_loc:(s + 1) * n_loc], meta.kidx, meta.cnt,
                     block_m=meta.block_m, block_k=meta.block_k,
                     block_n=128, interpret=True)
                 for s in range(shards)]
        np.testing.assert_array_equal(np.asarray(jnp.concatenate(parts, 1)),
                                      np.asarray(ref))


@pytest.mark.parametrize("dual", [False, True], ids=["B", "AB"])
@pytest.mark.parametrize("balance", [False, True],
                         ids=["plain", "balanced"])
def test_griffin_shard_decomposition_law(dual, balance):
    """A contiguous group of N tiles with its own metadata rows is a
    complete kernel problem: per-shard ``griffin_matmul_shard`` calls on
    manual slices, concatenated and globally un-permuted/unpadded, must
    be bit-equal to the unsharded kernel and allclose to the decompaction
    oracle."""
    gw = _gw(n=120, balance=balance)             # unpad [:, :n] is real
    a = _sparse_rows((8, 64)) if dual else _rand((8, 64), seed=6)
    ref = spmm_ops.griffin_matmul(a, gw, dual=dual, interpret=True)
    nt, bn = gw.kidx.shape[0], gw.block_n
    for shards in (2, 4):
        assert spmm_ops.shardable(gw, shards)
        tps = nt // shards
        parts = []
        for s in range(shards):
            sl = slice(s * tps, (s + 1) * tps)
            parts.append(spmm_ops.griffin_matmul_shard(
                a, gw.b_comp[:, s * tps * bn:(s + 1) * tps * bn],
                gw.kidx[sl], gw.cnt[sl], block_m=8, block_k=gw.block_k,
                block_n=bn, dual=dual, interpret=True))
        out = jnp.concatenate(parts, axis=1)
        if gw.inv_perm is not None:              # global column ops stay
            out = out[:, gw.inv_perm]            # with the caller
        out = out[:, :gw.n]
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # the oracle ignores A-block predication, but skipped A blocks are
    # exactly zero, so the values agree for the dual mode too
    oracle = jnp.dot(a, spmm_ops.decompact_weights(gw),
                     preferred_element_type=jnp.float32)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(oracle),
                               atol=1e-5)


def test_shard_map_1x1_mesh_is_identity():
    """mesh.size == 1: the shard_map path must reproduce the unsharded
    kernel bitwise (the degenerate cell of the parity matrix) — runnable
    on a single device."""
    mesh = _mesh("1x1")
    a, w = _rand((8, 64)), _rand((64, 64), seed=7)
    np.testing.assert_array_equal(
        np.asarray(dense_ops.dense_matmul(a, w, interpret=True, mesh=mesh)),
        np.asarray(dense_ops.dense_matmul(a, w, interpret=True)))
    sa = _sparse_rows((8, 64))
    np.testing.assert_array_equal(
        np.asarray(sparse_a_ops.sparse_a_matmul(sa, w, interpret=True,
                                                mesh=mesh)),
        np.asarray(sparse_a_ops.sparse_a_matmul(sa, w, interpret=True)))
    gw = _gw()
    np.testing.assert_array_equal(
        np.asarray(spmm_ops.griffin_matmul(a, gw, interpret=True,
                                           mesh=mesh)),
        np.asarray(spmm_ops.griffin_matmul(a, gw, interpret=True)))


# ---------------------------------------------------------------------------
# mesh-marked: real shard_map on emulated multi-device meshes
# ---------------------------------------------------------------------------

MESHES = ["1x2", "2x2", "2x4"]


@pytest.mark.mesh
@_needs_devices(8)
@pytest.mark.parametrize("spec", MESHES)
@pytest.mark.parametrize("dual", [False, True], ids=["B", "AB"])
def test_griffin_shard_map_parity(spec, dual):
    mesh = _mesh(spec)
    gw = _gw(n=128)
    a = _sparse_rows((8, 64)) if dual else _rand((8, 64), seed=8)
    ref = spmm_ops.griffin_matmul(a, gw, dual=dual, interpret=True)
    got = spmm_ops.griffin_matmul(a, gw, dual=dual, interpret=True,
                                  mesh=mesh)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    oracle = spmm_ops.griffin_matmul(a, gw, dual=dual, spmd=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(oracle),
                               atol=1e-5)


@pytest.mark.mesh
@_needs_devices(8)
@pytest.mark.parametrize("spec", MESHES)
def test_dense_and_sparse_a_shard_map_parity(spec):
    mesh = _mesh(spec)
    w = _rand((64, 64), seed=9)
    a, sa = _rand((8, 64), seed=10), _sparse_rows((8, 64))
    np.testing.assert_array_equal(
        np.asarray(dense_ops.dense_matmul(a, w, interpret=True, mesh=mesh)),
        np.asarray(dense_ops.dense_matmul(a, w, interpret=True)))
    got = sparse_a_ops.sparse_a_matmul(sa, w, interpret=True, mesh=mesh)
    np.testing.assert_array_equal(
        np.asarray(got),
        np.asarray(sparse_a_ops.sparse_a_matmul(sa, w, interpret=True)))
    np.testing.assert_allclose(
        np.asarray(got),
        np.asarray(sparse_a_ops.sparse_a_matmul(sa, w, spmd=True)),
        atol=1e-5)


def _linear_case(mode):
    """(x, w, a_sparsity) driving griffin_linear into ``mode``."""
    if mode in (Mode.B, Mode.AB):
        w = _gw(n=128)
    else:
        w = _rand((64, 128), seed=11)
    sparse_a = mode in (Mode.A, Mode.AB)
    x = _sparse_rows((8, 64)) if sparse_a else _rand((8, 64), seed=12)
    return x, w, (0.9 if sparse_a else 0.0)


@pytest.mark.mesh
@_needs_devices(8)
@pytest.mark.parametrize("mode", list(Mode), ids=[m.value for m in Mode])
def test_griffin_linear_shard_map_all_modes_2x4(mode):
    """Every execution Mode's GEMM goes through the shard_map'd real
    kernel (dispatch counter), bit-equal to the single-device kernel."""
    mesh = _mesh("2x4")
    x, w, a_sp = _linear_case(mode)
    with sparse_execution(use_kernels=True, interpret=True, a_sparsity=a_sp):
        ref = griffin_linear(x, w)
    reset_kernel_dispatch()
    with sparse_execution(use_kernels=True, interpret=True, a_sparsity=a_sp,
                          spmd_mesh=mesh):
        got = griffin_linear(x, w)
    counts = kernel_dispatch_counts()
    assert counts.get("shard_map", 0) == 1 and \
        counts.get("spmd_oracle", 0) == 0, (mode, counts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.mesh
@_needs_devices(8)
def test_griffin_linear_spmd_kernels_false_forces_oracle():
    """spmd_kernels=False retires the shard_map path: the decompaction
    oracle serves the GEMM (allclose, different reduction order) and the
    dispatch counter proves which path ran."""
    mesh = _mesh("2x4")
    x, gw = _rand((8, 64), seed=13), _gw(n=128)
    with sparse_execution(use_kernels=True, interpret=True):
        ref = griffin_linear(x, gw)
    reset_kernel_dispatch()
    with sparse_execution(use_kernels=True, spmd_mesh=mesh,
                          spmd_kernels=False):
        got = griffin_linear(x, gw)
    counts = kernel_dispatch_counts()
    assert counts.get("spmd_oracle", 0) == 1 and \
        counts.get("shard_map", 0) == 0, counts
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


@pytest.mark.mesh
@_needs_devices(8)
def test_griffin_linear_unshardable_leaf_falls_back_to_oracle():
    """A weight leaf whose N tiles do not divide the model axis cannot
    shard_map; dispatch falls back to the oracle instead of asserting."""
    mesh = _mesh("2x4")                          # mp = 4
    gw = _gw(n=48)                               # 3 N tiles: 3 % 4 != 0
    assert not spmm_ops.shardable(gw, 4)
    x = _rand((8, 64), seed=14)
    with pytest.raises(AssertionError):          # the op itself refuses
        spmm_ops.griffin_matmul(x, gw, interpret=True, mesh=mesh)
    reset_kernel_dispatch()
    with sparse_execution(use_kernels=True, spmd_mesh=mesh):
        got = griffin_linear(x, gw)
    assert kernel_dispatch_counts().get("spmd_oracle", 0) == 1
    with sparse_execution(use_kernels=True, interpret=True):
        ref = griffin_linear(x, gw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
