"""The mode-dispatched execution substrate: select_mode edges, the
GriffinWeights pytree invariants, auto_matmul four-mode dispatch,
griffin_linear model wiring, and sharding of the compacted pytree."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.hybrid import SPARSE_THRESHOLD, select_mode
from repro.core.spec import Mode
from repro.kernels import (GriffinWeights, auto_matmul, preprocess_weights,
                           stack_weights)
from repro.models.common import griffin_linear, sparse_execution
from repro.runtime.sharding import shard_params
from repro.sparsity import block_prune, sparsify_params


# ---------------------------------------------------------------------------
# select_mode threshold edges
# ---------------------------------------------------------------------------

def test_select_mode_threshold_edges():
    t = SPARSE_THRESHOLD
    assert select_mode(0.0, 0.0) == Mode.DENSE
    # the threshold itself is NOT sparse (strictly-greater comparison)
    assert select_mode(t, t) == Mode.DENSE
    eps = 1e-9
    assert select_mode(t + eps, 0.0) == Mode.A
    assert select_mode(0.0, t + eps) == Mode.B
    assert select_mode(t + eps, t + eps) == Mode.AB
    assert select_mode(1.0, 1.0) == Mode.AB
    # custom threshold moves the edge
    assert select_mode(0.3, 0.0, threshold=0.5) == Mode.DENSE
    assert select_mode(0.6, 0.0, threshold=0.5) == Mode.A


# ---------------------------------------------------------------------------
# GriffinWeights invariants
# ---------------------------------------------------------------------------

def _gw(rng, k=64, n=64, sparsity=0.5, balance=False):
    w = block_prune(jnp.asarray(rng.randn(k, n), jnp.float32), sparsity,
                    block_k=16, unit=8)
    return w, preprocess_weights(np.asarray(w), block_k=16, block_n=16,
                                 unit=8, balance=balance)


def test_density_and_compaction_invariants():
    rng = np.random.RandomState(0)
    w, gw = _gw(rng, sparsity=0.5)
    # density = surviving block fraction; compaction = padded depth fraction
    assert 0.0 < gw.density <= 1.0
    assert gw.density <= gw.compaction <= 1.0   # padding to max_cnt >= mean
    _, gw_dense = _gw(rng, sparsity=0.0)
    assert gw_dense.density == gw_dense.compaction == 1.0
    z = preprocess_weights(np.zeros((64, 64), np.float32), block_k=16,
                           block_n=16, unit=8)
    assert z.density == 0.0
    assert z.kidx.shape[-1] == 1                 # minimal padded depth


def test_griffin_weights_is_a_pytree():
    rng = np.random.RandomState(1)
    _, gw = _gw(rng, balance=True)
    leaves = jax.tree.leaves(gw)
    assert len(leaves) == 4                      # b_comp, kidx, cnt, inv_perm
    gw2 = jax.tree.map(lambda a: a, gw)
    assert isinstance(gw2, GriffinWeights)
    assert (gw2.k, gw2.n, gw2.block_k, gw2.block_n) == \
        (gw.k, gw.n, gw.block_k, gw.block_n)     # static aux survives


def test_stack_weights_pads_to_common_depth_and_slices_back():
    rng = np.random.RandomState(2)
    ws, gws = zip(*[_gw(rng, sparsity=s) for s in (0.3, 0.7)])
    stacked = stack_weights(list(gws))
    assert stacked.kidx.shape == (2,) + (gws[0].kidx.shape[0],
                                         max(g.kidx.shape[-1] for g in gws))
    for i, (w, g) in enumerate(zip(ws, gws)):
        sl = stacked[i]                          # __getitem__ slices leaves
        assert isinstance(sl, GriffinWeights)
        x = jnp.asarray(rng.randn(8, 64), jnp.float32)
        with sparse_execution(interpret=True):
            out = griffin_linear(x, sl)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# auto_matmul: all four modes dispatch and agree with the jnp product
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("a_sp,b_sp,mode", [
    (0.0, 0.0, Mode.DENSE), (0.5, 0.0, Mode.A),
    (0.0, 0.5, Mode.B), (0.5, 0.5, Mode.AB)])
def test_auto_matmul_dispatches_every_mode(a_sp, b_sp, mode):
    rng = np.random.RandomState(3)
    w, gw = _gw(rng, sparsity=0.5)
    a = rng.randn(16, 64).astype(np.float32)
    a[:, :32] = 0                                # genuinely sparse A blocks
    a = jnp.asarray(a)
    assert select_mode(a_sp, b_sp) == mode
    out = auto_matmul(a, w, gw, a_sparsity=a_sp, b_sparsity=b_sp,
                      interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ w),
                               rtol=1e-4, atol=1e-4)


def test_auto_matmul_sparse_b_declared_without_preprocessing_falls_back():
    rng = np.random.RandomState(4)
    a = jnp.asarray(rng.randn(8, 32), jnp.float32)
    w = jnp.asarray(rng.randn(32, 16), jnp.float32)
    out = auto_matmul(a, w, None, b_sparsity=0.5, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ w),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# griffin_linear wiring + sharding of the compacted pytree
# ---------------------------------------------------------------------------

def test_griffin_linear_modes_match_plain_matmul():
    rng = np.random.RandomState(5)
    w, gw = _gw(rng, sparsity=0.6)
    x = jnp.asarray(rng.randn(2, 8, 64), jnp.float32)   # leading batch dims
    # default scope: plain jnp
    np.testing.assert_array_equal(np.asarray(griffin_linear(x, w)),
                                  np.asarray(x @ w))
    # kernel scope: dense + Sparse.A kernels; compacted weights: Sparse.B/dual
    for scope in (dict(), dict(a_sparsity=0.5)):
        with sparse_execution(interpret=True, **scope):
            np.testing.assert_allclose(
                np.asarray(griffin_linear(x, w)), np.asarray(x @ w),
                rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(
                np.asarray(griffin_linear(x, gw)), np.asarray(x @ w),
                rtol=1e-4, atol=1e-4)


def test_sparsify_params_and_sharding_of_compacted_tree():
    rng = np.random.RandomState(6)
    params = {"layers": {
        "wq": jnp.asarray(rng.randn(2, 64, 64), jnp.float32),   # stacked
        "w_down": jnp.asarray(rng.randn(64, 64), jnp.float32),
        "ln1": jnp.zeros((64,), jnp.float32),
        "wi": jnp.asarray(rng.randn(64, 4), jnp.float32),       # tiny: kept
    }}
    sp = sparsify_params(params, 0.5, block_k=16, block_n=16, unit=8)
    assert isinstance(sp["layers"]["wq"], GriffinWeights)
    assert sp["layers"]["wq"].b_comp.ndim == 3          # stacked leading L
    assert isinstance(sp["layers"]["w_down"], GriffinWeights)
    assert not isinstance(sp["layers"]["wi"], GriffinWeights)   # min_dim
    # dense twin carries the same values as the compacted representation
    dense_tw = sparsify_params(params, 0.5, block_k=16, block_n=16, unit=8,
                               compact=False)
    x = jnp.asarray(rng.randn(4, 64), jnp.float32)
    with sparse_execution(interpret=True):
        out = griffin_linear(x, sp["layers"]["w_down"])
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(x @ dense_tw["layers"]["w_down"]), rtol=1e-4, atol=1e-4)

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sh = shard_params(jax.eval_shape(lambda: sp), mesh)
    specs = {jax.tree_util.keystr(p): s.spec for p, s in
             jax.tree_util.tree_flatten_with_path(sh)[0]}
    # metadata replicated; b_comp shards only its output axis
    assert specs["['layers']['wq'].kidx"] == P(None, None, None)
    assert specs["['layers']['wq'].cnt"] == P(None, None)
    assert specs["['layers']['wq'].b_comp"][-1] in ("model", None)
    assert specs["['layers']['wq'].b_comp"][:-1] == (None, None)
