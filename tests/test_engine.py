"""Continuous-batching engine tests.

Three layers (cheap to slow):
  - ``jit_serve_fns`` regression on a 1-device mesh (the prefill jit must
    carry the dp logits sharding that used to be computed-then-dropped);
  - engine machinery on a trivial fake ``ModelApi`` (slot reuse, event
    attribution, prompt-boundary emission, workload-category re-selection);
  - decode/prefill parity of registry families against the batch-1
    ``greedy_generate`` oracle: engine tokens == greedy tokens == the
    prefill-logits argmax at the prompt boundary.  Dense transformer+xlstm
    run tier-1; the full four-family sweep, dense AND block-pruned-compacted
    under ``sparse_execution``, is ``tier2`` (scripts/ci.sh runs it in its
    own stage).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs import get_config
from repro.core.spec import Mode
from repro.models import ModelApi, build_model
from repro.models.common import sparse_execution
from repro.runtime.engine import (Request, Scheduler, ServeEngine,
                                  synthetic_trace, weight_sparsity)
from repro.runtime.serve import greedy_generate, jit_serve_fns
from repro.sparsity import sparsify_params

FAMILY_ARCHS = {
    "transformer": "llama3.2-1b",
    "moe": "mixtral-8x7b",
    "whisper": "whisper-large-v3",
    "xlstm": "xlstm-1.3b",
    "hybrid": "recurrentgemma-9b",
}
# rglru's weight GEMMs are plain jnp matmuls (not griffin_linear-wired), so
# sparsify_params would hand its blocks GriffinWeights they cannot execute:
# the hybrid family runs the dense parity sweep only
SPARSE_FAMILIES = sorted(f for f in FAMILY_ARCHS if f != "hybrid")
PRUNE = dict(block_k=16, block_n=16, unit=8)   # reduced dims (d_model 64)


# ---------------------------------------------------------------------------
# fake model: deterministic request-dependent next-token function
# ---------------------------------------------------------------------------

def fake_api(vocab: int = 17, zero_logits: bool = False) -> ModelApi:
    """Minimal ModelApi: cache carries a per-row running token sum; the
    next token is (state + 1) % vocab, emitted as one-hot logits (add 1.0
    everywhere when ``zero_logits=False`` so measured activation sparsity
    stays 0).  Deterministic and request-dependent, so scheduler bugs
    (wrong slot, stale cache, cross-request leaks) change the tokens."""
    base = 0.0 if zero_logits else 1.0

    def logits_of(state):
        nxt = (state[:, 0] + 1) % vocab
        return jax.nn.one_hot(nxt, vocab, dtype=jnp.float32) + base

    def init(key):
        return {"w": jnp.zeros((vocab, vocab), jnp.float32)}

    def prefill(params, batch, cache_len=None):
        toks = batch["tokens"]
        state = jnp.sum(toks, axis=-1, keepdims=True).astype(jnp.int32) % vocab
        cache = {"state": state,
                 "pos": jnp.asarray(toks.shape[1] - 1, jnp.int32)}
        return cache, logits_of(state)

    def decode_step(params, cache, token):
        state = (cache["state"] + token) % vocab
        return logits_of(state), {"state": state, "pos": cache["pos"] + 1}

    def init_cache(batch, length):
        return {"state": jnp.zeros((batch, 1), jnp.int32),
                "pos": jnp.zeros((), jnp.int32)}

    return ModelApi(cfg=get_config("llama3.2-1b").reduced(), init=init,
                    loss=lambda p, b: jnp.zeros(()), prefill=prefill,
                    decode_step=decode_step, init_cache=init_cache,
                    param_count=lambda: 0, param_count_total=lambda: 0)


def _run_greedy(api, params, req, cache_len, scope=None):
    if scope is None:
        return greedy_generate(api, params, req.as_batch(),
                               steps=req.max_new_tokens,
                               cache_len=cache_len)
    with scope:
        return greedy_generate(api, params, req.as_batch(),
                               steps=req.max_new_tokens,
                               cache_len=cache_len)


# ---------------------------------------------------------------------------
# jit_serve_fns regression (satellite: logits_sh threading)
# ---------------------------------------------------------------------------

def test_jit_serve_fns_run_on_one_device_mesh():
    cfg = get_config("llama3.2-1b").reduced()
    api = build_model(cfg)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    B, S, clen = 2, 8, 16
    prefill_jit, decode_jit, (p_sh, c_sh, logits_sh) = \
        jit_serve_fns(api, mesh, B, clen)
    params = api.init(jax.random.PRNGKey(0))
    toks = jnp.ones((B, S), jnp.int32)
    cache, logits = prefill_jit(params, {"tokens": toks})
    assert logits.shape == (B, cfg.vocab_size)
    # the dp logits sharding is threaded through the jit (it used to be
    # computed and dropped)
    assert logits.sharding.is_equivalent_to(logits_sh, logits.ndim)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    logits2, cache2 = decode_jit(params, cache, tok)
    assert logits2.shape == (B, cfg.vocab_size)
    assert logits2.sharding.is_equivalent_to(logits_sh, logits2.ndim)
    assert int(cache2["pos"]) == S


def test_jit_serve_fns_shardings_follow_compacted_params():
    """GriffinWeights trees need their own specs: p_sh built from the dense
    init shapes would broadcast the parent GEMM's spec onto the metadata."""
    cfg = get_config("llama3.2-1b").reduced()
    api = build_model(cfg)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    params = sparsify_params(api.init(jax.random.PRNGKey(0)), 0.6, **PRUNE)
    prefill_jit, _, (p_sh, _, _) = jit_serve_fns(api, mesh, 2, 16,
                                                 params=params)
    assert jax.tree.structure(p_sh) == jax.tree.structure(
        jax.tree.map(lambda x: 0, params))
    with sparse_execution(use_kernels=False, interpret=True):
        _, logits = prefill_jit(params, {"tokens": jnp.ones((2, 8),
                                                            jnp.int32)})
    assert np.isfinite(np.asarray(logits, np.float32)).all()


# ---------------------------------------------------------------------------
# engine machinery on the fake model
# ---------------------------------------------------------------------------

def test_engine_matches_greedy_on_fake_model():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, tokens=rng.integers(1, 17, (int(rng.integers(2, 9)),),
                                               dtype=np.int32),
                    max_new_tokens=int(rng.integers(1, 7)),
                    arrival=int(rng.integers(0, 5))) for i in range(11)]
    eng = ServeEngine(api, params, num_slots=3, cache_len=32)
    outs = eng.run(reqs)
    assert sorted(outs) == list(range(11))
    for r in reqs:
        ref = _run_greedy(api, params, r, cache_len=32)
        assert outs[r.rid].tokens == list(np.asarray(ref[0])), r.rid


def test_engine_event_attribution_and_slot_bounds():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    reqs = [Request(rid=i, tokens=np.full((4,), i + 1, np.int32),
                    max_new_tokens=3, arrival=i // 2) for i in range(8)]
    eng = ServeEngine(api, params, num_slots=2, cache_len=16)
    for r in reqs:
        eng.add(r)
    while eng.sched.has_work():
        eng.step()
        assert len(eng.sched.running) <= 2      # slot count never exceeds pool
    # every emitted token attributed to exactly one request, counts exact
    per_rid: dict = {}
    for _, rid, _ in eng.events:
        per_rid[rid] = per_rid.get(rid, 0) + 1
    assert per_rid == {r.rid: r.max_new_tokens for r in reqs}
    assert sorted(eng.sched.finished) == [r.rid for r in reqs]
    assert eng.stats["emitted"] == sum(r.max_new_tokens for r in reqs)


def test_engine_prompt_boundary_matches_prefill_logits():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    req = Request(rid=0, tokens=np.asarray([3, 1, 4], np.int32),
                  max_new_tokens=4)
    eng = ServeEngine(api, params, num_slots=1, cache_len=16)
    outs = eng.run([req])
    _, logits = api.prefill(params, {"tokens": jnp.asarray(req.tokens)[None]},
                            cache_len=16)
    assert outs[0].tokens[0] == int(jnp.argmax(logits[0]))


def test_engine_reselects_mode_from_measured_sparsity():
    """One-hot logits are almost all exact zeros: after ``measure_every``
    decode steps the measured activation sparsity crosses the category
    threshold and the engine flips DENSE -> A, re-tracing its fns."""
    api = fake_api(zero_logits=True)
    params = api.init(jax.random.PRNGKey(0))
    reqs = [Request(rid=i, tokens=np.full((3,), 2, np.int32),
                    max_new_tokens=8) for i in range(2)]
    eng = ServeEngine(api, params, num_slots=2, cache_len=16,
                      measure_every=2)
    assert eng.mode == Mode.DENSE
    eng.run(reqs)
    assert eng.mode == Mode.A
    assert eng.a_measured > 0.5
    assert [m for _, m in eng.mode_history] == [Mode.DENSE, Mode.A]
    assert eng.stats["retraces"] == 2
    # declared sparsity pins the category regardless of measurement
    eng2 = ServeEngine(api, params, num_slots=2, cache_len=16,
                       a_sparsity=0.0, measure_every=2)
    eng2.run([dataclasses.replace(r) for r in reqs])
    assert eng2.mode == Mode.DENSE


def test_engine_static_policy_admits_only_on_drained_pool():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    reqs = [Request(rid=i, tokens=np.full((2,), 1, np.int32),
                    max_new_tokens=4 if i % 2 else 2) for i in range(6)]
    eng = ServeEngine(api, params, num_slots=2, cache_len=8, policy="static")
    eng.run(reqs)
    # group admissions: each admission step admits a full group of 2
    steps = sorted({o.admitted for o in eng.outputs.values()})
    assert len(steps) == 3
    for s in steps:
        assert sum(1 for o in eng.outputs.values() if o.admitted == s) == 2


def test_engine_rejects_oversized_and_frameless_requests():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    eng = ServeEngine(api, params, num_slots=1, cache_len=8)
    with pytest.raises(ValueError):
        eng.add(Request(rid=0, tokens=np.zeros((6,), np.int32),
                        max_new_tokens=4))
    wcfg = get_config("whisper-large-v3").reduced()
    wapi = build_model(wcfg)
    weng = ServeEngine(wapi, wapi.init(jax.random.PRNGKey(0)), num_slots=1,
                       cache_len=8)
    with pytest.raises(ValueError):
        weng.add(Request(rid=1, tokens=np.zeros((2,), np.int32),
                         max_new_tokens=2))


def test_weight_sparsity_counts_gemm_leaves_only():
    cfg = get_config("llama3.2-1b").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    assert weight_sparsity(params) < 0.01       # dense init: no exact zeros
    pruned = sparsify_params(params, 0.75, compact=False, **PRUNE)
    assert weight_sparsity(pruned) > 0.5
    compacted = sparsify_params(params, 0.75, **PRUNE)
    assert 0.3 < weight_sparsity(compacted) <= 1.0


# ---------------------------------------------------------------------------
# registry-family decode/prefill parity vs the greedy oracle
# ---------------------------------------------------------------------------

def _family_parity(arch: str, sparse: bool, num_requests: int = 5):
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    kw = {}
    if sparse:
        params = sparsify_params(params, 0.6, **PRUNE)
        kw = dict(use_kernels=True, interpret=True)
    reqs = synthetic_trace(cfg, num_requests=num_requests, seed=11,
                           prompt_lens=(6, 10), gen_lens=(2, 4),
                           arrival_every=1)
    cache_len = 16
    eng = ServeEngine(api, params, num_slots=2, cache_len=cache_len, **kw)
    outs = eng.run(reqs)
    # single-category run: the final-mode oracle replay below is only a
    # valid comparison when no mid-run flip occurred (real-model logits
    # have no exact zeros, so measurement cannot flip the category here)
    assert len(eng.mode_history) == 1, eng.mode_history
    for r in reqs:
        ref = _run_greedy(api, params, r, cache_len, scope=eng._scope())
        got = outs[r.rid].tokens
        assert got == list(np.asarray(ref[0])), (arch, sparse, r.rid)
        # prompt boundary: first emitted token is the prefill-logits argmax
        with eng._scope():
            _, logits0 = api.prefill(params, r.as_batch(),
                                     cache_len=cache_len)
        assert got[0] == int(jnp.argmax(logits0[0])), (arch, sparse)
    if sparse:
        assert eng.mode == Mode.B
        assert eng.b_sparsity > 0.05


@pytest.mark.parametrize("family", ["transformer", "xlstm"])
def test_engine_parity_dense_fast(family):
    _family_parity(FAMILY_ARCHS[family], sparse=False, num_requests=3)


@pytest.mark.tier2
@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_engine_parity_dense(family):
    _family_parity(FAMILY_ARCHS[family], sparse=False)


@pytest.mark.tier2
@pytest.mark.parametrize("family", SPARSE_FAMILIES)
def test_engine_parity_sparse(family):
    _family_parity(FAMILY_ARCHS[family], sparse=True, num_requests=3)
