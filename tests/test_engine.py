"""Continuous-batching engine tests.

Three layers (cheap to slow):
  - ``jit_serve_fns`` regression on a 1-device mesh (the prefill jit must
    carry the dp logits sharding that used to be computed-then-dropped,
    and the fused chunk ladder must run under the same shardings);
  - engine machinery on a trivial fake ``ModelApi`` (slot reuse, event
    attribution, prompt-boundary emission, workload-category re-selection,
    fused-vs-stepwise equivalence, stale-slot measurement masking);
  - decode/prefill parity of registry families against the batch-1
    ``greedy_generate`` oracle under a chunked + bucketed matrix: engine
    tokens == greedy tokens (oracle replaying the same prompt bucket) ==
    the prefill-logits argmax at the prompt boundary.  Dense
    transformer+xlstm run tier-1; the full four-family sweep, dense AND
    block-pruned-compacted under ``sparse_execution``, is ``tier2``
    (scripts/ci.sh runs it in its own stage).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs import get_config
from repro.core.spec import Mode
from repro.models import ModelApi, build_model
from repro.models.common import sparse_execution
from repro.runtime.engine import (MIN_BUCKET, Request, Scheduler, ServeEngine,
                                  synthetic_trace, weight_sparsity)
from repro.runtime.serve import (greedy_generate, jit_serve_fns,
                                 make_decode_chunk_fn, pad_prompt_batch)
from repro.sparsity import sparsify_params

FAMILY_ARCHS = {
    "transformer": "llama3.2-1b",
    "moe": "mixtral-8x7b",
    "whisper": "whisper-large-v3",
    "xlstm": "xlstm-1.3b",
    "hybrid": "recurrentgemma-9b",
}
# all five families are griffin_linear-wired (the rglru hybrid joined the
# substrate with the mesh-serving PR), so every family runs the sparse
# sweep too
SPARSE_FAMILIES = sorted(FAMILY_ARCHS)
PRUNE = dict(block_k=16, block_n=16, unit=8)   # reduced dims (d_model 64)


# ---------------------------------------------------------------------------
# fake model: deterministic request-dependent next-token function
# ---------------------------------------------------------------------------

def fake_api(vocab: int = 17, zero_logits: bool = False) -> ModelApi:
    """Minimal ModelApi: cache carries a per-row running token sum; the
    next token is (state + 1) % vocab, emitted as one-hot logits (add 1.0
    everywhere when ``zero_logits=False`` so measured activation sparsity
    stays 0).  Deterministic and request-dependent, so scheduler bugs
    (wrong slot, stale cache, cross-request leaks) change the tokens."""
    base = 0.0 if zero_logits else 1.0

    def logits_of(state):
        nxt = (state[:, 0] + 1) % vocab
        return jax.nn.one_hot(nxt, vocab, dtype=jnp.float32) + base

    def init(key):
        return {"w": jnp.zeros((vocab, vocab), jnp.float32)}

    def prefill(params, batch, cache_len=None):
        toks = batch["tokens"]
        state = jnp.sum(toks, axis=-1, keepdims=True).astype(jnp.int32) % vocab
        cache = {"state": state,
                 "pos": jnp.asarray(toks.shape[1] - 1, jnp.int32)}
        return cache, logits_of(state)

    def decode_step(params, cache, token):
        state = (cache["state"] + token) % vocab
        return logits_of(state), {"state": state, "pos": cache["pos"] + 1}

    def init_cache(batch, length):
        return {"state": jnp.zeros((batch, 1), jnp.int32),
                "pos": jnp.zeros((), jnp.int32)}

    return ModelApi(cfg=get_config("llama3.2-1b").reduced(), init=init,
                    loss=lambda p, b: jnp.zeros(()), prefill=prefill,
                    decode_step=decode_step, init_cache=init_cache,
                    param_count=lambda: 0, param_count_total=lambda: 0)


def _run_greedy(api, params, req, cache_len, scope=None, bucket=None):
    if scope is None:
        return greedy_generate(api, params, req.as_batch(),
                               steps=req.max_new_tokens,
                               cache_len=cache_len, prompt_bucket=bucket)
    with scope:
        return greedy_generate(api, params, req.as_batch(),
                               steps=req.max_new_tokens,
                               cache_len=cache_len, prompt_bucket=bucket)


# ---------------------------------------------------------------------------
# jit_serve_fns regression (satellite: logits_sh threading)
# ---------------------------------------------------------------------------

def test_jit_serve_fns_run_on_one_device_mesh():
    cfg = get_config("llama3.2-1b").reduced()
    api = build_model(cfg)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    B, S, clen = 2, 8, 16
    prefill_jit, decode_jit, chunk_for, (p_sh, c_sh, logits_sh) = \
        jit_serve_fns(api, mesh, B, clen)
    params = api.init(jax.random.PRNGKey(0))
    toks = jnp.ones((B, S), jnp.int32)
    cache, logits = prefill_jit(params, {"tokens": toks})
    assert logits.shape == (B, cfg.vocab_size)
    # the dp logits sharding is threaded through the jit (it used to be
    # computed and dropped)
    assert logits.sharding.is_equivalent_to(logits_sh, logits.ndim)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    logits2, cache2 = decode_jit(params, cache, tok)
    assert logits2.shape == (B, cfg.vocab_size)
    assert logits2.sharding.is_equivalent_to(logits_sh, logits2.ndim)
    assert int(cache2["pos"]) == S
    # fused chunk under the same shardings: 3 steps advance pos by 3 and
    # fill a (3, B) token ring; dead rows stay out of the measurement
    cache3, logits3 = prefill_jit(params, {"tokens": toks})
    tokens = jnp.argmax(logits3, -1).astype(jnp.int32)[:, None]
    remaining = jnp.asarray([3, 0], jnp.int32)
    cache3, tokens, remaining, ring, zn, zd = chunk_for(3)(
        params, cache3, tokens, remaining)
    assert ring.shape == (3, B) and ring.dtype == jnp.int32
    assert int(cache3["pos"]) == S + 2
    assert list(np.asarray(remaining)) == [0, 0]
    assert float(zd) == 3.0                     # one live row x three steps
    assert chunk_for(3) is chunk_for(3)         # ladder memoized per length


def test_jit_serve_fns_shardings_follow_compacted_params():
    """GriffinWeights trees need their own specs: p_sh built from the dense
    init shapes would broadcast the parent GEMM's spec onto the metadata."""
    cfg = get_config("llama3.2-1b").reduced()
    api = build_model(cfg)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    params = sparsify_params(api.init(jax.random.PRNGKey(0)), 0.6, **PRUNE)
    prefill_jit, _, _, (p_sh, _, _) = jit_serve_fns(api, mesh, 2, 16,
                                                    params=params)
    assert jax.tree.structure(p_sh) == jax.tree.structure(
        jax.tree.map(lambda x: 0, params))
    with sparse_execution(use_kernels=False, interpret=True):
        _, logits = prefill_jit(params, {"tokens": jnp.ones((2, 8),
                                                            jnp.int32)})
    assert np.isfinite(np.asarray(logits, np.float32)).all()


# ---------------------------------------------------------------------------
# engine machinery on the fake model
# ---------------------------------------------------------------------------

def test_engine_matches_greedy_on_fake_model():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, tokens=rng.integers(1, 17, (int(rng.integers(2, 9)),),
                                               dtype=np.int32),
                    max_new_tokens=int(rng.integers(1, 7)),
                    arrival=int(rng.integers(0, 5))) for i in range(11)]
    eng = ServeEngine(api, params, num_slots=3, cache_len=32)
    outs = eng.run(reqs)
    assert sorted(outs) == list(range(11))
    for r in reqs:
        ref = _run_greedy(api, params, r, cache_len=32)
        assert outs[r.rid].tokens == list(np.asarray(ref[0])), r.rid


def test_engine_event_attribution_and_slot_bounds():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    reqs = [Request(rid=i, tokens=np.full((4,), i + 1, np.int32),
                    max_new_tokens=3, arrival=i // 2) for i in range(8)]
    eng = ServeEngine(api, params, num_slots=2, cache_len=16)
    for r in reqs:
        eng.add(r)
    while eng.sched.has_work():
        eng.step()
        assert len(eng.sched.running) <= 2      # slot count never exceeds pool
    # every emitted token attributed to exactly one request, counts exact
    per_rid: dict = {}
    for _, rid, _ in eng.events:
        per_rid[rid] = per_rid.get(rid, 0) + 1
    assert per_rid == {r.rid: r.max_new_tokens for r in reqs}
    assert sorted(eng.sched.finished) == [r.rid for r in reqs]
    assert eng.stats["emitted"] == sum(r.max_new_tokens for r in reqs)


def test_engine_prompt_boundary_matches_prefill_logits():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    req = Request(rid=0, tokens=np.asarray([3, 1, 4], np.int32),
                  max_new_tokens=4)
    eng = ServeEngine(api, params, num_slots=1, cache_len=16)
    outs = eng.run([req])
    _, logits = api.prefill(params, {"tokens": jnp.asarray(req.tokens)[None]},
                            cache_len=16)
    assert outs[0].tokens[0] == int(jnp.argmax(logits[0]))


def test_engine_reselects_mode_from_measured_sparsity():
    """One-hot logits are almost all exact zeros: after ``measure_every``
    decode steps the measured activation sparsity crosses the category
    threshold and the engine flips DENSE -> A, re-tracing its fns."""
    api = fake_api(zero_logits=True)
    params = api.init(jax.random.PRNGKey(0))
    reqs = [Request(rid=i, tokens=np.full((3,), 2, np.int32),
                    max_new_tokens=8) for i in range(2)]
    eng = ServeEngine(api, params, num_slots=2, cache_len=16,
                      measure_every=2)
    assert eng.mode == Mode.DENSE
    eng.run(reqs)
    assert eng.mode == Mode.A
    assert eng.a_measured > 0.5
    assert [m for _, m in eng.mode_history] == [Mode.DENSE, Mode.A]
    assert eng.stats["retraces"] == 2
    # declared sparsity pins the category regardless of measurement
    eng2 = ServeEngine(api, params, num_slots=2, cache_len=16,
                       a_sparsity=0.0, measure_every=2)
    eng2.run([dataclasses.replace(r) for r in reqs])
    assert eng2.mode == Mode.DENSE


def test_engine_static_policy_admits_only_on_drained_pool():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    reqs = [Request(rid=i, tokens=np.full((2,), 1, np.int32),
                    max_new_tokens=4 if i % 2 else 2) for i in range(6)]
    eng = ServeEngine(api, params, num_slots=2, cache_len=8, policy="static")
    eng.run(reqs)
    # group admissions: each admission step admits a full group of 2
    steps = sorted({o.admitted for o in eng.outputs.values()})
    assert len(steps) == 3
    for s in steps:
        assert sum(1 for o in eng.outputs.values() if o.admitted == s) == 2


def test_engine_rejects_oversized_and_frameless_requests():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    eng = ServeEngine(api, params, num_slots=1, cache_len=8)
    with pytest.raises(ValueError):
        eng.add(Request(rid=0, tokens=np.zeros((6,), np.int32),
                        max_new_tokens=4))
    wcfg = get_config("whisper-large-v3").reduced()
    wapi = build_model(wcfg)
    weng = ServeEngine(wapi, wapi.init(jax.random.PRNGKey(0)), num_slots=1,
                       cache_len=8)
    with pytest.raises(ValueError):
        weng.add(Request(rid=1, tokens=np.zeros((2,), np.int32),
                         max_new_tokens=2))


def test_weight_sparsity_counts_gemm_leaves_only():
    cfg = get_config("llama3.2-1b").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    assert weight_sparsity(params) < 0.01       # dense init: no exact zeros
    pruned = sparsify_params(params, 0.75, compact=False, **PRUNE)
    assert weight_sparsity(pruned) > 0.5
    compacted = sparsify_params(params, 0.75, **PRUNE)
    assert 0.3 < weight_sparsity(compacted) <= 1.0


# ---------------------------------------------------------------------------
# fused-path regressions (stale slots, chunk ladder, prompt buckets)
# ---------------------------------------------------------------------------

def test_chunk_fn_masks_dead_rows_out_of_measurement():
    """Direct regression on the fused scan: rows with ``remaining == 0``
    (freed or never-admitted slots) must not leak their stale logits into
    the zero-fraction accumulator — the bug class the old
    ``logits[jnp.asarray(active)]`` gather guarded against."""
    api = fake_api(zero_logits=True)      # one-hot logits: zf ~ 16/17
    params = api.init(jax.random.PRNGKey(0))
    chunk_fn = make_decode_chunk_fn(api, 4)
    cache = {"state": jnp.asarray([[3], [9]], jnp.int32),
             "pos": jnp.zeros((2,), jnp.int32)}
    tokens = jnp.asarray([[1], [2]], jnp.int32)
    # row 1 is dead: its one-hot rows would dominate the mean if leaked
    _, _, _, _, zn, zd = chunk_fn(params, cache, tokens,
                                  jnp.asarray([4, 0], jnp.int32))
    assert float(zd) == 4.0               # only row 0, all four steps
    assert 0.9 < float(zn) / float(zd) < 1.0
    # all-dead pool: denominator 0, numerator 0 (engine skips measuring)
    _, _, _, _, zn0, zd0 = chunk_fn(params, cache, tokens,
                                    jnp.asarray([0, 0], jnp.int32))
    assert float(zd0) == 0.0 and float(zn0) == 0.0


def test_engine_measurement_ignores_stale_and_unadmitted_slots():
    """Engine-level twin: a 3-slot pool serving one live dense-logits
    request must stay DENSE even though the two never-admitted slots keep
    producing one-hot (zero-heavy) garbage rows every chunk."""

    vocab = 17

    def logits_of_mixed(state):
        nxt = (state[:, 0] + 1) % vocab
        onehot = jax.nn.one_hot(nxt, vocab, dtype=jnp.float32)
        # rows with state 0 (unadmitted slots never leave 0) emit bare
        # one-hot rows; live rows get a dense +1 offset
        dense = (state[:, 0] != 0).astype(jnp.float32)[:, None]
        return onehot + dense

    api = fake_api()
    api = dataclasses.replace(
        api,
        prefill=lambda params, batch, cache_len=None: (
            {"state": jnp.sum(batch["tokens"], -1, keepdims=True
                              ).astype(jnp.int32) % vocab,
             "pos": jnp.asarray(batch["tokens"].shape[1] - 1, jnp.int32)},
            logits_of_mixed(jnp.sum(batch["tokens"], -1, keepdims=True
                                    ).astype(jnp.int32) % vocab)),
        decode_step=lambda params, cache, token: (
            logits_of_mixed((cache["state"] + token) % vocab),
            {"state": (cache["state"] + token) % vocab,
             "pos": cache["pos"] + 1}))
    params = api.init(jax.random.PRNGKey(0))
    req = Request(rid=0, tokens=np.asarray([5], np.int32), max_new_tokens=9)
    eng = ServeEngine(api, params, num_slots=3, cache_len=16,
                      measure_every=2, decode_chunk=4)
    eng.run([req])
    # live row contributes ~16/17 one-hot zeros *plus* the dense offset ->
    # exactly zero zeros; stale rows would have pushed this above threshold
    assert eng.a_measured == 0.0, eng.a_measured
    assert eng.mode == Mode.DENSE
    assert [m for _, m in eng.mode_history] == [Mode.DENSE]


def test_engine_fused_and_stepwise_paths_agree():
    """`fused=False` preserves the PR 3 per-step hot path; both paths must
    produce identical per-request tokens and attribution counts."""
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    mk = lambda: [Request(rid=i,
                          tokens=rng.integers(1, 17, (int(p),), np.int32),
                          max_new_tokens=int(g), arrival=int(a))
                  for i, (p, g, a) in enumerate(
                      zip([3, 7, 2, 5, 4], [6, 1, 9, 3, 5],
                          [0, 0, 2, 3, 3]))]
    rng = np.random.default_rng(5)
    fused = ServeEngine(api, params, num_slots=2, cache_len=32,
                        decode_chunk=4).run(mk())
    rng = np.random.default_rng(5)
    stepwise = ServeEngine(api, params, num_slots=2, cache_len=32,
                           fused=False).run(mk())
    assert {r: o.tokens for r, o in fused.items()} == \
        {r: o.tokens for r, o in stepwise.items()}


def test_chunk_ladder_wastes_no_decode_steps():
    """The completion bound must account for the prefill-boundary token of
    freshly admitted slots (they owe the device one step fewer than the
    scheduler's pre-drain ``remaining`` says), and a tick whose live slots
    all owe zero decode steps must not dispatch a dead chunk."""
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    eng = ServeEngine(api, params, num_slots=2, cache_len=16, decode_chunk=8)
    eng.run([Request(rid=0, tokens=np.asarray([3, 1], np.int32),
                     max_new_tokens=4)])
    # prefill emits token 1; exactly 3 decode steps may run (2 + 1 ladder)
    assert eng.stats["decode_steps"] == 3, eng.stats
    assert eng.stats["emitted"] == 4
    # all-single-token admissions: prefill tokens ride the sync, no chunk
    eng2 = ServeEngine(api, params, num_slots=2, cache_len=16,
                       decode_chunk=8, max_admissions_per_step=2)
    eng2.run([Request(rid=i, tokens=np.asarray([i + 1], np.int32),
                      max_new_tokens=1) for i in range(2)])
    assert eng2.stats["decode_steps"] == 0
    assert eng2.stats["emitted"] == 2 and eng2.stats["host_syncs"] == 1


def test_chunk_ladder_is_capped_by_factory():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    eng = ServeEngine(api, params, num_slots=1, cache_len=16, decode_chunk=4)
    with pytest.raises(ValueError):
        eng._fns()[2](5)                      # beyond the configured ladder
    with pytest.raises(ValueError):
        eng._fns()[2](0)


def test_bucket_for_policy():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    eng = ServeEngine(api, params, num_slots=1, cache_len=40)
    assert eng.bucket_for(1) == MIN_BUCKET
    assert eng.bucket_for(8) == 8
    assert eng.bucket_for(9) == 16
    assert eng.bucket_for(17) == 32
    # bucket would overflow the cache -> exact-length fallback
    assert eng.bucket_for(33) is None
    off = ServeEngine(api, params, num_slots=1, cache_len=40,
                      bucket_prompts=False)
    assert off.bucket_for(9) is None
    # windowed archs cap buckets at the usable window, not the cache
    wcfg = get_config("mixtral-8x7b").reduced()   # window 32
    wapi = build_model(wcfg)
    weng = ServeEngine(wapi, wapi.init(jax.random.PRNGKey(0)), num_slots=1,
                       cache_len=64)
    assert weng.bucket_for(20) == 32
    assert weng.bucket_for(33) is None


def test_engine_bounds_prefill_shapes_on_ragged_trace():
    """Many distinct prompt lengths must collapse onto O(log cache_len)
    admitted prefill shapes — the retrace bound bucketing buys."""
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    reqs = [Request(rid=i, tokens=np.full((i + 1,), 2, np.int32),
                    max_new_tokens=2) for i in range(24)]   # lens 1..24
    eng = ServeEngine(api, params, num_slots=2, cache_len=32)
    eng.run(reqs)
    assert eng.prefill_buckets <= {8, 16, 32}
    assert len(eng.prefill_buckets) == 3


# ---------------------------------------------------------------------------
# registry-family decode/prefill parity vs the greedy oracle
# ---------------------------------------------------------------------------

def _family_parity(arch: str, sparse: bool, num_requests: int = 5,
                   decode_chunk: int = 3, bucket_prompts: bool = True):
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    kw = {}
    if sparse:
        params = sparsify_params(params, 0.6, **PRUNE)
        kw = dict(use_kernels=True, interpret=True)
    reqs = synthetic_trace(cfg, num_requests=num_requests, seed=11,
                           prompt_lens=(6, 10), gen_lens=(2, 4),
                           arrival_every=1)
    cache_len = 16
    eng = ServeEngine(api, params, num_slots=2, cache_len=cache_len,
                      decode_chunk=decode_chunk,
                      bucket_prompts=bucket_prompts, **kw)
    outs = eng.run(reqs)
    # single-category run: the final-mode oracle replay below is only a
    # valid comparison when no mid-run flip occurred (real-model logits
    # have no exact zeros, so measurement cannot flip the category here)
    assert len(eng.mode_history) == 1, eng.mode_history
    for r in reqs:
        bucket = eng.bucket_for(r.prompt_len)
        if bucket_prompts:
            assert bucket is not None     # this trace must exercise buckets
        ref = _run_greedy(api, params, r, cache_len, scope=eng._scope(),
                          bucket=bucket)
        got = outs[r.rid].tokens
        assert got == list(np.asarray(ref[0])), (arch, sparse, r.rid)
        # prompt boundary: first emitted token is the prefill-logits argmax
        # of the same padded batch the engine admitted with
        with eng._scope():
            _, logits0 = api.prefill(params, r.as_batch(bucket),
                                     cache_len=cache_len)
        assert got[0] == int(jnp.argmax(logits0[0])), (arch, sparse)
    if sparse:
        assert eng.mode == Mode.B
        assert eng.b_sparsity > 0.05


@pytest.mark.parametrize("family", ["transformer", "xlstm"])
@pytest.mark.parametrize("decode_chunk", [1, 3])
def test_engine_parity_dense_fast(family, decode_chunk):
    _family_parity(FAMILY_ARCHS[family], sparse=False, num_requests=3,
                   decode_chunk=decode_chunk)


def test_engine_parity_unbucketed_exact_lengths():
    """bucket_prompts=False keeps the exact-length prefill path alive (the
    fallback for prompts whose bucket would overflow the cache)."""
    _family_parity(FAMILY_ARCHS["transformer"], sparse=False,
                   num_requests=3, bucket_prompts=False)


@pytest.mark.tier2
@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_engine_parity_dense(family):
    _family_parity(FAMILY_ARCHS[family], sparse=False)


@pytest.mark.tier2
@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_engine_parity_dense_stepwise_chunk1(family):
    _family_parity(FAMILY_ARCHS[family], sparse=False, num_requests=3,
                   decode_chunk=1)


@pytest.mark.tier2
@pytest.mark.parametrize("family", SPARSE_FAMILIES)
def test_engine_parity_sparse(family):
    _family_parity(FAMILY_ARCHS[family], sparse=True, num_requests=3)


# ---------------------------------------------------------------------------
# EngineConfig (runtime/config.py)
# ---------------------------------------------------------------------------

def test_engine_config_json_roundtrip():
    from repro.runtime.config import ArenaConfig, EngineConfig
    from repro.runtime.config import RouterConfig
    cfg = EngineConfig(arena=ArenaConfig(num_slots=8, cache_len=96,
                                         page_size=16, kv_dtype="int8"),
                       router=RouterConfig(replicas=3, queue_bound=7),
                       mesh="2x2").with_fields(decode_chunk=4)
    assert EngineConfig.from_json(cfg.to_json()) == cfg


def test_engine_config_json_rejects_unknown():
    from repro.runtime.config import EngineConfig
    with pytest.raises(ValueError):
        EngineConfig.from_json('{"nope": {}}')
    with pytest.raises(ValueError):
        EngineConfig.from_json('{"arena": {"slotz": 4}}')


def test_engine_config_with_fields_routes_and_rejects():
    from repro.runtime.config import EngineConfig
    cfg = EngineConfig().with_fields(num_slots=6, use_kernels=True,
                                     mesh="4x1")
    assert cfg.arena.num_slots == 6
    assert cfg.kernels.use_kernels is True
    assert cfg.mesh == "4x1"
    with pytest.raises(TypeError):
        EngineConfig().with_fields(slotz=6)


def test_engine_config_derive_cache_len():
    from repro.runtime.config import EngineConfig
    assert EngineConfig.derive_cache_len((8, 16, 24), (12, 112)) == 137
    # heavy tail: cap = 2 * max gen, the bench_serve workload bound
    assert EngineConfig.heavy_gen_cap((12, 112)) == 224
    assert EngineConfig.derive_cache_len((8, 16, 24), (12, 112),
                                         "heavy") == 249


def test_engine_legacy_kwargs_warn_and_match_config():
    from repro.runtime.config import ArenaConfig, EngineConfig
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    with pytest.warns(DeprecationWarning, match="EngineConfig"):
        legacy = ServeEngine(api, params, num_slots=3, cache_len=24,
                             decode_chunk=2)
    cfg = EngineConfig(arena=ArenaConfig(num_slots=3, cache_len=24)
                       ).with_fields(decode_chunk=2)
    modern = ServeEngine(api, params, config=cfg)
    assert (legacy.num_slots, legacy.cache_len) == (3, 24)
    trace = lambda: [Request(rid=i, tokens=np.arange(1, 5 + i, dtype=np.int32),
                             max_new_tokens=3) for i in range(3)]
    outs_l = legacy.run(trace())
    outs_m = modern.run(trace())
    assert {r: o.tokens for r, o in outs_l.items()} == \
           {r: o.tokens for r, o in outs_m.items()}


def test_engine_unknown_kwarg_raises():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    with pytest.raises(TypeError, match="num_slotz"):
        ServeEngine(api, params, num_slotz=3)
