"""The closed-form analytical model tracks the simulator (paper Section I:
'an analytical model, verified by a simulator')."""
import numpy as np
import pytest

from repro.core.analytical import verify
from repro.core.evaluate import MaskModel
from repro.core.spec import CoreConfig, sparse_b


@pytest.mark.parametrize("density", [0.1, 0.2, 0.4])
@pytest.mark.parametrize("cfg", [(2, 0, 0, False), (4, 0, 1, False),
                                 (4, 0, 1, True), (8, 0, 1, True)])
def test_analytical_tracks_simulator(density, cfg):
    rng = np.random.default_rng(0)
    mm = MaskModel()
    mask = mm.weight_mask(512, 128, density, rng)
    spec = sparse_b(*cfg[:3], shuffle=cfg[3])
    chk = verify(spec, mask)
    # pre-screening accuracy band: within 45% of the simulator and always
    # ordered sanely (>= 1, <= window cap)
    assert 0.55 < chk.ratio < 1.8, (cfg, density, chk)
    assert 1.0 <= chk.predicted <= 1 + spec.db1 + 1e-9


def test_analytical_ranks_window_sizes():
    """The model must reproduce the paper's observation (1): larger db1 ->
    larger speedup, for fixed sparsity."""
    rng = np.random.default_rng(1)
    mm = MaskModel()
    mask = mm.weight_mask(512, 128, 0.2, rng)
    sp = [verify(sparse_b(d, 0, 1, shuffle=True), mask).predicted
          for d in (1, 2, 4, 8)]
    assert sp == sorted(sp), sp
