"""Substrate tests: data determinism, optimizer, compression, checkpoint
round-trip + resharding, elastic planning, straggler logic, losses."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, synth_batch
from repro.models.losses import chunked_cross_entropy
from repro.optim import adamw
from repro.optim.compression import compressed_psum_tree, init_error
from repro.runtime.elastic import plan_mesh, reshard
from repro.runtime.straggler import (StragglerConfig, StragglerDetector,
                                     reassign_shards)


SMALL = ShapeConfig("small", 16, 8, "train")


def test_data_deterministic_and_sharded():
    cfg = get_config("llama3.2-1b").reduced()
    b1 = synth_batch(cfg, SMALL, DataConfig(seed=7, num_shards=2, shard_id=0),
                     step=3)
    b2 = synth_batch(cfg, SMALL, DataConfig(seed=7, num_shards=2, shard_id=0),
                     step=3)
    b3 = synth_batch(cfg, SMALL, DataConfig(seed=7, num_shards=2, shard_id=1),
                     step=3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].shape == (4, 16)
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()


def test_adamw_descends_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                            weight_decay=0.0, clip_norm=10.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw.init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw.apply(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.2
    assert m["grad_norm"] > 0


def test_compressed_psum_matches_mean():
    kwargs = {}
    if hasattr(jax.sharding, "AxisType"):    # added after jax 0.4.x
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,)
    mesh = jax.make_mesh((1,), ("data",), **kwargs)
    g = {"a": jnp.asarray(np.random.RandomState(0).randn(32).astype(np.float32))}
    err = init_error(g)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def f(gg, ee):
        return compressed_psum_tree(gg, ee, mesh, ("data",))

    red, new_err = shard_map(f, mesh=mesh, in_specs=(P(), P()),
                             out_specs=(P(), P()))(g, err)
    # single shard: mean == dequantized self; error = quantization residual
    np.testing.assert_allclose(np.asarray(red["a"]), np.asarray(g["a"]),
                               atol=float(jnp.abs(g["a"]).max()) / 100)
    assert float(jnp.abs(new_err["a"]).max()) <= \
        float(jnp.abs(g["a"]).max()) / 127 + 1e-6


def test_error_feedback_converges():
    """Repeated compression of the same gradient loses nothing on average."""
    rng = np.random.RandomState(1)
    g = jnp.asarray(rng.randn(64).astype(np.float32))
    from repro.optim.compression import quantize, dequantize
    e = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for i in range(50):
        q, s = quantize(g + e)
        d = dequantize(q, s)
        e = (g + e) - d
        acc = acc + d
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g), atol=1e-3)


def test_checkpoint_roundtrip_and_retention(tmp_path):
    state = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "n": {"m": jnp.ones((4,), jnp.bfloat16)},
             "step": jnp.asarray(7, jnp.int32)}
    d = str(tmp_path / "ckpt")
    for s in (1, 2, 3, 4):
        save(d, s, state, keep=2)
    assert latest_step(d) == 4
    assert len([x for x in os.listdir(d) if x.startswith("step_")]) == 2
    template = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            state)
    out = restore(d, template)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(state["w"]))
    assert out["n"]["m"].dtype == jnp.bfloat16


def test_checkpoint_restores_onto_new_mesh(tmp_path):
    """Elastic restart: save replicated, restore sharded on a fresh mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    state = {"w": jnp.arange(8, dtype=jnp.float32)}
    d = str(tmp_path / "ck")
    save(d, 1, state)
    mesh = plan_mesh(1, 1)
    sh = {"w": NamedSharding(mesh, P())}
    template = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            state)
    out = restore(d, template, shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(8))


def test_plan_mesh_shapes():
    m = plan_mesh(1, 1)
    assert dict(m.shape) == {"data": 1, "model": 1}


def test_straggler_detection_and_reassignment():
    det = StragglerDetector(4, StragglerConfig(threshold=1.5, evict_after=3))
    for step in range(5):
        for h in range(4):
            det.record(h, 1.0 if h != 2 else 3.0)
        det.observe()           # one streak advance per closed step
    assert det.stragglers() == [2]
    assert det.evictions() == [2]
    plan = reassign_shards(8, [0, 1, 3])
    assert sorted(sum(plan.values(), [])) == list(range(8))
    assert 2 not in plan


def test_chunked_ce_matches_full():
    rng = np.random.RandomState(0)
    h = jnp.asarray(rng.randn(2, 10, 8).astype(np.float32))
    u = jnp.asarray(rng.randn(8, 32).astype(np.float32))
    lab = jnp.asarray(rng.randint(0, 32, (2, 10)), jnp.int32)
    lab = lab.at[0, :3].set(-1)        # masked positions
    full = h @ u
    lse = jax.nn.logsumexp(full, axis=-1)
    gold = jnp.take_along_axis(full, jnp.maximum(lab, 0)[..., None],
                               axis=-1)[..., 0]
    ref = ((lse - gold) * (lab >= 0)).sum() / (lab >= 0).sum()
    for chunk in (3, 5, 10, 16):
        got = chunked_cross_entropy(h, u, lab, chunk)
        np.testing.assert_allclose(float(got), float(ref), rtol=1e-5)


def test_train_step_descends_tiny_model():
    from repro.models import build_model
    from repro.runtime.train import init_state, make_train_step
    cfg = get_config("llama3.2-1b").reduced()
    api = build_model(cfg)
    state = init_state(api, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(
        api, adamw.AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=50)))
    dc = DataConfig(seed=0)
    losses = []
    for i in range(8):
        batch = {k: jnp.asarray(v)
                 for k, v in synth_batch(cfg, SMALL, dc, step=0).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses
