"""Hypothesis property sweeps over the scheduler, functional executor,
Pallas kernels and the serving engine's slot scheduler.

hypothesis is an *optional* [test] dependency (declared in pyproject.toml);
the module-level ``pytest.importorskip`` below turns its absence into a
clean skip instead of a collection error, so the tier-1 suite never
hard-fails on a minimal environment.  The deterministic seed-parametrized
variants of these sweeps live in the sibling test modules and always run.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(pip install -e .[test] to enable property sweeps)")
from hypothesis import given, settings, strategies as st

from repro.core.functional import execute_b_sparse, verify_schedule
from repro.core.scheduler import schedule
from repro.core.spec import CoreConfig, sparse_b
from repro.kernels import griffin_matmul, preprocess_weights
from repro.runtime.engine import Request, Scheduler, ServeEngine

CORE = CoreConfig()


@settings(max_examples=30, deadline=None)
@given(
    t=st.integers(2, 12), k0=st.sampled_from([4, 8, 16]),
    g=st.integers(1, 3), d1=st.integers(0, 4), d2=st.integers(0, 2),
    d3=st.integers(0, 2), density=st.floats(0.05, 0.95),
    seed=st.integers(0, 999),
)
def test_schedule_invariants_property(t, k0, g, d1, d2, d3, density, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random((2, t, k0, g)) < density
    s = schedule(mask, d1, d2, d3, record=True)
    verify_schedule(mask, s, d1, d2, d3)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 6), k=st.integers(3, 70), n=st.integers(1, 40),
    density=st.floats(0.02, 0.9), db1=st.integers(1, 6),
    db2=st.integers(0, 2), db3=st.integers(0, 2), sh=st.booleans(),
    seed=st.integers(0, 10_000),
)
def test_b_sparse_execution_property(m, k, n, density, db1, db2, db3, sh, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k))
    b = rng.standard_normal((k, n)) * (rng.random((k, n)) < density)
    spec = sparse_b(db1, db2, db3, shuffle=sh)
    c, ops = execute_b_sparse(a, b, spec, CORE)
    np.testing.assert_allclose(c, a @ b, rtol=1e-10, atol=1e-10)
    assert ops == (b != 0).sum()


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 40), kb=st.integers(2, 6), nb=st.integers(1, 5),
    block_k=st.sampled_from([8, 16]), block_n=st.sampled_from([16, 32]),
    density=st.floats(0.1, 0.9), dual=st.booleans(), seed=st.integers(0, 99),
)
def test_griffin_spmm_property(m, kb, nb, block_k, block_n, density, dual,
                               seed):
    rng = np.random.RandomState(seed)
    k, n = kb * block_k, nb * block_n
    unit = block_n // 2
    w = rng.randn(k, n).astype(np.float32)
    # zero random (block_k x unit) blocks
    keep = rng.rand(kb, n // unit) < density
    wb = w.reshape(kb, block_k, n // unit, unit).transpose(0, 2, 1, 3).copy()
    wb[~keep] = 0
    w = wb.transpose(0, 2, 1, 3).reshape(k, n)
    a = rng.randn(m, k).astype(np.float32)
    gw = preprocess_weights(w, block_k=block_k, block_n=block_n, unit=unit,
                            balance=True)
    out = griffin_matmul(jnp.asarray(a), gw, dual=dual, interpret=True)
    np.testing.assert_allclose(np.asarray(out), a @ w, rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 9), kb=st.integers(2, 5), nb=st.sampled_from([2, 4, 8]),
    trim=st.integers(0, 10), density=st.floats(0.1, 0.9),
    balance=st.booleans(), dual=st.booleans(), seed=st.integers(0, 10_000),
)
def test_griffin_shard_split_invariance_property(m, kb, nb, trim, density,
                                                 balance, dual, seed):
    """The output-axis partition law behind the shard_map serving path
    (DESIGN.md Section 10): for *every* split degree dividing the N
    tiles of random block-sparse weights, running the shard-local kernel
    entry on each contiguous tile group and concatenating is bit-equal
    to the unsharded kernel — so the model-axis size never changes the
    served logits.  Single-device: the slices are cut by hand, exactly
    as ``shard_specs`` would place them."""
    from repro.kernels.griffin_spmm.ops import griffin_matmul_shard
    bk = bn = 16
    k, n = kb * bk, nb * bn - min(trim, bn - 1)
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((k, n)).astype(np.float32)
    # zero (block_k x unit) pruning blocks on the ceil grid
    keep = rng.random((kb, -(-n // 8))) < density
    w = w * np.repeat(np.repeat(keep, bk, 0), 8, 1)[:k, :n]
    a = rng.standard_normal((m, k)).astype(np.float32)
    if dual:
        a[:, : (k // 2 // bk) * bk] = 0.0        # whole zero A blocks
    gw = preprocess_weights(w, block_k=bk, block_n=bn, unit=8,
                            balance=balance)
    ref = griffin_matmul(jnp.asarray(a), gw, dual=dual, interpret=True)
    nt = gw.kidx.shape[0]
    bm = max(8, -(-m // 8) * 8)                  # griffin_matmul's grid
    ap = jnp.pad(jnp.asarray(a), ((0, bm - m), (0, gw.k - k)))
    for shards in [d for d in range(1, nt + 1) if nt % d == 0]:
        tps = nt // shards
        parts = [griffin_matmul_shard(
                     ap, gw.b_comp[:, s * tps * bn:(s + 1) * tps * bn],
                     gw.kidx[s * tps:(s + 1) * tps],
                     gw.cnt[s * tps:(s + 1) * tps], block_m=bm, block_k=bk,
                     block_n=bn, dual=dual, interpret=True)
                 for s in range(shards)]
        out = jnp.concatenate(parts, axis=1)
        if gw.inv_perm is not None:
            out = out[:, gw.inv_perm]
        np.testing.assert_array_equal(np.asarray(out[:m, :gw.n]),
                                      np.asarray(ref), err_msg=str(shards))


# ---------------------------------------------------------------------------
# serving-engine slot scheduler (runtime.engine)
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(
    num_slots=st.integers(1, 5),
    policy=st.sampled_from(["continuous", "static"]),
    max_adm=st.integers(1, 3),
    trace=st.lists(st.tuples(st.integers(0, 25),      # arrival step
                             st.integers(1, 12),      # prompt len
                             st.integers(1, 9)),      # gen len
                   min_size=1, max_size=40),
)
def test_slot_scheduler_trace_invariants(num_slots, policy, max_adm, trace):
    """Random request traces through the serving scheduler, replaying the
    engine's emission discipline (one token at admission from the prefill
    logits, one per running slot per decode tick): no request dropped or
    duplicated, slot occupancy never exceeds the pool, every emitted token
    attributed to exactly one request, and the drain terminates."""
    sched = Scheduler(num_slots, policy, max_adm)
    reqs = [Request(rid=i, tokens=np.zeros((p,), np.int32),
                    max_new_tokens=g, arrival=a)
            for i, (a, p, g) in enumerate(trace)]
    for r in reqs:
        sched.add(r)
    emitted: dict = {}
    admitted: dict = {}
    step = 0
    bound = sum(g for _, _, g in trace) + max(a for a, _, _ in trace) + \
        len(trace) + 8
    while sched.has_work():
        for slot, req in sched.admissions(step):
            assert req.arrival <= step
            admitted[req.rid] = admitted.get(req.rid, 0) + 1
            emitted[req.rid] = emitted.get(req.rid, 0) + 1
            sched.emit(slot)
        assert len(sched.running) <= num_slots
        for slot in sched.active:
            rid = sched.running[slot].rid
            emitted[rid] = emitted.get(rid, 0) + 1
            sched.emit(slot)
        step += 1
        assert step <= bound, "scheduler failed to drain"
    assert admitted == {r.rid: 1 for r in reqs}
    assert emitted == {r.rid: r.max_new_tokens for r in reqs}
    assert sorted(sched.finished) == sorted(r.rid for r in reqs)
    assert not sched.running and not sched.waiting


@settings(max_examples=60, deadline=None)
@given(
    num_slots=st.integers(1, 4),
    policy=st.sampled_from(["continuous", "static"]),
    max_adm=st.integers(1, 3),
    trace=st.lists(st.tuples(st.integers(0, 25),      # arrival step
                             st.integers(1, 9)),      # gen len
                   min_size=1, max_size=40),
)
def test_slot_scheduler_admission_order_matches_scan(num_slots, policy,
                                                     max_adm, trace):
    """The heap-based O(1) admission path must admit exactly the requests,
    slots and order the original O(waiting)-per-tick list scan produced —
    FCFS by submission over the arrived portion of the queue — under
    arbitrary (including non-monotone) arrival patterns."""

    class ScanScheduler(Scheduler):
        """Reference: the pre-heap linear-scan admission (PR 3)."""

        def __init__(self, *a, **k):
            super().__init__(*a, **k)
            self._scan_waiting = []

        def add(self, req):
            self._scan_waiting.append(req)

        def has_work(self):
            return bool(self._scan_waiting or self.running)

        def admissions(self, step):
            if self.policy == "static" and self.running:
                return []
            budget = (self.num_slots if self.policy == "static"
                      else self.max_admissions)
            out = []
            while self._free and len(out) < budget:
                i = next((j for j, r in enumerate(self._scan_waiting)
                          if r.arrival <= step), None)
                if i is None:
                    break
                req = self._scan_waiting.pop(i)
                slot = self._free.pop()
                self.running[slot] = req
                self.remaining[slot] = req.max_new_tokens
                out.append((slot, req))
            return out

    def drive(sched):
        reqs = [Request(rid=i, tokens=np.zeros((2,), np.int32),
                        max_new_tokens=g, arrival=a)
                for i, (a, g) in enumerate(trace)]
        for r in reqs:
            sched.add(r)
        admitted, step = [], 0
        while sched.has_work():
            for slot, req in sched.admissions(step):
                admitted.append((step, slot, req.rid))
                sched.emit(slot)
            for slot in sched.active:
                sched.emit(slot)
            step += 1
            assert step < 10_000
        return admitted

    assert drive(Scheduler(num_slots, policy, max_adm)) == \
        drive(ScanScheduler(num_slots, policy, max_adm))


@settings(max_examples=12, deadline=None)
@given(
    num_slots=st.integers(1, 3),
    trace=st.lists(st.tuples(st.integers(0, 6),       # arrival step
                             st.integers(1, 6),       # prompt len
                             st.integers(1, 5)),      # gen len
                   min_size=1, max_size=10),
    seed=st.integers(0, 99),
)
def test_engine_token_attribution_property(num_slots, trace, seed):
    """The full engine (fake deterministic model) on random traces: each
    request's token stream matches an isolated batch-1 replay — tokens are
    never attributed to the wrong request, whatever the slot interleaving."""
    from test_engine import fake_api

    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=i, tokens=rng.integers(1, 17, (p,), dtype=np.int32),
                    max_new_tokens=g, arrival=a)
            for i, (a, p, g) in enumerate(trace)]
    eng = ServeEngine(api, params, num_slots=num_slots, cache_len=12)
    outs = eng.run(reqs)
    assert sorted(outs) == [r.rid for r in reqs]
    for r in reqs:
        state = int(np.sum(r.tokens)) % 17
        tok = (state + 1) % 17                  # prefill-boundary emission
        expect = [tok]
        for _ in range(r.max_new_tokens - 1):
            state = (state + tok) % 17          # decode feeds the token back
            tok = (state + 1) % 17
            expect.append(tok)
        assert outs[r.rid].tokens == expect, r.rid
    assert len(eng.events) == sum(r.max_new_tokens for r in reqs)


# ---------------------------------------------------------------------------
# SLO admission + multi-replica router (runtime.slo / runtime.router,
# DESIGN.md Section 13)
# ---------------------------------------------------------------------------

_SLO_REQS = st.lists(
    st.tuples(st.one_of(st.none(), st.integers(1, 40)),   # deadline_ms
              st.integers(0, 2),                          # priority
              st.integers(1, 12),                         # prompt len
              st.integers(1, 8)),                         # gen len
    min_size=1, max_size=25)


def _slo_reqs(spec):
    return [Request(rid=i, tokens=np.zeros((p,), np.int32),
                    max_new_tokens=g, priority=pr, deadline_ms=d)
            for i, (d, pr, p, g) in enumerate(spec)]


@settings(max_examples=50, deadline=None)
@given(spec=_SLO_REQS, b1=st.integers(1, 12), extra=st.integers(0, 12))
def test_admission_shed_deterministic_and_monotone_property(spec, b1, extra):
    """For a fixed push sequence the bounded EDF queue's shed decisions
    are a pure function of the bound: two identical queues shed the same
    rids for the same reasons in the same order, capacity sheds equal
    ``max(0, feasible - bound)`` exactly, and raising the bound never
    sheds more (the AdmissionQueue docstring contract)."""
    from repro.runtime.slo import AdmissionQueue, ShedReason

    def drive(bound):
        q = AdmissionQueue(bound)
        for r in _slo_reqs(spec):
            q.push(r, now=0)
        return q

    a, b = drive(b1), drive(b1)
    assert [(e.rid, e.reason) for e in a.shed_log] == \
        [(e.rid, e.reason) for e in b.shed_log]
    infeasible = sum(1 for e in a.shed_log
                     if e.reason is ShedReason.INFEASIBLE)
    full = sum(1 for e in a.shed_log if e.reason is ShedReason.QUEUE_FULL)
    feasible = len(spec) - infeasible
    assert full == max(0, feasible - b1)
    assert a.max_depth <= b1
    wider = drive(b1 + extra)
    assert len(wider.shed_log) <= len(a.shed_log)
    assert sum(1 for e in wider.shed_log
               if e.reason is ShedReason.INFEASIBLE) == infeasible


@settings(max_examples=50, deadline=None)
@given(spec=_SLO_REQS, bound=st.one_of(st.none(), st.integers(1, 10)),
       gaps=st.lists(st.integers(0, 6), min_size=1, max_size=30))
def test_admitted_slack_never_negative_property(spec, bound, gaps):
    """Whatever the push sequence and however long entries sit queued,
    ``pop`` never hands the dispatcher infeasible work: every admitted
    entry satisfies ``now + cost <= deadline`` (slack >= 0, stale entries
    shed as EXPIRED instead), and every pushed rid is accounted exactly
    once as admitted or shed."""
    from repro.runtime.slo import AdmissionQueue

    q = AdmissionQueue(bound)
    for r in _slo_reqs(spec):
        q.push(r, now=0)
    admitted, now = [], 0
    for gap in gaps:
        now += gap
        e, _expired = q.pop(now)
        if e is None:
            break
        slack = q.slack(e, now)
        assert slack is None or slack >= 0
        admitted.append(e.rid)
    while True:
        e, _expired = q.pop(now)
        if e is None:
            break
        assert (q.slack(e, now) or 0) >= 0
        admitted.append(e.rid)
    shed = [ev.rid for ev in q.shed_log]
    assert sorted(admitted + shed) == list(range(len(spec)))
    assert len(set(admitted) & set(shed)) == 0


@settings(max_examples=10, deadline=None)
@given(
    trace=st.lists(st.tuples(st.integers(0, 3),       # arrival tick
                             st.integers(1, 5),       # prompt len
                             st.integers(1, 5)),      # gen len
                   min_size=2, max_size=8),
    replicas=st.integers(2, 3), hedge_after=st.integers(1, 3),
    seed=st.integers(0, 99),
)
def test_router_hedging_token_exact_property(trace, replicas, hedge_after,
                                             seed):
    """Hedged re-dispatch never duplicates, drops or reorders tokens:
    whatever copy wins the race, every request's stream equals the
    deterministic batch-1 replay of the fake model, and a second run of
    the same trace routes identically (DESIGN.md Section 13)."""
    from test_engine import fake_api

    from repro.runtime.router import RouterEngine

    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=i, tokens=rng.integers(1, 17, (p,), dtype=np.int32),
                    max_new_tokens=g, arrival=a)
            for i, (a, p, g) in enumerate(trace)]

    def run():
        router = RouterEngine(
            lambda: ServeEngine(api, params, num_slots=2, cache_len=12),
            replicas, hedge_after=hedge_after)
        outs = router.run([dataclasses.replace(r) for r in reqs])
        return router, outs

    r1, outs = run()
    for r in reqs:
        state = int(np.sum(r.tokens)) % 17
        tok = (state + 1) % 17
        expect = [tok]
        for _ in range(r.max_new_tokens - 1):
            state = (state + tok) % 17
            tok = (state + 1) % 17
            expect.append(tok)
        assert list(map(int, outs[r.rid].tokens)) == expect, r.rid
        assert len(outs[r.rid].token_steps) == len(expect)
    r2, outs2 = run()
    assert {k: list(map(int, o.tokens)) for k, o in outs2.items()} == \
        {k: list(map(int, o.tokens)) for k, o in outs.items()}
    assert r1.stats == r2.stats


# ---------------------------------------------------------------------------
# autotuner selection + plan application (repro.tuning, DESIGN.md Section 12)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    table=st.dictionaries(st.text("abcdxyz_0123456789", min_size=1,
                                  max_size=10),
                          st.floats(0.0, 1e4, allow_nan=False,
                                    allow_infinity=False),
                          min_size=1, max_size=12),
    k=st.integers(1, 6), seed=st.integers(0, 999),
)
def test_shortlist_and_winner_deterministic_property(table, k, seed):
    """Shortlist selection and the measured winner are pure functions of
    a frozen score/measurement table: permuting row order never changes
    the outcome, ties always break by name."""
    from repro.tuning.search import select_best, shortlist

    rows = [{"name": n, "score": s} for n, s in table.items()]
    short = shortlist(rows, k)
    rng = np.random.default_rng(seed)
    perm = [rows[i] for i in rng.permutation(len(rows))]
    assert [r["name"] for r in shortlist(perm, k)] == \
        [r["name"] for r in short]
    assert len(short) == min(k, len(rows))
    scores = [r["score"] for r in short]
    assert scores == sorted(scores, reverse=True)
    assert all(r["score"] >= x["score"] for r in short for x in rows
               if x["name"] not in {s["name"] for s in short})

    shuffled = {n: table[n] for n in rng.permutation(list(table))}
    winner = select_best(table)
    assert select_best(shuffled) == winner
    assert table[winner] == max(table.values())
    ties = sorted(n for n, v in table.items() if v == table[winner])
    assert winner == ties[0]                  # deterministic tie-break


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 999), sparsity=st.floats(0.2, 0.9),
    bk=st.sampled_from([16, 32, 64]),
    thr=st.sampled_from([None, 0.05, 0.9]),
)
def test_plan_application_idempotent_property(seed, sparsity, bk, thr):
    """Applying the same kernel plan twice to the same source weights
    yields bit-identical compacted GriffinWeights — plan application has
    no hidden state (rng, caches, mutation of the source tree)."""
    from repro.sparsity import sparsify_params
    from repro.tuning import FamilyPlan, GemmRule

    rng = np.random.default_rng(seed)
    params = {"layers": [
        {"wo": rng.standard_normal((64, 64)).astype(np.float32),
         "w_up": rng.standard_normal((64, 96)).astype(np.float32)}]}
    plan = FamilyPlan(family="x", rules=(
        GemmRule(match="*", block_k=bk, block_n=bk, unit=8,
                 a_threshold=thr),))
    once = sparsify_params(params, sparsity, plan=plan,
                           block_k=16, block_n=16, unit=8)
    twice = sparsify_params(params, sparsity, plan=plan,
                            block_k=16, block_n=16, unit=8)
    for a, b in zip(*(l["layers"][0].values() for l in (once, twice))):
        assert (a.k, a.n, a.block_k, a.block_n, a.a_thr) == \
            (b.k, b.n, b.block_k, b.block_n, b.a_thr)
        assert a.block_k == min(bk, 64) and a.a_thr == thr
        for fa, fb in zip((a.b_comp, a.kidx, a.cnt, a.inv_perm),
                          (b.b_comp, b.kidx, b.cnt, b.inv_perm)):
            if fa is None or fb is None:
                assert fa is None and fb is None
            else:
                np.testing.assert_array_equal(np.asarray(fa),
                                              np.asarray(fb))
