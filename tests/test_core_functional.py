"""Functional fidelity: executing the schedule reproduces the exact GEMM."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.functional import execute_b_sparse
from repro.core.spec import CoreConfig, SPARSE_B_STAR, sparse_b


CORE = CoreConfig()


def _sparse_matrices(m, k, n, density, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k))
    b = rng.standard_normal((k, n)) * (rng.random((k, n)) < density)
    return a, b


@pytest.mark.parametrize("spec", [
    sparse_b(1, 0, 0), sparse_b(4, 0, 0), sparse_b(4, 0, 1),
    sparse_b(2, 1, 1), sparse_b(8, 0, 1, shuffle=True), SPARSE_B_STAR,
])
def test_b_sparse_execution_exact(spec):
    a, b = _sparse_matrices(8, 48, 24, 0.3, seed=0)
    c, ops = execute_b_sparse(a, b, spec, CORE)
    np.testing.assert_allclose(c, a @ b, rtol=1e-12, atol=1e-12)
    assert ops == (b != 0).sum()          # every effectual op exactly once


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 6), k=st.integers(3, 70), n=st.integers(1, 40),
    density=st.floats(0.02, 0.9), db1=st.integers(1, 6),
    db2=st.integers(0, 2), db3=st.integers(0, 2), sh=st.booleans(),
    seed=st.integers(0, 10_000),
)
def test_b_sparse_execution_property(m, k, n, density, db1, db2, db3, sh, seed):
    a, b = _sparse_matrices(m, k, n, density, seed)
    spec = sparse_b(db1, db2, db3, shuffle=sh)
    c, ops = execute_b_sparse(a, b, spec, CORE)
    np.testing.assert_allclose(c, a @ b, rtol=1e-10, atol=1e-10)
    assert ops == (b != 0).sum()
