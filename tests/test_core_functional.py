"""Functional fidelity: executing the schedule reproduces the exact GEMM.

The hypothesis property sweep lives in ``tests/test_properties.py`` (guarded
with ``pytest.importorskip`` — hypothesis is an optional [test] dependency).
"""
import numpy as np
import pytest

from repro.core.functional import execute_b_sparse
from repro.core.spec import CoreConfig, SPARSE_B_STAR, sparse_b


CORE = CoreConfig()


def _sparse_matrices(m, k, n, density, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k))
    b = rng.standard_normal((k, n)) * (rng.random((k, n)) < density)
    return a, b


@pytest.mark.parametrize("spec", [
    sparse_b(1, 0, 0), sparse_b(4, 0, 0), sparse_b(4, 0, 1),
    sparse_b(2, 1, 1), sparse_b(8, 0, 1, shuffle=True), SPARSE_B_STAR,
])
def test_b_sparse_execution_exact(spec):
    a, b = _sparse_matrices(8, 48, 24, 0.3, seed=0)
    c, ops = execute_b_sparse(a, b, spec, CORE)
    np.testing.assert_allclose(c, a @ b, rtol=1e-12, atol=1e-12)
    assert ops == (b != 0).sum()          # every effectual op exactly once


@pytest.mark.parametrize("seed", range(6))
def test_b_sparse_execution_seeds(seed):
    rng = np.random.default_rng(seed + 100)
    m, k, n = rng.integers(1, 7), rng.integers(3, 71), rng.integers(1, 41)
    a, b = _sparse_matrices(int(m), int(k), int(n), 0.25, seed)
    spec = sparse_b(int(rng.integers(1, 7)), int(rng.integers(0, 3)),
                    int(rng.integers(0, 3)), shuffle=bool(rng.integers(2)))
    c, ops = execute_b_sparse(a, b, spec, CORE)
    np.testing.assert_allclose(c, a @ b, rtol=1e-10, atol=1e-10)
    assert ops == (b != 0).sum()
