"""Scheduler invariants: hand cases, paper semantics.

The hypothesis property sweep lives in ``tests/test_properties.py`` (guarded
with ``pytest.importorskip`` — hypothesis is an optional [test] dependency).
"""
import numpy as np
import pytest

from repro.core.scheduler import (schedule, shuffle_lanes, static_pack_cycles,
                                  sparten_tile_cycles)
from repro.core.functional import verify_schedule


def test_dense_stream_takes_T_cycles():
    mask = np.ones((3, 12, 8, 2), dtype=bool)
    s = schedule(mask, 4, 1, 1)
    np.testing.assert_array_equal(s.cycles, 12)


def test_all_zero_stream_capped_by_window():
    mask = np.zeros((2, 20, 8, 1), dtype=bool)
    for d1 in (0, 1, 4):
        s = schedule(mask, d1, 0, 0)
        np.testing.assert_array_equal(s.cycles, -(-20 // (d1 + 1)))


def test_single_lane_backlog_serializes():
    # one lane busy every chunk: no window can help without lane moves
    m = np.zeros((1, 10, 4, 1), dtype=bool)
    m[0, :, 1, 0] = True
    assert schedule(m, 8, 0, 0).cycles[0] == 10
    # with (one-sided, Table II) lane borrowing, lane 0 absorbs every other
    # element of lane 1
    assert schedule(m, 8, 1, 0).cycles[0] <= 6


def test_speedup_never_exceeds_window_cap():
    rng = np.random.default_rng(0)
    mask = rng.random((20, 40, 16, 1)) < 0.05
    for d1 in (1, 3, 7):
        s = schedule(mask, d1, 2, 0)
        assert (s.cycles >= -(-40 // (d1 + 1))).all()


def test_shuffle_preserves_element_count():
    rng = np.random.default_rng(1)
    mask = rng.random((4, 9, 16, 3)) < 0.3
    sh = shuffle_lanes(mask)
    assert sh.sum() == mask.sum()
    # rotation is within groups of 4 lanes
    assert (sh.reshape(4, 9, 4, 4, 3).sum(axis=3) ==
            mask.reshape(4, 9, 4, 4, 3).sum(axis=3)).all()


@pytest.mark.parametrize("seed", range(10))
def test_schedule_invariants_seeds(seed):
    rng = np.random.default_rng(seed)
    t, k0 = int(rng.integers(2, 13)), int(rng.choice([4, 8, 16]))
    g, d1 = int(rng.integers(1, 4)), int(rng.integers(0, 5))
    d2, d3 = int(rng.integers(0, 3)), int(rng.integers(0, 3))
    mask = rng.random((2, t, k0, g)) < rng.uniform(0.05, 0.95)
    s = schedule(mask, d1, d2, d3, record=True)
    verify_schedule(mask, s, d1, d2, d3)


def test_static_bound_leq_greedy():
    """Offline packing can never be worse than the on-the-fly greedy."""
    rng = np.random.default_rng(2)
    mask = rng.random((30, 48, 16, 1)) < 0.2
    greedy = schedule(mask, 4, 0, 0).cycles
    static = static_pack_cycles(mask, 4, 0, 0)
    assert (static <= greedy).all()
    # and never better than the lane-capacity / travel lower bounds
    lane_tot = mask.sum(axis=1).max(axis=(1, 2))
    assert (static >= np.maximum(lane_tot, -(-48 // 5))).all()


def test_sparten_wave_max():
    counts = np.arange(64 * 64).reshape(64, 64)
    waves = sparten_tile_cycles(counts, pe_m=32, pe_n=32)
    assert waves.shape == (2, 2)
    assert waves[1, 1] == counts[32:, 32:].max()
