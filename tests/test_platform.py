"""configs.platform: platform selection, XLA flag staging and the kernel
lowering map the shard_map dispatch consults (DESIGN.md Section 10).

Everything here runs on one CPU device; the one process-global mutation
exercised is ``set_platform(None)`` / ``set_platform("cpu")`` (idempotent
on the CI backend).  GPU flag staging is tested through the pure
``_append_xla_flags`` helper against a monkeypatched environment so the
real backend never re-initializes mid-suite.
"""
import os
import warnings

import jax
import pytest

from repro.configs import platform as plat


def test_resolve_platform_precedence(monkeypatch):
    monkeypatch.delenv("GRIFFIN_PLATFORM", raising=False)
    assert plat.resolve_platform() == jax.default_backend()
    monkeypatch.setenv("GRIFFIN_PLATFORM", "TPU")
    assert plat.resolve_platform() == "tpu"          # env, case-folded
    assert plat.resolve_platform("cpu") == "cpu"     # arg beats env
    with pytest.raises(ValueError):
        plat.resolve_platform("rocm")
    monkeypatch.setenv("GRIFFIN_PLATFORM", "xpu")
    with pytest.raises(ValueError):
        plat.resolve_platform()


def test_kernel_lowering_map(monkeypatch):
    monkeypatch.delenv("GRIFFIN_PLATFORM", raising=False)
    assert plat.kernel_lowering("tpu") == "mosaic"
    assert plat.kernel_lowering("gpu") == "triton"
    assert plat.kernel_lowering("cpu") == "interpret"
    # only the interpret lowering forces interpret-mode pallas_call
    assert plat.kernel_interpret("cpu")
    assert not plat.kernel_interpret("tpu")
    assert not plat.kernel_interpret("gpu")
    # the CI backend is CPU: the no-arg form griffin_linear uses must say
    # interpret so shard_map'd kernels run on the emulated mesh
    if jax.default_backend() == "cpu":
        assert plat.kernel_interpret()


def test_append_xla_flags_deduplicates(monkeypatch):
    monkeypatch.setenv("XLA_FLAGS", "--xla_gpu_triton_gemm_any=False")
    plat._append_xla_flags(plat.GPU_XLA_FLAGS)
    flags = os.environ["XLA_FLAGS"]
    # an already-present flag key is never overridden or duplicated
    assert flags.count("--xla_gpu_triton_gemm_any") == 1
    assert "--xla_gpu_triton_gemm_any=False" in flags
    assert "--xla_gpu_enable_latency_hiding_scheduler=true" in flags


def test_set_platform_default_is_idempotent(monkeypatch):
    monkeypatch.delenv("GRIFFIN_PLATFORM", raising=False)
    before = jax.default_backend()
    assert plat.set_platform() == before
    assert plat.set_platform(before) == before
    assert jax.default_backend() == before


def test_set_host_device_count(monkeypatch):
    monkeypatch.setenv("XLA_FLAGS", "")
    n = len(jax.devices())
    with warnings.catch_warnings():
        warnings.simplefilter("error")               # matching count: quiet
        plat.set_host_device_count(n)
    assert f"--xla_force_host_platform_device_count={n}" \
        in os.environ["XLA_FLAGS"]
    if n != 64:
        # backend is already up with a different count: warn, never no-op
        # silently — the flag still lands for child processes
        with pytest.warns(UserWarning, match="next process"):
            plat.set_host_device_count(64)
