"""Plan-parity tier for the DSE-in-the-loop autotuner (DESIGN.md
Section 12).

What ships from ``repro.launch.autotune`` is a versioned kernel plan that
changes *how* GEMMs execute — compaction granularity at
``sparsify_params`` time, Mode-selection thresholds at serve time — and
must never change *what* they compute.  This tier pins both halves:

  - plan artifact: JSON round-trip, schema-version rejection, first-match
    rule resolution;
  - plan application is not a no-op: a per-family plan visibly changes
    the compacted ``GriffinWeights`` block shapes, stamps per-GEMM
    ``a_thr``, and flips ``select_mode`` outcomes (observed through the
    engine mode and the ``dual`` kernel-dispatch bucket);
  - plan parity: tuned-vs-default token identity across families
    {dense, ssm} x weight representations {pruned-dense, sparse-B
    compacted} x decode_chunk {1, 3};
  - tier2 + mesh: a plan survives ``MeshServeEngine`` + shard_map
    dispatch token-exactly (thresholds are trace-time constants; planned
    granularity keeps whole N tiles per model shard).

The deterministic shortlist/idempotency properties live in
tests/test_properties.py; the sweep-cache schema coupling in
tests/test_dse_cache.py.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.dse import CONFIG_SCHEMA_VERSION
from repro.core.spec import Mode
from repro.kernels.griffin_spmm.ops import GriffinWeights, decompact_weights
from repro.launch.mesh import serve_mesh
from repro.models import build_model
from repro.models.common import (kernel_dispatch_counts,
                                 reset_kernel_dispatch)
from repro.runtime.engine import ServeEngine, synthetic_trace
from repro.runtime.mesh_serve import MeshServeEngine
from repro.sparsity import sparsify_params
from repro.tuning import (PLAN_SCHEMA_VERSION, FamilyPlan, GemmRule,
                          KernelPlan, PlanSchemaError, load_plan)
from repro.tuning.measure import FAMILY_ARCHS, PRUNE
from repro.tuning.search import Candidate, enumerate_candidates


def _needs_devices(n: int):
    return pytest.mark.skipif(
        len(jax.devices()) < n,
        reason=f"needs {n} devices (export XLA_FLAGS="
               "--xla_force_host_platform_device_count=8)")


def _workload(family, requests=3):
    """Reduced model + deterministic mixed trace for one family."""
    cfg = get_config(FAMILY_ARCHS[family]).reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    trace = lambda: synthetic_trace(cfg, num_requests=requests, seed=3,
                                    prompt_lens=(4, 6), gen_lens=(3, 5),
                                    arrival_every=1)
    return cfg, api, params, trace


def _griffin_leaves(params):
    return [l for l in jax.tree.leaves(
        params, is_leaf=lambda x: isinstance(x, GriffinWeights))
        if isinstance(l, GriffinWeights)]


def _decompact_any(gw):
    """Dense reconstruction of a (possibly stacked) GriffinWeights."""
    if gw.b_comp.ndim == 2:
        return np.asarray(decompact_weights(gw))
    return np.stack([np.asarray(decompact_weights(dataclasses.replace(
        gw, b_comp=gw.b_comp[i], kidx=gw.kidx[i], cnt=gw.cnt[i],
        inv_perm=None if gw.inv_perm is None else gw.inv_perm[i])))
        for i in range(gw.b_comp.shape[0])])


def _tokens(outs):
    return {r: tuple(int(t) for t in o.tokens) for r, o in outs.items()}


_PLAN = FamilyPlan(
    family="dense", a_threshold=0.9,
    rules=(GemmRule(match="*", block_k=64, block_n=64, unit=8,
                    a_threshold=0.9),),
    predicted={"bk64_bn64_u8_f8_t0p9": {"score": 1.0}},
    measured={"winner": "bk64_bn64_u8_f8_t0p9"})


# ---------------------------------------------------------------------------
# plan artifact: JSON round-trip + schema rejection
# ---------------------------------------------------------------------------

def test_plan_json_round_trip(tmp_path):
    plan = KernelPlan(
        families={"dense": _PLAN,
                  "ssm": FamilyPlan(family="ssm", b_threshold=0.2)},
        meta={"tool": "repro.launch.autotune", "sparsity": 0.8})
    path = str(tmp_path / "plan.json")
    plan.save(path)
    re = load_plan(path)
    assert re.schema_version == PLAN_SCHEMA_VERSION
    assert re.families == plan.families      # frozen dataclasses: deep ==
    assert re.meta == plan.meta
    assert re.family("dense").rule_for("wo").block_k == 64
    assert re.family("moe") is None


def test_plan_schema_version_rejected(tmp_path):
    doc = KernelPlan(families={"dense": _PLAN}).to_json()
    for bad in (PLAN_SCHEMA_VERSION + 1, PLAN_SCHEMA_VERSION - 1, None,
                str(PLAN_SCHEMA_VERSION)):
        doc["schema_version"] = bad
        path = str(tmp_path / "bad.json")
        with open(path, "w") as f:
            json.dump({k: v for k, v in doc.items()
                       if v is not None or k != "schema_version"}, f)
        with pytest.raises(PlanSchemaError):
            load_plan(path)


def test_plan_and_sweep_cache_share_one_schema_constant():
    # one bump must simultaneously reject stale plan files and cold-start
    # DSE sweep rows cached under the old schema (DESIGN.md Section 12)
    assert PLAN_SCHEMA_VERSION == CONFIG_SCHEMA_VERSION


def test_rule_resolution_first_match_wins():
    fp = FamilyPlan(family="dense", rules=(
        GemmRule(match="wo", block_k=32),
        GemmRule(match="*", block_k=64)))
    assert fp.rule_for("wo").block_k == 32
    assert fp.rule_for("w_up").block_k == 64      # falls to the "*" rule
    assert FamilyPlan(family="dense").rule_for("wo") is None


def test_enumerate_candidates_budget_and_determinism():
    shapes = {"wo": (64, 64), "w_up": (64, 256)}
    cands = enumerate_candidates(shapes, budget=8)
    assert len(cands) == 8
    assert cands == enumerate_candidates(shapes, budget=8)
    assert len({c.name for c in cands}) == len(cands)
    # fitted to the actual dims: nothing coarser than the smallest GEMM
    assert all(c.block_k <= 64 and c.block_n <= 64 for c in cands)
    # a small budget still spans granularity AND both thresholds
    assert len({c.block_k for c in cands}) > 1
    assert len({c.a_threshold for c in cands}) > 1


def test_candidate_family_plan_shape():
    c = Candidate(block_k=64, block_n=64, unit=8, fanin=8, a_threshold=0.9)
    fp = c.family_plan("dense")
    assert fp.a_threshold == 0.9
    r = fp.rule_for("anything")
    assert (r.block_k, r.block_n, r.unit, r.a_threshold) == (64, 64, 8, 0.9)


# ---------------------------------------------------------------------------
# plan application is not a no-op (engine-level asserts)
# ---------------------------------------------------------------------------

def test_plan_changes_sparsify_block_shapes():
    _, _, params, _ = _workload("dense")
    base = _griffin_leaves(sparsify_params(params, 0.8, compact=True,
                                           **PRUNE))
    tuned = _griffin_leaves(sparsify_params(params, 0.8, compact=True,
                                            plan=_PLAN, **PRUNE))
    assert base and len(base) == len(tuned)
    assert all(g.block_k == 16 and g.block_n == 16 and g.a_thr is None
               for g in base)
    # the plan's "*" rule steered every leaf's compaction granularity
    # (clamped to the leaf dims) and stamped the per-GEMM threshold
    assert all(g.block_k == min(64, g.k) and g.block_n == min(64, g.n)
               and g.a_thr == 0.9 for g in tuned)
    assert any(g.block_k != b.block_k or g.block_n != b.block_n
               for g, b in zip(tuned, base))
    # compaction moved, values did not: both granularities reconstruct
    # the same pruned matrices (the mechanism behind token parity)
    for g, b in zip(tuned, base):
        k = min(g.k, b.k)
        np.testing.assert_array_equal(_decompact_any(g)[..., :k, :],
                                      _decompact_any(b)[..., :k, :])


def test_family_threshold_changes_engine_select_mode():
    """The plan's a_threshold flips the engine's global Mode decision
    (AB -> B under declared activation sparsity 0.5) and turns the dual
    kernels off — with token-identical output."""
    cfg, api, params, trace = _workload("dense")
    sp = sparsify_params(params, 0.8, compact=True, **PRUNE)
    kw = dict(num_slots=4, cache_len=16, use_kernels=True, interpret=True,
              a_sparsity=0.5, decode_chunk=3)
    base = ServeEngine(api, sp, **kw)
    assert base.mode == Mode.AB
    reset_kernel_dispatch()
    ref = _tokens(base.run(trace()))
    assert kernel_dispatch_counts().get("dual", 0) > 0

    fp = FamilyPlan(family=cfg.family, a_threshold=0.9)
    tuned = ServeEngine(api, sp, plan=fp, **kw)
    assert tuned.mode == Mode.B              # 0.5 declared < 0.9 planned
    reset_kernel_dispatch()
    got = _tokens(tuned.run(trace()))
    assert kernel_dispatch_counts().get("dual", 0) == 0
    assert got == ref


def test_per_gemm_a_thr_overrides_scope_threshold():
    """A rule-level a_threshold rides on the compacted weights
    (``GriffinWeights.a_thr``) and wins over the scope threshold inside
    ``griffin_linear`` even when the engine's global mode stays AB."""
    cfg, api, params, trace = _workload("dense")
    fp = FamilyPlan(family=cfg.family,
                    rules=(GemmRule(match="*", a_threshold=0.9),))
    sp = sparsify_params(params, 0.8, compact=True, plan=fp, **PRUNE)
    assert all(g.a_thr == 0.9 for g in _griffin_leaves(sp))
    kw = dict(num_slots=4, cache_len=16, use_kernels=True, interpret=True,
              a_sparsity=0.5, decode_chunk=3)
    # engine given only the rules (no family-level threshold): global
    # mode still AB, but every GEMM's own a_thr vetoes the dual kernels
    eng = ServeEngine(api, sp, plan=fp, **kw)
    assert eng.mode == Mode.AB
    reset_kernel_dispatch()
    got = _tokens(eng.run(trace()))
    assert kernel_dispatch_counts().get("dual", 0) == 0

    base = ServeEngine(api, sparsify_params(params, 0.8, compact=True,
                                            **PRUNE), **kw)
    reset_kernel_dispatch()
    ref = _tokens(base.run(trace()))
    assert kernel_dispatch_counts().get("dual", 0) > 0
    assert got == ref


def test_family_b_threshold_reaches_engine():
    _, api, params, _ = _workload("dense")
    sp = sparsify_params(params, 0.8, compact=True, **PRUNE)
    base = ServeEngine(api, sp, num_slots=4, cache_len=16,
                       use_kernels=True, interpret=True)
    assert base.mode == Mode.B
    tuned = ServeEngine(api, sp, num_slots=4, cache_len=16,
                        use_kernels=True, interpret=True,
                        plan=FamilyPlan(family="dense", b_threshold=0.999))
    assert tuned.b_sparsity == base.b_sparsity
    assert tuned.mode == Mode.DENSE          # planned b gate vetoes B


# ---------------------------------------------------------------------------
# plan parity: tuned-vs-default token identity matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [1, 3])
@pytest.mark.parametrize("compacted", [False, True],
                         ids=["dense", "sparseB"])
@pytest.mark.parametrize("family", ["dense", "ssm"])
def test_tuned_vs_default_token_identity(family, compacted, chunk):
    """The plan-parity contract across the engine matrix: the winning
    autotuner shape (coarse compaction + raised thresholds) serves the
    exact default token streams on both weight representations."""
    cfg, api, params, trace = _workload(family)
    plan = dataclasses.replace(_PLAN, family=cfg.family)
    if compacted:
        base_p = sparsify_params(params, 0.8, compact=True, **PRUNE)
        tuned_p = sparsify_params(params, 0.8, compact=True, plan=plan,
                                  **PRUNE)
        kw = dict(use_kernels=True, interpret=True)
    else:
        # pruned-dense twin: the plan only moves the engine thresholds
        base_p = tuned_p = sparsify_params(params, 0.8, compact=False,
                                           **PRUNE)
        kw = {}
    base = ServeEngine(api, base_p, num_slots=4, cache_len=16,
                       decode_chunk=chunk, **kw)
    ref = _tokens(base.run(trace()))
    tuned = ServeEngine(api, tuned_p, num_slots=4, cache_len=16,
                        decode_chunk=chunk, plan=plan, **kw)
    got = _tokens(tuned.run(trace()))
    assert got == ref, (family, compacted, chunk)
    assert all(len(t) > 0 for t in got.values())


# ---------------------------------------------------------------------------
# tier2 + mesh: a plan survives shard_map dispatch (CI sharded job)
# ---------------------------------------------------------------------------

@pytest.mark.tier2
@pytest.mark.mesh
@_needs_devices(8)
def test_plan_survives_mesh_shard_map():
    """Planned granularity + thresholds through ``MeshServeEngine`` on a
    2x4 mesh: the sharded tuned engine serves the unsharded *default*
    engine's tokens through the shard_map'd kernels (never the oracle).
    block_n=16 keeps whole N tiles per model shard (d_model 64 / model
    axis 4), the shardability contract from DESIGN.md Section 10."""
    cfg, api, params, trace = _workload("dense")
    plan = FamilyPlan(
        family=cfg.family, a_threshold=0.9,
        rules=(GemmRule(match="*", block_k=64, block_n=16, unit=8,
                        a_threshold=0.9),))
    default_p = sparsify_params(params, 0.8, compact=True, **PRUNE)
    tuned_p = sparsify_params(params, 0.8, compact=True, plan=plan, **PRUNE)

    ref_eng = ServeEngine(api, default_p, num_slots=4, cache_len=16,
                          decode_chunk=3, use_kernels=True, interpret=True)
    ref = _tokens(ref_eng.run(trace()))

    eng = MeshServeEngine(api, tuned_p, mesh=serve_mesh("2x4"), num_slots=4,
                          cache_len=16, decode_chunk=3, use_kernels=True,
                          interpret=True, plan=plan)
    # the plan rode through resharding: every GriffinWeights leaf placed
    # on the mesh still carries the planned granularity + threshold
    leaves = _griffin_leaves(eng.params)
    assert leaves and all(g.block_n == 16 and g.a_thr == 0.9
                          for g in leaves)
    assert eng._a_threshold == 0.9
    reset_kernel_dispatch()
    got = _tokens(eng.run(trace()))
    counts = kernel_dispatch_counts()
    assert counts.get("shard_map", 0) > 0, counts
    assert counts.get("spmd_oracle", 0) == 0, counts
    assert got == ref


# ---------------------------------------------------------------------------
# deterministic twins of the tests/test_properties.py hypothesis sweeps
# (those need the optional [test] dependency; these always run)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_shortlist_and_winner_deterministic(seed):
    from repro.tuning.search import select_best, shortlist

    rng = np.random.default_rng(seed)
    names = [f"c{i}" for i in range(10)]
    table = {n: float(rng.integers(0, 5)) for n in names}   # forced ties
    rows = [{"name": n, "score": s} for n, s in table.items()]
    perm = [rows[i] for i in rng.permutation(len(rows))]
    assert [r["name"] for r in shortlist(perm, 4)] == \
        [r["name"] for r in shortlist(rows, 4)]
    winner = select_best(table)
    shuffled = {n: table[n] for n in rng.permutation(names)}
    assert select_best(shuffled) == winner
    assert winner == sorted(n for n in names
                            if table[n] == max(table.values()))[0]


@pytest.mark.parametrize("bk,thr", [(16, None), (32, 0.05), (64, 0.9)])
def test_plan_application_idempotent(bk, thr):
    """sparsify_params(plan=...) twice from the same source: bit-identical
    compacted GriffinWeights — no hidden rng/cache/mutation."""
    rng = np.random.default_rng(7)
    params = {"layers": [
        {"wo": rng.standard_normal((64, 64)).astype(np.float32),
         "w_up": rng.standard_normal((64, 96)).astype(np.float32)}]}
    plan = FamilyPlan(family="x", rules=(
        GemmRule(match="*", block_k=bk, block_n=bk, unit=8,
                 a_threshold=thr),))
    kw = dict(block_k=16, block_n=16, unit=8)
    once = sparsify_params(params, 0.7, plan=plan, **kw)
    twice = sparsify_params(params, 0.7, plan=plan, **kw)
    for a, b in zip(_griffin_leaves(once), _griffin_leaves(twice)):
        assert (a.k, a.n, a.block_k, a.block_n, a.a_thr) == \
            (b.k, b.n, b.block_k, b.block_n, b.a_thr)
        assert a.block_k == bk and a.a_thr == thr
        for fa, fb in zip((a.b_comp, a.kidx, a.cnt, a.inv_perm),
                          (b.b_comp, b.kidx, b.cnt, b.inv_perm)):
            if fa is None or fb is None:
                assert fa is None and fb is None
            else:
                np.testing.assert_array_equal(np.asarray(fa),
                                              np.asarray(fb))
