"""SLO-aware multi-replica router tests (DESIGN.md Section 13).

Everything runs tier-1 on the deterministic fake ``ModelApi`` from
test_engine (next token is a pure function of the running token sum, so
any routing bug — wrong replica, lost prefix on retry, duplicated hedge
tokens — changes the stream).  The single-engine oracle for every parity
assertion is an uninterrupted ``ServeEngine`` run of the same request;
the chaos-marked replica-kill matrix lives in test_fault_tolerance.py.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.spec import Mode
from repro.runtime.engine import Attribution, Request, ServeEngine
from repro.runtime.fault import ReplicaFault, parse_fault_spec
from repro.runtime.router import RouterEngine
from repro.runtime.slo import (AdmissionQueue, CostModel, DegradationConfig,
                               DegradationLadder, ShedReason, latency_summary,
                               request_rows)

from tests.test_engine import fake_api


def _mk(api, params, slots=2, cache_len=32, **kw):
    return lambda: ServeEngine(api, params, num_slots=slots,
                               cache_len=cache_len, **kw)


def _trace(n, *, arrival_every=0, gen=4, prompt=4, **slo):
    return [Request(rid=i, tokens=np.full((prompt,), (i % 7) + 1, np.int32),
                    max_new_tokens=gen, arrival=i * arrival_every, **slo)
            for i in range(n)]


def _oracle(api, params, reqs, cache_len=32):
    """rid -> tokens from an uninterrupted single-engine run (slots
    generous so scheduling cannot interleave differently per request)."""
    ref = {}
    for r in reqs:
        eng = ServeEngine(api, params, num_slots=1, cache_len=cache_len)
        out = eng.run([dataclasses.replace(r, arrival=0)])
        ref[r.rid] = out[r.rid].tokens
    return ref


# ---------------------------------------------------------------------------
# admission queue / cost model / ladder units
# ---------------------------------------------------------------------------

def test_cost_model_buckets_prefill():
    cm = CostModel(prefill_tokens_per_step=8)
    assert cm.estimate(3, 4) == 1 + 4
    assert cm.estimate(3, 4, bucket=16) == 2 + 4
    assert cm.estimate(100, 1) == 13 + 1


def test_admission_queue_sheds_exactly_overflow():
    q = AdmissionQueue(bound=3)
    reqs = _trace(8)
    events = [q.push(r, now=0) for r in reqs]
    sheds = [e for e in events if e is not None]
    assert len(sheds) == 5 and q.depth == 3
    assert all(e.reason == ShedReason.QUEUE_FULL for e in sheds)
    assert q.max_depth == 3
    # no deadlines: EDF order degrades to (priority, submission) — the
    # queue keeps the first three, sheds every later arrival
    assert sorted(e.rid for e in sheds) == [3, 4, 5, 6, 7]


def test_admission_queue_prefers_earliest_deadline_and_priority():
    q = AdmissionQueue(bound=2)
    late = Request(rid=0, tokens=np.ones(2, np.int32), max_new_tokens=2,
                   deadline_ms=50)
    soon = Request(rid=1, tokens=np.ones(2, np.int32), max_new_tokens=2,
                   deadline_ms=10)
    best_effort = Request(rid=2, tokens=np.ones(2, np.int32),
                          max_new_tokens=2)
    assert q.push(late, 0) is None and q.push(best_effort, 0) is None
    ev = q.push(soon, 0)         # displaces the best-effort entry
    assert ev is not None and ev.rid == 2
    e1, _ = q.pop(0)
    e2, _ = q.pop(0)
    assert (e1.rid, e2.rid) == (1, 0)


def test_admission_queue_infeasible_and_expired():
    q = AdmissionQueue(bound=4, cost_model=CostModel(per_token_steps=1.0))
    hopeless = Request(rid=0, tokens=np.ones(2, np.int32),
                       max_new_tokens=10, deadline_ms=3)
    ev = q.push(hopeless, now=0)
    assert ev.reason == ShedReason.INFEASIBLE
    ok = Request(rid=1, tokens=np.ones(2, np.int32), max_new_tokens=2,
                 deadline_ms=6)
    assert q.push(ok, now=0) is None
    # admitted => slack never negative at pop time
    entry, expired = q.pop(now=2)
    assert entry is not None and not expired
    assert q.slack(entry, now=2) >= 0
    q.push(ok, now=0)
    entry, expired = q.pop(now=5)      # 5 + cost(3) > deadline(6)
    assert entry is None
    assert [e.reason for e in expired] == [ShedReason.EXPIRED]


def test_degradation_ladder_hysteresis():
    lad = DegradationLadder(DegradationConfig(patience=2))
    levels = [lad.update(p, t) for t, p in enumerate(
        [0.9, 0.9,          # 2 ticks above high water -> level 1
         0.5,               # between the water marks: streaks reset
         0.9, 0.9,          # -> level 2
         0.1, 0.1,          # 2 ticks below low water -> back to 1
         0.1, 0.1])]        # -> 0
    assert levels == [0, 1, 1, 1, 2, 2, 1, 1, 0]
    assert [lvl for _, lvl in lad.history] == [1, 2, 1, 0]


def test_parse_replica_fault_spec():
    spec = parse_fault_spec("replica:1@3:decode:5")
    f = spec.build_replica()
    assert (f.replica, f.at_step, f.during, f.recover_after) == (1, 3,
                                                                 "decode", 5)
    f2 = parse_fault_spec("replica:0@2").build_replica()
    assert f2.during == "any" and f2.recover_after is None
    with pytest.raises(ValueError):
        parse_fault_spec("replica:0@2:nonsense")
    with pytest.raises(ValueError):
        parse_fault_spec("replica:0@2:idle:0")


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------

def test_router_tokens_match_single_engine_oracle():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    reqs = _trace(9, arrival_every=1)
    router = RouterEngine(_mk(api, params), 3)
    outs = router.run(reqs)
    ref = _oracle(api, params, reqs)
    assert sorted(outs) == list(range(9))
    for r in reqs:
        assert outs[r.rid].tokens == ref[r.rid], f"rid {r.rid} diverged"
        assert outs[r.rid].attribution == Attribution.NORMAL
    assert router.stats["completed"] == 9 and router.stats["shed"] == 0


def test_router_is_deterministic():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))

    def once():
        router = RouterEngine(_mk(api, params), 2, queue_bound=3,
                              hedge_after=2,
                              degradation=DegradationConfig())
        outs = router.run(_trace(12, gen=3, deadline_ms=20))
        return ([(o.rid, tuple(o.tokens), o.attribution, o.finished,
                  o.replica) for o in outs.values()],
                [(e.rid, e.step, e.reason) for e in router.shed_log],
                router.clock, dict(router.stats))

    assert once() == once()


def test_router_bounded_queue_sheds_and_stays_bounded():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    router = RouterEngine(_mk(api, params, slots=1), 2, queue_bound=2)
    reqs = _trace(10, gen=6)              # all arrive at tick 0
    outs = router.run(reqs)
    assert router.stats["shed"] > 0
    assert router.max_queue_depth <= 2
    shed = [o for o in outs.values() if o.attribution == Attribution.SHED]
    done = [o for o in outs.values() if o.finished >= 0]
    assert len(shed) == router.stats["shed"]
    assert len(shed) + len(done) == len(reqs)
    for o in shed:
        assert o.shed_reason == "queue_full" and o.tokens == []


def test_router_priority_shed_at_level3():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    router = RouterEngine(_mk(api, params, slots=1), 1, queue_bound=8,
                          degradation=DegradationConfig(
                              patience=1, shed_min_priority=1))
    # a tick-0 flood drives the ladder to level 3, then late low-priority
    # arrivals hit the priority shed (level 3 acts at admission time)
    reqs = [dataclasses.replace(r, priority=i % 2)
            for i, r in enumerate(_trace(10, gen=6))]
    late = [Request(rid=10 + i, tokens=np.full((4,), 2, np.int32),
                    max_new_tokens=6, arrival=6 + i, priority=1)
            for i in range(4)]
    outs = router.run(reqs + late)
    degraded = [o for o in outs.values() if o.shed_reason == "degraded"]
    assert degraded, "ladder never reached the priority-shed level"
    by_rid = {r.rid: r for r in reqs + late}
    for o in degraded:
        assert by_rid[o.rid].priority >= 1
    assert router.ladder.level >= 0 and router.ladder.history


def test_router_hedges_stalled_requests_token_exact():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    # 2-slot replicas admit one request per engine tick, so the second
    # request dispatched to a replica has no first token after a tick —
    # stalled past the hedge threshold, it re-dispatches to the replica
    # with the spare slot and both copies race token-identically
    router = RouterEngine(_mk(api, params, slots=2), 3, hedge_after=1)
    reqs = _trace(5, gen=5)
    outs = router.run(reqs)
    ref = _oracle(api, params, reqs)
    assert router.stats["hedged"] > 0
    for r in reqs:
        o = outs[r.rid]
        assert o.finished >= 0
        assert o.tokens == ref[r.rid], f"rid {r.rid} diverged"
        # no duplicate / reordered tokens regardless of which copy won
        assert len(o.tokens) == r.max_new_tokens
    hedged = [o for o in outs.values() if o.hedged]
    assert hedged and all(o.attribution == Attribution.HEDGED
                          for o in hedged)
    # the loser was cancelled: no engine still owns a hedged rid
    for h in router.replicas:
        for o in hedged:
            eng_out = h.engine.outputs.get(o.rid)
            assert eng_out is None or o.replica == h.index


def test_router_replica_kill_retries_and_rejoins():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    fault = ReplicaFault(replica=1, at_step=1, during="decode",
                         recover_after=2)
    # decode_chunk=2 spans each request over several ticks, so the fault
    # site actually observes replica 1 mid-decode
    router = RouterEngine(_mk(api, params, decode_chunk=2), 2,
                          replica_faults=[fault])
    reqs = _trace(8, arrival_every=1, gen=5)
    outs = router.run(reqs)
    ref = _oracle(api, params, reqs)
    assert fault.fired
    events = [h["event"] for h in router.health_log]
    assert events == ["kill", "rejoin"]
    assert router.stats["retried"] > 0
    for r in reqs:
        assert outs[r.rid].finished >= 0
        assert outs[r.rid].tokens == ref[r.rid], f"rid {r.rid} diverged"
    retried = [o for o in outs.values()
               if o.attribution == Attribution.RETRIED]
    assert retried and all(o.retries >= 1 for o in retried)
    assert all(h.up for h in router.replicas)


def test_router_no_survivors_raises():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    router = RouterEngine(_mk(api, params), 1, replica_faults=[
        ReplicaFault(replica=0, at_step=0, during="any")])
    with pytest.raises(RuntimeError, match="no live replicas"):
        router.run(_trace(2))


# ---------------------------------------------------------------------------
# engine hooks the router depends on
# ---------------------------------------------------------------------------

def test_engine_cancel_frees_slot_and_queue():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    eng = ServeEngine(api, params, num_slots=1, cache_len=32)
    a, b = _trace(2, gen=6)
    eng.add(a)
    eng.add(b)
    eng.step()                       # admits a; b queued
    assert eng.load == 2
    assert eng.cancel(b.rid) and eng.load == 1       # waiting removal
    assert eng.cancel(a.rid) and eng.load == 0       # running removal
    assert not eng.cancel(a.rid)                     # unknown now
    eng.step()
    assert eng.outputs[a.rid].finished < 0           # never force-finished


def test_engine_chunk_cap_preserves_tokens():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    reqs = _trace(4, gen=8)
    free = ServeEngine(api, params, num_slots=2, cache_len=32)
    outs_free = free.run([dataclasses.replace(r) for r in reqs])
    capped = ServeEngine(api, params, num_slots=2, cache_len=32)
    capped.chunk_cap = 2
    outs_cap = capped.run([dataclasses.replace(r) for r in reqs])
    for r in reqs:
        assert outs_cap[r.rid].tokens == outs_free[r.rid].tokens
    # the cap bit: more, shorter chunks for the same decode work
    assert capped.stats["chunk_calls"] > free.stats["chunk_calls"]


def test_engine_set_degraded_forces_cheaper_mode():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    eng = ServeEngine(api, params, num_slots=1, cache_len=32)
    eng.b_sparsity = 0.03            # pruned, but under the B threshold
    eng.mode = eng._select_mode()
    assert eng.mode == Mode.DENSE
    eng.set_degraded(True)
    assert eng.mode == Mode.B and eng.degraded
    eng.set_degraded(True)           # idempotent
    eng.set_degraded(False)
    assert eng.mode == Mode.DENSE
    assert [m for _, m in eng.mode_history][-2:] == [Mode.B, Mode.DENSE]


def test_request_output_timestamps_and_slo_rows():
    api = fake_api()
    params = api.init(jax.random.PRNGKey(0))
    reqs = _trace(3, arrival_every=2, gen=4, ttft_deadline_ms=8,
                  deadline_ms=40)
    eng = ServeEngine(api, params, num_slots=2, cache_len=32)
    outs = eng.run([dataclasses.replace(r) for r in reqs])
    for o in outs.values():
        assert len(o.token_steps) == len(o.tokens)
        assert o.token_steps == sorted(o.token_steps)
        assert o.attribution == Attribution.NORMAL
    rows = request_rows(outs, reqs)
    assert [r["rid"] for r in rows] == [0, 1, 2]
    assert all(r["ttft"] is not None and r["ttft"] >= 0 for r in rows)
    summary = latency_summary(rows)
    assert summary["completed"] == 3 and summary["shed"] == 0
    assert summary["slo_attainment"] is not None
