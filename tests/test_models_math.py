"""Model math: flash attention vs direct softmax, chunkwise mLSTM vs
step-recurrent, local attention vs masked reference, RG-LRU scan vs loop,
MoE dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.attention import attention, decode_attention, local_attention
from repro.models.moe import init_moe, moe_ffn
from repro.models import xlstm as xl
from repro.models import rglru as rg


def _ref_attn(q, k, v, causal=True, window=None):
    B, S, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qg = q.reshape(B, S, KVH, G, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) / np.sqrt(hd)
    qpos, kpos = np.arange(S), np.arange(k.shape[1])
    m = np.ones((S, k.shape[1]), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window:
        m &= qpos[:, None] - kpos[None, :] < window
    logits = jnp.where(m[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, -1)
    return jnp.einsum("bkgqs,bskd->bkgqd", p, v).transpose(0, 3, 1, 2, 4
                                                           ).reshape(q.shape)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 7),
                                           (False, None)])
def test_flash_attention_fwd_bwd(causal, window):
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(2, 29, 8, 16), jnp.float32)
    k = jnp.asarray(rng.randn(2, 29, 4, 16), jnp.float32)
    v = jnp.asarray(rng.randn(2, 29, 4, 16), jnp.float32)
    out = attention(q, k, v, causal=causal, window=window, kv_chunk=8)
    ref = _ref_attn(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
    g1 = jax.grad(lambda *a: (attention(*a, causal=causal, window=window,
                                        kv_chunk=8) ** 2).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: (_ref_attn(*a, causal, window) ** 2).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_local_attention_matches_masked_reference():
    rng = np.random.RandomState(1)
    B, S, H, KVH, hd, W = 2, 48, 4, 2, 8, 16
    q = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, KVH, hd), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, KVH, hd), jnp.float32)
    out = local_attention(q, k, v, window=W, q_chunk=8)
    ref = _ref_attn(q, k, v, causal=True, window=W)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_decode_matches_prefix_of_full_attention():
    rng = np.random.RandomState(2)
    B, S, H, KVH, hd = 2, 12, 4, 2, 8
    q = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, KVH, hd), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, KVH, hd), jnp.float32)
    full = _ref_attn(q, k, v, causal=True)
    last = decode_attention(q[:, -1:], k, v, jnp.asarray(S - 1))
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, -1]), atol=2e-5)


def _xcfg():
    return ModelConfig(name="x", family="ssm", num_layers=2, d_model=32,
                       num_heads=2, num_kv_heads=2, d_ff=0, vocab_size=64,
                       xlstm_pattern=("m", "s"), dtype="float32",
                       remat=False)


def test_mlstm_chunkwise_equals_stepwise():
    """The chunkwise-parallel train path and the O(1)-state recurrent decode
    path implement the same recurrence."""
    cfg = _xcfg()
    p = xl.init_mlstm(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.RandomState(3).randn(2, 16, 32), jnp.float32)
    out_chunk, st_chunk = xl.mlstm_seq(cfg, p, x, chunk=8)
    # step-by-step with chunk=1
    st = None
    outs = []
    for t in range(16):
        o, st = xl.mlstm_seq(cfg, p, x[:, t:t + 1], state=st, chunk=1)
        outs.append(o)
    out_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(out_step),
                               rtol=2e-4, atol=2e-4)
    for a, b in zip(st_chunk, st):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=2e-4)


def test_rg_lru_scan_equals_loop():
    cfg = ModelConfig(name="r", family="hybrid", num_layers=3, d_model=16,
                      num_heads=2, num_kv_heads=1, d_ff=32, vocab_size=64,
                      window=8, block_pattern=("rec", "rec", "attn"),
                      lru_width=16, dtype="float32", remat=False)
    p = rg.init_rec_block(cfg, jax.random.PRNGKey(1))
    x = jnp.asarray(np.random.RandomState(4).randn(2, 10, 16), jnp.float32)
    out_seq, (h_last, conv) = rg.rec_mix(cfg, p, x)
    st = None
    outs = []
    for t in range(10):
        o, st = rg.rec_mix(cfg, p, x[:, t:t + 1], state=st)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(out_seq), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st[0]), np.asarray(h_last),
                               rtol=1e-4, atol=1e-4)


def test_moe_dispatch_invariants():
    """Every kept token lands in exactly one slot of its expert; output is
    the prob-weighted sum of its experts' outputs; no (N,E,C) tensor."""
    moe = MoEConfig(num_experts=4, top_k=2, capacity_factor=2.0)
    p = init_moe(jax.random.PRNGKey(2), 16, 32, moe, jnp.float32)
    x = jnp.asarray(np.random.RandomState(5).randn(24, 16), jnp.float32)
    out, aux = moe_ffn(p, x, moe)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0
    # identical tokens must produce identical outputs (routing determinism)
    x2 = jnp.concatenate([x[:1]] * 4 + [x[1:5]], axis=0)
    out2, _ = moe_ffn(p, x2, moe)
    np.testing.assert_allclose(np.asarray(out2[0]), np.asarray(out2[1]),
                               rtol=1e-5, atol=1e-5)
    # gradient flows
    g = jax.grad(lambda xx: moe_ffn(p, xx, moe)[0].sum())(x)
    assert np.isfinite(np.asarray(g)).all()


def test_moe_valid_mask_makes_padding_invisible():
    """Bucketed prefill right-pads prompts: with the pad rows masked out
    via ``valid``, the real rows' outputs must be bit-identical to a
    drop-free run of the real rows alone — pads consume no expert capacity
    and contribute nothing (models/moe.py, DESIGN.md Section 9)."""
    moe = MoEConfig(num_experts=4, top_k=2, capacity_factor=1.25)
    p = init_moe(jax.random.PRNGKey(3), 16, 32, moe, jnp.float32)
    rng = np.random.RandomState(6)
    x = jnp.asarray(rng.randn(10, 16), jnp.float32)
    pads = jnp.asarray(rng.randn(6, 16), jnp.float32)   # garbage pad rows
    ref, _ = moe_ffn(p, x, moe, drop_free=True)
    valid = jnp.arange(16) < 10
    out, _ = moe_ffn(p, jnp.concatenate([x, pads]), moe, valid=valid)
    np.testing.assert_array_equal(np.asarray(out[:10]), np.asarray(ref))
    # pad rows emit exactly zero (routed to the dump row)
    assert not np.asarray(out[10:]).any()
