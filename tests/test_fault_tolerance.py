"""Fault-tolerant elastic serving tests (DESIGN.md Section 11).

Two tiers:

  - tier-1 (unmarked, runs on one device): the deterministic fault hooks —
    ``FaultInjector`` fires exactly once at the configured phase/step, the
    ``--inject-fault`` spec parser, the straggler observe/query split
    (regression: querying must not advance the eviction streak), the
    ``plan_mesh_shape`` degenerate-survivor table, checkpoint round-trips
    of live serving state (compacted ``GriffinWeights`` + promoted per-slot
    counters, leaf-exact), scheduler queue serialization through a
    checkpoint manifest, and single-device kill -> rollback-and-replay
    token parity (in-memory and via ``--snapshot-dir`` disk snapshots),
    plus a seeded hypothesis property that recovery is invariant to *when*
    the fault fires.

  - chaos (the CI ``chaos`` job:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8 pytest -m chaos``):
    the chaos matrix — kill phase {admission, prefill, decode} x mesh
    transition {2x2 -> 1x2, 2x4 -> 2x2} x weights {dense, sparse-B} must
    finish the trace with tokens identical to an *uninterrupted unsharded*
    run, exercising snapshot -> ``elastic.plan_mesh`` -> reshard -> replay
    end-to-end; plus the straggler-eviction-driven remesh, disk-snapshot
    recovery on a mesh, and the 2x2-saved -> 1x2-restored checkpoint
    resharding round-trip.  Skipped (not failed) below 8 devices.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import read_manifest, restore, save
from repro.configs import get_config
from repro.launch.mesh import mesh_spec, serve_mesh
from repro.models import build_model
from repro.runtime.elastic import (plan_mesh, plan_mesh_shape, reshard,
                                   surviving)
from repro.runtime.engine import (Attribution, Request, Scheduler,
                                  ServeEngine, _promote_arena,
                                  synthetic_trace)
from repro.runtime.fault import (DeviceLoss, FaultInjector, ReplicaFault,
                                 parse_fault_spec)
from repro.runtime.mesh_serve import MeshServeEngine, serve_shardings
from repro.runtime.paging import PageAllocator
from repro.runtime.router import RouterEngine
from repro.runtime.straggler import StragglerConfig, StragglerDetector
from repro.sparsity import sparsify_params

PRUNE = dict(block_k=16, block_n=16, unit=8)   # reduced dims (d_model 64)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                      # pragma: no cover
    HAVE_HYPOTHESIS = False


def _needs_devices(n: int):
    return pytest.mark.skipif(
        len(jax.devices()) < n,
        reason=f"needs {n} devices (export XLA_FLAGS="
               "--xla_force_host_platform_device_count=8)")


def _trace(cfg, n=4):
    return synthetic_trace(cfg, num_requests=n, seed=11,
                           prompt_lens=(6, 10), gen_lens=(2, 4),
                           arrival_every=1)


def _tokens(outs):
    return {r: list(map(int, o.tokens)) for r, o in outs.items()}


def _assert_trees_equal(a, b):
    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = jax.tree_util.tree_flatten_with_path(b)[0]
    assert [jax.tree_util.keystr(p) for p, _ in fa] == \
        [jax.tree_util.keystr(p) for p, _ in fb]
    for (p, x), (_, y) in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=jax.tree_util.keystr(p))


@pytest.fixture(scope="module")
def small():
    cfg = get_config("llama3.2-1b").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


@pytest.fixture(scope="module")
def reference(small):
    cfg, api, params = small
    eng = ServeEngine(api, params, num_slots=3, cache_len=24, decode_chunk=4)
    return _tokens(eng.run(_trace(cfg, 5)))


# ---------------------------------------------------------------------------
# tier-1: injector semantics
# ---------------------------------------------------------------------------

def test_fault_injector_fires_once_at_matching_phase():
    inj = FaultInjector(kill_devices=(3, 1, 3), at_step=2, phase="decode")
    inj.poll("admission", 5)            # wrong phase: never fires
    inj.poll("decode", 1)               # right phase, too early
    assert not inj.fired
    with pytest.raises(DeviceLoss) as e:
        inj.poll("decode", 4)
    assert e.value.lost == (1, 3)       # deduped, sorted ids
    assert inj.fired and inj.fired_at == 4
    inj.poll("decode", 5)               # recovery replays the tick: no re-fire


def test_fault_injector_phase_matters():
    for phase in ("admission", "prefill"):
        inj = FaultInjector(kill_devices=(0,), at_step=0, phase=phase)
        inj.poll("decode", 9)
        assert not inj.fired
        with pytest.raises(DeviceLoss):
            inj.poll(phase, 0)


def test_fault_injector_host_delay():
    inj = FaultInjector(delay_host=1, at_step=3, delay_factor=12.0)
    assert inj.host_delay(1, 2) == 1.0      # not yet due
    assert inj.host_delay(0, 5) == 1.0      # wrong host
    assert inj.host_delay(1, 3) == 12.0     # persistent from at_step on
    assert inj.host_delay(1, 9) == 12.0
    assert not inj.fired                    # delays never raise


def test_fault_injector_validation():
    with pytest.raises(ValueError):
        FaultInjector(kill_devices=(0,), phase="epilogue")
    with pytest.raises(ValueError):
        FaultInjector(kill_devices=(0,), at_step=-1)


def test_parse_fault_spec():
    s = parse_fault_spec("kill:-1@3")
    assert (s.kind, s.index, s.at_step, s.phase) == ("kill", -1, 3, "decode")
    s = parse_fault_spec("kill:2@0:prefill")
    assert (s.index, s.phase) == (2, "prefill")
    s = parse_fault_spec("delay:1@4")
    assert (s.kind, s.index, s.at_step, s.factor) == ("delay", 1, 4, 8.0)
    assert parse_fault_spec("delay:0@2:50").factor == 50.0
    for bad in ("", "kill", "kill:", "kill:1", "kill:x@3", "kill:1@x",
                "kill:1@-2", "kill:1@3:warmup", "delay:1@3:1.0",
                "delay:1@3:x", "reboot:1@3"):
        with pytest.raises(ValueError):
            parse_fault_spec(bad)


def test_fault_spec_build_resolves_device_index():
    @dataclasses.dataclass
    class Dev:
        id: int
    devs = [Dev(10), Dev(11), Dev(12)]
    inj = parse_fault_spec("kill:-1@3:prefill").build(devs)
    assert inj.kill_devices == (12,) and inj.phase == "prefill"
    inj = parse_fault_spec("delay:1@2:9").build(devs)
    assert inj.delay_host == 1 and inj.delay_factor == 9.0


# ---------------------------------------------------------------------------
# tier-1: straggler observe/query split (regression)
# ---------------------------------------------------------------------------

def test_straggler_query_is_side_effect_free():
    """The pre-split detector advanced ``flagged_streak`` inside
    ``stragglers()``, so any second query in a step double-counted the
    streak and evicted in half the configured time."""
    det = StragglerDetector(4, StragglerConfig(threshold=1.5, evict_after=3))
    for h in range(4):
        det.record(h, 1.0 if h != 2 else 3.0)
    for _ in range(10):                     # query storm: no side effects
        assert det.stragglers() == [2]
    assert list(det.flagged_streak) == [0, 0, 0, 0]
    assert det.evictions() == []
    for step in range(3):
        det.observe()                       # only observe() closes a step
        det.evictions()                     # interleaved queries stay free
        assert det.flagged_streak[2] == step + 1
    assert det.evictions() == [2]


def test_straggler_streak_resets_when_host_recovers():
    det = StragglerDetector(2, StragglerConfig(threshold=1.5, evict_after=4))
    for _ in range(3):
        det.record(0, 1.0), det.record(1, 9.0)
        det.observe()
    assert det.flagged_streak[1] == 3
    for _ in range(30):                     # EMA pulls host 1 back to par
        det.record(0, 1.0), det.record(1, 1.0)
        det.observe()
    assert det.flagged_streak[1] == 0 and det.evictions() == []


def test_straggler_rejects_empty_fleet():
    with pytest.raises(ValueError):
        StragglerDetector(0)


# ---------------------------------------------------------------------------
# tier-1: plan_mesh degenerate survivor counts
# ---------------------------------------------------------------------------

def test_plan_mesh_shape_table():
    """Pinned (n_devices, model_parallel) -> (data, model) table, including
    every degenerate case: a lone survivor, non-power-of-two survivor
    counts, and fewer survivors than the requested TP degree."""
    table = {
        (1, 1): (1, 1), (1, 4): (1, 1),     # lone survivor ignores TP ask
        (2, 2): (1, 2), (2, 1): (2, 1),
        (3, 2): (1, 2),                     # non-pow2: drop to 2 devices
        (5, 4): (1, 4), (6, 3): (2, 2),
        (7, 2): (2, 2), (7, 4): (1, 4),     # the 2x4 - 1 survivor cells
        (8, 4): (2, 4), (8, 2): (4, 2), (8, 1): (8, 1),
        (16, 4): (4, 4),
    }
    for (n, mp), want in table.items():
        assert plan_mesh_shape(n, mp) == want, (n, mp)
    for data, model in table.values():      # contract: pow2 axes
        assert data & (data - 1) == 0 and model & (model - 1) == 0
    for n, mp in ((0, 1), (1, 0), (-3, 2)):
        with pytest.raises(ValueError):
            plan_mesh_shape(n, mp)


def test_plan_mesh_builds_named_axes():
    m = plan_mesh(1, 1)
    assert m.axis_names == ("data", "model") and m.size == 1
    with pytest.raises(ValueError):
        plan_mesh(2, 2, devices=jax.devices()[:1])   # planned > provided


def test_surviving_filters_lost_ids_in_mesh_order():
    m = serve_mesh("1x1")
    dev = list(np.asarray(m.devices).flat)[0]
    assert surviving(m.devices, []) == [dev]
    assert surviving(m.devices, [dev.id]) == []


# ---------------------------------------------------------------------------
# tier-1: checkpointed serving state (satellite: save/restore roundtrip)
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_compacted_serving_state(tmp_path, small):
    """A serving snapshot — compacted ``GriffinWeights`` params plus a
    promoted (B,)-counter arena — must survive save/restore leaf-exact."""
    cfg, api, params = small
    sp = sparsify_params(params, 0.6, **PRUNE)
    cache = _promote_arena(api.init_cache(3, 16), 3)
    cache = jax.tree.map(
        lambda x: jnp.asarray(np.random.default_rng(0)
                              .standard_normal(x.shape).astype(x.dtype))
        if jnp.issubdtype(x.dtype, jnp.floating) else x, cache)
    state = {"params": sp, "cache": cache,
             "tokens": jnp.arange(3, dtype=jnp.int32)[:, None],
             "remaining": jnp.asarray([4, 0, 2], jnp.int32)}
    d = str(tmp_path / "ck")
    save(d, 7, state)
    template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype),
        state)
    out = restore(d, template, step=7)
    _assert_trees_equal(out, state)


def test_scheduler_state_dict_roundtrip():
    """Queue snapshot -> JSON -> rebuild must reproduce admission order,
    free-slot stack and per-slot countdowns exactly (extras included)."""
    sched = Scheduler(3, "continuous", max_admissions_per_step=2)
    rng = np.random.default_rng(3)
    for rid in range(6):
        extras = ({"frames": rng.standard_normal((2, 4)).astype(np.float32)}
                  if rid % 2 else None)
        sched.add(Request(rid=rid, tokens=np.arange(4 + rid, dtype=np.int32),
                          max_new_tokens=2 + rid % 3, arrival=rid // 2,
                          extras=extras))
    sched.admissions(0)                     # move some into running
    sched.emit(sched.active[0])             # and free a slot again
    d = json.loads(json.dumps(sched.state_dict()))
    clone = Scheduler.from_state_dict(d)
    assert clone.state_dict() == sched.state_dict()
    # behavioural equality: the clone admits the same requests henceforth
    for step in range(1, 5):
        a, b = sched.admissions(step), clone.admissions(step)
        assert [(s, r.rid) for s, r in a] == [(s, r.rid) for s, r in b]
    assert clone.finished == sched.finished
    assert clone.waiting_count == sched.waiting_count


def test_scheduler_state_rides_checkpoint_manifest(tmp_path):
    sched = Scheduler(2)
    sched.add(Request(rid=0, tokens=np.arange(5, dtype=np.int32),
                      max_new_tokens=3))
    d = str(tmp_path / "ck")
    save(d, 4, {"x": jnp.zeros(2)}, extra={"scheduler": sched.state_dict(),
                                           "clock": 4})
    man = read_manifest(d)                  # latest by default
    assert man["step"] == 4 and man["extra"]["clock"] == 4
    clone = Scheduler.from_state_dict(man["extra"]["scheduler"])
    assert clone.waiting_count == 1
    with pytest.raises(FileNotFoundError):
        read_manifest(str(tmp_path / "empty"))


# ---------------------------------------------------------------------------
# tier-1: single-device kill -> rollback-and-replay token parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("phase", ["admission", "prefill", "decode"])
def test_single_device_kill_recovers_token_exact(small, reference, phase):
    """A kill at any injection point rolls back to the tick-start snapshot
    and replays; the finished trace must equal the uninterrupted run's
    token for token (restart-in-place: one device has no survivors to
    remesh over, so recovery reuses the same device)."""
    cfg, api, params = small
    inj = FaultInjector(kill_devices=(0,), at_step=2, phase=phase)
    eng = ServeEngine(api, params, num_slots=3, cache_len=24,
                      decode_chunk=4, fault_injector=inj)
    out = eng.run(_trace(cfg, 5))
    assert inj.fired and eng.recoveries == 1
    assert eng.recovery_log == [{"step": 2, "lost": [0],
                                 "mesh": "unsharded"}]
    assert _tokens(out) == reference


def test_snapshot_dir_disk_recovery(tmp_path, small, reference):
    """With ``snapshot_dir`` set, tick-start snapshots go through
    ``checkpoint.save`` (scheduler queues in the manifest's ``extra``) and
    recovery restores through ``checkpoint.restore`` — same tokens."""
    cfg, api, params = small
    d = str(tmp_path / "snap")
    inj = FaultInjector(kill_devices=(0,), at_step=3, phase="decode")
    eng = ServeEngine(api, params, num_slots=3, cache_len=24,
                      decode_chunk=4, fault_injector=inj, snapshot_dir=d)
    out = eng.run(_trace(cfg, 5))
    assert eng.recoveries == 1 and _tokens(out) == reference
    man = read_manifest(d)                  # snapshots really hit disk
    sched = Scheduler.from_state_dict(man["extra"]["scheduler"])
    assert sched.num_slots == 3


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(k=st.integers(0, 5),
           phase=st.sampled_from(["admission", "prefill", "decode"]))
    def test_recovery_invariant_to_fault_step(k, phase):
        """Property: *when* the fault fires must not change the served
        tokens — every (step, phase) recovery converges to the same trace
        as the uninterrupted run."""
        cfg = get_config("llama3.2-1b").reduced()
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        key = "ref"
        if key not in _PROP_REF:
            eng = ServeEngine(api, params, num_slots=3, cache_len=24,
                              decode_chunk=4)
            _PROP_REF[key] = _tokens(eng.run(_trace(cfg, 5)))
        inj = FaultInjector(kill_devices=(0,), at_step=k, phase=phase)
        eng = ServeEngine(api, params, num_slots=3, cache_len=24,
                          decode_chunk=4, fault_injector=inj)
        out = eng.run(_trace(cfg, 5))
        assert inj.fired and eng.recoveries == 1
        assert _tokens(out) == _PROP_REF[key]

    _PROP_REF: dict = {}


# ---------------------------------------------------------------------------
# chaos: the fault matrix on an emulated 8-device host (CI `chaos` job)
# ---------------------------------------------------------------------------

_REF_CACHE: dict = {}


def _reference8(arch, sparse):
    """Uninterrupted *unsharded* tokens per weight representation — the
    oracle every chaos cell must match (memoized across the matrix)."""
    key = (arch, sparse)
    if key not in _REF_CACHE:
        cfg = get_config(arch).reduced()
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        kw = {}
        if sparse:
            params = sparsify_params(params, 0.6, **PRUNE)
            kw = dict(use_kernels=True, interpret=True)
        eng = ServeEngine(api, params, num_slots=4, cache_len=16,
                          decode_chunk=3, **kw)
        outs = eng.run(_trace(cfg, 4))
        assert len(eng.mode_history) == 1, "mode flip would break replay"
        _REF_CACHE[key] = (api, params, _tokens(outs))
    return _REF_CACHE[key]


def _chaos_cell(spec, mp, expect, sparse, phase, at_step=3,
                snapshot_dir=None):
    api, params, ref = _reference8("llama3.2-1b", sparse)
    mesh = serve_mesh(spec)
    kill = int(np.asarray(mesh.devices).flat[-1].id)
    inj = FaultInjector(kill_devices=(kill,), at_step=at_step, phase=phase)
    eng = MeshServeEngine(api, params, mesh=mesh, num_slots=4, cache_len=16,
                          decode_chunk=3, fault_injector=inj,
                          recovery_model_parallel=mp,
                          snapshot_dir=snapshot_dir)
    out = eng.run(_trace(api.cfg, 4))
    assert inj.fired and eng.recoveries == 1, (spec, phase, sparse)
    assert mesh_spec(eng.mesh) == expect, (spec, phase, sparse)
    assert eng.recovery_log[-1]["lost"] == [kill]
    assert _tokens(out) == ref, (spec, phase, sparse)
    return eng


@pytest.mark.chaos
@_needs_devices(8)
@pytest.mark.parametrize("phase", ["admission", "prefill", "decode"])
@pytest.mark.parametrize("spec,mp,expect",
                         [("2x2", None, "1x2"), ("2x4", 2, "2x2")],
                         ids=["2x2to1x2", "2x4to2x2"])
@pytest.mark.parametrize("sparse", [False, True], ids=["dense", "sparseB"])
def test_chaos_matrix(phase, spec, mp, expect, sparse):
    """Kill one device mid-trace at every injection point, on both mesh
    transitions, for both weight representations: the engine must remesh
    onto the survivors and finish with the uninterrupted unsharded run's
    tokens (acceptance criterion)."""
    _chaos_cell(spec, mp, expect, sparse, phase)


# ---------------------------------------------------------------------------
# chaos: router replica-kill matrix (DESIGN.md Section 13) — single-device
# replicas, so these cells need no emulated mesh
# ---------------------------------------------------------------------------

_ROUTER_ORACLE: dict = {}


def _router_oracle(api, params, reqs):
    """Uninterrupted single-engine tokens per request — the oracle every
    router chaos cell must match (greedy decode is request-independent,
    so batch-1 replays are the strongest comparison)."""
    if not _ROUTER_ORACLE:
        for r in reqs:
            eng = ServeEngine(api, params, num_slots=1, cache_len=24,
                              decode_chunk=2)
            out = eng.run([dataclasses.replace(r, arrival=0)])
            _ROUTER_ORACLE[r.rid] = list(map(int, out[r.rid].tokens))
    return _ROUTER_ORACLE


@pytest.mark.chaos
@pytest.mark.parametrize("hedge", [None, 1], ids=["retry", "hedge"])
@pytest.mark.parametrize("during,at_step",
                         [("idle", 0), ("prefill", 1), ("decode", 2)])
def test_chaos_router_replica_kill(small, during, at_step, hedge):
    """Kill replica 1 {while idle, about to prefill, mid-decode}, with and
    without hedging armed: drained in-flight requests must replay on the
    survivor (or promote to their live hedge copy), the replica must
    rejoin after recovery, and every request must finish token-identical
    to the uninterrupted single-engine oracle (acceptance criterion)."""
    cfg, api, params = small
    reqs = synthetic_trace(cfg, num_requests=6, seed=11,
                           prompt_lens=(6, 10), gen_lens=(4, 6))
    ref = _router_oracle(api, params, reqs)
    fault = ReplicaFault(replica=1, at_step=at_step, during=during,
                         recover_after=3)
    router = RouterEngine(
        lambda: ServeEngine(api, params, num_slots=2, cache_len=24,
                            decode_chunk=2),
        2, hedge_after=hedge, replica_faults=[fault])
    outs = router.run([dataclasses.replace(r) for r in reqs])
    assert fault.fired, f"{during} fault site never matched"
    kill = router.health_log[0]
    assert kill["event"] == "kill" and kill["state"] == during
    assert any(h["event"] == "rejoin" for h in router.health_log)
    assert all(h.up for h in router.replicas)
    for r in reqs:
        o = outs[r.rid]
        assert o.finished >= 0, f"rid {r.rid} never finished"
        assert list(map(int, o.tokens)) == ref[r.rid], \
            f"rid {r.rid} diverged from the single-engine oracle"
    for rid in kill["drained"]:
        assert outs[rid].attribution in (Attribution.RETRIED,
                                         Attribution.HEDGED)
    if during == "idle":
        assert kill["drained"] == [] and router.stats["retried"] == 0
    elif hedge is None:
        assert router.stats["retried"] > 0


@pytest.mark.chaos
@_needs_devices(8)
def test_chaos_straggler_eviction_drives_remesh():
    """A persistently delayed host must be evicted by the *detector* (the
    injector only inflates its step times) and routed through the same
    snapshot -> remesh -> reshard path: 2x2 -> 1x2, token parity kept."""
    api, params, ref = _reference8("llama3.2-1b", False)
    inj = FaultInjector(delay_host=1, at_step=2, delay_factor=50.0)
    det = StragglerDetector(2, StragglerConfig(evict_after=3))
    eng = MeshServeEngine(api, params, mesh=serve_mesh("2x2"), num_slots=4,
                          cache_len=16, decode_chunk=3, fault_injector=inj,
                          straggler=det)
    out = eng.run(_trace(api.cfg, 4))
    assert not inj.fired                    # no DeviceLoss was raised
    assert eng.recoveries == 1 and mesh_spec(eng.mesh) == "1x2"
    assert len(eng.recovery_log[-1]["lost"]) == 2   # host row = 2 devices
    assert _tokens(out) == ref


@pytest.mark.chaos
@_needs_devices(8)
def test_chaos_disk_snapshot_recovery_on_mesh(tmp_path):
    """Mesh recovery through the on-disk path: snapshots written with
    ``checkpoint.save`` restore through ``checkpoint.restore`` directly
    onto the *post-loss* mesh's shardings."""
    d = str(tmp_path / "snap")
    eng = _chaos_cell("2x2", None, "1x2", False, "decode", snapshot_dir=d)
    man = read_manifest(d)
    assert "scheduler" in man["extra"]
    assert eng.recovery_log[-1]["mesh"] == "1x2"


@pytest.mark.chaos
@_needs_devices(8)
def test_chaos_checkpoint_reshards_2x2_to_1x2(tmp_path):
    """Satellite: a checkpoint saved from a 2x2-sharded serving state must
    restore leaf-exactly under 1x2 shardings (params incl. compacted
    ``GriffinWeights``, arena, promoted (B,) counters)."""
    cfg = get_config("llama3.2-1b").reduced()
    api = build_model(cfg)
    params = sparsify_params(api.init(jax.random.PRNGKey(0)), 0.6, **PRUNE)
    cache = _promote_arena(api.init_cache(4, 16), 4)
    host = {"params": jax.tree.map(np.asarray, params),
            "cache": jax.tree.map(np.asarray, cache),
            "remaining": np.asarray([3, 1, 0, 2], np.int32)}

    def place(mesh):
        p_sh, c_sh, rep = serve_shardings(api, mesh, params, 4, 16)
        return {"params": p_sh, "cache": c_sh, "remaining": rep}

    sharded = reshard(host, place(serve_mesh("2x2")))
    d = str(tmp_path / "ck")
    save(d, 1, sharded)
    template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype),
        host)
    small_mesh = serve_mesh("1x2")
    out = restore(d, template, shardings=place(small_mesh))
    _assert_trees_equal(out, host)
    devs = {dv for leaf in jax.tree_util.tree_leaves(out)
            for dv in leaf.sharding.device_set}
    assert devs <= set(np.asarray(small_mesh.devices).flat)


# ---------------------------------------------------------------------------
# paged arena under faults (DESIGN.md Section 14): the page table, allocator
# state and int8 scales must ride snapshot -> rollback -> replay exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("phase", ["admission", "prefill", "decode"])
def test_single_device_kill_recovers_paged_token_exact(small, reference,
                                                       phase):
    """fp32 paged + kill at any phase must replay to the FIXED arena's
    reference tokens (paged fp32 is bit-exact, and recovery restores the
    pool + page table + host allocator from the tick-start snapshot)."""
    cfg, api, params = small
    inj = FaultInjector(kill_devices=(0,), at_step=2, phase=phase)
    eng = ServeEngine(api, params, num_slots=3, cache_len=24,
                      decode_chunk=4, page_size=8, fault_injector=inj)
    assert eng._paged is not None
    out = eng.run(_trace(cfg, 5))
    assert inj.fired and eng.recoveries == 1
    assert _tokens(out) == reference
    # replay rebuilt the same page bookkeeping state machine: every page
    # is either free or parked on a dead slot awaiting the next tick-start
    # flush (the drained trace never starts another tick)
    parked = sum(len(ids) for ids in eng._slot_pages.values())
    assert eng._page_alloc.free_pages + parked == eng._paged.usable_pages
    assert set(eng._slot_pages) <= eng._dirty_slots


def test_single_device_kill_recovers_paged_int8(small):
    """int8 paged kill -> replay must match the *unfaulted int8 paged* run
    token for token: quantized pools and their per-row scales are restored
    bit-exactly, so requantization never happens on replay."""
    cfg, api, params = small

    def engine(inj=None):
        return ServeEngine(api, params, num_slots=3, cache_len=24,
                           decode_chunk=4, page_size=8, kv_dtype="int8",
                           fault_injector=inj)

    ref = _tokens(engine().run(_trace(cfg, 5)))
    inj = FaultInjector(kill_devices=(0,), at_step=3, phase="decode")
    eng = engine(inj)
    out = eng.run(_trace(cfg, 5))
    assert inj.fired and eng.recoveries == 1
    assert _tokens(out) == ref


def test_snapshot_dir_carries_paging_state(tmp_path, small, reference):
    """Disk snapshots must carry the paged host state in the manifest
    (allocator + slot->pages + dirty set) next to the device pool/table,
    and disk recovery must land on the reference tokens."""
    cfg, api, params = small
    d = str(tmp_path / "snap")
    inj = FaultInjector(kill_devices=(0,), at_step=3, phase="decode")
    eng = ServeEngine(api, params, num_slots=3, cache_len=24,
                      decode_chunk=4, page_size=8, fault_injector=inj,
                      snapshot_dir=d)
    out = eng.run(_trace(cfg, 5))
    assert eng.recoveries == 1 and _tokens(out) == reference
    man = read_manifest(d)
    paging = man["extra"]["paging"]
    assert paging["allocator"]["num_pages"] == eng._paged.num_pages
    restored = PageAllocator.from_state_dict(paging["allocator"])
    held = {i for ids in paging["slot_pages"].values() for i in ids}
    assert held <= set(paging["allocator"]["held"])
    assert restored.free_pages == eng._paged.num_pages - 1 - \
        len(paging["allocator"]["held"])


@pytest.mark.chaos
@_needs_devices(8)
@pytest.mark.parametrize("kv_dtype", ["fp32", "int8"])
@pytest.mark.parametrize("phase", ["admission", "prefill", "decode"])
def test_chaos_paged_mesh_kill(phase, kv_dtype):
    """Paged arena on a 2x2 mesh, kill one device at every injection point:
    the dp-sharded page pool + replicated page table must snapshot,
    reshard onto the 1x2 survivor mesh, and replay token-identical to the
    *uninterrupted unsharded paged* run with the same kv_dtype (fp32 also
    equals the fixed-arena reference by bit-exactness)."""
    api, params, fixed_ref = _reference8("llama3.2-1b", False)
    paged_eng = ServeEngine(api, params, num_slots=4, cache_len=16,
                            decode_chunk=3, page_size=8, kv_dtype=kv_dtype)
    assert paged_eng._paged is not None
    ref = _tokens(paged_eng.run(_trace(api.cfg, 4)))
    if kv_dtype == "fp32":
        assert ref == fixed_ref
    mesh = serve_mesh("2x2")
    kill = int(np.asarray(mesh.devices).flat[-1].id)
    inj = FaultInjector(kill_devices=(kill,), at_step=3, phase=phase)
    eng = MeshServeEngine(api, params, mesh=mesh, num_slots=4, cache_len=16,
                          decode_chunk=3, page_size=8, kv_dtype=kv_dtype,
                          fault_injector=inj)
    out = eng.run(_trace(api.cfg, 4))
    assert inj.fired and eng.recoveries == 1
    assert mesh_spec(eng.mesh) == "1x2"
    assert _tokens(out) == ref, (phase, kv_dtype)
