"""DSE results cache: content-hashed round trips, invalidation, robustness."""
import json
import os

import numpy as np
import pytest

from repro.core import CoreConfig, Mode
from repro.core.dse import (ResultsCache, design_fingerprint, sweep)
from repro.core.evaluate import DEFAULT_MASK_MODEL, MaskModel
from repro.core.spec import GRIFFIN, SPARSE_B_STAR, sparse_b

CORE = CoreConfig()
DESIGNS = [SPARSE_B_STAR, sparse_b(2, 1, 0, shuffle=True), GRIFFIN]


def test_sweep_cache_round_trip(tmp_path):
    cache = ResultsCache(str(tmp_path / "cache"))
    cold = sweep(DESIGNS, Mode.B, CORE, seed=1, cache=cache)
    assert cache.hits == 0 and cache.misses == len(DESIGNS)
    warm = sweep(DESIGNS, Mode.B, CORE, seed=1, cache=cache)
    assert cache.hits == len(DESIGNS)
    assert warm == cold                     # exact round trip through JSON
    # and identical to an uncached sweep
    assert sweep(DESIGNS, Mode.B, CORE, seed=1) == cold


def test_fingerprint_sensitivity():
    base = design_fingerprint(SPARSE_B_STAR, Mode.B, CORE, 1,
                              DEFAULT_MASK_MODEL)
    assert base == design_fingerprint(SPARSE_B_STAR, Mode.B, CORE, 1,
                                      DEFAULT_MASK_MODEL)
    others = [
        design_fingerprint(SPARSE_B_STAR, Mode.A, CORE, 1, DEFAULT_MASK_MODEL),
        design_fingerprint(SPARSE_B_STAR, Mode.B, CORE, 2, DEFAULT_MASK_MODEL),
        design_fingerprint(sparse_b(4, 0, 1), Mode.B, CORE, 1,
                           DEFAULT_MASK_MODEL),
        design_fingerprint(SPARSE_B_STAR, Mode.B, CoreConfig(k0=32), 1,
                           DEFAULT_MASK_MODEL),
        design_fingerprint(SPARSE_B_STAR, Mode.B, CORE, 1,
                           MaskModel(chan_cv=0.7)),
        design_fingerprint(GRIFFIN, Mode.B, CORE, 1, DEFAULT_MASK_MODEL),
    ]
    assert len(set(others + [base])) == len(others) + 1


def test_corrupt_cache_entry_is_a_miss(tmp_path):
    cache = ResultsCache(str(tmp_path / "cache"))
    designs = DESIGNS[:1]
    cold = sweep(designs, Mode.B, CORE, seed=1, cache=cache)
    # corrupt every entry on disk
    for fn in os.listdir(cache.path):
        with open(os.path.join(cache.path, fn), "w") as f:
            f.write("{not json")
    again = sweep(designs, Mode.B, CORE, seed=1, cache=cache)
    assert again == cold                    # recomputed, not poisoned
    # and the entry was repaired in place
    fn = os.path.join(cache.path, os.listdir(cache.path)[0])
    assert json.load(open(fn)) == cold[0]


def test_cache_get_put_direct(tmp_path):
    cache = ResultsCache(str(tmp_path / "c"))
    assert cache.get("deadbeef") is None
    row = {"design": "x", "speedup": 1.25}
    cache.put("deadbeef", row)
    assert cache.get("deadbeef") == row


def test_fingerprint_includes_config_schema_version(monkeypatch):
    """The candidate-config / kernel-plan schema version (repro.tuning,
    DESIGN.md Section 12) is part of every sweep fingerprint: a schema
    bump must cold-start rows cached under the old schema, because the
    autotuner's scores are only comparable within one schema."""
    import repro.core.dse as dse

    base = design_fingerprint(SPARSE_B_STAR, Mode.B, CORE, 1,
                              DEFAULT_MASK_MODEL)
    monkeypatch.setattr(dse, "CONFIG_SCHEMA_VERSION",
                        dse.CONFIG_SCHEMA_VERSION + 1)
    bumped = design_fingerprint(SPARSE_B_STAR, Mode.B, CORE, 1,
                                DEFAULT_MASK_MODEL)
    assert bumped != base


def test_schema_bump_cold_starts_sweep_cache(tmp_path, monkeypatch):
    """Regression: rows cached under an older CONFIG_SCHEMA_VERSION are
    misses for the current code (and vice versa), never silent hits."""
    import repro.core.dse as dse

    cache = ResultsCache(str(tmp_path / "cache"))
    designs = DESIGNS[:2]
    monkeypatch.setattr(dse, "CONFIG_SCHEMA_VERSION", 1)   # "old" schema
    old = sweep(designs, Mode.B, CORE, seed=1, cache=cache)
    assert (cache.hits, cache.misses) == (0, 2)

    monkeypatch.setattr(dse, "CONFIG_SCHEMA_VERSION", 2)   # schema bump
    new = sweep(designs, Mode.B, CORE, seed=1, cache=cache)
    assert (cache.hits, cache.misses) == (0, 4)            # cold again
    assert new == old                       # same physics, fresh rows

    # each schema's rows now hit under their own version only
    sweep(designs, Mode.B, CORE, seed=1, cache=cache)
    assert (cache.hits, cache.misses) == (2, 4)
    monkeypatch.setattr(dse, "CONFIG_SCHEMA_VERSION", 1)
    sweep(designs, Mode.B, CORE, seed=1, cache=cache)
    assert (cache.hits, cache.misses) == (4, 4)
