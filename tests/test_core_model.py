"""Evaluation model: workloads, overheads, hybrid morphing, DSE scoring."""
import numpy as np
import pytest

from repro.core import (CoreConfig, GRIFFIN, Mode, PRESETS, SPARSE_AB_STAR,
                        SPARSE_B_STAR, gemm_cycles, power_area, running_spec,
                        select_mode, sparse_a, sparse_ab, sparse_b, structure)
from repro.core.evaluate import MaskModel, network_speedup
from repro.core.overhead import TABLE_VII_TOTALS
from repro.core.workloads import (TABLE_IV, category_workloads,
                                  paper_dense_latency, paper_workloads)

CORE = CoreConfig()


def test_dense_latency_matches_table_iv():
    """Our GEMM streams produce the paper's dense cycle counts (+-10%)."""
    for w in paper_workloads():
        ratio = w.dense_cycles(CORE) / paper_dense_latency(w.name)
        assert 0.9 < ratio < 1.1, (w.name, ratio)


def test_gemm_speedup_in_valid_range():
    rng = np.random.default_rng(0)
    a = rng.random((32, 256)) < 0.5
    b = rng.random((256, 64)) < 0.2
    for spec, mode, cap in [
        (sparse_b(4, 0, 1), Mode.B, 5.0),
        (sparse_a(2, 1, 0), Mode.A, 3.0),
        (sparse_ab(2, 0, 0, 2, 0, 1), Mode.AB, 9.0),
    ]:
        r = gemm_cycles(spec, mode, a, b, CORE)
        assert 1.0 <= r.speedup <= cap + 1e-6, (spec.label(), r.speedup)


def test_dense_mode_gives_no_speedup():
    rng = np.random.default_rng(1)
    a = rng.random((16, 128)) < 0.5
    b = rng.random((128, 32)) < 0.2
    r = gemm_cycles(SPARSE_B_STAR, Mode.DENSE, a, b, CORE)
    assert r.speedup == pytest.approx(1.0)


def test_structure_formulas_match_paper_quotes():
    """Section IV-B quotes for Sparse.AB*(2,0,0,2,0,1)."""
    s = structure(SPARSE_AB_STAR, CORE)
    assert s.abuf_depth == 9          # "9-entry ABUF"
    assert s.bbuf_depth == 3          # "3-entry BBUF"
    assert s.amux_fanin == 9          # "9-input AMUX"
    assert s.bmux_fanin == 3          # "3-input BMUXs"
    assert s.extra_adders_per_pe == 1  # "one extra adder tree"


def test_power_area_fits_table_vii():
    for name, (p_ref, a_ref) in TABLE_VII_TOTALS.items():
        design = GRIFFIN if name == "Griffin" else PRESETS[name]
        pa = power_area(design)
        assert abs(pa.power_mw / p_ref - 1) < 0.12, (name, pa.power_mw)
        assert abs(pa.area_kum2 / a_ref - 1) < 0.20, (name, pa.area_kum2)


def test_hybrid_morphs_and_dual_downgrades():
    assert running_spec(GRIFFIN, Mode.B).label() == "Griffin.confB"
    assert running_spec(GRIFFIN, Mode.A).label() == "Griffin.confA"
    assert running_spec(GRIFFIN, Mode.AB) is GRIFFIN.base
    down = running_spec(SPARSE_AB_STAR, Mode.B)
    assert down.a_window == (0, 0, 0) and down.b_window == (2, 0, 1)


def test_select_mode():
    assert select_mode(0.0, 0.8) == Mode.B
    assert select_mode(0.5, 0.0) == Mode.A
    assert select_mode(0.5, 0.8) == Mode.AB
    assert select_mode(0.01, 0.02) == Mode.DENSE


def test_hybrid_beats_downgrade_on_single_sparse():
    """The paper's headline: Griffin's morph outperforms the dual design's
    downgrade on DNN.B (Table III / Fig 8b)."""
    wl = category_workloads(Mode.B)[5]    # BERT: the pure DNN.B benchmark
    sp_hybrid = network_speedup(running_spec(GRIFFIN, Mode.B), wl, CORE,
                                seed=3, mode=Mode.B)
    sp_down = network_speedup(running_spec(SPARSE_AB_STAR, Mode.B), wl, CORE,
                              seed=3, mode=Mode.B)
    assert sp_hybrid > sp_down * 1.15


def test_mask_model_density_is_calibrated():
    mm = MaskModel()
    rng = np.random.default_rng(0)
    m = mm.weight_mask(512, 256, 0.2, rng, q=9)
    assert abs(m.mean() - 0.2) < 0.02
    a = mm.act_mask(128, 512, 0.5, rng)
    assert abs(a.mean() - 0.5) < 0.03
