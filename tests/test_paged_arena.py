"""Paged KV arena tests (DESIGN.md Section 14).

Four layers, mirroring the subsystem's own:

* ``PageAllocator`` units — deterministic lowest-first reuse, exhaustion,
  double-free detection, state round-trip, and the admission-order
  property (any interleaving of reserve/free yields non-overlapping
  reservations that never include the DUMP page) — seeded deterministic
  sweep always, hypothesis sweep when installed;
* discovery — which cache leaves page per family (the eval_shape probe of
  ``runtime.paging.discover_paged_keys``), cache_len rounding, and the
  fixed-arena degradation for families with no pageable leaves (xlstm) or
  a window smaller than the cache (rglru at long cache_len);
* engine parity — fixed vs paged ``ServeEngine`` on the same trace must be
  token-identical for fp32 pages (the gathered paged view has exactly the
  fixed arena's shape, so reductions are bit-equal); transformer + the
  xlstm degradation run tier-1, the full five-family x chunk matrix is the
  tier-2 sweep;
* int8 — per-row quantization error bound, and the teacher-forced logit
  tolerance gate: int8-paged decode logits within INT8_LOGIT_RTOL of the
  fp32-paged run on identical token inputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.optim.compression import dequantize_rows, quantize_rows
from repro.runtime.config import ArenaConfig, EngineConfig
from repro.runtime.engine import (ServeEngine, _batch_axes,
                                  _make_paged_insert, _promote_arena,
                                  synthetic_trace)
from repro.runtime.paging import (DUMP_PAGE, PageAllocator, build_spec,
                                  discover_paged_keys, paged_tree)

FAMILY_ARCHS = {
    "transformer": "llama3.2-1b",
    "moe": "mixtral-8x7b",
    "whisper": "whisper-large-v3",
    "xlstm": "xlstm-1.3b",
    "hybrid": "recurrentgemma-9b",
}

# teacher-forced int8-vs-fp32 decode logit gap, relative to the fp32 logit
# scale.  Measured ~0.003 on the reduced transformer; 0.02 leaves ~7x
# headroom while still catching a broken quantization path outright
# (mis-scaled pages blow past 0.1 immediately).
INT8_LOGIT_RTOL = 0.02

_API_CACHE = {}


def _api(arch):
    if arch not in _API_CACHE:
        cfg = get_config(arch).reduced()
        api = build_model(cfg)
        _API_CACHE[arch] = (cfg, api, api.init(jax.random.PRNGKey(0)))
    return _API_CACHE[arch]


# ---------------------------------------------------------------------------
# PageAllocator units
# ---------------------------------------------------------------------------

def test_allocator_lowest_first_and_deterministic_reuse():
    alloc = PageAllocator(9)                    # pages 1..8 usable, 0 = DUMP
    a = alloc.reserve(3)
    b = alloc.reserve(3)
    assert a == [1, 2, 3] and b == [4, 5, 6]
    alloc.free(a)
    # freed pages are reused lowest-first: same request, same pages
    assert alloc.reserve(2) == [1, 2]
    assert alloc.reserve(2) == [3, 7]


def test_allocator_never_hands_out_dump():
    alloc = PageAllocator(5)
    ids = alloc.reserve(4)
    assert DUMP_PAGE not in ids
    assert alloc.reserve(1) is None             # pool exhausted, 0 stays out


def test_allocator_exhaustion_is_all_or_nothing():
    alloc = PageAllocator(9)
    assert alloc.reserve(8) is not None
    before = alloc.free_pages
    assert alloc.reserve(1) is None
    assert alloc.free_pages == before           # failed reserve takes nothing


def test_allocator_double_free_raises():
    alloc = PageAllocator(9)
    ids = alloc.reserve(2)
    alloc.free(ids)
    with pytest.raises(ValueError):
        alloc.free(ids)
    with pytest.raises(ValueError):
        alloc.free([7])                         # never reserved


def test_allocator_state_roundtrip():
    alloc = PageAllocator(17)
    a = alloc.reserve(4)
    b = alloc.reserve(5)
    alloc.free(a)
    clone = PageAllocator.from_state_dict(alloc.state_dict())
    assert clone.free_pages == alloc.free_pages
    # identical future behavior: same reservations in the same order
    for _ in range(3):
        assert clone.reserve(3) == alloc.reserve(3)
    clone.free(b)
    alloc.free(b)
    assert clone.state_dict() == alloc.state_dict()


def _run_alloc_ops(ops, num_pages=17):
    """Admission-order property: under ANY interleaving of reserve/free,
    live reservations never overlap each other and never include DUMP."""
    alloc = PageAllocator(num_pages)
    held = []
    for kind, val in ops:
        if kind == 0:
            ids = alloc.reserve(1 + val % 6)
            if ids is not None:
                assert DUMP_PAGE not in ids
                live = {i for h in held for i in h}
                assert not live & set(ids), "overlapping page assignment"
                held.append(ids)
        elif held:
            alloc.free(held.pop(val % len(held)))
    live = [i for h in held for i in h]
    assert len(live) == len(set(live))
    for h in held:
        alloc.free(h)
    assert alloc.free_pages == num_pages - 1    # all pages come home


def test_allocator_admission_order_property_seeded():
    for seed in range(20):
        rng = np.random.default_rng(seed)
        ops = [(int(rng.integers(0, 2)), int(rng.integers(0, 64)))
               for _ in range(60)]
        _run_alloc_ops(ops)


def test_allocator_admission_order_property_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.lists(st.tuples(st.integers(0, 1), st.integers(0, 63)),
                        max_size=80))
    @hyp.settings(max_examples=60, deadline=None)
    def prop(ops):
        _run_alloc_ops(ops)

    prop()


# ---------------------------------------------------------------------------
# discovery + spec
# ---------------------------------------------------------------------------

def test_discovery_per_family():
    for family, arch in FAMILY_ARCHS.items():
        _, api, _ = _api(arch)
        keys = discover_paged_keys(api, 16)
        if family == "xlstm":
            assert keys == (), (family, keys)   # pure recurrent state
        else:
            assert keys == ("k", "v"), (family, keys)


def test_whisper_cross_attention_stays_fixed():
    # xk/xv (encoder K/V) are written once at admission and never grow —
    # they must not be classified as pageable
    _, api, _ = _api(FAMILY_ARCHS["whisper"])
    assert "xk" not in discover_paged_keys(api, 16)


def test_build_spec_rounds_cache_len_to_page_multiple():
    _, api, _ = _api(FAMILY_ARCHS["transformer"])
    spec, clen = build_spec(api, 2, 10, 4)
    assert clen == 12 and spec.cache_len == 12
    assert spec.max_pages == 3
    assert spec.max_pages * spec.page_size == clen


def test_build_spec_degrades_when_window_below_cache():
    # rglru window (32, reduced) < cache_len 64: the rolling cache caps at
    # the window, the length probes cannot differ, paging degrades to the
    # fixed arena at the ORIGINAL cache_len
    _, api, _ = _api(FAMILY_ARCHS["hybrid"])
    spec, clen = build_spec(api, 2, 64, 4)
    assert spec is None and clen == 64


def test_build_spec_validates_page_size_and_dtype():
    _, api, _ = _api(FAMILY_ARCHS["transformer"])
    with pytest.raises(ValueError):
        build_spec(api, 2, 16, 3)               # not a power of two
    with pytest.raises(ValueError):
        build_spec(api, 2, 16, 4, kv_dtype="fp8")


def test_paged_tree_shapes_and_dtypes():
    cfg, api, _ = _api(FAMILY_ARCHS["transformer"])
    for kv_dtype, pool_dt in (("fp32", None), ("int8", jnp.int8)):
        spec, clen = build_spec(api, 2, 16, 4, kv_dtype=kv_dtype)
        arena = paged_tree(_promote_arena(api.init_cache(2, clen), 2),
                           2, spec)
        assert arena["pages"].shape == (2, spec.max_pages)
        assert arena["pages"].dtype == jnp.int32
        L = cfg.num_layers
        assert arena["k"].shape[:3] == (L, spec.num_pages, spec.page_size)
        if kv_dtype == "int8":
            assert arena["k"].dtype == pool_dt
            assert arena["k_scale"].shape == (L, spec.num_pages,
                                              spec.page_size)
            assert arena["k_scale"].dtype == jnp.float32
        else:
            assert "k_scale" not in arena


# ---------------------------------------------------------------------------
# fixed vs paged engine parity
# ---------------------------------------------------------------------------

def _engine(api, params, *, page_size=None, kv_dtype="fp32", decode_chunk=3,
            num_slots=2, cache_len=16):
    return ServeEngine(api, params, config=EngineConfig(
        arena=ArenaConfig(num_slots=num_slots, cache_len=cache_len,
                          page_size=page_size, kv_dtype=kv_dtype)
    ).with_fields(decode_chunk=decode_chunk))


def _fixed_vs_paged(arch, decode_chunk, kv_dtype="fp32", num_requests=4):
    cfg, api, params = _api(arch)

    def trace():
        return synthetic_trace(cfg, num_requests=num_requests, seed=11,
                               prompt_lens=(6, 10), gen_lens=(2, 4),
                               arrival_every=1)

    fixed = _engine(api, params, decode_chunk=decode_chunk)
    outs_f = fixed.run(trace())
    paged = _engine(api, params, page_size=4, kv_dtype=kv_dtype,
                    decode_chunk=decode_chunk)
    assert paged._paged is not None
    outs_p = paged.run(trace())
    return [(r.rid, outs_f[r.rid].tokens, outs_p[r.rid].tokens)
            for r in trace()]


@pytest.mark.parametrize("decode_chunk", [1, 3])
def test_paged_parity_transformer(decode_chunk):
    for rid, fixed, paged in _fixed_vs_paged(FAMILY_ARCHS["transformer"],
                                             decode_chunk):
        assert fixed == paged, rid


def test_paged_engine_degrades_for_xlstm():
    cfg, api, params = _api(FAMILY_ARCHS["xlstm"])
    eng = _engine(api, params, page_size=4)
    assert eng._paged is None                   # fixed-arena degradation
    outs = eng.run(synthetic_trace(cfg, num_requests=3, seed=11,
                                   prompt_lens=(6, 10), gen_lens=(2, 4),
                                   arrival_every=1))
    assert all(len(o.tokens) > 0 for o in outs.values())


@pytest.mark.tier2
@pytest.mark.parametrize("decode_chunk", [1, 3])
@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_paged_parity_all_families(family, decode_chunk):
    if family == "xlstm":
        pytest.skip("no pageable leaves — covered by the degradation test")
    for rid, fixed, paged in _fixed_vs_paged(FAMILY_ARCHS[family],
                                             decode_chunk):
        assert fixed == paged, (family, rid)


def test_paged_parity_survives_slot_reuse():
    # more requests than slots x pages headroom: slots and pages recycle
    # through the dirty-flush path mid-run and parity must hold throughout
    for rid, fixed, paged in _fixed_vs_paged(FAMILY_ARCHS["transformer"],
                                             decode_chunk=3,
                                             num_requests=8):
        assert fixed == paged, rid


def test_paging_state_roundtrip():
    _, api, params = _api(FAMILY_ARCHS["transformer"])
    eng = _engine(api, params, page_size=4)
    eng._page_alloc.reserve(3)
    ids = eng._page_alloc.reserve(2)
    eng._slot_pages[1] = ids
    eng._dirty_slots.add(0)
    state = eng._paging_state()
    eng2 = _engine(api, params, page_size=4)
    eng2._restore_paging(state)
    assert eng2._paging_state() == state
    assert eng2._reserved_pages == {}           # in-flight gates never ride


# ---------------------------------------------------------------------------
# int8 pages
# ---------------------------------------------------------------------------

def test_quantize_rows_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(3, 5, 4, 8)) * 10.0, jnp.float32)
    q, scale = quantize_rows(x, 2)
    assert q.dtype == jnp.int8 and scale.shape == (3, 5)
    err = jnp.max(jnp.abs(dequantize_rows(q, scale) - x))
    # half-step rounding error at the per-row scale
    assert float(err) <= float(jnp.max(scale)) * 0.5 + 1e-6


def _paged_decode_logits(api, params, kv_dtype, prompt, steps, clen=16,
                         page_size=4, forced=None):
    """Raw paged decode loop: admit one prompt through the paged insert,
    then decode ``steps`` tokens (teacher-forced when ``forced`` given),
    returning the (steps+1, vocab) logit trajectory."""
    spec, clen = build_spec(api, 1, clen, page_size, None, kv_dtype)
    arena = paged_tree(_promote_arena(api.init_cache(1, clen), 1), 1, spec)
    sub, logits0 = api.prefill(params, {"tokens": prompt}, cache_len=clen)
    alloc = PageAllocator(spec.num_pages)
    ids = alloc.reserve(spec.pages_needed(prompt.shape[1] + steps))
    insert = _make_paged_insert(_batch_axes(api, clen), spec)
    cache, _, _, tok = insert(
        arena, jnp.zeros((1, 1), jnp.int32), jnp.zeros((1,), jnp.int32),
        sub, logits0, jnp.asarray(0), jnp.asarray(steps),
        jnp.asarray(spec.page_row(ids)))
    outs = [logits0[0]]
    nxt = tok[:, None]
    for t in range(steps):
        if forced is not None:
            nxt = forced[t][None, None]
        logits, cache = api.decode_step(params, cache, nxt)
        outs.append(logits[0])
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    return jnp.stack(outs)


def test_int8_logit_tolerance_gate():
    cfg, api, params = _api(FAMILY_ARCHS["transformer"])
    prompt = jnp.asarray(
        np.random.default_rng(3).integers(1, cfg.vocab_size, (1, 6)),
        jnp.int32)
    l32 = _paged_decode_logits(api, params, "fp32", prompt, steps=8)
    toks = jnp.argmax(l32, -1).astype(jnp.int32)
    l8 = _paged_decode_logits(api, params, "int8", prompt, steps=8,
                              forced=toks)
    rel = float(jnp.max(jnp.abs(l8 - l32)) / jnp.max(jnp.abs(l32)))
    assert rel <= INT8_LOGIT_RTOL, rel


def test_int8_parity_transformer_reduced():
    # not guaranteed in general (int8 is gated by logit tolerance, not
    # token equality) but deterministic on this seed-pinned reduced config
    # — a regression here means the quantization path moved
    for rid, fixed, paged in _fixed_vs_paged(FAMILY_ARCHS["transformer"],
                                             decode_chunk=3,
                                             kv_dtype="int8"):
        assert fixed == paged, rid
