"""Per-architecture smoke tests: reduced config of the same family, one
train step + prefill + decode step on CPU; asserts shapes and no NaNs.

The FULL configs are exercised only by the dry-run (launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs
from repro.models import build_model, input_specs

ARCHS = sorted(all_configs().keys())


def _batch(cfg, B=2, S=32):
    rng = np.random.RandomState(0)
    tokens = jnp.asarray(rng.randint(1, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.randn(B, cfg.enc_frames, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def smoke(request):
    return {}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = all_configs()[arch].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(api.loss))(params, batch)
    assert np.isfinite(float(loss)), (arch, float(loss))
    gnorm = jnp.sqrt(sum((g.astype(jnp.float32) ** 2).sum()
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)), arch
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    cfg = all_configs()[arch].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    cache, logits = jax.jit(lambda p, b: api.prefill(p, b, cache_len=S + 4)
                            )(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    logits2, cache2 = jax.jit(api.decode_step)(params, cache, tok)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), arch
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_shapes(arch):
    from repro.configs import SHAPES, applicable_shapes
    cfg = all_configs()[arch]
    for sname in applicable_shapes(cfg):
        specs = input_specs(cfg, SHAPES[sname])
        assert specs, (arch, sname)
        for v in jax.tree.leaves(specs):
            assert isinstance(v, jax.ShapeDtypeStruct)


def test_param_counts_sane():
    """Analytic non-embedding param counts must be within 20% of the
    published sizes (sanity that the configs are the right models)."""
    expected = {
        "stablelm-1.6b": 1.4e9, "command-r-plus-104b": 98e9,
        "llama3.2-1b": 1.0e9, "minitron-8b": 6.4e9,
        "mixtral-8x7b": 46e9, "llama4-scout-17b-a16e": 100e9,
        "chameleon-34b": 33e9, "xlstm-1.3b": 1.1e9,
        "whisper-large-v3": 1.4e9, "recurrentgemma-9b": 7.6e9,
    }
    for name, target in expected.items():
        cfg = all_configs()[name]
        api = build_model(cfg)
        n = api.param_count_total()
        assert 0.55 * target < n < 1.8 * target, (name, n, target)
